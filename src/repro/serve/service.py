"""The always-on enumeration service (DESIGN.md §7).

:class:`EnumerationService` turns the PR-1 session API into a long-lived
server with the admission / coalescing / execution split of the slf
exemplar's ``task_manager`` / ``shared_tasks`` design (SNIPPETS.md,
snippet 3),
the same continuous-batching shape production inference stacks use:

* **Admission** (`repro.serve.admission`): many client threads call
  :meth:`submit`; each query passes per-tenant quota + global
  backpressure checks and lands in a bounded FIFO.  Unsatisfiable
  queries short-circuit to an empty terminal result without queueing.
* **Coalescing** (`repro.serve.coalescer`): the single dispatcher thread
  drains admissions into buckets keyed by
  ``Enumerator.coalesce_key(query) + (collect,)`` and dispatches a
  bucket the moment its lane budget fills or its batch window closes —
  so heterogeneous concurrent load rides the session compile cache at
  one compilation per bucket instead of one per query.
* **Execution**: each dispatch is one ``Enumerator.run_pack`` call —
  inert-lane padded to a fixed ``max_lanes`` so every dispatch of a
  bucket reuses one jitted engine; overflowed lanes ride the PR-4
  doubled-``stack_cap`` retry and report ``retries`` in their terminal
  status.  Results stream back per client as chunked match-mapping
  slices (`repro.serve.stream`), and `repro.serve.metrics` records QPS,
  queue depth, batch occupancy, latency percentiles, and cache hit rate.

All JAX dispatch happens on the dispatcher thread; client threads only
touch numpy (query preparation) and thread-safe queues.  One dispatcher
is the right shape for one device — packs, not threads, are the
parallelism axis (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Union

from repro.core.engine import EngineConfig
from repro.core.graph import Graph, PackedGraph
from repro.core.session import Enumerator, Query, SubgraphIndex
from repro.serve.admission import AdmissionQueue, Backpressure, QuotaExceeded, Request
from repro.serve.coalescer import Coalescer
from repro.serve.metrics import ServiceMetrics
from repro.serve.stream import ResultChunk, ResultStatus, ResultStream

__all__ = [
    "EnumerationService", "ServiceConfig",
    "Backpressure", "QuotaExceeded",
]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the serving layer (the engine's own knobs live in
    :class:`~repro.core.engine.EngineConfig`).

    Attributes:
      max_lanes: pack width of every dispatch; buckets dispatch early when
        this many queries coalesce.  Also the vmapped engine's lane count,
        so one compilation per bucket serves every dispatch.
      batch_window_s: longest a pending query waits for lane-mates before
        its bucket dispatches partially filled.
      max_queue_depth: global admission bound (backpressure past it).
      max_outstanding_per_tenant: per-tenant quota on queued + in-flight
        queries (immediate reject past it).
      chunk_size: match mappings per streamed :class:`ResultChunk`.
      max_cache_entries: LRU bound handed to the session compile cache
        when the service builds its own :class:`Enumerator` — a long-lived
        server must not grow the cache without limit.
      default_collect: match-materialization budget (per worker) applied
        when ``submit(collect=None)``; 0 = counting mode, no chunks.
      memory_budget_bytes: device-memory budget for resident target planes
        (DESIGN.md §9).  When set (and the service builds its own session)
        the enumerator runs the out-of-core partitioned backend: every
        target is row-partitioned so its padded resident planes fit the
        budget, and partitions stream through the device.  ``None`` keeps
        the whole target resident (the monolithic backends).
      warmup_profile: patterns (or prepared queries) whose engines are
        pre-traced by ``Enumerator.warm`` during :meth:`start`, before the
        dispatcher accepts work — moves the compile stalls of the hot
        coalesce buckets from first-submit latency to startup.
    """

    max_lanes: int = 8
    batch_window_s: float = 0.002
    max_queue_depth: int = 256
    max_outstanding_per_tenant: int = 64
    chunk_size: int = 256
    max_cache_entries: int = 256
    default_collect: int = 0
    memory_budget_bytes: Optional[int] = None
    warmup_profile: tuple = ()


class EnumerationService:
    """A long-lived enumeration server over one :class:`Enumerator` session.

    Typical use::

        svc = EnumerationService(index, n_workers=8, service=ServiceConfig())
        with svc:                                    # start()/stop(drain=True)
            handles = [svc.submit(p, tenant="t0") for p in patterns]
            for h in handles:
                ms = h.result(timeout=60.0)          # terminal MatchSet
        print(svc.stats())                           # metrics snapshot
    """

    def __init__(
        self,
        index: Union[SubgraphIndex, Graph, PackedGraph, None] = None,
        config: Optional[EngineConfig] = None,
        service: Optional[ServiceConfig] = None,
        enumerator: Optional[Enumerator] = None,
        clock=time.monotonic,
        **config_kwargs,
    ):
        self.service_config = service or ServiceConfig()
        sc = self.service_config
        if enumerator is not None:
            if index is not None or config is not None or config_kwargs:
                raise ValueError(
                    "pass either enumerator= or (index/config/**kwargs), not both"
                )
            self.enumerator = enumerator
        else:
            self.enumerator = Enumerator(
                index, config=config,
                max_cache_entries=sc.max_cache_entries,
                memory_budget_bytes=sc.memory_budget_bytes,
                **config_kwargs,
            )
        self._clock = clock
        self.metrics = ServiceMetrics(clock=clock)
        self.admission = AdmissionQueue(
            max_depth=sc.max_queue_depth,
            max_outstanding_per_tenant=sc.max_outstanding_per_tenant,
            clock=clock,
        )
        self.coalescer = Coalescer(
            max_lanes=sc.max_lanes, window_s=sc.batch_window_s, clock=clock,
        )
        # collect -> EngineConfig with that collect_matches budget; stable
        # identities keep the session compile-cache keys stable
        self._cfgs: Dict[int, EngineConfig] = {}
        self._in_flight = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._drain_on_stop = True
        self._warmed = False

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "EnumerationService":
        """Start the dispatcher thread (idempotent).

        If ``ServiceConfig.warmup_profile`` names patterns, their engines
        are pre-traced synchronously first (``Enumerator.warm`` with the
        service's ``default_collect`` budget — the cfg first submits will
        use), so the dispatcher opens with the hot coalesce buckets
        already compiled."""
        if self.service_config.warmup_profile and not self._warmed:
            self._warmed = True
            n = self.enumerator.warm(
                self.service_config.warmup_profile,
                collect_matches=self.service_config.default_collect,
                lanes=self.service_config.max_lanes,
            )
            self.metrics.inc("warmup_compiles", n["compiles"])
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._dispatch_loop, name="sge-serve-dispatch", daemon=True,
            )
            self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = None) -> None:
        """Stop the dispatcher.  ``drain=True`` executes everything already
        admitted or coalescing first; ``drain=False`` fails pending queries
        with a terminal shutdown error."""
        if self._thread is None:
            # never started: resolve whatever queued so clients can't hang
            self._settle_pending(drain)
            return
        self._drain_on_stop = drain
        self._stop.set()
        self.admission.kick()
        self._thread.join(timeout)
        self._thread = None

    def __enter__(self) -> "EnumerationService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)

    # -- client surface ----------------------------------------------------

    def submit(
        self,
        query: Union[Query, Graph],
        tenant: str = "default",
        name: Optional[str] = None,
        collect: Optional[int] = None,
        timeout: Optional[float] = None,
    ) -> ResultStream:
        """Submit one query; returns its :class:`ResultStream` immediately.

        ``query`` is a prepared :class:`Query` or a raw pattern
        :class:`Graph` (prepared here against the service's index —
        host-side numpy, safe from any thread).  ``collect`` is the
        per-worker match-materialization budget: > 0 streams mapping
        chunks, 0 counts only.  ``timeout`` bounds how long a full queue
        may block this call (backpressure); quota violations reject
        immediately with :class:`QuotaExceeded`.
        """
        t0 = self._clock()
        self.metrics.inc("submitted")
        q = query if isinstance(query, Query) else self.enumerator.prepare(query, name=name)
        collect = self.service_config.default_collect if collect is None else collect
        stream = ResultStream(name=name or q.name, tenant=tenant)
        if not q.plan.satisfiable:
            # answered from the plan alone — no queue slot, no engine
            self.metrics.inc("unsat")
            ms = self.enumerator.run_pack([q], pack_size=1)[0]
            ms.name = stream.name
            stream._finish(ResultStatus(
                ok=True, matchset=ms, error=None, retries=0, n_chunks=0,
                latency_s=self._clock() - t0,
            ))
            self.metrics.observe_completion(self._clock() - t0, retries=0)
            return stream
        req = Request(query=q, tenant=tenant, stream=stream, collect=collect,
                      submitted_at=t0)
        try:
            self.admission.admit(req, timeout=timeout)
        except QuotaExceeded:
            self.metrics.inc("rejected_quota")
            raise
        except Backpressure:
            self.metrics.inc("rejected_backpressure")
            raise
        self.metrics.inc("admitted")
        return stream

    def stats(self) -> Dict[str, float]:
        """Point-in-time metrics snapshot (counters, latency percentiles,
        QPS, batch occupancy, queue gauges, compile-cache stats)."""
        return self.metrics.snapshot(
            cache=self.enumerator.cache_stats(),
            queue_depth=self.admission.depth(),
            coalescing=self.coalescer.pending(),
            in_flight=self._in_flight,
        )

    def update_index(self, add_edges=(), remove_edges=()):
        """Apply an edge-edit set to the live target (DESIGN.md §8).

        Builds the next index version via :meth:`SubgraphIndex.update`
        (incremental bitmap / CSR-plane patching, untouched planes shared),
        swaps it in for queries prepared from now on, and evicts
        compile-cache entries keyed to the retired fingerprint.  Returns
        the :class:`~repro.core.delta.GraphDelta`.

        Safe to call from any client thread while the dispatcher runs:
        queries already prepared keep their own version (coalesce keys and
        engine-cache keys carry the index fingerprint, so versions never
        share a pack or produce a false cache hit), and the swap itself is
        a single attribute assignment.
        """
        old = self.enumerator.index
        if old is None:
            raise ValueError("update_index: service has no index")
        new_index, delta = old.update(
            add_edges=add_edges, remove_edges=remove_edges
        )
        self.metrics.inc("index_updates")
        if delta.is_empty:
            return delta  # no-op edit: same index object, nothing to swap
        self.enumerator.index = new_index
        dropped = self.enumerator.invalidate_index(delta.old_fingerprint)
        if dropped:
            self.metrics.inc("cache_invalidated", dropped)
        return delta

    # -- dispatcher --------------------------------------------------------

    def _bucket_key(self, req: Request) -> tuple:
        return self.enumerator.coalesce_key(
            req.query, self._cfg_for(req.collect)
        ) + (req.collect,)

    def _cfg_for(self, collect: int) -> EngineConfig:
        cfg = self._cfgs.get(collect)
        if cfg is None:
            base = self.enumerator.config
            cfg = base if collect == base.collect_matches else dataclasses.replace(
                base, collect_matches=collect
            )
            self._cfgs[collect] = cfg
        return cfg

    def _dispatch_loop(self) -> None:
        sc = self.service_config
        idle_wait = max(sc.batch_window_s, 1e-3)
        while True:
            deadline = self.coalescer.next_deadline()
            if deadline is None:
                timeout = idle_wait
            else:
                timeout = min(idle_wait, max(deadline - self._clock(), 0.0))
            if self._stop.is_set():
                timeout = 0.0
            for req in self.admission.pop(timeout=timeout):
                self.metrics.observe_queue_wait(self._clock() - req.submitted_at)
                full = self.coalescer.add(self._bucket_key(req), req)
                if full is not None:
                    self._execute(*full)
            for key, batch in self.coalescer.ripe():
                self._execute(key, batch)
            if self._stop.is_set():
                drained = self.admission.depth() == 0 and self.coalescer.pending() == 0
                if not self._drain_on_stop:
                    self._settle_pending(drain=False)
                    return
                if drained:
                    return

    def _settle_pending(self, drain: bool) -> None:
        """Resolve everything still queued/coalescing — executed (drain)
        or failed with a shutdown status — so no client blocks forever."""
        batches = [(self._bucket_key(r), [r]) for r in self.admission.pop(timeout=0)]
        batches += self.coalescer.flush()
        for key, batch in batches:
            if drain:
                self._execute(key, batch)
            else:
                for req in batch:
                    self._fail(req, "service stopped before execution")

    def _fail(self, req: Request, error: str) -> None:
        req.stream._finish(ResultStatus(
            ok=False, matchset=None, error=error, retries=0, n_chunks=0,
            latency_s=self._clock() - req.submitted_at,
        ))
        self.admission.release(req.tenant)
        self.metrics.observe_completion(
            self._clock() - req.submitted_at, retries=0, ok=False,
        )

    def _execute(self, key: tuple, batch: list) -> None:
        """Run one coalesced bucket as a single padded pack and deliver."""
        sc = self.service_config
        cfg = self._cfg_for(batch[0].collect)
        self._in_flight = len(batch)
        try:
            try:
                results = self.enumerator.run_pack(
                    [r.query for r in batch], pack_size=sc.max_lanes, cfg=cfg,
                )
            except Exception as e:  # noqa: BLE001 — server must not die
                for req in batch:
                    self._fail(req, f"{type(e).__name__}: {e}")
                return
            self.metrics.observe_dispatch(len(batch), sc.max_lanes)
            for req, ms in zip(batch, results):
                n_chunks = 0
                if req.collect:
                    maps = ms.mappings()  # decodes the pack's match buffer
                    for start in range(0, len(maps), sc.chunk_size):
                        part = maps[start:start + sc.chunk_size]
                        req.stream._push_chunk(ResultChunk(
                            seq=n_chunks,
                            mappings=tuple(part),
                            final=start + sc.chunk_size >= len(maps),
                        ))
                        n_chunks += 1
                    self.metrics.inc("chunks", n_chunks)
                latency = self._clock() - req.submitted_at
                req.stream._finish(ResultStatus(
                    ok=True, matchset=ms, error=None, retries=ms.retries,
                    n_chunks=n_chunks, latency_s=latency,
                ))
                self.admission.release(req.tenant)
                self.metrics.observe_completion(latency, retries=ms.retries)
        finally:
            self._in_flight = 0
