"""GNN substrate: message passing via ``segment_sum`` over edge indices.

JAX sparse is BCOO-only, so the message-passing primitive here is built from
first principles (per the brief): gather source-node features along the edge
list, transform, and scatter-reduce to destinations with
``jax.ops.segment_sum`` / ``segment_max``.  This substrate also backs the SGE
engine's roofline comparisons — subgraph enumeration *is* an edge-gather
workload (DESIGN.md §4).

Edge tensors carry the logical axis ``edge`` (sharded over
``('pod','data','model')`` when divisible) so the scatter-add becomes a
cross-shard psum under GSPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constraint
from repro.models.common import ParamSpec, dot


@dataclasses.dataclass(frozen=True)
class GraphShape:
    """Static shape of a (possibly batched) graph input."""

    n_nodes: int
    n_edges: int
    d_feat: int
    n_graphs: int = 1  # > 1 for batched small graphs (molecule shape)
    d_edge_feat: int = 0
    with_positions: bool = False


def segment_sum(data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_mean(data, segment_ids, num_segments):
    s = jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)
    cnt = jax.ops.segment_sum(
        jnp.ones((data.shape[0],), jnp.float32), segment_ids, num_segments=num_segments
    )
    return s / jnp.maximum(cnt, 1.0)[:, None]


def segment_max(data, segment_ids, num_segments):
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


AGGREGATORS = {"sum": segment_sum, "mean": segment_mean, "max": segment_max}


def gather_src(h: jnp.ndarray, src: jnp.ndarray) -> jnp.ndarray:
    """Edge-wise gather of source-node features; edge-sharded."""
    msg = jnp.take(h, src, axis=0)
    return constraint(msg, ("edge", None))


def sym_norm_weights(src: jnp.ndarray, dst: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    """GCN symmetric normalization 1/sqrt((deg(u)+1)(deg(v)+1)) per edge
    (self-loops folded into the +1)."""
    ones = jnp.ones((src.shape[0],), jnp.float32)
    deg = jax.ops.segment_sum(ones, dst, num_segments=n_nodes) + 1.0
    return jax.lax.rsqrt(jnp.take(deg, src)) * jax.lax.rsqrt(jnp.take(deg, dst))


def mlp_specs(dims: Sequence[int], prefix: str, dtype=jnp.float32) -> Dict[str, ParamSpec]:
    """Param specs for a plain MLP ``dims[0] -> ... -> dims[-1]``."""
    out: Dict[str, ParamSpec] = {}
    for i in range(len(dims) - 1):
        out[f"{prefix}_w{i}"] = ParamSpec(
            (dims[i], dims[i + 1]), (None, "tensor" if i == 0 else None), dtype
        )
        out[f"{prefix}_b{i}"] = ParamSpec((dims[i + 1],), (None,), dtype, init="zeros")
    return out


def mlp_apply(params: Dict[str, jnp.ndarray], prefix: str, x: jnp.ndarray,
              n_layers: int, act=jax.nn.relu, final_act: bool = False) -> jnp.ndarray:
    for i in range(n_layers):
        x = dot(x, params[f"{prefix}_w{i}"]) + params[f"{prefix}_b{i}"]
        if i < n_layers - 1 or final_act:
            x = act(x)
    return x


def masked_softmax_ce(logits: jnp.ndarray, labels: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross entropy over nodes; ``labels < 0`` masked out."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), jnp.maximum(labels, 0)[:, None], axis=1
    )[:, 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, jnp.sum(mask)
