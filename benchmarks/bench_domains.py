"""Domain-preprocessing benchmark: host loop vs jitted device fixpoint vs
Pallas-interpret, plus a prune-quality table (AC → FC vs AC ⇄ FC).

  PYTHONPATH=src python -m benchmarks.bench_domains [--patterns N] [--smoke]

Three ways to compute RI-DS domains for a ≥ 32-pattern same-bucket batch
(DESIGN.md §5):

  * ``host``   — the numpy oracle, one Python arc-loop per query (the old
    `core/domains.py` path and still the correctness reference);
  * ``jitted`` — the device fixpoint, **one vmapped jitted call** for the
    whole padded batch (the `Enumerator.prepare_batch` backend);
  * ``pallas`` — the same engine with the row-AND-any reduction routed
    through the Pallas kernels in **interpret mode** (semantics validation;
    slower than jnp on CPU — see API.md's use_pallas caveat), measured on a
    small slice.

Asserts (the CI smoke gate):

  * device bits == numpy-oracle bits for every pattern and both variants;
  * the batched jitted call beats the per-query host loop in wall-clock;
  * AC ⇄ FC (ri-ds-si-acfc) domains are never larger than AC → FC.

Emits CSV rows (name, us_per_query, derived) and a JSON artifact.
"""

from __future__ import annotations

import argparse
import time

try:
    from benchmarks import common
except ImportError:  # executed from an arbitrary cwd
    import repro.bench  # noqa: F401  (puts the repo root on sys.path)
    from benchmarks import common

import numpy as np

from repro.core import SubgraphIndex
from repro.core import domains as dom_mod
from repro.core.graph import popcount
from repro.data import graphgen


def _corpus(n_patterns: int, smoke: bool, seed: int):
    n, m = (90, 360) if smoke else (200, 900)
    tgt = graphgen.random_graph(n, m, n_labels=4, seed=seed)
    pats = [graphgen.extract_pattern(tgt, 5 + (i % 4), seed=seed + 1 + i)
            for i in range(n_patterns)]
    return tgt, pats


def run(n_patterns: int = 32, smoke: bool = False, seed: int = 7) -> dict:
    assert n_patterns >= 32, "the acceptance criterion is a >=32-pattern batch"
    tgt, pats = _corpus(n_patterns, smoke, seed)
    index = SubgraphIndex.build(tgt)
    packed = index.packed

    # one shared shape bucket (pads = corpus maxima) => one compilation
    dims = [dom_mod.domain_bucket(p) for p in pats]
    p_pad = max(d[0] for d in dims)
    a_pad = max(d[1] for d in dims)
    l_pad = max(d[2] for d in dims)

    flags = dict(use_ac=True, use_fc=True, interleave=False)

    def batch(use_pallas=False, patterns=pats, interleave=False):
        return dom_mod.compute_domains_batch(
            patterns, packed, use_ac=True, use_fc=True, interleave=interleave,
            use_pallas=use_pallas, p_pad=p_pad, arc_pad=a_pad, loop_pad=l_pad,
            batch_pad=len(patterns),
        )

    def best_of(fn, reps=3):
        """Best wall-clock of ``reps`` runs (de-noises the CI smoke gate)."""
        best, out = float("inf"), None
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best, out

    # --- host loop (the old per-query path; correctness reference) --------
    t_host, host = best_of(
        lambda: [dom_mod.compute_domains(p, packed, **flags) for p in pats]
    )

    # --- jitted batched device fixpoint ----------------------------------
    batch()  # warm-up: one compilation per bucket is the amortized regime
    t_jit, dev = best_of(batch)

    for h, d in zip(host, dev):
        assert h.satisfiable == d.satisfiable
        np.testing.assert_array_equal(h.bits, d.bits)
    assert t_jit < t_host, (
        f"batched device preprocessing ({t_jit:.3f}s) must beat the "
        f"per-query host loop ({t_host:.3f}s) on a {n_patterns}-pattern batch"
    )

    # --- Pallas interpret mode (semantics check; small slice) -------------
    n_pal = 2 if smoke else 4
    pal_pats = pats[:n_pal]
    batch(use_pallas=True, patterns=pal_pats)  # warm-up
    t_pal, pal = best_of(lambda: batch(use_pallas=True, patterns=pal_pats),
                         reps=1 if smoke else 2)
    for h, d in zip(host[:n_pal], pal):
        np.testing.assert_array_equal(h.bits, d.bits)

    # --- prune quality: AC -> FC vs AC <-> FC -----------------------------
    batch(interleave=True)  # warm-up (separate static-flag compilation)
    t_joint, joint = best_of(lambda: batch(interleave=True))
    bits_seq = sum(int(popcount(r.bits).sum()) for r in dev)
    bits_joint = sum(int(popcount(r.bits).sum()) for r in joint)
    tightened = sum(
        1 for a, b in zip(dev, joint)
        if int(popcount(b.bits).sum()) < int(popcount(a.bits).sum())
        or (a.satisfiable and not b.satisfiable)
    )
    assert bits_joint <= bits_seq, "AC ⇄ FC may never enlarge domains"

    n = len(pats)
    print("variant,total_domain_bits,unsat_queries,queries_tightened")
    print(f"ri-ds-si-fc,{bits_seq},{sum(not r.satisfiable for r in dev)},-")
    print(f"ri-ds-si-acfc,{bits_joint},{sum(not r.satisfiable for r in joint)},{tightened}")
    print()
    print(common.csv_row("domains_host_loop", t_host / n * 1e6, "numpy oracle"))
    print(common.csv_row("domains_jitted_batch", t_jit / n * 1e6,
                         f"speedup={t_host / t_jit:.1f}x bucket=({p_pad},{a_pad},{l_pad})"))
    print(common.csv_row("domains_jitted_acfc", t_joint / n * 1e6, "joint fixpoint"))
    print(common.csv_row("domains_pallas_interpret", t_pal / n_pal * 1e6,
                         f"n={n_pal} (interpret mode: validation, not speed)"))
    payload = dict(
        n_patterns=n,
        bucket=dict(p_pad=p_pad, arc_pad=a_pad, loop_pad=l_pad),
        host_s=t_host,
        jitted_batch_s=t_jit,
        jitted_acfc_s=t_joint,
        pallas_interpret_s=t_pal,
        pallas_patterns=n_pal,
        speedup=t_host / t_jit,
        domain_bits_fc=bits_seq,
        domain_bits_acfc=bits_joint,
        queries_tightened=tightened,
    )
    common.save_json("domains", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--patterns", type=int, default=32)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="small target for CI (same assertions)")
    args = ap.parse_args()
    out = run(n_patterns=args.patterns, smoke=args.smoke, seed=args.seed)
    print(f"\n{out['n_patterns']} patterns, one bucket {out['bucket']}: "
          f"host loop {out['host_s']:.3f}s -> batched device "
          f"{out['jitted_batch_s']:.3f}s ({out['speedup']:.1f}x); "
          f"AC⇄FC tightened {out['queries_tightened']} queries "
          f"({out['domain_bits_fc']} -> {out['domain_bits_acfc']} domain bits)")


if __name__ == "__main__":
    main()
