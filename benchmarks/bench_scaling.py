"""C3 — worker scaling (paper Tables 2/3): BSP speedup at 1/2/4/8/16 workers,
split into short and long instances.

Expected, per the paper: speedup grows with workers on long instances
(5.96 / 5.21 / 9.49 at 16 workers on the three collections); short instances
benefit little or regress.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.core import EngineConfig

WORKERS = (1, 2, 4, 8, 16)
LONG_THRESHOLD_STATES = 20_000  # "long-running" split (deterministic proxy
# for the paper's 1-second wall-time split)


def run(scale: float = 0.5, seed: int = 7) -> Dict:
    collections = common.bench_instances(scale=scale, seed=seed)
    out: Dict[str, Dict] = {}
    for cname, instances in collections.items():
        cache: dict = {}
        # classify by single-worker states
        base_cfg = EngineConfig(n_workers=1, expand_width=4)
        base_runs = {i.name: common.run_instance(i, cfg=base_cfg, packed_cache=cache)
                     for i in instances}
        rows: List[Dict] = []
        for v in WORKERS:
            cfg = EngineConfig(n_workers=v, expand_width=4)
            for inst in instances:
                b = base_runs[inst.name]
                if b.states == 0:
                    continue
                r = common.run_instance(inst, cfg=cfg, packed_cache=cache)
                assert r.matches == b.matches, (inst.name, v)
                rows.append(dict(
                    instance=inst.name, workers=v, steps=r.steps,
                    base_steps=b.steps, states=b.states,
                    long=b.states >= LONG_THRESHOLD_STATES,
                    speedup=b.steps / max(r.steps, 1),
                ))
        out[cname] = summarize(rows)
        out[cname]["_rows"] = rows
    common.save_json("scaling", out)
    return out


def summarize(rows: List[Dict]) -> Dict:
    summary: Dict[str, Dict] = {}
    for v in WORKERS:
        vr = [r for r in rows if r["workers"] == v]
        for split in ("all", "short", "long"):
            sel = [
                r for r in vr
                if split == "all"
                or (split == "long") == r["long"]
            ]
            if not sel:
                continue
            sp = np.array([r["speedup"] for r in sel])
            tot_base = sum(r["base_steps"] for r in sel)
            tot = sum(r["steps"] for r in sel)
            summary.setdefault(split, {})[v] = {
                "avg": float(tot_base / max(tot, 1)),  # aggregate (paper's avg)
                "gmean": float(np.exp(np.mean(np.log(np.maximum(sp, 1e-9))))),
                "max": float(sp.max()),
                "n": len(sel),
            }
    return summary


def emit_csv(out: Dict) -> List[str]:
    lines = []
    for cname, summ in out.items():
        for split in ("all", "short", "long"):
            if split not in summ:
                continue
            for v, s in summ[split].items():
                lines.append(common.csv_row(
                    f"scaling/{cname}/{split}/w{v}", 0.0,
                    f"avg={s['avg']:.2f};gmean={s['gmean']:.2f};"
                    f"max={s['max']:.2f};n={s['n']}",
                ))
    return lines


if __name__ == "__main__":
    print("\n".join(emit_csv(run())))
