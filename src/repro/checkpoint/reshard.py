"""Elastic re-sharding: restore a checkpoint onto a different mesh.

Checkpoints store *logical* (unsharded) arrays (store.py), so elasticity is
a placement problem, not a data problem: given the restored host arrays and
a new mesh, ``place`` produces jax arrays with shardings derived from the
model's logical axes on the *new* mesh.  A job that loses a pod restarts on
the smaller mesh with the same checkpoint; divisibility degradation (a dim no
longer divisible by the new axis product) falls back to replication per
`repro.distributed.shardings.logical_to_pspec`.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh

from repro.distributed.shardings import named_sharding


def place(host_tree: Any, logical_tree: Any, mesh: Mesh):
    """Device-put a host pytree with shardings from logical axes on ``mesh``."""

    def put(arr, logical):
        sh = named_sharding(logical, arr.shape, mesh)
        return jax.device_put(arr, sh)

    return jax.tree.map(
        put,
        host_tree,
        logical_tree,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple))
        or (isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)),
    )
