"""Shared test fixtures and strategies.

NOTE: no XLA_FLAGS here — tests must see the real (1-device) platform; only
launch/dryrun.py forces the 512-placeholder-device environment.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.graph import Graph


def random_graph(rng, n, m, n_labels=1, n_elabs=1, undirected=True,
                 selfloops=0) -> Graph:
    """Random labeled graph; ``selfloops`` adds that many loop edges ``(u, u)``
    on distinct nodes (patterns extracted from the graph inherit them)."""
    edges = set()
    tries = 0
    while len(edges) < m and tries < 40 * m:
        u, v = rng.integers(0, n, 2)
        tries += 1
        if u == v:
            continue
        if (u, v) in edges or (undirected and (v, u) in edges):
            continue
        edges.add((int(u), int(v)))
    edges = sorted(edges)
    if selfloops:
        for u in rng.choice(n, size=min(selfloops, n), replace=False):
            edges.append((int(u), int(u)))
    return Graph.from_edges(
        n,
        edges,
        labels=rng.integers(0, n_labels, n),
        edge_labels=rng.integers(0, n_elabs, len(edges)),
        undirected=undirected,
    )


def bump_edge_label(g: Graph, edge_idx: int, new_label: int) -> Graph:
    """Copy of ``g`` with one edge's label replaced — used to produce
    patterns whose edge label is out of the target's label range."""
    elabs = g.edge_labels.copy()
    elabs[edge_idx] = new_label
    return Graph(n=g.n, src=g.src, dst=g.dst, labels=g.labels, edge_labels=elabs)


def extract_connected_pattern(rng, g: Graph, n_nodes: int) -> Graph:
    start = int(rng.integers(g.n))
    nodes = [start]
    seen = {start}
    while len(nodes) < n_nodes:
        frontier = set()
        for u in nodes:
            frontier |= set(g.neighbors(u).tolist())
        frontier -= seen
        if not frontier:
            break
        nxt = int(rng.choice(sorted(frontier)))
        nodes.append(nxt)
        seen.add(nxt)
    idx = {u: i for i, u in enumerate(nodes)}
    edges, elabs = [], []
    for u, v, l in zip(g.src.tolist(), g.dst.tolist(), g.edge_labels.tolist()):
        if u in idx and v in idx:
            edges.append((idx[u], idx[v]))
            elabs.append(l)
    return Graph.from_edges(
        len(nodes), edges, labels=g.labels[nodes], edge_labels=elabs
    )


def power_law_target(rng, n, avg_deg=4.0, alpha=2.0, n_labels=8,
                     n_edge_labels=1, selfloops=0) -> Graph:
    """Large-sparse random target (power-law degrees, ``n_t ≫`` engine
    lanes) — the regime the CSR step backend exists for.  Hub rows are long,
    tail nodes near-isolated (many degenerate zero-length ``indptr`` runs),
    so CSR paths are exercised at realistic sparsity rather than on dense
    toy graphs.  ``selfloops`` appends loop edges on distinct nodes, as in
    :func:`random_graph`."""
    from repro.data.graphgen import power_law_graph

    g = power_law_graph(
        n, avg_deg=avg_deg, alpha=alpha, n_labels=n_labels,
        n_edge_labels=n_edge_labels, seed=int(rng.integers(2**31)),
    )
    if not selfloops:
        return g
    edges = list(zip(g.src.tolist(), g.dst.tolist()))
    elabs = g.edge_labels.tolist()
    for u in rng.choice(n, size=min(selfloops, n), replace=False):
        edges.append((int(u), int(u)))
        elabs.append(int(rng.integers(0, n_edge_labels)))
    return Graph.from_edges(n, edges, labels=g.labels, edge_labels=elabs)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
