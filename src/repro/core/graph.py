"""Graph representations for subgraph enumeration.

Two forms:

* :class:`Graph` — host-side (numpy) labeled directed multigraph-free graph
  with CSR adjacency.  Used by preprocessing (ordering, domains) and by the
  pure-Python reference oracle.
* :class:`PackedGraph` — device-friendly packed-bitmap adjacency.  Row ``u``
  of ``adj_out`` has bit ``v`` set iff the edge ``(u, v)`` exists; ``adj_in``
  has bit ``v`` set in row ``u`` iff ``(v, u)`` exists.  Bitmaps are stored
  per edge label so that edge-label compatibility is a pure bitwise AND.

The paper's target graphs (PPIS32 / GRAEMLIN32 / PDBSv1) have at most ~33k
nodes, so an ``n x ceil(n/32)`` uint32 bitmap costs at most ~136 MB — and is
sharded over the mesh ``model`` axis at scale (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

WORD_BITS = 32


def n_words(n: int) -> int:
    """Number of uint32 words needed to hold ``n`` bits."""
    return max(1, (n + WORD_BITS - 1) // WORD_BITS)


@dataclasses.dataclass(frozen=True)
class Graph:
    """A directed, node- and edge-labeled graph (host side, numpy).

    Undirected graphs are represented by storing both arcs.
    """

    n: int
    src: np.ndarray  # [m] int32
    dst: np.ndarray  # [m] int32
    labels: np.ndarray  # [n] int32 node labels
    edge_labels: np.ndarray  # [m] int32

    # ---- constructors -------------------------------------------------
    @staticmethod
    def from_edges(
        n: int,
        edges: Sequence[Tuple[int, int]],
        labels: Optional[Sequence[int]] = None,
        edge_labels: Optional[Sequence[int]] = None,
        undirected: bool = False,
    ) -> "Graph":
        edges = list(edges)
        if undirected:
            edges = edges + [(v, u) for (u, v) in edges]
            if edge_labels is not None:
                edge_labels = list(edge_labels) + list(edge_labels)
        m = len(edges)
        src = np.asarray([e[0] for e in edges], dtype=np.int32)
        dst = np.asarray([e[1] for e in edges], dtype=np.int32)
        if labels is None:
            labels = np.zeros(n, dtype=np.int32)
        if edge_labels is None:
            edge_labels = np.zeros(m, dtype=np.int32)
        g = Graph(
            n=n,
            src=src,
            dst=dst,
            labels=np.asarray(labels, dtype=np.int32),
            edge_labels=np.asarray(edge_labels, dtype=np.int32),
        )
        g.validate()
        return g

    # ---- basic properties ---------------------------------------------
    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_node_labels(self) -> int:
        return int(self.labels.max()) + 1 if self.n else 0

    @property
    def n_edge_labels(self) -> int:
        return int(self.edge_labels.max()) + 1 if self.m else 1

    def validate(self) -> None:
        if self.m:
            assert self.src.min() >= 0 and self.src.max() < self.n
            assert self.dst.min() >= 0 and self.dst.max() < self.n
        assert self.labels.shape == (self.n,)
        assert self.edge_labels.shape == (self.m,)

    # ---- degrees -------------------------------------------------------
    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n).astype(np.int32)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n).astype(np.int32)

    def degrees(self) -> np.ndarray:
        """Total degree (in + out); for undirected graphs this double counts,
        which is consistent as long as it is used consistently."""
        return self.out_degrees() + self.in_degrees()

    # ---- neighborhoods --------------------------------------------------
    def out_neighbors(self, u: int) -> np.ndarray:
        return self.dst[self.src == u]

    def in_neighbors(self, u: int) -> np.ndarray:
        return self.src[self.dst == u]

    def neighbors(self, u: int) -> np.ndarray:
        return np.unique(np.concatenate([self.out_neighbors(u), self.in_neighbors(u)]))

    def has_edge(self, u: int, v: int) -> bool:
        return bool(np.any((self.src == u) & (self.dst == v)))

    def edge_label(self, u: int, v: int) -> int:
        idx = np.nonzero((self.src == u) & (self.dst == v))[0]
        if idx.size == 0:
            raise KeyError((u, v))
        return int(self.edge_labels[idx[0]])

    # ---- adjacency structures -------------------------------------------
    def csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Out-adjacency CSR: (indptr [n+1], indices [m], edge_labels [m]).

        Canonical form: ``indices`` are **sorted within each row** (by
        destination, then edge label for parallel edges), so segment
        consumers can binary-search / sorted-intersect them directly.
        Duplicate edges are kept — this is an edge-list CSR; the per-plane
        form the CSR step backend consumes (:func:`csr_planes`) dedupes.
        Degenerate rows (isolated vertices) are zero-length ``indptr`` runs.
        """
        order = np.lexsort((self.edge_labels, self.dst, self.src))
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        if self.m:
            np.add.at(indptr, self.src.astype(np.int64) + 1, 1)
        indptr = np.cumsum(indptr)
        return indptr, self.dst[order], self.edge_labels[order]

    def csr_planes(self, n_elab: Optional[int] = None) -> "CsrPlanes":
        """Per-``(edge_label, direction)`` canonical CSR adjacency planes —
        the sparse twin of :meth:`adjacency_bitmaps` (see :class:`CsrPlanes`).

        Plane ``l*2 + 0`` row ``u`` lists ``v`` with ``(u, v) ∈ E`` label
        ``l``; plane ``l*2 + 1`` row ``u`` lists ``v`` with ``(v, u) ∈ E``.
        Rows are sorted ascending and **deduplicated** (duplicate arcs set
        the same adjacency bit once), making each plane bit-for-bit the
        dense bitmap's support.
        """
        nl = n_elab if n_elab is not None else self.n_edge_labels
        if self.m and int(self.edge_labels.max()) >= nl:
            raise ValueError(
                f"edge label {int(self.edge_labels.max())} >= n_elab={nl}"
            )
        n = self.n
        # flat row keys: (elab * 2 + dir) * n + row_node
        out_key = (self.edge_labels.astype(np.int64) * 2 + 0) * n + self.src
        in_key = (self.edge_labels.astype(np.int64) * 2 + 1) * n + self.dst
        keys = np.concatenate([out_key, in_key])
        cols = np.concatenate([self.dst, self.src]).astype(np.int64)
        order = np.lexsort((cols, keys))
        keys, cols = keys[order], cols[order]
        if keys.size:
            keep = np.ones(keys.size, dtype=bool)
            keep[1:] = (keys[1:] != keys[:-1]) | (cols[1:] != cols[:-1])
            keys, cols = keys[keep], cols[keep]
        return _assemble_csr_planes(keys, cols, 2 * nl, n)

    def adjacency_bitmaps(self, w: Optional[int] = None) -> np.ndarray:
        """Packed adjacency bitmaps ``[n_edge_labels, 2, n, w]`` uint32.

        ``[l, 0, u]`` row: bit ``v`` set iff ``(u, v) in E`` with label ``l``
        ``[l, 1, u]`` row: bit ``v`` set iff ``(v, u) in E`` with label ``l``
        """
        w = w or n_words(self.n)
        nl = self.n_edge_labels
        bits = np.zeros((nl, 2, self.n, w), dtype=np.uint32)
        word = (self.dst // WORD_BITS).astype(np.int64)
        bit = np.uint32(1) << (self.dst % WORD_BITS).astype(np.uint32)
        np.bitwise_or.at(bits, (self.edge_labels, 0, self.src, word), bit)
        word_in = (self.src // WORD_BITS).astype(np.int64)
        bit_in = np.uint32(1) << (self.src % WORD_BITS).astype(np.uint32)
        np.bitwise_or.at(bits, (self.edge_labels, 1, self.dst, word_in), bit_in)
        return bits

    def partition(
        self,
        n_parts: Optional[int] = None,
        max_bytes: Optional[int] = None,
        n_elab: Optional[int] = None,
    ) -> "PartitionedPlanes":
        """Degree-aware contiguous CSR partitioning of this graph's canonical
        adjacency planes (see :func:`partition_csr_planes`).  Exactly one of
        ``n_parts=`` / ``max_bytes=`` selects the partition count."""
        return partition_csr_planes(
            self.csr_planes(n_elab), n_parts=n_parts, max_bytes=max_bytes
        )


@dataclasses.dataclass(frozen=True)
class PackedGraph:
    """Device-friendly packed form of a target graph.

    Attributes:
      n: number of target nodes.
      w: number of uint32 words per node bitmap row (``>= ceil(n/32)``).
      adj_bits: ``[n_edge_labels, 2, n, w]`` uint32 adjacency bitmaps.
      labels: ``[n]`` int32.
      deg_out / deg_in: ``[n]`` int32.
    """

    n: int
    w: int
    adj_bits: np.ndarray
    labels: np.ndarray
    deg_out: np.ndarray
    deg_in: np.ndarray

    @staticmethod
    def from_graph(g: Graph, w: Optional[int] = None, pad_words_to: int = 1) -> "PackedGraph":
        w = w or n_words(g.n)
        if pad_words_to > 1:
            w = ((w + pad_words_to - 1) // pad_words_to) * pad_words_to
        return PackedGraph(
            n=g.n,
            w=w,
            adj_bits=g.adjacency_bitmaps(w),
            labels=g.labels.copy(),
            deg_out=g.out_degrees(),
            deg_in=g.in_degrees(),
        )

    @property
    def n_edge_labels(self) -> int:
        return int(self.adj_bits.shape[0])


@dataclasses.dataclass(frozen=True)
class CsrPlanes:
    """Canonical per-``(edge_label, direction)`` CSR adjacency (host numpy)
    — the sparse layout behind the engine's ``step_backend="csr"``.

    One flat ``indices`` array holds every plane's rows back to back;
    ``indptr[p, t]`` / ``indptr[p, t + 1]`` bound row ``t`` of plane
    ``p = elab * 2 + dir`` as **global** offsets into ``indices`` (so
    ``indptr[p, n_t] == indptr[p + 1, 0]``).  Rows are sorted ascending and
    deduplicated; an isolated vertex is a zero-length run.  Footprint is
    ``O(nnz + n_planes · n_t)`` words versus the dense bitmaps'
    ``O(n_planes · n_t · w)`` — the reason this layout exists
    (DESIGN.md §6.4).
    """

    n_t: int
    indptr: np.ndarray  # [n_planes, n_t + 1] int32, global offsets
    indices: np.ndarray  # [nnz] int32, sorted + deduped per row
    deg_cap: int  # max row length over all planes

    @property
    def n_planes(self) -> int:
        return int(self.indptr.shape[0])

    @property
    def n_edge_labels(self) -> int:
        return self.n_planes // 2

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.indptr.nbytes + self.indices.nbytes)


@dataclasses.dataclass(frozen=True)
class CsrPlaneSet:
    """Per-plane CSR adjacency with independently owned buffers — the
    mutable-friendly twin of :class:`CsrPlanes` behind
    ``SubgraphIndex.update()`` (DESIGN.md §8).

    :class:`CsrPlanes` stores every plane in one flat ``indices`` array, so
    patching a single row would force a full copy of all planes.  Here each
    plane ``p = elab * 2 + dir`` owns its own ``(indptr, indices)`` pair:
    :meth:`patched` rebuilds only the planes a delta touches and **shares the
    other planes' arrays by reference** (asserted by ``id()`` in
    ``tests/test_incremental_conformance.py``).  :meth:`to_planes` concatenates
    back to the canonical flat layout without re-sorting — rows are already
    sorted and deduplicated.

    ``indptrs[p]`` is ``[n_t + 1]`` int64 with plane-local offsets;
    ``indices[p]`` is ``[nnz_p]`` int32 sorted + deduped per row.
    """

    n_t: int
    indptrs: Tuple[np.ndarray, ...]
    indices: Tuple[np.ndarray, ...]

    @property
    def n_planes(self) -> int:
        return len(self.indptrs)

    @property
    def nnz(self) -> int:
        return sum(int(ix.shape[0]) for ix in self.indices)

    @staticmethod
    def from_bitmaps(adj_bits: np.ndarray) -> "CsrPlaneSet":
        """Split the canonical flat planes of ``adj_bits`` into per-plane
        buffers (row content bit-identical to :func:`csr_planes_from_bitmaps`)."""
        flat = csr_planes_from_bitmaps(adj_bits)
        base = flat.indptr.astype(np.int64)
        indptrs, indices = [], []
        for p in range(flat.n_planes):
            ptr = base[p]
            indptrs.append(np.ascontiguousarray(ptr - ptr[0]))
            indices.append(np.ascontiguousarray(flat.indices[ptr[0] : ptr[-1]]))
        return CsrPlaneSet(n_t=flat.n_t, indptrs=tuple(indptrs), indices=tuple(indices))

    def grown(self, n_planes: int) -> "CsrPlaneSet":
        """Append empty planes up to ``n_planes`` (existing buffers shared)."""
        if n_planes <= self.n_planes:
            return self
        extra = n_planes - self.n_planes
        empty_ptr = np.zeros(self.n_t + 1, dtype=np.int64)
        empty_idx = np.zeros(0, dtype=np.int32)
        return CsrPlaneSet(
            n_t=self.n_t,
            indptrs=self.indptrs + tuple(empty_ptr for _ in range(extra)),
            indices=self.indices + tuple(empty_idx for _ in range(extra)),
        )

    def patched(self, plane_rows: dict) -> "CsrPlaneSet":
        """New plane set with ``plane_rows[p][row] = sorted indices`` spliced
        in.  Only planes appearing in ``plane_rows`` get new buffers; every
        other plane's ``(indptr, indices)`` arrays are reused as-is."""
        indptrs = list(self.indptrs)
        indices = list(self.indices)
        for p, rows in plane_rows.items():
            if not rows:
                continue
            ptr, idx = indptrs[p], indices[p]
            lens = np.diff(ptr)
            pieces = []
            prev_end = 0
            for r in sorted(rows):
                s, e = int(ptr[r]), int(ptr[r + 1])
                new_row = np.asarray(rows[r], dtype=np.int32)
                pieces.append(idx[prev_end:s])
                pieces.append(new_row)
                prev_end = e
                lens[r] = new_row.shape[0]
            pieces.append(idx[prev_end:])
            new_ptr = np.zeros(self.n_t + 1, dtype=np.int64)
            np.cumsum(lens, out=new_ptr[1:])
            indptrs[p] = new_ptr
            indices[p] = np.concatenate(pieces) if pieces else idx
        return CsrPlaneSet(n_t=self.n_t, indptrs=tuple(indptrs), indices=tuple(indices))

    def to_planes(self) -> "CsrPlanes":
        """Concatenate to the canonical flat :class:`CsrPlanes` layout.

        No re-sorting happens — per-plane rows are already canonical; only
        the global offsets are recomputed."""
        offsets = np.zeros(self.n_planes + 1, dtype=np.int64)
        np.cumsum([ix.shape[0] for ix in self.indices], out=offsets[1:])
        indptr = np.stack(
            [self.indptrs[p] + offsets[p] for p in range(self.n_planes)]
        ).astype(np.int32)
        flat = (
            np.concatenate(self.indices)
            if self.n_planes
            else np.zeros(0, dtype=np.int32)
        )
        deg_cap = max(
            (int(np.diff(ptr).max()) for ptr in self.indptrs if ptr.shape[0] > 1),
            default=0,
        )
        return CsrPlanes(
            n_t=self.n_t, indptr=indptr, indices=flat.astype(np.int32), deg_cap=deg_cap
        )


@dataclasses.dataclass(frozen=True)
class PartitionedPlanes:
    """A contiguous row-partitioning of :class:`CsrPlanes` — the out-of-core
    target layout behind ``step_backend="partitioned"`` (DESIGN.md §9).

    Partition ``p`` owns global rows ``[node_start[p], node_start[p+1])`` of
    every adjacency plane.  Each entry of ``parts`` is a :class:`CsrPlanes`
    over **local** rows (``n_t`` = partition size, global row ``v`` maps to
    local row ``v - node_start[p]``) whose ``indices`` keep **global** column
    ids — boundary (cut) arcs are *not* replicated into neighbor partitions;
    an extension that needs a non-resident row is parked in the spill
    frontier until its partition is swapped in.  Only one partition's planes
    need be device-resident at a time, so peak plane memory is
    ``max_resident_nbytes`` instead of the whole target's ``nbytes``.
    """

    n_t: int
    node_start: np.ndarray  # [n_parts + 1] int64, node_start[0]=0, [-1]=n_t
    parts: Tuple[CsrPlanes, ...]  # local rows, global columns
    cut_per_part: np.ndarray  # [n_parts] int64 out-arcs leaving the partition

    @property
    def n_parts(self) -> int:
        return len(self.parts)

    @property
    def n_planes(self) -> int:
        return self.parts[0].n_planes if self.parts else 0

    @property
    def cut_edges(self) -> int:
        """Total boundary arcs (row and column in different partitions),
        counted once per out-plane entry."""
        return int(self.cut_per_part.sum())

    @property
    def deg_cap(self) -> int:
        return max((p.deg_cap for p in self.parts), default=0)

    @property
    def max_local(self) -> int:
        """Largest partition row count (pads the shared compile shape)."""
        return max((p.n_t for p in self.parts), default=0)

    @property
    def max_nnz(self) -> int:
        return max((p.nnz for p in self.parts), default=0)

    def part_of(self, nodes: np.ndarray) -> np.ndarray:
        """Owning partition id per global node id."""
        return np.searchsorted(self.node_start, np.asarray(nodes), side="right") - 1

    def resident_nbytes(self, pid: int) -> int:
        """Plane bytes resident while partition ``pid`` is swapped in."""
        return self.parts[pid].nbytes

    @property
    def max_resident_nbytes(self) -> int:
        return max((p.nbytes for p in self.parts), default=0)


def _slice_planes(planes: CsrPlanes, lo: int, hi: int) -> CsrPlanes:
    """Rows ``[lo, hi)`` of every plane as a local-row :class:`CsrPlanes`.

    Each plane's rows are contiguous in the flat ``indices`` array, so the
    slice is a per-plane copy-free gather rebased to partition-local offsets.
    """
    n_loc = hi - lo
    ptr = planes.indptr
    new_ptr = np.zeros((planes.n_planes, n_loc + 1), dtype=np.int64)
    pieces = []
    off = 0
    for p in range(planes.n_planes):
        s, e = int(ptr[p, lo]), int(ptr[p, hi])
        pieces.append(planes.indices[s:e])
        new_ptr[p] = ptr[p, lo : hi + 1].astype(np.int64) - s + off
        off += e - s
    indices = (
        np.concatenate(pieces) if pieces else np.zeros(0, dtype=np.int32)
    ).astype(np.int32)
    deg_cap = int(np.diff(new_ptr, axis=1).max()) if n_loc else 0
    return CsrPlanes(
        n_t=n_loc, indptr=new_ptr.astype(np.int32), indices=indices, deg_cap=deg_cap
    )


def _partition_points(planes: CsrPlanes, n_parts: int) -> np.ndarray:
    """Degree-aware contiguous split: node boundaries chosen so cumulative
    row weight (nnz across planes + indptr words) is balanced per part."""
    n_t = planes.n_t
    n_parts = max(1, min(n_parts, max(n_t, 1)))
    if n_t == 0:
        return np.zeros(n_parts + 1, dtype=np.int64)
    row_nnz = np.diff(planes.indptr.astype(np.int64), axis=1).sum(axis=0)
    weight = row_nnz + planes.n_planes  # + per-row indptr cost
    cum = np.cumsum(weight)
    targets = cum[-1] * (np.arange(1, n_parts, dtype=np.float64) / n_parts)
    cuts = np.searchsorted(cum, targets, side="left") + 1
    starts = np.concatenate([[0], cuts, [n_t]]).astype(np.int64)
    # monotone + in range; equal neighbors yield empty partitions, which is
    # fine (their planes are zero-row) but we nudge to keep ranges valid.
    return np.maximum.accumulate(np.clip(starts, 0, n_t))


def partition_csr_planes(
    planes: CsrPlanes,
    n_parts: Optional[int] = None,
    max_bytes: Optional[int] = None,
) -> PartitionedPlanes:
    """Partition :class:`CsrPlanes` into contiguous degree-balanced row
    ranges (see :class:`PartitionedPlanes`).

    Exactly one of ``n_parts`` / ``max_bytes`` selects the partition count:
    ``max_bytes`` picks the smallest count whose largest partition's resident
    plane bytes fit the budget.  Boundary arcs are never replicated — on
    expander-like graphs the cut is ``O(nnz)``, which would void the memory
    bound; they are counted in ``cut_per_part`` for planning reports.
    """
    if (n_parts is None) == (max_bytes is None):
        raise ValueError("pass exactly one of n_parts= / max_bytes=")
    if n_parts is not None:
        if n_parts < 1:
            raise ValueError(f"n_parts must be >= 1, got {n_parts}")
        candidates = [n_parts]
    else:
        if max_bytes <= 0:
            raise ValueError(f"max_bytes must be > 0, got {max_bytes}")
        first = max(1, -(-planes.nbytes // max_bytes))  # ceil
        candidates = range(first, max(planes.n_t, 1) + 1)

    result = None
    for cand in candidates:
        starts = _partition_points(planes, cand)
        parts = tuple(
            _slice_planes(planes, int(starts[i]), int(starts[i + 1]))
            for i in range(len(starts) - 1)
        )
        result = (starts, parts)
        if max_bytes is None or max(p.nbytes for p in parts) <= max_bytes:
            break
    starts, parts = result
    if max_bytes is not None and max(p.nbytes for p in parts) > max_bytes:
        raise ValueError(
            f"cannot fit any partitioning under max_bytes={max_bytes}: "
            f"smallest achievable resident set is {max(p.nbytes for p in parts)} B"
        )

    # cut accounting: out-plane entries whose column leaves the row's range.
    cut = np.zeros(len(parts), dtype=np.int64)
    for pid, part in enumerate(parts):
        lo, hi = int(starts[pid]), int(starts[pid + 1])
        for p in range(0, part.n_planes, 2):  # out planes only (dir == 0)
            s, e = int(part.indptr[p, 0]), int(part.indptr[p, part.n_t])
            cols = part.indices[s:e]
            cut[pid] += int(np.count_nonzero((cols < lo) | (cols >= hi)))
    return PartitionedPlanes(
        n_t=planes.n_t, node_start=starts, parts=parts, cut_per_part=cut
    )


def _assemble_csr_planes(
    row_keys: np.ndarray, cols: np.ndarray, n_planes: int, n_t: int
) -> CsrPlanes:
    """Shared :class:`CsrPlanes` assembly from (sorted, deduped) flat row
    keys ``plane * n_t + row`` and their column entries — both builders
    (:meth:`Graph.csr_planes`, :func:`csr_planes_from_bitmaps`) must stay
    bit-identical, so the bincount → cumsum → overlapping-``indptr`` logic
    lives once."""
    counts = np.bincount(row_keys, minlength=n_planes * n_t).astype(np.int64)
    flat_ptr = np.zeros(n_planes * n_t + 1, dtype=np.int64)
    np.cumsum(counts, out=flat_ptr[1:])
    if n_t:
        # overlapping [n_planes, n_t + 1] view: row p = flat_ptr[p*n : p*n+n+1]
        indptr = np.stack(
            [flat_ptr[p * n_t : p * n_t + n_t + 1] for p in range(n_planes)]
        ).astype(np.int32)
        deg_cap = int(counts.max()) if counts.size else 0
    else:
        indptr = np.zeros((n_planes, 1), dtype=np.int32)
        deg_cap = 0
    return CsrPlanes(
        n_t=n_t, indptr=indptr, indices=cols.astype(np.int32), deg_cap=deg_cap
    )


def csr_planes_from_bitmaps(adj_bits: np.ndarray) -> CsrPlanes:
    """Convert dense ``[n_elab, 2, n_t, w]`` adjacency bitmaps to
    :class:`CsrPlanes` (bit-for-bit the same adjacency relation) — the
    conformance bridge that lets the CSR step backend run any dense-built
    :class:`~repro.core.plan.SearchPlan`."""
    ne, two, n_t, w = adj_bits.shape
    flat = np.ascontiguousarray(adj_bits.reshape(ne * two * n_t, w))
    # uint32 LSB-first bit unpacking: little-endian byte view + little bitorder
    expanded = np.unpackbits(
        flat.astype("<u4").view(np.uint8).reshape(flat.shape[0], w * 4),
        axis=1, bitorder="little",
    )
    rows, cols = np.nonzero(expanded[:, : max(n_t, 1)])
    return _assemble_csr_planes(rows, cols, ne * two, n_t)


# ---------------------------------------------------------------------------
# degree buckets (hub-aware CSR walk, DESIGN.md §10)
# ---------------------------------------------------------------------------

def deg_bucket_caps(deg_cap: int, base: int = 8) -> Tuple[int, ...]:
    """Pow2 ladder of per-bucket degree caps covering rows up to ``deg_cap``.

    Bucket ``i`` holds rows with length ≤ ``caps[i]`` (and > ``caps[i-1]``):
    ``(base, 2·base, 4·base, …)`` until the last cap reaches ``deg_cap``.
    On power-law targets almost every row lands in the first bucket, so a
    walk clamped to the row's bucket cap does ``O(base)`` work per tail
    lane instead of the global hub-sized ``deg_cap``.
    """
    base = max(1, base)
    caps = [base]
    while caps[-1] < deg_cap:
        caps.append(caps[-1] * 2)
    return tuple(caps)


def deg_bucket_index(deg: np.ndarray, caps: Sequence[int]) -> np.ndarray:
    """Bucket index per row length (``deg == 0`` maps to bucket 0)."""
    caps = np.asarray(caps, dtype=np.int64)
    return np.searchsorted(caps, np.maximum(np.asarray(deg, dtype=np.int64), 1),
                           side="left").astype(np.int64)


# ---------------------------------------------------------------------------
# bitmap helpers (host side)
# ---------------------------------------------------------------------------

def bitmap_from_indices(idx: np.ndarray, n: int, w: Optional[int] = None) -> np.ndarray:
    """Pack node indices into a ``[w]`` uint32 bitmap."""
    w = w or n_words(n)
    out = np.zeros(w, dtype=np.uint32)
    idx = np.asarray(idx, dtype=np.int64)
    np.bitwise_or.at(out, idx // WORD_BITS, np.uint32(1) << (idx % WORD_BITS).astype(np.uint32))
    return out


def bitmap_to_indices(bits: np.ndarray) -> np.ndarray:
    """Unpack a ``[w]`` uint32 bitmap into sorted node indices."""
    b = np.asarray(bits, dtype=np.uint32)
    set_bits = (b[:, None] >> np.arange(WORD_BITS, dtype=np.uint32)) & np.uint32(1)
    wi, bi = np.nonzero(set_bits)
    return (wi * WORD_BITS + bi).astype(np.int64)


def popcount(bits: np.ndarray) -> np.ndarray:
    """Per-row popcount of a uint32 bitmap array (last axis reduced)."""
    b = np.asarray(bits, dtype=np.uint32)
    # SWAR popcount
    b = b - ((b >> 1) & np.uint32(0x55555555))
    b = (b & np.uint32(0x33333333)) + ((b >> 2) & np.uint32(0x33333333))
    b = (b + (b >> 4)) & np.uint32(0x0F0F0F0F)
    return ((b * np.uint32(0x01010101)) >> 24).astype(np.int64).sum(axis=-1)
