"""Benchmark entrypoint — one section per paper table/figure.

  ``PYTHONPATH=src python -m benchmarks.run [--scale S] [--quick]``

Prints ``name,us_per_call,derived`` CSV rows per measurement and saves JSON
artifacts under artifacts/bench/ for EXPERIMENTS.md.

Sections:
  searchspace — paper Figs. 7/8/9/12 (RI-DS vs -SI vs -SI-FC)
  stealing    — paper Fig. 3 + steal-depth (C7)
  coalescing  — paper Fig. 4 (task-group size)
  scaling     — paper Tables 2/3 (worker sweep, short/long split)
  roofline    — §Roofline summary from dry-run artifacts (if present)
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--quick", action="store_true", help="tiny instances")
    ap.add_argument(
        "--sections", default="searchspace,stealing,coalescing,scaling,roofline"
    )
    args = ap.parse_args()
    scale = 0.15 if args.quick else args.scale
    sections = args.sections.split(",")

    print("name,us_per_call,derived")
    t0 = time.time()

    if "searchspace" in sections:
        from benchmarks import bench_searchspace

        out = bench_searchspace.run(scale=scale)
        print("\n".join(bench_searchspace.emit_csv(out)), flush=True)

    if "stealing" in sections:
        from benchmarks import bench_stealing

        out = bench_stealing.run(scale=scale)
        print("\n".join(bench_stealing.emit_csv(out)), flush=True)

    if "coalescing" in sections:
        from benchmarks import bench_coalescing

        out = bench_coalescing.run(scale=scale)
        print("\n".join(bench_coalescing.emit_csv(out)), flush=True)

    if "scaling" in sections:
        from benchmarks import bench_scaling

        out = bench_scaling.run(scale=scale)
        print("\n".join(bench_scaling.emit_csv(out)), flush=True)

    if "roofline" in sections:
        from benchmarks import roofline

        roofline.main()

    print(f"# total benchmark time: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
