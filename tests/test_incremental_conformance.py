"""Incremental-enumeration differential gate (DESIGN.md §8).

The standing invariant of the dynamic-graph subsystem, asserted for every
step backend and every corpus, on counts AND sorted node-indexed mappings:

    ``full(G ± e)  ==  old ⊕ delta(± e)``

The left side is a fresh enumeration of the edited target; the right side
is the prior result patched by ``Enumerator.run_delta`` — removals
invalidate old matches by membership test, insertions are enumerated by
anchoring pattern edges onto the inserted arcs.  Both sides must be
bit-identical, for single-arc and batched multi-arc deltas, across
dense / self-loop / multi-edge-label / power-law corpora, and the engine
path must also agree with the fully independent one-arc-at-a-time numpy
oracle (:func:`repro.core.ref.ref_delta`).

Also locked down here (the PR's satellites):

* plane sharing — ``SubgraphIndex.update`` touching one ``(elab, dir)``
  CSR plane must alias (``is``), not deep-copy, every untouched plane;
* compile-cache versioning — engine-cache and coalesce keys carry the
  index fingerprint, so an update never produces a false cache hit, and
  retired versions can be evicted;
* edit edge cases — duplicate insert, remove-absent, self-loop delete,
  insert+remove of one arc in a single ``update()`` (must cancel to a
  true no-op: same index object, empty delta);
* a hypothesis property test over random edit streams;
* the serving layer's live ``update_index`` swap;
* the mesh path (runs in CI's 4-virtual-device job).
"""

import jax
import numpy as np
import pytest

from repro.core import Enumerator, SubgraphIndex
from repro.core import extend
from repro.core.delta import apply_delta, as_node_mappings, normalize_edges
from repro.core.graph import Graph
from repro.core.ref import ref_delta, ref_node_mappings
from repro.serve import EnumerationService, ServiceConfig
from tests.conftest import (
    extract_connected_pattern,
    power_law_target,
    random_graph,
)

BACKENDS = extend.STEP_BACKENDS


# ---------------------------------------------------------------------------
# corpora: (target, pattern) generators exercising distinct delta shapes
# ---------------------------------------------------------------------------

def _canon(tgt: Graph) -> Graph:
    """Dedupe the arc list (no-edit ``apply_delta``).  The dynamic index is
    defined over arc *sets*; conftest's ``undirected=True`` graphs carry
    doubled self-loop arcs whose bincount degrees disagree with the
    bitmaps, so dynamic corpora start from the canonical form."""
    return apply_delta(tgt)


def _dense(rng):
    tgt = _canon(random_graph(rng, 24, 60, n_labels=2))
    return tgt, extract_connected_pattern(rng, tgt, 4)


def _selfloops(rng):
    tgt = _canon(random_graph(rng, 20, 48, n_labels=1, selfloops=5))
    return tgt, extract_connected_pattern(rng, tgt, 4)


def _multi_elab(rng):
    tgt = _canon(random_graph(rng, 22, 56, n_labels=2, n_elabs=3))
    return tgt, extract_connected_pattern(rng, tgt, 4)


def _power_law(rng):
    tgt = _canon(power_law_target(rng, 300, avg_deg=3.0, n_labels=4, selfloops=2))
    return tgt, extract_connected_pattern(rng, tgt, 4)


CORPORA = {
    "dense": _dense,
    "selfloops": _selfloops,
    "multi_elab": _multi_elab,
    "power_law": _power_law,
}

# ref_delta re-enumerates fully per inserted arc — cross-check it on the
# small corpora only
REF_CORPORA = ("dense", "selfloops", "multi_elab")


def _arcs(g: Graph):
    return list(zip(g.src.tolist(), g.dst.tolist(), g.edge_labels.tolist()))


def _sample_edits(rng, tgt: Graph, k_add=4, k_rem=3, loops=False):
    """A batched delta: ``k_add`` absent arcs to insert (labels within the
    target's range) and ``k_rem`` present arcs to remove."""
    present = _arcs(tgt)
    aset = set(present)
    nl = int(tgt.edge_labels.max()) + 1 if tgt.m else 1
    absent = []
    while len(absent) < k_add:
        u, v = (int(x) for x in rng.integers(0, tgt.n, 2))
        if u == v and not loops:
            continue
        t = (u, v, int(rng.integers(0, nl)))
        if t not in aset and t not in absent:
            absent.append(t)
    rem_idx = rng.choice(len(present), size=min(k_rem, len(present)),
                         replace=False)
    return absent, [present[i] for i in rem_idx]


def _enum(idx, backend, **kw):
    kw.setdefault("n_workers", 4)
    kw.setdefault("expand_width", 2)
    return Enumerator(idx, step_backend=backend, **kw)


def _assert_delta_equals_fresh(enum, pattern, tgt, adds, rems):
    """The differential gate body: run old, update, run_delta, compare to a
    fresh engine run of the edited index on counts and sorted mappings."""
    idx = enum.index
    q = enum.prepare(pattern)
    ms_old = enum.run(q)
    new_idx, delta = idx.update(add_edges=adds, remove_edges=rems)
    q2 = enum.prepare(pattern, index=new_idx)
    dm = enum.run_delta(q2, ms_old, delta)
    fresh = enum.run(q2)
    assert dm.matches == fresh.matches
    assert dm.apply(ms_old) == sorted(as_node_mappings(fresh))
    # the patched index is content-identical to a fresh build
    rebuilt = SubgraphIndex.build(apply_delta(tgt, added=adds, removed=rems))
    np.testing.assert_array_equal(new_idx.packed.adj_bits,
                                  rebuilt.packed.adj_bits)
    np.testing.assert_array_equal(new_idx.packed.deg_out,
                                  rebuilt.packed.deg_out)
    np.testing.assert_array_equal(new_idx.packed.deg_in,
                                  rebuilt.packed.deg_in)
    return dm, new_idx


# ---------------------------------------------------------------------------
# the differential gate, every backend x every corpus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("corpus", sorted(CORPORA))
@pytest.mark.parametrize("backend", BACKENDS)
def test_delta_equals_fresh(rng, backend, corpus):
    """``full(G±e) == old ⊕ delta(±e)`` for a batched mixed delta, on
    counts and sorted node-indexed mappings."""
    tgt, pat = CORPORA[corpus](rng)
    adds, rems = _sample_edits(rng, tgt, k_add=4, k_rem=3,
                               loops=corpus == "selfloops")
    enum = _enum(SubgraphIndex.build(tgt), backend)
    _assert_delta_equals_fresh(enum, pat, tgt, adds, rems)


@pytest.mark.parametrize("kind", ("add_only", "remove_only", "single_arc"))
def test_delta_kinds(rng, kind):
    """Pure-insert, pure-remove, and single-arc deltas all satisfy the
    gate (the batched mixed case above covers the general shape)."""
    tgt, pat = _dense(rng)
    adds, rems = _sample_edits(rng, tgt, k_add=4, k_rem=3)
    if kind == "add_only":
        rems = []
    elif kind == "remove_only":
        adds = []
    else:
        adds, rems = adds[:1], []
    enum = _enum(SubgraphIndex.build(tgt), "jnp")
    _assert_delta_equals_fresh(enum, pat, tgt, adds, rems)


@pytest.mark.parametrize("corpus", REF_CORPORA)
def test_delta_matches_ref_oracle(rng, corpus):
    """The engine's delta agrees with the independent one-arc-at-a-time
    numpy oracle on the exact invalidated and new mapping sets."""
    tgt, pat = CORPORA[corpus](rng)
    adds, rems = _sample_edits(rng, tgt, k_add=3, k_rem=3,
                               loops=corpus == "selfloops")
    enum = _enum(SubgraphIndex.build(tgt), "jnp")
    dm, _ = _assert_delta_equals_fresh(enum, pat, tgt, adds, rems)
    want = ref_delta(pat, tgt, added=adds, removed=rems)
    assert sorted(dm.added) == want.added
    assert sorted(dm.removed) == want.removed
    assert dm.matches == want.matches


def test_chained_updates(rng):
    """Three consecutive update()/run_delta() rounds maintain the match
    set exactly (versions chain: 0 -> 1 -> 2 -> 3)."""
    tgt, pat = _dense(rng)
    idx = SubgraphIndex.build(tgt)
    enum = _enum(idx, "jnp")
    cur = as_node_mappings(enum.run(enum.prepare(pat)))
    g = tgt
    for step in range(3):
        adds, rems = _sample_edits(rng, g, k_add=3, k_rem=2)
        new_idx, delta = idx.update(add_edges=adds, remove_edges=rems)
        assert new_idx.version == idx.version + 1
        q = enum.prepare(pat, index=new_idx)
        dm = enum.run_delta(q, cur, delta)
        cur = dm.apply(cur)
        g = apply_delta(g, added=adds, removed=rems)
        idx = new_idx
    assert cur == ref_node_mappings(pat, g)


def test_seed_chunking_and_buffer_growth(rng):
    """Seed batches larger than the worker capacity chunk across engine
    invocations, and an undersized match ring grows until nothing is
    dropped — results stay exact either way."""
    tgt, pat = _dense(rng)
    adds, rems = _sample_edits(rng, tgt, k_add=10, k_rem=0)
    enum = _enum(SubgraphIndex.build(tgt), "jnp", n_workers=2, stack_cap=12)
    enum._DELTA_MCAP = 1  # force per-chunk match-ring growth retries
    dm, _ = _assert_delta_equals_fresh(enum, pat, tgt, adds, rems)
    assert dm.n_seeds >= 0  # chunking exercised; exactness asserted above


def test_run_delta_rejects_stale_query(rng):
    """run_delta refuses a query prepared against the wrong index version
    (the fingerprint pins the delta to one transition)."""
    tgt, pat = _dense(rng)
    idx = SubgraphIndex.build(tgt)
    enum = _enum(idx, "jnp")
    q_old = enum.prepare(pat)
    ms = enum.run(q_old)
    _, delta = idx.update(add_edges=_sample_edits(rng, tgt)[0])
    with pytest.raises(ValueError, match="fingerprint"):
        enum.run_delta(q_old, ms, delta)


def test_delta_reuses_edge_seeded_query_plan(rng):
    """An edge-seeded query's plan *is* the anchor plan for its own seed
    edge: ``run_delta`` must reuse it by identity instead of rebuilding an
    equal plan (PR-9 follow-up), and the differential gate still holds on
    the edge-seeded ordering."""
    tgt, pat = _power_law(rng)
    adds, rems = _sample_edits(rng, tgt, k_add=4, k_rem=3)
    idx = SubgraphIndex.build(tgt)
    enum = _enum(idx, "csr", root_seeding="auto")
    q = enum.prepare(pat, seed_edge="auto")
    assert q.plan.seed_edge is not None
    ms_old = enum.run(q)
    new_idx, delta = idx.update(add_edges=adds, remove_edges=rems)
    q2 = enum.prepare(pat, index=new_idx, seed_edge="auto")
    dm = enum.run_delta(q2, ms_old, delta)
    fresh = enum.run(q2)
    assert dm.matches == fresh.matches
    assert dm.apply(ms_old) == sorted(as_node_mappings(fresh))
    # the seed-edge anchor got the query plan itself, by identity; every
    # other anchor got a rebuilt plan of its own
    anchors = dict(enum._anchor_plans(q2))
    seed = q2.plan.seed_edge
    if seed in anchors:  # the seed edge survives unless the delta removed it
        assert anchors[seed] is q2.plan
    assert all(p is not q2.plan for a, p in anchors.items() if a != seed)


def test_vertex_seeded_query_builds_all_anchor_plans(rng):
    """Without a seed edge, no anchor can alias the query plan — the
    documented fallback: every anchor gets its own rebuilt plan, all
    sharing the query's padding and one DomainResult."""
    tgt, pat = _dense(rng)
    idx = SubgraphIndex.build(tgt)
    enum = _enum(idx, "jnp")
    q = enum.prepare(pat)
    assert q.plan.seed_edge is None
    anchors = dict(enum._anchor_plans(q))
    assert anchors  # connected patterns always have edge triples
    for aplan in anchors.values():
        assert aplan is not q.plan
        assert aplan.p_pad == q.plan.p_pad
        assert aplan.max_parents == q.plan.max_parents


# ---------------------------------------------------------------------------
# satellite: edit edge cases (set semantics of update())
# ---------------------------------------------------------------------------

def test_update_edge_cases(rng):
    tgt, _ = _multi_elab(rng)
    idx = SubgraphIndex.build(tgt)
    arcs = _arcs(tgt)
    (absent, _) = _sample_edits(rng, tgt, k_add=2, k_rem=0)

    # duplicate insert of a present arc: no-op — the same index comes back
    same, d = idx.update(add_edges=[arcs[0], arcs[0]])
    assert same is idx and d.is_empty
    assert d.old_version == d.new_version == idx.version
    assert d.old_fingerprint == d.new_fingerprint == idx.fingerprint

    # removing an absent arc: no-op
    same, d = idx.update(remove_edges=[absent[0]])
    assert same is idx and d.is_empty

    # insert + remove of the same arc in one update cancels to a no-op
    same, d = idx.update(add_edges=[absent[0]], remove_edges=[absent[0]])
    assert same is idx and d.is_empty
    same, d = idx.update(add_edges=[arcs[0]], remove_edges=[arcs[0]])
    assert same is idx and d.is_empty

    # mixed real + degenerate edits: only the effective part survives
    new_idx, d = idx.update(
        add_edges=[absent[0], absent[0], arcs[1]],   # dup + already-present
        remove_edges=[arcs[2], (absent[1])],          # real + absent
    )
    assert d.added == normalize_edges([absent[0]])
    assert d.removed == normalize_edges([arcs[2]])
    assert new_idx.version == idx.version + 1

    # out-of-range endpoints are rejected
    with pytest.raises(ValueError, match="out of range"):
        idx.update(add_edges=[(0, tgt.n, 0)])


def test_self_loop_insert_and_delete(rng):
    """Deltas on loop arcs flow through the loop-anchor seeding path and
    the membership invalidation exactly."""
    tgt, pat = _selfloops(rng)
    loops = [a for a in _arcs(tgt) if a[0] == a[1]]
    assert loops, "selfloops corpus must contain loop arcs"
    free = [u for u in range(tgt.n) if (u, u, 0) not in set(_arcs(tgt))]
    enum = _enum(SubgraphIndex.build(tgt), "jnp")
    _assert_delta_equals_fresh(
        enum, pat, tgt, adds=[(free[0], free[0], 0)], rems=[loops[0]]
    )


def test_new_edge_label_grows_planes(rng):
    """Inserting an arc with a previously unseen edge label grows the
    plane axis; the patched index still equals a fresh build and the gate
    still holds (patterns using old labels are unaffected; a pattern on
    the new label gains its matches)."""
    tgt, pat = _dense(rng)
    nl = int(tgt.edge_labels.max()) + 1
    (u, v, _), = _sample_edits(rng, tgt, k_add=1, k_rem=0)[0]
    enum = _enum(SubgraphIndex.build(tgt), "jnp")
    _assert_delta_equals_fresh(enum, pat, tgt, adds=[(u, v, nl)], rems=[])


# ---------------------------------------------------------------------------
# satellite: plane sharing (aliasing, not deep copies)
# ---------------------------------------------------------------------------

def test_update_shares_untouched_planes(rng):
    """update() touching one (elab, dir) plane pair must alias every other
    plane's CSR buffers by identity — structural sharing is what makes a
    1-arc update O(touched rows), not O(graph)."""
    tgt, _ = _multi_elab(rng)
    idx = SubgraphIndex.build(tgt)
    ps = idx.plane_set()  # materialize before update so patching is active
    n_planes = len(ps.indices)
    assert n_planes >= 4  # multi-elab: sharing is observable

    adds, _ = _sample_edits(rng, tgt, k_add=1, k_rem=0)
    (u, v, l) = adds[0]
    new_idx, _ = idx.update(add_edges=[(u, v, l)])
    ps2 = new_idx.plane_set()

    touched = {2 * l, 2 * l + 1}
    for p in range(n_planes):
        if p in touched:
            assert ps2.indices[p] is not ps.indices[p], f"plane {p} not patched"
        else:
            assert ps2.indices[p] is ps.indices[p], f"plane {p} deep-copied"
            assert ps2.indptrs[p] is ps.indptrs[p], f"plane {p} indptr copied"

    # the patched planes carry exactly the edited rows, per-row equal to a
    # fresh build of the edited graph
    fresh = SubgraphIndex.build(apply_delta(tgt, added=[(u, v, l)]))
    a, b = new_idx.csr_planes(), fresh.csr_planes()
    np.testing.assert_array_equal(a.indptr, b.indptr)
    np.testing.assert_array_equal(a.indices[: int(a.indptr.max())],
                                  b.indices[: int(b.indptr.max())])


def test_removal_update_shares_untouched_planes(rng):
    tgt, _ = _multi_elab(rng)
    idx = SubgraphIndex.build(tgt)
    ps = idx.plane_set()
    (u, v, l) = _arcs(tgt)[0]
    new_idx, _ = idx.update(remove_edges=[(u, v, l)])
    ps2 = new_idx.plane_set()
    untouched = [p for p in range(len(ps.indices)) if p not in (2 * l, 2 * l + 1)]
    assert untouched and all(ps2.indices[p] is ps.indices[p] for p in untouched)


# ---------------------------------------------------------------------------
# satellite: compile-cache versioning (no false hits across versions)
# ---------------------------------------------------------------------------

def test_cache_keys_version_by_fingerprint(rng):
    """After an update, a same-shape query against the new version must
    not hit the old version's cache entry (its first run creates a fresh
    versioned entry and the counts move through the new target's content)
    — while the underlying XLA trace is shared, so the update costs no
    re-trace.  Re-running either version then hits its own entry."""
    tgt, pat = _dense(rng)
    idx = SubgraphIndex.build(tgt)
    enum = _enum(idx, "jnp")
    q1 = enum.prepare(pat)
    ms1 = enum.run(q1)

    adds, rems = _sample_edits(rng, tgt, k_add=4, k_rem=3)
    new_idx, delta = idx.update(add_edges=adds, remove_edges=rems)
    assert new_idx.fingerprint != idx.fingerprint
    q2 = enum.prepare(pat, index=new_idx)
    assert q2.bucket == q1.bucket  # same shape bucket on purpose

    before = enum.cache_stats()
    ms2 = enum.run(q2)
    mid = enum.cache_stats()
    assert mid["entries"] > before["entries"], (
        "same-bucket query on a new index version must get its own "
        "versioned cache entry, not hit the old version's"
    )
    assert mid["compiles"] == before["compiles"], (
        "the shared-shape XLA trace must be reused across index versions"
    )
    # a false hit would run the old target's arrays: the counts must move
    # through the *new* target's content
    fresh = _enum(SubgraphIndex.build(apply_delta(tgt, adds, rems)), "jnp")
    assert ms2.matches == fresh.run(fresh.prepare(pat)).matches
    ms1b, ms2b = enum.run(q1), enum.run(q2)
    after = enum.cache_stats()
    assert after["entries"] == mid["entries"]  # both versions now cached
    assert after["cache_hits"] > mid["cache_hits"]
    assert (ms1b.matches, ms2b.matches) == (ms1.matches, ms2.matches)


def test_invalidate_index_evicts_retired_version(rng):
    tgt, pat = _dense(rng)
    idx = SubgraphIndex.build(tgt)
    enum = _enum(idx, "jnp")
    enum.run(enum.prepare(pat))
    new_idx, delta = idx.update(add_edges=_sample_edits(rng, tgt)[0])
    enum.run(enum.prepare(pat, index=new_idx))
    entries = enum.cache_stats()["entries"]
    dropped = enum.invalidate_index(delta.old_fingerprint)
    assert dropped >= 1
    assert enum.cache_stats()["entries"] == entries - dropped
    # empty fingerprint (hand-built queries) never matches anything
    assert enum.invalidate_index("") == 0


def test_coalesce_key_distinguishes_versions(rng):
    tgt, pat = _dense(rng)
    idx = SubgraphIndex.build(tgt)
    enum = _enum(idx, "jnp")
    new_idx, _ = idx.update(add_edges=_sample_edits(rng, tgt)[0])
    k1 = enum.coalesce_key(enum.prepare(pat))
    k2 = enum.coalesce_key(enum.prepare(pat, index=new_idx))
    assert k1 != k2  # versions must never share a coalesced pack


def test_service_update_index(rng):
    """The serving layer swaps index versions live: queries submitted
    after update_index() run against the new content, metrics record the
    swap, and retired-version engines are evicted."""
    tgt, pat = _dense(rng)
    adds, rems = _sample_edits(rng, tgt, k_add=4, k_rem=3)
    want_old = len(ref_node_mappings(pat, tgt))
    want_new = len(ref_node_mappings(pat, apply_delta(tgt, adds, rems)))

    svc = EnumerationService(
        SubgraphIndex.build(tgt), n_workers=2, expand_width=2,
        service=ServiceConfig(batch_window_s=0.0),
    )
    with svc:
        assert svc.submit(pat).result(timeout=60.0).matches == want_old
        delta = svc.update_index(add_edges=adds, remove_edges=rems)
        assert not delta.is_empty
        assert svc.submit(pat).result(timeout=60.0).matches == want_new
        # degenerate edit: counted, but nothing swapped
        assert svc.update_index(add_edges=[adds[0]]).is_empty
    stats = svc.stats()
    assert stats["index_updates"] == 2
    assert stats["cache_invalidated"] >= 1


# ---------------------------------------------------------------------------
# property test: random edit streams (hypothesis)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment without hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=6, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        n_steps=st.integers(1, 3),
        k_add=st.integers(0, 4),
        k_rem=st.integers(0, 4),
    )
    def test_property_random_edit_streams(seed, n_steps, k_add, k_rem):
        """Maintaining matches through a random stream of batched edits
        ends bit-identical to enumerating the final graph from scratch
        (independent numpy reference)."""
        rng = np.random.default_rng(seed)
        tgt = _canon(random_graph(rng, 12, 26, n_labels=2,
                                  selfloops=int(rng.integers(0, 3))))
        pat = extract_connected_pattern(rng, tgt, int(rng.integers(3, 5)))
        if pat.m == 0:
            return
        idx = SubgraphIndex.build(tgt)
        enum = _enum(idx, "jnp", n_workers=2)
        cur = as_node_mappings(enum.run(enum.prepare(pat)))
        g = tgt
        for _ in range(n_steps):
            adds, rems = _sample_edits(
                rng, g, k_add=k_add, k_rem=k_rem, loops=True
            )
            new_idx, delta = idx.update(add_edges=adds, remove_edges=rems)
            if delta.is_empty:
                assert new_idx is idx
                continue
            q = enum.prepare(pat, index=new_idx)
            cur = enum.run_delta(q, cur, delta).apply(cur)
            g = apply_delta(g, added=adds, removed=rems)
            idx = new_idx
        assert cur == ref_node_mappings(pat, g)


# ---------------------------------------------------------------------------
# mesh path (runs in CI's 4-virtual-device job)
# ---------------------------------------------------------------------------

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=N)",
)


@multi_device
def test_mesh_delta_conformance(rng):
    """run_delta through a sharded Enumerator (worker axis over 2 devices)
    returns the same added/removed mapping sets as the single-device path,
    and the gate holds."""
    tgt, pat = _dense(rng)
    adds, rems = _sample_edits(rng, tgt, k_add=4, k_rem=3)
    mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    idx = SubgraphIndex.build(tgt)
    plain = _enum(idx, "jnp")
    shard = Enumerator(idx, n_workers=4, expand_width=2, mesh=mesh)
    dm_p, _ = _assert_delta_equals_fresh(plain, pat, tgt, adds, rems)
    dm_s, _ = _assert_delta_equals_fresh(shard, pat, tgt, adds, rems)
    assert sorted(dm_s.added) == sorted(dm_p.added)
    assert sorted(dm_s.removed) == sorted(dm_p.removed)
