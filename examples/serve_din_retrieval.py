"""DIN retrieval serving: one user against many candidates, batched.

  PYTHONPATH=src python examples/serve_din_retrieval.py [--candidates 50000]

Demonstrates the ``retrieval_cand`` production path at laptop scale: embed
the user's behavior sequence once, score every candidate through the target
attention + MLP stack fully vectorized, then top-k.  Includes a latency
measurement loop (the serve_p99 path).
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import graphgen
from repro.models.common import init_from_specs
from repro.models.recsys import din as din_mod


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--candidates", type=int, default=50_000)
    ap.add_argument("--items", type=int, default=100_000)
    ap.add_argument("--topk", type=int, default=100)
    args = ap.parse_args()

    cfg = din_mod.DINConfig(embed_dim=18, seq_len=100, attn_mlp=(80, 40),
                            mlp=(200, 80), n_items=args.items, n_cats=1000,
                            d_dense=8)
    params = init_from_specs(jax.random.PRNGKey(0), din_mod.param_specs(cfg))
    rng = np.random.default_rng(0)

    user = {
        "hist_items": jnp.asarray(rng.integers(0, args.items, (1, 100)), jnp.int32),
        "hist_cats": jnp.asarray(rng.integers(0, 1000, (1, 100)), jnp.int32),
        "hist_len": jnp.asarray([63], jnp.int32),
        "cand_items": jnp.asarray(rng.integers(0, args.items, args.candidates), jnp.int32),
        "cand_cats": jnp.asarray(rng.integers(0, 1000, args.candidates), jnp.int32),
        "dense": jnp.asarray(rng.normal(size=(1, 8)), jnp.float32),
    }

    @jax.jit
    def retrieve(params, batch):
        scores = din_mod.score_candidates(params, cfg, batch)
        return jax.lax.top_k(scores, args.topk)

    scores, idx = jax.block_until_ready(retrieve(params, user))
    t0 = time.perf_counter()
    scores, idx = jax.block_until_ready(retrieve(params, user))
    dt = time.perf_counter() - t0
    print(f"[retrieval] scored {args.candidates} candidates in {dt*1e3:.1f}ms "
          f"({args.candidates/dt/1e6:.2f}M cand/s); "
          f"top item {int(user['cand_items'][idx[0]])} score {float(scores[0]):.3f}")

    # online scoring latency (serve_p99-like, batch 512)
    batch = {k: jnp.asarray(v) for k, v in graphgen.din_batch(
        512, 100, args.items, 1000, 8, seed=1).items()}
    batch.pop("click")
    score = jax.jit(lambda p, b: din_mod.score(p, cfg, b))
    jax.block_until_ready(score(params, batch))
    lat = []
    for _ in range(10):
        t0 = time.perf_counter()
        jax.block_until_ready(score(params, batch))
        lat.append(time.perf_counter() - t0)
    print(f"[serve] batch-512 scoring: p50 {np.median(lat)*1e3:.2f}ms "
          f"p99 {np.percentile(lat, 99)*1e3:.2f}ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
