"""Atomic, shard-aware checkpointing (no orbax in this environment).

Layout:  ``<dir>/step_<n>/`` containing
  * ``manifest.json`` — treedef paths, shapes, dtypes, mesh metadata, and a
    completion marker written LAST (a directory without a manifest is an
    aborted write and is ignored / garbage-collected).
  * ``arrays.npz``    — flattened leaves keyed by escaped tree paths.

Writes go to ``<dir>/.tmp_step_<n>`` then ``os.rename`` — atomic on POSIX, so
a crash mid-write can never corrupt the latest checkpoint (restart-safety for
the 1000-node story).  ``async_write=True`` snapshots to host memory
synchronously, then persists on a background thread (training continues
through the I/O).

Multi-host note: each host saves only the leaves it owns
(``addressable_shards``) under a per-host suffix; ``restore`` reassembles.
In this single-process container that degenerates to one file, but the
manifest already carries the mesh/topology metadata used by
`repro.checkpoint.reshard` for elastic restarts.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_writer_lock = threading.Lock()


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = leaf
    return out, treedef


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:08d}")


def save(
    base: str,
    step: int,
    params: Any,
    opt_state: Any = None,
    keep: int = 3,
    async_write: bool = False,
    extra_meta: Optional[dict] = None,
) -> str:
    """Write an atomic checkpoint; returns the final directory path."""
    tree = {"params": params, "opt_state": opt_state}
    flat, _ = _flatten_with_paths(tree)
    # snapshot to host memory synchronously (device buffers may be donated)
    host = {k: np.asarray(v) for k, v in flat.items() if v is not None}
    meta = {
        "step": step,
        "time": time.time(),
        "n_leaves": len(host),
        "leaves": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in host.items()
        },
        "process_count": jax.process_count(),
        **(extra_meta or {}),
    }

    def _write():
        with _writer_lock:
            os.makedirs(base, exist_ok=True)
            tmp = os.path.join(base, f".tmp_step_{step:08d}")
            final = _step_dir(base, step)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, _ARRAYS), **host)
            # manifest last == completion marker
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(meta, f, indent=2)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _gc(base, keep)

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
    else:
        _write()
    return _step_dir(base, step)


def _gc(base: str, keep: int) -> None:
    steps = list_steps(base)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(base, s), ignore_errors=True)


def list_steps(base: str):
    if not os.path.isdir(base):
        return []
    out = []
    for name in os.listdir(base):
        if name.startswith("step_") and os.path.exists(
            os.path.join(base, name, _MANIFEST)
        ):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def restore(
    base: str, step: int, like_params: Any = None, like_opt: Any = None
) -> Tuple[int, Any, Any]:
    """Load a checkpoint.  With ``like_*`` pytrees given, leaves are restored
    into that structure (and re-sharded to the leaves' shardings if they are
    jax arrays); otherwise a flat dict keyed by tree path is returned."""
    d = _step_dir(base, step)
    with open(os.path.join(d, _MANIFEST)) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, _ARRAYS))

    def rebuild(like, prefix):
        if like is None:
            return None
        flat, treedef = _flatten_with_paths(like)
        leaves = []
        for key in flat:
            arr = data[prefix + key]
            leaves.append(arr)
        # order of _flatten_with_paths is deterministic; rebuild by treedef
        _, td = jax.tree_util.tree_flatten(like)
        return jax.tree_util.tree_unflatten(td, leaves)

    if like_params is None:
        return meta["step"], dict(data), None
    params = rebuild(like_params, "['params']")
    opt = rebuild(like_opt, "['opt_state']") if like_opt is not None else None
    return meta["step"], params, opt


def restore_latest(
    base: str, like_params: Any = None, like_opt: Any = None
) -> Optional[Tuple[int, Any, Any]]:
    steps = list_steps(base)
    if not steps:
        return None
    return restore(base, steps[-1], like_params, like_opt)


def wait_for_writes() -> None:
    """Barrier for in-flight async writes (tests / clean shutdown)."""
    with _writer_lock:
        pass
