"""din — embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn.  [arXiv:1706.06978; paper]

Shapes:
  * ``train_batch``    batch 65,536       — BCE train step (grad + AdamW)
  * ``serve_p99``      batch 512          — online CTR scoring
  * ``serve_bulk``     batch 262,144      — offline scoring
  * ``retrieval_cand`` 1 × 1,000,000      — one user vs 1M candidates,
                       fully batched target attention (+ top-1000)

Embedding tables: 10M items / 10k categories, row-sharded over ``model``.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.registry import Arch, Cell, CellBuild
from repro.data import graphgen
from repro.models.common import abstract_from_specs, init_from_specs, logical_from_specs
from repro.models.recsys import din as din_mod
from repro.train import optimizer as opt_mod
from repro.train.trainer import make_train_step

CFG = din_mod.DINConfig(
    embed_dim=18, seq_len=100, attn_mlp=(80, 40), mlp=(200, 80),
    n_items=10_000_000, n_cats=10_000, d_dense=8,
)
SMOKE_CFG = din_mod.DINConfig(
    embed_dim=8, seq_len=10, attn_mlp=(16, 8), mlp=(32, 16),
    n_items=1000, n_cats=50, d_dense=8,
)
OPT = opt_mod.AdamWConfig(lr=1e-3, total_steps=100000)

I32 = jnp.int32
F32 = jnp.float32


def _score_flops(cfg: din_mod.DINConfig, batch: int) -> float:
    de = cfg.d_emb
    dims_a = [4 * de] + list(cfg.attn_mlp) + [1]
    attn = sum(2.0 * dims_a[i] * dims_a[i + 1] for i in range(len(dims_a) - 1))
    dims_m = [2 * de + cfg.d_dense] + list(cfg.mlp) + [1]
    mlp = sum(2.0 * dims_m[i] * dims_m[i + 1] for i in range(len(dims_m) - 1))
    return batch * (cfg.seq_len * attn + 2.0 * cfg.seq_len * de + mlp)


def _batch_abstract(cfg: din_mod.DINConfig, b: int):
    sds = {
        "hist_items": jax.ShapeDtypeStruct((b, cfg.seq_len), I32),
        "hist_cats": jax.ShapeDtypeStruct((b, cfg.seq_len), I32),
        "hist_len": jax.ShapeDtypeStruct((b,), I32),
        "target_item": jax.ShapeDtypeStruct((b,), I32),
        "target_cat": jax.ShapeDtypeStruct((b,), I32),
        "dense": jax.ShapeDtypeStruct((b, cfg.d_dense), F32),
        "click": jax.ShapeDtypeStruct((b,), I32),
    }
    logical = {
        "hist_items": ("batch", None), "hist_cats": ("batch", None),
        "hist_len": ("batch",), "target_item": ("batch",),
        "target_cat": ("batch",), "dense": ("batch", None), "click": ("batch",),
    }
    return sds, logical


def build_train(cfg: din_mod.DINConfig, batch: int) -> CellBuild:
    specs = din_mod.param_specs(cfg)
    p_abs, p_log = abstract_from_specs(specs), logical_from_specs(specs)
    o_abs, o_log = opt_mod.abstract_state(p_abs), opt_mod.state_logical(p_log)
    b_abs, b_log = _batch_abstract(cfg, batch)
    step = make_train_step(lambda p, b: din_mod.loss_fn(p, cfg, b), OPT)
    return CellBuild(
        fn=step, args=(p_abs, o_abs, b_abs), logical=(p_log, o_log, b_log),
        model_flops=3.0 * _score_flops(cfg, batch), donate=(0, 1),
    )


def build_serve(cfg: din_mod.DINConfig, batch: int) -> CellBuild:
    specs = din_mod.param_specs(cfg)
    p_abs, p_log = abstract_from_specs(specs), logical_from_specs(specs)
    b_abs, b_log = _batch_abstract(cfg, batch)
    b_abs.pop("click"); b_log.pop("click")

    def step(params, batch):
        return din_mod.score(params, cfg, batch)

    return CellBuild(
        fn=step, args=(p_abs, b_abs), logical=(p_log, b_log),
        model_flops=_score_flops(cfg, batch),
    )


def build_retrieval(cfg: din_mod.DINConfig, n_cand: int) -> CellBuild:
    specs = din_mod.param_specs(cfg)
    p_abs, p_log = abstract_from_specs(specs), logical_from_specs(specs)
    b_abs = {
        "hist_items": jax.ShapeDtypeStruct((1, cfg.seq_len), I32),
        "hist_cats": jax.ShapeDtypeStruct((1, cfg.seq_len), I32),
        "hist_len": jax.ShapeDtypeStruct((1,), I32),
        "cand_items": jax.ShapeDtypeStruct((n_cand,), I32),
        "cand_cats": jax.ShapeDtypeStruct((n_cand,), I32),
        "dense": jax.ShapeDtypeStruct((1, cfg.d_dense), F32),
    }
    b_log = {
        "hist_items": (None, None), "hist_cats": (None, None), "hist_len": (None,),
        "cand_items": ("batch",), "cand_cats": ("batch",), "dense": (None, None),
    }

    def step(params, batch):
        scores = din_mod.score_candidates(params, cfg, batch)
        return jax.lax.top_k(scores, 1000)

    return CellBuild(
        fn=step, args=(p_abs, b_abs), logical=(p_log, b_log),
        model_flops=_score_flops(cfg, n_cand),
    )


def smoke() -> Dict[str, float]:
    cfg = SMOKE_CFG
    params = init_from_specs(jax.random.PRNGKey(0), din_mod.param_specs(cfg))
    batch = {k: jnp.asarray(v) for k, v in graphgen.din_batch(
        8, cfg.seq_len, cfg.n_items, cfg.n_cats, cfg.d_dense).items()}
    step = make_train_step(lambda p, b: din_mod.loss_fn(p, cfg, b), OPT)
    opt = opt_mod.init(params)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    lv = float(metrics["loss_total"])
    assert np.isfinite(lv)
    scores = jax.jit(lambda p, b: din_mod.score(p, cfg, b))(p2, batch)
    assert scores.shape == (8,) and bool(jnp.all(jnp.isfinite(scores)))
    cand = {
        "hist_items": batch["hist_items"][:1], "hist_cats": batch["hist_cats"][:1],
        "hist_len": batch["hist_len"][:1],
        "cand_items": jnp.arange(256, dtype=jnp.int32) % cfg.n_items,
        "cand_cats": jnp.arange(256, dtype=jnp.int32) % cfg.n_cats,
        "dense": batch["dense"][:1],
    }
    s = jax.jit(lambda p, b: din_mod.score_candidates(p, cfg, b))(p2, cand)
    assert s.shape == (256,) and bool(jnp.all(jnp.isfinite(s)))
    return {"loss": lv}


ARCH = registry.register(
    Arch(
        name="din",
        family="recsys",
        cfg=CFG,
        cells={
            "train_batch": Cell("din", "train_batch", "train",
                                lambda: build_train(CFG, 65536)),
            "serve_p99": Cell("din", "serve_p99", "serve",
                              lambda: build_serve(CFG, 512)),
            "serve_bulk": Cell("din", "serve_bulk", "serve",
                               lambda: build_serve(CFG, 262144)),
            "retrieval_cand": Cell("din", "retrieval_cand", "retrieval",
                                   lambda: build_retrieval(CFG, 1_000_000)),
        },
        smoke=smoke,
        notes="Embedding-bag substrate (take + segment_sum); paper technique "
        "N/A to the model math; the LPT bucket balancer shards skewed "
        "serve_bulk batches host-side (DESIGN.md §4).",
    )
)
