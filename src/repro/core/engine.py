"""Frontier-vectorized parallel RI/RI-DS search engine — the driver layer.

The TPU-native form of the paper's work-stealing DFS (DESIGN.md §2),
split into a layered pipeline (DESIGN.md §6): `repro.core.frontier` owns
the ring-buffer stack state and ops, `repro.core.extend` the expansion
step behind the ``StepBackend`` seam (``step_backend="jnp"`` loose-ops
reference / ``"pallas"`` fused `repro.kernels.extend_step` kernel), and
this module only the ``lax.while_loop`` drivers, the steal rounds
(`repro.core.scheduler` decides, this module moves entries), and the
``shard_map`` glue.  **Both** execution paths call the one shared step:

* **single device** (``run(plan, cfg)``): all ``V`` workers in one array
  program; the steal round is plain gathers/scatters over ``V``.
* **mesh-sharded** (``run(plan, cfg, mesh=...)``): the ``V`` axis shards
  over the mesh ``data`` axis via ``shard_map`` (DESIGN.md §2.4); steal
  rounds all-gather occupancy + donor rows, every device computes the
  *same* `repro.core.scheduler.plan_steals`, termination is a cross-device
  ``lax.psum``.  With ``D == 1`` the collectives are identities and
  results are bit-identical to the single-device path.

Counters are per-worker int32 (DESIGN.md §2.5); cross-query aggregation
happens on host in int64.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.core import extend, frontier, scheduler
from repro.core.plan import SearchPlan

# Re-exports: the state/plan layers moved out in the §6 split but remain
# importable from the engine (configs/sge.py, session, tests, dryrun).
from repro.core.extend import (  # noqa: F401
    CSR_PLAN_LOGICAL, CsrPlanArrays, PLAN_LOGICAL, PlanArrays,
    abstract_csr_plan_arrays, abstract_plan_arrays, is_csr_only,
    make_csr_plan_arrays, make_plan_arrays, plan_arrays_for,
    plan_partition_specs, plan_partition_specs_for, resolve_step_backend,
    resolve_step_backend_for_plan,
)
from repro.core.frontier import (  # noqa: F401
    STATE_LOGICAL, EngineState, abstract_engine_state, init_state,
    state_partition_specs,
)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine parameters.

    Attributes:
      n_workers: number of (virtual) workers ``V``.  On a mesh, ``V`` is
        sharded over the ``data`` axis; on one device all ``V`` run vectorized
        (used by the CPU benchmarks to reproduce the paper's worker sweeps).
      expand_width: entries expanded per worker per step (SIMD lane count).
      steal_chunk: entries a donor offers per steal round — the paper's task
        group size (Fig. 4: 4 is best).
      keep_min: donors never drop below this size.
      recv_cap: max entries a receiver accepts per round.
      rebalance_interval: steps between steal rounds.
      work_stealing: disable to reproduce the paper's Fig. 3 ablation.
      stack_cap: ring-buffer capacity per worker; 0 = auto
        (``expand_width * (p_pad + 2) + steal_chunk + 8``).
      max_steps: safety bound on outer loop iterations (0 = 2**30).
      collect_matches: if > 0, materialize up to this many mappings per worker
        into a ring buffer (the paper's tools print matches; counting is the
        benchmarked mode).
      step_backend: which ``StepBackend`` expands lanes (DESIGN.md §6.2):
        ``"jnp"`` (loose-ops reference), ``"pallas"`` (the fused
        `repro.kernels.extend_step` kernel — interpret mode off-TPU),
        ``"csr"`` (sparse CSR adjacency walk for huge targets, DESIGN.md
        §6.4), or ``"auto"`` (``csr`` past ``extend.CSR_AUTO_NT`` target
        nodes, else ``jnp``).
      use_pallas: with ``step_backend="jnp"``, route only the
        candidate-bitmap AND through `repro.kernels.candidate_mask` (the
        pre-seam kerneling point; the fused backend subsumes it); with
        ``"csr"``, route the CSR walk through `repro.kernels.csr_extend`.
      store_used: keep per-entry used-bitmaps on the stack (True) or
        recompute them from the mapping at expansion time (False; refuted
        as a default by §Perf iteration 7 — see EXPERIMENTS.md §Perf).
    """

    n_workers: int = 1
    expand_width: int = 8
    steal_chunk: int = 4
    keep_min: int = 2
    recv_cap: int = 4
    rebalance_interval: int = 8
    work_stealing: bool = True
    stack_cap: int = 0
    max_steps: int = 0
    collect_matches: int = 0
    step_backend: str = "jnp"
    use_pallas: bool = False
    store_used: bool = True

    def __post_init__(self):
        if self.step_backend not in extend.STEP_BACKENDS + ("auto",):
            raise ValueError(
                f"step_backend={self.step_backend!r}; expected one of "
                f"{extend.STEP_BACKENDS + ('auto',)}"
            )

    def resolved_stack_cap(self, p_pad: int) -> int:
        if self.stack_cap:
            return self.stack_cap
        return self.expand_width * (p_pad + 2) + self.steal_chunk + 8


class EngineResult(NamedTuple):
    matches: int
    states: int
    steps: int
    steals: int
    steal_rounds: int
    mean_steal_depth: float
    mean_expand_depth: float
    per_worker_states: np.ndarray
    per_worker_matches: np.ndarray
    overflow: bool
    match_buf: Optional[np.ndarray]
    per_worker_steals: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# steal round (cross-worker, pure array ops over the V axis)
# ---------------------------------------------------------------------------

def _steal_round(cfg: EngineConfig, state: EngineState) -> EngineState:
    policy = scheduler.StealPolicy(
        steal_chunk=cfg.steal_chunk, keep_min=cfg.keep_min, recv_cap=cfg.recv_cap
    )
    v_workers, s_cap = state.st_depth.shape
    c = cfg.steal_chunk

    donate, accepted, dest_rank, dest_pos = scheduler.plan_steals(state.size, policy)
    wor = scheduler.receiver_workers(state.size)  # [V] worker per rank

    any_transfer = jnp.sum(accepted) > 0

    # gather donated rows from stack bottoms: donor d slot j = logical pos j
    slot_j = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (v_workers, c))
    src_slot = (state.base[:, None] + slot_j) % s_cap  # [V, C]
    didx = jnp.arange(v_workers, dtype=jnp.int32)[:, None]
    don_depth = state.st_depth[didx, src_slot]  # [V, C]
    don_map = state.st_map[didx, src_slot]
    don_used = state.st_used[didx, src_slot]
    don_cand = state.st_cand[didx, src_slot]

    taken = slot_j < accepted[:, None]  # [V, C]
    dest_w = jnp.where(taken, wor[jnp.clip(dest_rank, 0, v_workers - 1)], -1)
    # receivers are empty (size==0) so intake slot = (base + pos) % S
    recv_base = jnp.where(dest_w >= 0, state.base[jnp.maximum(dest_w, 0)], 0)
    dst_slot = (recv_base + dest_pos) % s_cap
    dw = jnp.where(dest_w >= 0, dest_w, v_workers)  # drop invalid

    st_depth = state.st_depth.at[dw, dst_slot].set(don_depth, mode="drop")
    st_map = state.st_map.at[dw, dst_slot].set(don_map, mode="drop")
    st_used = state.st_used.at[dw, dst_slot].set(don_used, mode="drop")
    st_cand = state.st_cand.at[dw, dst_slot].set(don_cand, mode="drop")

    # intake counts / steal metrics per receiver
    flat_w = dw.reshape(-1)
    ones = jnp.where(dest_w.reshape(-1) >= 0, 1, 0)
    recv_cnt = jnp.zeros((v_workers,), jnp.int32).at[flat_w].add(ones, mode="drop")
    depth_add = jnp.zeros((v_workers,), jnp.int32).at[flat_w].add(
        jnp.where(dest_w.reshape(-1) >= 0, don_depth.reshape(-1), 0), mode="drop"
    )

    # donors advance base (accepted slots were their bottom prefix)
    new_base = (state.base + accepted) % s_cap
    new_size = state.size - accepted + recv_cnt

    return state._replace(
        st_depth=st_depth,
        st_map=st_map,
        st_used=st_used,
        st_cand=st_cand,
        base=new_base,
        size=new_size,
        steals=state.steals + recv_cnt,
        steal_depth=state.steal_depth + depth_add,
        steal_rounds=state.steal_rounds + any_transfer.astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def make_expand_fn(cfg: EngineConfig, plan: extend.AnyPlanArrays):
    """Build the purely worker-local part of one engine round:
    ``rebalance_interval`` shared expansion steps
    (`repro.core.extend.make_step_fn`), over whatever worker axis the
    caller holds (all ``V`` workers single-device, or the local ``V / D``
    shard under ``shard_map``).

    Under the CSR backend (:class:`~repro.core.extend.CsrPlanArrays`) each
    round ends with a ring compaction (`repro.core.frontier.compact`): the
    sparse walk's segment gathers want every worker's stack as one
    contiguous bottom-anchored block — the layout hook ``compact``'s
    docstring has anticipated since the §6 split.  Compaction only rotates
    physical slots, so results stay bit-identical (the conformance suite
    asserts this against the dense backends)."""
    step = extend.make_step_fn(cfg, plan)
    is_csr = isinstance(plan, extend.CsrPlanArrays)

    def expand(state: EngineState) -> EngineState:
        state = lax.fori_loop(
            0, cfg.rebalance_interval, lambda _, st: step(st), state
        )
        if is_csr:
            sd, sm, su, sc, base, size = frontier.compact(
                state.st_depth, state.st_map, state.st_used, state.st_cand,
                state.base, state.size,
            )
            state = state._replace(
                st_depth=sd, st_map=sm, st_used=su, st_cand=sc,
                base=base, size=size,
            )
        return state

    return expand


def make_round_fn(cfg: EngineConfig, plan: extend.AnyPlanArrays):
    """Build the body of the outer loop: ``rebalance_interval`` expansion
    steps followed by one steal round.  Exposed separately so the dry-run /
    roofline can lower exactly one round (stable cost accounting)."""
    expand = make_expand_fn(cfg, plan)

    def body(state: EngineState) -> EngineState:
        state = expand(state)
        if cfg.work_stealing and cfg.n_workers > 1:
            state = _steal_round(cfg, state)
        return state._replace(steps=state.steps + cfg.rebalance_interval)

    return body


def _engine_loop(
    cfg: EngineConfig, plan: extend.AnyPlanArrays, state: EngineState
) -> EngineState:
    max_steps = cfg.max_steps or (1 << 30)
    body = make_round_fn(cfg, plan)

    # ~overflow: a full ring freezes its worker (the pop guard yields k=0
    # while size > 0), so an overflowed run can never drain — abort it
    # promptly; the result is undercounted either way and the session
    # retries with a doubled stack_cap (`repro.core.session.Enumerator.run`).
    def cond(state: EngineState) -> jnp.ndarray:
        return (jnp.sum(state.size) > 0) & (state.steps < max_steps) & ~state.overflow

    return lax.while_loop(cond, body, state)


# ---------------------------------------------------------------------------
# mesh-sharded execution: shard_map over the worker axis (DESIGN.md §2.4)
# ---------------------------------------------------------------------------

def mesh_worker_axis(mesh: Mesh) -> str:
    """The mesh axis the worker dimension shards over: ``data`` by
    convention, else the mesh's first axis."""
    return "data" if "data" in mesh.axis_names else mesh.axis_names[0]


def mesh_signature(mesh: Optional[Mesh]) -> Optional[tuple]:
    """Hashable identity of a mesh for compile-cache keys: axis names,
    axis sizes, and the flat device ids."""
    if mesh is None:
        return None
    return (
        tuple(str(a) for a in mesh.axis_names),
        tuple(int(s) for s in mesh.shape.values()),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def _steal_round_sharded(cfg: EngineConfig, state: EngineState, axis: str) -> EngineState:
    """One steal round under ``shard_map``: ``state`` holds this device's
    ``V / D`` worker stacks.

    The collective form of :func:`_steal_round` (DESIGN.md §2.4):
    ``all_gather`` occupancy → every device runs the same deterministic
    :func:`repro.core.scheduler.plan_steals` (no coordinator) →
    ``all_gather`` each donor's bottom ``steal_chunk`` rows (the steal
    traffic, ``V·C·(1 + P + W_used + W)`` words/round) → each device
    scatters only entries addressed to its local receivers; donors advance
    base by the globally agreed accepted count.  Entry-for-entry identical
    to the unsharded round computed in one address space.
    """
    policy = scheduler.StealPolicy(
        steal_chunk=cfg.steal_chunk, keep_min=cfg.keep_min, recv_cap=cfg.recv_cap
    )
    v_loc, s_cap = state.st_depth.shape
    c = cfg.steal_chunk
    d = lax.axis_index(axis)

    sizes = lax.all_gather(state.size, axis, tiled=True)  # [V]
    v_tot = sizes.shape[0]
    donate, accepted, dest_rank, dest_pos = scheduler.plan_steals(sizes, policy)
    wor = scheduler.receiver_workers(sizes)  # [V] global worker per rank
    any_transfer = jnp.sum(accepted) > 0

    # gather local donors' bottom rows, then all-gather them to every device
    slot_j = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (v_loc, c))
    src_slot = (state.base[:, None] + slot_j) % s_cap  # [V_loc, C]
    lidx = jnp.arange(v_loc, dtype=jnp.int32)[:, None]
    don_depth = lax.all_gather(state.st_depth[lidx, src_slot], axis, tiled=True)
    don_map = lax.all_gather(state.st_map[lidx, src_slot], axis, tiled=True)
    don_used = lax.all_gather(state.st_used[lidx, src_slot], axis, tiled=True)
    don_cand = lax.all_gather(state.st_cand[lidx, src_slot], axis, tiled=True)

    # destination workers (global ids), restricted to this device's shard
    slot_g = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (v_tot, c))
    taken = slot_g < accepted[:, None]  # [V, C]
    dest_w = jnp.where(taken, wor[jnp.clip(dest_rank, 0, v_tot - 1)], -1)
    local_dest = dest_w - d * v_loc
    on_dev = (dest_w >= 0) & (local_dest >= 0) & (local_dest < v_loc)
    safe_dest = jnp.clip(local_dest, 0, v_loc - 1)
    # receivers are empty (size==0) so intake slot = (base + pos) % S
    recv_base = jnp.where(on_dev, state.base[safe_dest], 0)
    dst_slot = (recv_base + dest_pos) % s_cap
    dw = jnp.where(on_dev, safe_dest, v_loc)  # drop off-device slots

    st_depth = state.st_depth.at[dw, dst_slot].set(don_depth, mode="drop")
    st_map = state.st_map.at[dw, dst_slot].set(don_map, mode="drop")
    st_used = state.st_used.at[dw, dst_slot].set(don_used, mode="drop")
    st_cand = state.st_cand.at[dw, dst_slot].set(don_cand, mode="drop")

    # intake counts / steal metrics for local receivers only
    flat_w = dw.reshape(-1)
    on_flat = on_dev.reshape(-1)
    recv_cnt = jnp.zeros((v_loc,), jnp.int32).at[flat_w].add(
        jnp.where(on_flat, 1, 0), mode="drop"
    )
    depth_add = jnp.zeros((v_loc,), jnp.int32).at[flat_w].add(
        jnp.where(on_flat, don_depth.reshape(-1), 0), mode="drop"
    )

    # local donors advance base by their slice of the global accepted vector
    accepted_loc = lax.dynamic_slice_in_dim(accepted, d * v_loc, v_loc)
    new_base = (state.base + accepted_loc) % s_cap
    new_size = state.size - accepted_loc + recv_cnt

    return state._replace(
        st_depth=st_depth,
        st_map=st_map,
        st_used=st_used,
        st_cand=st_cand,
        base=new_base,
        size=new_size,
        steals=state.steals + recv_cnt,
        steal_depth=state.steal_depth + depth_add,
        steal_rounds=state.steal_rounds + any_transfer.astype(jnp.int32),
    )


def _sharded_device_loop(
    cfg: EngineConfig, axis: str, plan: extend.AnyPlanArrays, state: EngineState
) -> EngineState:
    """Per-device program run under ``shard_map``: local expansion rounds
    (the same shared step as the single-device path), collective steal
    rounds, and psum-based termination detection.

    The loop carries the psum'd global entry count so the `while` condition
    is collective-free; every device sees the same count and therefore runs
    the same number of rounds (SPMD lockstep).
    """
    max_steps = cfg.max_steps or (1 << 30)
    expand = make_expand_fn(cfg, plan)

    def global_size(st: EngineState) -> jnp.ndarray:
        return lax.psum(jnp.sum(st.size), axis)

    def global_overflow(st: EngineState) -> jnp.ndarray:
        return lax.psum(st.overflow.astype(jnp.int32), axis) > 0

    def body(carry):
        st, _, _ = carry
        st = expand(st)
        if cfg.work_stealing and cfg.n_workers > 1:
            st = _steal_round_sharded(cfg, st, axis)
        st = st._replace(steps=st.steps + cfg.rebalance_interval)
        return st, global_size(st), global_overflow(st)

    # ~overflow: abort promptly on any device's overflow (see _engine_loop);
    # the psum'd flag keeps every device exiting the same iteration.
    def cond(carry):
        st, gsize, govf = carry
        return (gsize > 0) & (st.steps < max_steps) & ~govf

    state, _, _ = lax.while_loop(
        cond, body, (state, global_size(state), global_overflow(state))
    )
    # overflow is device-local until here; replicate so the P() out-spec holds
    overflow = lax.psum(state.overflow.astype(jnp.int32), axis) > 0
    return state._replace(overflow=overflow)


def make_sharded_engine_fn(
    cfg: EngineConfig, mesh: Mesh, axis: Optional[str] = None, n_t: int = 0,
    csr_only: bool = False,
):
    """Jitted ``(PlanArrays | CsrPlanArrays, EngineState) -> EngineState``
    with the worker axis sharded over ``axis`` of ``mesh`` via ``shard_map``.

    ``cfg.n_workers`` must be a multiple of the axis size (the session API
    snaps it up; `repro.core.session.Enumerator`).  ``n_t`` / ``csr_only``
    feed the ``"auto"`` backend resolution (the plan in-specs pytree must
    match the array layout `plan_arrays_for` will build).
    """
    axis = axis or mesh_worker_axis(mesh)
    n_dev = int(mesh.shape[axis])
    if cfg.n_workers % n_dev:
        raise ValueError(
            f"n_workers={cfg.n_workers} not divisible by mesh axis "
            f"{axis!r} size {n_dev}; round up to a multiple"
        )
    specs = state_partition_specs(axis)
    fn = shard_map(
        functools.partial(_sharded_device_loop, cfg, axis),
        mesh=mesh,
        in_specs=(plan_partition_specs_for(cfg, n_t, csr_only), specs),
        out_specs=specs,
        check_rep=False,
    )
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _sharded_fn_cached(
    cfg: EngineConfig, mesh: Mesh, axis: Optional[str], n_t: int, csr_only: bool
):
    # Mesh hashes by device set + axis names, so repeated direct eng.run()
    # calls over a collection reuse one jitted engine per (cfg, mesh) —
    # the module-level analogue of _run_jit; the session layer keeps its
    # own richer cache (shape buckets, counters).
    return make_sharded_engine_fn(cfg, mesh, axis, n_t=n_t, csr_only=csr_only)


def run_sharded(plan: SearchPlan, cfg: EngineConfig, mesh: Mesh) -> EngineResult:
    """Enumerate with worker stacks sharded over ``mesh`` (see :func:`run`)."""
    fn = _sharded_fn_cached(cfg, mesh, None, plan.n_t, extend.is_csr_only(plan))
    arrays = plan_arrays_for(cfg, plan)
    state = init_state(plan, cfg)
    final = jax.block_until_ready(fn(arrays, state))
    return result_from_state(final, cfg)


@functools.partial(jax.jit, static_argnums=(0,))
def _run_jit(
    cfg: EngineConfig, plan: extend.AnyPlanArrays, state: EngineState
) -> EngineState:
    return _engine_loop(cfg, plan, state)


def run(plan: SearchPlan, cfg: EngineConfig, mesh: Optional[Mesh] = None) -> EngineResult:
    """Enumerate all isomorphic subgraphs described by ``plan``.

    With ``mesh=None`` (the default) all ``V`` workers run in one device
    program — today's single-device behavior, unchanged.  With a mesh the
    worker axis shards over its ``data`` axis (:func:`run_sharded`).
    The plan arrays match the resolved step backend (dense bitmaps, or
    CSR planes for ``step_backend="csr"`` / large-``n_t`` ``"auto"``).
    """
    if mesh is not None:
        return run_sharded(plan, cfg, mesh)
    arrays = plan_arrays_for(cfg, plan)
    state = init_state(plan, cfg)
    final = jax.block_until_ready(_run_jit(cfg, arrays, state))
    return result_from_state(final, cfg)


def result_from_state(final: EngineState, cfg: EngineConfig) -> EngineResult:
    """Reduce a drained (unbatched) :class:`EngineState` to an
    :class:`EngineResult` — shared by the one-shot :func:`run` and the
    session executor (`repro.core.session`), whose batch path reduces one
    vmapped lane at a time."""
    steals = int(jnp.sum(final.steals))
    sdepth = int(jnp.sum(final.steal_depth))
    states = int(jnp.sum(final.states))
    edepth = int(jnp.sum(final.exp_depth))
    return EngineResult(
        matches=int(jnp.sum(final.matches)),
        states=states,
        steps=int(final.steps),
        steals=steals,
        steal_rounds=int(final.steal_rounds),
        mean_steal_depth=(sdepth / steals) if steals else 0.0,
        mean_expand_depth=(edepth / states) if states else 0.0,
        per_worker_states=np.asarray(final.states),
        per_worker_matches=np.asarray(final.matches),
        overflow=bool(final.overflow),
        match_buf=np.asarray(final.match_buf) if cfg.collect_matches else None,
        per_worker_steals=np.asarray(final.steals),
    )
