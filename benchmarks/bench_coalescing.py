"""C2 — task-coalescing (steal-chunk) size sweep (paper Fig. 4).

The paper's task-group size maps to ``steal_chunk`` (entries per steal —
each entry already coalesces all siblings of one tree node).  Expected, per
the paper: small groups (≈4) minimize makespan; very large groups strand big
subtrees on one worker and *increase* steals/makespan.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.core import EngineConfig

CHUNKS = (1, 2, 4, 8, 16)


def run(scale: float = 0.5, seed: int = 7, workers: int = 16) -> Dict:
    collections = common.bench_instances(scale=scale, seed=seed)
    rows: List[Dict] = []
    for cname, instances in collections.items():
        cache: dict = {}
        for chunk in CHUNKS:
            cfg = EngineConfig(
                n_workers=workers, expand_width=4,
                steal_chunk=chunk, recv_cap=chunk, rebalance_interval=8,
            )
            steps, steals, states, walls = [], [], [], []
            for inst in instances:
                r = common.run_instance(inst, cfg=cfg, packed_cache=cache)
                if r.states == 0:
                    continue
                steps.append(r.steps)
                steals.append(r.steals)
                states.append(r.states)
                walls.append(r.wall_s)
            rows.append(dict(
                collection=cname, chunk=chunk,
                total_steps=float(np.sum(steps)),
                total_steals=float(np.sum(steals)),
                total_states=float(np.sum(states)),
                total_wall_s=float(np.sum(walls)),
            ))
    out = {"rows": rows}
    common.save_json("coalescing", out)
    return out


def emit_csv(out: Dict) -> List[str]:
    lines = []
    base: Dict[str, float] = {}
    for row in out["rows"]:
        if row["chunk"] == 4:
            base[row["collection"]] = row["total_steps"]
    for row in out["rows"]:
        rel = row["total_steps"] / max(base.get(row["collection"], 1), 1)
        lines.append(common.csv_row(
            f"coalescing/{row['collection']}/chunk{row['chunk']}",
            row["total_wall_s"] * 1e6 / max(row["total_states"], 1),
            f"steps_vs_chunk4={rel:.3f};steals={row['total_steals']:.0f}",
        ))
    return lines


if __name__ == "__main__":
    print("\n".join(emit_csv(run())))
