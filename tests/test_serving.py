"""The always-on serving layer (DESIGN.md §7): coalescer/admission units,
streaming bit-identity, overflow retry under concurrency, quotas,
shutdown, and the mixed dense/CSR multi-client integration case (the CI
step sets SGE_SERVE_INTEGRATION=1 to include the big one)."""

import os
import threading
import warnings

import pytest

from repro.core import EngineConfig, Enumerator, Query, SubgraphIndex
from repro.core.plan import build_csr_plan
from repro.serve import (
    Backpressure,
    Coalescer,
    EnumerationService,
    QuotaExceeded,
    ServiceConfig,
    ServiceError,
)
from repro.serve.admission import AdmissionQueue, Request
from tests.conftest import extract_connected_pattern, random_graph

CFG = EngineConfig(n_workers=4, expand_width=2)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _corpus(rng, n_pats=6, n=40, m=120):
    tgt = random_graph(rng, n, m, n_labels=3)
    pats = []
    while len(pats) < n_pats:
        p = extract_connected_pattern(rng, tgt, int(rng.integers(2, 5)))
        if p.m > 0:
            pats.append(p)
    return tgt, pats


# ---------------------------------------------------------------------------
# Coalescer (pure unit, fake clock)
# ---------------------------------------------------------------------------

def test_coalescer_lane_budget_dispatches_immediately():
    clk = FakeClock()
    c = Coalescer(max_lanes=3, window_s=10.0, clock=clk)
    assert c.add("k", 1) is None
    assert c.add("k", 2) is None
    key, items = c.add("k", 3)  # budget filled: no window wait
    assert (key, items) == ("k", [1, 2, 3])
    assert c.pending() == 0 and c.ripe() == []


def test_coalescer_window_ripens_oldest_first():
    clk = FakeClock()
    c = Coalescer(max_lanes=8, window_s=1.0, clock=clk)
    c.add("a", 1)
    clk.t = 0.5
    c.add("b", 2)
    c.add("a", 3)          # does not reset bucket a's window
    assert c.ripe() == []
    assert c.next_deadline() == pytest.approx(1.0)  # bucket a's oldest + window
    clk.t = 1.0
    assert c.ripe() == [("a", [1, 3])]   # b not due yet
    clk.t = 1.5
    assert c.ripe() == [("b", [2])]
    assert c.next_deadline() is None


def test_coalescer_flush_and_fifo_order():
    c = Coalescer(max_lanes=8, window_s=1.0, clock=FakeClock())
    for i in range(3):
        c.add("x", i)
    c.add("y", 99)
    assert c.flush() == [("x", [0, 1, 2]), ("y", [99])]
    assert c.pending() == 0


# ---------------------------------------------------------------------------
# Admission queue (pure unit)
# ---------------------------------------------------------------------------

def _req(tenant="t"):
    return Request(query=None, tenant=tenant, stream=None, collect=0,
                   submitted_at=0.0)


def test_admission_quota_rejects_immediately():
    q = AdmissionQueue(max_depth=16, max_outstanding_per_tenant=2)
    q.admit(_req("a"))
    q.admit(_req("a"))
    with pytest.raises(QuotaExceeded):
        q.admit(_req("a"), timeout=5.0)  # quota never blocks, even w/ timeout
    q.admit(_req("b"))  # other tenants unaffected
    assert q.outstanding("a") == 2 and q.outstanding("b") == 1
    # quota frees on release (terminal status), not on pop (execution start)
    assert len(q.pop(timeout=0)) == 3
    assert q.outstanding("a") == 2
    q.release("a")
    q.admit(_req("a"))


def test_admission_backpressure_blocks_then_rejects():
    q = AdmissionQueue(max_depth=1, max_outstanding_per_tenant=8)
    q.admit(_req("a"))
    with pytest.raises(Backpressure):
        q.admit(_req("b"), timeout=None)  # no timeout = no blocking
    with pytest.raises(Backpressure):
        q.admit(_req("b"), timeout=0.01)
    # a concurrent pop() frees space and unblocks the waiter
    done = []

    def late_pop():
        q.pop(timeout=0)
        done.append(True)

    t = threading.Timer(0.05, late_pop)
    t.start()
    q.admit(_req("b"), timeout=5.0)
    t.join()
    assert done and q.depth() == 1


# ---------------------------------------------------------------------------
# Service: streaming identity + determinism
# ---------------------------------------------------------------------------

def test_stream_chunks_concatenate_bit_identically(rng):
    """Chunks arrive in deterministic seq order and concatenate to exactly
    the one-shot run's mappings; counts match too."""
    tgt, pats = _corpus(rng, n_pats=4)
    index = SubgraphIndex.build(tgt)
    ref = Enumerator(index, config=CFG)
    svc = EnumerationService(
        index, config=CFG,
        service=ServiceConfig(max_lanes=4, batch_window_s=0.001, chunk_size=3),
    )
    with svc:
        handles = [svc.submit(p, collect=64) for p in pats]
        for p, h in zip(pats, handles):
            chunks = h.chunks(timeout=120.0)
            ms = h.result()
            one = ref.run(ref.prepare(p), collect_matches=64)
            assert (ms.matches, ms.states) == (one.matches, one.states)
            assert [c.seq for c in chunks] == list(range(len(chunks)))
            assert all(len(c.mappings) <= 3 for c in chunks)
            if chunks:
                assert chunks[-1].final and not any(c.final for c in chunks[:-1])
            concat = [m for c in chunks for m in c.mappings]
            assert concat == one.mappings()
            assert h.status().n_chunks == len(chunks)
    # a second identical service run streams the identical chunk sequence
    svc2 = EnumerationService(
        index, config=CFG,
        service=ServiceConfig(max_lanes=4, batch_window_s=0.001, chunk_size=3),
    )
    with svc2:
        h2 = svc2.submit(pats[0], collect=64)
        assert h2.chunks(timeout=120.0) == handles[0].chunks()


def test_counting_mode_streams_no_chunks(rng):
    tgt, pats = _corpus(rng, n_pats=2)
    index = SubgraphIndex.build(tgt)
    ref = Enumerator(index, config=CFG)
    with EnumerationService(index, config=CFG) as svc:
        h = svc.submit(pats[0], collect=0)
        assert h.chunks(timeout=120.0) == []
        assert h.result().matches == ref.run(ref.prepare(pats[0])).matches


def test_concurrent_clients_match_standalone_runs(rng):
    """Many client threads, coalesced packs: every streamed result equals
    a standalone run; metrics add up."""
    tgt, pats = _corpus(rng, n_pats=8)
    index = SubgraphIndex.build(tgt)
    ref = Enumerator(index, config=CFG)
    expected = [ref.run(ref.prepare(p)) for p in pats]
    svc = EnumerationService(
        index, config=CFG,
        service=ServiceConfig(max_lanes=4, batch_window_s=0.005),
    )
    results = [None] * len(pats)
    errors = []

    def client(i):
        try:
            h = svc.submit(pats[i], tenant=f"t{i % 3}", collect=0, timeout=30.0)
            results[i] = h.result(timeout=120.0)
        except BaseException as e:
            errors.append(e)

    with svc:
        threads = [threading.Thread(target=client, args=(i,)) for i in range(len(pats))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120.0)
    assert not errors, errors
    for ms, exp in zip(results, expected):
        assert (ms.matches, ms.states) == (exp.matches, exp.states)
    stats = svc.stats()
    assert stats["completed"] == len(pats)
    assert stats["dispatches"] >= 1
    assert 0 < stats["batch_occupancy"] <= 1
    assert stats["latency_p99_s"] >= stats["latency_p50_s"] > 0
    assert stats["cache_compiles"] >= 1 and stats["cache_hit_rate"] >= 0


def test_unsatisfiable_short_circuits(rng):
    from tests.conftest import bump_edge_label

    tgt, pats = _corpus(rng, n_pats=1)
    index = SubgraphIndex.build(tgt)
    bad = bump_edge_label(pats[0], 0, 9)
    svc = EnumerationService(index, config=CFG)  # not even started
    h = svc.submit(bad, collect=8)
    assert h.done  # answered at submit time, no queue slot, no engine
    assert h.result().matches == 0 and h.chunks() == []
    assert svc.stats()["unsat"] == 1
    assert svc.enumerator.cache_stats()["compiles"] == 0
    svc.stop()


# ---------------------------------------------------------------------------
# Service: overflow retry under concurrency
# ---------------------------------------------------------------------------

def test_overflow_retry_reported_with_concurrent_inflight(rng):
    """Several in-flight queries whose stacks overflow: each rides the
    PR-4 doubled-stack_cap retry, reports retries=1 in its terminal
    status, and still counts exactly like a roomy run."""
    tgt = random_graph(rng, 40, 120, n_labels=2)
    index = SubgraphIndex.build(tgt)
    bigs = [extract_connected_pattern(rng, tgt, 6) for _ in range(3)]
    small = extract_connected_pattern(rng, tgt, 3)
    roomy = Enumerator(index, n_workers=2, expand_width=2)
    expected = {i: roomy.run(roomy.prepare(p)) for i, p in enumerate(bigs + [small])}

    tight_cfg = EngineConfig(n_workers=2, expand_width=2, stack_cap=8)
    svc = EnumerationService(
        index, config=tight_cfg,
        service=ServiceConfig(max_lanes=4, batch_window_s=0.001),
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)  # the retry warns
        with svc:
            handles = [svc.submit(p, tenant=f"t{i}", collect=0, timeout=30.0)
                       for i, p in enumerate(bigs + [small])]
            statuses = [h.status(timeout=240.0) for h in handles]
    for i, st in enumerate(statuses):
        assert st.ok
        assert st.matchset.matches == expected[i].matches
        assert st.retries == st.matchset.retries
    assert [st.retries for st in statuses[:3]] == [1, 1, 1], (
        "every overflowed in-flight query must report its retry"
    )
    assert statuses[3].retries == 0, "the small query must not report a retry"
    assert svc.stats()["retries"] == 3


# ---------------------------------------------------------------------------
# Service: quotas, backpressure, shutdown
# ---------------------------------------------------------------------------

def test_service_quota_and_backpressure(rng):
    tgt, pats = _corpus(rng, n_pats=1)
    index = SubgraphIndex.build(tgt)
    svc = EnumerationService(
        index, config=CFG,
        service=ServiceConfig(max_queue_depth=3, max_outstanding_per_tenant=2),
    )
    # dispatcher not started: submissions stay queued deterministically
    svc.submit(pats[0], tenant="a")
    svc.submit(pats[0], tenant="a")
    with pytest.raises(QuotaExceeded):
        svc.submit(pats[0], tenant="a")
    svc.submit(pats[0], tenant="b")          # queue now full (depth 3)
    with pytest.raises(Backpressure):
        svc.submit(pats[0], tenant="c", timeout=0.01)
    stats = svc.stats()
    assert stats["rejected_quota"] == 1 and stats["rejected_backpressure"] == 1
    assert stats["queue_depth"] == 3
    # draining stop executes what was admitted
    svc.stop(drain=True)
    assert svc.stats()["completed"] == 3


def test_service_stop_without_drain_fails_pending(rng):
    tgt, pats = _corpus(rng, n_pats=1)
    index = SubgraphIndex.build(tgt)
    svc = EnumerationService(index, config=CFG)
    h = svc.submit(pats[0])
    svc.stop(drain=False)
    st = h.status(timeout=10.0)
    assert not st.ok and "stopped" in st.error
    with pytest.raises(ServiceError):
        h.result()
    # the tenant's quota slot was released with the failure
    assert svc.admission.outstanding("default") == 0


# ---------------------------------------------------------------------------
# Integration: N clients, mixed dense/CSR targets (own CI step)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(
    not os.environ.get("SGE_SERVE_INTEGRATION"),
    reason="serving integration case runs in its own CI step "
    "(SGE_SERVE_INTEGRATION=1)",
)
def test_integration_mixed_dense_csr_clients(rng):
    """One service, step_backend='auto', 12 client threads with dense AND
    CSR-only queries in flight at once: the coalescer must keep the
    buckets apart (compile count == bucket count) while every client's
    streamed result stays bit-identical to a standalone run."""
    dense_tgt, dense_pats = _corpus(rng, n_pats=8, n=50, m=160)
    sparse_tgt = random_graph(rng, 200, 420, n_labels=3)
    cfg = EngineConfig(n_workers=4, expand_width=2, step_backend="auto")
    index = SubgraphIndex.build(dense_tgt)

    queries = []
    for i in range(12):
        if i % 3 == 2:
            pat = extract_connected_pattern(rng, sparse_tgt, 3)
            queries.append(Query(pattern=pat, plan=build_csr_plan(pat, sparse_tgt),
                                 variant="ri", name=f"csr{i}", prepare_s=0.0))
        else:
            queries.append(None)  # dense: prepared by the service from the raw pattern

    svc = EnumerationService(
        index, config=cfg,
        service=ServiceConfig(max_lanes=4, batch_window_s=0.005),
    )
    ref = Enumerator(config=cfg)
    results = [None] * len(queries)
    errors = []

    def client(i):
        try:
            q = queries[i] if queries[i] is not None else dense_pats[i % len(dense_pats)]
            h = svc.submit(q, tenant=f"t{i % 4}", collect=16, timeout=30.0)
            results[i] = (h.result(timeout=240.0), h.mappings())
        except BaseException as e:
            errors.append(e)

    with svc:
        threads = [threading.Thread(target=client, args=(i,)) for i in range(len(queries))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(240.0)
    assert not errors, errors

    prep = Enumerator(index, config=cfg)
    n_buckets = len({
        prep.coalesce_key(q if q is not None else prep.prepare(dense_pats[i % len(dense_pats)]))
        for i, q in enumerate(queries)
    })
    assert svc.enumerator.cache_stats()["compiles"] == n_buckets
    assert n_buckets >= 2, "dense and csr queries must occupy distinct buckets"
    for i, (ms, maps) in enumerate(results):
        q = queries[i] if queries[i] is not None else prep.prepare(dense_pats[i % len(dense_pats)])
        one = ref.run(q, collect_matches=16)
        assert (ms.matches, ms.states) == (one.matches, one.states)
        assert maps == one.mappings()
    stats = svc.stats()
    assert stats["completed"] == len(queries)
    assert stats["dispatches"] >= n_buckets


# ---------------------------------------------------------------------------
# metrics: sliding windows (direct unit tests)
# ---------------------------------------------------------------------------

from repro.serve import metrics as metrics_mod  # noqa: E402


def test_latency_window_empty_and_single():
    """Empty windows report 0.0 everywhere (no NaNs, no exceptions); one
    observation is every percentile."""
    w = metrics_mod.LatencyWindow(cap=8)
    assert len(w) == 0
    assert w.percentile(50) == 0.0
    assert w.percentile(99) == 0.0
    assert w.mean() == 0.0
    assert w.max() == 0.0
    w.record(0.25)
    for p in (0, 50, 99, 100):
        assert w.percentile(p) == 0.25
    assert w.mean() == 0.25 and w.max() == 0.25


def test_latency_window_nearest_rank_exact():
    """Nearest-rank percentiles on a known population, unsorted insertion
    order."""
    w = metrics_mod.LatencyWindow(cap=16)
    for v_ in (5.0, 1.0, 3.0, 2.0, 4.0):  # sorted: [1..5]
        w.record(v_)
    assert w.percentile(50) == 2.0   # round(0.5*5)=2 -> index 1
    assert w.percentile(90) == 4.0   # round(4.5)=4  -> index 3
    assert w.percentile(99) == 5.0
    assert w.percentile(0) == 1.0
    assert w.percentile(100) == 5.0


def test_latency_window_wraparound_keeps_most_recent():
    """Past cap, old observations fall out: percentiles cover exactly the
    last cap records."""
    w = metrics_mod.LatencyWindow(cap=100)
    for v_ in range(250):
        w.record(float(v_))
    assert len(w) == 100            # retained: [150.0 .. 249.0]
    assert w.max() == 249.0
    assert w.mean() == (150.0 + 249.0) / 2
    assert w.percentile(50) == 199.0   # rank round(50)=50 -> index 49
    assert w.percentile(99) == 248.0   # rank round(99)=99 -> index 98
    assert w.percentile(100) == 249.0


def test_service_metrics_concurrent_record():
    """Counters and windows stay consistent under concurrent observers
    (client threads + dispatcher thread in the real service)."""
    m = metrics_mod.ServiceMetrics(window=4096)
    n_threads, per_thread = 8, 400

    def observer(tid):
        for i in range(per_thread):
            m.observe_queue_wait(0.001 * tid)
            m.observe_completion(1.0, retries=(i % 2), ok=(i % 10 != 0))
            m.inc("submitted")

    threads = [threading.Thread(target=observer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    total = n_threads * per_thread
    snap = m.snapshot()
    assert snap["submitted"] == total
    assert snap["completed"] + snap["failed"] == total
    assert snap["failed"] == n_threads * (per_thread // 10)
    assert snap["retries"] == n_threads * (per_thread // 2)
    assert snap["latency_p50_s"] == 1.0 and snap["latency_max_s"] == 1.0
    assert snap["qps"] >= 0.0


def test_snapshot_schema_stable_and_formats():
    """Every COUNTERS name appears in the snapshot (zeros included) and
    format_snapshot renders without KeyError."""
    m = metrics_mod.ServiceMetrics()
    snap = m.snapshot()
    for name in metrics_mod.COUNTERS:
        assert name in snap
    assert "warmup_compiles" in snap
    assert isinstance(metrics_mod.format_snapshot(snap), str)


# ---------------------------------------------------------------------------
# warmup profile: compiles move to start(), first submits are cache hits
# ---------------------------------------------------------------------------

def test_warmup_profile_precompiles_dispatch_engines(rng):
    """ServiceConfig.warmup_profile pre-traces the pack engines during
    start(); the first real submits then compile nothing new."""
    tgt, pats = _corpus(rng, n_pats=3)
    index = SubgraphIndex.build(tgt)
    svc = EnumerationService(
        index, config=CFG,
        service=ServiceConfig(max_lanes=4, batch_window_s=0.001,
                              warmup_profile=tuple(pats)),
    )
    with svc:
        warm_spent = svc.stats()["warmup_compiles"]
        assert warm_spent >= 1
        compiles = svc.enumerator.cache_stats()["compiles"]
        handles = [svc.submit(p) for p in pats]
        for h in handles:
            assert h.result(timeout=240.0).states >= 0
        assert svc.enumerator.cache_stats()["compiles"] == compiles
        assert svc.stats()["warmup_compiles"] == warm_spent
    # start() is idempotent: re-entering does not re-warm
    with svc:
        assert svc.stats()["warmup_compiles"] == warm_spent
