"""GNN substrate + model tests: aggregation semantics, permutation
equivariance, sampler validity, bucket balancing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import init_from_specs
from repro.models.gnn import common as gcommon
from repro.models.gnn import gcn, sage, sampler as sampler_mod, schnet


def test_segment_ops():
    data = jnp.asarray([[1.0], [2.0], [3.0], [4.0]])
    seg = jnp.asarray([0, 0, 1, 1])
    np.testing.assert_allclose(
        np.asarray(gcommon.segment_sum(data, seg, 2)), [[3.0], [7.0]]
    )
    np.testing.assert_allclose(
        np.asarray(gcommon.segment_mean(data, seg, 2)), [[1.5], [3.5]]
    )
    np.testing.assert_allclose(
        np.asarray(gcommon.segment_max(data, seg, 2)), [[2.0], [4.0]]
    )


def test_sym_norm_weights():
    # path 0-1-2 (directed both ways)
    src = jnp.asarray([0, 1, 1, 2])
    dst = jnp.asarray([1, 0, 2, 1])
    w = np.asarray(gcommon.sym_norm_weights(src, dst, 3))
    # deg+1: node0=2, node1=3, node2=2
    np.testing.assert_allclose(w[0], 1 / np.sqrt(2 * 3), rtol=1e-6)
    np.testing.assert_allclose(w[2], 1 / np.sqrt(3 * 2), rtol=1e-6)


def test_gcn_node_permutation_equivariance(rng):
    """Relabeling nodes permutes GCN outputs identically."""
    cfg = gcn.GCNConfig(n_layers=2, d_hidden=8)
    n, e, f = 10, 30, 5
    params = init_from_specs(jax.random.PRNGKey(0), gcn.param_specs(cfg, f, 3))
    feats = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    out = gcn.forward(params, cfg, {"feats": feats, "src": src, "dst": dst})

    perm = rng.permutation(n)
    inv = np.argsort(perm)
    batch_p = {
        "feats": feats[perm],
        "src": jnp.asarray(inv)[src],
        "dst": jnp.asarray(inv)[dst],
    }
    out_p = gcn.forward(params, cfg, batch_p)
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out)[perm], rtol=1e-4, atol=1e-5
    )


def test_schnet_translation_invariance(rng):
    """SchNet depends on positions only through distances."""
    cfg = schnet.SchNetConfig(n_interactions=2, d_hidden=8, n_rbf=6, cutoff=4.0)
    n, e, f = 8, 20, 4
    params = init_from_specs(jax.random.PRNGKey(0), schnet.param_specs(cfg, f, 2))
    batch = {
        "feats": jnp.asarray(rng.normal(size=(n, f)), jnp.float32),
        "src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "positions": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    }
    out1 = schnet.forward(params, cfg, batch)
    batch2 = dict(batch, positions=batch["positions"] + 100.0)
    out2 = schnet.forward(params, cfg, batch2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=2e-3, atol=1e-4)


def test_neighbor_sampler_block_validity(rng):
    # ring graph with chords
    n = 60
    edges = [(i, (i + 1) % n) for i in range(n)] + [(i, (i + 7) % n) for i in range(n)]
    from repro.core.graph import Graph

    g = Graph.from_edges(n, edges, undirected=True)
    indptr, indices, _ = g.csr()
    labels = rng.integers(0, 5, n)
    s = sampler_mod.NeighborSampler(indptr, indices, labels, fanout=(3, 2), seed=0)
    seeds = rng.choice(n, size=8, replace=False)
    block = s.sample(seeds)
    n_pad, e_pad = sampler_mod.block_shape(8, (3, 2))
    assert block.feats_idx.shape == (n_pad,)
    assert block.src.shape == (e_pad,)
    # real edges reference valid local ids
    assert block.src[: block.n_edges].max() < block.n_nodes
    assert block.dst[: block.n_edges].max() < block.n_nodes
    # labels present exactly on seeds
    assert np.all(block.labels[:8] == labels[seeds])
    assert np.all(block.labels[8:] == -1)
    # local->global mapping consistent: seed rows match
    assert np.array_equal(block.feats_idx[:8], seeds)


def test_bucket_balancer_on_skewed_blocks(rng):
    sizes = rng.pareto(1.2, 128) * 100 + 10
    n = 16
    asg = sampler_mod.balance_buckets(sizes, n)
    import numpy as np

    # LPT: makespan within 4/3 of the lower bound max(mean, biggest item)
    makespan = np.bincount(asg, weights=sizes, minlength=n).max()
    opt_lb = max(sizes.sum() / n, sizes.max())
    assert makespan <= 4.0 / 3.0 * opt_lb + 1e-9
