"""sge — the paper's own workload as dry-run cells (bonus beyond the 40
assigned cells).

One cell per data collection, sized to the collection's largest target graph
(Table 1 of the paper), lowering **one engine round** (``rebalance_interval``
expansion steps + one steal round) under the production mesh:

  * ``sge_ppis32``     n_t = 12,575  (dense PPI)
  * ``sge_graemlin32`` n_t =  6,726  (dense microbial)
  * ``sge_pdbsv1``     n_t = 33,067  (large sparse)

Workers shard over ``('pod','data')`` (the paper's thread axis) — the
executable form of this is the engine's ``shard_map`` path
(`repro.core.engine.run_sharded`, DESIGN.md §2.4); packed bitmap words
shard over ``'model'`` (tensor parallelism the paper did not have —
DESIGN.md §2.2).  Bitmap words are padded to multiples of 128 so the
tensor axis always divides.

MODEL_FLOPS: useful bitwise word-lane ops per round =
``R · V · E · W · (max_parents + 3)`` (dom ∧ ¬used ∧ parents, push/pop
bookkeeping excluded), counted at 1 op per 32-bit word-lane.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import numpy as np

from repro.configs import registry
from repro.configs.registry import Arch, Cell, CellBuild, round_up
from repro.core import EngineConfig, Enumerator, Graph, SubgraphIndex
from repro.core import engine as eng
from repro.core.ref import brute_force_count, ref_enumerate
from repro.data import graphgen

P_PAD = 64  # pattern positions (paper patterns: up to 256 edges / ~128 nodes
MAX_PARENTS = 8
ENGINE = EngineConfig(
    n_workers=64,
    expand_width=64,
    steal_chunk=4,  # the paper's best task-group size (Fig. 4)
    rebalance_interval=8,
    store_used=True,  # §Perf iter 7 tried recompute-from-mapping (False) and
    # was REFUTED: the per-lane reconstruction loop costs more boundary
    # traffic than the stored bitmap saves (memory term 0.47×; see
    # EXPERIMENTS.md §Perf) — kept as a config option, default stored.
)

COLLECTION_NT = {
    "sge_ppis32": 12575,
    "sge_graemlin32": 6726,
    "sge_pdbsv1": 33067,
}

# The genuinely-sparse pdbsv1-class cell (DESIGN.md §6.4): same n_t, but the
# adjacency is CSR planes sized for a mean degree of ~8 — the dense cells
# above carry [n_elab, 2, n_t, w] bitmaps (~273 MB at this n_t per label
# plane pair), which the csr step backend never materializes.
SPARSE_AVG_DEG = 8
SPARSE_DEG_CAP = 512
# The pdbsv1-scale CSR cell runs the paper's strongest variant: its AC ⇄ FC
# domains come from the CSR-native fixpoint (DESIGN.md §11) — no dense
# adjacency exists at any point of preprocessing or enumeration.
CSR_VARIANT = "ri-ds-si-acfc"


def _w_for(n_t: int) -> int:
    return round_up((n_t + 31) // 32, 128)


def build_round(n_t: int, cfg: EngineConfig = ENGINE) -> CellBuild:
    w = _w_for(n_t)
    plan_abs = eng.abstract_plan_arrays(n_t, w, P_PAD, MAX_PARENTS)
    state_abs = eng.abstract_engine_state(cfg, w, P_PAD)

    def round_fn(plan, state):
        return eng.make_round_fn(cfg, plan)(state)

    flops = (
        cfg.rebalance_interval
        * cfg.n_workers
        * cfg.expand_width
        * w
        * (MAX_PARENTS + 3)
    )
    return CellBuild(
        fn=round_fn,
        args=(plan_abs, state_abs),
        logical=(eng.PLAN_LOGICAL, eng.STATE_LOGICAL),
        model_flops=float(flops),
        note=f"one engine round; n_t={n_t} w={w} V={cfg.n_workers} E={cfg.expand_width}",
        donate=(1,),
    )


def build_csr_round(n_t: int, cfg: EngineConfig = ENGINE) -> CellBuild:
    """One engine round through the sparse CSR step backend — the
    >33k-node regime where the dense cells' ``[n_t, w]`` bitmap rows stop
    fitting (ROADMAP: sparse-CSR extension backend)."""
    cfg = dataclasses.replace(cfg, step_backend="csr")
    w = _w_for(n_t)
    nnz = 2 * n_t * SPARSE_AVG_DEG  # out + in planes
    plan_abs = eng.abstract_csr_plan_arrays(
        n_t, w, P_PAD, MAX_PARENTS, nnz=nnz, deg_cap=SPARSE_DEG_CAP,
    )
    state_abs = eng.abstract_engine_state(cfg, w, P_PAD)

    def round_fn(plan, state):
        return eng.make_round_fn(cfg, plan)(state)

    # per lane per step: deg_cap-wide driver gather + dedupe, MAX_PARENTS
    # binary searches of log2(deg_cap) compares each, and the w-word
    # base/scatter work — all counted at 1 op per 32-bit word-lane.
    log_deg = max(1, (SPARSE_DEG_CAP - 1).bit_length())
    per_lane = SPARSE_DEG_CAP * (2 + MAX_PARENTS * log_deg) + 2 * w
    flops = (
        cfg.rebalance_interval * cfg.n_workers * cfg.expand_width * per_lane
    )
    return CellBuild(
        fn=round_fn,
        args=(plan_abs, state_abs),
        logical=(eng.CSR_PLAN_LOGICAL, eng.STATE_LOGICAL),
        model_flops=float(flops),
        note=(
            f"one csr engine round ({CSR_VARIANT}, CSR-native domains); "
            f"n_t={n_t} nnz={nnz} "
            f"deg_cap={SPARSE_DEG_CAP} V={cfg.n_workers} E={cfg.expand_width}"
        ),
        donate=(1,),
    )


def smoke() -> Dict[str, float]:
    """End-to-end enumeration on a generated PPI-like instance through the
    session API, verified against the sequential oracle — and the session's
    compile cache must actually hit on a second same-bucket query."""
    tgt = graphgen.random_graph(48, 160, n_labels=4, seed=3)
    session = Enumerator(
        SubgraphIndex.build(tgt), config=EngineConfig(n_workers=4, expand_width=4)
    )
    pat = graphgen.extract_pattern(tgt, 5, seed=4)
    res = session.run(session.prepare(pat, name="smoke0"))
    ref = ref_enumerate(pat, tgt, variant="ri-ds-si-fc")
    assert res.matches == ref.matches and res.states == ref.states, (
        res.matches, res.states, ref.matches, ref.states,
    )
    assert res.matches >= 1  # extracted patterns always occur
    pat2 = graphgen.extract_pattern(tgt, 6, seed=5)
    session.run(session.prepare(pat2, name="smoke1"))
    info = session.cache_info()
    assert info["compiles"] == 1 and info["cache_hits"] >= 1, info
    # the mesh-sharded path must be bit-identical on however many devices
    # this host has (1 in the smoke container; collectives are identities)
    sharded = Enumerator(
        SubgraphIndex.build(tgt),
        config=EngineConfig(n_workers=4, expand_width=4),
        mesh=min(len(jax.devices()), 4),
    )
    res_sh = sharded.run(sharded.prepare(pat, name="smoke0-sharded"))
    assert (res_sh.matches, res_sh.states) == (res.matches, res.states), (
        res_sh.matches, res_sh.states, res.matches, res.states,
    )
    # the sparse CSR backend must reproduce the dense result bit-for-bit
    # (the conformance suite covers the full matrix; this is the config
    # smoke's one-query gate)
    csr = Enumerator(
        SubgraphIndex.build(tgt),
        config=EngineConfig(n_workers=4, expand_width=4, step_backend="csr"),
    )
    res_csr = csr.run(csr.prepare(pat, name="smoke0-csr"))
    assert (res_csr.matches, res_csr.states) == (res.matches, res.states), (
        res_csr.matches, res_csr.states, res.matches, res.states,
    )
    # the pdbsv1-class CSR-only pipeline (DESIGN.md §11): a sparse index
    # under the full ri-ds-si-acfc variant — dense adjacency bitmaps never
    # exist, domains come from the CSR-native AC ⇄ FC fixpoint, and the
    # match set equals the dense session's
    sparse = Enumerator(
        SubgraphIndex.build(tgt, sparse=True),
        variant=CSR_VARIANT,
        config=EngineConfig(n_workers=4, expand_width=4, step_backend="csr"),
    )
    res_sp = sparse.run(sparse.prepare(pat, name="smoke0-sparse"))
    assert res_sp.matches == res.matches, (res_sp.matches, res.matches)
    return {
        "matches": float(res.matches),
        "states": float(res.states),
        "engine_compiles": float(info["compiles"]),
    }


ARCH = registry.register(
    Arch(
        name="sge",
        family="sge",
        cfg=ENGINE,
        cells={
            **{
                name: Cell("sge", name, "engine", functools.partial(build_round, nt))
                for name, nt in COLLECTION_NT.items()
            },
            "sge_pdbsv1_csr": Cell(
                "sge", "sge_pdbsv1_csr", "engine",
                functools.partial(build_csr_round, COLLECTION_NT["sge_pdbsv1"]),
            ),
        },
        smoke=smoke,
        notes="The paper's contribution itself; see DESIGN.md §2 for the "
        "work-stealing → SPMD mapping.",
    )
)
