"""Core subgraph-enumeration library (the paper's contribution).

Layers:
  graph      — host graph + packed-bitmap representations
  ordering   — RI GreatestConstraintFirst ordering (+ SI tie-break)
  domains    — RI-DS domains: init, arc consistency, forward checking
  plan       — SearchPlan: static arrays for the engine
  engine     — frontier-vectorized work-stealing search (jax)
  scheduler  — steal-round policy (shared with the GNN batch balancer)
  ref        — sequential + brute-force oracles
  api        — enumerate_subgraphs()
"""

from repro.core.api import EnumerationResult, enumerate_subgraphs
from repro.core.engine import EngineConfig, EngineResult
from repro.core.graph import Graph, PackedGraph
from repro.core.plan import SearchPlan, VARIANTS, build_plan

__all__ = [
    "EnumerationResult",
    "enumerate_subgraphs",
    "EngineConfig",
    "EngineResult",
    "Graph",
    "PackedGraph",
    "SearchPlan",
    "VARIANTS",
    "build_plan",
]
