"""Subgraph-enumeration driver — the paper's tool, end to end.

  PYTHONPATH=src python -m repro.launch.sge_run --collection ppis32-like \
      --variant ri-ds-si-fc --workers 16 --scale 0.3

Generates (or loads) a collection, prepares one
:class:`~repro.core.session.SubgraphIndex` per target, and runs every
pattern through a single :class:`~repro.core.session.Enumerator` session —
so all instances share a handful of shape-bucketed engine compilations.
Three execution modes map to the session's three methods:

  * ``--mode single``   one engine invocation per query (default);
  * ``--mode packed``   LPT-balanced vmapped packs (``run_batch``; on the
    production mesh the pack axis maps to ``pod``);
  * ``--mode stream``   results printed as packs drain (``stream``; the
    serving path).

``--step-backend pallas`` swaps the engine's expansion step for the fused
Pallas ``extend_step`` kernel (DESIGN.md §6.2) — results are bit-identical
to the default ``jnp`` backend; off-TPU the kernel runs in interpret mode
(validation, not speed — see API.md).  ``--step-backend csr`` runs the
sparse CSR walk (DESIGN.md §6.4; also bit-identical), ``auto`` picks csr
past 32,768 target nodes.  ``--sparse-index`` goes further: targets are
indexed CSR-only (DESIGN.md §11), so dense adjacency bitmaps never exist
anywhere — any ``--variant`` works, with domains from the CSR-native
AC/FC fixpoint.

``--devices N`` runs the paper's worker sweep multi-device: the session's
worker stacks shard over a 1-D ``data`` mesh of ``N`` devices
(``shard_map``; DESIGN.md §2.4).  On a CPU-only host the flag forces ``N``
virtual XLA devices (``--xla_force_host_platform_device_count``) so the
scaling benchmarks run multi-"core" in CI; on a real backend it takes the
first ``N`` of ``jax.local_devices()``.

Reports per-instance matches / states / steps plus collection aggregates —
the shape of the paper's experiment tables — the session's compile cache
counters, and (multi-device) per-device steal traffic.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def _force_virtual_devices() -> None:
    """Honor ``--devices N`` before jax locks the platform: XLA device count
    is fixed at first backend initialization, so on CPU the flag must be in
    ``XLA_FLAGS`` before ``import jax`` (transitively below)."""
    n = None
    for i, tok in enumerate(sys.argv):
        if tok == "--devices" and i + 1 < len(sys.argv):
            n = sys.argv[i + 1]
        elif tok.startswith("--devices="):
            n = tok.split("=", 1)[1]
    if n is None:
        return
    try:
        n = int(n)
    except ValueError:
        return  # argparse will report the usage error
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()


_force_virtual_devices()

import jax  # noqa: E402  (after the XLA_FLAGS shim, deliberately)

from repro.core import EngineConfig, Enumerator, SubgraphIndex  # noqa: E402
from repro.data import graphgen  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--collection", default="ppis32-like",
                    choices=sorted(graphgen.COLLECTIONS))
    ap.add_argument("--variant", default="ri-ds-si-fc")
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--expand", type=int, default=4)
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--mode", choices=("single", "packed", "stream"),
                    default="single")
    ap.add_argument("--packed", action="store_true",
                    help="deprecated alias for --mode packed")
    ap.add_argument("--pack-size", type=int, default=4)
    ap.add_argument("--devices", type=int, default=0,
                    help="shard worker stacks over N devices (0 = no mesh; "
                    "on CPU forces N virtual XLA devices)")
    ap.add_argument("--step-backend",
                    choices=("jnp", "pallas", "csr", "auto", "partitioned"),
                    default="jnp",
                    help="expansion-step backend (DESIGN.md §6.2): 'jnp' "
                    "loose ops, 'pallas' the fused extend_step kernel "
                    "(interpret mode off-TPU — validation, not speed), "
                    "'csr' the sparse adjacency walk for huge targets "
                    "(§6.4), 'auto' = csr past 32,768 target nodes, "
                    "'partitioned' the out-of-core streaming walk (§9)")
    ap.add_argument("--mem-budget", type=int, default=0, metavar="BYTES",
                    help="device-memory budget for resident target planes "
                    "(DESIGN.md §9): partitions each target so its padded "
                    "resident CSR planes fit BYTES and streams the "
                    "partitions through the device (implies the "
                    "partitioned backend); 0 = whole target resident")
    ap.add_argument("--partitions", type=int, default=0, metavar="N",
                    help="explicit target partition count for the "
                    "partitioned backend (0 = derive from --mem-budget, "
                    "or 1 if neither is given)")
    ap.add_argument("--root-seeding", choices=("vertex", "edge", "auto"),
                    default="vertex",
                    help="root frontier construction (DESIGN.md §10): "
                    "'vertex' the depth-0 per-worker node split, 'edge' "
                    "depth-1 seeds enumerated from the rarest target edge "
                    "class (plans are built with seed_edge='auto'), "
                    "'auto' = edge whenever the plan carries a seed edge")
    ap.add_argument("--csr-walk", choices=("bucketed", "flat"),
                    default="bucketed",
                    help="CSR adjacency-walk schedule (DESIGN.md §10): "
                    "'bucketed' trips each lane at its row's pow2 "
                    "degree-bucket cap, 'flat' scans every lane to the "
                    "global deg_cap (the pre-bucketing behavior)")
    ap.add_argument("--sparse-index", action="store_true",
                    help="build CSR-only target indexes (SubgraphIndex."
                    "build(..., sparse=True), DESIGN.md §11): dense "
                    "adjacency bitmaps never exist — domains come from the "
                    "CSR-native AC/FC fixpoint and plans are CSR-only; "
                    "requires --step-backend csr, auto, or partitioned")
    args = ap.parse_args()
    if args.sparse_index and args.step_backend in ("jnp", "pallas"):
        raise SystemExit(
            f"--sparse-index builds CSR-only plans, which the dense "
            f"'{args.step_backend}' backend cannot run; use --step-backend "
            "csr, auto, or partitioned"
        )
    mode = "packed" if args.packed else args.mode
    if args.partitions and args.step_backend != "partitioned":
        args.step_backend = "partitioned"

    mesh = None
    if args.devices:
        if args.devices > len(jax.local_devices()):
            raise SystemExit(
                f"--devices {args.devices}: only {len(jax.local_devices())} "
                "local devices (is XLA_FLAGS set by another import?)"
            )
        mesh = args.devices

    instances = graphgen.make_collection(
        args.collection, pattern_edges=(8, 16, 24), patterns_per_target=2,
        scale=args.scale, seed=args.seed,
    )
    cfg = EngineConfig(n_workers=args.workers, expand_width=args.expand,
                       step_backend=args.step_backend,
                       n_partitions=args.partitions,
                       root_seeding=args.root_seeding,
                       csr_walk=args.csr_walk)
    session = Enumerator(
        config=cfg, variant=args.variant, mesh=mesh,
        memory_budget_bytes=args.mem_budget or None,
    )

    indices: dict = {}
    t0 = time.perf_counter()
    queries = []
    for inst in instances:
        key = id(inst.target)
        if key not in indices:
            indices[key] = SubgraphIndex.build(
                inst.target, sparse=args.sparse_index
            )
        queries.append(session.prepare(
            inst.pattern, name=inst.name, index=indices[key],
            seed_edge="auto" if args.root_seeding != "vertex" else None))

    matches = states = 0
    pw_steals = None

    def tally(ms):
        nonlocal matches, states, pw_steals
        matches += ms.matches
        states += ms.states
        if ms.per_worker_steals is not None:
            if pw_steals is None:
                pw_steals = ms.per_worker_steals.astype("int64").copy()
            else:
                pw_steals += ms.per_worker_steals

    if mode == "single":
        for q in queries:
            ms = session.run(q)
            print(f"{ms.name:40s} matches={ms.matches:<8d} states={ms.states:<9d} "
                  f"steps={ms.steps:<7d} steals={ms.steals:<5d} {ms.match_s:6.2f}s")
            tally(ms)
    elif mode == "packed":
        for ms in session.run_batch(queries, pack_size=args.pack_size):
            print(f"{ms.name:40s} matches={ms.matches:<8d} states={ms.states:<9d} "
                  f"steps={ms.steps}")
            tally(ms)
    else:  # stream: print in completion order, as the serving loop would
        for ms in session.stream(queries, pack_size=args.pack_size):
            print(f"{ms.name:40s} matches={ms.matches:<8d} states={ms.states:<9d} "
                  f"steps={ms.steps}")
            tally(ms)

    total = time.perf_counter() - t0
    info = session.cache_info()
    print(f"\n[{args.collection}/{mode}/{args.step_backend}] {len(queries)} queries, "
          f"{matches} matches, {states} states, {total:.1f}s "
          f"({states/max(total,1e-9):.0f} states/s); "
          f"engine compiles={info['compiles']} cache_hits={info['cache_hits']}")
    if args.devices and pw_steals is not None:
        v_per_dev = session.config.n_workers // args.devices
        per_dev = pw_steals.reshape(args.devices, v_per_dev).sum(axis=1)
        print(f"mesh: {args.devices} device(s) x {v_per_dev} workers; "
              "entries stolen into each device: "
              + " ".join(f"d{i}={int(s)}" for i, s in enumerate(per_dev)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
