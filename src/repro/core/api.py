"""Public one-shot API for the subgraph-enumeration core.

    from repro.core import enumerate_subgraphs
    res = enumerate_subgraphs(pattern, target, variant="ri-ds-si-fc",
                              n_workers=16)
    print(res.matches, res.states)

This is a compatibility wrapper over the prepared-query session API
(`repro.core.session`): each call builds a throwaway
:class:`~repro.core.session.SubgraphIndex` and runs one
:class:`~repro.core.session.Query` through a process-wide
:class:`~repro.core.session.Enumerator` keyed by the engine config, so
repeated calls with the same config reuse the same shape-bucketed jitted
engines.  For multi-query workloads, use the session API directly — it
amortizes the target packing as well.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Union

from repro.core.engine import EngineConfig, EngineResult
from repro.core.graph import Graph, PackedGraph
from repro.core.plan import SearchPlan
from repro.core.session import SubgraphIndex, shared_enumerator


@dataclasses.dataclass
class EnumerationResult:
    matches: int
    states: int
    steps: int
    steals: int
    steal_rounds: int
    mean_steal_depth: float
    preprocess_s: float
    match_s: float
    engine: EngineResult
    plan: SearchPlan

    @property
    def total_s(self) -> float:
        return self.preprocess_s + self.match_s


def enumerate_subgraphs(
    pattern: Graph,
    target: Union[Graph, PackedGraph],
    variant: str = "ri-ds-si-fc",
    config: Optional[EngineConfig] = None,
    **config_kwargs,
) -> EnumerationResult:
    """Enumerate all non-induced subgraphs of ``target`` isomorphic to
    ``pattern``.

    Args:
      pattern: the (small) pattern graph.
      target: the target graph; a pre-packed :class:`PackedGraph` is reused
        across queries against the same target (the common case in the
        paper's collections: thousands of patterns per target).
      variant: ``ri`` | ``ri-ds`` | ``ri-ds-si`` | ``ri-ds-si-fc`` |
        ``ri-ds-si-acfc`` (AC ⇄ FC joint fixpoint, DESIGN.md §5).
      config: engine configuration; keyword overrides accepted.
    """
    cfg = config or EngineConfig(**config_kwargs)
    if config is not None and config_kwargs:
        cfg = dataclasses.replace(config, **config_kwargs)

    t0 = time.perf_counter()
    index = SubgraphIndex.build(target)
    session = shared_enumerator(cfg)
    query = session.prepare(pattern, variant=variant, index=index)
    t1 = time.perf_counter()

    ms = session.run(query)
    return EnumerationResult(
        matches=ms.matches,
        states=ms.states,
        steps=ms.steps,
        steals=ms.steals,
        steal_rounds=ms.steal_rounds,
        mean_steal_depth=ms.mean_steal_depth,
        preprocess_s=t1 - t0,
        match_s=ms.match_s,
        engine=ms.engine,
        plan=ms.plan,
    )
