"""Attention: GQA + RoPE with memory-efficient blockwise (flash-style)
softmax in pure JAX.

The KV sequence is scanned in blocks with an online-softmax carry
``(m, l, acc)`` in fp32; each block step is wrapped in ``jax.checkpoint`` so
the backward pass recomputes block scores instead of saving the O(S·S_kv)
score tensor.  This keeps prefill_32k (and train_4k under remat) inside HBM
without a custom kernel, and XLA still counts the matmul FLOPs for the
roofline analysis.

Sharding note (§Perf iteration 1): grouped-query attention is computed by
**expanding K/V to the full head count** (``jnp.repeat`` over heads) rather
than reshaping Q to ``[B, KH, G, S, dh]``.  With the production mesh the
grouped layout's head dims (KH = 8, G = H/KH) do not divide the 16-way
``model`` axis, so GSPMD replicated the fp32 score tensors on every model
shard — inflating per-device attention HBM traffic ~16×.  The expanded
``[B, H, ...]`` layout keeps H (48/64/32 — all divisible by 16) sharded
end-to-end; the repeated KV blocks are small (kb ≤ 1024) next to the score
tensors they shard.

Decode uses the same routine with a length-1 query block and a positional
validity mask, so a sequence-sharded KV cache (logical axis ``seq``) turns
the softmax reduction into a psum — flash-decoding via GSPMD.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.distributed.shardings import constraint

NEG_INF = -1e30


def rope_freqs(d_head: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding.  x: [B, S, H, dh]; positions: [B, S] int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _attn_block_step(scale, q, q_pos, carry, kv_blk):
    """One online-softmax step over a KV block.

    q: [B, H, S, dh]; kv_blk: (k [B, H, kb, dh], v, kv_pos [kb]).
    carry: (m, l, acc) fp32 with shapes [B, H, S(, dh)].
    """
    m, l, acc = carry
    k, v, kv_pos = kv_blk
    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((3,), (3,)), ((0, 1), (0, 1))),
    )  # [B, H, S, kb]
    s = constraint(s * scale, ("batch", "tensor", None, None))
    # causal masking; invalid (beyond kv_valid_len) positions carry 2**30
    mask = q_pos[:, None] >= kv_pos[None, :]
    s = jnp.where(mask[None, None], s, NEG_INF)

    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p, v.astype(jnp.float32), (((3,), (2,)), ((0, 1), (0, 1))),
    )  # [B, H, S, dh]
    acc_new = acc * alpha[..., None] + pv
    return (m_new, l_new, acc_new), None


def decode_attention(
    q: jnp.ndarray,  # [B, 1, H, dh]
    k: jnp.ndarray,  # [B, S_kv, KH, dh] (the cache)
    v: jnp.ndarray,
    q_offset,  # scalar position of the query token
    kv_valid_len,  # scalar; kv positions >= len are masked
) -> jnp.ndarray:
    """Single-token decode: unblocked grouped attention over the cache.

    §Perf iter 5: the KV-expansion layout regressed decode (the repeated KV
    blocks dominate when the score tensor is only [B, H, 1, kb]).  Decode
    instead keeps the grouped [B, KH, G, 1, S] scores — small even at 32k —
    and leaves the cache unexpanded, so the ``seq``-sharded cache turns the
    softmax into a psum (flash-decoding via GSPMD).
    """
    b, s, h, dh = q.shape
    _, s_kv, kh, _ = k.shape
    g = h // kh
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(b, s, kh, g, dh)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale  # [B, KH, G, 1, S_kv]
    kv_pos = jnp.arange(s_kv, dtype=jnp.int32)
    mask = (kv_pos[None, :] <= q_offset) & (kv_pos[None, :] < kv_valid_len)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, dh).astype(q.dtype)


def windowed_attention(
    q: jnp.ndarray,  # [B, S, H, dh]  (self-attention over the same sequence)
    k: jnp.ndarray,  # [B, S, KH, dh]
    v: jnp.ndarray,
    *,
    window: int,
    q_chunk: int = 1024,
) -> jnp.ndarray:
    """Sub-quadratic causal sliding-window attention: O(S · window).

    Scans query chunks; each chunk attends only its ``window + q_chunk``
    KV neighborhood, sliced with ``dynamic_slice`` — total work O(S·w)
    instead of O(S²).  This is the opt-in ``attn_window`` long-context
    variant (EXPERIMENTS.md §Beyond); the assigned full-attention archs keep
    their mandated ``long_500k`` SKIP.
    """
    b, s, h, dh = q.shape
    _, _, kh, _ = k.shape
    g = h // kh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    cq = min(q_chunk, s)
    assert s % cq == 0, (s, cq)
    n_chunks = s // cq
    win = min(window, s)
    span = win + cq  # kv neighborhood per query chunk
    scale = 1.0 / np.sqrt(dh)

    kp = jnp.pad(k, ((0, 0), (win, 0), (0, 0), (0, 0)))  # left-pad history
    vp = jnp.pad(v, ((0, 0), (win, 0), (0, 0), (0, 0)))

    def chunk(ci):
        q_c = lax.dynamic_slice_in_dim(q, ci * cq, cq, axis=1)
        k_c = lax.dynamic_slice_in_dim(kp, ci * cq, span, axis=1)
        v_c = lax.dynamic_slice_in_dim(vp, ci * cq, span, axis=1)
        sc = jnp.einsum(
            "bqhd,bkhd->bhqk", q_c.astype(jnp.float32), k_c.astype(jnp.float32)
        ) * scale
        q_pos = ci * cq + jnp.arange(cq)
        k_pos = ci * cq - win + jnp.arange(span)  # global kv positions
        mask = (
            (q_pos[:, None] >= k_pos[None, :])
            & (q_pos[:, None] - k_pos[None, :] < win + 1)
            & (k_pos[None, :] >= 0)
        )
        sc = jnp.where(mask[None, None], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v_c.astype(jnp.float32))
        return o.astype(q.dtype)

    out = lax.map(jax.checkpoint(chunk), jnp.arange(n_chunks))
    # [n_chunks, B, cq, H, dh] -> [B, S, H, dh]
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)


def blockwise_attention(
    q: jnp.ndarray,  # [B, S, H, dh]
    k: jnp.ndarray,  # [B, S_kv, KH, dh]
    v: jnp.ndarray,  # [B, S_kv, KH, dh]
    *,
    causal: bool = True,
    q_offset: int = 0,
    kv_valid_len: Optional[jnp.ndarray] = None,  # scalar; masks kv >= len
    kv_block: int = 1024,
) -> jnp.ndarray:
    """Grouped-query blockwise attention; returns [B, S, H, dh]."""
    del causal  # all supported paths are causal (decode masks via positions)
    b, s, h, dh = q.shape
    if s == 1:
        return decode_attention(
            q, k, v,
            q_offset if not isinstance(q_offset, int) else jnp.int32(q_offset),
            kv_valid_len if kv_valid_len is not None else jnp.int32(k.shape[1]),
        )
    _, s_kv, kh, _ = k.shape
    assert h % kh == 0, (h, kh)
    g = h // kh
    scale = 1.0 / np.sqrt(dh)

    kb = min(kv_block, s_kv)
    n_blocks = (s_kv + kb - 1) // kb
    pad = n_blocks * kb - s_kv

    # expand KV to full head count so the head dim shards over `model`
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)

    qh = constraint(q.transpose(0, 2, 1, 3), ("batch", "tensor", None, None))
    kx = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vx = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kx = constraint(kx, ("batch", "tensor", None, None))
    vx = constraint(vx, ("batch", "tensor", None, None))
    kx = kx.reshape(b, h, n_blocks, kb, dh).transpose(2, 0, 1, 3, 4)
    vx = vx.reshape(b, h, n_blocks, kb, dh).transpose(2, 0, 1, 3, 4)

    kv_pos = jnp.arange(n_blocks * kb, dtype=jnp.int32).reshape(n_blocks, kb)
    valid = kv_pos < (s_kv if kv_valid_len is None else kv_valid_len)
    kv_pos = jnp.where(valid, kv_pos, jnp.int32(2**30))  # masked = "future"
    q_pos = q_offset + jnp.arange(s, dtype=jnp.int32)

    m0 = jnp.full((b, h, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, dh), jnp.float32)

    step = functools.partial(_attn_block_step, scale, qh, q_pos)
    (m, l, acc), _ = lax.scan(jax.checkpoint(step), (m0, l0, a0), (kx, vx, kv_pos))

    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 2, 1, 3)
    return out.astype(q.dtype)
