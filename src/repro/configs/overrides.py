"""Config overrides: ``--set path.to.field=value`` on frozen dataclasses.

The real-config-system layer: every launcher accepts ``--set`` assignments
that are applied recursively with ``dataclasses.replace`` (configs stay
frozen/hashable — required for jit static args).  Values are coerced to the
field's annotated type; dotted paths descend into nested dataclasses
(e.g. ``moe.top_k=4``).

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-12b \
      --set n_layers=4 --set attn_window=4096
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence


class OverrideError(ValueError):
    pass


def _coerce(raw: str, current: Any) -> Any:
    if current is None:
        # best-effort literal
        for cast in (int, float):
            try:
                return cast(raw)
            except ValueError:
                pass
        return raw
    t = type(current)
    if t is bool:
        if raw.lower() in ("1", "true", "yes", "on"):
            return True
        if raw.lower() in ("0", "false", "no", "off"):
            return False
        raise OverrideError(f"cannot parse bool from {raw!r}")
    if t is int:
        return int(raw)
    if t is float:
        return float(raw)
    if t is str:
        return raw
    if t is tuple:
        parts = [p for p in raw.split(",") if p]
        elem = current[0] if current else raw
        return tuple(_coerce(p, elem) for p in parts)
    raise OverrideError(f"unsupported field type {t} for value {raw!r}")


def apply_one(cfg: Any, path: str, raw: str) -> Any:
    """Return a copy of ``cfg`` with ``path`` (dotted) set to ``raw``."""
    head, _, rest = path.partition(".")
    if not dataclasses.is_dataclass(cfg):
        raise OverrideError(f"{type(cfg).__name__} is not a config dataclass")
    names = {f.name for f in dataclasses.fields(cfg)}
    if head not in names:
        raise OverrideError(
            f"unknown field {head!r} on {type(cfg).__name__}; have {sorted(names)}"
        )
    current = getattr(cfg, head)
    if rest:
        if current is None:
            raise OverrideError(f"{head!r} is None; cannot descend into {rest!r}")
        return dataclasses.replace(cfg, **{head: apply_one(current, rest, raw)})
    return dataclasses.replace(cfg, **{head: _coerce(raw, current)})


def apply(cfg: Any, assignments: Sequence[str]) -> Any:
    """Apply ``key=value`` assignments (as from argparse ``--set``)."""
    for a in assignments or ():
        if "=" not in a:
            raise OverrideError(f"expected key=value, got {a!r}")
        path, _, raw = a.partition("=")
        cfg = apply_one(cfg, path.strip(), raw.strip())
    return cfg
