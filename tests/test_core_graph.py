"""Unit tests: graph representations and bitmap helpers."""

import numpy as np
import pytest

from repro.core.graph import (
    Graph,
    PackedGraph,
    bitmap_from_indices,
    bitmap_to_indices,
    csr_planes_from_bitmaps,
    n_words,
    popcount,
)


def test_bitmap_roundtrip(rng):
    for n in (1, 31, 32, 33, 100, 1000):
        idx = np.unique(rng.integers(0, n, size=min(n, 37)))
        bits = bitmap_from_indices(idx, n)
        back = bitmap_to_indices(bits)
        assert np.array_equal(np.sort(idx), back)
        assert popcount(bits[None, :])[0] == len(idx)


def test_popcount_matrix(rng):
    bits = rng.integers(0, 2**32, size=(7, 5), dtype=np.uint32)
    expect = np.array(
        [sum(bin(int(w)).count("1") for w in row) for row in bits]
    )
    assert np.array_equal(popcount(bits), expect)


def test_adjacency_bitmaps_directed():
    g = Graph.from_edges(4, [(0, 1), (1, 2), (3, 0)], edge_labels=[0, 1, 0])
    p = PackedGraph.from_graph(g)
    assert p.n_edge_labels == 2
    # out: label 0: 0->1, 3->0
    assert bitmap_to_indices(p.adj_bits[0, 0, 0]).tolist() == [1]
    assert bitmap_to_indices(p.adj_bits[0, 0, 3]).tolist() == [0]
    # label 1: 1->2
    assert bitmap_to_indices(p.adj_bits[1, 0, 1]).tolist() == [2]
    # in rows: adj_in[l, u] bit v iff v->u
    assert bitmap_to_indices(p.adj_bits[0, 1, 1]).tolist() == [0]
    assert bitmap_to_indices(p.adj_bits[1, 1, 2]).tolist() == [1]


def test_degrees_and_neighbors():
    g = Graph.from_edges(3, [(0, 1), (1, 2)], undirected=True)
    assert g.out_degrees().tolist() == [1, 2, 1]
    assert g.in_degrees().tolist() == [1, 2, 1]
    assert set(g.neighbors(1).tolist()) == {0, 2}
    assert g.has_edge(0, 1) and g.has_edge(1, 0) and not g.has_edge(0, 2)


def test_pad_words():
    g = Graph.from_edges(3, [(0, 1)], undirected=True)
    p = PackedGraph.from_graph(g, pad_words_to=128)
    assert p.w == 128
    assert p.adj_bits.shape[-1] == 128
    # padding bits must stay zero
    assert p.adj_bits[:, :, :, 1:].sum() == 0


def test_n_words():
    assert n_words(0) == 1
    assert n_words(1) == 1
    assert n_words(32) == 1
    assert n_words(33) == 2


# ---------------------------------------------------------------------------
# CSR canonical form (consumed directly by the csr step backend, so these
# arrays must be canonical: sorted indices per row, degenerate runs exact)
# ---------------------------------------------------------------------------

def _assert_rows_sorted(indptr, indices):
    for u in range(len(indptr) - 1):
        seg = indices[indptr[u]:indptr[u + 1]]
        assert np.all(np.diff(seg) >= 0), (u, seg)


def test_csr_rows_sorted_and_complete(rng):
    """csr() indices are sorted within every row regardless of edge
    insertion order, and each row is exactly the out-neighborhood."""
    n = 12
    edges = [(int(u), int(v)) for u, v in rng.integers(0, n, (40, 2)) if u != v]
    g = Graph.from_edges(n, edges)
    indptr, indices, elabs = g.csr()
    assert indptr[0] == 0 and indptr[-1] == g.m
    _assert_rows_sorted(indptr, indices)
    for u in range(n):
        seg = indices[indptr[u]:indptr[u + 1]]
        np.testing.assert_array_equal(np.sort(seg), np.sort(g.out_neighbors(u)))


def test_csr_empty_graph():
    g = Graph.from_edges(0, [])
    indptr, indices, elabs = g.csr()
    assert indptr.tolist() == [0] and indices.size == 0 and elabs.size == 0
    cp = g.csr_planes(n_elab=1)
    assert cp.indptr.shape == (2, 1) and cp.nnz == 0 and cp.deg_cap == 0


def test_csr_isolated_vertices():
    """Isolated vertices are zero-length indptr runs — before and after
    populated rows."""
    g = Graph.from_edges(5, [(1, 3), (3, 1)])
    indptr, indices, _ = g.csr()
    assert indptr.tolist() == [0, 0, 1, 1, 2, 2]
    cp = g.csr_planes()
    for p in range(cp.n_planes):
        row_lens = np.diff(cp.indptr[p])
        assert row_lens[0] == 0 and row_lens[2] == 0 and row_lens[4] == 0


def test_csr_self_loops_kept():
    """Self-loops appear in their own row (and on the plane diagonals),
    sorted in place among the other neighbors."""
    g = Graph.from_edges(4, [(2, 2), (2, 0), (2, 3)])
    indptr, indices, _ = g.csr()
    np.testing.assert_array_equal(indices[indptr[2]:indptr[3]], [0, 2, 3])
    cp = g.csr_planes()
    out_row2 = cp.indices[cp.indptr[0, 2]:cp.indptr[0, 3]]
    np.testing.assert_array_equal(out_row2, [0, 2, 3])
    in_row2 = cp.indices[cp.indptr[1, 2]:cp.indptr[1, 3]]
    np.testing.assert_array_equal(in_row2, [2])


def test_csr_duplicate_edges():
    """csr() keeps duplicates (edge-list CSR, sorted so they're adjacent);
    csr_planes() dedupes them — its rows are bitmap supports."""
    g = Graph.from_edges(3, [(0, 1), (0, 2), (0, 1), (0, 1)])
    indptr, indices, _ = g.csr()
    np.testing.assert_array_equal(indices[indptr[0]:indptr[1]], [1, 1, 1, 2])
    cp = g.csr_planes()
    np.testing.assert_array_equal(cp.indices[cp.indptr[0, 0]:cp.indptr[0, 1]],
                                  [1, 2])
    assert cp.deg_cap == 2


def test_csr_planes_match_bitmaps(rng):
    """csr_planes() is bit-for-bit the support of adjacency_bitmaps() —
    the contract the conformance suite's bit-identity rests on — including
    with multiple edge labels, duplicates, and self-loops."""
    n = 14
    edges = [(int(u), int(v)) for u, v in rng.integers(0, n, (50, 2))]
    edges += edges[:5]  # duplicates (some may be self-loops already)
    elabs = rng.integers(0, 3, len(edges))
    g = Graph.from_edges(n, edges, edge_labels=elabs)
    cp = g.csr_planes()
    cb = csr_planes_from_bitmaps(PackedGraph.from_graph(g).adj_bits)
    np.testing.assert_array_equal(cp.indptr, cb.indptr)
    np.testing.assert_array_equal(cp.indices, cb.indices)
    assert cp.deg_cap == cb.deg_cap and cp.n_t == cb.n_t


def test_csr_planes_label_overflow_rejected():
    g = Graph.from_edges(2, [(0, 1)], edge_labels=[3])
    with pytest.raises(ValueError, match="edge label"):
        g.csr_planes(n_elab=2)
