"""``repro.bench`` — import shim for the repo-root ``benchmarks/`` package.

The benchmark scripts live next to the repo root (not under ``src/``) so
they can write ``artifacts/``; historically every consumer did its own
``sys.path.insert(0, ".")`` which only worked when the cwd happened to be
the repo root.  Importing this module instead locates the repo root from
the installed package path and makes ``benchmarks`` importable::

    import repro.bench                      # side effect: root on sys.path
    from benchmarks import common           # now resolves anywhere

or, equivalently::

    from repro.bench import benchmarks_root
"""

from __future__ import annotations

import os
import sys

# src/repro/bench/__init__.py -> repo root is three levels up from here.
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def benchmarks_root() -> str:
    """Absolute path of the repo-root ``benchmarks/`` directory."""
    return os.path.join(_ROOT, "benchmarks")


if os.path.isdir(benchmarks_root()) and _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)
