"""LM transformer tests: loss, grads, KV-cache decode consistency, MoE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tf
from repro.models.moe import MoEConfig, capacity, moe_ffn


def tiny_cfg(moe=False, **kw):
    return tf.LMConfig(
        name="t",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=97,
        activation="swiglu" if moe else "squared_relu",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, capacity_round=8,
                      n_shared_experts=1) if moe else None,
        max_seq_len=32,
        loss_chunk=16,
        kv_block=8,
        **kw,
    )


@pytest.mark.parametrize("moe", [False, True])
def test_loss_and_grads_finite(moe):
    cfg = tiny_cfg(moe)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p: tf.loss_fn(p, cfg, batch), has_aux=True)
    )(params)
    assert jnp.isfinite(loss)
    # near-uniform init => loss ~ ln(vocab)
    assert abs(float(metrics["lm_loss"]) - np.log(cfg.vocab_size)) < 2.0
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("moe", [False, True])
def test_decode_matches_prefill(moe):
    """Greedy decode from a prefix cache must reproduce the prefill logits of
    the next position — the KV-cache correctness gate.

    The MoE variant needs a drop-free capacity: prefill(9) dispatches in
    groups of 9 tokens while prefill(8)+decode dispatch in groups of 1, so
    any capacity-dropout difference between the paths would (legitimately)
    change the logits and mask the cache comparison this test is about.
    capacity_factor=2 makes capacity >= the max per-expert assignment count
    (one per token) in every group, so neither path ever drops."""
    cfg = tiny_cfg(moe)
    if moe:
        import dataclasses

        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=2.0)
        )
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)

    # full prefill over all 9 tokens: logits at last position
    full_logits, _ = jax.jit(lambda p, t: tf.prefill(p, cfg, t, max_len=16))(
        params, toks
    )
    # prefill over the first 8, then decode token 9
    _, cache = jax.jit(lambda p, t: tf.prefill(p, cfg, t, max_len=16))(
        params, toks[:, :8]
    )
    step_logits, _ = jax.jit(
        lambda p, c, t, l: tf.decode_step(p, cfg, c, t, l)
    )(params, cache, toks[:, 8:9], jnp.int32(8))
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )


def test_chunked_loss_matches_direct():
    cfg = tiny_cfg(False)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    hidden, _ = tf.forward(params, cfg, toks)
    loss_chunked, _ = tf.lm_loss(hidden, params["lm_head"], toks, chunk=8)
    # direct full-vocab loss
    logits = jnp.einsum(
        "bsd,dv->bsv", hidden.astype(jnp.float32),
        params["lm_head"].astype(jnp.float32),
    )
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, toks[..., None], axis=-1)[..., 0]
    direct = jnp.mean(logz - gold)
    np.testing.assert_allclose(float(loss_chunked), float(direct), rtol=1e-5)


@pytest.mark.parametrize("groups", [1, 4])
def test_moe_capacity_and_combination(groups):
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff_expert=16, capacity_round=4,
                    capacity_factor=100.0,  # huge capacity: nothing dropped
                    dispatch_groups=groups)
    t, d = 12, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    router = jnp.concatenate([jnp.ones((d, 1)), -jnp.ones((d, 1))], axis=1)
    wg = jax.random.normal(jax.random.PRNGKey(1), (2, d, 16)) * 0.1
    wu = jax.random.normal(jax.random.PRNGKey(2), (2, d, 16)) * 0.1
    wd = jax.random.normal(jax.random.PRNGKey(3), (2, 16, d)) * 0.1
    out, aux = moe_ffn(x, router, wg, wu, wd, cfg)
    assert out.shape == (t, d)
    assert jnp.isfinite(aux)
    # with top-1 routing and ample capacity, output equals the selected
    # expert's FFN applied per token
    logits = x @ router
    sel = jnp.argmax(logits, axis=-1)
    expect = []
    for i in range(t):
        e = int(sel[i])
        h = jax.nn.silu(x[i] @ wg[e]) * (x[i] @ wu[e])
        expect.append(h @ wd[e])
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.stack(expect)),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_overflow():
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff_expert=8, capacity_round=4,
                    capacity_factor=0.01, dispatch_groups=1)
    cap = capacity(cfg, 1000)
    assert cap == 8  # int(1000*0.01/2)+1 = 6 -> rounded up to 8
    x = jnp.ones((64, 4))
    router = jnp.zeros((4, 2)).at[:, 0].set(1.0)  # everyone routes to expert 0
    wg = jnp.ones((2, 4, 8)) * 0.1
    wu = jnp.ones((2, 4, 8)) * 0.1
    wd = jnp.ones((2, 8, 4)) * 0.1
    out, _ = moe_ffn(x, router, wg, wu, wd, cfg)
    # only `capacity(64)` tokens produce nonzero output (one dispatch group)
    cap64 = capacity(cfg, 64)
    nz = jnp.sum(jnp.any(out != 0, axis=-1))
    assert int(nz) == cap64


def test_moe_group_local_capacity():
    """Group-local dispatch: each group gets its own capacity slice."""
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff_expert=8, capacity_round=4,
                    capacity_factor=0.1, dispatch_groups=4)
    x = jnp.ones((64, 4))
    router = jnp.zeros((4, 2)).at[:, 0].set(1.0)
    wg = jnp.ones((2, 4, 8)) * 0.1
    wu = jnp.ones((2, 4, 8)) * 0.1
    wd = jnp.ones((2, 8, 4)) * 0.1
    out, _ = moe_ffn(x, router, wg, wu, wd, cfg)
    # per-group capacity for 16 tokens each
    cap_g = capacity(cfg, 16)
    nz = int(jnp.sum(jnp.any(out != 0, axis=-1)))
    assert nz == 4 * cap_g


def test_param_count_formulas():
    cfg = tiny_cfg(True)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    assert actual == cfg.param_count()
    assert cfg.active_param_count() < cfg.param_count()
