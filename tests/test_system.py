"""End-to-end behaviour tests for the paper's system.

Covers the full pipeline on a generated collection: pack → preprocess →
parallel enumerate → verify against the sequential oracle; plus the
search-space monotonicity claims and the serving/training drivers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, PackedGraph, enumerate_subgraphs
from repro.core.ref import ref_enumerate
from repro.data import graphgen


def test_collection_end_to_end():
    instances = graphgen.make_collection(
        "ppis32-like", pattern_edges=(4, 8), patterns_per_target=1,
        scale=0.1, seed=3,
    )
    assert len(instances) >= 2
    cache = {}
    for inst in instances:
        if id(inst.target) not in cache:
            cache[id(inst.target)] = PackedGraph.from_graph(inst.target)
        packed = cache[id(inst.target)]
        res = enumerate_subgraphs(
            inst.pattern, packed, variant="ri-ds-si-fc",
            n_workers=8, expand_width=4,
        )
        ref = ref_enumerate(inst.pattern, inst.target, variant="ri-ds-si-fc",
                            packed=packed)
        assert res.matches == ref.matches, inst.name
        assert res.states == ref.states, inst.name
        assert res.matches >= 1, inst.name  # extracted patterns always occur


def test_variant_pruning_sound():
    """SI and FC never change match counts (soundness, paper C4/C5).

    Note: search-space SIZE is not per-instance monotone — orderings are
    heuristics and an SI tie-break can occasionally enlarge one instance's
    tree (the paper's own comparison [Bonnici & Giugno 2017] observes the
    same); aggregate reductions are measured in benchmarks/bench_searchspace.
    """
    instances = graphgen.make_collection(
        "graemlin32-like", pattern_edges=(8, 16), patterns_per_target=1,
        scale=0.15, seed=5,
    )
    cfg = EngineConfig(n_workers=4, expand_width=4)
    for inst in instances:
        packed = PackedGraph.from_graph(inst.target)
        results = {}
        for v in ("ri-ds", "ri-ds-si", "ri-ds-si-fc"):
            results[v] = enumerate_subgraphs(inst.pattern, packed, variant=v,
                                             config=cfg)
        m = results["ri-ds"].matches
        assert results["ri-ds-si"].matches == m
        assert results["ri-ds-si-fc"].matches == m
        # FC on top of the SAME SI ordering can only remove candidates
        assert results["ri-ds-si-fc"].states <= results["ri-ds-si"].states * 1.2 + 2


def test_train_driver_loss_improves(tmp_path):
    from repro.launch.train import train_lm
    from repro.models.transformer import LMConfig

    cfg = LMConfig(name="sys-tiny", n_layers=2, d_model=32, n_heads=4,
                   n_kv_heads=2, d_ff=64, vocab_size=64, activation="swiglu",
                   max_seq_len=32, loss_chunk=16, kv_block=8)
    # lr sized to the tiny model: the default 3e-4 moves the loss by less
    # than batch noise within 25 steps, making the assertion a coin flip
    _, _, history = train_lm(cfg, steps=25, batch=4, seq=24, lr=3e-3,
                             ckpt_dir=str(tmp_path / "ck"), log=lambda *_: None)
    assert len(history) == 25
    assert history[-1] < history[0], "training must reduce loss"


def test_serve_driver_smoke():
    """The always-on service entrypoint (DESIGN.md §7): N synthetic
    clients through one EnumerationService, streamed results verified
    against standalone runs inside the driver itself."""
    from repro.launch.serve import main

    assert main(["--smoke", "--clients", "2", "--queries", "2",
                 "--target-n", "36", "--no-csr", "--window-ms", "1"]) == 0


def test_work_stealing_transfers_happen():
    """On an imbalanced instance, stealing must actually move work."""
    tgt = graphgen.random_graph(60, 400, n_labels=1, seed=11)
    pat = graphgen.extract_pattern(tgt, 6, seed=12)
    res = enumerate_subgraphs(
        pat, tgt, variant="ri", n_workers=16, expand_width=2,
        rebalance_interval=2,
    )
    if res.states > 500:
        assert res.steals > 0, "expected steal traffic on an irregular instance"
        per_w = res.engine.per_worker_states
        assert (per_w > 0).sum() >= 2, "work must spread beyond one worker"
