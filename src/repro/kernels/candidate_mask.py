"""Pallas TPU kernel for the engine's hot loop: batched candidate bitmaps.

For a batch of ``b`` search lanes, compute

    cand[l] = dom_bits[pos[l]] ∧ ¬used[l] ∧ ⋀_j adj_rows[row_idx[l, j]]

over packed uint32 bitmaps of ``w`` words.  ``row_idx`` is the flattened
``(edge_label, direction, mapped_parent)`` adjacency row per parent-constraint
slot; unused slots point at a **neutral all-ones row** appended at index
``n_rows`` so the kernel body is branch-free.

TPU mapping
-----------
* Grid ``(b, mp + 1)`` — lane-major, then one step per parent slot plus one
  for the ``dom ∧ ¬used`` initialization.
* The row gathers are expressed through **scalar-prefetched index maps**
  (``pltpu.PrefetchScalarGridSpec``): the BlockSpec ``index_map`` for the
  adjacency operand reads ``row_idx`` to select which ``(1, w)`` row block the
  pipeline DMAs into VMEM next.  This is the TPU-native form of the paper's
  pointer-chasing adjacency-list walk: the DMA engine chases the indices
  while the VPU ANDs the previous row.
* Block shapes are ``(1, w)`` with ``w`` padded to a multiple of 128 lanes
  (uint32 words), so each AND is a full-width VPU op; the running candidate
  bitmap lives in the output block in VMEM across the ``mp`` grid steps
  (same output index for all j ⇒ accumulation without HBM round-trips).

VMEM footprint per grid step: 3 × w × 4 bytes (dom/used-or-row + out) —
≤ ~1.2 MB even for the largest paper target (33k nodes ⇒ w = 1034 → padded
1152 words ⇒ 4.6 KB/row); far below the ~16 MB VMEM budget, leaving the
pipeline free to double-buffer row DMAs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE_WORDS = 128  # pad w to a multiple of the 128-lane VPU width


def pad_words(w: int) -> int:
    return ((w + LANE_WORDS - 1) // LANE_WORDS) * LANE_WORDS


def _kernel(pos_ref, row_idx_ref, dom_ref, row_ref, used_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = dom_ref[...] & ~used_ref[...]

    @pl.when(j > 0)
    def _and_row():
        out_ref[...] = out_ref[...] & row_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def candidate_mask(
    rows: jnp.ndarray,  # [n_rows + 1, w] uint32, last row all-ones
    dom_bits: jnp.ndarray,  # [p_pad, w] uint32
    pos: jnp.ndarray,  # [b] int32
    row_idx: jnp.ndarray,  # [b, mp] int32 (unused slots -> n_rows)
    used: jnp.ndarray,  # [b, w] uint32
    interpret: bool = True,
) -> jnp.ndarray:
    """Jit'd wrapper; pads the word dimension and invokes the kernel.

    ``interpret=True`` executes the kernel body in Python on CPU (the
    validation mode for this container); on TPU pass ``interpret=False``.
    """
    b, w = used.shape
    mp = row_idx.shape[1]
    wp = pad_words(w)
    if wp != w:
        padw = ((0, 0), (0, wp - w))
        rows = jnp.pad(rows, padw)
        dom_bits = jnp.pad(dom_bits, padw)
        used = jnp.pad(used, padw)

    grid = (b, mp + 1)

    def dom_map(l, j, pos_s, idx_s):
        return (pos_s[l], 0)

    def row_map(l, j, pos_s, idx_s):
        # j == 0 is the init step; feed the neutral row (index n_rows).
        jj = jnp.maximum(j - 1, 0)
        return (jnp.where(j == 0, rows.shape[0] - 1, idx_s[l, jj]), 0)

    def lane_map(l, j, pos_s, idx_s):
        return (l, 0)

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, wp), dom_map),
                pl.BlockSpec((1, wp), row_map),
                pl.BlockSpec((1, wp), lane_map),
            ],
            out_specs=pl.BlockSpec((1, wp), lane_map),
        ),
        out_shape=jax.ShapeDtypeStruct((b, wp), jnp.uint32),
        interpret=interpret,
    )(pos.astype(jnp.int32), row_idx.astype(jnp.int32), dom_bits, rows, used)
    return out[:, :w]


def flatten_adj_rows(adj_bits: jnp.ndarray) -> jnp.ndarray:
    """``[n_elab, 2, n_t, w] -> [n_elab * 2 * n_t + 1, w]`` with a trailing
    all-ones neutral row (AND-identity) for padded parent slots."""
    ne, two, n_t, w = adj_bits.shape
    flat = adj_bits.reshape(ne * two * n_t, w)
    ones = jnp.full((1, w), jnp.uint32(0xFFFFFFFF))
    return jnp.concatenate([flat, ones], axis=0)


def flat_row_index(
    parent_pos: jnp.ndarray,  # [mp] int32 (-1 padded)
    parent_dir: jnp.ndarray,
    parent_elab: jnp.ndarray,
    mapping: jnp.ndarray,  # [p_pad] int32
    n_t: int,
    n_rows: int,
) -> jnp.ndarray:
    """Per-lane flattened adjacency row indices for `candidate_mask`."""
    t = jnp.where(parent_pos >= 0, mapping[jnp.maximum(parent_pos, 0)], 0)
    idx = (parent_elab * 2 + parent_dir) * n_t + jnp.clip(t, 0, n_t - 1)
    return jnp.where(parent_pos >= 0, idx, n_rows).astype(jnp.int32)
