"""stablelm-12b — 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; hf]"""

from repro.configs.lm_common import make_lm_arch
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    loss_chunk=65536,  # §Perf iter 2: fewer lm_head re-reads (was 2048)
    vocab_size=100352,
    activation="swiglu",
    max_seq_len=32768,
)

SMOKE = LMConfig(
    name="stablelm-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    activation="swiglu",
    max_seq_len=64,
    loss_chunk=16,
    kv_block=8,
)

ARCH = make_lm_arch(CFG, SMOKE)
