"""Prepared-query session API for subgraph enumeration.

The paper's workloads are collections of *thousands* of patterns per target
(PPIS32: 420, PDBSv1: 1760 queries).  The one-shot
:func:`repro.core.api.enumerate_subgraphs` re-packs the target, rebuilds the
plan and re-traces the engine on every call; this module is the
session-oriented surface that amortizes all three:

* :class:`SubgraphIndex` — a prepared target: the :class:`PackedGraph`
  bitmaps plus label/degree metadata, built once, reusable across queries
  and picklable (pure numpy — ship it to another process, load it in a
  server).
* :class:`Query` — a pattern compiled against an index into a
  :class:`SearchPlan` whose padding is snapped to **shape buckets**
  (``p_pad ∈ {16, 32, 64, 128}``, fixed ``max_parents``), so thousands of
  patterns lower to a handful of XLA compilations.
* :class:`Enumerator` — the session object: an :class:`EngineConfig`, an
  optional device mesh (``mesh=`` shards the worker axis over the mesh
  ``data`` axis via ``shard_map``; ``n_workers`` snaps up to a multiple of
  the device count — see DESIGN.md §2.4), a keyed compile cache ``(kind,
  mesh signature, p_pad, max_parents, n_t, w, …) → jitted engine`` with
  ``compiles`` / ``cache_hits`` counters, and three execution methods
  sharing one code path:

    - ``run(query)``                 — one query, one engine invocation;
    - ``run_batch(queries)``         — LPT-balanced vmapped packs (the
      former ``core/multi.py`` driver), exactly one result per query, in
      input order;
    - ``stream(queries)``            — generator yielding a
      :class:`MatchSet` per query as packs drain (the serving path).

  Preprocessing batches too (DESIGN.md §5): ``prepare_batch(patterns)``
  runs the AC ⇄ FC domain fixpoint for a whole padded pattern batch as one
  vmapped jitted call on device, keyed into the same compile cache; raw
  ``Graph`` inputs to ``run_batch``/``stream`` route through it
  automatically (``domain_backend='numpy'`` restores the host loop).

Results unify into :class:`MatchSet`: counts, per-worker statistics, and
lazy match materialization (``mappings()`` re-runs the prepared query with
a match buffer only when asked).

Typical use::

    index = SubgraphIndex.build(target)             # once per target
    enum = Enumerator(index, n_workers=16)          # once per session
    q = enum.prepare(pattern)                       # per pattern (cheap)
    ms = enum.run(q)                                # engine reused
    for ms in enum.stream([enum.prepare(p) for p in patterns]):
        print(ms.name, ms.matches)
    enum.cache_info()   # {'compiles': 1, 'cache_hits': 419, ...}
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
import warnings
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import domains as dom_mod
from repro.core import engine as eng
from repro.core import extend
from repro.core.engine import EngineConfig, EngineResult
from repro.core.graph import Graph, PackedGraph, popcount
from repro.core.plan import SearchPlan, build_plan, variant_flags
from repro.core.scheduler import balance_assignment

# Padded pattern-position buckets: every plan's ``p_pad`` snaps up to one of
# these, so patterns of size 3..16 share one engine compilation, 17..32 the
# next, and so on.  Beyond the last bucket we round up to multiples of it.
SHAPE_BUCKETS: Tuple[int, ...] = (16, 32, 64, 128)

# Fixed parent-slot padding for bucketed plans (the ordering expands it when
# a dense pattern genuinely needs more; that pattern then lands in its own —
# rare — bucket).
DEFAULT_MAX_PARENTS = 8

# Cap on the lazily materialized match buffer (per worker).
_MATERIALIZE_CAP = 1 << 17


def snap_p_pad(n_p: int) -> int:
    """Smallest shape bucket that holds ``n_p`` pattern positions."""
    for b in SHAPE_BUCKETS:
        if n_p <= b:
            return b
    top = SHAPE_BUCKETS[-1]
    return ((n_p + top - 1) // top) * top


def snap_arc_pad(n_arcs: int) -> int:
    """Arc-slot bucket for the device domain engine: multiples of 8."""
    return max(8, ((n_arcs + 7) // 8) * 8)


def snap_loop_pad(n_loops: int) -> int:
    """Self-loop-slot bucket: 1 (the loop-free common case) or multiples
    of 4."""
    return 1 if n_loops == 0 else ((n_loops + 3) // 4) * 4


def snap_batch_pad(n: int) -> int:
    """Pattern-batch lane bucket: next power of two (inert lanes replicate
    lane 0 and are discarded), so B patterns cost O(log B) compilations."""
    return 1 << max(n - 1, 0).bit_length()


# ---------------------------------------------------------------------------
# SubgraphIndex — a prepared target
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SubgraphIndex:
    """A target graph prepared for repeated querying.

    Holds the packed adjacency bitmaps plus the label/degree metadata the
    preprocessing (domains, ordering) consults.  Pure numpy — picklable and
    shareable across processes; build once per target, reuse for every
    pattern.
    """

    packed: PackedGraph
    n_labels: int
    label_counts: np.ndarray  # [n_labels] int64
    max_degree: int
    build_s: float

    @staticmethod
    def build(target: Union[Graph, PackedGraph, "SubgraphIndex"]) -> "SubgraphIndex":
        if isinstance(target, SubgraphIndex):
            return target
        t0 = time.perf_counter()
        packed = target if isinstance(target, PackedGraph) else PackedGraph.from_graph(target)
        n_labels = int(packed.labels.max()) + 1 if packed.n else 0
        counts = np.bincount(packed.labels, minlength=max(n_labels, 1)).astype(np.int64)
        degs = packed.deg_out + packed.deg_in
        max_deg = int(degs.max()) if packed.n else 0
        return SubgraphIndex(
            packed=packed,
            n_labels=n_labels,
            label_counts=counts,
            max_degree=max_deg,
            build_s=time.perf_counter() - t0,
        )

    @property
    def n(self) -> int:
        return self.packed.n

    @property
    def w(self) -> int:
        return self.packed.w

    @property
    def n_edge_labels(self) -> int:
        return self.packed.n_edge_labels


# ---------------------------------------------------------------------------
# Query — a pattern compiled against an index
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Query:
    """A pattern prepared against a :class:`SubgraphIndex`.

    ``plan`` is padded to a shape bucket so that same-bucket queries share
    one jitted engine inside an :class:`Enumerator`.
    """

    pattern: Graph
    plan: SearchPlan
    variant: str
    name: str
    prepare_s: float

    @property
    def bucket(self) -> Tuple[int, int, int, int, int]:
        """The compile-cache shape key: (p_pad, max_parents, n_t, w, n_elab)."""
        p = self.plan
        return (p.p_pad, p.max_parents, p.n_t, p.w, p.n_edge_labels)

    @property
    def satisfiable(self) -> bool:
        return self.plan.satisfiable


def prepare_query(
    pattern: Graph,
    index: Union[SubgraphIndex, Graph, PackedGraph],
    variant: str = "ri-ds-si-fc",
    name: Optional[str] = None,
    p_pad: Optional[int] = None,
    max_parents: Optional[int] = None,
) -> Query:
    """Compile ``pattern`` against ``index`` into a bucketed :class:`Query`."""
    index = SubgraphIndex.build(index)
    t0 = time.perf_counter()
    plan = build_plan(
        pattern,
        index.packed,
        variant=variant,
        p_pad=p_pad if p_pad is not None else snap_p_pad(pattern.n),
        max_parents=max_parents if max_parents is not None else DEFAULT_MAX_PARENTS,
    )
    return Query(
        pattern=pattern,
        plan=plan,
        variant=variant,
        name=name or _default_name(pattern),
        prepare_s=time.perf_counter() - t0,
    )


def _default_name(pattern: Graph) -> str:
    """Default query name, shared by prepare_query and prepare_batch."""
    return f"q{pattern.n}n{pattern.m}m"


# ---------------------------------------------------------------------------
# MatchSet — the unified result
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MatchSet:
    """Result of enumerating one query: counts, per-worker stats, lazy matches."""

    name: str
    query_index: int
    matches: int
    states: int
    steps: int
    steals: int
    steal_rounds: int
    mean_steal_depth: float
    mean_expand_depth: float
    per_worker_states: Optional[np.ndarray]
    per_worker_matches: Optional[np.ndarray]
    per_worker_steals: Optional[np.ndarray]
    preprocess_s: float
    match_s: float
    plan: SearchPlan
    engine: EngineResult
    retries: int = 0  # overflow retries spent (stack_cap doubled each time)
    _match_buf: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    _materialize: Optional[Callable[[], Optional[np.ndarray]]] = dataclasses.field(
        default=None, repr=False
    )
    _mappings: Optional[List[Tuple[int, ...]]] = dataclasses.field(default=None, repr=False)

    @property
    def total_s(self) -> float:
        return self.preprocess_s + self.match_s

    def mappings(self) -> List[Tuple[int, ...]]:
        """Materialized match mappings (order position -> target node).

        Lazy: if the engine ran in counting mode (the benchmarked mode), the
        prepared query is re-run once with a match buffer sized to hold every
        match; the result is cached on the MatchSet.
        """
        if self._mappings is not None:
            return self._mappings
        if self.matches == 0:
            self._mappings = []
            return self._mappings
        if self.matches > _MATERIALIZE_CAP and self._match_buf is None:
            raise RuntimeError(
                f"{self.matches} matches exceed the materialization cap "
                f"({_MATERIALIZE_CAP}); re-run with an explicit "
                "collect_matches budget and consume engine.match_buf directly"
            )
        buf = self._match_buf
        if buf is None and self._materialize is not None:
            buf = self._materialize()
        out: List[Tuple[int, ...]] = []
        if buf is not None:
            n_p = self.plan.n_p
            rows = buf.reshape(-1, buf.shape[-1])[:, :n_p]
            valid = (rows >= 0).all(axis=1)
            out = [tuple(int(x) for x in r) for r in rows[valid]]
        self._mappings = out
        return out


def _empty_engine_result() -> EngineResult:
    return EngineResult(
        matches=0, states=0, steps=0, steals=0, steal_rounds=0,
        mean_steal_depth=0.0, mean_expand_depth=0.0,
        per_worker_states=None, per_worker_matches=None,
        overflow=False, match_buf=None,
    )


# ---------------------------------------------------------------------------
# Enumerator — the session
# ---------------------------------------------------------------------------

class Enumerator:
    """A subgraph-enumeration session with a shape-bucketed compile cache.

    Holds an :class:`EngineConfig` and a dict of jitted engines keyed by
    ``(cfg, kind, pack, bucket)``.  All three execution methods go through
    the same cache, so any mix of ``run`` / ``run_batch`` / ``stream`` over
    same-bucket queries costs at most one compilation per (kind, pack
    width).  ``compiles`` and ``cache_hits`` counters let benchmarks prove
    recompilation is gone.

    ``Enumerator(..., step_backend="auto")`` defers the expansion-backend
    choice to the target size: queries against targets beyond
    ``extend.CSR_AUTO_NT`` (32,768) nodes run the sparse CSR backend
    (DESIGN.md §6.4), smaller ones the dense ``jnp`` step.  An explicit
    ``step_backend=`` always wins.  The cache key carries the cfg *and*
    the bucket's ``n_t``, so one session can mix resolutions without
    collisions.
    """

    def __init__(
        self,
        index: Union[SubgraphIndex, Graph, PackedGraph, None] = None,
        config: Optional[EngineConfig] = None,
        variant: str = "ri-ds-si-fc",
        mesh: Union["jax.sharding.Mesh", int, None] = None,
        domain_backend: str = "device",
        max_cache_entries: int = 0,
        **config_kwargs,
    ):
        cfg = config or EngineConfig(**config_kwargs)
        if config is not None and config_kwargs:
            cfg = dataclasses.replace(config, **config_kwargs)
        self.mesh = _coerce_mesh(mesh)
        if self.mesh is not None:
            axis = eng.mesh_worker_axis(self.mesh)
            n_dev = int(self.mesh.shape[axis])
            if cfg.n_workers % n_dev:
                # snap up so every device owns the same number of stacks
                cfg = dataclasses.replace(
                    cfg, n_workers=((cfg.n_workers + n_dev - 1) // n_dev) * n_dev
                )
        if domain_backend not in ("device", "numpy"):
            raise ValueError(
                f"domain_backend must be 'device' or 'numpy', got {domain_backend!r}"
            )
        self.config = cfg
        self.variant = variant
        self.domain_backend = domain_backend
        if max_cache_entries < 0:
            raise ValueError(f"max_cache_entries must be >= 0, got {max_cache_entries}")
        self.max_cache_entries = max_cache_entries
        self.index = SubgraphIndex.build(index) if index is not None else None
        # LRU-ordered compile cache: hits move entries to the back, inserts
        # evict from the front once max_cache_entries is exceeded (0 = no
        # bound — batch scripts; servers set a bound, DESIGN.md §7).
        self._engines: "collections.OrderedDict[tuple, Callable]" = collections.OrderedDict()
        # target-side device arrays for batched domain preprocessing, keyed
        # by the packed target's identity (pinned so ids can't be recycled)
        self._dom_targets: Dict[int, Tuple[PackedGraph, dom_mod.TargetDomainArrays]] = {}
        self.compiles = 0
        self.cache_hits = 0
        self.evictions = 0

    # -- cache -------------------------------------------------------------

    def cache_stats(self) -> Dict[str, int]:
        """Compile-cache counters: ``compiles`` / ``cache_hits`` /
        ``evictions`` plus current ``entries`` and the configured
        ``max_entries`` bound (0 = unbounded).  The serving metrics layer
        snapshots this to report cache hit rate."""
        return {
            "compiles": self.compiles,
            "cache_hits": self.cache_hits,
            "evictions": self.evictions,
            "entries": len(self._engines),
            "max_entries": self.max_cache_entries,
        }

    # kept name from PR 1; same counters, cache_stats() is the full view
    cache_info = cache_stats

    def _cache_put(self, key: tuple, fn: Callable) -> None:
        """Insert a jitted engine, LRU-evicting past ``max_cache_entries``."""
        self._engines[key] = fn
        if self.max_cache_entries:
            while len(self._engines) > self.max_cache_entries:
                self._engines.popitem(last=False)
                self.evictions += 1

    def _cache_get(self, key: tuple) -> Optional[Callable]:
        fn = self._engines.get(key)
        if fn is not None:
            self._engines.move_to_end(key)
            self.cache_hits += 1
        return fn

    def _engine_fn(self, cfg: EngineConfig, kind: str, pack: int, query: Query) -> Callable:
        key = (cfg, kind, pack, eng.mesh_signature(self.mesh)) + query.bucket
        if eng.resolve_step_backend_for_plan(cfg, query.plan) == "csr":
            # csr plan arrays carry density-dependent shapes (deg_cap, nnz);
            # without them in the key, a same-bucket different-density query
            # would count as a cache hit while jit silently retraces
            key = key + extend.csr_shape_bucket(query.plan)
        fn = self._cache_get(key)
        if fn is not None:
            return fn
        self.compiles += 1
        if kind == "single":
            if self.mesh is not None:
                fn = eng.make_sharded_engine_fn(
                    cfg, self.mesh, n_t=query.plan.n_t,
                    csr_only=eng.is_csr_only(query.plan),
                )
            else:
                fn = jax.jit(functools.partial(eng._engine_loop, cfg))
        else:
            fn = jax.jit(jax.vmap(functools.partial(eng._engine_loop, cfg)))
        self._cache_put(key, fn)
        return fn

    # -- preparation -------------------------------------------------------

    def prepare(
        self,
        pattern: Graph,
        variant: Optional[str] = None,
        name: Optional[str] = None,
        index: Union[SubgraphIndex, Graph, PackedGraph, None] = None,
    ) -> Query:
        """Compile a pattern into a bucketed :class:`Query` for this session."""
        idx = index if index is not None else self.index
        if idx is None:
            raise ValueError(
                "Enumerator has no default SubgraphIndex; pass index= to "
                "prepare() or construct Enumerator(index, ...)"
            )
        return prepare_query(pattern, idx, variant=variant or self.variant, name=name)

    def prepare_batch(
        self,
        patterns: Sequence[Graph],
        variant: Optional[str] = None,
        names: Optional[Sequence[str]] = None,
        index: Union[SubgraphIndex, Graph, PackedGraph, None] = None,
        backend: Optional[str] = None,
    ) -> List[Query]:
        """Prepare a batch of patterns with **device-resident** domain
        preprocessing (DESIGN.md §5): patterns are grouped by domain shape
        bucket ``(p_pad, arc_pad, loop_pad)``, each group's AC ⇄ FC fixpoint
        runs as **one vmapped jitted call** (padded to a power-of-two lane
        count), and the jitted fixpoints are keyed into this session's
        compile cache alongside the engines.  Results are bit-identical to
        per-query :meth:`prepare` (the numpy oracle) — only the wall-clock
        changes.  ``backend='numpy'`` (or ``Enumerator(domain_backend=
        'numpy')``) falls back to per-query host preprocessing.
        """
        idx = index if index is not None else self.index
        if idx is None:
            raise ValueError(
                "Enumerator has no default SubgraphIndex; pass index= to "
                "prepare_batch() or construct Enumerator(index, ...)"
            )
        idx = SubgraphIndex.build(idx)
        variant = variant or self.variant
        patterns = list(patterns)
        if names is not None and len(names) != len(patterns):
            raise ValueError(
                f"names has {len(names)} entries for {len(patterns)} patterns"
            )
        name_of = lambda i, p: (
            names[i] if names is not None else _default_name(p)
        )
        backend = backend or self.domain_backend
        if backend == "numpy":
            return [
                self.prepare(p, variant=variant, name=name_of(i, p), index=idx)
                for i, p in enumerate(patterns)
            ]

        flags = variant_flags(variant)
        groups: Dict[tuple, List[int]] = {}
        for i, p in enumerate(patterns):
            n_p, n_a, n_l = dom_mod.domain_bucket(p)
            key = (snap_p_pad(n_p), snap_arc_pad(n_a), snap_loop_pad(n_l))
            groups.setdefault(key, []).append(i)

        out: List[Optional[Query]] = [None] * len(patterns)
        tgt_arrays = self._target_domain_arrays(idx)
        for (p_pad, a_pad, l_pad), idxs in groups.items():
            b_pad = snap_batch_pad(len(idxs))
            fn = self._domain_fn(flags, b_pad, p_pad, a_pad, l_pad, idx)
            t0 = time.perf_counter()
            doms = dom_mod.compute_domains_batch(
                [patterns[i] for i in idxs],
                idx.packed,
                use_ac=flags["use_ac"],
                use_fc=flags["use_fc"],
                interleave=flags["interleave"],
                use_pallas=self.config.use_pallas,
                p_pad=p_pad,
                arc_pad=a_pad,
                loop_pad=l_pad,
                batch_pad=b_pad,
                tgt_arrays=tgt_arrays,
                fn=fn,
            )
            dom_s = (time.perf_counter() - t0) / max(len(idxs), 1)
            for i, dres in zip(idxs, doms):
                t1 = time.perf_counter()
                plan = build_plan(
                    patterns[i],
                    idx.packed,
                    variant=variant,
                    p_pad=snap_p_pad(patterns[i].n),
                    max_parents=DEFAULT_MAX_PARENTS,
                    domains=dres,
                )
                out[i] = Query(
                    pattern=patterns[i],
                    plan=plan,
                    variant=variant,
                    name=name_of(i, patterns[i]),
                    prepare_s=dom_s + (time.perf_counter() - t1),
                )
        assert all(q is not None for q in out)
        return out  # type: ignore[return-value]

    # targets whose device-resident domain arrays stay cached; adjacency
    # bitmaps dominate the footprint, so keep only a few (FIFO-evicted).
    _DOM_TARGET_CACHE = 4

    def _target_domain_arrays(self, index: SubgraphIndex) -> dom_mod.TargetDomainArrays:
        """Device-resident target arrays for domain preprocessing, built
        once per index and cached (bounded) on the session.  The cache
        entry pins the PackedGraph so its id() cannot be recycled."""
        key = id(index.packed)
        hit = self._dom_targets.get(key)
        if hit is not None:
            return hit[1]
        arrays = dom_mod.target_domain_arrays(index.packed)
        while len(self._dom_targets) >= self._DOM_TARGET_CACHE:
            self._dom_targets.pop(next(iter(self._dom_targets)))
        self._dom_targets[key] = (index.packed, arrays)
        return arrays

    def _domain_fn(
        self, flags: Dict[str, bool], b_pad: int, p_pad: int, a_pad: int,
        l_pad: int, index: SubgraphIndex,
    ) -> Callable:
        """The jitted batched domain fixpoint for one shape bucket, keyed
        into the session compile cache (kind='domains')."""
        pallas_mode = "per-arc" if self.config.use_pallas else "off"
        key = (
            "domains", flags["use_ac"], flags["use_fc"], flags["interleave"],
            pallas_mode, b_pad, p_pad, a_pad, l_pad,
            index.n, index.w, index.n_edge_labels,
        )
        fn = self._cache_get(key)
        if fn is not None:
            return fn
        self.compiles += 1
        fn = dom_mod.device_fixpoint(
            use_ac=flags["use_ac"], use_fc=flags["use_fc"],
            interleave=flags["interleave"], pallas_mode=pallas_mode,
            batched=True,
        )
        self._cache_put(key, fn)
        return fn

    def _coerce(self, q: Union[Query, Graph]) -> Query:
        return q if isinstance(q, Query) else self.prepare(q)

    def _coerce_all(self, queries: Iterable[Union[Query, Graph]]) -> List[Query]:
        """Coerce a mixed Query/Graph sequence; raw patterns go through the
        batched device preprocessing path in one sweep."""
        qs = list(queries)
        todo = [i for i, q in enumerate(qs) if not isinstance(q, Query)]
        if todo:
            prepared = self.prepare_batch([qs[i] for i in todo])
            for i, q in zip(todo, prepared):
                qs[i] = q
        return qs  # type: ignore[return-value]

    # -- execution: single -------------------------------------------------

    def run(self, query: Union[Query, Graph], collect_matches: int = 0) -> MatchSet:
        """Run one prepared query through the (cached) engine.

        A run whose stack high-watermark breached its ring capacity has
        *undercounted* (full workers freeze instead of expanding), so an
        ``overflow`` result is never returned silently: the query is
        retried once with a doubled ``stack_cap`` (with a warning;
        ``MatchSet.retries`` records it).  If the doubled cap still
        overflows, a ``RuntimeError`` asks for an explicit budget.
        """
        query = self._coerce(query)
        if not query.plan.satisfiable:
            return self._matchset(query, -1, _empty_engine_result(), 0.0)
        cfg = self.config
        if collect_matches:
            cfg = dataclasses.replace(cfg, collect_matches=collect_matches)
        t0 = time.perf_counter()
        res = self._run_single(cfg, query)
        retries = 0
        if res.overflow:
            res = self._retry_overflowed(cfg, query)
            retries = 1
        match_s = time.perf_counter() - t0
        return self._matchset(query, -1, res, match_s, retries=retries)

    def _run_single(self, cfg: EngineConfig, query: Query) -> EngineResult:
        """One engine invocation through the compile cache (no retry).

        Plan arrays follow the resolved step backend: dense
        :class:`~repro.core.extend.PlanArrays`, or
        :class:`~repro.core.extend.CsrPlanArrays` for ``step_backend="csr"``
        — including ``"auto"``, which flips to the sparse layout past
        ``extend.CSR_AUTO_NT`` target nodes (the cache key carries both the
        cfg and ``n_t``, so the resolution is stable per entry)."""
        fn = self._engine_fn(cfg, "single", 1, query)
        arrays = eng.plan_arrays_for(cfg, query.plan)
        state = eng.init_state(query.plan, cfg)
        final = jax.block_until_ready(fn(arrays, state))
        return eng.result_from_state(final, cfg)

    def _retry_overflowed(self, cfg: EngineConfig, query: Query) -> EngineResult:
        """``cfg``'s run of ``query`` overflowed (undercounted): warn and
        re-run once with a doubled ``stack_cap``; raise if even that
        overflows.  Shared by run() and the pack path."""
        cap = cfg.resolved_stack_cap(query.plan.p_pad)
        warnings.warn(
            f"query {query.name!r} overflowed its worker stacks "
            f"(stack_cap={cap}); retrying once with stack_cap={2 * cap} — "
            "set EngineConfig.stack_cap to avoid the duplicated work",
            RuntimeWarning,
            stacklevel=3,
        )
        res = self._run_single(
            dataclasses.replace(cfg, stack_cap=2 * cap), query
        )
        if res.overflow:
            raise RuntimeError(
                f"engine stack overflow persists at stack_cap={2 * cap} "
                f"for query {query.name!r} — set an explicit "
                "EngineConfig.stack_cap budget"
            )
        return res

    # -- execution: batch / stream ----------------------------------------

    def coalesce_key(self, query: Query, cfg: Optional[EngineConfig] = None) -> tuple:
        """The pack-compatibility key of a query: queries with equal keys
        can stack lane-for-lane into one vmapped pack (same jitted engine,
        same array shapes).  ``stream``/``run_batch`` group by it, and the
        serving layer's continuous coalescer (`repro.serve`) buckets
        pending queries by exactly this key, so concurrent heterogeneous
        load rides the compile cache at one compilation per key.

        The key is the shape bucket ``(p_pad, max_parents, n_t, w,
        n_elab)``; under the csr backend it also carries the plan's padded
        ``(deg_cap, nnz)`` — two same-bucket targets of different density
        have differently shaped :class:`~repro.core.extend.CsrPlanArrays`
        and cannot share a pack lane.
        """
        cfg = cfg or self.config
        key = query.bucket
        if eng.resolve_step_backend_for_plan(cfg, query.plan) == "csr":
            key = key + extend.csr_shape_bucket(query.plan)
        return key

    def run_pack(
        self,
        queries: Sequence[Union[Query, Graph]],
        pack_size: Optional[int] = None,
        cfg: Optional[EngineConfig] = None,
    ) -> List[MatchSet]:
        """Batch-submission hook for the serving layer: execute queries
        that share one :meth:`coalesce_key` as padded vmapped packs of
        ``pack_size`` lanes, returning one :class:`MatchSet` per query in
        input order (``query_index`` is the input position).

        Unlike :meth:`run_batch` this does **no** grouping or LPT
        balancing — the caller (the `repro.serve` coalescer) has already
        decided the pack; mixed keys raise.  Unsatisfiable queries get
        empty results without touching the engine.  ``cfg`` overrides the
        session config (the service uses it to thread per-request
        ``collect_matches`` budgets); overflowed lanes go through the
        usual doubled-``stack_cap`` single retry.  Under a mesh, queries
        route singly through the sharded engine (pack-vmap over
        ``shard_map`` is an open ROADMAP item).
        """
        cfg = cfg or self.config
        qs = self._coerce_all(queries)
        pack_size = pack_size or max(len(qs), 1)
        out: List[Optional[MatchSet]] = [None] * len(qs)
        live: List[int] = []
        for i, q in enumerate(qs):
            if q.plan.satisfiable:
                live.append(i)
            else:
                out[i] = self._matchset(q, i, _empty_engine_result(), 0.0)
        if live:
            keys = {self.coalesce_key(qs[i], cfg) for i in live}
            if len(keys) > 1:
                raise ValueError(
                    f"run_pack requires one coalesce_key per pack, got {len(keys)}: "
                    f"{sorted(keys)}"
                )
            if self.mesh is not None:
                for i in live:
                    ms = self.run(qs[i], collect_matches=cfg.collect_matches)
                    ms.query_index = i
                    out[i] = ms
            else:
                for j in range(0, len(live), pack_size):
                    for ms in self._run_pack(live[j:j + pack_size], qs, cfg, pack_size):
                        out[ms.query_index] = ms
        assert all(m is not None for m in out), "run_pack dropped a query"
        return out  # type: ignore[return-value]

    def stream(
        self,
        queries: Iterable[Union[Query, Graph]],
        pack_size: int = 4,
    ) -> Iterator[MatchSet]:
        """Yield one :class:`MatchSet` per query as vmapped packs drain.

        Queries are grouped by shape bucket, LPT-balanced into packs of
        ``pack_size`` (padded with inert lanes so every pack shares one
        compilation), and executed pack by pack; each completed pack yields
        its per-query results immediately.  ``MatchSet.query_index`` carries
        the position in the input sequence.
        """
        qs: List[Query] = self._coerce_all(queries)
        cfg = self.config

        if self.mesh is not None:
            # The pack vmap does not compose with shard_map engines yet:
            # under a mesh each query runs through the (cached) sharded
            # single-query engine, yielding in input order.
            for i, q in enumerate(qs):
                if not q.plan.satisfiable:
                    yield self._matchset(q, i, _empty_engine_result(), 0.0)
                else:
                    ms = self.run(q)
                    ms.query_index = i
                    yield ms
            return

        groups: Dict[tuple, List[int]] = {}
        for i, q in enumerate(qs):
            if not q.plan.satisfiable:
                yield self._matchset(q, i, _empty_engine_result(), 0.0)
            else:
                groups.setdefault(self.coalesce_key(q, cfg), []).append(i)

        for idxs in groups.values():
            weights = [_predict_work(qs[i].plan) for i in idxs]
            n_packs = max(1, (len(idxs) + pack_size - 1) // pack_size)
            assignment = balance_assignment(weights, n_packs)
            for pack_id in range(n_packs):
                members = [i for i, a in zip(idxs, assignment) if a == pack_id]
                # LPT balances weight, not count: an overloaded pack is split
                # into pack_size chunks so every engine call has the same lane
                # width (one compilation per bucket, counters stay honest).
                for j in range(0, len(members), pack_size):
                    yield from self._run_pack(members[j:j + pack_size], qs, cfg, pack_size)

    def run_batch(
        self,
        queries: Sequence[Union[Query, Graph]],
        pack_size: int = 4,
    ) -> List[MatchSet]:
        """Run a batch of queries; exactly one result per query, in order."""
        queries = list(queries)
        out: List[Optional[MatchSet]] = [None] * len(queries)
        for ms in self.stream(queries, pack_size=pack_size):
            out[ms.query_index] = ms
        assert all(r is not None for r in out), "stream dropped a query"
        return out  # type: ignore[return-value]

    def _run_pack(
        self, members: List[int], qs: List[Query], cfg: EngineConfig, pack_size: int
    ) -> Iterator[MatchSet]:
        """Execute one padded pack of same-bucket queries, yielding results."""
        t0 = time.perf_counter()
        plans = [qs[i].plan for i in members]
        fn = self._engine_fn(cfg, "batch", pack_size, qs[members[0]])
        arrays = [eng.plan_arrays_for(cfg, p) for p in plans]
        states = [eng.init_state(p, cfg) for p in plans]
        # pad inert lanes so every pack of this bucket shares one compilation
        # (size==0 lanes freeze immediately under the vmapped while_loop)
        while len(arrays) < pack_size:
            arrays.append(arrays[0])
            states.append(_inert_state(states[0]))
        stacked_plan = jax.tree.map(lambda *xs: jnp.stack(xs), *arrays)
        stacked_state = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        final = jax.block_until_ready(fn(stacked_plan, stacked_state))
        match_s = (time.perf_counter() - t0) / max(len(members), 1)
        for row, i in enumerate(members):
            lane = jax.tree.map(lambda x, r=row: x[r], final)
            res = eng.result_from_state(lane, cfg)
            if res.overflow:
                # the pack undercounted this lane; go straight to the
                # doubled-stack_cap single retry (re-running at the original
                # cap would deterministically overflow again)
                res = self._retry_overflowed(cfg, qs[i])
                yield self._matchset(qs[i], i, res, match_s, retries=1)
                continue
            yield self._matchset(qs[i], i, res, match_s)

    # -- result assembly ---------------------------------------------------

    def _matchset(
        self, query: Query, idx: int, res: EngineResult, match_s: float,
        retries: int = 0,
    ) -> MatchSet:
        materialize = None
        if res.match_buf is None and query.plan.satisfiable:
            def materialize(q: Query = query, m: int = res.matches):
                # round the buffer up to a power of two so re-materializations
                # of different queries share a handful of engine configs
                cap = min(1 << max(m - 1, 1).bit_length(), _MATERIALIZE_CAP)
                return self.run(q, collect_matches=cap).engine.match_buf

        return MatchSet(
            name=query.name,
            query_index=idx,
            matches=res.matches,
            states=res.states,
            steps=res.steps,
            steals=res.steals,
            steal_rounds=res.steal_rounds,
            mean_steal_depth=res.mean_steal_depth,
            mean_expand_depth=res.mean_expand_depth,
            per_worker_states=res.per_worker_states,
            per_worker_matches=res.per_worker_matches,
            per_worker_steals=res.per_worker_steals,
            preprocess_s=query.prepare_s,
            match_s=match_s,
            plan=query.plan,
            engine=res,
            retries=retries,
            _match_buf=res.match_buf,
            _materialize=materialize,
        )


def _coerce_mesh(mesh) -> Optional["jax.sharding.Mesh"]:
    """Accept a ``jax.sharding.Mesh``, an int device count (first ``n``
    local devices on a 1-D ``data`` axis), or ``None``."""
    if mesh is None or isinstance(mesh, jax.sharding.Mesh):
        return mesh
    if isinstance(mesh, int):
        devs = jax.local_devices()
        if mesh > len(devs):
            raise ValueError(
                f"mesh={mesh} devices requested but only {len(devs)} local "
                "devices exist (on CPU set XLA_FLAGS="
                "--xla_force_host_platform_device_count=N before importing jax)"
            )
        return jax.make_mesh((mesh,), ("data",), devices=devs[:mesh])
    raise TypeError(f"mesh must be a Mesh, int, or None, got {type(mesh)!r}")


# Process-wide sessions for the compatibility wrappers and benchmark
# harness: one Enumerator (and thus one engine-compile cache) per config.
_SHARED: Dict[EngineConfig, Enumerator] = {}


def shared_enumerator(cfg: EngineConfig) -> Enumerator:
    """The process-wide session for ``cfg`` (created on first use)."""
    s = _SHARED.get(cfg)
    if s is None:
        s = _SHARED[cfg] = Enumerator(config=cfg)
    return s


def _predict_work(plan: SearchPlan) -> float:
    """Cheap work proxy: product of the first few domain sizes (the former
    ``core/multi.py`` heuristic feeding LPT pack balancing)."""
    sizes = popcount(plan.dom_bits[: min(plan.n_p, 4)])
    return float(np.prod(np.maximum(sizes, 1), dtype=np.float64))


def _inert_state(template: eng.EngineState) -> eng.EngineState:
    """A copy of ``template`` with no work: size 0, empty candidate bitmaps.

    Used to pad packs to a fixed lane count; the vmapped while_loop freezes
    these lanes immediately, so they cost nothing but shape stability."""
    return template._replace(
        size=jnp.zeros_like(template.size),
        st_cand=jnp.zeros_like(template.st_cand),
    )
