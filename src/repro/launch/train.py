"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch <id> [--steps N]
      [--smoke] [--ckpt-dir DIR] [--accum K]

``--smoke`` uses the architecture's reduced config and synthetic data — this
is what CI runs.  Full configs require the production mesh (see
launch/dryrun.py for topology validation); on this CPU container full-size
training is intentionally refused rather than silently attempted.

The ~100M-parameter end-to-end example lives in ``examples/train_lm_100m.py``
and uses this module's machinery.
"""

from __future__ import annotations

import argparse
import functools
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data import graphgen
from repro.models import transformer as tf
from repro.train import optimizer as opt_mod
from repro.train.trainer import LoopConfig, TrainLoop, make_train_step


def lm_data_iterator(cfg: tf.LMConfig, batch: int, seq: int, seed: int = 0,
                     noise: float = 0.1):
    """Synthetic LM batches: per-sequence affine progressions with
    ``noise``-fraction corruption — structured enough that next-token loss
    demonstrably falls, noisy enough to be non-trivial."""
    rng = np.random.default_rng(seed)
    v = cfg.vocab_size
    while True:
        stride = rng.integers(1, 7, size=(batch, 1))
        phase = rng.integers(0, v, size=(batch, 1))
        t = np.arange(seq + 1)[None, :]
        toks = (phase + stride * t) % v
        flip = rng.random((batch, seq + 1)) < noise
        toks = np.where(flip, rng.integers(0, v, toks.shape), toks)
        toks = toks.astype(np.int32)
        yield {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }


def train_lm(
    cfg: tf.LMConfig,
    steps: int = 50,
    batch: int = 4,
    seq: int = 64,
    ckpt_dir=None,
    accum: int = 1,
    lr: float = 3e-4,
    log=print,
):
    opt_cfg = opt_mod.AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                                  total_steps=steps)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_mod.init(params)
    loss_fn = functools.partial(lambda c, p, b: tf.loss_fn(p, c, b), cfg)
    step_fn = jax.jit(make_train_step(loss_fn, opt_cfg, accum_steps=accum))
    loop = TrainLoop(step_fn, LoopConfig(total_steps=steps, checkpoint_every=max(steps // 2, 1),
                                         log_every=max(steps // 10, 1)),
                     ckpt_dir=ckpt_dir, log=log)
    data = lm_data_iterator(cfg, batch * accum if accum > 1 else batch, seq)
    if accum > 1:
        base = data

        def reshaped():
            for b in base:
                yield jax.tree.map(lambda x: x.reshape(accum, batch, *x.shape[1:]), b)

        data = reshaped()
    params, opt_state, history = loop.run(params, opt_state, data)
    return params, opt_state, history


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="config override, e.g. --set n_layers=4 --set moe.top_k=2")
    args = ap.parse_args()

    arch = registry.get(args.arch)
    if arch.family == "lm":
        import importlib

        from repro.configs import overrides as ov

        mod = importlib.import_module(f"repro.configs.{args.arch.replace('-', '_')}")
        cfg = mod.SMOKE if args.smoke else mod.CFG
        cfg = ov.apply(cfg, args.overrides)
        _, _, history = train_lm(cfg, steps=args.steps, ckpt_dir=args.ckpt_dir,
                                 accum=args.accum)
        improved = history[-1] < history[0]
        print(f"[train] {args.arch}: loss {history[0]:.3f} -> {history[-1]:.3f} "
              f"({'improved' if improved else 'NOT improved'})")
        return 0
    # non-LM archs: run the smoke (a full train step on synthetic data)
    out = arch.smoke()
    print(f"[train] {args.arch} smoke: {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
