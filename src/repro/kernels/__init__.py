"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel ships three layers:
  <name>.py  — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling
  ops.py     — jit'd public wrappers (interpret-mode auto-detect)
  ref.py     — pure-jnp oracles; tests sweep shapes/dtypes and assert
               equality (bitwise kernels: exact; flash attention: rtol)

Kernels:
  candidate_mask   — the paper's hot loop: per-lane candidate bitmaps via
                     scalar-prefetch-indexed adjacency-row DMA + wide AND
  domain_ac        — RI-DS arc-consistency row filter (SDDMM-shaped)
  popcount_reduce  — per-row popcounts (domain sizes, match stats)
  flash_attention  — fused causal online-softmax attention (beyond-paper;
                     the pure-JAX blockwise form stays the default so XLA
                     cost analysis sees the FLOPs for §Roofline)
"""
