"""RI static node ordering (GreatestConstraintFirst) with the paper's
domain-size tie-breaking (RI-DS-SI).

RI orders the pattern nodes *before* the search so that each node visited is
maximally constrained by already-ordered nodes.  The greedy criteria, applied
lexicographically when selecting the next node ``u`` among the unordered:

  1. ``w_m(u)`` — number of ``u``'s neighbors already in the ordering
     (the paper's "number of neighbors in the partial ordering").
  2. ``w_n(u)`` — number of ``u``'s unordered neighbors that are themselves
     neighbors of ordered nodes ("nodes in the ordering reachable via nodes
     not in the ordering").
  3. ``deg(u)`` — total degree.
  4. **SI tie-break (this paper, §4.2.1)**: smaller domain first.  This is the
     constraint-first principle continued: among otherwise identical nodes,
     the one with fewer candidate target nodes is more constrained.

The first node is the one with maximum degree (domain-size tie-broken under
SI).  Neighborhoods are undirected unions of in- and out-neighbors, matching
the RI reference implementation.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, TYPE_CHECKING

import numpy as np

from repro.core.graph import Graph, deg_bucket_caps, deg_bucket_index

if TYPE_CHECKING:
    from repro.core.graph import CsrPlanes


@dataclasses.dataclass(frozen=True)
class Ordering:
    """A static search order over pattern nodes.

    Attributes:
      order: ``[n_p]`` pattern node ids, ``order[i]`` is searched at depth i.
      parents: per position ``i``, a list of ``(pos_j, direction, edge_label)``
        triples — one per pattern edge between ``order[i]`` and an
        earlier-ordered node ``order[pos_j]``.  ``direction == 0`` means the
        pattern edge is ``(order[pos_j] -> order[i])`` (check the target
        out-row of the mapped parent), ``1`` means ``(order[i] ->
        order[pos_j])`` (check the target in-row).
    """

    order: np.ndarray
    parents: Tuple[Tuple[Tuple[int, int, int], ...], ...]

    @property
    def n(self) -> int:
        return int(self.order.shape[0])

    @property
    def max_parents(self) -> int:
        return max((len(p) for p in self.parents), default=0)

    def parent_arrays(self, max_parents: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Dense ``(parent_pos, parent_dir, parent_elab, n_parents)`` arrays,
        padded with ``parent_pos == -1``."""
        mp = max(1, max_parents or self.max_parents, self.max_parents)
        n = self.n
        pos = np.full((n, mp), -1, dtype=np.int32)
        dr = np.zeros((n, mp), dtype=np.int32)
        el = np.zeros((n, mp), dtype=np.int32)
        cnt = np.zeros((n,), dtype=np.int32)
        for i, plist in enumerate(self.parents):
            cnt[i] = len(plist)
            for j, (p, d, l) in enumerate(plist):
                pos[i, j], dr[i, j], el[i, j] = p, d, l
        return pos, dr, el, cnt


def _neighbor_sets(g: Graph) -> List[set]:
    nbr = [set() for _ in range(g.n)]
    for u, v in zip(g.src.tolist(), g.dst.tolist()):
        if u != v:
            nbr[u].add(v)
            nbr[v].add(u)
    return nbr


def greatest_constraint_first(
    pattern: Graph,
    domain_sizes: Optional[np.ndarray] = None,
    singleton_first: bool = False,
    seed_order: Optional[Tuple[int, ...]] = None,
) -> Ordering:
    """Compute the RI (GreatestConstraintFirst) ordering.

    Args:
      pattern: the pattern graph.
      domain_sizes: optional ``[n_p]`` candidate-set sizes.  When given, ties
        on ``(w_m, w_n, deg)`` are broken in favor of the smaller domain
        (RI-DS-SI, paper §4.2.1).
      singleton_first: RI-DS places all pattern nodes with singleton domains
        at the *beginning* of the ordering (paper §4.1).  Requires
        ``domain_sizes``.
      seed_order: optional forced prefix of pattern node ids placed at the
        front of the ordering verbatim (duplicates collapsed).  Used by the
        delta-seeding path (DESIGN.md §8) to anchor a pattern edge's
        endpoints at positions 0/1; overrides ``singleton_first``'s
        pre-placement, the greedy criteria still order the rest.

    Returns:
      An :class:`Ordering` with per-position parent constraint lists.
    """
    n = pattern.n
    deg = pattern.degrees()
    nbr = _neighbor_sets(pattern)
    ds = None
    if domain_sizes is not None:
        ds = np.asarray(domain_sizes, dtype=np.int64)
        assert ds.shape == (n,)

    in_order = np.zeros(n, dtype=bool)
    order: List[int] = []

    def key(u: int) -> Tuple:
        w_m = sum(1 for v in nbr[u] if in_order[v])
        w_n = sum(
            1
            for v in nbr[u]
            if not in_order[v] and any(in_order[x] for x in nbr[v])
        )
        k = (w_m, w_n, int(deg[u]))
        if ds is not None:
            # smaller domain preferred => negate for max-selection
            k = k + (-int(ds[u]),)
        # deterministic final tie-break on node id (smaller id first)
        return k + (-u,)

    # Delta seeding: anchor endpoints are forced to the front.
    if seed_order is not None:
        for u in seed_order:
            u = int(u)
            if not in_order[u]:
                order.append(u)
                in_order[u] = True
    # RI-DS: singleton domains first (their assignment is forced).
    elif singleton_first and ds is not None:
        for u in np.nonzero(ds == 1)[0].tolist():
            order.append(int(u))
            in_order[u] = True

    # first non-singleton node: max degree (SI: domain tie-break applies too)
    while len(order) < n:
        best, best_key = None, None
        for u in range(n):
            if in_order[u]:
                continue
            k = key(u)
            if best_key is None or k > best_key:
                best, best_key = u, k
        order.append(int(best))
        in_order[best] = True

    # Build per-position parent constraints from pattern edges.
    pos_of = {u: i for i, u in enumerate(order)}
    parents: List[List[Tuple[int, int, int]]] = [[] for _ in range(n)]
    for u, v, l in zip(pattern.src.tolist(), pattern.dst.tolist(), pattern.edge_labels.tolist()):
        iu, iv = pos_of[u], pos_of[v]
        if iu < iv:
            # edge (u -> v), u ordered earlier: at position iv, parent iu, out-dir
            parents[iv].append((iu, 0, int(l)))
        elif iv < iu:
            # edge (u -> v), v ordered earlier: at position iu, parent iv, in-dir
            parents[iu].append((iv, 1, int(l)))
        # self loops (iu == iv) cannot be parent constraints (one position);
        # they are enforced as unary domain constraints in
        # repro.core.domains.initial_domains (DESIGN.md §5).
    return Ordering(order=np.asarray(order, dtype=np.int32), parents=tuple(tuple(p) for p in parents))


# ---------------------------------------------------------------------------
# edge-centric seed selection (HiPerMotif-style, DESIGN.md §10)
# ---------------------------------------------------------------------------

def edge_class_stats(planes: "CsrPlanes") -> np.ndarray:
    """Target arc counts per ``(edge_label, src-deg-bucket, dst-deg-bucket)``
    class — ``[n_elab, B, B]`` int64, ``B`` the pow2 degree-bucket ladder
    (`repro.core.graph.deg_bucket_caps`) of the planes' ``deg_cap``.

    Each out-plane arc ``(s, t)`` with label ``l`` is counted once, at
    ``(l, bucket(outdeg_l(s)), bucket(indeg_l(t)))`` — the class frequency
    table :func:`select_seed_edge` ranks pattern edges by.  O(nnz) host
    work over already-built :class:`~repro.core.graph.CsrPlanes`.
    """
    caps = deg_bucket_caps(max(planes.deg_cap, 1))
    b = len(caps)
    nl = planes.n_edge_labels
    hist = np.zeros((nl, b, b), dtype=np.int64)
    ptr = planes.indptr.astype(np.int64)
    for l in range(nl):
        out_len = np.diff(ptr[2 * l])  # [n_t] per-source outdeg_l
        in_len = np.diff(ptr[2 * l + 1])  # [n_t] per-dest indeg_l
        s, e = int(ptr[2 * l, 0]), int(ptr[2 * l, -1])
        cols = planes.indices[s:e]  # arc destinations, row-major
        if cols.size == 0:
            continue
        sb = deg_bucket_index(np.repeat(out_len, out_len), caps)
        db = deg_bucket_index(in_len[cols], caps)
        np.add.at(hist, (l, sb, db), 1)
    return hist


def select_seed_edge(
    pattern: Graph, planes: "CsrPlanes"
) -> Optional[Tuple[int, int, int]]:
    """Rarest-edge-class seed selection (HiPerMotif, DESIGN.md §10).

    Ranks every non-self-loop pattern edge ``(u, v, l)`` by how many target
    arcs could host it: the sum of :func:`edge_class_stats` classes with
    matching label and src/dst degree buckets **at least** the pattern
    endpoints' per-label degrees (an arc in a smaller bucket can never
    satisfy the endpoint's adjacency requirements).  Returns the edge with
    the fewest compatible arcs — the root frontier edge seeding enumerates
    — with deterministic ``(count, l, u, v)`` tie-breaking, or ``None``
    when the pattern has no usable edge (empty or all self-loops).
    """
    if pattern.m == 0:
        return None
    hist = edge_class_stats(planes)
    caps = deg_bucket_caps(max(planes.deg_cap, 1))
    nl_t = hist.shape[0]
    src = pattern.src
    dst = pattern.dst
    elab = pattern.edge_labels
    best = None
    seen = set()
    for u, v, l in zip(src.tolist(), dst.tolist(), elab.tolist()):
        if u == v or (l, u, v) in seen:
            continue
        seen.add((l, u, v))
        if l >= nl_t:
            count = 0  # label absent from the target: trivially rarest
        else:
            po = int(np.sum((src == u) & (elab == l)))
            pi = int(np.sum((dst == v) & (elab == l)))
            sb = int(deg_bucket_index(np.asarray([po]), caps)[0])
            db = int(deg_bucket_index(np.asarray([pi]), caps)[0])
            count = int(hist[l, sb:, db:].sum())
        k = (count, l, u, v)
        if best is None or k < best:
            best = k
    if best is None:
        return None
    return (best[2], best[3], best[1])
