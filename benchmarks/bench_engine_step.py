"""Engine-step backend benchmark: loose-ops jnp step vs an alternate
``step_backend`` — the fused Pallas kernel or the sparse CSR walk
(DESIGN.md §6 / §6.4).

  PYTHONPATH=src python benchmarks/bench_engine_step.py [--smoke]
      [--step-backend pallas|csr]

Two sections:

1. **Corpus sweep** — a ppis32-like collection through a ≥ 32-worker
   session twice, once per backend:

   * **bit-identity** (always asserted): matches, states, steps, and
     steals agree query-for-query between ``jnp`` and the alternate
     backend.  Off TPU the fused ``pallas`` kernel runs in *interpret
     mode* (Python kernel body — ~10-100× slower than jnp; see API.md),
     so its identity sweep covers the smallest-states slice of the corpus
     there and the full corpus on TPU; the ``csr`` backend's jnp-math
     walk is fast everywhere and always sweeps the full corpus.
   * **speedup** (asserted in compiled mode only): ``pallas`` must beat
     loose ops by ≥ 1.5× wall-clock.  Interpret mode is exempt by
     construction — it validates semantics, not speed — so on CPU the
     ratio is only reported.

2. **Sparse-target demo** (the csr headline: runs under ``--step-backend
   csr``, in both interpret and compiled modes) — a
   power-law target at pdbsv1 scale (``n_t = 33,067``) is enumerated
   through a **CSR-only plan**: the dense ``[n_elab, 2, n_t, w]``
   adjacency bitmaps are *never materialized*.  Asserted always: the CSR
   structure is ≥ 50× smaller than the dense working set the jnp backend
   would need (reported byte-for-byte, the dense side computed
   analytically since allocating it is exactly what this backend avoids),
   and the engine's counts equal the sequential reference oracle.
   Asserted in compiled mode only: the csr step is not slower than the
   dense jnp step on the same sparse target (interpret exempt).

Emits CSV rows (name, us_per_state, derived) and a JSON artifact.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

try:
    from benchmarks import common
except ImportError:  # executed from an arbitrary cwd
    import repro.bench  # noqa: F401  (puts the repo root on sys.path)
    from benchmarks import common

from repro.core import EngineConfig, Enumerator, SubgraphIndex
from repro.core import engine as eng
from repro.core.plan import build_csr_plan, build_plan
from repro.core.graph import PackedGraph
from repro.core.ref import ref_enumerate
from repro.data import graphgen
from repro.kernels import ops as kops

SPEEDUP_FLOOR = 1.5  # compiled-mode acceptance for pallas (interpret exempt)
# interpret mode: only identity-check pallas queries up to this many
# (jnp-counted) search states, so the Python kernel body finishes in CI time
INTERPRET_STATE_BUDGET = 60_000

SPARSE_NT = 33_067  # sge_pdbsv1 (Table 1) — the paper's largest target
SPARSE_MEM_FACTOR = 50  # csr structure must be >= this much smaller


def _corpus(smoke: bool, scale: float, seed: int):
    if smoke:
        return graphgen.make_collection(
            "ppis32-like", pattern_edges=(8,), patterns_per_target=1,
            scale=min(scale, 0.12), seed=seed,
        )
    return graphgen.make_collection(
        "ppis32-like", pattern_edges=(8, 16, 24), patterns_per_target=2,
        scale=scale, seed=seed,
    )


def _sweep(cfg: EngineConfig, instances, indices, names=None):
    """Run (a subset of) the collection; returns (per-query dict, wall_s).

    The compile pass is excluded from the timing: each query runs once to
    warm the session's shape-bucket cache, then once timed — the amortized
    regime the session API exists for.
    """
    session = Enumerator(config=cfg)
    queries = [
        session.prepare(inst.pattern, name=inst.name, index=indices[id(inst.target)])
        for inst in instances
        if names is None or inst.name in names
    ]
    for q in queries:  # warm-up: compile + first execution
        session.run(q)
    t0 = time.perf_counter()
    out = {}
    for q in queries:
        ms = session.run(q)
        out[q.name] = dict(matches=ms.matches, states=ms.states,
                           steps=ms.steps, steals=ms.steals)
    return out, time.perf_counter() - t0


def run_sparse_target(workers: int, seed: int, interpret: bool) -> dict:
    """The csr headline: enumerate a pdbsv1-scale power-law target through
    a CSR-only plan, with the dense working set never allocated."""
    tgt = graphgen.power_law_graph(
        SPARSE_NT, avg_deg=4.0, alpha=0.5, n_labels=32, seed=seed,
    )
    deg = tgt.out_degrees() + tgt.in_degrees()
    # start extraction at a busy node so the pattern is non-trivial
    pat = graphgen.extract_pattern(
        tgt, 6, seed=seed, start=int(np.argsort(deg)[-80]),
    )
    assert pat.m > 0, "sparse pattern extraction degenerated"
    plan = build_csr_plan(pat, tgt, variant="ri")
    assert plan.adj_bits.shape[2] == 0  # nothing dense was ever built

    # --- memory: byte-for-byte, the dense side analytic ------------------
    csr_bytes = plan.csr.nbytes
    dense_bytes = plan.n_edge_labels * 2 * plan.n_t * plan.w * 4
    mem_ratio = dense_bytes / max(csr_bytes, 1)
    assert mem_ratio >= SPARSE_MEM_FACTOR, (
        f"csr structure ({csr_bytes} B) must be >= {SPARSE_MEM_FACTOR}x "
        f"smaller than the dense adjacency working set ({dense_bytes} B); "
        f"measured {mem_ratio:.0f}x"
    )

    cfg = EngineConfig(n_workers=workers, expand_width=4, step_backend="csr")
    res = eng.run(plan, cfg)  # warm-up/compile
    t0 = time.perf_counter()
    res = eng.run(plan, cfg)
    t_csr = time.perf_counter() - t0

    # --- correctness at scale: the sequential oracle (also CSR-walking) --
    ref = ref_enumerate(pat, tgt, plan=plan)
    assert (res.matches, res.states) == (ref.matches, ref.states), (
        f"csr engine diverged from the sequential oracle on the sparse "
        f"target: engine=({res.matches}, {res.states}) "
        f"ref=({ref.matches}, {ref.states})"
    )

    # --- speed vs the dense jnp step: compiled mode only ------------------
    # (building the 273 MB dense plan is exactly what csr avoids, so the
    # comparison is opt-in to compiled mode where the gate applies)
    t_jnp = None
    sparse_speedup = None
    if not interpret:
        dense_plan = build_plan(pat, PackedGraph.from_graph(tgt), variant="ri")
        cfg_j = dataclasses.replace(cfg, step_backend="jnp")
        rj = eng.run(dense_plan, cfg_j)  # warm-up/compile
        t0 = time.perf_counter()
        rj = eng.run(dense_plan, cfg_j)
        t_jnp = time.perf_counter() - t0
        assert (rj.matches, rj.states) == (res.matches, res.states)
        sparse_speedup = t_jnp / max(t_csr, 1e-9)
        assert sparse_speedup >= 1.0, (
            f"csr step must not lose to the dense step on its home turf "
            f"(sparse n_t={SPARSE_NT}) in compiled mode; measured "
            f"{sparse_speedup:.2f}x ({t_jnp:.3f}s vs {t_csr:.3f}s)"
        )

    print(common.csv_row(
        "engine_step/csr_sparse_33k", t_csr * 1e6 / max(res.states, 1),
        f"n_t={SPARSE_NT};m={tgt.m};matches={res.matches};"
        f"states={res.states};csr_bytes={csr_bytes};"
        f"dense_bytes={dense_bytes};mem_ratio={mem_ratio:.0f}x;"
        f"ref_verified=True",
    ))
    return dict(
        n_t=SPARSE_NT,
        target_edges=int(tgt.m),
        matches=int(res.matches),
        states=int(res.states),
        csr_bytes=int(csr_bytes),
        dense_bytes=int(dense_bytes),
        mem_ratio=mem_ratio,
        csr_wall_s=t_csr,
        jnp_wall_s=t_jnp,
        sparse_speedup=sparse_speedup,
        speedup_asserted=not interpret,
        ref_verified=True,
    )


def run(smoke: bool = False, scale: float = 0.3, workers: int = 32,
        seed: int = 7, step_backend: str = "pallas") -> dict:
    assert workers >= 32, "the acceptance criterion is a >=32-worker run"
    assert step_backend in ("pallas", "csr")
    instances = _corpus(smoke, scale, seed)
    indices: dict = {}
    for inst in instances:
        indices.setdefault(id(inst.target), SubgraphIndex.build(inst.target))

    base = EngineConfig(n_workers=workers, expand_width=4)
    interpret = kops.resolve_interpret(None)

    jnp_res, t_jnp = _sweep(base, instances, indices)
    total_states = sum(r["states"] for r in jnp_res.values())

    # pick the alternate sweep's query set: everything in compiled mode or
    # for the csr backend (jnp-math walk — no interpret penalty), the
    # smallest-states prefix under the budget for interpret-mode pallas
    if interpret and step_backend == "pallas":
        by_states = sorted(jnp_res.items(), key=lambda kv: kv[1]["states"])
        picked, budget = [], INTERPRET_STATE_BUDGET
        for name, r in by_states:
            if r["states"] <= budget or not picked:
                picked.append(name)
                budget -= r["states"]
        names = set(picked)
    else:
        names = None

    alt_cfg = dataclasses.replace(base, step_backend=step_backend)
    alt_res, t_alt = _sweep(alt_cfg, instances, indices, names=names)

    # --- bit-identity: the seam's core contract ---------------------------
    for name, r in alt_res.items():
        assert r == jnp_res[name], (
            f"{name}: {step_backend} step diverged from loose-ops step — "
            f"{step_backend}={r} jnp={jnp_res[name]}"
        )
    checked_states = sum(jnp_res[n]["states"] for n in alt_res)

    # --- speed: compiled mode must win (pallas), interpret just reports ---
    # compare on the same query set the alternate sweep ran
    t_jnp_same = t_jnp
    if names is not None and len(names) < len(jnp_res):
        _, t_jnp_same = _sweep(base, instances, indices, names=names)
    speedup = t_jnp_same / max(t_alt, 1e-9)
    if not interpret and step_backend == "pallas":
        assert speedup >= SPEEDUP_FLOOR, (
            f"fused extend_step must be >= {SPEEDUP_FLOOR}x the loose-ops "
            f"step in compiled mode; measured {speedup:.2f}x "
            f"({t_jnp_same:.3f}s vs {t_alt:.3f}s)"
        )

    # the sparse 33k-target demo is the csr headline; the pallas sweep keeps
    # its pre-existing scope (CI runs both rows, so coverage is unchanged)
    sparse = (
        run_sparse_target(workers, seed, interpret)
        if step_backend == "csr" else None
    )

    mode = "interpret" if interpret else "compiled"
    print(common.csv_row(
        "engine_step/jnp", t_jnp * 1e6 / max(total_states, 1),
        f"queries={len(jnp_res)};states={total_states};wall={t_jnp:.3f}s",
    ))
    print(common.csv_row(
        f"engine_step/{step_backend}_{mode}",
        t_alt * 1e6 / max(checked_states, 1),
        f"queries={len(alt_res)};states={checked_states};wall={t_alt:.3f}s;"
        f"speedup={speedup:.2f}x;identical=True",
    ))
    payload = dict(
        mode=mode,
        workers=workers,
        step_backend=step_backend,
        queries=len(jnp_res),
        alt_queries=len(alt_res),
        total_states=total_states,
        checked_states=checked_states,
        jnp_wall_s=t_jnp,
        jnp_wall_same_set_s=t_jnp_same,
        alt_wall_s=t_alt,
        speedup_same_set=speedup,
        speedup_asserted=not interpret and step_backend == "pallas",
        bit_identical=True,
        sparse=sparse,
    )
    common.save_json("engine_step", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--workers", type=int, default=32)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--step-backend", choices=("pallas", "csr"),
                    default="pallas",
                    help="alternate backend to sweep against jnp")
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus for CI (same assertions)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the JSON payload to PATH")
    args = ap.parse_args()
    out = run(smoke=args.smoke, scale=args.scale, workers=args.workers,
              seed=args.seed, step_backend=args.step_backend)
    common.write_json_path(args.json, out)
    verdict = (
        f"{out['speedup_same_set']:.2f}x (asserted >= {SPEEDUP_FLOOR}x)"
        if out["speedup_asserted"]
        else f"{out['speedup_same_set']:.2f}x (interpret/csr: reported only)"
    )
    print(
        f"\n[{out['mode']}] {out['queries']} queries, {out['workers']} workers: "
        f"loose-ops {out['jnp_wall_s']:.2f}s; {out['step_backend']} step on "
        f"{out['alt_queries']} queries ({out['checked_states']} states) "
        f"bit-identical; alt/loose = {verdict}"
    )
    sp = out["sparse"]
    if sp is not None:
        print(
            f"sparse n_t={sp['n_t']}: csr structure {sp['csr_bytes']/1e6:.1f} MB "
            f"vs dense {sp['dense_bytes']/1e6:.1f} MB ({sp['mem_ratio']:.0f}x), "
            f"{sp['matches']} matches / {sp['states']} states in "
            f"{sp['csr_wall_s']:.2f}s, ref-verified"
        )


if __name__ == "__main__":
    main()
