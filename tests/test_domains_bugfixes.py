"""Deterministic regression tests for the preprocessing bugfixes and the
device-resident domain engine (DESIGN.md §5) — no hypothesis dependency, so
they run even where hypothesis is absent (the property-test versions live in
test_core_domains.py / test_core_oracle.py).

Covers:
  * self-loop constraints enforced end-to-end (they used to be dropped:
    `_pattern_arcs` skips ``u == v`` and parent tables cannot express them);
  * pattern edge labels outside the target's range -> unsatisfiable, never
    IndexError / silently-clamped gathers;
  * the DomainResult invariant: unsatisfiable => all-zero bits;
  * device fixpoint engine == numpy oracle, bit for bit, on a fixed-seed
    corpus (single, batched, and Pallas-interpret paths).
"""

import numpy as np
import pytest

from repro.core import EngineConfig, enumerate_subgraphs
from repro.core import domains as dom_mod
from repro.core.graph import Graph, PackedGraph, bitmap_to_indices, popcount
from repro.core.ref import brute_force_count, ref_enumerate
from tests.conftest import bump_edge_label, extract_connected_pattern, random_graph

# (use_ac, use_fc, interleave) triples covering all pipeline modes incl. the
# AC ⇄ FC joint fixpoint (variant ri-ds-si-acfc)
PIPELINES = [(False, False, False), (True, False, False), (True, True, False),
             (True, True, True)]


# ---------------------------------------------------------------------------
# self-loop enforcement
# ---------------------------------------------------------------------------

def test_selfloop_restricts_initial_domains():
    """A pattern node with a self-loop may only map to target nodes that
    carry a same-label self-loop (previously unenforced end-to-end)."""
    # target: triangle, self-loop only on node 0
    tgt = Graph.from_edges(3, [(0, 1), (1, 2), (2, 0), (0, 0)], undirected=True)
    pat = Graph.from_edges(2, [(0, 1), (0, 0)], undirected=True)
    packed = PackedGraph.from_graph(tgt)
    bits = dom_mod.initial_domains(pat, packed)
    assert bitmap_to_indices(bits[0]).tolist() == [0]  # loop node -> node 0 only
    assert len(bitmap_to_indices(bits[1])) == 3


def test_selfloop_label_must_match():
    tgt = Graph.from_edges(2, [(0, 1), (0, 0)], edge_labels=[0, 1],
                           undirected=True)
    packed = PackedGraph.from_graph(tgt)
    # pattern self-loop with label 0: target's loop has label 1 -> empty
    pat = Graph.from_edges(1, [(0, 0)], edge_labels=[0], undirected=True)
    res = dom_mod.compute_domains(pat, packed, use_ac=False)
    assert not res.satisfiable
    # same label 1 -> node 0
    pat1 = Graph.from_edges(1, [(0, 0)], edge_labels=[1], undirected=True)
    res1 = dom_mod.compute_domains(pat1, packed, use_ac=False)
    assert res1.satisfiable
    assert bitmap_to_indices(res1.bits[0]).tolist() == [0]


def test_selfloop_brute_force_agreement():
    """Self-loop constraints end-to-end (this silently disagreed with brute
    force before the fix: loop edges were dropped by preprocessing).

    Target: a triangle where only node 0 carries a self-loop; pattern: an
    edge whose first endpoint has a self-loop.  Only mappings placing the
    loop node on target node 0 survive.
    """
    tgt = Graph.from_edges(3, [(0, 1), (1, 2), (2, 0), (0, 0)], undirected=True)
    pat = Graph.from_edges(2, [(0, 1), (0, 0)], undirected=True)
    bf = brute_force_count(pat, tgt)
    assert bf == 2  # loop node -> 0, other endpoint -> 1 or 2
    for variant in ("ri", "ri-ds", "ri-ds-si", "ri-ds-si-fc", "ri-ds-si-acfc"):
        ref = ref_enumerate(pat, tgt, variant=variant)
        assert ref.matches == bf, variant
        res = enumerate_subgraphs(pat, tgt, variant=variant, n_workers=2,
                                  expand_width=2)
        assert res.matches == bf, variant


def test_selfloop_label_mismatch_no_match():
    """A pattern self-loop whose label differs from the target's loop label
    must not match (labels checked, not just loop presence)."""
    tgt = Graph.from_edges(2, [(0, 1), (0, 0)], edge_labels=[0, 1],
                           undirected=True)
    pat = Graph.from_edges(2, [(0, 1), (0, 0)], edge_labels=[0, 0],
                           undirected=True)
    assert brute_force_count(pat, tgt) == 0
    res = enumerate_subgraphs(pat, tgt, variant="ri-ds-si-fc")
    assert res.matches == 0


def test_selfloop_random_corpus_brute_force():
    """Fixed-seed sweep: self-loop-bearing patterns agree with brute force
    through every variant."""
    checked = 0
    for seed in range(8):
        rng = np.random.default_rng(seed)
        tgt = random_graph(rng, 6, 8, n_labels=2, selfloops=2)
        pat = extract_connected_pattern(rng, tgt, 3)
        if pat.m == 0 or not np.any(pat.src == pat.dst):
            continue
        bf = brute_force_count(pat, tgt)
        for variant in ("ri", "ri-ds-si-fc", "ri-ds-si-acfc"):
            assert ref_enumerate(pat, tgt, variant=variant).matches == bf
            res = enumerate_subgraphs(pat, tgt, variant=variant, n_workers=2,
                                      expand_width=2)
            assert res.matches == bf
        checked += 1
    assert checked >= 2  # the sweep must actually exercise loop patterns


# ---------------------------------------------------------------------------
# label overflow + stale bits
# ---------------------------------------------------------------------------

def test_label_overflow_is_unsat_not_indexerror():
    """A pattern edge label outside the target's range must yield
    satisfiable=False in every pipeline mode (it used to IndexError)."""
    tgt = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3)], undirected=True)
    packed = PackedGraph.from_graph(tgt)
    pat = bump_edge_label(Graph.from_edges(2, [(0, 1)], undirected=True), 0, 7)
    for use_ac, use_fc, interleave in PIPELINES:
        res = dom_mod.compute_domains(
            pat, packed, use_ac=use_ac, use_fc=use_fc, interleave=interleave
        )
        assert not res.satisfiable
        assert not res.bits.any()
    # overflow self-loop label too
    loop = Graph.from_edges(1, [(0, 0)], edge_labels=[9], undirected=True)
    res = dom_mod.compute_domains(loop, packed, use_ac=False)
    assert not res.satisfiable and not res.bits.any()
    # end to end: zero matches, no crash, in every variant
    for variant in ("ri", "ri-ds", "ri-ds-si-fc", "ri-ds-si-acfc"):
        assert ref_enumerate(pat, tgt, variant=variant).matches == 0
        assert enumerate_subgraphs(pat, tgt, variant=variant).matches == 0


def test_unsat_results_have_zeroed_bits():
    """DomainResult invariant: satisfiable=False => all-zero bits (early
    unsat exits used to leak partially filtered bitmaps)."""
    # FC collision
    bits = np.zeros((2, 1), dtype=np.uint32)
    bits[0, 0] = 0b01
    bits[1, 0] = 0b01
    res = dom_mod.forward_check_singletons(bits)
    assert not res.satisfiable and not res.bits.any()
    # AC-driven emptying: star pattern needs a degree-3 hub, path target
    # has none beyond label/degree compat
    tgt = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3)], undirected=True)
    pat = Graph.from_edges(4, [(0, 1), (0, 2), (0, 3)], undirected=True)
    packed = PackedGraph.from_graph(tgt)
    res = dom_mod.compute_domains(pat, packed, use_ac=True)
    assert not res.satisfiable and not res.bits.any()


# ---------------------------------------------------------------------------
# device engine == numpy oracle, fixed-seed corpus
# ---------------------------------------------------------------------------

def test_device_fixpoint_matches_numpy_fixed_seeds():
    for seed in range(6):
        rng = np.random.default_rng(seed)
        tgt = random_graph(rng, 12, 24, n_labels=2, n_elabs=2,
                           selfloops=seed % 3)
        pat = extract_connected_pattern(rng, tgt, 3)
        if pat.m == 0:
            continue
        if seed % 2:
            pat = bump_edge_label(pat, int(rng.integers(pat.m)), 5)
        packed = PackedGraph.from_graph(tgt)
        for use_ac, use_fc, interleave in PIPELINES:
            a = dom_mod.compute_domains(
                pat, packed, use_ac=use_ac, use_fc=use_fc, interleave=interleave
            )
            b = dom_mod.compute_domains_device(
                pat, packed, use_ac=use_ac, use_fc=use_fc, interleave=interleave
            )
            assert a.satisfiable == b.satisfiable, (seed, use_ac, use_fc, interleave)
            np.testing.assert_array_equal(a.bits, b.bits)


def test_device_batch_matches_numpy_fixed_seed():
    rng = np.random.default_rng(1)
    tgt = random_graph(rng, 14, 30, n_labels=2, selfloops=2)
    pats = []
    while len(pats) < 5:
        p = extract_connected_pattern(rng, tgt, int(rng.integers(2, 5)))
        if p.m:
            pats.append(p)
    packed = PackedGraph.from_graph(tgt)
    outs = dom_mod.compute_domains_batch(
        pats, packed, use_ac=True, use_fc=True, interleave=True, batch_pad=8
    )
    for p, o in zip(pats, outs):
        a = dom_mod.compute_domains(p, packed, use_ac=True, use_fc=True,
                                    interleave=True)
        assert a.satisfiable == o.satisfiable
        np.testing.assert_array_equal(a.bits, o.bits)


def test_device_pallas_interpret_matches_numpy(rng):
    """use_pallas routes the sweep through the Pallas kernels (interpret
    mode on CPU) — same bits on both the single-query (scalar-prefetch
    sweep kernel) and batched (per-arc kernels) paths."""
    tgt = random_graph(rng, 10, 20, n_labels=2, selfloops=1)
    pat = extract_connected_pattern(rng, tgt, 3)
    if pat.m == 0:
        pytest.skip("empty pattern")
    packed = PackedGraph.from_graph(tgt)
    a = dom_mod.compute_domains(pat, packed, use_ac=True, use_fc=True,
                                interleave=True)
    b = dom_mod.compute_domains_device(pat, packed, use_ac=True, use_fc=True,
                                       interleave=True, use_pallas=True)
    assert a.satisfiable == b.satisfiable
    np.testing.assert_array_equal(a.bits, b.bits)
    outs = dom_mod.compute_domains_batch(
        [pat, pat], packed, use_ac=True, use_fc=True, interleave=True,
        use_pallas=True,
    )
    for o in outs:
        np.testing.assert_array_equal(a.bits, o.bits)


def test_sparse_domains_match_dense_fixed_seeds():
    """``compute_domains_sparse`` == ``compute_domains``, bit for bit, on a
    corpus with self-loops, multiple edge labels, and out-of-range labels,
    for every pipeline mode — including the unsat rules (label overflow and
    empty-domain zeroing), which the sparse path used to skip for variant
    ``ri`` (DESIGN.md §11)."""
    from repro.core.graph import n_words

    checked_loops = checked_overflow = 0
    for seed in range(10):
        rng = np.random.default_rng(seed)
        tgt = random_graph(rng, 14, 30, n_labels=2, n_elabs=2,
                           selfloops=seed % 3)
        pat = extract_connected_pattern(rng, tgt, 3)
        if pat.m == 0:
            continue
        if seed % 2:
            pat = bump_edge_label(pat, int(rng.integers(pat.m)), 5)
            checked_overflow += 1
        if np.any(pat.src == pat.dst):
            checked_loops += 1
        packed = PackedGraph.from_graph(tgt)
        w = n_words(tgt.n)
        np.testing.assert_array_equal(
            dom_mod.initial_domains_sparse(pat, tgt, w),
            dom_mod.initial_domains(pat, packed),
        )
        for use_ac, use_fc, interleave in PIPELINES:
            a = dom_mod.compute_domains(
                pat, packed, use_ac=use_ac, use_fc=use_fc,
                interleave=interleave,
            )
            b = dom_mod.compute_domains_sparse(
                pat, tgt, w, use_ac=use_ac, use_fc=use_fc,
                interleave=interleave,
            )
            assert a.satisfiable == b.satisfiable, (
                seed, use_ac, use_fc, interleave,
            )
            np.testing.assert_array_equal(a.bits, b.bits)
    # the sweep must actually exercise the rules under test
    assert checked_overflow >= 2 and checked_loops >= 2


def test_acfc_subset_and_states_fixed_seed():
    """Joint AC ⇄ FC fixpoint: domains ⊆ sequential AC → FC, matches equal,
    states never larger under the same ordering."""
    from repro.core.plan import build_plan

    for seed in range(6):
        rng = np.random.default_rng(seed)
        tgt = random_graph(rng, 12, 26, n_labels=2, selfloops=seed % 2)
        pat = extract_connected_pattern(rng, tgt, 4)
        if pat.m == 0:
            continue
        packed = PackedGraph.from_graph(tgt)
        seq = dom_mod.compute_domains(pat, packed, use_ac=True, use_fc=True)
        joint = dom_mod.compute_domains(pat, packed, use_ac=True, use_fc=True,
                                        interleave=True)
        if seq.satisfiable and joint.satisfiable:
            assert not np.any(joint.bits & ~seq.bits)
            assert popcount(joint.bits).sum() <= popcount(seq.bits).sum()
        fc = ref_enumerate(pat, tgt, variant="ri-ds-si-fc")
        acfc = ref_enumerate(pat, tgt, variant="ri-ds-si-acfc")
        assert acfc.matches == fc.matches
        p_fc = build_plan(pat, packed, variant="ri-ds-si-fc")
        p_acfc = build_plan(pat, packed, variant="ri-ds-si-acfc")
        if p_fc.order.tolist() == p_acfc.order.tolist():
            assert acfc.states <= fc.states
