"""Optimizer + gradient-compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import compression
from repro.train import optimizer as opt_mod


def test_adamw_first_step_analytic():
    cfg = opt_mod.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                              weight_decay=0.0, grad_clip=0.0,
                              warmup_steps=0, total_steps=10**9,
                              min_lr_ratio=1.0)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, -0.5])}
    state = opt_mod.init(params)
    new_p, new_s, metrics = opt_mod.update(cfg, grads, state, params)
    # bias-corrected first step = lr * g/(|g| + eps) = lr * sign(g)
    np.testing.assert_allclose(
        np.asarray(new_p["w"]), [1.0 - 0.1, -2.0 + 0.1], rtol=1e-5
    )
    assert int(new_s.step) == 1


def test_adamw_converges_quadratic():
    cfg = opt_mod.AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=1.0,
                              warmup_steps=5, total_steps=300)
    target = jnp.asarray([3.0, -1.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt_mod.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return opt_mod.update(cfg, grads, state, params)

    for _ in range(300):
        params, state, _ = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_grad_clip_caps_norm():
    cfg = opt_mod.AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 100.0)}
    state = opt_mod.init(params)
    _, new_s, metrics = opt_mod.update(cfg, grads, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    # post-clip first moment has norm <= (1-b1)*clip
    assert float(jnp.linalg.norm(new_s.mu["w"])) <= 0.1 + 1e-6


def test_schedule_shape():
    cfg = opt_mod.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
    lr0 = float(opt_mod.schedule(cfg, jnp.asarray(0)))
    lr10 = float(opt_mod.schedule(cfg, jnp.asarray(10)))
    lr100 = float(opt_mod.schedule(cfg, jnp.asarray(100)))
    assert lr0 == 0.0
    assert lr10 == pytest.approx(1.0)
    assert lr100 == pytest.approx(0.1, rel=1e-3)


def test_compression_error_feedback_unbiased():
    """With error feedback, the cumulative decoded signal tracks the
    cumulative true gradient."""
    rng = np.random.default_rng(0)
    g_true = rng.normal(size=(64,)).astype(np.float32)
    err = jnp.zeros(64)
    total_dec = np.zeros(64)
    for i in range(50):
        q, s, err = compression.compress(jnp.asarray(g_true), err)
        total_dec += np.asarray(compression.decompress(q, s))
    np.testing.assert_allclose(total_dec / 50, g_true, atol=1e-2)


def test_compress_grads_tree():
    grads = {"a": jnp.ones((8,)), "b": {"c": jnp.full((4,), -2.0)}}
    err = compression.init_error(grads)
    out, err2 = compression.compress_grads(grads, err)
    assert jax.tree.structure(out) == jax.tree.structure(grads)
    np.testing.assert_allclose(np.asarray(out["a"]), np.ones(8), atol=0.02)
