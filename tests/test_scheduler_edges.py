"""Deterministic edge cases for the steal policy and the engine's steal
round (complementing the hypothesis sweep in test_scheduler.py, which is
skipped when hypothesis is absent): no donors, keep_min / recv_cap clamps,
single-worker no-op, and conservation of entries through a full round.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core.engine import EngineConfig, EngineState
from repro.core.scheduler import StealPolicy, plan_steals, receiver_workers


def _plan(sizes, **kw):
    policy = StealPolicy(**kw)
    return tuple(np.asarray(x) for x in plan_steals(jnp.asarray(sizes, jnp.int32), policy))


def test_all_empty_stacks_no_donors():
    donate, accepted, dest_rank, _ = _plan([0, 0, 0, 0])
    assert donate.sum() == 0
    assert accepted.sum() == 0
    assert np.all(dest_rank == -1)


def test_no_receivers_no_transfers():
    donate, accepted, dest_rank, _ = _plan([10, 10, 10])
    assert donate.sum() > 0  # offers exist...
    assert accepted.sum() == 0  # ...but nobody is hungry
    assert np.all(dest_rank == -1)


def test_donor_clamped_at_keep_min():
    donate, accepted, _, _ = _plan([10, 4, 3, 0], steal_chunk=8, keep_min=3)
    assert donate.tolist() == [7, 1, 0, 0]  # never below keep_min
    assert np.all(accepted <= donate)


def test_receiver_clamped_at_recv_cap():
    # three eager donors, one receiver with cap 2: exactly 2 move
    donate, accepted, dest_rank, dest_pos = _plan(
        [9, 9, 9, 0], steal_chunk=4, keep_min=0, recv_cap=2
    )
    assert donate.tolist() == [4, 4, 4, 0]
    assert accepted.sum() == 2
    taken = dest_rank >= 0
    assert np.all(dest_rank[taken] == 0)
    assert sorted(dest_pos[taken].tolist()) == [0, 1]


def test_single_worker_noop():
    donate, accepted, dest_rank, _ = _plan([7])
    assert donate.tolist() == [4]  # offers, with nobody to take
    assert accepted.sum() == 0
    assert np.all(dest_rank == -1)
    # the engine additionally skips the round entirely at n_workers == 1
    cfg = EngineConfig(n_workers=1, expand_width=2)
    state = _toy_state([5], cfg)
    out = eng._steal_round(cfg, state)
    assert np.asarray(out.size).tolist() == [5]
    assert int(out.steal_rounds) == 0


def _toy_state(sizes, cfg, s_cap=8, p_pad=4, w=1):
    """An EngineState whose stack entries are tagged (worker, position) so
    conservation can be checked entry-for-entry; bases are staggered so the
    ring-buffer wraparound path is exercised."""
    v = len(sizes)
    st_depth = np.zeros((v, s_cap), np.int32)
    st_map = np.full((v, s_cap, p_pad), -1, np.int32)
    st_used = np.zeros((v, s_cap, w), np.uint32)
    st_cand = np.zeros((v, s_cap, w), np.uint32)
    base = np.asarray([(3 * k) % s_cap for k in range(v)], np.int32)
    for k, sz in enumerate(sizes):
        for j in range(sz):
            slot = (base[k] + j) % s_cap
            st_depth[k, slot] = 1 + j
            st_map[k, slot, 0] = 100 * k + j  # unique entry tag
            st_used[k, slot, 0] = np.uint32(1 + k)
            st_cand[k, slot, 0] = np.uint32(1 + j)
    return EngineState(
        st_depth=jnp.asarray(st_depth),
        st_map=jnp.asarray(st_map),
        st_used=jnp.asarray(st_used),
        st_cand=jnp.asarray(st_cand),
        base=jnp.asarray(base),
        size=jnp.asarray(sizes, jnp.int32),
        matches=jnp.zeros((v,), jnp.int32),
        states=jnp.zeros((v,), jnp.int32),
        exp_depth=jnp.zeros((v,), jnp.int32),
        steals=jnp.zeros((v,), jnp.int32),
        steal_depth=jnp.zeros((v,), jnp.int32),
        steal_rounds=jnp.zeros((), jnp.int32),
        steps=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.bool_),
        match_buf=jnp.full((v, 1, p_pad), -1, jnp.int32),
    )


def _entries(state):
    """Multiset of live stack entries as (depth, tag, used, cand) tuples."""
    depth = np.asarray(state.st_depth)
    tag = np.asarray(state.st_map)[:, :, 0]
    used = np.asarray(state.st_used)[:, :, 0]
    cand = np.asarray(state.st_cand)[:, :, 0]
    base = np.asarray(state.base)
    size = np.asarray(state.size)
    s_cap = depth.shape[1]
    out = []
    for k in range(depth.shape[0]):
        for j in range(size[k]):
            slot = (base[k] + j) % s_cap
            out.append((int(depth[k, slot]), int(tag[k, slot]),
                        int(used[k, slot]), int(cand[k, slot])))
    return sorted(out)


def test_steal_round_conserves_entries():
    cfg = EngineConfig(n_workers=4, expand_width=2,
                       steal_chunk=3, keep_min=1, recv_cap=2)
    state = _toy_state([6, 0, 5, 0], cfg)
    before = _entries(state)
    out = eng._steal_round(cfg, state)
    after = _entries(out)
    assert int(np.asarray(out.size).sum()) == len(before)
    assert after == before  # same entries, just redistributed
    assert int(np.asarray(out.steals).sum()) > 0  # something actually moved
    # donors kept >= keep_min, receivers got <= recv_cap
    assert np.all(np.asarray(out.size)[[0, 2]] >= cfg.keep_min)
    assert np.all(np.asarray(out.steals) <= cfg.recv_cap)


def test_sharded_steal_round_matches_unsharded_on_one_device():
    """The shard_map round with D=1 (collectives are identities) must be
    state-for-state identical to the plain round."""
    from jax.experimental.shard_map import shard_map

    cfg = EngineConfig(n_workers=4, expand_width=2,
                       steal_chunk=3, keep_min=1, recv_cap=2)
    state = _toy_state([6, 0, 5, 0], cfg)
    ref = eng._steal_round(cfg, state)

    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    specs = eng.state_partition_specs("data")
    fn = shard_map(
        functools.partial(eng._steal_round_sharded, cfg, axis="data"),
        mesh=mesh, in_specs=(specs,), out_specs=specs, check_rep=False,
    )
    out = jax.jit(fn)(state)
    for name in EngineState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, name)), np.asarray(getattr(out, name)), err_msg=name
        )
