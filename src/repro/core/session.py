"""Prepared-query session API for subgraph enumeration.

The paper's workloads are collections of *thousands* of patterns per target
(PPIS32: 420, PDBSv1: 1760 queries).  The one-shot
:func:`repro.core.api.enumerate_subgraphs` re-packs the target, rebuilds the
plan and re-traces the engine on every call; this module is the
session-oriented surface that amortizes all three:

* :class:`SubgraphIndex` — a prepared target: the :class:`PackedGraph`
  bitmaps plus label/degree metadata, built once, reusable across queries
  and picklable (pure numpy — ship it to another process, load it in a
  server).
* :class:`Query` — a pattern compiled against an index into a
  :class:`SearchPlan` whose padding is snapped to **shape buckets**
  (``p_pad ∈ {16, 32, 64, 128}``, fixed ``max_parents``), so thousands of
  patterns lower to a handful of XLA compilations.
* :class:`Enumerator` — the session object: an :class:`EngineConfig`, an
  optional device mesh (``mesh=`` shards the worker axis over the mesh
  ``data`` axis via ``shard_map``; ``n_workers`` snaps up to a multiple of
  the device count — see DESIGN.md §2.4), a keyed compile cache ``(kind,
  mesh signature, p_pad, max_parents, n_t, w, …) → jitted engine`` with
  ``compiles`` / ``cache_hits`` counters, and three execution methods
  sharing one code path:

    - ``run(query)``                 — one query, one engine invocation;
    - ``run_batch(queries)``         — LPT-balanced vmapped packs (the
      former ``core/multi.py`` driver), exactly one result per query, in
      input order;
    - ``stream(queries)``            — generator yielding a
      :class:`MatchSet` per query as packs drain (the serving path).

  Preprocessing batches too (DESIGN.md §5): ``prepare_batch(patterns)``
  runs the AC ⇄ FC domain fixpoint for a whole padded pattern batch as one
  vmapped jitted call on device, keyed into the same compile cache; raw
  ``Graph`` inputs to ``run_batch``/``stream`` route through it
  automatically (``domain_backend='numpy'`` restores the host loop).

Results unify into :class:`MatchSet`: counts, per-worker statistics, and
lazy match materialization (``mappings()`` re-runs the prepared query with
a match buffer only when asked).

Typical use::

    index = SubgraphIndex.build(target)             # once per target
    enum = Enumerator(index, n_workers=16)          # once per session
    q = enum.prepare(pattern)                       # per pattern (cheap)
    ms = enum.run(q)                                # engine reused
    for ms in enum.stream([enum.prepare(p) for p in patterns]):
        print(ms.name, ms.matches)
    enum.cache_info()   # {'compiles': 1, 'cache_hits': 419, ...}
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import threading
import time
import warnings
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delta as delta_mod
from repro.core import domains as dom_mod
from repro.core import engine as eng
from repro.core import extend
from repro.core import frontier
from repro.core.delta import DeltaMatchSet, GraphDelta
from repro.core.engine import EngineConfig, EngineResult
from repro.core.graph import (
    WORD_BITS,
    CsrPlanes,
    CsrPlaneSet,
    Graph,
    PackedGraph,
    bitmap_to_indices,
    n_words,
    popcount,
)
from repro.core.plan import SearchPlan, build_csr_plan, build_plan, variant_flags
from repro.core.scheduler import balance_assignment

# Padded pattern-position buckets: every plan's ``p_pad`` snaps up to one of
# these, so patterns of size 3..16 share one engine compilation, 17..32 the
# next, and so on.  Beyond the last bucket we round up to multiples of it.
SHAPE_BUCKETS: Tuple[int, ...] = (16, 32, 64, 128)

# Fixed parent-slot padding for bucketed plans (the ordering expands it when
# a dense pattern genuinely needs more; that pattern then lands in its own —
# rare — bucket).
DEFAULT_MAX_PARENTS = 8

# Cap on the lazily materialized match buffer (per worker).
_MATERIALIZE_CAP = 1 << 17


def snap_p_pad(n_p: int) -> int:
    """Smallest shape bucket that holds ``n_p`` pattern positions."""
    for b in SHAPE_BUCKETS:
        if n_p <= b:
            return b
    top = SHAPE_BUCKETS[-1]
    return ((n_p + top - 1) // top) * top


def snap_arc_pad(n_arcs: int) -> int:
    """Arc-slot bucket for the device domain engine: multiples of 8."""
    return max(8, ((n_arcs + 7) // 8) * 8)


def snap_loop_pad(n_loops: int) -> int:
    """Self-loop-slot bucket: 1 (the loop-free common case) or multiples
    of 4."""
    return 1 if n_loops == 0 else ((n_loops + 3) // 4) * 4


def _match_count(old) -> int:
    """Prior-match count without materializing mappings: a MatchSet-like
    object carries it as ``.matches`` (an int); anything else is a
    sequence of mappings."""
    m = getattr(old, "matches", None)
    if isinstance(m, int):
        return m
    try:
        return len(old)
    except TypeError:
        return len(list(old))


def snap_batch_pad(n: int) -> int:
    """Pattern-batch lane bucket: next power of two (inert lanes replicate
    lane 0 and are discarded), so B patterns cost O(log B) compilations."""
    return 1 << max(n - 1, 0).bit_length()


# ---------------------------------------------------------------------------
# SubgraphIndex — a prepared target
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SubgraphIndex:
    """A target graph prepared for repeated querying.

    Holds the packed adjacency bitmaps plus the label/degree metadata the
    preprocessing (domains, ordering) consults.  Pure numpy — picklable and
    shareable across processes; build once per target, reuse for every
    pattern.

    Indexes are **versioned** (DESIGN.md §8): :meth:`update` produces a new
    index with incrementally patched bitmaps/CSR planes, ``version + 1``,
    and a content ``fingerprint`` chained through the edit — the
    fingerprint keys engine-compile caches and serving coalesce buckets, so
    a post-update run can never alias a stale compiled plan.
    """

    packed: PackedGraph
    n_labels: int
    label_counts: np.ndarray  # [n_labels] int64
    max_degree: int
    build_s: float
    version: int = 0
    fingerprint: str = ""
    # CSR-only index (DESIGN.md §11): build(target, sparse=True) never
    # materializes the dense adjacency bitmaps — ``packed`` is a metadata
    # shell whose ``adj_bits`` has a zero node axis, ``graph`` retains the
    # host Graph for CSR-native preprocessing, and plans built against the
    # index come from build_csr_plan (only the csr/auto/partitioned step
    # backends can run them).
    sparse: bool = False
    graph: Optional[Graph] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    # lazily built sparse adjacency, shared across versions per plane
    # (update() patches only touched planes — see graph.CsrPlaneSet)
    _plane_set: Optional[CsrPlaneSet] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _csr_flat: Optional[CsrPlanes] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @staticmethod
    def build(
        target: Union[Graph, PackedGraph, "SubgraphIndex"],
        sparse: bool = False,
    ) -> "SubgraphIndex":
        if isinstance(target, SubgraphIndex):
            return target
        if sparse:
            return SubgraphIndex._build_sparse(target)
        t0 = time.perf_counter()
        packed = target if isinstance(target, PackedGraph) else PackedGraph.from_graph(target)
        n_labels = int(packed.labels.max()) + 1 if packed.n else 0
        counts = np.bincount(packed.labels, minlength=max(n_labels, 1)).astype(np.int64)
        degs = packed.deg_out + packed.deg_in
        max_deg = int(degs.max()) if packed.n else 0
        return SubgraphIndex(
            packed=packed,
            n_labels=n_labels,
            label_counts=counts,
            max_degree=max_deg,
            build_s=time.perf_counter() - t0,
            version=0,
            fingerprint=_fingerprint_packed(packed),
        )

    @staticmethod
    def _build_sparse(target: Graph) -> "SubgraphIndex":
        """CSR-only index of a host :class:`Graph`: the packed form is a
        metadata shell (labels/degrees plus an ``adj_bits`` placeholder with
        a zero node axis) and the canonical :class:`CsrPlanes` are built
        eagerly — they *are* the adjacency."""
        if not isinstance(target, Graph):
            raise TypeError(
                "SubgraphIndex.build(sparse=True) needs a host Graph — a "
                f"{type(target).__name__} has already materialized (or "
                "implies) the dense bitmaps"
            )
        t0 = time.perf_counter()
        w = n_words(target.n)
        nl = target.n_edge_labels
        planes = target.csr_planes(nl)
        labels = np.asarray(target.labels, dtype=np.int32)
        packed = PackedGraph(
            n=target.n,
            w=w,
            adj_bits=np.zeros((nl, 2, 0, w), dtype=np.uint32),
            labels=labels,
            deg_out=target.out_degrees(),
            deg_in=target.in_degrees(),
        )
        n_labels = int(labels.max()) + 1 if target.n else 0
        counts = np.bincount(labels, minlength=max(n_labels, 1)).astype(np.int64)
        degs = packed.deg_out + packed.deg_in
        return SubgraphIndex(
            packed=packed,
            n_labels=n_labels,
            label_counts=counts,
            max_degree=int(degs.max()) if target.n else 0,
            build_s=time.perf_counter() - t0,
            version=0,
            fingerprint=_fingerprint_sparse(planes, labels, target.n, w),
            sparse=True,
            graph=target,
            _csr_flat=planes,
        )

    @property
    def n(self) -> int:
        return self.packed.n

    @property
    def w(self) -> int:
        return self.packed.w

    @property
    def n_edge_labels(self) -> int:
        return self.packed.n_edge_labels

    # -- sparse adjacency (shared with plans via SearchPlan.csr_factory) ---

    def plane_set(self) -> CsrPlaneSet:
        """Per-plane CSR adjacency, built lazily and patched (not rebuilt)
        by :meth:`update` — untouched planes share buffers across versions."""
        if self.sparse:
            raise ValueError(
                "sparse SubgraphIndex has no per-plane set derived from "
                "dense bitmaps; use csr_planes() for the flat adjacency"
            )
        if self._plane_set is None:
            object.__setattr__(
                self, "_plane_set", CsrPlaneSet.from_bitmaps(self.packed.adj_bits)
            )
        return self._plane_set

    def csr_planes(self) -> CsrPlanes:
        """Canonical flat :class:`CsrPlanes` of this index version (cached);
        plans built against this index consume it through their
        ``csr_factory`` so the csr step backend never re-derives planes from
        the dense bitmaps."""
        if self._csr_flat is None:
            object.__setattr__(self, "_csr_flat", self.plane_set().to_planes())
        return self._csr_flat

    # -- incremental update (DESIGN.md §8) ---------------------------------

    def update(
        self,
        add_edges: Iterable = (),
        remove_edges: Iterable = (),
    ) -> Tuple["SubgraphIndex", GraphDelta]:
        """Apply an edge edit, returning ``(new_index, delta)``.

        Edits are ``(u, v)`` or ``(u, v, elab)`` arc triples with set
        semantics: duplicate inserts and removals of absent arcs are
        dropped, and an arc both inserted and removed in the *same* call
        cancels before anything is applied (no-op delta ≡ empty).  A true
        no-op returns ``self`` unchanged (same object, same version).

        The new index patches copies of the dense bitmaps in place (bit
        flips on touched rows), re-sorts only the touched rows of the
        touched CSR planes (untouched planes share buffers by reference),
        recomputes degrees for touched nodes only, and shares the label
        arrays.  Node set and node labels are immutable; inserting an arc
        with a new edge label grows the plane axis.

        Degrees are recomputed from the patched bitmaps, i.e. as
        *distinct-arc* counts — for an index built from an arc list with
        duplicates (``Graph.from_edges(undirected=True)`` doubles
        self-loop arcs) a touched node's degree normalizes to its
        distinct count.  Both counts are sound for the domain filters;
        build from a deduped arc list when exact degree parity with a
        fresh build matters.
        """
        if self.sparse:
            raise NotImplementedError(
                "incremental update of a sparse (CSR-only) SubgraphIndex is "
                "not supported — rebuild with SubgraphIndex.build(graph, "
                "sparse=True), or build a dense index when deltas are needed"
            )
        t0 = time.perf_counter()
        adds = delta_mod.normalize_edges(add_edges)
        rems = delta_mod.normalize_edges(remove_edges)
        cancel = set(adds) & set(rems)
        packed = self.packed
        n, w, nl = packed.n, packed.w, packed.n_edge_labels
        for (u, v, l) in tuple(adds) + tuple(rems):
            if not (0 <= u < n and 0 <= v < n):
                raise ValueError(f"edit arc ({u}, {v}) out of range for n={n}")
            if l < 0:
                raise ValueError(f"negative edge label {l}")

        def present(t) -> bool:
            u, v, l = t
            if l >= nl:
                return False
            return bool((int(packed.adj_bits[l, 0, u, v // WORD_BITS])
                         >> (v % WORD_BITS)) & 1)

        eff_add = tuple(t for t in adds if t not in cancel and not present(t))
        eff_rem = tuple(t for t in rems if t not in cancel and present(t))
        if not eff_add and not eff_rem:
            return self, GraphDelta(
                added=(), removed=(),
                old_version=self.version, new_version=self.version,
                old_fingerprint=self.fingerprint,
                new_fingerprint=self.fingerprint,
            )

        nl_new = max(nl, 1 + max((l for (_, _, l) in eff_add), default=-1))
        if nl_new > nl:
            adj = np.zeros((nl_new, 2, n, w), dtype=np.uint32)
            adj[:nl] = packed.adj_bits
        else:
            adj = packed.adj_bits.copy()
        for (u, v, l) in eff_add:
            adj[l, 0, u, v // WORD_BITS] |= np.uint32(1) << np.uint32(v % WORD_BITS)
            adj[l, 1, v, u // WORD_BITS] |= np.uint32(1) << np.uint32(u % WORD_BITS)
        for (u, v, l) in eff_rem:
            adj[l, 0, u, v // WORD_BITS] &= ~(np.uint32(1) << np.uint32(v % WORD_BITS))
            adj[l, 1, v, u // WORD_BITS] &= ~(np.uint32(1) << np.uint32(u % WORD_BITS))

        # degrees: recompute touched endpoints from the patched bitmaps
        # (set semantics — identical to a fresh build of the edited graph)
        deg_out = packed.deg_out.copy()
        deg_in = packed.deg_in.copy()
        touched_src = np.fromiter(
            {u for (u, _, _) in eff_add + eff_rem}, dtype=np.int64)
        touched_dst = np.fromiter(
            {v for (_, v, _) in eff_add + eff_rem}, dtype=np.int64)
        if len(touched_src):
            deg_out[touched_src] = popcount(adj[:, 0, touched_src, :]).sum(axis=0)
        if len(touched_dst):
            deg_in[touched_dst] = popcount(adj[:, 1, touched_dst, :]).sum(axis=0)

        new_packed = PackedGraph(
            n=n, w=w, adj_bits=adj, labels=packed.labels,
            deg_out=deg_out, deg_in=deg_in,
        )

        # CSR plane set: patch only touched (plane, row) pairs; untouched
        # plane buffers are shared by reference (satellite aliasing test)
        new_plane_set = None
        if self._plane_set is not None:
            rows_of: Dict[int, Dict[int, np.ndarray]] = {}
            for (u, v, l) in eff_add + eff_rem:
                rows_of.setdefault(l * 2, {})[u] = None
                rows_of.setdefault(l * 2 + 1, {})[v] = None
            for p, rows in rows_of.items():
                for r in rows:
                    rows[r] = bitmap_to_indices(adj[p // 2, p % 2, r])
            new_plane_set = self.plane_set().grown(2 * nl_new).patched(rows_of)

        h = hashlib.blake2b(digest_size=16)
        h.update(self.fingerprint.encode())
        h.update(repr((eff_add, eff_rem)).encode())
        new_fp = h.hexdigest()

        degs = deg_out + deg_in
        new_index = SubgraphIndex(
            packed=new_packed,
            n_labels=self.n_labels,
            label_counts=self.label_counts,
            max_degree=int(degs.max()) if n else 0,
            build_s=time.perf_counter() - t0,
            version=self.version + 1,
            fingerprint=new_fp,
            _plane_set=new_plane_set,
        )
        delta = GraphDelta(
            added=eff_add,
            removed=eff_rem,
            old_version=self.version,
            new_version=new_index.version,
            old_fingerprint=self.fingerprint,
            new_fingerprint=new_fp,
        )
        return new_index, delta


def _fingerprint_packed(packed: PackedGraph) -> str:
    """Content fingerprint of a packed target: shapes + adjacency bits +
    node labels.  Chain-extended by :meth:`SubgraphIndex.update` so every
    index version has a distinct, deterministic identity."""
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((packed.n, packed.w, packed.adj_bits.shape)).encode())
    h.update(np.ascontiguousarray(packed.adj_bits).tobytes())
    h.update(np.ascontiguousarray(packed.labels).tobytes())
    return h.hexdigest()


def _fingerprint_sparse(planes: CsrPlanes, labels: np.ndarray, n: int, w: int) -> str:
    """Content fingerprint of a sparse (CSR-only) index: shapes + CSR
    adjacency + node labels — same role as :func:`_fingerprint_packed`
    without ever touching dense bitmaps."""
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((n, w, planes.n_planes, planes.nnz, "csr")).encode())
    h.update(np.ascontiguousarray(planes.indptr).tobytes())
    h.update(np.ascontiguousarray(planes.indices).tobytes())
    h.update(np.ascontiguousarray(labels).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Query — a pattern compiled against an index
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Query:
    """A pattern prepared against a :class:`SubgraphIndex`.

    ``plan`` is padded to a shape bucket so that same-bucket queries share
    one jitted engine inside an :class:`Enumerator`.
    """

    pattern: Graph
    plan: SearchPlan
    variant: str
    name: str
    prepare_s: float
    # The index this query was prepared against (None for hand-built
    # queries): run_delta needs it for anchor plans, and its fingerprint
    # versions the engine-cache / coalesce keys (DESIGN.md §8).
    index: Optional[SubgraphIndex] = dataclasses.field(default=None, repr=False)
    # per-anchor plan cache for run_delta: {(pa, pb, elab): SearchPlan}
    _anchors: Dict[Tuple[int, int, int], SearchPlan] = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    _anchor_domains: Optional[dom_mod.DomainResult] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def bucket(self) -> Tuple[int, int, int, int, int]:
        """The compile-cache shape key: (p_pad, max_parents, n_t, w, n_elab).

        Shape-only on purpose — same-shape queries against one index share
        compiled engines; the *content* identity rides separately as
        :attr:`index_fingerprint` in the engine-cache and coalesce keys.
        """
        p = self.plan
        return (p.p_pad, p.max_parents, p.n_t, p.w, p.n_edge_labels)

    @property
    def index_fingerprint(self) -> str:
        """Fingerprint of the index version this query binds to ("" for
        hand-built queries with no index)."""
        return self.index.fingerprint if self.index is not None else ""

    @property
    def satisfiable(self) -> bool:
        return self.plan.satisfiable


def prepare_query(
    pattern: Graph,
    index: Union[SubgraphIndex, Graph, PackedGraph],
    variant: str = "ri-ds-si-fc",
    name: Optional[str] = None,
    p_pad: Optional[int] = None,
    max_parents: Optional[int] = None,
    seed_edge=None,
) -> Query:
    """Compile ``pattern`` against ``index`` into a bucketed :class:`Query`.

    ``seed_edge`` (``"auto"`` or an explicit ``(u, v, elab)`` pattern-edge
    triple) enables edge-centric root seeding (DESIGN.md §10): the plan
    anchors the edge's endpoints at positions 0/1 so engines with
    ``root_seeding="edge"``/``"auto"`` can seed from the rare target edge
    class.  Selection reuses the index's cached CSR planes.

    Preparation routes by the index layout (DESIGN.md §11): a **sparse**
    index (``SubgraphIndex.build(graph, sparse=True)``) compiles through
    :func:`~repro.core.plan.build_csr_plan` — domains come from the
    CSR-native fixpoint and the resulting plan is CSR-only.
    """
    index = SubgraphIndex.build(index)
    t0 = time.perf_counter()
    if index.sparse:
        plan = build_csr_plan(
            pattern,
            index.graph,
            variant=variant,
            p_pad=p_pad if p_pad is not None else snap_p_pad(pattern.n),
            max_parents=max_parents if max_parents is not None else DEFAULT_MAX_PARENTS,
            w=index.w,
            seed_edge=seed_edge,
            planes=index.csr_planes(),
        )
    else:
        plan = build_plan(
            pattern,
            index.packed,
            variant=variant,
            p_pad=p_pad if p_pad is not None else snap_p_pad(pattern.n),
            max_parents=max_parents if max_parents is not None else DEFAULT_MAX_PARENTS,
            csr_factory=index.csr_planes,
            seed_edge=seed_edge,
        )
    return Query(
        pattern=pattern,
        plan=plan,
        variant=variant,
        name=name or _default_name(pattern),
        prepare_s=time.perf_counter() - t0,
        index=index,
    )


def _default_name(pattern: Graph) -> str:
    """Default query name, shared by prepare_query and prepare_batch."""
    return f"q{pattern.n}n{pattern.m}m"


# ---------------------------------------------------------------------------
# MatchSet — the unified result
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MatchSet:
    """Result of enumerating one query: counts, per-worker stats, lazy matches."""

    name: str
    query_index: int
    matches: int
    states: int
    steps: int
    steals: int
    steal_rounds: int
    mean_steal_depth: float
    mean_expand_depth: float
    per_worker_states: Optional[np.ndarray]
    per_worker_matches: Optional[np.ndarray]
    per_worker_steals: Optional[np.ndarray]
    preprocess_s: float
    match_s: float
    plan: SearchPlan
    engine: EngineResult
    retries: int = 0  # overflow retries spent (stack_cap doubled each time)
    _match_buf: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    _materialize: Optional[Callable[[], Optional[np.ndarray]]] = dataclasses.field(
        default=None, repr=False
    )
    _mappings: Optional[List[Tuple[int, ...]]] = dataclasses.field(default=None, repr=False)

    @property
    def total_s(self) -> float:
        return self.preprocess_s + self.match_s

    def mappings(self) -> List[Tuple[int, ...]]:
        """Materialized match mappings (order position -> target node).

        Lazy: if the engine ran in counting mode (the benchmarked mode), the
        prepared query is re-run once with a match buffer sized to hold every
        match; the result is cached on the MatchSet.
        """
        if self._mappings is not None:
            return self._mappings
        if self.matches == 0:
            self._mappings = []
            return self._mappings
        if self.matches > _MATERIALIZE_CAP and self._match_buf is None:
            raise RuntimeError(
                f"{self.matches} matches exceed the materialization cap "
                f"({_MATERIALIZE_CAP}); re-run with an explicit "
                "collect_matches budget and consume engine.match_buf directly"
            )
        buf = self._match_buf
        if buf is None and self._materialize is not None:
            buf = self._materialize()
        out: List[Tuple[int, ...]] = []
        if buf is not None:
            n_p = self.plan.n_p
            rows = buf.reshape(-1, buf.shape[-1])[:, :n_p]
            valid = (rows >= 0).all(axis=1)
            out = [tuple(int(x) for x in r) for r in rows[valid]]
        self._mappings = out
        return out


def _empty_engine_result() -> EngineResult:
    return EngineResult(
        matches=0, states=0, steps=0, steals=0, steal_rounds=0,
        mean_steal_depth=0.0, mean_expand_depth=0.0,
        per_worker_states=None, per_worker_matches=None,
        overflow=False, match_buf=None,
    )


# ---------------------------------------------------------------------------
# Enumerator — the session
# ---------------------------------------------------------------------------

class Enumerator:
    """A subgraph-enumeration session with a shape-bucketed compile cache.

    Holds an :class:`EngineConfig` and a dict of jitted engines keyed by
    ``(cfg, kind, pack, bucket)``.  All three execution methods go through
    the same cache, so any mix of ``run`` / ``run_batch`` / ``stream`` over
    same-bucket queries costs at most one compilation per (kind, pack
    width).  ``compiles`` and ``cache_hits`` counters let benchmarks prove
    recompilation is gone.

    ``Enumerator(..., step_backend="auto")`` defers the expansion-backend
    choice to the target size: queries against targets beyond
    ``extend.CSR_AUTO_NT`` (32,768) nodes run the sparse CSR backend
    (DESIGN.md §6.4), smaller ones the dense ``jnp`` step.  An explicit
    ``step_backend=`` always wins.  The cache key carries the cfg *and*
    the bucket's ``n_t``, so one session can mix resolutions without
    collisions.

    ``Enumerator(..., memory_budget_bytes=N)`` selects the **out-of-core
    partitioned** backend (DESIGN.md §9): each target is row-partitioned
    into the smallest count whose padded resident planes fit ``N`` bytes,
    and enumeration streams the partitions through the device
    (``step_backend="partitioned"`` with ``EngineConfig.n_partitions``
    picks the count explicitly instead).  Results are bit-identical to the
    monolithic backends; compile-cache and coalesce keys carry the
    partition identity, and :meth:`warm` pre-traces hot buckets.
    """

    def __init__(
        self,
        index: Union[SubgraphIndex, Graph, PackedGraph, None] = None,
        config: Optional[EngineConfig] = None,
        variant: str = "ri-ds-si-fc",
        mesh: Union["jax.sharding.Mesh", int, None] = None,
        domain_backend: str = "device",
        max_cache_entries: int = 0,
        memory_budget_bytes: Optional[int] = None,
        **config_kwargs,
    ):
        cfg = config or EngineConfig(**config_kwargs)
        if config is not None and config_kwargs:
            cfg = dataclasses.replace(config, **config_kwargs)
        if memory_budget_bytes is not None:
            if memory_budget_bytes <= 0:
                raise ValueError(
                    f"memory_budget_bytes must be positive, got {memory_budget_bytes}"
                )
            # an explicit budget implies the out-of-core backend: the
            # partition count is derived per target so the resident padded
            # planes fit the budget (DESIGN.md §9)
            cfg = dataclasses.replace(cfg, step_backend="partitioned")
        self.memory_budget_bytes = memory_budget_bytes
        self.mesh = _coerce_mesh(mesh)
        if self.mesh is not None:
            axis = eng.mesh_worker_axis(self.mesh)
            n_dev = int(self.mesh.shape[axis])
            if cfg.n_workers % n_dev:
                # snap up so every device owns the same number of stacks
                cfg = dataclasses.replace(
                    cfg, n_workers=((cfg.n_workers + n_dev - 1) // n_dev) * n_dev
                )
        if domain_backend not in ("device", "numpy"):
            raise ValueError(
                f"domain_backend must be 'device' or 'numpy', got {domain_backend!r}"
            )
        self.config = cfg
        self.variant = variant
        self.domain_backend = domain_backend
        if max_cache_entries < 0:
            raise ValueError(f"max_cache_entries must be >= 0, got {max_cache_entries}")
        self.max_cache_entries = max_cache_entries
        self.index = SubgraphIndex.build(index) if index is not None else None
        # LRU-ordered compile cache: hits move entries to the back, inserts
        # evict from the front once max_cache_entries is exceeded (0 = no
        # bound — batch scripts; servers set a bound, DESIGN.md §7).
        self._engines: "collections.OrderedDict[tuple, Callable]" = collections.OrderedDict()
        # shape-keyed XLA trace pool backing the fingerprinted entries in
        # _engines: index versions of one shape share a single trace
        # (bounded by shape diversity, not by version count)
        self._traces: Dict[tuple, Callable] = {}
        # entries per trace shape: LRU-evicting the last entry of a shape
        # drops its trace too, so max_cache_entries still bounds compiled
        # memory.  invalidate_index decrements but keeps zero-ref traces:
        # an index update never changes array shapes (n is immutable), so
        # the next version re-uses the trace immediately — dropping it
        # there would recreate the per-version retrace the pool exists to
        # avoid (DESIGN.md §8).
        self._trace_refs: Dict[tuple, int] = {}
        # sticky high-water match-buffer size for seeded delta runs (see
        # _run_seeded): grow-retries fold into one steady-state shape
        self._delta_mcap = self._DELTA_MCAP
        # device-resident adjacency bitmaps keyed by index fingerprint:
        # the dominant host→device transfer, shared by a version's query
        # plan and every delta anchor plan (kept to the two most recent
        # versions — old + new during an update handoff)
        self._adj_device: "collections.OrderedDict[str, jnp.ndarray]" = (
            collections.OrderedDict()
        )
        # guards _engines: the serving dispatcher thread runs engines while
        # service.update_index() invalidates stale entries from a client
        # thread (DESIGN.md §8)
        self._cache_lock = threading.Lock()
        # target-side device arrays for batched domain preprocessing, keyed
        # by the packed target's identity (pinned so ids can't be recycled);
        # values are dense TargetDomainArrays or CsrTargetDomainArrays per
        # the index layout
        self._dom_targets: Dict[int, Tuple[PackedGraph, tuple]] = {}
        self.compiles = 0
        self.cache_hits = 0
        self.evictions = 0

    # -- cache -------------------------------------------------------------

    def cache_stats(self) -> Dict[str, int]:
        """Compile-cache counters: ``compiles`` / ``cache_hits`` /
        ``evictions`` plus current ``entries`` and the configured
        ``max_entries`` bound (0 = unbounded).  The serving metrics layer
        snapshots this to report cache hit rate."""
        return {
            "compiles": self.compiles,
            "cache_hits": self.cache_hits,
            "evictions": self.evictions,
            "entries": len(self._engines),
            "max_entries": self.max_cache_entries,
        }

    # kept name from PR 1; same counters, cache_stats() is the full view
    cache_info = cache_stats

    def _cache_put(self, key: tuple, fn: Callable) -> None:
        """Insert a jitted engine, LRU-evicting past ``max_cache_entries``."""
        with self._cache_lock:
            if key not in self._engines:
                sk = key[:-1]
                self._trace_refs[sk] = self._trace_refs.get(sk, 0) + 1
            self._engines[key] = fn
            if self.max_cache_entries:
                while len(self._engines) > self.max_cache_entries:
                    old_key, _ = self._engines.popitem(last=False)
                    self.evictions += 1
                    self._release_trace_locked(old_key, drop_if_unused=True)

    def _release_trace_locked(self, key: tuple, drop_if_unused: bool) -> None:
        """One engine-cache entry for ``key`` went away; decrement its
        trace shape's refcount and (for LRU eviction) drop an unreferenced
        trace so the entry bound still bounds compiled memory."""
        sk = key[:-1]
        n = self._trace_refs.get(sk, 0) - 1
        if n > 0:
            self._trace_refs[sk] = n
        else:
            self._trace_refs.pop(sk, None)
            if drop_if_unused:
                self._traces.pop(sk, None)

    def _cache_get(self, key: tuple) -> Optional[Callable]:
        with self._cache_lock:
            fn = self._engines.get(key)
            if fn is not None:
                self._engines.move_to_end(key)
                self.cache_hits += 1
            return fn

    def invalidate_index(self, fingerprint: str) -> int:
        """Drop every compile-cache entry keyed to ``fingerprint`` (an
        index version retired by ``SubgraphIndex.update``) and return the
        number dropped.  The serving layer calls this on index swap so
        stale engines stop occupying the LRU; correctness never depends on
        it — the fingerprint in the key already prevents false hits."""
        if not fingerprint:
            return 0
        with self._cache_lock:
            stale = [k for k in self._engines if fingerprint in k]
            for k in stale:
                del self._engines[k]
                # keep zero-ref traces: the successor version has the same
                # shapes and re-uses them without a retrace
                self._release_trace_locked(k, drop_if_unused=False)
            self._adj_device.pop(fingerprint, None)
            return len(stale)

    def _engine_fn(self, cfg: EngineConfig, kind: str, pack: int, query: Query) -> Callable:
        # layout check first: an explicitly dense backend against a
        # CSR-only plan must raise *before* a compile is spent/counted
        extend.validate_backend_for_plan(cfg, query.plan)
        shape_key = (cfg, kind, pack, eng.mesh_signature(self.mesh)) + query.bucket
        resolved = eng.resolve_step_backend_for_plan(cfg, query.plan)
        if resolved == "csr":
            # csr plan arrays carry density-dependent shapes (deg_cap, nnz);
            # without them in the key, a same-bucket different-density query
            # would count as a cache hit while jit silently retraces
            shape_key = shape_key + extend.csr_shape_bucket(query.plan)
        elif resolved == "partitioned":
            # partition identity: same-bucket targets with different
            # partitionings (count or padded per-partition shapes) must not
            # share a compiled partitioned engine
            shape_key = shape_key + extend.partitioned_shape_bucket(
                query.plan, max(1, cfg.n_partitions)
            )
        # the trailing fingerprint versions the entry to one index content:
        # after an index update, same-shape queries get a fresh entry (no
        # false hit on a retired version, and retired versions can be
        # evicted by invalidate_index — see the incremental conformance
        # suite).  The engine itself is content-agnostic (plan arrays are
        # call arguments), so entries for different versions of one shape
        # share a single XLA trace from the pool below — an update never
        # re-traces, which is what keeps run_delta's per-version cost
        # proportional to the delta (DESIGN.md §8).
        key = shape_key + (query.index_fingerprint,)
        fn = self._cache_get(key)
        if fn is not None:
            return fn
        with self._cache_lock:
            fn = self._traces.get(shape_key)
        if fn is None:
            self.compiles += 1
            if kind == "part":
                fn = eng.make_partitioned_engine_fn(cfg, self.mesh)
            elif kind == "single":
                if self.mesh is not None:
                    fn = eng.make_sharded_engine_fn(
                        cfg, self.mesh, n_t=query.plan.n_t,
                        csr_only=eng.is_csr_only(query.plan),
                    )
                else:
                    fn = jax.jit(functools.partial(eng._engine_loop, cfg))
            else:
                fn = jax.jit(jax.vmap(functools.partial(eng._engine_loop, cfg)))
            with self._cache_lock:
                self._traces[shape_key] = fn
        self._cache_put(key, fn)
        return fn

    # -- preparation -------------------------------------------------------

    def prepare(
        self,
        pattern: Graph,
        variant: Optional[str] = None,
        name: Optional[str] = None,
        index: Union[SubgraphIndex, Graph, PackedGraph, None] = None,
        seed_edge=None,
    ) -> Query:
        """Compile a pattern into a bucketed :class:`Query` for this session.

        ``seed_edge`` is forwarded to :func:`prepare_query` (edge-centric
        seeding, DESIGN.md §10).  A sparse index yields a CSR-only plan;
        if this session's step backend is explicitly dense
        (``"jnp"``/``"pallas"``), that combination can never run, so it
        raises here — before any engine is compiled."""
        idx = index if index is not None else self.index
        if idx is None:
            raise ValueError(
                "Enumerator has no default SubgraphIndex; pass index= to "
                "prepare() or construct Enumerator(index, ...)"
            )
        q = prepare_query(
            pattern, idx, variant=variant or self.variant, name=name,
            seed_edge=seed_edge,
        )
        extend.validate_backend_for_plan(self.config, q.plan)
        return q

    def prepare_batch(
        self,
        patterns: Sequence[Graph],
        variant: Optional[str] = None,
        names: Optional[Sequence[str]] = None,
        index: Union[SubgraphIndex, Graph, PackedGraph, None] = None,
        backend: Optional[str] = None,
    ) -> List[Query]:
        """Prepare a batch of patterns with **device-resident** domain
        preprocessing (DESIGN.md §5): patterns are grouped by domain shape
        bucket ``(p_pad, arc_pad, loop_pad)``, each group's AC ⇄ FC fixpoint
        runs as **one vmapped jitted call** (padded to a power-of-two lane
        count), and the jitted fixpoints are keyed into this session's
        compile cache alongside the engines.  Results are bit-identical to
        per-query :meth:`prepare` (the numpy oracle) — only the wall-clock
        changes.  ``backend='numpy'`` (or ``Enumerator(domain_backend=
        'numpy')``) falls back to per-query host preprocessing.

        A **sparse** index routes the same grouped fixpoint through the
        CSR-layout target arrays (DESIGN.md §11) and assembles CSR-only
        plans — dense adjacency bitmaps never exist on host or device.
        """
        idx = index if index is not None else self.index
        if idx is None:
            raise ValueError(
                "Enumerator has no default SubgraphIndex; pass index= to "
                "prepare_batch() or construct Enumerator(index, ...)"
            )
        idx = SubgraphIndex.build(idx)
        variant = variant or self.variant
        patterns = list(patterns)
        if names is not None and len(names) != len(patterns):
            raise ValueError(
                f"names has {len(names)} entries for {len(patterns)} patterns"
            )
        name_of = lambda i, p: (
            names[i] if names is not None else _default_name(p)
        )
        backend = backend or self.domain_backend
        if backend == "numpy":
            return [
                self.prepare(p, variant=variant, name=name_of(i, p), index=idx)
                for i, p in enumerate(patterns)
            ]

        flags = variant_flags(variant)
        groups: Dict[tuple, List[int]] = {}
        for i, p in enumerate(patterns):
            n_p, n_a, n_l = dom_mod.domain_bucket(p)
            key = (snap_p_pad(n_p), snap_arc_pad(n_a), snap_loop_pad(n_l))
            groups.setdefault(key, []).append(i)

        out: List[Optional[Query]] = [None] * len(patterns)
        tgt_arrays = self._target_domain_arrays(idx)
        for (p_pad, a_pad, l_pad), idxs in groups.items():
            b_pad = snap_batch_pad(len(idxs))
            fn = self._domain_fn(flags, b_pad, p_pad, a_pad, l_pad, idx)
            t0 = time.perf_counter()
            doms = dom_mod.compute_domains_batch(
                [patterns[i] for i in idxs],
                idx.packed,
                use_ac=flags["use_ac"],
                use_fc=flags["use_fc"],
                interleave=flags["interleave"],
                use_pallas=self.config.use_pallas,
                p_pad=p_pad,
                arc_pad=a_pad,
                loop_pad=l_pad,
                batch_pad=b_pad,
                tgt_arrays=tgt_arrays,
                fn=fn,
            )
            dom_s = (time.perf_counter() - t0) / max(len(idxs), 1)
            for i, dres in zip(idxs, doms):
                t1 = time.perf_counter()
                if idx.sparse:
                    plan = build_csr_plan(
                        patterns[i],
                        idx.graph,
                        variant=variant,
                        p_pad=snap_p_pad(patterns[i].n),
                        max_parents=DEFAULT_MAX_PARENTS,
                        w=idx.w,
                        domains=dres,
                        planes=idx.csr_planes(),
                    )
                else:
                    plan = build_plan(
                        patterns[i],
                        idx.packed,
                        variant=variant,
                        p_pad=snap_p_pad(patterns[i].n),
                        max_parents=DEFAULT_MAX_PARENTS,
                        domains=dres,
                        csr_factory=idx.csr_planes,
                    )
                extend.validate_backend_for_plan(self.config, plan)
                out[i] = Query(
                    pattern=patterns[i],
                    plan=plan,
                    variant=variant,
                    name=name_of(i, patterns[i]),
                    prepare_s=dom_s + (time.perf_counter() - t1),
                    index=idx,
                )
        assert all(q is not None for q in out)
        return out  # type: ignore[return-value]

    # targets whose device-resident domain arrays stay cached; adjacency
    # bitmaps dominate the footprint, so keep only a few (FIFO-evicted).
    _DOM_TARGET_CACHE = 4

    def _target_domain_arrays(
        self, index: SubgraphIndex
    ) -> Union[dom_mod.TargetDomainArrays, dom_mod.CsrTargetDomainArrays]:
        """Device-resident target arrays for domain preprocessing, built
        once per index and cached (bounded) on the session.  The cache
        entry pins the PackedGraph so its id() cannot be recycled.  A
        sparse index gets the CSR-layout arrays (DESIGN.md §11) — the
        fixpoint engine dispatches on the tuple type."""
        key = id(index.packed)
        hit = self._dom_targets.get(key)
        if hit is not None:
            return hit[1]
        if index.sparse:
            arrays = dom_mod.csr_target_domain_arrays(
                index.graph, index.w, planes=index.csr_planes()
            )
        else:
            arrays = dom_mod.target_domain_arrays(index.packed)
        while len(self._dom_targets) >= self._DOM_TARGET_CACHE:
            self._dom_targets.pop(next(iter(self._dom_targets)))
        self._dom_targets[key] = (index.packed, arrays)
        return arrays

    def _domain_fn(
        self, flags: Dict[str, bool], b_pad: int, p_pad: int, a_pad: int,
        l_pad: int, index: SubgraphIndex,
    ) -> Callable:
        """The jitted batched domain fixpoint for one shape bucket, keyed
        into the session compile cache (kind='domains').  For a sparse
        index the key carries the CSR domain-array shape components
        (padded ``nnz`` and ``deg_cap``) — two same-``(n_t, w)`` targets of
        different density trace differently shaped fixpoints and must not
        collide."""
        pallas_mode = "per-arc" if self.config.use_pallas else "off"
        key = (
            "domains", flags["use_ac"], flags["use_fc"], flags["interleave"],
            pallas_mode, b_pad, p_pad, a_pad, l_pad,
            index.n, index.w, index.n_edge_labels,
        )
        if index.sparse:
            cp = index.csr_planes()
            key = key + (
                "csr",
                extend._pad_nnz(int(cp.nnz)),
                extend._pad_deg_cap(int(cp.deg_cap)),
            )
        fn = self._cache_get(key)
        if fn is not None:
            return fn
        self.compiles += 1
        fn = dom_mod.device_fixpoint(
            use_ac=flags["use_ac"], use_fc=flags["use_fc"],
            interleave=flags["interleave"], pallas_mode=pallas_mode,
            batched=True,
        )
        self._cache_put(key, fn)
        return fn

    def _coerce(self, q: Union[Query, Graph]) -> Query:
        return q if isinstance(q, Query) else self.prepare(q)

    def _coerce_all(self, queries: Iterable[Union[Query, Graph]]) -> List[Query]:
        """Coerce a mixed Query/Graph sequence; raw patterns go through the
        batched device preprocessing path in one sweep."""
        qs = list(queries)
        todo = [i for i, q in enumerate(qs) if not isinstance(q, Query)]
        if todo:
            prepared = self.prepare_batch([qs[i] for i in todo])
            for i, q in zip(todo, prepared):
                qs[i] = q
        return qs  # type: ignore[return-value]

    # -- execution: single -------------------------------------------------

    def run(self, query: Union[Query, Graph], collect_matches: int = 0) -> MatchSet:
        """Run one prepared query through the (cached) engine.

        A run whose stack high-watermark breached its ring capacity has
        *undercounted* (full workers freeze instead of expanding), so an
        ``overflow`` result is never returned silently: the query is
        retried once with a doubled ``stack_cap`` (with a warning;
        ``MatchSet.retries`` records it).  If the doubled cap still
        overflows, a ``RuntimeError`` asks for an explicit budget.
        """
        query = self._coerce(query)
        if not query.plan.satisfiable:
            return self._matchset(query, -1, _empty_engine_result(), 0.0)
        cfg = self.config
        if collect_matches:
            cfg = dataclasses.replace(cfg, collect_matches=collect_matches)
        t0 = time.perf_counter()
        res = self._run_single(cfg, query)
        retries = 0
        if res.overflow:
            res = self._retry_overflowed(cfg, query)
            retries = 1
        match_s = time.perf_counter() - t0
        return self._matchset(query, -1, res, match_s, retries=retries)

    def _run_single(self, cfg: EngineConfig, query: Query) -> EngineResult:
        """One engine invocation through the compile cache (no retry).

        Plan arrays follow the resolved step backend: dense
        :class:`~repro.core.extend.PlanArrays`, or
        :class:`~repro.core.extend.CsrPlanArrays` for ``step_backend="csr"``
        — including ``"auto"``, which flips to the sparse layout past
        ``extend.CSR_AUTO_NT`` target nodes (the cache key carries both the
        cfg and ``n_t``, so the resolution is stable per entry)."""
        if eng.resolve_step_backend_for_plan(cfg, query.plan) == "partitioned":
            return self._run_partitioned(cfg, query)
        fn = self._engine_fn(cfg, "single", 1, query)
        arrays = self._plan_arrays(cfg, query)
        state = eng.init_state(query.plan, cfg)
        final = jax.block_until_ready(fn(arrays, state))
        return eng.result_from_state(final, cfg)

    # -- execution: out-of-core partitioned (DESIGN.md §9) ------------------

    def _partition_count(self, cfg: EngineConfig, plan: SearchPlan) -> int:
        """Partition count for a plan under this session: an explicit
        ``EngineConfig.n_partitions`` wins; otherwise the session's
        ``memory_budget_bytes`` derives the smallest count whose padded
        resident planes fit; otherwise 1 (degenerate — the whole target is
        one resident partition)."""
        if cfg.n_partitions > 0:
            return cfg.n_partitions
        if self.memory_budget_bytes is not None:
            return extend.plan_partitions_budget(
                plan, self.memory_budget_bytes
            ).n_parts
        return 1

    def _run_partitioned(self, cfg: EngineConfig, query: Query) -> EngineResult:
        """One out-of-core run: the host scheduling loop of
        :func:`repro.core.engine.run_partitioned`, with every inner-engine
        (re)build routed through this session's compile cache — warm legs
        and repeat queries are cache hits, and the counters stay honest."""
        runc = dataclasses.replace(
            cfg,
            step_backend="partitioned",
            n_partitions=self._partition_count(cfg, query.plan),
        )
        return eng.run_partitioned(
            query.plan,
            runc,
            mesh=self.mesh,
            engine_factory=lambda c: self._engine_fn(c, "part", 1, query),
        )

    def warm(
        self,
        queries: Iterable[Union[Query, Graph]],
        collect_matches: int = 0,
        lanes: int = 1,
    ) -> Dict[str, int]:
        """Pre-trace the engines the given queries will need (PR-6
        follow-up: proactive compile-cache warmup).

        Each query's engine is resolved through the normal compile cache
        and invoked once on an **inert** state (zero stack sizes — the
        device loop exits immediately), which forces the XLA compile without
        enumerating anything.  Subsequent :meth:`run` / :meth:`run_pack`
        submits of same-key queries are then pure cache hits, so a serving
        process can move every compile stall to startup
        (``ServiceConfig.warmup_profile``).  Pass the ``collect_matches``
        budget the later submits will use — the buffer size is part of the
        traced shapes.  ``lanes > 1`` warms the vmapped *pack* engine of
        that width instead of the single-query path (what
        :meth:`run_pack` dispatches actually invoke; ignored where packs
        route singly — mesh and partitioned sessions).

        Returns ``{"warmed": queries traced, "compiles": fresh XLA
        compilations spent}`` (0 fresh compiles means everything was
        already warm).
        """
        before = self.compiles
        warmed = 0
        for q in self._coerce_all(queries):
            if not q.plan.satisfiable:
                continue
            cfg = self.config
            if collect_matches:
                cfg = dataclasses.replace(cfg, collect_matches=collect_matches)
            if eng.resolve_step_backend_for_plan(cfg, q.plan) == "partitioned":
                runc = dataclasses.replace(
                    cfg,
                    step_backend="partitioned",
                    n_partitions=self._partition_count(cfg, q.plan),
                )
                fn = self._engine_fn(runc, "part", 1, q)
                pp = extend.plan_partitions(q.plan, runc.n_partitions)
                arrays = extend.make_part_plan_arrays(q.plan, pp, 0)
                st = _inert_state(eng.init_state(q.plan, runc))
                spill = frontier.init_spill_state(
                    runc.n_workers,
                    runc.resolved_spill_cap(q.plan.p_pad),
                    q.plan.p_pad,
                    q.plan.w,
                )
                jax.block_until_ready(fn(arrays, st, spill))
            elif lanes > 1 and self.mesh is None:
                # the pack path stacks per-lane arrays/states; an all-inert
                # pack of the dispatch width traces the same vmapped engine
                fn = self._engine_fn(cfg, "batch", lanes, q)
                arrays = eng.plan_arrays_for(cfg, q.plan)
                st = _inert_state(eng.init_state(q.plan, cfg))
                stacked = jax.tree.map(lambda x: jnp.stack([x] * lanes), arrays)
                states = jax.tree.map(lambda x: jnp.stack([x] * lanes), st)
                jax.block_until_ready(fn(stacked, states))
            else:
                fn = self._engine_fn(cfg, "single", 1, q)
                arrays = self._plan_arrays(cfg, q)
                st = _inert_state(eng.init_state(q.plan, cfg))
                jax.block_until_ready(fn(arrays, st))
            warmed += 1
        return {"warmed": warmed, "compiles": self.compiles - before}

    def _plan_arrays(self, cfg: EngineConfig, query: Query,
                     plan: Optional[SearchPlan] = None):
        """:func:`~repro.core.extend.plan_arrays_for` with the adjacency
        transfer cached per index fingerprint (``_adj_device``): the query
        plan and its delta anchor plans all reference one version's bitmap
        object, so only the first run of a version ships it to device."""
        plan = plan or query.plan
        fp = query.index_fingerprint
        if not fp or eng.resolve_step_backend_for_plan(cfg, plan) == "csr":
            return eng.plan_arrays_for(cfg, plan)
        dev = self._adj_device.get(fp)
        if dev is None or tuple(dev.shape) != tuple(plan.adj_bits.shape):
            dev = jnp.asarray(plan.adj_bits, jnp.uint32)
            self._adj_device[fp] = dev
            self._adj_device.move_to_end(fp)
            while len(self._adj_device) > 2:
                self._adj_device.popitem(last=False)
        return eng.plan_arrays_for(cfg, plan, adj_bits=dev)

    def _retry_overflowed(self, cfg: EngineConfig, query: Query) -> EngineResult:
        """``cfg``'s run of ``query`` overflowed (undercounted): warn and
        re-run once with a doubled ``stack_cap``; raise if even that
        overflows.  Shared by run() and the pack path."""
        cap = cfg.resolved_stack_cap(query.plan.p_pad)
        warnings.warn(
            f"query {query.name!r} overflowed its worker stacks "
            f"(stack_cap={cap}); retrying once with stack_cap={2 * cap} — "
            "set EngineConfig.stack_cap to avoid the duplicated work",
            RuntimeWarning,
            stacklevel=3,
        )
        res = self._run_single(
            dataclasses.replace(cfg, stack_cap=2 * cap), query
        )
        if res.overflow:
            raise RuntimeError(
                f"engine stack overflow persists at stack_cap={2 * cap} "
                f"for query {query.name!r} — set an explicit "
                "EngineConfig.stack_cap budget"
            )
        return res

    # -- execution: delta (DESIGN.md §8) -----------------------------------

    def run_delta(
        self,
        query: Union[Query, Graph],
        old_matches,
        delta: GraphDelta,
    ) -> DeltaMatchSet:
        """Incrementally maintain ``old_matches`` across one index update.

        ``query`` must be prepared against the delta's **new** index
        version (after ``new_index, delta = index.update(...)``, call
        ``enum.prepare(pattern, index=new_index)``); ``old_matches`` is the
        prior result for the old version — a :class:`MatchSet` or a list of
        node-indexed mappings.  Work is restricted to the delta:

        * removals invalidate prior matches by membership test (no
          enumeration at all);
        * insertions are enumerated by anchoring each distinct pattern
          edge onto each compatible inserted target arc and running the
          engine from those seeds only
          (`repro.core.frontier.init_delta_state`), deduplicated by the
          max-inserted-edge-index rule (`repro.core.delta`).

        Returns a :class:`DeltaMatchSet`; ``result.apply(old_matches)`` is
        bit-identical to a fresh enumeration's sorted mappings — the
        standing gate in ``tests/test_incremental_conformance.py``.
        """
        query = self._coerce(query)
        if delta.new_fingerprint and query.index_fingerprint != delta.new_fingerprint:
            raise ValueError(
                "run_delta: query is not prepared against the delta's new "
                "index version (fingerprint mismatch) — after "
                "SubgraphIndex.update(), prepare the query against the "
                "returned index"
            )
        t0 = time.perf_counter()
        removed: List[Tuple[int, ...]] = []
        if delta.removed:
            old_arr = delta_mod.as_mapping_array(old_matches)
            n_old = len(old_arr)
            removed = delta_mod.invalidated_mappings(
                query.pattern, old_arr, delta.removed
            )
        else:
            n_old = _match_count(old_matches)
        added: List[Tuple[int, ...]] = []
        states = seeds = anchors = retries = 0
        if delta.added and query.plan.satisfiable:
            for anchor, aplan in self._anchor_plans(query):
                sd, sm, sc = delta_mod.build_anchor_seeds(aplan, anchor, delta.added)
                if not sd.shape[0]:
                    continue
                anchors += 1
                seeds += int(sd.shape[0])
                rows, st, rt = self._run_seeded(query, aplan, sd, sm, sc)
                states += st
                retries += rt
                added.extend(
                    delta_mod.filter_new_matches(
                        query.pattern,
                        delta_mod.canonical_mappings(aplan, rows),
                        delta.added,
                        anchor,
                    )
                )
        return DeltaMatchSet(
            name=query.name,
            added=sorted(added),
            removed=sorted(removed),
            n_old=n_old,
            states=states,
            n_seeds=seeds,
            n_anchors=anchors,
            preprocess_s=query.prepare_s,
            match_s=time.perf_counter() - t0,
            retries=retries,
            delta=delta,
        )

    def _anchor_plans(self, query: Query) -> Iterator[Tuple[Tuple[int, int, int], SearchPlan]]:
        """``(anchor, plan)`` per distinct pattern edge triple, cached on
        the query.  Domains are ordering-independent, so one DomainResult
        is computed once and shared by every anchor plan; anchor plans keep
        the query's padding so same-shape anchors share compiled engines."""
        if query.index is None:
            raise ValueError(
                "run_delta needs a query bound to a SubgraphIndex "
                "(prepare it through an Enumerator / prepare_query)"
            )
        idx = query.index
        flags = variant_flags(query.variant)
        if query._anchor_domains is None:
            # The query plan retains the node-indexed domain fixpoint it
            # was assembled from; reuse it (AC/FC is by far the dominant
            # host cost per version) and only recompute for plans built
            # by older paths that did not stash it.
            query._anchor_domains = query.plan.domains
        if query._anchor_domains is None:
            query._anchor_domains = dom_mod.compute_domains(
                query.pattern,
                idx.packed,
                use_ac=flags["use_ac"],
                use_fc=flags["use_fc"],
                interleave=flags["interleave"],
            )
        for anchor in delta_mod.pattern_edge_triples(query.pattern):
            aplan = query._anchors.get(anchor)
            if aplan is None:
                if query.plan.seed_edge == anchor:
                    # An edge-seeded query plan *is* this anchor's plan:
                    # _assemble_plan already forced the seed edge's
                    # endpoints to positions 0/1 with the same domains and
                    # padding, so the anchor seeds stay aligned with the
                    # query's own seed-edge ordering instead of rebuilding
                    # an identical plan (PR-9 follow-up).
                    aplan = query.plan
                else:
                    pa, pb, _ = anchor
                    aplan = build_plan(
                        query.pattern,
                        idx.packed,
                        variant=query.variant,
                        p_pad=query.plan.p_pad,
                        max_parents=query.plan.max_parents,
                        domains=query._anchor_domains,
                        anchor=(pa,) if pa == pb else (pa, pb),
                        csr_factory=idx.csr_planes,
                    )
                query._anchors[anchor] = aplan
            yield anchor, aplan

    # first match-buffer size for seeded runs; grown (pow2) if any worker's
    # per-run match count wraps its ring
    _DELTA_MCAP = 256

    def _run_seeded(
        self,
        query: Query,
        aplan: SearchPlan,
        sd: np.ndarray,
        sm: np.ndarray,
        sc: np.ndarray,
    ) -> Tuple[np.ndarray, int, int]:
        """Run the engine from delta seed entries, in worker-capacity
        chunks; returns ``(match rows in aplan position space [K, n_p],
        states, retries)``.  Seeded runs always collect matches (the delta
        result is the mappings); a run whose per-worker match count wraps
        the collect ring, or that overflows its stacks, is retried with a
        doubled buffer / stack cap."""
        cfg0 = self.config
        aq = Query(
            pattern=query.pattern, plan=aplan, variant=query.variant,
            name=f"{query.name}~delta", prepare_s=0.0, index=query.index,
        )
        v = cfg0.n_workers
        cap0 = cfg0.resolved_stack_cap(aplan.p_pad)
        chunk = v * max(cap0 // 2, 1)
        rows_out: List[np.ndarray] = []
        states = retries = 0
        for j in range(0, int(sd.shape[0]), chunk):
            cs, cm, cc = sd[j:j + chunk], sm[j:j + chunk], sc[j:j + chunk]
            # start from the largest buffer any prior seeded run needed:
            # growth is sticky on the enumerator so a steady-state edit
            # stream settles on one traced shape instead of paying a
            # grow-retry (and an XLA compile) per call
            mcap = max(self._DELTA_MCAP, self._delta_mcap)
            cap = cap0
            while True:
                cfg = dataclasses.replace(
                    cfg0, collect_matches=mcap, stack_cap=cap
                )
                fn = self._engine_fn(cfg, "single", 1, aq)
                arrays = self._plan_arrays(cfg, aq, aplan)
                state = frontier.init_delta_state(aplan, cfg, cs, cm, cc)
                final = jax.block_until_ready(fn(arrays, state))
                res = eng.result_from_state(final, cfg)
                if res.overflow:
                    if cap >= cap0 * 4:
                        raise RuntimeError(
                            f"delta run for {query.name!r} still overflows "
                            f"at stack_cap={cap} — set an explicit "
                            "EngineConfig.stack_cap budget"
                        )
                    cap *= 2
                    retries += 1
                    continue
                pw = res.per_worker_matches
                top = int(np.max(pw)) if pw is not None and pw.size else res.matches
                if top > mcap:
                    mcap = 1 << (top - 1).bit_length()
                    self._delta_mcap = max(self._delta_mcap, mcap)
                    retries += 1
                    continue
                break
            states += res.states
            if res.match_buf is not None and res.matches:
                buf = np.asarray(res.match_buf)
                rows = buf.reshape(-1, buf.shape[-1])
                valid = (rows[:, : aplan.n_p] >= 0).all(axis=1)
                rows_out.append(rows[valid][:, : aplan.n_p])
        if rows_out:
            return np.concatenate(rows_out, axis=0), states, retries
        return np.zeros((0, aplan.n_p), dtype=np.int32), states, retries

    # -- execution: batch / stream ----------------------------------------

    def coalesce_key(self, query: Query, cfg: Optional[EngineConfig] = None) -> tuple:
        """The pack-compatibility key of a query: queries with equal keys
        can stack lane-for-lane into one vmapped pack (same jitted engine,
        same array shapes).  ``stream``/``run_batch`` group by it, and the
        serving layer's continuous coalescer (`repro.serve`) buckets
        pending queries by exactly this key, so concurrent heterogeneous
        load rides the compile cache at one compilation per key.

        The key is the shape bucket ``(p_pad, max_parents, n_t, w,
        n_elab)`` plus the query's index fingerprint — queries against
        different *contents* (two targets, or two versions of one updated
        index) never share a pack, since their plan arrays differ
        (DESIGN.md §8).  Under the csr backend it also carries the plan's
        padded ``(deg_cap, nnz)`` — two same-bucket targets of different
        density have differently shaped
        :class:`~repro.core.extend.CsrPlanArrays` and cannot share a pack
        lane.
        """
        cfg = cfg or self.config
        key = query.bucket + (query.index_fingerprint,)
        resolved = eng.resolve_step_backend_for_plan(cfg, query.plan)
        if resolved == "csr":
            key = key + extend.csr_shape_bucket(query.plan)
        elif resolved == "partitioned":
            # partition identity: two targets sharing a bucket but not a
            # partitioning (count or padded per-partition shapes) run
            # different compiled engines and must not coalesce
            key = key + extend.partitioned_shape_bucket(
                query.plan, self._partition_count(cfg, query.plan)
            )
        return key

    def run_pack(
        self,
        queries: Sequence[Union[Query, Graph]],
        pack_size: Optional[int] = None,
        cfg: Optional[EngineConfig] = None,
    ) -> List[MatchSet]:
        """Batch-submission hook for the serving layer: execute queries
        that share one :meth:`coalesce_key` as padded vmapped packs of
        ``pack_size`` lanes, returning one :class:`MatchSet` per query in
        input order (``query_index`` is the input position).

        Unlike :meth:`run_batch` this does **no** grouping or LPT
        balancing — the caller (the `repro.serve` coalescer) has already
        decided the pack; mixed keys raise.  Unsatisfiable queries get
        empty results without touching the engine.  ``cfg`` overrides the
        session config (the service uses it to thread per-request
        ``collect_matches`` budgets); overflowed lanes go through the
        usual doubled-``stack_cap`` single retry.  Under a mesh, queries
        route singly through the sharded engine (pack-vmap over
        ``shard_map`` is an open ROADMAP item).
        """
        cfg = cfg or self.config
        qs = self._coerce_all(queries)
        pack_size = pack_size or max(len(qs), 1)
        out: List[Optional[MatchSet]] = [None] * len(qs)
        live: List[int] = []
        for i, q in enumerate(qs):
            if q.plan.satisfiable:
                live.append(i)
            else:
                out[i] = self._matchset(q, i, _empty_engine_result(), 0.0)
        if live:
            keys = {self.coalesce_key(qs[i], cfg) for i in live}
            if len(keys) > 1:
                raise ValueError(
                    f"run_pack requires one coalesce_key per pack, got {len(keys)}: "
                    f"{sorted(keys)}"
                )
            if self.mesh is not None or cfg.step_backend == "partitioned":
                # sharded and out-of-core engines run queries singly (the
                # pack vmap composes with neither shard_map nor the host
                # partition-scheduling loop); the coalesce key still
                # grouped them, so the compile cache is shared
                for i in live:
                    ms = self.run(qs[i], collect_matches=cfg.collect_matches)
                    ms.query_index = i
                    out[i] = ms
            else:
                for j in range(0, len(live), pack_size):
                    for ms in self._run_pack(live[j:j + pack_size], qs, cfg, pack_size):
                        out[ms.query_index] = ms
        assert all(m is not None for m in out), "run_pack dropped a query"
        return out  # type: ignore[return-value]

    def stream(
        self,
        queries: Iterable[Union[Query, Graph]],
        pack_size: int = 4,
    ) -> Iterator[MatchSet]:
        """Yield one :class:`MatchSet` per query as vmapped packs drain.

        Queries are grouped by shape bucket, LPT-balanced into packs of
        ``pack_size`` (padded with inert lanes so every pack shares one
        compilation), and executed pack by pack; each completed pack yields
        its per-query results immediately.  ``MatchSet.query_index`` carries
        the position in the input sequence.
        """
        qs: List[Query] = self._coerce_all(queries)
        cfg = self.config

        if self.mesh is not None or cfg.step_backend == "partitioned":
            # The pack vmap composes with neither shard_map engines nor the
            # out-of-core host scheduling loop: each query runs through the
            # (cached) single-query path, yielding in input order.
            for i, q in enumerate(qs):
                if not q.plan.satisfiable:
                    yield self._matchset(q, i, _empty_engine_result(), 0.0)
                else:
                    ms = self.run(q)
                    ms.query_index = i
                    yield ms
            return

        groups: Dict[tuple, List[int]] = {}
        for i, q in enumerate(qs):
            if not q.plan.satisfiable:
                yield self._matchset(q, i, _empty_engine_result(), 0.0)
            else:
                groups.setdefault(self.coalesce_key(q, cfg), []).append(i)

        for idxs in groups.values():
            weights = [_predict_work(qs[i].plan) for i in idxs]
            n_packs = max(1, (len(idxs) + pack_size - 1) // pack_size)
            assignment = balance_assignment(weights, n_packs)
            for pack_id in range(n_packs):
                members = [i for i, a in zip(idxs, assignment) if a == pack_id]
                # LPT balances weight, not count: an overloaded pack is split
                # into pack_size chunks so every engine call has the same lane
                # width (one compilation per bucket, counters stay honest).
                for j in range(0, len(members), pack_size):
                    yield from self._run_pack(members[j:j + pack_size], qs, cfg, pack_size)

    def run_batch(
        self,
        queries: Sequence[Union[Query, Graph]],
        pack_size: int = 4,
    ) -> List[MatchSet]:
        """Run a batch of queries; exactly one result per query, in order."""
        queries = list(queries)
        out: List[Optional[MatchSet]] = [None] * len(queries)
        for ms in self.stream(queries, pack_size=pack_size):
            out[ms.query_index] = ms
        assert all(r is not None for r in out), "stream dropped a query"
        return out  # type: ignore[return-value]

    def _run_pack(
        self, members: List[int], qs: List[Query], cfg: EngineConfig, pack_size: int
    ) -> Iterator[MatchSet]:
        """Execute one padded pack of same-bucket queries, yielding results."""
        t0 = time.perf_counter()
        plans = [qs[i].plan for i in members]
        fn = self._engine_fn(cfg, "batch", pack_size, qs[members[0]])
        arrays = [eng.plan_arrays_for(cfg, p) for p in plans]
        states = [eng.init_state(p, cfg) for p in plans]
        # pad inert lanes so every pack of this bucket shares one compilation
        # (size==0 lanes freeze immediately under the vmapped while_loop)
        while len(arrays) < pack_size:
            arrays.append(arrays[0])
            states.append(_inert_state(states[0]))
        stacked_plan = jax.tree.map(lambda *xs: jnp.stack(xs), *arrays)
        stacked_state = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        final = jax.block_until_ready(fn(stacked_plan, stacked_state))
        match_s = (time.perf_counter() - t0) / max(len(members), 1)
        for row, i in enumerate(members):
            lane = jax.tree.map(lambda x, r=row: x[r], final)
            res = eng.result_from_state(lane, cfg)
            if res.overflow:
                # the pack undercounted this lane; go straight to the
                # doubled-stack_cap single retry (re-running at the original
                # cap would deterministically overflow again)
                res = self._retry_overflowed(cfg, qs[i])
                yield self._matchset(qs[i], i, res, match_s, retries=1)
                continue
            yield self._matchset(qs[i], i, res, match_s)

    # -- result assembly ---------------------------------------------------

    def _matchset(
        self, query: Query, idx: int, res: EngineResult, match_s: float,
        retries: int = 0,
    ) -> MatchSet:
        materialize = None
        if res.match_buf is None and query.plan.satisfiable:
            def materialize(q: Query = query, m: int = res.matches):
                # round the buffer up to a power of two so re-materializations
                # of different queries share a handful of engine configs
                cap = min(1 << max(m - 1, 1).bit_length(), _MATERIALIZE_CAP)
                return self.run(q, collect_matches=cap).engine.match_buf

        return MatchSet(
            name=query.name,
            query_index=idx,
            matches=res.matches,
            states=res.states,
            steps=res.steps,
            steals=res.steals,
            steal_rounds=res.steal_rounds,
            mean_steal_depth=res.mean_steal_depth,
            mean_expand_depth=res.mean_expand_depth,
            per_worker_states=res.per_worker_states,
            per_worker_matches=res.per_worker_matches,
            per_worker_steals=res.per_worker_steals,
            preprocess_s=query.prepare_s,
            match_s=match_s,
            plan=query.plan,
            engine=res,
            retries=retries,
            _match_buf=res.match_buf,
            _materialize=materialize,
        )


def _coerce_mesh(mesh) -> Optional["jax.sharding.Mesh"]:
    """Accept a ``jax.sharding.Mesh``, an int device count (first ``n``
    local devices on a 1-D ``data`` axis), or ``None``."""
    if mesh is None or isinstance(mesh, jax.sharding.Mesh):
        return mesh
    if isinstance(mesh, int):
        devs = jax.local_devices()
        if mesh > len(devs):
            raise ValueError(
                f"mesh={mesh} devices requested but only {len(devs)} local "
                "devices exist (on CPU set XLA_FLAGS="
                "--xla_force_host_platform_device_count=N before importing jax)"
            )
        return jax.make_mesh((mesh,), ("data",), devices=devs[:mesh])
    raise TypeError(f"mesh must be a Mesh, int, or None, got {type(mesh)!r}")


# Process-wide sessions for the compatibility wrappers and benchmark
# harness: one Enumerator (and thus one engine-compile cache) per config.
_SHARED: Dict[EngineConfig, Enumerator] = {}


def shared_enumerator(cfg: EngineConfig) -> Enumerator:
    """The process-wide session for ``cfg`` (created on first use)."""
    s = _SHARED.get(cfg)
    if s is None:
        s = _SHARED[cfg] = Enumerator(config=cfg)
    return s


def _predict_work(plan: SearchPlan) -> float:
    """Cheap work proxy: product of the first few domain sizes (the former
    ``core/multi.py`` heuristic feeding LPT pack balancing)."""
    sizes = popcount(plan.dom_bits[: min(plan.n_p, 4)])
    return float(np.prod(np.maximum(sizes, 1), dtype=np.float64))


def _inert_state(template: eng.EngineState) -> eng.EngineState:
    """A copy of ``template`` with no work: size 0, empty candidate bitmaps.

    Used to pad packs to a fixed lane count; the vmapped while_loop freezes
    these lanes immediately, so they cost nothing but shape stability."""
    return template._replace(
        size=jnp.zeros_like(template.size),
        st_cand=jnp.zeros_like(template.st_cand),
    )
