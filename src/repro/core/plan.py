"""SearchPlan — the static preprocessing product handed to the engine.

Bundles everything the vectorized search needs as dense, padded arrays:
ordering-position-indexed domains, parent constraint tables and the packed
target graph.  Ordering happens on host in numpy; domains come from the
numpy oracle or, via ``domains=``, from the device-resident fixpoint engine
(DESIGN.md §5).  The arrays are small except the bitmaps, which the engine
shards.

Pattern **self-loops** never appear in the parent tables (both endpoints
share one ordering position); they are enforced as unary constraints baked
into ``dom_bits`` by ``initial_domains``, which the engine/ref candidate
checks inherit (candidates are always ⊆ the position's domain).

Variants (paper terminology):

  * ``ri``            — RI: static domains are label+degree compat only.
  * ``ri-ds``         — RI-DS: + arc-consistent domains, singletons first.
  * ``ri-ds-si``      — + domain-size tie-breaking in the ordering (§4.2.1).
  * ``ri-ds-si-fc``   — + singleton forward checking (§4.2.2).
  * ``ri-ds-si-acfc`` — AC ⇄ FC interleaved to their *joint* fixpoint
    (DESIGN.md §5): FC removals re-trigger AC, so domains are never larger
    (often smaller) than ``ri-ds-si-fc``'s sequential AC → FC pass.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import domains as dom_mod
from repro.core import ordering as ord_mod
from repro.core.graph import (
    CsrPlanes, Graph, PackedGraph, csr_planes_from_bitmaps, n_words, popcount,
)

VARIANTS = ("ri", "ri-ds", "ri-ds-si", "ri-ds-si-fc", "ri-ds-si-acfc")


def variant_flags(variant: str) -> Dict[str, bool]:
    """Decompose a variant name into preprocessing switches:
    ``use_ac`` (arc consistency), ``use_si`` (domain-size ordering
    tie-break), ``use_fc`` (singleton forward checking), ``interleave``
    (AC ⇄ FC joint fixpoint)."""
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}, expected one of {VARIANTS}")
    return dict(
        use_ac=variant != "ri",
        use_si=variant in ("ri-ds-si", "ri-ds-si-fc", "ri-ds-si-acfc"),
        use_fc=variant in ("ri-ds-si-fc", "ri-ds-si-acfc"),
        interleave=variant == "ri-ds-si-acfc",
    )


@dataclasses.dataclass
class SearchPlan:
    """Static arrays for the vectorized search engine.

    All position-indexed arrays are padded to ``p_pad`` positions and
    ``max_parents`` parent slots.
    """

    variant: str
    n_p: int  # actual number of pattern nodes
    p_pad: int  # padded position count (>= n_p)
    n_t: int
    w: int  # bitmap words per row
    order: np.ndarray  # [p_pad] int32 pattern node id per position (-1 pad)
    parent_pos: np.ndarray  # [p_pad, max_parents] int32, -1 padded
    parent_dir: np.ndarray  # [p_pad, max_parents] int32
    parent_elab: np.ndarray  # [p_pad, max_parents] int32
    n_parents: np.ndarray  # [p_pad] int32
    dom_bits: np.ndarray  # [p_pad, w] uint32 — domain of order[i], position space
    adj_bits: np.ndarray  # [n_elab, 2, n_t, w] uint32 ([n_elab, 2, 0, w] when
    # the plan is CSR-only — see ``csr`` and :func:`build_csr_plan`)
    satisfiable: bool
    # Sparse adjacency twin (DESIGN.md §6.4): set by build_csr_plan (then
    # adj_bits is an empty placeholder and only step_backend="csr" can run
    # the plan) or lazily derived from adj_bits by the csr plan-array
    # builder (`repro.core.extend.make_csr_plan_arrays`).
    csr: Optional[CsrPlanes] = None
    # Lazy CsrPlanes supplier (e.g. ``SubgraphIndex.csr_planes`` so that
    # incrementally patched plane sets are reused instead of re-deriving the
    # flat planes from adj_bits per plan); consulted by
    # ``repro.core.extend.make_csr_plan_arrays`` when ``csr`` is unset.
    csr_factory: Optional[Callable[[], CsrPlanes]] = dataclasses.field(
        default=None, compare=False, repr=False
    )
    # Node-indexed domain fixpoint the plan was assembled from, retained so
    # delta anchor plans (``Enumerator._anchor_plans``, DESIGN.md §8) reuse
    # it instead of re-running AC/FC per index version.
    domains: Optional[dom_mod.DomainResult] = dataclasses.field(
        default=None, compare=False, repr=False
    )
    # Edge-centric seeding (DESIGN.md §10): the pattern edge ``(u, v, elab)``
    # whose endpoints occupy ordering positions 0/1, selected by
    # ``repro.core.ordering.select_seed_edge`` (or forced explicitly).  When
    # set, ``EngineConfig.root_seeding="edge"|"auto"`` enumerates this edge
    # class's target arcs directly into depth-1 root entries.
    seed_edge: Optional[Tuple[int, int, int]] = None

    @property
    def max_parents(self) -> int:
        return int(self.parent_pos.shape[1])

    @property
    def n_edge_labels(self) -> int:
        return int(self.adj_bits.shape[0])

    def domain_sizes(self) -> np.ndarray:
        return popcount(self.dom_bits[: self.n_p])


def build_plan(
    pattern: Graph,
    target: PackedGraph,
    variant: str = "ri-ds-si-fc",
    p_pad: Optional[int] = None,
    max_parents: Optional[int] = None,
    ac_iters: Optional[int] = None,
    domains: Optional[dom_mod.DomainResult] = None,
    anchor: Optional[Tuple[int, ...]] = None,
    csr_factory: Optional[Callable[[], CsrPlanes]] = None,
    seed_edge=None,
) -> SearchPlan:
    """Run preprocessing (domains + ordering) and emit a :class:`SearchPlan`.

    ``domains`` short-circuits the domain pipeline with a precomputed
    :class:`~repro.core.domains.DomainResult` (the batched device
    preprocessing path, `repro.core.session.Enumerator.prepare_batch`);
    it must match the variant's flags — the session guarantees this.

    ``anchor`` forces the given pattern node ids to the front of the
    ordering (delta seeding, DESIGN.md §8): an anchor plan for pattern edge
    ``(pa, pb)`` passes ``(pa, pb)`` so seeds can pin positions 0/1 onto an
    inserted target edge.  Domains are ordering-independent, so one
    ``DomainResult`` is shared across all anchor plans of a query.

    ``seed_edge`` enables edge-centric seeding (DESIGN.md §10): ``"auto"``
    picks the rarest target edge class via
    `repro.core.ordering.select_seed_edge` (over ``csr_factory``'s planes
    when given, else planes derived from the dense bitmaps); an explicit
    ``(u, v, elab)`` pattern-edge triple forces the choice.  The winning
    edge's endpoints are anchored to ordering positions 0/1 and recorded on
    ``SearchPlan.seed_edge``.  Mutually exclusive with ``anchor``.
    """
    flags = variant_flags(variant)
    use_ds, use_si = flags["use_ac"], flags["use_si"]

    seed = _resolve_seed_edge(
        pattern, seed_edge,
        csr_factory if csr_factory is not None
        else (lambda: csr_planes_from_bitmaps(target.adj_bits)),
    )

    # --- domains ---------------------------------------------------------
    if domains is not None:
        if domains.bits.shape != (pattern.n, target.w):
            raise ValueError(
                f"precomputed domains shape {domains.bits.shape} != "
                f"{(pattern.n, target.w)}"
            )
        dres = domains
    else:
        dres = dom_mod.compute_domains(
            pattern, target, use_ac=use_ds, use_fc=flags["use_fc"],
            ac_iters=ac_iters, interleave=flags["interleave"],
        )
    return _assemble_plan(
        pattern, dres, variant, use_ds, use_si, p_pad, max_parents,
        n_t=target.n, w=target.w, adj_bits=target.adj_bits, csr=None,
        anchor=anchor, csr_factory=csr_factory, seed_edge=seed,
    )


def build_csr_plan(
    pattern: Graph,
    target: Graph,
    variant: str = "ri",
    p_pad: Optional[int] = None,
    max_parents: Optional[int] = None,
    w: Optional[int] = None,
    ac_iters: Optional[int] = None,
    domains: Optional[dom_mod.DomainResult] = None,
    use_pallas: bool = False,
    anchor: Optional[Tuple[int, ...]] = None,
    seed_edge=None,
    planes: Optional[CsrPlanes] = None,
) -> SearchPlan:
    """Build a **CSR-only** :class:`SearchPlan` straight from a host
    :class:`Graph` — the dense ``[n_elab, 2, n_t, w]`` adjacency bitmaps are
    never materialized (DESIGN.md §6.4), so targets far beyond the paper's
    33k nodes fit in memory.  ``plan.adj_bits`` is an empty placeholder and
    ``plan.csr`` holds the canonical adjacency planes; only
    ``step_backend="csr"`` (or ``"auto"``) can execute the result.

    Every variant is supported (DESIGN.md §11): ``ri`` computes initial
    domains on host, the ``ri-ds*`` variants run the CSR-native device
    fixpoint (`repro.core.domains.compute_domains_csr` — AC sweeps walk the
    same `CsrPlanes` the engine enumerates over; ``use_pallas`` routes them
    through the scalar-prefetch `csr_arc_sweep` kernel).  Domains are
    bit-identical to the dense :func:`build_plan` pipeline for the same
    variant.  ``domains=`` short-circuits with a precomputed
    :class:`~repro.core.domains.DomainResult` (the batched session path),
    which must match the variant's flags.  ``planes=`` threads an
    already-built :class:`~repro.core.graph.CsrPlanes` through (the
    session's sparse index caches one per version) instead of re-deriving
    it from the edge list per pattern.
    """
    flags = variant_flags(variant)
    use_ds, use_si = flags["use_ac"], flags["use_si"]
    w = w or n_words(target.n)
    n_elab = target.n_edge_labels
    if planes is None:
        planes = target.csr_planes(n_elab)
    if domains is not None:
        if domains.bits.shape != (pattern.n, w):
            raise ValueError(
                f"precomputed domains shape {domains.bits.shape} != "
                f"{(pattern.n, w)}"
            )
        dres = domains
    else:
        tgt_arrays = (
            dom_mod.csr_target_domain_arrays(target, w, planes=planes)
            if (use_ds or flags["use_fc"]) else None
        )
        dres = dom_mod.compute_domains_sparse(
            pattern, target, w, use_ac=use_ds, use_fc=flags["use_fc"],
            interleave=flags["interleave"], use_pallas=use_pallas,
            ac_iters=ac_iters, tgt_arrays=tgt_arrays,
        )
    seed = _resolve_seed_edge(pattern, seed_edge, lambda: planes)
    return _assemble_plan(
        pattern, dres, variant, use_ds=use_ds, use_si=use_si,
        p_pad=p_pad, max_parents=max_parents,
        n_t=target.n, w=w,
        adj_bits=np.zeros((n_elab, 2, 0, w), dtype=np.uint32),
        csr=planes,
        anchor=anchor, seed_edge=seed,
    )


def _resolve_seed_edge(pattern: Graph, seed_edge, planes_factory):
    """Normalize a ``seed_edge=`` argument to a validated ``(u, v, elab)``
    pattern-edge triple (or ``None``): ``"auto"`` consults
    `repro.core.ordering.select_seed_edge` over the factory's planes; an
    explicit triple must name an existing non-self-loop pattern edge."""
    if seed_edge is None:
        return None
    if seed_edge == "auto":
        return ord_mod.select_seed_edge(pattern, planes_factory())
    u, v, lab = (int(x) for x in seed_edge)
    if u == v:
        raise ValueError(f"seed_edge {(u, v, lab)} is a self-loop")
    hit = np.any(
        (pattern.src == u) & (pattern.dst == v) & (pattern.edge_labels == lab)
    )
    if not hit:
        raise ValueError(f"seed_edge {(u, v, lab)} is not a pattern edge")
    return (u, v, lab)


def _assemble_plan(
    pattern: Graph,
    dres: dom_mod.DomainResult,
    variant: str,
    use_ds: bool,
    use_si: bool,
    p_pad: Optional[int],
    max_parents: Optional[int],
    n_t: int,
    w: int,
    adj_bits: np.ndarray,
    csr: Optional[CsrPlanes],
    anchor: Optional[Tuple[int, ...]] = None,
    csr_factory: Optional[Callable[[], CsrPlanes]] = None,
    seed_edge: Optional[Tuple[int, int, int]] = None,
) -> SearchPlan:
    """Ordering + padded-array assembly shared by :func:`build_plan` and
    :func:`build_csr_plan`."""
    dom_sizes = popcount(dres.bits)

    # Edge seeding rides the delta-anchor machinery: the seed edge's
    # endpoints become the forced ordering prefix (positions 0/1).
    if seed_edge is not None:
        if anchor is not None:
            raise ValueError("anchor= and seed_edge= are mutually exclusive")
        anchor = (seed_edge[0], seed_edge[1])

    # --- ordering ----------------------------------------------------------
    # RI ignores domains when ordering; RI-DS places singletons first (but its
    # greedy tie-break does not see domain sizes); SI adds the size tie-break.
    if anchor is not None:
        ordering = ord_mod.greatest_constraint_first(
            pattern,
            domain_sizes=dom_sizes if use_si else None,
            seed_order=tuple(anchor),
        )
    elif use_si:
        ordering = ord_mod.greatest_constraint_first(
            pattern, domain_sizes=dom_sizes, singleton_first=True
        )
    elif use_ds:
        # expose only singleton-ness, so placement matches RI-DS while the
        # greedy tie-break stays size-blind (all non-singletons look equal).
        flat = np.where(dom_sizes == 1, 1, 2).astype(np.int64)
        ordering = ord_mod.greatest_constraint_first(
            pattern, domain_sizes=flat, singleton_first=True
        )
    else:
        ordering = ord_mod.greatest_constraint_first(pattern)

    n_p = pattern.n
    p_pad = max(p_pad or n_p, n_p, 1)
    ppos, pdir, pelab, pcnt = ordering.parent_arrays(max_parents)
    mp = ppos.shape[1]

    order = np.full(p_pad, -1, dtype=np.int32)
    order[:n_p] = ordering.order
    parent_pos = np.full((p_pad, mp), -1, dtype=np.int32)
    parent_pos[:n_p] = ppos
    parent_dir = np.zeros((p_pad, mp), dtype=np.int32)
    parent_dir[:n_p] = pdir
    parent_elab = np.zeros((p_pad, mp), dtype=np.int32)
    parent_elab[:n_p] = pelab
    n_parents = np.zeros(p_pad, dtype=np.int32)
    n_parents[:n_p] = pcnt

    dom_pos = np.zeros((p_pad, w), dtype=np.uint32)
    dom_pos[:n_p] = dres.bits[ordering.order]

    return SearchPlan(
        variant=variant,
        n_p=n_p,
        p_pad=p_pad,
        n_t=n_t,
        w=w,
        order=order,
        parent_pos=parent_pos,
        parent_dir=parent_dir,
        parent_elab=parent_elab,
        n_parents=n_parents,
        dom_bits=dom_pos,
        adj_bits=adj_bits,
        satisfiable=dres.satisfiable,
        csr=csr,
        csr_factory=csr_factory,
        domains=dres,
        seed_edge=seed_edge,
    )
