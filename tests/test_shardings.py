"""Logical-axis sharding rules."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.shardings import logical_to_pspec, named_sharding, tree_shardings
from repro.launch.mesh import make_local_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh(("data", "model"))


def test_divisibility_fallback(mesh):
    # 1-device mesh: every axis product is 1 -> replicated
    spec = logical_to_pspec(("batch", "tensor"), (8, 16), mesh)
    assert spec == P(None, None)


def test_axis_mapping_shapes():
    """On a fake multi-axis mesh-shape dict, verify divisibility logic via a
    stub mesh object."""

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    spec = logical_to_pspec(("batch", None, "tensor"), (256, 7, 4096), FakeMesh())
    assert spec == P(("pod", "data"), None, "model")
    # not divisible by 32 -> replicated
    spec = logical_to_pspec(("batch",), (100,), FakeMesh())
    assert spec == P(None)
    # divisible by model=16
    spec = logical_to_pspec(("tensor",), (48,), FakeMesh())
    assert spec == P("model")
    # edge axis flattens three mesh axes when divisible by 512
    spec = logical_to_pspec(("edge",), (1024,), FakeMesh())
    assert spec == P(("pod", "data", "model"))
    # axis used once only
    spec = logical_to_pspec(("batch", "fsdp"), (32, 32), FakeMesh())
    assert spec[0] == ("pod", "data")
    assert spec[1] is None  # pod/data already consumed


def test_tree_shardings_structure(mesh):
    abstract = {"a": jax.ShapeDtypeStruct((4, 4), jnp.float32),
                "b": (jax.ShapeDtypeStruct((2,), jnp.int32),)}
    logical = {"a": ("batch", None), "b": ((None,),)}
    out = tree_shardings(logical, abstract, mesh)
    assert set(out.keys()) == {"a", "b"}
    assert out["a"].spec == P(None, None)  # 4 not divisible by ndev? 1-dev -> repl


def test_scalar_logical(mesh):
    s = named_sharding((), (), mesh)
    assert s.spec == P()
