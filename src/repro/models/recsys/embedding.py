"""Sparse embedding substrate: lookup + embedding-bag built from
``jnp.take`` + ``jax.ops.segment_sum`` (JAX has no native EmbeddingBag —
per the brief, this IS part of the system).

Tables are row-sharded over the mesh ``model`` axis (logical ``tensor``);
GSPMD turns the gathers into index-broadcast + partial-gather + psum.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constraint
from repro.models.common import ParamSpec


def table_spec(n_rows: int, dim: int, name: str = "table") -> ParamSpec:
    # rows over tensor (model) axis: the canonical row-wise table sharding
    return ParamSpec((n_rows, dim), ("tensor", None), jnp.float32, scale=0.01)


def lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Plain embedding lookup; ids any shape, output ids.shape + [dim]."""
    return jnp.take(table, jnp.maximum(ids, 0), axis=0)


def embedding_bag(
    table: jnp.ndarray,
    ids: jnp.ndarray,  # [n_ids] flat multi-hot indices
    bag_ids: jnp.ndarray,  # [n_ids] which bag each id belongs to
    n_bags: int,
    mode: str = "sum",
    weights: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """EmbeddingBag: ragged gather + segment reduce.

    ``ids < 0`` are padding and contribute nothing.
    """
    rows = lookup(table, ids)
    valid = (ids >= 0).astype(rows.dtype)[:, None]
    if weights is not None:
        valid = valid * weights[:, None]
    rows = rows * valid
    out = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(valid[:, 0], bag_ids, num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    elif mode != "sum":
        raise ValueError(mode)
    return constraint(out, ("batch", None))
