"""Deprecated multi-query driver — now a shim over `repro.core.session`.

The LPT pack balancing, plan stacking and vmapped engine execution that
lived here migrated into :class:`repro.core.session.Enumerator`
(``run_batch`` / ``stream``), which adds shape-bucketed compile caching on
top.  New code should use the session API::

    from repro.core.session import Enumerator, SubgraphIndex
    enum = Enumerator(SubgraphIndex.build(target), config=cfg)
    results = enum.run_batch([enum.prepare(p) for p in patterns])

:func:`enumerate_many` is kept with its original signature and now returns
**exactly one result per input pattern, in input order** (the old
implementation silently dropped unprocessed queries and lost name
alignment).  :func:`run_batch` over raw plans is kept for callers that
stack their own same-shaped plans.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.engine import EngineConfig
from repro.core.graph import Graph
from repro.core.plan import SearchPlan
from repro.core.session import Enumerator, SubgraphIndex


@dataclasses.dataclass
class QueryResult:
    name: str
    matches: int
    states: int
    steps: int


def _stack_plans(plans: Sequence[SearchPlan], cfg: EngineConfig):
    arrays = [eng.plan_arrays_for(cfg, p) for p in plans]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *arrays)


def run_batch(plans: Sequence[SearchPlan], cfg: EngineConfig):
    """Run a pack of same-shaped plans; returns stacked final EngineStates.

    Deprecated: prefer :meth:`Enumerator.run_batch`, which adds LPT
    balancing, bucket grouping and compile caching."""
    stacked = _stack_plans(plans, cfg)
    states = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[eng.init_state(p, cfg) for p in plans]
    )

    @jax.jit
    def go(plan_arrays, st):
        return jax.vmap(lambda pl, s: eng._engine_loop(cfg, pl, s))(plan_arrays, st)

    return jax.block_until_ready(go(stacked, states))


def enumerate_many(
    patterns: Sequence[Graph],
    target: Graph,
    variant: str = "ri-ds-si-fc",
    cfg: Optional[EngineConfig] = None,
    pack_size: int = 4,
    names: Optional[Sequence[str]] = None,
) -> List[QueryResult]:
    """Enumerate every pattern against ``target`` in LPT-balanced packs.

    Compatibility wrapper over :meth:`Enumerator.run_batch`; returns one
    :class:`QueryResult` per pattern, aligned with the input order."""
    cfg = cfg or EngineConfig(n_workers=8, expand_width=4)
    names = list(names or [f"q{i}" for i in range(len(patterns))])
    if len(names) != len(patterns):
        raise ValueError(
            f"names has {len(names)} entries for {len(patterns)} patterns"
        )
    session = Enumerator(SubgraphIndex.build(target), config=cfg, variant=variant)
    queries = [session.prepare(p, name=n) for p, n in zip(patterns, names)]
    results = session.run_batch(queries, pack_size=pack_size)
    return [
        QueryResult(name=ms.name, matches=ms.matches, states=ms.states, steps=ms.steps)
        for ms in results
    ]
