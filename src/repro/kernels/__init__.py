"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel ships three layers:
  <name>.py  — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling
  ops.py     — jit'd public wrappers (interpret-mode auto-detect)
  ref.py     — pure-jnp oracles; tests sweep shapes/dtypes and assert
               equality (bitwise kernels: exact; flash attention: rtol)

Kernels:
  extend_step      — the paper's hot loop, fully fused (DESIGN.md §6.3):
                     lowest-bit extraction + candidate AND-tree + match
                     flagging in one pallas_call (the engine's
                     step_backend="pallas")
  candidate_mask   — per-lane candidate bitmaps only, via
                     scalar-prefetch-indexed adjacency-row DMA + wide AND
                     (the step_backend="jnp" + use_pallas kerneling point)
  csr_extend       — the sparse expansion step (DESIGN.md §6.4): scalar-
                     prefetched CSR segment bounds, pl.ds neighbor loads,
                     sorted-intersection instead of the dense AND-tree
                     (the step_backend="csr" + use_pallas kerneling point)
  domain_ac        — RI-DS arc-consistency row filter (SDDMM-shaped)
  popcount_reduce  — per-row popcounts (domain sizes, match stats)
  flash_attention  — fused causal online-softmax attention (beyond-paper;
                     the pure-JAX blockwise form stays the default so XLA
                     cost analysis sees the FLOPs for §Roofline)
"""
