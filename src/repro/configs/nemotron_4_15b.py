"""nemotron-4-15b — 32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000,
squared-ReLU MLP.  [arXiv:2402.16819; unverified]"""

from repro.configs.lm_common import make_lm_arch
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="nemotron-4-15b",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    loss_chunk=65536,  # §Perf iter 2: fewer lm_head re-reads (was 2048)
    vocab_size=256000,
    activation="squared_relu",
    max_seq_len=32768,
)

SMOKE = LMConfig(
    name="nemotron-4-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    activation="squared_relu",
    max_seq_len=64,
    loss_chunk=16,
    kv_block=8,
)

ARCH = make_lm_arch(CFG, SMOKE, notes="Dense GQA + squared-ReLU; paper "
                    "technique N/A (regular load; DESIGN.md §4).")
