"""Jit'd public wrappers around the Pallas kernels.

``INTERPRET`` defaults to True on CPU (this container) so the kernels
execute their Python bodies for validation; on a TPU backend it flips to
False automatically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import candidate_mask as _cm
from repro.kernels import domain_ac as _ac
from repro.kernels import popcount_reduce as _pc
from repro.kernels import ref as kref

INTERPRET = jax.default_backend() != "tpu"


def candidate_mask(rows, dom_bits, pos, row_idx, used, interpret=None):
    """See `repro.kernels.candidate_mask.candidate_mask`."""
    it = INTERPRET if interpret is None else interpret
    return _cm.candidate_mask(rows, dom_bits, pos, row_idx, used, interpret=it)


def adjacency_any(rows, mask, interpret=None):
    """See `repro.kernels.domain_ac.adjacency_any`."""
    it = INTERPRET if interpret is None else interpret
    return _ac.adjacency_any(rows, mask, interpret=it)


def arc_any_sweep(adj_flat, arc_row, masks, interpret=None):
    """See `repro.kernels.domain_ac.arc_any_sweep`."""
    it = INTERPRET if interpret is None else interpret
    return _ac.arc_any_sweep(adj_flat, arc_row, masks, interpret=it)


def popcount_rows(bits, interpret=None):
    """See `repro.kernels.popcount_reduce.popcount_rows`."""
    it = INTERPRET if interpret is None else interpret
    return _pc.popcount_rows(bits, interpret=it)


flatten_adj_rows = _cm.flatten_adj_rows
flat_row_index = _cm.flat_row_index
pack_bits = kref.pack_bits_ref
