"""Pallas TPU kernels for RI-DS arc-consistency filtering (DESIGN.md §5).

One AC test for a single constraint arc ``(p, q, dir, label)`` asks, for
every target node ``t``, whether ``adj_rows[t] ∧ D(q)`` has any set bit —
a ``[n_t, w]`` bitmap AND against a broadcast ``[w]`` mask followed by a
per-row any-reduce.  This is the SDDMM-shaped part of domain preprocessing
(DESIGN.md §2): dense rows stream from HBM once, the mask stays resident in
VMEM.

Two granularities:

* :func:`adjacency_any` — one arc.  Grid over row tiles of ``tr`` rows;
  block ``(tr, w)`` of adjacency rows, mask block ``(1, w)`` pinned (same
  index every step), output ``(tr, 1)`` int32 flags.  ``w`` padded to
  128-word lanes, ``tr`` a multiple of 8 sublanes.  Composes with ``vmap``
  (plain BlockSpecs), which is what the batched domain engine uses.
* :func:`arc_any_sweep` — **all arcs of one AC sweep in a single
  ``pallas_call``**.  Grid ``(n_arcs, row tiles)``; the adjacency operand's
  ``index_map`` reads the scalar-prefetched ``arc_row`` table to pick which
  ``(label, dir)`` plane the pipeline DMAs next — the same
  pointer-chasing-by-prefetch trick as `candidate_mask`.  Used by the
  single-query device fixpoint (`repro.core.domains.device_fixpoint`); the
  scalar-prefetch grid spec has no vmap rule, so the batched path falls
  back to per-arc kernels.
* :func:`csr_arc_sweep` — the same sweep over **CSR planes** (DESIGN.md
  §11): no dense ``[n_planes, n_t, w]`` operand exists, so each grid step
  walks a row tile's neighbor segments with ``pl.ds`` dynamic slices of the
  flat ``indices`` block (the `csr_extend` load pattern) and any-reduces
  the mask bit tests per row.  The per-plane segment bounds arrive as
  ``(1, tr)`` operand blocks whose ``index_map`` chases the
  scalar-prefetched ``arc_row`` table.  Scalar-prefetch again means no
  vmap rule — batched CSR fixpoints use the jnp oracle
  (`repro.kernels.ref.csr_arc_sweep_ref`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.candidate_mask import pad_words
from repro.kernels.csr_extend import SENTINEL

ROW_TILE = 256


def _kernel(rows_ref, mask_ref, out_ref):
    hit = (rows_ref[...] & mask_ref[...]) != 0  # [tr, w] bool
    out_ref[...] = jnp.any(hit, axis=-1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile"))
def adjacency_any(
    rows: jnp.ndarray,  # [n_t, w] uint32
    mask: jnp.ndarray,  # [w] uint32
    interpret: bool = True,
    row_tile: int = ROW_TILE,
) -> jnp.ndarray:
    """Per-row any-bit test of ``rows ∧ mask`` -> ``[n_t]`` int32 {0,1}."""
    n_t, w = rows.shape
    wp = pad_words(w)
    tr = row_tile
    n_pad = ((n_t + tr - 1) // tr) * tr
    rows_p = jnp.pad(rows, ((0, n_pad - n_t), (0, wp - w)))
    mask_p = jnp.pad(mask, (0, wp - w))[None, :]

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // tr,),
        in_specs=[
            pl.BlockSpec((tr, wp), lambda i: (i, 0)),
            pl.BlockSpec((1, wp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
        interpret=interpret,
    )(rows_p, mask_p)
    return out[:n_t, 0]


def _sweep_kernel(arc_row_ref, adj_ref, mask_ref, out_ref):
    hit = (adj_ref[0] & mask_ref[...]) != 0  # [tr, w] & [1, w] -> [tr, w]
    out_ref[...] = jnp.any(hit, axis=-1)[None, :].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile"))
def arc_any_sweep(
    adj_flat: jnp.ndarray,  # [n_planes, n_t, w] uint32 (label-major planes)
    arc_row: jnp.ndarray,  # [n_arcs] int32 plane index per arc
    masks: jnp.ndarray,  # [n_arcs, w] uint32 (D(q) bitmap per arc)
    interpret: bool = True,
    row_tile: int = ROW_TILE,
) -> jnp.ndarray:
    """All arcs of one AC sweep in one kernel call.

    ``out[a, t] = any(adj_flat[arc_row[a], t] ∧ masks[a])`` — ``[n_arcs,
    n_t]`` int32 {0, 1}.  The adjacency plane per grid step is chosen by the
    scalar-prefetched ``arc_row`` table, so the DMA engine chases the arc
    table while the VPU reduces the previous tile.
    """
    n_arcs, w = masks.shape
    n_t = adj_flat.shape[1]
    wp = pad_words(w)
    tr = min(row_tile, max(8, ((n_t + 7) // 8) * 8))
    n_pad = ((n_t + tr - 1) // tr) * tr
    adj_p = jnp.pad(adj_flat, ((0, 0), (0, n_pad - n_t), (0, wp - w)))
    masks_p = jnp.pad(masks, ((0, 0), (0, wp - w)))

    def adj_map(a, i, arc_row_s):
        return (arc_row_s[a], i, 0)

    def mask_map(a, i, arc_row_s):
        return (a, 0)

    def out_map(a, i, arc_row_s):
        return (a, i)

    out = pl.pallas_call(
        _sweep_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_arcs, n_pad // tr),
            in_specs=[
                pl.BlockSpec((1, tr, wp), adj_map),
                pl.BlockSpec((1, wp), mask_map),
            ],
            out_specs=pl.BlockSpec((1, tr), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((n_arcs, n_pad), jnp.int32),
        interpret=interpret,
    )(arc_row.astype(jnp.int32), adj_p, masks_p)
    return out[:, :n_t]


def _csr_sweep_kernel(
    arc_row_ref, sst_ref, sln_ref, ind_ref, mask_ref, out_ref, *, deg_cap: int
):
    tr = out_ref.shape[1]
    wp = mask_ref.shape[1]
    offs = lax.iota(jnp.int32, deg_cap)
    row_iota = lax.iota(jnp.int32, tr)
    mask = mask_ref[0, :]  # [wp]

    def row(j, acc):
        s = sst_ref[0, j]
        ln = jnp.minimum(sln_ref[0, j], deg_cap)
        u = ind_ref[0, pl.ds(s, deg_cap)]  # [deg_cap]
        k_on = offs < ln
        u_c = jnp.clip(u, 0, wp * 32 - 1)
        word = u_c // 32
        bit = (u_c % 32).astype(jnp.uint32)
        in_dom = (jnp.take(mask, word) >> bit) & jnp.uint32(1)
        hit = jnp.any(k_on & (in_dom != 0))
        return jnp.where(row_iota == j, hit.astype(jnp.int32), acc)

    out_ref[...] = lax.fori_loop(0, tr, row, jnp.zeros((tr,), jnp.int32))[None, :]


@functools.partial(jax.jit, static_argnames=("deg_cap", "interpret", "row_tile"))
def csr_arc_sweep(
    seg_start: jnp.ndarray,  # [n_planes, n_t] int32 global offsets
    seg_len: jnp.ndarray,  # [n_planes, n_t] int32 row lengths
    indices: jnp.ndarray,  # [n_idx] int32 flat CSR columns (sentinel tail)
    arc_row: jnp.ndarray,  # [n_arcs] int32 plane index per arc
    masks: jnp.ndarray,  # [n_arcs, w] uint32 (D(q) bitmap per arc)
    deg_cap: int = 8,
    interpret: bool = True,
    row_tile: int = ROW_TILE,
) -> jnp.ndarray:
    """All arcs of one CSR AC sweep in one kernel call (DESIGN.md §11).

    ``out[a, t] = any(u in row(arc_row[a], t) : bit u set in masks[a])`` —
    ``[n_arcs, n_t]`` int32 {0, 1}, the sparse twin of `arc_any_sweep`.
    Grid ``(n_arcs, row tiles)``; the per-plane ``seg_start`` / ``seg_len``
    blocks are selected by the scalar-prefetched ``arc_row`` table, and
    each row's neighbor segment is a ``pl.ds`` slice of the flat VMEM
    ``indices`` block — dense adjacency bitmaps never exist.  ``indices``
    must be over-padded by ``deg_cap``
    (`repro.core.domains.csr_target_domain_arrays` guarantees it) so
    segment slices never clamp.  Oracle:
    `repro.kernels.ref.csr_arc_sweep_ref`.
    """
    n_arcs, w = masks.shape
    n_t = seg_start.shape[1]
    wp = pad_words(w)
    tr = min(row_tile, max(8, ((n_t + 7) // 8) * 8))
    n_pad = ((n_t + tr - 1) // tr) * tr
    sst_p = jnp.pad(seg_start, ((0, 0), (0, n_pad - n_t)))
    sln_p = jnp.pad(seg_len, ((0, 0), (0, n_pad - n_t)))  # pad rows: len 0
    masks_p = jnp.pad(masks, ((0, 0), (0, wp - w)))
    n_ind = indices.shape[0]
    n_ipad = pad_words(n_ind)
    if n_ipad != n_ind:
        indices = jnp.pad(indices, (0, n_ipad - n_ind), constant_values=SENTINEL)

    def seg_map(a, i, arc_row_s):
        return (arc_row_s[a], i)

    def ind_map(a, i, arc_row_s):
        return (0, 0)

    def mask_map(a, i, arc_row_s):
        return (a, 0)

    def out_map(a, i, arc_row_s):
        return (a, i)

    out = pl.pallas_call(
        functools.partial(_csr_sweep_kernel, deg_cap=deg_cap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_arcs, n_pad // tr),
            in_specs=[
                pl.BlockSpec((1, tr), seg_map),  # seg_start
                pl.BlockSpec((1, tr), seg_map),  # seg_len
                pl.BlockSpec((1, n_ipad), ind_map),  # flat CSR indices
                pl.BlockSpec((1, wp), mask_map),
            ],
            out_specs=pl.BlockSpec((1, tr), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((n_arcs, n_pad), jnp.int32),
        interpret=interpret,
    )(
        arc_row.astype(jnp.int32),
        sst_p.astype(jnp.int32),
        sln_p.astype(jnp.int32),
        indices.reshape(1, n_ipad),
        masks_p,
    )
    return out[:, :n_t]
