"""The expansion step — candidate bitmaps, lowest-untried-bit extraction,
child emission, match counting — behind the ``StepBackend`` seam
(DESIGN.md §6.2).

One expansion step, for every popped lane: extract the lowest untried
candidate bit ``v``, extend the mapping, build the child's candidate
bitmap ``dom[pos+1] ∧ ¬used' ∧ ⋀ adj_rows(mapped parents)`` (the paper's
check-consistency-before-spawning rule, §3.1), and flag matches at full
depth.  The work is *lane-flat*: the step function flattens all
``V·expand_width`` lanes of its worker shard into one batch, so a backend
sees a single dense batch regardless of worker count or mesh shard — and a
Pallas backend gets one big grid instead of ``V`` vmapped kernel calls.

Backends (selected by ``EngineConfig.step_backend``):

* ``"jnp"`` — :class:`JnpStepBackend`, the loose-ops reference: pure jnp
  phases with full HBM round-trips between them; with
  ``EngineConfig.use_pallas`` the candidate-bitmap AND routes through the
  `repro.kernels.candidate_mask` kernel (the pre-seam behavior, kept as
  the mask-only kerneling point of comparison).
* ``"pallas"`` — :class:`PallasStepBackend`, the fused
  `repro.kernels.extend_step` kernel: adjacency-row gathers
  (scalar-prefetched), the ``dom ∧ ¬used ∧ parents`` AND-tree, per-lane
  lowest-bit extraction and match flagging in **one** kernel invocation
  (DESIGN.md §6.3) — subsuming ``candidate_mask`` on the engine path.

Both backends are bit-identical on every :class:`StepLanes` field the
engine consumes (property-tested in ``tests/test_extend_step.py``); the
driver (`repro.core.engine`) never knows which one ran.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Protocol, Tuple, TYPE_CHECKING

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro.core import frontier
from repro.core.frontier import EngineState
from repro.core.graph import WORD_BITS
from repro.core.plan import SearchPlan

if TYPE_CHECKING:  # engine imports extend; annotations only
    from repro.core.engine import EngineConfig

STEP_BACKENDS = ("jnp", "pallas")


class PlanArrays(NamedTuple):
    """Device-resident static plan arrays (see SearchPlan)."""

    order_valid: jnp.ndarray  # [p_pad] bool (True for real positions)
    parent_pos: jnp.ndarray  # [p_pad, mp] int32
    parent_dir: jnp.ndarray  # [p_pad, mp]
    parent_elab: jnp.ndarray  # [p_pad, mp]
    dom_bits: jnp.ndarray  # [p_pad, w] uint32
    adj_bits: jnp.ndarray  # [n_elab, 2, n_t, w] uint32
    n_p: jnp.ndarray  # scalar int32 (actual pattern size)


def make_plan_arrays(plan: SearchPlan) -> PlanArrays:
    return PlanArrays(
        order_valid=jnp.asarray(plan.order >= 0),
        parent_pos=jnp.asarray(plan.parent_pos, jnp.int32),
        parent_dir=jnp.asarray(plan.parent_dir, jnp.int32),
        parent_elab=jnp.asarray(plan.parent_elab, jnp.int32),
        dom_bits=jnp.asarray(plan.dom_bits, jnp.uint32),
        adj_bits=jnp.asarray(plan.adj_bits, jnp.uint32),
        n_p=jnp.asarray(plan.n_p, jnp.int32),
    )


def abstract_plan_arrays(
    n_t: int, w: int, p_pad: int, max_parents: int, n_elab: int = 1
) -> PlanArrays:
    sds = jax.ShapeDtypeStruct
    return PlanArrays(
        order_valid=sds((p_pad,), jnp.bool_),
        parent_pos=sds((p_pad, max_parents), jnp.int32),
        parent_dir=sds((p_pad, max_parents), jnp.int32),
        parent_elab=sds((p_pad, max_parents), jnp.int32),
        dom_bits=sds((p_pad, w), jnp.uint32),
        adj_bits=sds((n_elab, 2, n_t, w), jnp.uint32),
        n_p=sds((), jnp.int32),
    )


PLAN_LOGICAL = PlanArrays(
    order_valid=(None,),
    parent_pos=(None, None),
    parent_dir=(None, None),
    parent_elab=(None, None),
    dom_bits=(None, "tensor"),
    adj_bits=(None, None, None, "tensor"),
    n_p=(),
)


def plan_partition_specs() -> PlanArrays:
    """PartitionSpecs for :class:`PlanArrays`: fully replicated (every
    device needs the whole domain/adjacency bitmaps to expand its workers)."""
    P = PartitionSpec
    return PlanArrays(
        order_valid=P(None),
        parent_pos=P(None, None),
        parent_dir=P(None, None),
        parent_elab=P(None, None),
        dom_bits=P(None, None),
        adj_bits=P(None, None, None, None),
        n_p=P(),
    )


# ---------------------------------------------------------------------------
# bit helpers
# ---------------------------------------------------------------------------

def pop_lowest_bit(cand: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Extract the lowest set bit of a ``[W]`` uint32 bitmap.

    Returns ``(valid, v, cand_without_v)``; ``v`` is the global bit index.
    """
    nz = cand != 0
    valid = jnp.any(nz)
    widx = jnp.argmax(nz)  # first non-zero word (0 if none)
    word = cand[widx]
    # trailing zeros = popcount(~w & (w - 1)); word==0 guarded by `valid`.
    tz = lax.population_count(~word & (word - jnp.uint32(1)))
    v = widx.astype(jnp.int32) * WORD_BITS + tz.astype(jnp.int32)
    cand2 = cand.at[widx].set(word & (word - jnp.uint32(1)))
    return valid, v, cand2


def bit_row(v: jnp.ndarray, w: int) -> jnp.ndarray:
    """One-hot ``[w]`` uint32 bitmap with bit ``v`` set."""
    word = v // WORD_BITS
    bit = jnp.uint32(1) << (v % WORD_BITS).astype(jnp.uint32)
    return jnp.zeros((w,), jnp.uint32).at[word].set(bit)


def compute_cand_jnp(
    plan: PlanArrays, pos: jnp.ndarray, map_: jnp.ndarray, used: jnp.ndarray
) -> jnp.ndarray:
    """Candidate bitmap for order position ``pos`` given mapping/used.

    ``dom[pos] ∧ ¬used ∧ ⋀_parents adj_bits[elab, dir, mapped_parent]`` —
    the engine's hot loop; `repro.kernels.extend_step` is the fused Pallas
    form and `repro.kernels.candidate_mask` the mask-only one.
    """
    mp = plan.parent_pos.shape[1]
    safe_pos = jnp.clip(pos, 0, plan.dom_bits.shape[0] - 1)
    cand = plan.dom_bits[safe_pos] & ~used

    def body(j, c):
        pp = plan.parent_pos[safe_pos, j]
        pd = plan.parent_dir[safe_pos, j]
        pl = plan.parent_elab[safe_pos, j]
        t = jnp.where(pp >= 0, map_[jnp.maximum(pp, 0)], 0)
        row = plan.adj_bits[pl, pd, jnp.clip(t, 0, plan.adj_bits.shape[2] - 1)]
        return jnp.where(pp >= 0, c & row, c)

    return lax.fori_loop(0, mp, body, cand)


# ---------------------------------------------------------------------------
# the StepBackend seam
# ---------------------------------------------------------------------------

class StepLanes(NamedTuple):
    """Everything one expansion produces per flattened lane ``[B = V·E]``.

    ``v`` is informational (-1 or unspecified on invalid lanes; every
    consumer gates on ``valid``); the stack payloads are ``cand2`` (the
    parent's residual candidates), ``(map2, used2, child_cand)`` (the
    child entry), and the ``is_match`` / ``has_child`` flags the driver
    accumulates.
    """

    valid: jnp.ndarray  # [B] bool — lane had an untried candidate
    v: jnp.ndarray  # [B] int32 — extracted target node
    is_match: jnp.ndarray  # [B] bool — extension completed the pattern
    has_child: jnp.ndarray  # [B] bool — child has a non-empty candidate set
    cand2: jnp.ndarray  # [B, W] uint32 — parent candidates minus v
    map2: jnp.ndarray  # [B, P] int32 — mapping extended with v
    used2: jnp.ndarray  # [B, W] uint32 — used-bitmap with v set
    child_cand: jnp.ndarray  # [B, W] uint32 — zeroed unless a child is wanted


class StepBackend(Protocol):
    """One expansion over a flat batch of popped lanes (DESIGN.md §6.2).

    Implementations must be bit-identical on every field of
    :class:`StepLanes` that the engine consumes (all but ``v`` on invalid
    lanes); ``tests/test_extend_step.py`` property-tests this.
    """

    name: str

    def expand_lanes(
        self,
        depth: jnp.ndarray,  # [B] int32 (0 on off lanes)
        map_: jnp.ndarray,  # [B, P] int32
        used: jnp.ndarray,  # [B, W] uint32
        cand: jnp.ndarray,  # [B, W] uint32 (0 on off lanes)
    ) -> StepLanes:
        ...


class JnpStepBackend:
    """Reference backend: the loose-ops jnp step (optionally routing the
    candidate-bitmap AND through the ``candidate_mask`` kernel when
    ``cfg.use_pallas`` — the pre-seam kerneling point)."""

    name = "jnp"

    def __init__(self, cfg: "EngineConfig", plan: PlanArrays):
        self.plan = plan
        self.p_pad, self.w = plan.dom_bits.shape
        if cfg.use_pallas:
            from repro.kernels import ops as kops

            rows = kops.flatten_adj_rows(plan.adj_bits)
            n_rows = rows.shape[0] - 1
            n_t = plan.adj_bits.shape[2]
            p_max = self.p_pad - 1

            def compute_cand(pos, map2, used2):
                safe_pos = jnp.clip(pos, 0, p_max)
                row_idx = jax.vmap(
                    lambda p, m: kops.flat_row_index(
                        plan.parent_pos[p], plan.parent_dir[p], plan.parent_elab[p],
                        m, n_t, n_rows,
                    )
                )(safe_pos, map2)
                return kops.candidate_mask(rows, plan.dom_bits, safe_pos, row_idx, used2)
        else:
            compute_one = functools.partial(compute_cand_jnp, plan)

            def compute_cand(pos, map2, used2):
                return jax.vmap(compute_one)(pos, map2, used2)

        self._compute_cand = compute_cand

    def expand_lanes(self, depth, map_, used, cand) -> StepLanes:
        plan = self.plan
        b = depth.shape[0]
        valid, v, cand2 = jax.vmap(pop_lowest_bit)(cand)
        map2 = jnp.where(
            valid[:, None],
            map_.at[jnp.arange(b), jnp.clip(depth, 0, self.p_pad - 1)].set(v),
            map_,
        )
        used2 = jnp.where(
            valid[:, None], used | jax.vmap(bit_row, (0, None))(v, self.w), used
        )
        is_match = valid & (depth + 1 >= plan.n_p)
        want_child = valid & ~is_match
        child_cand = self._compute_cand(jnp.where(want_child, depth + 1, 0), map2, used2)
        child_cand = jnp.where(want_child[:, None], child_cand, jnp.uint32(0))
        has_child = want_child & jnp.any(child_cand != 0, axis=-1)
        return StepLanes(valid, v, is_match, has_child, cand2, map2, used2, child_cand)


class PallasStepBackend:
    """The fused step: one `repro.kernels.extend_step` invocation per
    expansion (DESIGN.md §6.3).

    jnp's only jobs here are scalar bookkeeping the scalar-prefetch
    machinery requires up front — the extracted ``v`` feeds the flattened
    adjacency-row table the kernel's DMA pipeline chases — and the cheap
    ``map2`` / ``used2`` payload updates.  All ``w``-wide work (extraction,
    the AND-tree, child zeroing, match/child flagging) happens inside the
    kernel without intermediate HBM round-trips.
    """

    name = "pallas"

    def __init__(self, cfg: "EngineConfig", plan: PlanArrays):
        from repro.kernels import ops as kops

        self._kops = kops
        self.plan = plan
        self.p_pad, self.w = plan.dom_bits.shape
        self.rows = kops.flatten_adj_rows(plan.adj_bits)
        self.n_rows = self.rows.shape[0] - 1
        self.n_t = plan.adj_bits.shape[2]

    def expand_lanes(self, depth, map_, used, cand) -> StepLanes:
        plan, kops = self.plan, self._kops
        b = depth.shape[0]
        valid_j, v_j, _ = jax.vmap(pop_lowest_bit)(cand)
        map2 = jnp.where(
            valid_j[:, None],
            map_.at[jnp.arange(b), jnp.clip(depth, 0, self.p_pad - 1)].set(v_j),
            map_,
        )
        used2 = jnp.where(
            valid_j[:, None], used | jax.vmap(bit_row, (0, None))(v_j, self.w), used
        )
        child_pos = jnp.clip(depth + 1, 0, self.p_pad - 1)
        row_idx = jax.vmap(
            lambda p, m: kops.flat_row_index(
                plan.parent_pos[p], plan.parent_dir[p], plan.parent_elab[p],
                m, self.n_t, self.n_rows,
            )
        )(child_pos, map2)
        cand2, child_cand, meta = kops.extend_step(
            self.rows, plan.dom_bits, child_pos, row_idx, depth, plan.n_p,
            used, cand,
        )
        valid = meta[:, 0] != 0
        return StepLanes(
            valid=valid,
            v=meta[:, 1],
            is_match=meta[:, 2] != 0,
            has_child=meta[:, 3] != 0,
            cand2=cand2,
            map2=map2,
            used2=used2,
            child_cand=child_cand,
        )


def make_step_backend(cfg: "EngineConfig", plan: PlanArrays) -> StepBackend:
    if cfg.step_backend == "jnp":
        return JnpStepBackend(cfg, plan)
    if cfg.step_backend == "pallas":
        return PallasStepBackend(cfg, plan)
    raise ValueError(
        f"unknown step_backend {cfg.step_backend!r}; expected one of {STEP_BACKENDS}"
    )


# ---------------------------------------------------------------------------
# the shared expansion step (frontier pop -> backend -> counters -> push)
# ---------------------------------------------------------------------------

def make_step_fn(cfg: "EngineConfig", plan: PlanArrays):
    """Build one full expansion step ``EngineState -> EngineState`` over
    whatever worker axis the caller holds (all ``V`` workers single-device,
    or the local ``V / D`` shard under ``shard_map``) — the one step both
    engine paths share (DESIGN.md §6)."""
    backend = make_step_backend(cfg, plan)
    e = cfg.expand_width

    def step(st: EngineState) -> EngineState:
        v_loc, s_cap = st.st_depth.shape
        pop = frontier.pop_top_k(
            st.st_depth, st.st_map, st.st_used, st.st_cand,
            st.base, st.size, e, store_used=cfg.store_used,
        )

        b = v_loc * e
        lanes = backend.expand_lanes(
            pop.depth.reshape(b),
            pop.map.reshape(b, -1),
            pop.used.reshape(b, -1),
            pop.cand.reshape(b, -1),
        )
        sh2 = lambda x: x.reshape(v_loc, e)  # noqa: E731
        sh3 = lambda x: x.reshape((v_loc, e) + x.shape[1:])  # noqa: E731
        valid = sh2(lanes.valid) & pop.lane_on
        is_match = sh2(lanes.is_match) & pop.lane_on
        has_child = sh2(lanes.has_child) & pop.lane_on
        cand2 = sh3(lanes.cand2)
        map2 = sh3(lanes.map2)
        used2 = sh3(lanes.used2)
        child_cand = sh3(lanes.child_cand)

        states = st.states + jnp.sum(valid, axis=1, dtype=jnp.int32)
        exp_depth = st.exp_depth + jnp.sum(
            jnp.where(valid, pop.depth, 0), axis=1, dtype=jnp.int32
        )
        matches = st.matches + jnp.sum(is_match, axis=1, dtype=jnp.int32)

        mbuf = st.match_buf
        if cfg.collect_matches > 0:
            mcap = mbuf.shape[1]
            # per-lane match ordinal within this step, on top of the
            # pre-step per-worker match count
            m_prefix = jnp.cumsum(is_match.astype(jnp.int32), axis=1) - is_match
            m_slot = (st.matches[:, None] + m_prefix) % mcap
            m_slot = jnp.where(is_match, m_slot, mcap)  # drop non-matches
            vidx = jnp.arange(v_loc, dtype=jnp.int32)[:, None]
            mbuf = mbuf.at[vidx, m_slot].set(map2, mode="drop")

        parent_keep = pop.lane_on & jnp.any(cand2 != 0, axis=-1)
        st_depth, st_map, st_used, st_cand, new_size = frontier.push_entries(
            st.st_depth, st.st_map, st.st_used, st.st_cand, st.base, st.size,
            pop.k, parent_keep, has_child,
            pop.depth, pop.map, pop.used, cand2,
            pop.depth + 1, map2, used2, child_cand,
            store_used=cfg.store_used,
        )
        overflow = st.overflow | frontier.overflowed(new_size, s_cap)
        return st._replace(
            st_depth=st_depth, st_map=st_map, st_used=st_used, st_cand=st_cand,
            size=new_size, matches=matches, states=states,
            exp_depth=exp_depth, match_buf=mbuf, overflow=overflow,
        )

    return step
