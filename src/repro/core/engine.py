"""Frontier-vectorized parallel RI/RI-DS search engine.

This is the TPU-native form of the paper's work-stealing DFS (DESIGN.md §2):

* Each of ``V`` workers owns a **ring-buffer stack** of search-tree entries in
  dense SoA arrays.  An entry is ``(depth, mapping, used-bitmap,
  candidate-bitmap)`` — the candidate bitmap coalesces *all* untried siblings
  of one tree node (the paper's task-coalescing taken to its limit; a task
  ``(μ_i, v_t)`` is one bit).
* Every step, each worker pops its top ``expand_width`` entries, extracts the
  lowest untried candidate bit per entry, pushes back surviving parents below
  the freshly created children (depth-first order preserved per worker), and
  counts matches at full depth.  Candidate bitmaps for children are
  ``domain ∧ ¬used ∧ (adjacency rows of mapped parents)`` — the paper's
  "check consistency before spawning" (§3.1), so every stacked task is
  consistent.
* Every ``rebalance_interval`` steps, workers run a steal round
  (`repro.core.scheduler`): bottom-of-stack entries (near-root ⇒ big
  subtrees) from loaded workers move to starving ones.
* Termination: the global entry count hits zero — the all-reduce analogue of
  the paper's ring-token detection.

Everything is static-shape jnp inside ``lax.while_loop``.  Two execution
paths share the expansion step (DESIGN.md §2.4):

* **single device** (``run(plan, cfg)``): all ``V`` workers live in one
  array program; the steal round is plain gathers/scatters over the ``V``
  axis.
* **mesh-sharded** (``run(plan, cfg, mesh=...)``): the ``V`` axis is
  sharded over the mesh ``data`` axis via ``shard_map`` — each device owns
  ``V / D`` worker stacks.  A steal round all-gathers the stack-occupancy
  vector and each donor's bottom ``steal_chunk`` entries (``lax.all_gather``
  over ``data``), every device computes the *same* global steal plan
  (`repro.core.scheduler.plan_steals`), and scatters only the entries bound
  for its local receivers.  Termination is a cross-device ``lax.psum`` of
  the total entry count — the collective form of the paper's ring-token
  detection.  With ``D == 1`` (or ``mesh=None``) the collectives are
  identities and results are bit-identical to the single-device path.

Counters (matches / states / steals / depth sums) are **per-worker int32**:
on a mesh each device accumulates only its own workers' counts, so the
per-device bound is 2^31 per *worker*, not per collection — single-instance
state counts in our collections are far below that, and the multi-query
driver sums per-instance results in int64 on host.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.core import scheduler
from repro.core.graph import WORD_BITS, bitmap_from_indices
from repro.core.plan import SearchPlan


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine parameters.

    Attributes:
      n_workers: number of (virtual) workers ``V``.  On a mesh, ``V`` is
        sharded over the ``data`` axis; on one device all ``V`` run vectorized
        (used by the CPU benchmarks to reproduce the paper's worker sweeps).
      expand_width: entries expanded per worker per step (SIMD lane count).
      steal_chunk: entries a donor offers per steal round — the paper's task
        group size (Fig. 4: 4 is best).
      keep_min: donors never drop below this size.
      recv_cap: max entries a receiver accepts per round.
      rebalance_interval: steps between steal rounds.
      work_stealing: disable to reproduce the paper's Fig. 3 ablation.
      stack_cap: ring-buffer capacity per worker; 0 = auto
        (``expand_width * (p_pad + 2) + steal_chunk + 8``).
      max_steps: safety bound on outer loop iterations (0 = 2**30).
      collect_matches: if > 0, materialize up to this many mappings per worker
        into a ring buffer (the paper's tools print matches; counting is the
        benchmarked mode).
      use_pallas: route candidate-bitmap computation through the Pallas
        kernel (`repro.kernels.ops.candidate_mask`) instead of pure jnp.
      store_used: keep per-entry used-bitmaps on the stack (True) or
        recompute them from the mapping at expansion time (False).  §Perf
        iteration 7: the used-bitmap duplicates information already in the
        mapping; dropping it removes one of the two W-wide stack arrays
        (≈1/3 of stack scatter/steal traffic) at the cost of p_pad fused
        VPU ops per expanded lane.
    """

    n_workers: int = 1
    expand_width: int = 8
    steal_chunk: int = 4
    keep_min: int = 2
    recv_cap: int = 4
    rebalance_interval: int = 8
    work_stealing: bool = True
    stack_cap: int = 0
    max_steps: int = 0
    collect_matches: int = 0
    use_pallas: bool = False
    store_used: bool = True

    def resolved_stack_cap(self, p_pad: int) -> int:
        if self.stack_cap:
            return self.stack_cap
        return self.expand_width * (p_pad + 2) + self.steal_chunk + 8


class PlanArrays(NamedTuple):
    """Device-resident static plan arrays (see SearchPlan)."""

    order_valid: jnp.ndarray  # [p_pad] bool (True for real positions)
    parent_pos: jnp.ndarray  # [p_pad, mp] int32
    parent_dir: jnp.ndarray  # [p_pad, mp]
    parent_elab: jnp.ndarray  # [p_pad, mp]
    dom_bits: jnp.ndarray  # [p_pad, w] uint32
    adj_bits: jnp.ndarray  # [n_elab, 2, n_t, w] uint32
    n_p: jnp.ndarray  # scalar int32 (actual pattern size)


class EngineState(NamedTuple):
    st_depth: jnp.ndarray  # [V, S] int32
    st_map: jnp.ndarray  # [V, S, P] int32
    st_used: jnp.ndarray  # [V, S, W] uint32
    st_cand: jnp.ndarray  # [V, S, W] uint32
    base: jnp.ndarray  # [V] int32 ring-buffer base
    size: jnp.ndarray  # [V] int32
    matches: jnp.ndarray  # [V] int32
    states: jnp.ndarray  # [V] int32
    exp_depth: jnp.ndarray  # [V] int32 summed depth of expanded entries
    steals: jnp.ndarray  # [V] int32 entries received
    steal_depth: jnp.ndarray  # [V] int32 summed depth of stolen entries
    steal_rounds: jnp.ndarray  # [] int32 rounds with any transfer
    steps: jnp.ndarray  # [] int32
    overflow: jnp.ndarray  # [] bool — stack high-watermark breached
    match_buf: jnp.ndarray  # [V, Mcap, P] int32 (Mcap >= 1)


class EngineResult(NamedTuple):
    matches: int
    states: int
    steps: int
    steals: int
    steal_rounds: int
    mean_steal_depth: float
    mean_expand_depth: float
    per_worker_states: np.ndarray
    per_worker_matches: np.ndarray
    overflow: bool
    match_buf: Optional[np.ndarray]
    per_worker_steals: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# bit helpers
# ---------------------------------------------------------------------------

def _pop_lowest_bit(cand: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Extract the lowest set bit of a ``[W]`` uint32 bitmap.

    Returns ``(valid, v, cand_without_v)``; ``v`` is the global bit index.
    """
    nz = cand != 0
    valid = jnp.any(nz)
    widx = jnp.argmax(nz)  # first non-zero word (0 if none)
    word = cand[widx]
    # trailing zeros = popcount(~w & (w - 1)); word==0 guarded by `valid`.
    tz = lax.population_count(~word & (word - jnp.uint32(1)))
    v = widx.astype(jnp.int32) * WORD_BITS + tz.astype(jnp.int32)
    cand2 = cand.at[widx].set(word & (word - jnp.uint32(1)))
    return valid, v, cand2


def _bit_row(v: jnp.ndarray, w: int) -> jnp.ndarray:
    """One-hot ``[w]`` uint32 bitmap with bit ``v`` set."""
    word = v // WORD_BITS
    bit = jnp.uint32(1) << (v % WORD_BITS).astype(jnp.uint32)
    return jnp.zeros((w,), jnp.uint32).at[word].set(bit)


def _used_from_map(map_: jnp.ndarray, depth: jnp.ndarray, w: int) -> jnp.ndarray:
    """Reconstruct the used-bitmap from mapped targets at positions < depth
    (store_used=False path)."""
    p_pad = map_.shape[0]

    def body(j, u):
        valid = (j < depth) & (map_[j] >= 0)
        t = jnp.maximum(map_[j], 0)
        word = t // WORD_BITS
        bit = jnp.where(valid, jnp.uint32(1) << (t % WORD_BITS).astype(jnp.uint32),
                        jnp.uint32(0))
        return u.at[word].set(u[word] | bit)

    return lax.fori_loop(0, p_pad, body, jnp.zeros((w,), jnp.uint32))


def _compute_cand_jnp(
    plan: PlanArrays, pos: jnp.ndarray, map_: jnp.ndarray, used: jnp.ndarray
) -> jnp.ndarray:
    """Candidate bitmap for order position ``pos`` given mapping/used.

    ``dom[pos] ∧ ¬used ∧ ⋀_parents adj_bits[elab, dir, mapped_parent]`` —
    the engine's hot loop; `repro.kernels.candidate_mask` is the Pallas form.
    """
    mp = plan.parent_pos.shape[1]
    safe_pos = jnp.clip(pos, 0, plan.dom_bits.shape[0] - 1)
    cand = plan.dom_bits[safe_pos] & ~used

    def body(j, c):
        pp = plan.parent_pos[safe_pos, j]
        pd = plan.parent_dir[safe_pos, j]
        pl = plan.parent_elab[safe_pos, j]
        t = jnp.where(pp >= 0, map_[jnp.maximum(pp, 0)], 0)
        row = plan.adj_bits[pl, pd, jnp.clip(t, 0, plan.adj_bits.shape[2] - 1)]
        return jnp.where(pp >= 0, c & row, c)

    return lax.fori_loop(0, mp, body, cand)


# ---------------------------------------------------------------------------
# per-worker expansion step (vmapped over the worker axis)
# ---------------------------------------------------------------------------

def _worker_step(cfg: EngineConfig, plan: PlanArrays, compute_cand, carry):
    (st_depth, st_map, st_used, st_cand, base, size, matches, states, exp_depth, mbuf) = carry
    s_cap = st_depth.shape[0]
    p_pad = st_map.shape[1]
    w = st_cand.shape[1]
    e = cfg.expand_width

    # ---- select top-of-stack lanes (respecting the capacity guard) --------
    space = s_cap - size
    k = jnp.minimum(jnp.minimum(size, e), space).astype(jnp.int32)
    lane = jnp.arange(e, dtype=jnp.int32)
    lane_on = lane < k
    pos = size - 1 - lane  # top-first
    slot = jnp.where(lane_on, (base + pos) % s_cap, 0)

    depth = jnp.where(lane_on, st_depth[slot], 0)
    cand = jnp.where(lane_on[:, None], st_cand[slot], jnp.uint32(0))
    map_ = st_map[slot]
    if cfg.store_used:
        used = st_used[slot]
    else:
        used = jax.vmap(lambda m, dd: _used_from_map(m, dd, w))(map_, depth)

    # ---- extract one candidate per lane ------------------------------------
    valid, v, cand2 = jax.vmap(_pop_lowest_bit)(cand)
    valid = valid & lane_on
    states = states + jnp.sum(valid, dtype=jnp.int32)
    exp_depth = exp_depth + jnp.sum(jnp.where(valid, depth, 0), dtype=jnp.int32)

    # ---- build children -----------------------------------------------------
    map2 = jnp.where(
        valid[:, None],
        map_.at[jnp.arange(e), jnp.clip(depth, 0, p_pad - 1)].set(v),
        map_,
    )
    used2 = jnp.where(valid[:, None], used | jax.vmap(_bit_row, (0, None))(v, w), used)
    is_match = valid & (depth + 1 >= plan.n_p)
    matches = matches + jnp.sum(is_match, dtype=jnp.int32)

    want_child = valid & ~is_match
    child_cand = compute_cand(
        jnp.where(want_child, depth + 1, 0), map2, used2
    )
    child_cand = jnp.where(want_child[:, None], child_cand, jnp.uint32(0))
    has_child = want_child & jnp.any(child_cand != 0, axis=-1)

    # ---- match ring buffer ---------------------------------------------------
    if cfg.collect_matches > 0:
        mcap = mbuf.shape[0]
        # per-lane match ordinal within this step
        m_prefix = jnp.cumsum(is_match.astype(jnp.int32)) - is_match
        m_slot = (matches - jnp.sum(is_match, dtype=jnp.int32) + m_prefix) % mcap
        m_slot = jnp.where(is_match, m_slot, mcap)  # drop non-matches
        mbuf = mbuf.at[m_slot].set(map2, mode="drop")

    # ---- push back: parents (below) then children (above), lane k-1 .. 0 ----
    parent_keep = lane_on & jnp.any(cand2 != 0, axis=-1)
    # reversed-lane order: lane k-1 emitted first (deepest lane 0 ends on top)
    rev = e - 1 - lane
    pk_r = parent_keep[rev]
    hc_r = has_child[rev]
    per_lane = pk_r.astype(jnp.int32) + hc_r.astype(jnp.int32)
    offs = jnp.cumsum(per_lane) - per_lane  # position of lane rev[i]'s first push
    parent_out = jnp.where(pk_r, offs, -1)
    child_out = jnp.where(hc_r, offs + pk_r.astype(jnp.int32), -1)
    # map back to lane order
    inv = rev  # reversal is its own inverse
    parent_out = parent_out[inv]
    child_out = child_out[inv]
    total_push = jnp.sum(per_lane)

    new_size = size - k + total_push
    push_base = size - k  # logical position of first pushed entry

    def slots_for(out_pos):
        return jnp.where(out_pos >= 0, (base + push_base + out_pos) % s_cap, s_cap)

    p_slots = slots_for(parent_out)
    c_slots = slots_for(child_out)

    st_depth = st_depth.at[p_slots].set(depth, mode="drop")
    st_map = st_map.at[p_slots].set(map_, mode="drop")
    st_cand = st_cand.at[p_slots].set(cand2, mode="drop")

    st_depth = st_depth.at[c_slots].set(depth + 1, mode="drop")
    st_map = st_map.at[c_slots].set(map2, mode="drop")
    st_cand = st_cand.at[c_slots].set(child_cand, mode="drop")

    if cfg.store_used:
        st_used = st_used.at[p_slots].set(used, mode="drop")
        st_used = st_used.at[c_slots].set(used2, mode="drop")

    return (st_depth, st_map, st_used, st_cand, base, new_size, matches, states, exp_depth, mbuf)


# ---------------------------------------------------------------------------
# steal round (cross-worker, pure array ops over the V axis)
# ---------------------------------------------------------------------------

def _steal_round(cfg: EngineConfig, state: EngineState) -> EngineState:
    policy = scheduler.StealPolicy(
        steal_chunk=cfg.steal_chunk, keep_min=cfg.keep_min, recv_cap=cfg.recv_cap
    )
    v_workers, s_cap = state.st_depth.shape
    c = cfg.steal_chunk

    donate, accepted, dest_rank, dest_pos = scheduler.plan_steals(state.size, policy)
    wor = scheduler.receiver_workers(state.size)  # [V] worker per rank

    any_transfer = jnp.sum(accepted) > 0

    # gather donated rows from stack bottoms: donor d slot j = logical pos j
    slot_j = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (v_workers, c))
    src_slot = (state.base[:, None] + slot_j) % s_cap  # [V, C]
    didx = jnp.arange(v_workers, dtype=jnp.int32)[:, None]
    don_depth = state.st_depth[didx, src_slot]  # [V, C]
    don_map = state.st_map[didx, src_slot]
    don_used = state.st_used[didx, src_slot]
    don_cand = state.st_cand[didx, src_slot]

    taken = slot_j < accepted[:, None]  # [V, C]
    dest_w = jnp.where(taken, wor[jnp.clip(dest_rank, 0, v_workers - 1)], -1)
    # receivers are empty (size==0) so intake slot = (base + pos) % S
    recv_base = jnp.where(dest_w >= 0, state.base[jnp.maximum(dest_w, 0)], 0)
    dst_slot = (recv_base + dest_pos) % s_cap
    dw = jnp.where(dest_w >= 0, dest_w, v_workers)  # drop invalid

    st_depth = state.st_depth.at[dw, dst_slot].set(don_depth, mode="drop")
    st_map = state.st_map.at[dw, dst_slot].set(don_map, mode="drop")
    st_used = state.st_used.at[dw, dst_slot].set(don_used, mode="drop")
    st_cand = state.st_cand.at[dw, dst_slot].set(don_cand, mode="drop")

    # intake counts / steal metrics per receiver
    flat_w = dw.reshape(-1)
    ones = jnp.where(dest_w.reshape(-1) >= 0, 1, 0)
    recv_cnt = jnp.zeros((v_workers,), jnp.int32).at[flat_w].add(ones, mode="drop")
    depth_add = jnp.zeros((v_workers,), jnp.int32).at[flat_w].add(
        jnp.where(dest_w.reshape(-1) >= 0, don_depth.reshape(-1), 0), mode="drop"
    )

    # donors advance base (accepted slots were their bottom prefix)
    new_base = (state.base + accepted) % s_cap
    new_size = state.size - accepted + recv_cnt

    return state._replace(
        st_depth=st_depth,
        st_map=st_map,
        st_used=st_used,
        st_cand=st_cand,
        base=new_base,
        size=new_size,
        steals=state.steals + recv_cnt,
        steal_depth=state.steal_depth + depth_add,
        steal_rounds=state.steal_rounds + any_transfer.astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def make_plan_arrays(plan: SearchPlan) -> PlanArrays:
    return PlanArrays(
        order_valid=jnp.asarray(plan.order >= 0),
        parent_pos=jnp.asarray(plan.parent_pos, jnp.int32),
        parent_dir=jnp.asarray(plan.parent_dir, jnp.int32),
        parent_elab=jnp.asarray(plan.parent_elab, jnp.int32),
        dom_bits=jnp.asarray(plan.dom_bits, jnp.uint32),
        adj_bits=jnp.asarray(plan.adj_bits, jnp.uint32),
        n_p=jnp.asarray(plan.n_p, jnp.int32),
    )


def init_state(plan: SearchPlan, cfg: EngineConfig) -> EngineState:
    """Initial work distribution (paper §3.3): depth-0 candidates are split
    into equal contiguous target-node ranges, one root entry per worker."""
    v = cfg.n_workers
    p_pad, w = plan.p_pad, plan.w
    s_cap = cfg.resolved_stack_cap(p_pad)
    mcap = max(1, cfg.collect_matches)

    splits = np.linspace(0, plan.n_t, v + 1).astype(np.int64)
    root_cands = np.zeros((v, w), dtype=np.uint32)
    for k in range(v):
        idx = np.arange(splits[k], splits[k + 1])
        if idx.size:
            root_cands[k] = bitmap_from_indices(idx, plan.n_t, w) & plan.dom_bits[0]
    if not plan.satisfiable:
        root_cands[:] = 0

    st_depth = np.zeros((v, s_cap), dtype=np.int32)
    st_map = np.full((v, s_cap, p_pad), -1, dtype=np.int32)
    st_used = np.zeros((v, s_cap, w if cfg.store_used else 1), dtype=np.uint32)
    st_cand = np.zeros((v, s_cap, w), dtype=np.uint32)
    st_cand[:, 0] = root_cands
    size = (root_cands.any(axis=1)).astype(np.int32)

    return EngineState(
        st_depth=jnp.asarray(st_depth),
        st_map=jnp.asarray(st_map),
        st_used=jnp.asarray(st_used),
        st_cand=jnp.asarray(st_cand),
        base=jnp.zeros((v,), jnp.int32),
        size=jnp.asarray(size),
        matches=jnp.zeros((v,), jnp.int32),
        states=jnp.zeros((v,), jnp.int32),
        exp_depth=jnp.zeros((v,), jnp.int32),
        steals=jnp.zeros((v,), jnp.int32),
        steal_depth=jnp.zeros((v,), jnp.int32),
        steal_rounds=jnp.zeros((), jnp.int32),
        steps=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.bool_),
        match_buf=jnp.full((v, mcap, p_pad), -1, jnp.int32),
    )


def make_expand_fn(cfg: EngineConfig, plan: PlanArrays):
    """Build the purely worker-local part of one engine round:
    ``rebalance_interval`` expansion steps, vmapped over whatever worker
    axis the caller holds (all ``V`` workers single-device, or the local
    ``V / D`` shard under ``shard_map``)."""
    if cfg.use_pallas:
        from repro.kernels import ops as kops

        rows = kops.flatten_adj_rows(plan.adj_bits)
        n_rows = rows.shape[0] - 1
        n_t = plan.adj_bits.shape[2]
        p_max = plan.dom_bits.shape[0] - 1

        def compute_cand(pos, map2, used2):
            safe_pos = jnp.clip(pos, 0, p_max)
            row_idx = jax.vmap(
                lambda p, m: kops.flat_row_index(
                    plan.parent_pos[p], plan.parent_dir[p], plan.parent_elab[p],
                    m, n_t, n_rows,
                )
            )(safe_pos, map2)
            return kops.candidate_mask(rows, plan.dom_bits, safe_pos, row_idx, used2)
    else:
        compute_one = functools.partial(_compute_cand_jnp, plan)

        def compute_cand(pos, map2, used2):
            return jax.vmap(compute_one)(pos, map2, used2)

    step_fn = jax.vmap(
        functools.partial(_worker_step, cfg, plan, compute_cand),
        in_axes=((0, 0, 0, 0, 0, 0, 0, 0, 0, 0),),
        out_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0),
    )

    def expand(state: EngineState) -> EngineState:
        def inner(_, st: EngineState) -> EngineState:
            carry = (
                st.st_depth, st.st_map, st.st_used, st.st_cand,
                st.base, st.size, st.matches, st.states, st.exp_depth,
                st.match_buf,
            )
            out = step_fn(carry)
            (st_depth, st_map, st_used, st_cand, base, size, matches, states,
             exp_depth, mbuf) = out
            s_cap = st_depth.shape[1]
            overflow = st.overflow | jnp.any(size > s_cap - 1)
            return st._replace(
                st_depth=st_depth, st_map=st_map, st_used=st_used, st_cand=st_cand,
                base=base, size=size, matches=matches, states=states,
                exp_depth=exp_depth, match_buf=mbuf, overflow=overflow,
            )

        return lax.fori_loop(0, cfg.rebalance_interval, inner, state)

    return expand


def make_round_fn(cfg: EngineConfig, plan: PlanArrays):
    """Build the body of the outer loop: ``rebalance_interval`` expansion
    steps followed by one steal round.  Exposed separately so the dry-run /
    roofline can lower exactly one round (stable cost accounting)."""
    expand = make_expand_fn(cfg, plan)

    def body(state: EngineState) -> EngineState:
        state = expand(state)
        if cfg.work_stealing and cfg.n_workers > 1:
            state = _steal_round(cfg, state)
        return state._replace(steps=state.steps + cfg.rebalance_interval)

    return body


def _engine_loop(cfg: EngineConfig, plan: PlanArrays, state: EngineState) -> EngineState:
    max_steps = cfg.max_steps or (1 << 30)
    body = make_round_fn(cfg, plan)

    def cond(state: EngineState) -> jnp.ndarray:
        return (jnp.sum(state.size) > 0) & (state.steps < max_steps)

    return lax.while_loop(cond, body, state)


# ---------------------------------------------------------------------------
# mesh-sharded execution: shard_map over the worker axis (DESIGN.md §2.4)
# ---------------------------------------------------------------------------

def mesh_worker_axis(mesh: Mesh) -> str:
    """The mesh axis the worker dimension shards over: ``data`` by
    convention, else the mesh's first axis."""
    return "data" if "data" in mesh.axis_names else mesh.axis_names[0]


def mesh_signature(mesh: Optional[Mesh]) -> Optional[tuple]:
    """Hashable identity of a mesh for compile-cache keys: axis names,
    axis sizes, and the flat device ids."""
    if mesh is None:
        return None
    return (
        tuple(str(a) for a in mesh.axis_names),
        tuple(int(s) for s in mesh.shape.values()),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def state_partition_specs(axis: str) -> EngineState:
    """PartitionSpecs for :class:`EngineState`: worker-axis arrays sharded
    over ``axis``, loop scalars replicated."""
    P = PartitionSpec
    return EngineState(
        st_depth=P(axis, None),
        st_map=P(axis, None, None),
        st_used=P(axis, None, None),
        st_cand=P(axis, None, None),
        base=P(axis),
        size=P(axis),
        matches=P(axis),
        states=P(axis),
        exp_depth=P(axis),
        steals=P(axis),
        steal_depth=P(axis),
        steal_rounds=P(),
        steps=P(),
        overflow=P(),
        match_buf=P(axis, None, None),
    )


def plan_partition_specs() -> PlanArrays:
    """PartitionSpecs for :class:`PlanArrays`: fully replicated (every
    device needs the whole domain/adjacency bitmaps to expand its workers)."""
    P = PartitionSpec
    return PlanArrays(
        order_valid=P(None),
        parent_pos=P(None, None),
        parent_dir=P(None, None),
        parent_elab=P(None, None),
        dom_bits=P(None, None),
        adj_bits=P(None, None, None, None),
        n_p=P(),
    )


def _steal_round_sharded(cfg: EngineConfig, state: EngineState, axis: str) -> EngineState:
    """One steal round under ``shard_map``: ``state`` holds this device's
    ``V / D`` worker stacks.

    Protocol (the collective form of :func:`_steal_round`):

    1. ``all_gather`` the local occupancy vectors → global ``sizes [V]``.
    2. Every device runs the same deterministic
       :func:`repro.core.scheduler.plan_steals` on it — no coordinator.
    3. ``all_gather`` each donor's bottom ``steal_chunk`` stack rows (the
       steal traffic: ``V·C·(1 + P + W_used + W)`` words per round).
    4. Each device scatters only the donated entries whose destination
       worker lives in its local shard; donors advance their ring-buffer
       base by their (globally agreed) accepted count.

    Identical to the single-device round entry-for-entry: the gathered
    ``don_*`` arrays and the global plan are exactly what the unsharded
    path computes in one address space.
    """
    policy = scheduler.StealPolicy(
        steal_chunk=cfg.steal_chunk, keep_min=cfg.keep_min, recv_cap=cfg.recv_cap
    )
    v_loc, s_cap = state.st_depth.shape
    c = cfg.steal_chunk
    d = lax.axis_index(axis)

    sizes = lax.all_gather(state.size, axis, tiled=True)  # [V]
    v_tot = sizes.shape[0]
    donate, accepted, dest_rank, dest_pos = scheduler.plan_steals(sizes, policy)
    wor = scheduler.receiver_workers(sizes)  # [V] global worker per rank
    any_transfer = jnp.sum(accepted) > 0

    # gather local donors' bottom rows, then all-gather them to every device
    slot_j = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (v_loc, c))
    src_slot = (state.base[:, None] + slot_j) % s_cap  # [V_loc, C]
    lidx = jnp.arange(v_loc, dtype=jnp.int32)[:, None]
    don_depth = lax.all_gather(state.st_depth[lidx, src_slot], axis, tiled=True)
    don_map = lax.all_gather(state.st_map[lidx, src_slot], axis, tiled=True)
    don_used = lax.all_gather(state.st_used[lidx, src_slot], axis, tiled=True)
    don_cand = lax.all_gather(state.st_cand[lidx, src_slot], axis, tiled=True)

    # destination workers (global ids), restricted to this device's shard
    slot_g = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (v_tot, c))
    taken = slot_g < accepted[:, None]  # [V, C]
    dest_w = jnp.where(taken, wor[jnp.clip(dest_rank, 0, v_tot - 1)], -1)
    local_dest = dest_w - d * v_loc
    on_dev = (dest_w >= 0) & (local_dest >= 0) & (local_dest < v_loc)
    safe_dest = jnp.clip(local_dest, 0, v_loc - 1)
    # receivers are empty (size==0) so intake slot = (base + pos) % S
    recv_base = jnp.where(on_dev, state.base[safe_dest], 0)
    dst_slot = (recv_base + dest_pos) % s_cap
    dw = jnp.where(on_dev, safe_dest, v_loc)  # drop off-device slots

    st_depth = state.st_depth.at[dw, dst_slot].set(don_depth, mode="drop")
    st_map = state.st_map.at[dw, dst_slot].set(don_map, mode="drop")
    st_used = state.st_used.at[dw, dst_slot].set(don_used, mode="drop")
    st_cand = state.st_cand.at[dw, dst_slot].set(don_cand, mode="drop")

    # intake counts / steal metrics for local receivers only
    flat_w = dw.reshape(-1)
    on_flat = on_dev.reshape(-1)
    recv_cnt = jnp.zeros((v_loc,), jnp.int32).at[flat_w].add(
        jnp.where(on_flat, 1, 0), mode="drop"
    )
    depth_add = jnp.zeros((v_loc,), jnp.int32).at[flat_w].add(
        jnp.where(on_flat, don_depth.reshape(-1), 0), mode="drop"
    )

    # local donors advance base by their slice of the global accepted vector
    accepted_loc = lax.dynamic_slice_in_dim(accepted, d * v_loc, v_loc)
    new_base = (state.base + accepted_loc) % s_cap
    new_size = state.size - accepted_loc + recv_cnt

    return state._replace(
        st_depth=st_depth,
        st_map=st_map,
        st_used=st_used,
        st_cand=st_cand,
        base=new_base,
        size=new_size,
        steals=state.steals + recv_cnt,
        steal_depth=state.steal_depth + depth_add,
        steal_rounds=state.steal_rounds + any_transfer.astype(jnp.int32),
    )


def _sharded_device_loop(
    cfg: EngineConfig, axis: str, plan: PlanArrays, state: EngineState
) -> EngineState:
    """Per-device program run under ``shard_map``: local expansion rounds,
    collective steal rounds, and psum-based termination detection.

    The loop carries the psum'd global entry count so the `while` condition
    is collective-free; every device sees the same count and therefore runs
    the same number of rounds (SPMD lockstep).
    """
    max_steps = cfg.max_steps or (1 << 30)
    expand = make_expand_fn(cfg, plan)

    def global_size(st: EngineState) -> jnp.ndarray:
        return lax.psum(jnp.sum(st.size), axis)

    def body(carry):
        st, _ = carry
        st = expand(st)
        if cfg.work_stealing and cfg.n_workers > 1:
            st = _steal_round_sharded(cfg, st, axis)
        st = st._replace(steps=st.steps + cfg.rebalance_interval)
        return st, global_size(st)

    def cond(carry):
        st, gsize = carry
        return (gsize > 0) & (st.steps < max_steps)

    state, _ = lax.while_loop(cond, body, (state, global_size(state)))
    # overflow is device-local until here; replicate so the P() out-spec holds
    overflow = lax.psum(state.overflow.astype(jnp.int32), axis) > 0
    return state._replace(overflow=overflow)


def make_sharded_engine_fn(cfg: EngineConfig, mesh: Mesh, axis: Optional[str] = None):
    """Jitted ``(PlanArrays, EngineState) -> EngineState`` with the worker
    axis sharded over ``axis`` of ``mesh`` via ``shard_map``.

    ``cfg.n_workers`` must be a multiple of the axis size (the session API
    snaps it up; `repro.core.session.Enumerator`).
    """
    axis = axis or mesh_worker_axis(mesh)
    n_dev = int(mesh.shape[axis])
    if cfg.n_workers % n_dev:
        raise ValueError(
            f"n_workers={cfg.n_workers} not divisible by mesh axis "
            f"{axis!r} size {n_dev}; round up to a multiple"
        )
    specs = state_partition_specs(axis)
    fn = shard_map(
        functools.partial(_sharded_device_loop, cfg, axis),
        mesh=mesh,
        in_specs=(plan_partition_specs(), specs),
        out_specs=specs,
        check_rep=False,
    )
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _sharded_fn_cached(cfg: EngineConfig, mesh: Mesh, axis: Optional[str]):
    # Mesh hashes by device set + axis names, so repeated direct eng.run()
    # calls over a collection reuse one jitted engine per (cfg, mesh) —
    # the module-level analogue of _run_jit; the session layer keeps its
    # own richer cache (shape buckets, counters).
    return make_sharded_engine_fn(cfg, mesh, axis)


def run_sharded(plan: SearchPlan, cfg: EngineConfig, mesh: Mesh) -> EngineResult:
    """Enumerate with worker stacks sharded over ``mesh`` (see :func:`run`)."""
    fn = _sharded_fn_cached(cfg, mesh, None)
    arrays = make_plan_arrays(plan)
    state = init_state(plan, cfg)
    final = jax.block_until_ready(fn(arrays, state))
    return result_from_state(final, cfg)


# ---------------------------------------------------------------------------
# abstract builders (dry-run lowering without allocation)
# ---------------------------------------------------------------------------

def abstract_plan_arrays(
    n_t: int, w: int, p_pad: int, max_parents: int, n_elab: int = 1
) -> PlanArrays:
    sds = jax.ShapeDtypeStruct
    return PlanArrays(
        order_valid=sds((p_pad,), jnp.bool_),
        parent_pos=sds((p_pad, max_parents), jnp.int32),
        parent_dir=sds((p_pad, max_parents), jnp.int32),
        parent_elab=sds((p_pad, max_parents), jnp.int32),
        dom_bits=sds((p_pad, w), jnp.uint32),
        adj_bits=sds((n_elab, 2, n_t, w), jnp.uint32),
        n_p=sds((), jnp.int32),
    )


PLAN_LOGICAL = PlanArrays(
    order_valid=(None,),
    parent_pos=(None, None),
    parent_dir=(None, None),
    parent_elab=(None, None),
    dom_bits=(None, "tensor"),
    adj_bits=(None, None, None, "tensor"),
    n_p=(),
)


def abstract_engine_state(cfg: EngineConfig, w: int, p_pad: int) -> EngineState:
    v = cfg.n_workers
    s_cap = cfg.resolved_stack_cap(p_pad)
    mcap = max(1, cfg.collect_matches)
    w_used = w if cfg.store_used else 1
    sds = jax.ShapeDtypeStruct
    return EngineState(
        st_depth=sds((v, s_cap), jnp.int32),
        st_map=sds((v, s_cap, p_pad), jnp.int32),
        st_used=sds((v, s_cap, w_used), jnp.uint32),
        st_cand=sds((v, s_cap, w), jnp.uint32),
        base=sds((v,), jnp.int32),
        size=sds((v,), jnp.int32),
        matches=sds((v,), jnp.int32),
        states=sds((v,), jnp.int32),
        exp_depth=sds((v,), jnp.int32),
        steals=sds((v,), jnp.int32),
        steal_depth=sds((v,), jnp.int32),
        steal_rounds=sds((), jnp.int32),
        steps=sds((), jnp.int32),
        overflow=sds((), jnp.bool_),
        match_buf=sds((v, mcap, p_pad), jnp.int32),
    )


STATE_LOGICAL = EngineState(
    st_depth=("worker", None),
    st_map=("worker", None, None),
    st_used=("worker", None, "tensor"),
    st_cand=("worker", None, "tensor"),
    base=("worker",),
    size=("worker",),
    matches=("worker",),
    states=("worker",),
    exp_depth=("worker",),
    steals=("worker",),
    steal_depth=("worker",),
    steal_rounds=(),
    steps=(),
    overflow=(),
    match_buf=("worker", None, None),
)


@functools.partial(jax.jit, static_argnums=(0,))
def _run_jit(cfg: EngineConfig, plan: PlanArrays, state: EngineState) -> EngineState:
    return _engine_loop(cfg, plan, state)


def run(plan: SearchPlan, cfg: EngineConfig, mesh: Optional[Mesh] = None) -> EngineResult:
    """Enumerate all isomorphic subgraphs described by ``plan``.

    With ``mesh=None`` (the default) all ``V`` workers run in one device
    program — today's single-device behavior, unchanged.  With a mesh the
    worker axis shards over its ``data`` axis (:func:`run_sharded`).
    """
    if mesh is not None:
        return run_sharded(plan, cfg, mesh)
    arrays = make_plan_arrays(plan)
    state = init_state(plan, cfg)
    final = jax.block_until_ready(_run_jit(cfg, arrays, state))
    return result_from_state(final, cfg)


def result_from_state(final: EngineState, cfg: EngineConfig) -> EngineResult:
    """Reduce a drained (unbatched) :class:`EngineState` to an
    :class:`EngineResult` — shared by the one-shot :func:`run` and the
    session executor (`repro.core.session`), whose batch path reduces one
    vmapped lane at a time."""
    steals = int(jnp.sum(final.steals))
    sdepth = int(jnp.sum(final.steal_depth))
    states = int(jnp.sum(final.states))
    edepth = int(jnp.sum(final.exp_depth))
    return EngineResult(
        matches=int(jnp.sum(final.matches)),
        states=states,
        steps=int(final.steps),
        steals=steals,
        steal_rounds=int(final.steal_rounds),
        mean_steal_depth=(sdepth / steals) if steals else 0.0,
        mean_expand_depth=(edepth / states) if states else 0.0,
        per_worker_states=np.asarray(final.states),
        per_worker_matches=np.asarray(final.matches),
        overflow=bool(final.overflow),
        match_buf=np.asarray(final.match_buf) if cfg.collect_matches else None,
        per_worker_steals=np.asarray(final.steals),
    )
