"""C9 — out-of-core partitioned enumeration under a device-memory budget
(DESIGN.md §9).

  PYTHONPATH=src python -m benchmarks.bench_outofcore            # 33k nodes
  PYTHONPATH=src python -m benchmarks.bench_outofcore --smoke    # CI-sized

Enumerates a power-law target whose resident CSR planes are streamed
through a budget at least ``--budget-factor`` (default 4) times smaller
than the whole-target resident set, and checks, in order:

* the derived partition count's **padded** resident plane bytes — what the
  device actually holds (``extend.part_resident_nbytes``) — sit under the
  budget (asserted, not just reported);
* match/state counts are bit-identical to the monolithic CSR backend *and*
  to the sequential numpy oracle (``ref.ref_enumerate`` on the same plan);
* wall-clock overhead of streaming vs the whole-target CSR run, reported
  honestly: cold (includes the partitioned path's one shared compile) and
  warm (compile cached) separately.  The overhead is real work — spilled
  extensions wait for their partition's residency — not an artifact; the
  point of the mode is peak memory, not speed.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict

from benchmarks import common
from repro.core import EngineConfig, engine as eng, extend, ref
from repro.core import plan as plan_mod
from repro.data import graphgen


def run(n_nodes: int = 33_000, budget_factor: int = 4, seed: int = 7,
        workers: int = 8) -> Dict:
    target = graphgen.power_law_graph(n_nodes, avg_deg=4.0, n_labels=8,
                                      seed=seed)
    pattern = graphgen.extract_pattern(target, 8, seed=seed)
    plan = plan_mod.build_csr_plan(pattern, target)

    whole = extend.part_resident_nbytes(extend.plan_partitions(plan, 1))
    budget = whole // budget_factor
    pp = extend.plan_partitions_budget(plan, budget)
    resident = extend.part_resident_nbytes(pp)
    assert resident <= budget, (
        f"budget violated: {resident} resident bytes > {budget} budget")

    base_cfg = EngineConfig(n_workers=workers, expand_width=4,
                            step_backend="csr")
    part_cfg = EngineConfig(n_workers=workers, expand_width=4,
                            step_backend="partitioned",
                            n_partitions=pp.n_parts)

    # cold = includes compiles; warm = second run, compile caches hot
    t0 = time.perf_counter()
    base = eng.run(plan, base_cfg)
    base_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    base = eng.run(plan, base_cfg)
    base_warm = time.perf_counter() - t0

    stats: Dict = {}
    t0 = time.perf_counter()
    part = eng.run_partitioned(plan, part_cfg, stats=stats)
    part_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    part = eng.run_partitioned(plan, part_cfg, stats=stats)
    part_warm = time.perf_counter() - t0

    assert stats["resident_plane_bytes"] <= budget, stats
    assert part.matches == base.matches, (part.matches, base.matches)
    assert part.states == base.states, (part.states, base.states)

    oracle = ref.ref_enumerate(pattern, target, plan=plan)
    assert part.matches == oracle.matches, (part.matches, oracle.matches)
    assert part.states == oracle.states, (part.states, oracle.states)

    out = dict(
        n_nodes=target.n, n_edges=target.m, pattern_nodes=pattern.n,
        matches=part.matches, states=part.states,
        whole_resident_bytes=whole, budget_bytes=budget,
        resident_plane_bytes=stats["resident_plane_bytes"],
        budget_reduction=whole / max(stats["resident_plane_bytes"], 1),
        n_parts=stats["n_parts"], partition_visits=stats["visits"],
        legs=stats["legs"], spilled=stats["spilled"],
        dead_spills=stats["dead_spills"], cut_edges=stats["cut_edges"],
        base_cold_s=base_cold, base_warm_s=base_warm,
        part_cold_s=part_cold, part_warm_s=part_warm,
        warm_overhead=part_warm / max(base_warm, 1e-9),
    )
    common.save_json("outofcore", out)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=33_000)
    ap.add_argument("--budget-factor", type=int, default=4)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (2048 nodes), same assertions")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the JSON payload to PATH")
    args = ap.parse_args()
    n = 2048 if args.smoke else args.nodes

    out = run(n, budget_factor=args.budget_factor, seed=args.seed,
              workers=args.workers)
    common.write_json_path(args.json, out)
    print(f"[outofcore] {out['n_nodes']} nodes / {out['n_edges']} edges, "
          f"pattern {out['pattern_nodes']} nodes: "
          f"{out['matches']} matches, {out['states']} states "
          f"(oracle + monolithic-CSR verified)")
    print(f"[outofcore] whole-target resident {out['whole_resident_bytes']} B "
          f"-> budget {out['budget_bytes']} B -> {out['n_parts']} partitions, "
          f"{out['resident_plane_bytes']} B resident "
          f"({out['budget_reduction']:.1f}x under whole target)")
    print(f"[outofcore] {out['partition_visits']} partition visits, "
          f"{out['legs']} legs, {out['spilled']} spilled "
          f"({out['dead_spills']} dead), {out['cut_edges']} cut arcs")
    print(f"[outofcore] wall: csr cold {out['base_cold_s']:.2f}s warm "
          f"{out['base_warm_s']:.2f}s; partitioned cold "
          f"{out['part_cold_s']:.2f}s warm {out['part_warm_s']:.2f}s "
          f"({out['warm_overhead']:.1f}x warm overhead — streaming trades "
          "time for peak plane memory)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
