"""GraphCast-style weather step on the icosahedral mesh (scaled down).

  PYTHONPATH=src python examples/weather_graphcast.py [--refinement 3]

Builds the real encoder-processor-decoder topology: a lat-lon grid, an
icosahedral mesh at the requested refinement (full config uses refinement 6
⇒ 40,962 mesh nodes), grid→mesh / mesh→grid bipartite edges, and runs one
training step (MSE over n_vars channels) + a rollout step, asserting finite
outputs.  This is the weather-native configuration of the ``graphcast``
architecture that the generic benchmark shapes approximate (DESIGN.md §4).
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import graphgen
from repro.models.common import init_from_specs
from repro.models.gnn import graphcast
from repro.train import optimizer as opt_mod
from repro.train.trainer import make_train_step


def build_batch(refinement: int, grid_h: int, grid_w: int, n_vars: int, seed=0):
    rng = np.random.default_rng(seed)
    nm, em = graphgen.icosa_mesh_shape(refinement)
    ng = grid_h * grid_w
    fanout = 4
    batch = {
        "feats": rng.normal(size=(ng, n_vars)).astype(np.float32),
        "mesh_feats": rng.normal(size=(nm, 4)).astype(np.float32),
        "g2m_src": rng.integers(0, ng, ng * fanout).astype(np.int32),
        "g2m_dst": rng.integers(0, nm, ng * fanout).astype(np.int32),
        "g2m_efeats": rng.normal(size=(ng * fanout, 4)).astype(np.float32),
        "mesh_src": rng.integers(0, nm, em).astype(np.int32),
        "mesh_dst": rng.integers(0, nm, em).astype(np.int32),
        "mesh_efeats": rng.normal(size=(em, 4)).astype(np.float32),
        "m2g_src": rng.integers(0, nm, ng * fanout).astype(np.int32),
        "m2g_dst": rng.integers(0, ng, ng * fanout).astype(np.int32),
        "m2g_efeats": rng.normal(size=(ng * fanout, 4)).astype(np.float32),
        "targets": rng.normal(size=(ng, n_vars)).astype(np.float32),
    }
    return {k: jnp.asarray(v) for k, v in batch.items()}, ng, nm, em


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--refinement", type=int, default=2)
    ap.add_argument("--grid", type=int, default=24)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--vars", type=int, default=16)
    args = ap.parse_args()

    cfg = graphcast.GraphCastConfig(
        n_layers=args.layers, d_hidden=args.hidden,
        mesh_refinement=args.refinement, n_vars=args.vars,
    )
    batch, ng, nm, em = build_batch(args.refinement, args.grid, args.grid, args.vars)
    print(f"[weather] grid {ng} nodes, mesh {nm} nodes / {em} arcs, "
          f"{args.layers}L x d{args.hidden}")

    params = init_from_specs(
        jax.random.PRNGKey(0), graphcast.param_specs(cfg, args.vars, args.vars)
    )
    opt_cfg = opt_mod.AdamWConfig(lr=3e-4, warmup_steps=2, total_steps=20)
    step = jax.jit(make_train_step(
        lambda p, b: graphcast.loss_fn(p, cfg, b), opt_cfg))
    opt = opt_mod.init(params)
    losses = []
    for i in range(5):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss_total"]))
        print(f"  step {i}: loss {losses[-1]:.4f}")
    assert np.isfinite(losses).all()

    # rollout: prediction feeds back as input features
    pred = jax.jit(lambda p, b: graphcast.forward(p, cfg, b))(params, batch)
    batch2 = dict(batch, feats=pred)
    pred2 = jax.jit(lambda p, b: graphcast.forward(p, cfg, b))(params, batch2)
    assert bool(jnp.all(jnp.isfinite(pred2)))
    print(f"[weather] 2-step rollout OK; loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
