"""SchNet: continuous-filter convolutions over radial basis expansions.

Per interaction block (Schütt et al.):
  d_ij  = ||x_i - x_j||                (edge distances from positions)
  rbf   = exp(-γ (d - μ_k)^2)          (n_rbf Gaussian bases over [0, cutoff])
  W_ij  = filter-MLP(rbf)              (continuous filter, ssp activations)
  m_i   = Σ_j (h_j W1) ⊙ W_ij          (cfconv: gather, modulate, scatter-sum)
  h_i  += W3 · ssp(W2 · m_i)           (atom-wise update, residual)

This is the triplet-free member of the molecular-GNN kernel regime — pure
edge-gather + scatter, so it shares the substrate with GCN/SAGE (and the SGE
engine).  Node inputs arrive as precomputed features (the modality frontend
stub per the brief); a linear layer maps them to ``d_hidden``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constraint
from repro.models.common import ParamSpec, dot
from repro.models.gnn.common import gather_src, masked_softmax_ce, segment_sum


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0


def ssp(x):
    """Shifted softplus — SchNet's activation."""
    return jax.nn.softplus(x) - jnp.log(2.0)


def param_specs(cfg: SchNetConfig, d_in: int, d_out: int) -> Dict[str, ParamSpec]:
    d = cfg.d_hidden
    specs: Dict[str, ParamSpec] = {
        "embed_w": ParamSpec((d_in, d), (None, "tensor"), jnp.float32),
        "embed_b": ParamSpec((d,), (None,), jnp.float32, init="zeros"),
        "out_w0": ParamSpec((d, d // 2), (None, None), jnp.float32),
        "out_b0": ParamSpec((d // 2,), (None,), jnp.float32, init="zeros"),
        "out_w1": ParamSpec((d // 2, d_out), (None, None), jnp.float32),
        "out_b1": ParamSpec((d_out,), (None,), jnp.float32, init="zeros"),
    }
    for i in range(cfg.n_interactions):
        specs[f"f_w0_{i}"] = ParamSpec((cfg.n_rbf, d), (None, "tensor"), jnp.float32)
        specs[f"f_b0_{i}"] = ParamSpec((d,), (None,), jnp.float32, init="zeros")
        specs[f"f_w1_{i}"] = ParamSpec((d, d), (None, None), jnp.float32)
        specs[f"f_b1_{i}"] = ParamSpec((d,), (None,), jnp.float32, init="zeros")
        specs[f"in_w1_{i}"] = ParamSpec((d, d), (None, None), jnp.float32)
        specs[f"in_w2_{i}"] = ParamSpec((d, d), (None, None), jnp.float32)
        specs[f"in_b2_{i}"] = ParamSpec((d,), (None,), jnp.float32, init="zeros")
        specs[f"in_w3_{i}"] = ParamSpec((d, d), (None, None), jnp.float32)
        specs[f"in_b3_{i}"] = ParamSpec((d,), (None,), jnp.float32, init="zeros")
    return specs


def rbf_expand(dist: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 1.0 / (mu[1] - mu[0]) ** 2
    return jnp.exp(-gamma * jnp.square(dist[:, None] - mu[None, :]))


def forward(params, cfg: SchNetConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    src, dst = batch["src"], batch["dst"]
    n = batch["feats"].shape[0]
    pos = batch["positions"]
    h = dot(batch["feats"], params["embed_w"]) + params["embed_b"]

    diff = jnp.take(pos, src, axis=0) - jnp.take(pos, dst, axis=0)
    dist = jnp.sqrt(jnp.sum(jnp.square(diff), axis=-1) + 1e-12)
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff)  # [E, n_rbf]
    rbf = constraint(rbf, ("edge", None))
    # smooth cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)

    for i in range(cfg.n_interactions):
        filt = ssp(dot(rbf, params[f"f_w0_{i}"]) + params[f"f_b0_{i}"])
        filt = ssp(dot(filt, params[f"f_w1_{i}"]) + params[f"f_b1_{i}"])
        filt = filt * env[:, None]
        x = dot(h, params[f"in_w1_{i}"])
        msg = gather_src(x, src) * filt
        agg = segment_sum(msg, dst, n)
        upd = ssp(dot(agg, params[f"in_w2_{i}"]) + params[f"in_b2_{i}"])
        h = h + dot(upd, params[f"in_w3_{i}"]) + params[f"in_b3_{i}"]
        h = constraint(h, (None, None))

    out = ssp(dot(h, params["out_w0"]) + params["out_b0"])
    return dot(out, params["out_w1"]) + params["out_b1"]


def loss_fn(params, cfg: SchNetConfig, batch):
    out = forward(params, cfg, batch)
    if "graph_ids" in batch and "graph_targets" in batch:
        # per-graph energy: sum-pool node outputs, MSE against graph targets
        g = segment_sum(out, batch["graph_ids"], batch["graph_targets"].shape[0])
        loss = jnp.mean(jnp.square(g - batch["graph_targets"]))
        return loss, {"loss": loss}
    if "labels" in batch:
        loss, count = masked_softmax_ce(out, batch["labels"])
        return loss, {"loss": loss, "nodes": count}
    loss = jnp.mean(jnp.square(out - batch["targets"]))
    return loss, {"loss": loss}
