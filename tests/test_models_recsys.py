"""Recsys substrate tests: embedding-bag semantics, DIN scoring paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import init_from_specs
from repro.models.recsys import din as din_mod
from repro.models.recsys.embedding import embedding_bag, lookup


def test_embedding_bag_against_loop(rng):
    table = jnp.asarray(rng.normal(size=(50, 6)), jnp.float32)
    ids = jnp.asarray([3, 7, -1, 7, 2, -1, -1, 11], jnp.int32)
    bags = jnp.asarray([0, 0, 0, 1, 1, 1, 2, 3], jnp.int32)
    out_sum = embedding_bag(table, ids, bags, 4, mode="sum")
    out_mean = embedding_bag(table, ids, bags, 4, mode="mean")
    expect = np.zeros((4, 6), np.float32)
    counts = np.zeros(4)
    for i, (t, b) in enumerate(zip(ids.tolist(), bags.tolist())):
        if t >= 0:
            expect[b] += np.asarray(table[t])
            counts[b] += 1
    np.testing.assert_allclose(np.asarray(out_sum), expect, rtol=1e-6)
    expect_mean = expect / np.maximum(counts, 1)[:, None]
    np.testing.assert_allclose(np.asarray(out_mean), expect_mean, rtol=1e-6)


def test_embedding_bag_weighted(rng):
    table = jnp.asarray(rng.normal(size=(10, 4)), jnp.float32)
    ids = jnp.asarray([1, 2], jnp.int32)
    bags = jnp.asarray([0, 0], jnp.int32)
    w = jnp.asarray([0.5, 2.0], jnp.float32)
    out = embedding_bag(table, ids, bags, 1, weights=w)
    expect = 0.5 * np.asarray(table[1]) + 2.0 * np.asarray(table[2])
    np.testing.assert_allclose(np.asarray(out[0]), expect, rtol=1e-6)


def _din_setup(rng, batch=6):
    cfg = din_mod.DINConfig(embed_dim=4, seq_len=5, attn_mlp=(8, 4), mlp=(16, 8),
                            n_items=40, n_cats=7, d_dense=3)
    params = init_from_specs(jax.random.PRNGKey(0), din_mod.param_specs(cfg))
    batch_d = {
        "hist_items": jnp.asarray(rng.integers(0, 40, (batch, 5)), jnp.int32),
        "hist_cats": jnp.asarray(rng.integers(0, 7, (batch, 5)), jnp.int32),
        "hist_len": jnp.asarray(rng.integers(1, 6, batch), jnp.int32),
        "target_item": jnp.asarray(rng.integers(0, 40, batch), jnp.int32),
        "target_cat": jnp.asarray(rng.integers(0, 7, batch), jnp.int32),
        "dense": jnp.asarray(rng.normal(size=(batch, 3)), jnp.float32),
        "click": jnp.asarray(rng.integers(0, 2, batch), jnp.int32),
    }
    return cfg, params, batch_d


def test_din_loss_and_grad(rng):
    cfg, params, batch = _din_setup(rng)
    (loss, _), grads = jax.value_and_grad(
        lambda p: din_mod.loss_fn(p, cfg, batch), has_aux=True
    )(params)
    assert jnp.isfinite(loss)
    assert 0.2 < float(loss) < 2.0  # ~ln 2 at init
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_din_history_mask(rng):
    """Positions beyond hist_len must not influence the score."""
    cfg, params, batch = _din_setup(rng, batch=2)
    batch["hist_len"] = jnp.asarray([2, 5], jnp.int32)
    s1 = din_mod.score(params, cfg, batch)
    tampered = dict(batch)
    hist = np.asarray(batch["hist_items"]).copy()
    hist[0, 2:] = (hist[0, 2:] + 13) % 40  # change masked-out items of row 0
    tampered["hist_items"] = jnp.asarray(hist)
    s2 = din_mod.score(params, cfg, tampered)
    np.testing.assert_allclose(float(s1[0]), float(s2[0]), rtol=1e-5)
    # row 1 uses all 5 positions; leave it untouched -> identical anyway
    np.testing.assert_allclose(float(s1[1]), float(s2[1]), rtol=1e-5)


def test_score_candidates_matches_pointwise(rng):
    """Retrieval wide-scoring == calling score per candidate."""
    cfg, params, batch = _din_setup(rng, batch=1)
    nc = 9
    cand = {
        "hist_items": batch["hist_items"],
        "hist_cats": batch["hist_cats"],
        "hist_len": batch["hist_len"],
        "cand_items": jnp.asarray(rng.integers(0, 40, nc), jnp.int32),
        "cand_cats": jnp.asarray(rng.integers(0, 7, nc), jnp.int32),
        "dense": batch["dense"],
    }
    wide = din_mod.score_candidates(params, cfg, cand)
    for i in range(nc):
        single = dict(
            batch,
            target_item=cand["cand_items"][i : i + 1],
            target_cat=cand["cand_cats"][i : i + 1],
        )
        s = din_mod.score(params, cfg, single)
        np.testing.assert_allclose(float(wide[i]), float(s[0]), rtol=1e-4, atol=1e-5)


def test_lookup_clamps_negative():
    table = jnp.arange(12.0).reshape(4, 3)
    out = lookup(table, jnp.asarray([-1, 2]))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(table[0]))
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(table[2]))
