"""The reproduction gate: engine == sequential oracle == brute force.

Hypothesis property tests over random labeled directed/undirected graphs,
all four algorithm variants, multiple worker/width configurations.
"""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import EngineConfig, PackedGraph, enumerate_subgraphs
from repro.core.graph import Graph
from repro.core.ref import brute_force_count, ref_enumerate
from tests.conftest import bump_edge_label, extract_connected_pattern, random_graph


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(6, 24),
    density=st.floats(1.0, 2.5),
    n_labels=st.integers(1, 3),
    n_elabs=st.integers(1, 2),
    undirected=st.booleans(),
    pat_nodes=st.integers(2, 4),
    selfloops=st.integers(0, 3),
    variant=st.sampled_from(["ri", "ri-ds-si-fc", "ri-ds-si-acfc"]),
)
def test_engine_matches_oracle(seed, n, density, n_labels, n_elabs, undirected,
                               pat_nodes, selfloops, variant):
    rng = np.random.default_rng(seed)
    tgt = random_graph(rng, n, int(n * density), n_labels, n_elabs, undirected,
                       selfloops=selfloops)
    pat = extract_connected_pattern(rng, tgt, pat_nodes)
    if pat.m == 0:
        return
    ref = ref_enumerate(pat, tgt, variant=variant)
    res = enumerate_subgraphs(pat, tgt, variant=variant, n_workers=4, expand_width=2)
    assert res.matches == ref.matches
    assert res.states == ref.states
    assert res.matches >= 1  # extracted subgraph must occur


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(4, 7),
    pat_nodes=st.integers(2, 3),
    selfloops=st.integers(0, 2),
    overflow=st.booleans(),
)
def test_brute_force_agreement(seed, n, pat_nodes, selfloops, overflow):
    rng = np.random.default_rng(seed)
    tgt = random_graph(rng, n, n + 2, n_labels=2, selfloops=selfloops)
    pat = extract_connected_pattern(rng, tgt, pat_nodes)
    if pat.m == 0:
        return
    if overflow:
        # out-of-range edge label: zero matches everywhere, never an error
        pat = bump_edge_label(pat, int(rng.integers(pat.m)), 5)
    bf = brute_force_count(pat, tgt)
    for variant in ("ri", "ri-ds", "ri-ds-si", "ri-ds-si-fc", "ri-ds-si-acfc"):
        ref = ref_enumerate(pat, tgt, variant=variant)
        assert ref.matches == bf, variant
        res = enumerate_subgraphs(pat, tgt, variant=variant, n_workers=2, expand_width=2)
        assert res.matches == bf, variant


# The deterministic self-loop / overflow regression tests live in
# tests/test_domains_bugfixes.py (no hypothesis dependency, so they run
# even where hypothesis is absent); this module keeps the property tests.


def test_worker_config_invariance(rng):
    """Match/state counts must not depend on parallel configuration."""
    tgt = random_graph(rng, 30, 70, n_labels=2)
    pat = extract_connected_pattern(rng, tgt, 4)
    base = None
    packed = PackedGraph.from_graph(tgt)
    for v, e, steal in [(1, 1, False), (1, 8, False), (4, 2, True),
                        (16, 4, True), (16, 4, False), (8, 1, True)]:
        res = enumerate_subgraphs(
            pat, packed, variant="ri-ds-si-fc",
            n_workers=v, expand_width=e, work_stealing=steal,
        )
        if base is None:
            base = (res.matches, res.states)
        assert (res.matches, res.states) == base, (v, e, steal)


def test_unsatisfiable_label():
    """Pattern label absent from target -> zero matches, zero search."""
    from repro.core.graph import Graph

    tgt = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3)], labels=[0, 0, 0, 0],
                           undirected=True)
    pat = Graph.from_edges(2, [(0, 1)], labels=[1, 0], undirected=True)
    res = enumerate_subgraphs(pat, tgt, variant="ri-ds")
    assert res.matches == 0


def test_mapping_materialization(rng):
    """collect_matches records valid mappings."""
    tgt = random_graph(rng, 12, 24, n_labels=1)
    pat = extract_connected_pattern(rng, tgt, 3)
    if pat.m == 0:
        pytest.skip("empty pattern")
    res = enumerate_subgraphs(
        pat, tgt, variant="ri", n_workers=2, expand_width=2, collect_matches=64,
    )
    buf = res.engine.match_buf
    assert buf is not None
    recorded = buf[buf[:, :, 0] >= 0]
    n_rec = int((buf[:, :, : pat.n] >= 0).all(axis=-1).sum())
    assert n_rec == min(res.matches, n_rec)  # ring buffer holds <= matches
    # each recorded mapping is injective
    from repro.core.plan import build_plan
    from repro.core.graph import PackedGraph

    for w in range(buf.shape[0]):
        for i in range(buf.shape[1]):
            row = buf[w, i, : pat.n]
            if (row >= 0).all():
                assert len(set(row.tolist())) == pat.n
