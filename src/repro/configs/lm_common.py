"""Shared cell builders for the five assigned LM architectures.

Shapes (assigned):
  * ``train_4k``    seq 4,096 × global batch 256   → full train step
                    (grad + clip + AdamW/ZeRO update)
  * ``prefill_32k`` seq 32,768 × batch 32          → prefill (logits + KV cache)
  * ``decode_32k``  KV 32,768 × batch 128          → one-token decode step
  * ``long_500k``   seq 524,288 × batch 1          → **SKIP**: every assigned
                    LM arch is pure full-attention; the brief mandates
                    sub-quadratic attention for this shape (DESIGN.md §4).

MODEL_FLOPS convention: 6·N_active·tokens for training, 2·N_active·tokens for
inference, with N_active excluding the input embedding table (its lookup is a
gather, not a matmul) but including the LM head.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.registry import Arch, Cell, CellBuild
from repro.data import graphgen
from repro.models import transformer as tf
from repro.train import optimizer as opt_mod
from repro.train.trainer import make_train_step

TRAIN_SHAPE = dict(seq=4096, batch=256)
PREFILL_SHAPE = dict(seq=32768, batch=32)
DECODE_SHAPE = dict(seq=32768, batch=128)
LONG_SHAPE = dict(seq=524288, batch=1)

OPT = opt_mod.AdamWConfig(lr=3e-4, total_steps=100000)


def _n_active(cfg: tf.LMConfig) -> int:
    return cfg.active_param_count() - cfg.vocab_size * cfg.d_model


def _batch_abstract(cfg: tf.LMConfig, batch: int, seq: int):
    sds = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    logical = {"tokens": ("batch", None), "labels": ("batch", None)}
    return sds, logical


def build_train(cfg: tf.LMConfig, batch: int, seq: int) -> CellBuild:
    step = make_train_step(functools.partial(_lm_loss, cfg), OPT)
    p_abs = tf.abstract_params(cfg)
    p_log = tf.param_logical(cfg)
    o_abs = opt_mod.abstract_state(p_abs)
    o_log = opt_mod.state_logical(p_log)
    b_abs, b_log = _batch_abstract(cfg, batch, seq)
    tokens = batch * seq
    return CellBuild(
        fn=step,
        args=(p_abs, o_abs, b_abs),
        logical=(p_log, o_log, b_log),
        model_flops=6.0 * _n_active(cfg) * tokens,
        donate=(0, 1),
    )


def _lm_loss(cfg, params, batch):
    return tf.loss_fn(params, cfg, batch)


def build_prefill(cfg: tf.LMConfig, batch: int, seq: int) -> CellBuild:
    def step(params, tokens):
        return tf.prefill(params, cfg, tokens, max_len=seq)

    p_abs = tf.abstract_params(cfg)
    p_log = tf.param_logical(cfg)
    t_abs = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return CellBuild(
        fn=step,
        args=(p_abs, t_abs),
        logical=(p_log, ("batch", None)),
        model_flops=2.0 * _n_active(cfg) * batch * seq,
    )


def build_decode(cfg: tf.LMConfig, batch: int, seq: int) -> CellBuild:
    def step(params, cache, tokens, cache_len):
        return tf.decode_step(params, cfg, cache, tokens, cache_len)

    p_abs = tf.abstract_params(cfg)
    p_log = tf.param_logical(cfg)
    c_abs = tf.abstract_cache(cfg, batch, seq)
    t_abs = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    l_abs = jax.ShapeDtypeStruct((), jnp.int32)
    return CellBuild(
        fn=step,
        args=(p_abs, c_abs, t_abs, l_abs),
        logical=(p_log, tf.CACHE_LOGICAL, ("batch", None), ()),
        model_flops=2.0 * _n_active(cfg) * batch,
        donate=(1,),
    )


def make_lm_arch(cfg: tf.LMConfig, smoke_cfg: tf.LMConfig, notes: str = "") -> Arch:
    name = cfg.name
    cells = {
        "train_4k": Cell(name, "train_4k", "train",
                         lambda: build_train(cfg, **TRAIN_SHAPE)),
        "prefill_32k": Cell(name, "prefill_32k", "prefill",
                            lambda: build_prefill(cfg, **PREFILL_SHAPE)),
        "decode_32k": Cell(name, "decode_32k", "decode",
                           lambda: build_decode(cfg, **DECODE_SHAPE)),
        "long_500k": Cell(
            name, "long_500k", "decode", None,
            skip_reason="pure full-attention arch; long_500k requires "
            "sub-quadratic attention (skip per brief; see DESIGN.md §4 and "
            "the opt-in sliding-window variant in EXPERIMENTS.md §Beyond)",
        ),
    }
    return registry.register(
        Arch(
            name=name,
            family="lm",
            cfg=cfg,
            cells=cells,
            smoke=lambda: lm_smoke(smoke_cfg),
            notes=notes,
        )
    )


def lm_smoke(cfg: tf.LMConfig) -> Dict[str, float]:
    """Reduced-config train+decode step on CPU, shape/NaN asserts."""
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        k: jnp.asarray(v)
        for k, v in graphgen.lm_batch(2, 16, cfg.vocab_size, seed=0).items()
    }
    step = make_train_step(functools.partial(_lm_loss, cfg), OPT)
    opt = opt_mod.init(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    loss = float(metrics["loss_total"])
    assert np_finite(loss), f"non-finite loss {loss}"
    logits, cache = jax.jit(lambda p, t: tf.prefill(p, cfg, t, max_len=32))(
        params2, batch["tokens"]
    )
    assert logits.shape == (2, cfg.vocab_size)
    lg, _ = jax.jit(
        lambda p, c, t, l: tf.decode_step(p, cfg, c, t, l)
    )(params2, cache, batch["tokens"][:, :1], jnp.int32(16))
    assert lg.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg)))
    return {"loss": loss}


def np_finite(x) -> bool:
    import math

    return math.isfinite(x)
