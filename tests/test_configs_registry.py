"""Registry integrity + per-arch smoke tests (reduced configs, CPU).

The smoke tests are the per-architecture gate required by the brief: each
instantiates a reduced config of the same family and runs one forward/train
step asserting finite outputs and correct shapes.
"""

import jax
import pytest

from repro.configs import registry

ARCHS = sorted(registry.load_all())

LM_ARCHS = ["grok-1-314b", "kimi-k2-1t-a32b", "nemotron-4-15b", "minitron-8b",
            "stablelm-12b"]
GNN_ARCHS = ["gcn-cora", "graphcast", "schnet", "graphsage-reddit"]


def test_all_assigned_archs_registered():
    for a in LM_ARCHS + GNN_ARCHS + ["din", "sge"]:
        assert a in ARCHS


def test_cell_matrix_complete():
    cells = registry.all_cells()
    assigned = [c for c in cells if c.arch != "sge"]
    assert len(assigned) == 40  # 10 archs x 4 shapes
    skipped = [c for c in assigned if c.build is None]
    # exactly the five full-attention long_500k cells are skipped
    assert sorted(c.arch for c in skipped) == sorted(LM_ARCHS)
    assert all(c.shape == "long_500k" for c in skipped)
    assert all(c.skip_reason for c in skipped)
    sge = [c for c in cells if c.arch == "sge"]
    # 3 dense collection rounds + the sparse-CSR pdbsv1 round
    assert len(sge) == 4


def test_cells_have_model_flops():
    for cell in registry.all_cells():
        if cell.build is None:
            continue
        if cell.arch in ("gcn-cora",) and cell.shape in ("full_graph_sm",):
            b = cell.build()
            assert b.model_flops > 0
            assert len(b.args) == len(b.logical)


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch):
    out = registry.get(arch).smoke()
    assert isinstance(out, dict) and out
