"""Pallas TPU kernel: fused causal flash attention (forward).

The LM cells' attention is pure-JAX blockwise softmax (models/attention.py)
so XLA's cost analysis sees its FLOPs; this kernel is the TPU-native fused
form for production deployment — scores never leave VMEM, HBM traffic drops
from O(S·S_kv) to O(S·d).

Grid ``(B·H, S/bq, S_kv/bk)`` with the KV block index innermost; the online
softmax carry (m, l) and the output accumulator live in VMEM scratch across
the KV sweep of each query block.  Causality prunes nothing here (masked
blocks still run — a block-skip variant needs a dynamic grid, out of scope);
masking is positional inside the block.

Validated against `repro.kernels.ref.flash_attention_ref` in interpret mode
(tests/test_kernels.py); tolerance 2e-2 for bf16 inputs, 1e-5 fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, bq, bk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ()))
    )
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,  # [BH, S, d]
    k: jnp.ndarray,  # [BH, S_kv, d]
    v: jnp.ndarray,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused causal attention over flattened (batch·head) leading dim."""
    bh, s, d = q.shape
    _, s_kv, _ = k.shape
    bq = min(block_q, s)
    bk = min(block_k, s_kv)
    assert s % bq == 0 and s_kv % bk == 0, (s, bq, s_kv, bk)
    scale = 1.0 / (d ** 0.5)

    grid = (bh, s // bq, s_kv // bk)
    kernel = functools.partial(_kernel, scale=scale, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
