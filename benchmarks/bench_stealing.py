"""C1/C7 — effect of work stealing with 16 workers (paper Fig. 3) and
steal-depth behavior (the steal-from-the-back heuristic).

Metrics (BSP methodology — benchmarks/common.py):
  * step-count makespan with vs without stealing (paper: stealing gives
    ~1.65× at 16 workers);
  * per-worker states stddev (paper: high imbalance without stealing);
  * mean depth of stolen entries (near-root expected — C7).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.core import EngineConfig


def run(scale: float = 0.5, seed: int = 7, workers: int = 16) -> Dict:
    collections = common.bench_instances(scale=scale, seed=seed)
    rows: List[Dict] = []
    for cname, instances in collections.items():
        cache: dict = {}
        for steal in (True, False):
            cfg = EngineConfig(
                n_workers=workers, expand_width=4, work_stealing=steal,
                steal_chunk=4, rebalance_interval=8,
            )
            steps, stds, walls, steals, depths, states = [], [], [], [], [], []
            for inst in instances:
                r = common.run_instance(inst, cfg=cfg, packed_cache=cache)
                if r.states == 0:
                    continue
                steps.append(r.steps)
                stds.append(float(np.std(r.per_worker_states)) /
                            max(float(np.mean(r.per_worker_states)), 1e-9))
                walls.append(r.wall_s)
                steals.append(r.steals)
                states.append(r.states)
            rows.append(dict(
                collection=cname, stealing=steal,
                total_steps=float(np.sum(steps)),
                mean_worker_cv=float(np.mean(stds)),
                total_wall_s=float(np.sum(walls)),
                total_steals=float(np.sum(steals)),
                total_states=float(np.sum(states)),
            ))
    # steal-depth experiment (C7): one long-ish instance per collection
    depth_rows = []
    for cname, instances in collections.items():
        cache: dict = {}
        best = max(instances, key=lambda i: i.pattern.m)
        cfg = EngineConfig(n_workers=workers, expand_width=4)
        r_ = common.run_instance(best, cfg=cfg, packed_cache=cache)
        import repro.core.engine as eng
        from repro.core import PackedGraph, build_plan
        plan = build_plan(best.pattern, cache[id(best.target)], variant="ri-ds-si-fc")
        res = eng.run(plan, cfg)
        depth_rows.append(dict(
            collection=cname, instance=best.name,
            mean_steal_depth=res.mean_steal_depth,
            mean_expand_depth=res.mean_expand_depth,
            pattern_nodes=int(best.pattern.n),
            steals=res.steals,
        ))
    out = {"stealing": rows, "steal_depth": depth_rows}
    common.save_json("stealing", out)
    return out


def emit_csv(out: Dict) -> List[str]:
    lines = []
    by_coll: Dict[str, Dict[bool, Dict]] = {}
    for row in out["stealing"]:
        by_coll.setdefault(row["collection"], {})[row["stealing"]] = row
    for cname, d in by_coll.items():
        if True in d and False in d:
            speed = d[False]["total_steps"] / max(d[True]["total_steps"], 1)
            lines.append(common.csv_row(
                f"stealing/{cname}",
                d[True]["total_wall_s"] * 1e6 / max(d[True]["total_states"], 1),
                f"bsp_speedup_from_stealing={speed:.2f};"
                f"cv_with={d[True]['mean_worker_cv']:.3f};"
                f"cv_without={d[False]['mean_worker_cv']:.3f};"
                f"steals={d[True]['total_steals']:.0f}",
            ))
    for row in out["steal_depth"]:
        # C7: stolen entries should sit closer to the root than the entries
        # the owners are expanding (bottom-of-stack stealing)
        lines.append(common.csv_row(
            f"steal_depth/{row['collection']}", 0.0,
            f"steal_depth={row['mean_steal_depth']:.2f};"
            f"expand_depth={row['mean_expand_depth']:.2f};"
            f"pattern_nodes={row['pattern_nodes']};steals={row['steals']}",
        ))
    return lines


if __name__ == "__main__":
    print("\n".join(emit_csv(run())))
