"""Pallas TPU kernel for the sparse CSR expansion step (DESIGN.md §6.4).

The dense fused step (`repro.kernels.extend_step`) ANDs whole ``[w]``-word
adjacency bitmap rows — ``O(n_planes · n_t · w)`` resident words, which
stops scaling past the paper's 33k-node targets.  This kernel walks the
**CSR adjacency planes** instead: for each popped lane it

1. extracts the lowest untried candidate bit ``v`` in-register (the same
   ``cand2`` / fused ``¬(used ∨ bit(v))`` child init as the dense kernel);
2. loads the **driver** parent's neighbor segment with a ``pl.ds`` dynamic
   slice of the flat ``indices`` array — the segment bounds arrive through
   **scalar prefetch** (the backend gathers ``indptr[plane, t]`` /
   ``indptr[plane, t + 1]`` per lane before launch, the same
   row-bounds-ahead-of-data pattern the dense kernel uses for row ids);
3. sorted-intersects: each proposed neighbor survives iff its bit is set in
   ``dom ∧ ¬used'`` and a vectorized binary search finds it in every other
   mapped parent's (sorted, sentinel-padded) segment;
4. scatters the survivors into the child candidate bitmap and emits the
   ``(valid, v, is_match, has_child)`` meta row.

TPU mapping
-----------
* Grid ``(b,)`` — one step per lane; all ``deg_cap``-wide vector work for a
  lane happens in one step, so segments never round-trip through HBM.
* ``indices`` is presented as a single ``[1, N]`` VMEM-resident block
  (sparse targets keep ``N·4`` bytes in the low MBs — pdbsv1-scale graphs
  are ~100 words of indices per *thousand* dense bitmap words); the
  per-parent ``pl.ds`` loads slice it at the prefetched offsets.
* The membership search and the survivor scatter are expressed as jnp ops
  on values inside the kernel (gather / searchsorted / scatter-add over
  ``deg_cap``-length int vectors).  Off-TPU the kernel runs in interpret
  mode — the validation mode for this container; semantics are gated by
  ``csr_extend_ref`` and the cross-backend conformance suite
  (``tests/test_backend_conformance.py``).

Oracle: `repro.kernels.ref.csr_extend_ref` (bit-exact — it is also the
``CsrStepBackend``'s jnp compute path, so kernel-vs-oracle equality is
exactly kernel-vs-engine equality).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.candidate_mask import pad_words
from repro.kernels.extend_step import META_WIDTH, _lowest_bit

# python int (not a jnp scalar: pallas kernels must not capture traced
# constants); fits int32 and exceeds every node id, so sentinel-masked
# segments stay sorted.
SENTINEL = 2**31 - 1


def _kernel(
    cpos_ref, sst_ref, sln_ref, depth_ref, np_ref,  # scalar prefetch
    cand_ref, used_ref, dom_ref, ind_ref,  # operands
    cand2_ref, child_ref, meta_ref,  # outputs
    *, mp: int, deg_cap: int,
):
    l = pl.program_id(0)
    wp = cand_ref.shape[1]

    c = cand_ref[...]
    valid, v, vmask = _lowest_bit(c)
    cand2_ref[...] = c ^ vmask
    base = dom_ref[...] & ~used_ref[...] & ~vmask  # [1, wp]

    # --- driver segment: first real parent slot ---------------------------
    lens = sln_ref[l, :]  # [mp] from SMEM
    real = lens >= 0
    has_parent = jnp.any(real)
    d = jnp.argmax(real)
    d_start = sst_ref[l, d]
    d_len = jnp.where(has_parent, lens[d], 0)
    offs = lax.iota(jnp.int32, deg_cap)
    u = ind_ref[0, pl.ds(d_start, deg_cap)]  # [deg_cap]
    k_on = offs < d_len
    dup = jnp.concatenate([jnp.zeros((1,), bool), u[1:] == u[:-1]])
    ok = k_on & ~dup

    # --- membership in dom ∧ ¬used' ---------------------------------------
    u_c = jnp.clip(u, 0, wp * 32 - 1)
    word = u_c // 32
    bit = (u_c % 32).astype(jnp.uint32)
    in_base = (jnp.take(base[0], word) >> bit) & jnp.uint32(1)
    ok = ok & (in_base != 0)

    # --- sorted-intersection against the other parents' segments ----------
    def member(j, ok):
        seg = ind_ref[0, pl.ds(sst_ref[l, j], deg_cap)]
        seg = jnp.where(offs < sln_ref[l, j], seg, jnp.int32(SENTINEL))
        p = jnp.searchsorted(seg, u)
        hit = jnp.take(seg, jnp.clip(p, 0, deg_cap - 1)) == u
        skip = jnp.logical_not(real[j]) | (j == d)
        return ok & (skip | hit)

    ok = lax.fori_loop(0, mp, member, ok)

    # --- scatter survivors; parentless lanes keep the plain base ----------
    bits = jnp.where(ok, jnp.uint32(1) << bit, jnp.uint32(0))
    w_scatter = jnp.where(ok, word, wp)  # out-of-range ⇒ dropped
    walked = jnp.zeros((wp,), jnp.uint32).at[w_scatter].add(bits, mode="drop")
    child = jnp.where(has_parent, walked[None, :], base)

    depth = depth_ref[l]
    n_p = np_ref[0]
    is_match = valid & (depth + 1 >= n_p)
    want_child = valid & jnp.logical_not(is_match)
    child = jnp.where(want_child, child, jnp.uint32(0))
    child_ref[...] = child
    has_child = want_child & jnp.any(child != jnp.uint32(0))
    meta_ref[...] = jnp.stack(
        [
            valid.astype(jnp.int32),
            jnp.where(valid, v, -1),
            is_match.astype(jnp.int32),
            has_child.astype(jnp.int32),
        ]
    ).reshape(1, META_WIDTH)


@functools.partial(jax.jit, static_argnames=("deg_cap", "interpret"))
def csr_extend(
    indices: jnp.ndarray,  # [nnz_pad + deg_cap] int32 flat CSR columns
    dom_bits: jnp.ndarray,  # [p_pad, w] uint32
    seg_start: jnp.ndarray,  # [b, mp] int32 global segment offsets
    seg_len: jnp.ndarray,  # [b, mp] int32 (-1 on unused parent slots)
    child_pos: jnp.ndarray,  # [b] int32 order position of the child
    depth: jnp.ndarray,  # [b] int32 depth of the popped entry
    n_p: jnp.ndarray,  # scalar int32 actual pattern size
    used: jnp.ndarray,  # [b, w] uint32
    cand: jnp.ndarray,  # [b, w] uint32
    deg_cap: int = 8,
    interpret: bool = True,
):
    """One sparse fused expansion over ``b`` lanes.

    Same contract as `repro.kernels.extend_step.extend_step` with the
    scalar-prefetched row-id table replaced by per-parent CSR segment
    bounds: returns ``(cand2 [b, w], child_cand [b, w], meta [b, 4])``,
    ``meta`` columns ``(valid, v, is_match, has_child)``.  ``indices`` must
    be over-padded by ``deg_cap`` (`repro.core.extend.make_csr_plan_arrays`
    guarantees it) so segment slices never clamp.  ``interpret=True``
    executes the kernel body in Python on CPU (the validation mode for
    this container).
    """
    b, w = cand.shape
    mp = seg_len.shape[1]
    if mp == 0:  # degenerate plans: keep one neutral (unused) parent slot
        seg_start = jnp.zeros((b, 1), jnp.int32)
        seg_len = jnp.full((b, 1), -1, jnp.int32)
        mp = 1
    wp = pad_words(w)
    if wp != w:
        padw = ((0, 0), (0, wp - w))
        dom_bits = jnp.pad(dom_bits, padw)
        used = jnp.pad(used, padw)
        cand = jnp.pad(cand, padw)
    n_ind = indices.shape[0]
    n_pad = pad_words(n_ind)
    if n_pad != n_ind:
        indices = jnp.pad(indices, (0, n_pad - n_ind), constant_values=SENTINEL)

    grid = (b,)

    def lane_map(l, cpos_s, sst_s, sln_s, depth_s, np_s):
        return (l, 0)

    def dom_map(l, cpos_s, sst_s, sln_s, depth_s, np_s):
        return (cpos_s[l], 0)

    def ind_map(l, cpos_s, sst_s, sln_s, depth_s, np_s):
        return (0, 0)

    cand2, child, meta = pl.pallas_call(
        functools.partial(_kernel, mp=mp, deg_cap=deg_cap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, wp), lane_map),  # cand
                pl.BlockSpec((1, wp), lane_map),  # used
                pl.BlockSpec((1, wp), dom_map),  # dom_bits
                pl.BlockSpec((1, n_pad), ind_map),  # flat CSR indices
            ],
            out_specs=[
                pl.BlockSpec((1, wp), lane_map),  # cand2
                pl.BlockSpec((1, wp), lane_map),  # child_cand
                pl.BlockSpec((1, META_WIDTH), lane_map),  # meta
            ],
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, wp), jnp.uint32),
            jax.ShapeDtypeStruct((b, wp), jnp.uint32),
            jax.ShapeDtypeStruct((b, META_WIDTH), jnp.int32),
        ),
        interpret=interpret,
    )(
        child_pos.astype(jnp.int32),
        seg_start.astype(jnp.int32),
        seg_len.astype(jnp.int32),
        depth.astype(jnp.int32),
        jnp.asarray(n_p, jnp.int32).reshape((1,)),
        cand,
        used,
        dom_bits,
        indices.reshape(1, n_pad),
    )
    return cand2[:, :w], child[:, :w], meta


def _kernel_bucketed(
    cpos_ref, sst_ref, sln_ref, depth_ref, np_ref,  # scalar prefetch
    cand_ref, used_ref, dom_ref, ind_ref,  # operands
    cand2_ref, child_ref, meta_ref,  # outputs
    *, mp: int, deg_cap: int, chunk: int,
):
    """Degree-bucketed walk (DESIGN.md §10): the driver segment is consumed
    in ``chunk``-wide ``pl.ds`` loads, ``fori_loop``-bounded by the lane's
    pow2 degree-bucket cap instead of the global ``deg_cap``, and parent
    membership is a branchless binary search on the flat ``indices`` block
    at prefetched per-parent bounds — no ``deg_cap``-wide segment loads."""
    l = pl.program_id(0)
    wp = cand_ref.shape[1]
    n_pad = ind_ref.shape[1]

    c = cand_ref[...]
    valid, v, vmask = _lowest_bit(c)
    cand2_ref[...] = c ^ vmask
    base = dom_ref[...] & ~used_ref[...] & ~vmask  # [1, wp]

    # --- driver segment + its pow2 bucket cap -----------------------------
    lens = sln_ref[l, :]  # [mp] from SMEM
    real = lens >= 0
    has_parent = jnp.any(real)
    d = jnp.argmax(real)
    d_start = sst_ref[l, d]
    d_len = jnp.where(has_parent, lens[d], 0)
    m = jnp.maximum(d_len, 1) - 1
    for shift in (1, 2, 4, 8, 16):
        m = m | (m >> shift)
    bcap = jnp.minimum(jnp.maximum(m + 1, chunk), deg_cap)
    trips = (bcap + chunk - 1) // chunk

    ind = ind_ref[0, :]  # [n_pad] block value for searched gathers
    lo0 = sst_ref[l, :]
    hi0 = lo0 + jnp.maximum(lens, 0)
    search_iters = max(1, deg_cap).bit_length() + 1
    offs_c = lax.iota(jnp.int32, chunk)

    def member(j, carry):
        u, ok = carry
        lo = jnp.full((chunk,), lo0[j], jnp.int32)
        hi = jnp.full((chunk,), hi0[j], jnp.int32)

        def step(_, lh):
            lo, hi = lh
            pred = lo < hi
            mid = (lo + hi) >> 1
            val = jnp.take(ind, jnp.clip(mid, 0, n_pad - 1))
            go = pred & (val < u)
            return jnp.where(go, mid + 1, lo), jnp.where(pred & ~go, mid, hi)

        lo, _ = lax.fori_loop(0, search_iters, step, (lo, hi))
        hit = (lo < hi0[j]) & (jnp.take(ind, jnp.clip(lo, 0, n_pad - 1)) == u)
        skip = jnp.logical_not(real[j]) | (j == d)
        return u, ok & (skip | hit)

    def trip(i, carry):
        prev, walked = carry
        u = ind_ref[0, pl.ds(d_start + i * chunk, chunk)]  # [chunk]
        k_on = (i * chunk + offs_c) < d_len
        left = jnp.concatenate([prev.reshape(1), u[:-1]])
        ok = k_on & (u != left)  # rows are deduped; boundary-safe defense
        rem = jnp.clip(d_len - i * chunk, 0, chunk)
        last = jnp.take(u, jnp.maximum(rem - 1, 0))
        prev2 = jnp.where(rem > 0, last, prev)

        u_c = jnp.clip(u, 0, wp * 32 - 1)
        word = u_c // 32
        bit = (u_c % 32).astype(jnp.uint32)
        in_base = (jnp.take(base[0], word) >> bit) & jnp.uint32(1)
        ok = ok & (in_base != 0)
        _, ok = lax.fori_loop(0, mp, member, (u, ok))
        bits = jnp.where(ok, jnp.uint32(1) << bit, jnp.uint32(0))
        w_scatter = jnp.where(ok, word, wp)  # out-of-range ⇒ dropped
        walked = walked.at[w_scatter].add(bits, mode="drop")
        return prev2, walked

    _, walked = lax.fori_loop(
        0, trips, trip, (jnp.int32(-1), jnp.zeros((wp,), jnp.uint32))
    )
    child = jnp.where(has_parent, walked[None, :], base)

    depth = depth_ref[l]
    n_p = np_ref[0]
    is_match = valid & (depth + 1 >= n_p)
    want_child = valid & jnp.logical_not(is_match)
    child = jnp.where(want_child, child, jnp.uint32(0))
    child_ref[...] = child
    has_child = want_child & jnp.any(child != jnp.uint32(0))
    meta_ref[...] = jnp.stack(
        [
            valid.astype(jnp.int32),
            jnp.where(valid, v, -1),
            is_match.astype(jnp.int32),
            has_child.astype(jnp.int32),
        ]
    ).reshape(1, META_WIDTH)


@functools.partial(jax.jit, static_argnames=("deg_cap", "chunk", "interpret"))
def csr_extend_bucketed(
    indices: jnp.ndarray,  # [nnz_pad + deg_cap] int32 flat CSR columns
    dom_bits: jnp.ndarray,  # [p_pad, w] uint32
    seg_start: jnp.ndarray,  # [b, mp] int32 global segment offsets
    seg_len: jnp.ndarray,  # [b, mp] int32 (-1 on unused parent slots)
    child_pos: jnp.ndarray,  # [b] int32 order position of the child
    depth: jnp.ndarray,  # [b] int32 depth of the popped entry
    n_p: jnp.ndarray,  # scalar int32 actual pattern size
    used: jnp.ndarray,  # [b, w] uint32
    cand: jnp.ndarray,  # [b, w] uint32
    deg_cap: int = 8,
    chunk: int = 8,
    interpret: bool = True,
):
    """Bucketed sparse fused expansion over ``b`` lanes (DESIGN.md §10).

    Identical contract and results to :func:`csr_extend`; only the walk
    schedule differs — each lane visits its driver segment at the row's
    pow2 degree-bucket width, so tail rows cost ``O(chunk)`` instead of the
    global hub-sized ``deg_cap``.  Oracle:
    `repro.kernels.ref.csr_extend_bucketed_ref`.
    """
    b, w = cand.shape
    mp = seg_len.shape[1]
    if mp == 0:  # degenerate plans: keep one neutral (unused) parent slot
        seg_start = jnp.zeros((b, 1), jnp.int32)
        seg_len = jnp.full((b, 1), -1, jnp.int32)
        mp = 1
    wp = pad_words(w)
    if wp != w:
        padw = ((0, 0), (0, wp - w))
        dom_bits = jnp.pad(dom_bits, padw)
        used = jnp.pad(used, padw)
        cand = jnp.pad(cand, padw)
    n_ind = indices.shape[0]
    n_pad = pad_words(n_ind)
    if n_pad != n_ind:
        indices = jnp.pad(indices, (0, n_pad - n_ind), constant_values=SENTINEL)

    grid = (b,)

    def lane_map(l, cpos_s, sst_s, sln_s, depth_s, np_s):
        return (l, 0)

    def dom_map(l, cpos_s, sst_s, sln_s, depth_s, np_s):
        return (cpos_s[l], 0)

    def ind_map(l, cpos_s, sst_s, sln_s, depth_s, np_s):
        return (0, 0)

    cand2, child, meta = pl.pallas_call(
        functools.partial(_kernel_bucketed, mp=mp, deg_cap=deg_cap, chunk=chunk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, wp), lane_map),  # cand
                pl.BlockSpec((1, wp), lane_map),  # used
                pl.BlockSpec((1, wp), dom_map),  # dom_bits
                pl.BlockSpec((1, n_pad), ind_map),  # flat CSR indices
            ],
            out_specs=[
                pl.BlockSpec((1, wp), lane_map),  # cand2
                pl.BlockSpec((1, wp), lane_map),  # child_cand
                pl.BlockSpec((1, META_WIDTH), lane_map),  # meta
            ],
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, wp), jnp.uint32),
            jax.ShapeDtypeStruct((b, wp), jnp.uint32),
            jax.ShapeDtypeStruct((b, META_WIDTH), jnp.int32),
        ),
        interpret=interpret,
    )(
        child_pos.astype(jnp.int32),
        seg_start.astype(jnp.int32),
        seg_len.astype(jnp.int32),
        depth.astype(jnp.int32),
        jnp.asarray(n_p, jnp.int32).reshape((1,)),
        cand,
        used,
        dom_bits,
        indices.reshape(1, n_pad),
    )
    return cand2[:, :w], child[:, :w], meta
