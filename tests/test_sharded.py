"""Mesh-sharded execution layer (DESIGN.md §2.4).

Single-device assertions always run; the multi-device sweep needs virtual
devices (device count is locked at first jax init, so conftest keeps tests
on the real 1-device platform) and runs in CI as a separate process:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m pytest tests/test_sharded.py -q
"""

import jax
import numpy as np
import pytest

from repro.core import EngineConfig, Enumerator, SubgraphIndex
from repro.core import engine as eng
from repro.core.graph import PackedGraph
from repro.core.plan import build_plan
from tests.conftest import extract_connected_pattern, random_graph

CFG = EngineConfig(n_workers=4, expand_width=2)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=N)",
)


def _case(rng, n=40, m=120):
    tgt = random_graph(rng, n, m, n_labels=3)
    pat = extract_connected_pattern(rng, tgt, 5)
    return tgt, pat


def _result_tuple(r):
    return (r.matches, r.states, r.steps, r.steals, r.steal_rounds)


def test_mesh_none_is_the_existing_engine(rng):
    """Enumerator(mesh=None) must reproduce eng.run() exactly — the
    single-device fallback is the pre-sharding engine, untouched."""
    tgt, pat = _case(rng)
    plan = build_plan(pat, PackedGraph.from_graph(tgt))
    direct = eng.run(plan, CFG)

    session = Enumerator(SubgraphIndex.build(tgt), config=CFG, mesh=None)
    ms = session.run(session.prepare(pat))
    assert (ms.matches, ms.states, ms.steps, ms.steals) == (
        direct.matches, direct.states, direct.steps, direct.steals,
    )
    np.testing.assert_array_equal(ms.per_worker_states, direct.per_worker_states)
    np.testing.assert_array_equal(ms.per_worker_matches, direct.per_worker_matches)


def test_mesh_size_one_bit_identical(rng):
    """On a 1-device mesh every collective is an identity: the shard_map
    engine must agree with the plain engine counter-for-counter."""
    tgt, pat = _case(rng)
    plan = build_plan(pat, PackedGraph.from_graph(tgt))
    ref = eng.run(plan, CFG)
    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    sh = eng.run(plan, CFG, mesh=mesh)
    assert _result_tuple(sh) == _result_tuple(ref)
    np.testing.assert_array_equal(sh.per_worker_states, ref.per_worker_states)
    np.testing.assert_array_equal(sh.per_worker_steals, ref.per_worker_steals)


def test_session_mesh_int_coercion_and_snapping(rng):
    tgt, _ = _case(rng)
    s = Enumerator(SubgraphIndex.build(tgt), config=CFG, mesh=1)
    assert s.mesh is not None and s.config.n_workers == CFG.n_workers
    with pytest.raises(ValueError):
        Enumerator(SubgraphIndex.build(tgt), config=CFG,
                   mesh=len(jax.local_devices()) + 1)


@multi_device
def test_multi_device_results_identical(rng):
    """The acceptance invariant: sharding over 2 (and 4) devices changes
    nothing — not even per-worker counters."""
    tgt, pat = _case(rng, n=48, m=160)
    plan = build_plan(pat, PackedGraph.from_graph(tgt))
    cfg = EngineConfig(n_workers=8, expand_width=4)
    ref = eng.run(plan, cfg)
    for n_dev in (2, 4):
        if n_dev > len(jax.devices()) or cfg.n_workers % n_dev:
            continue
        mesh = jax.make_mesh((n_dev,), ("data",), devices=jax.devices()[:n_dev])
        sh = eng.run(plan, cfg, mesh=mesh)
        assert _result_tuple(sh) == _result_tuple(ref), n_dev
        np.testing.assert_array_equal(sh.per_worker_states, ref.per_worker_states)
        np.testing.assert_array_equal(sh.per_worker_steals, ref.per_worker_steals)


@multi_device
def test_multi_device_session_and_worker_snapping(rng):
    tgt, pat = _case(rng, n=48, m=160)
    base = Enumerator(SubgraphIndex.build(tgt), n_workers=8, expand_width=4)
    ref = base.run(base.prepare(pat))

    n_dev = 2
    s = Enumerator(SubgraphIndex.build(tgt), n_workers=7, expand_width=4,
                   mesh=n_dev)
    assert s.config.n_workers == 8  # snapped up to a multiple of the mesh
    ms = s.run(s.prepare(pat))
    assert ms.matches == ref.matches  # match count is V-invariant

    # batch/stream run through the sharded single path, in order
    qs = [s.prepare(pat, name=f"q{i}") for i in range(3)]
    out = s.run_batch(qs)
    assert [m.query_index for m in out] == [0, 1, 2]
    assert all(m.matches == ref.matches for m in out)


@multi_device
def test_multi_device_engine_rejects_indivisible_workers():
    mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    with pytest.raises(ValueError):
        eng.make_sharded_engine_fn(EngineConfig(n_workers=3), mesh)


@multi_device
def test_mesh_signature_distinguishes_cache_entries(rng):
    """Same config, different meshes must not share a compiled engine."""
    tgt, pat = _case(rng, n=48, m=160)
    idx = SubgraphIndex.build(tgt)
    a = Enumerator(idx, n_workers=8, expand_width=4, mesh=1)
    b = Enumerator(idx, n_workers=8, expand_width=4, mesh=2)
    assert eng.mesh_signature(a.mesh) != eng.mesh_signature(b.mesh)
    assert a.run(a.prepare(pat)).matches == b.run(b.prepare(pat)).matches
