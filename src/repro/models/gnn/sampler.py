"""Neighbor sampling for minibatch GNN training (GraphSAGE-style), plus the
paper-derived bucket balancer for skewed batched graphs.

``NeighborSampler`` is a real host-side (numpy) fanout sampler over CSR
adjacency: per minibatch it samples up to ``fanout[k]`` neighbors per
frontier node per hop, relabels the union subgraph to contiguous local ids,
and emits fixed-shape (padded) arrays ready for the jitted train step —
static shapes are what keeps the step compilable.

``balance_buckets`` spreads variable-size graphs/subgraphs across shards
with the scheduler's LPT policy — the work-stealing insight applied to
irregular minibatches (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import balance_assignment


@dataclasses.dataclass
class SampledBlock:
    """Padded union subgraph for one minibatch."""

    feats_idx: np.ndarray  # [n_pad] global node id per local node (-1 pad)
    src: np.ndarray  # [e_pad] local ids (pad edges point at node 0 w/ weight 0 — masked by label)
    dst: np.ndarray  # [e_pad]
    labels: np.ndarray  # [n_pad]; only seed rows carry labels, rest -1
    n_nodes: int
    n_edges: int


def block_shape(batch_nodes: int, fanout: Sequence[int]) -> Tuple[int, int]:
    """Worst-case (n_pad, e_pad) for a fanout-sampled block."""
    n = batch_nodes
    n_pad = batch_nodes
    e_pad = 0
    frontier = batch_nodes
    for f in fanout:
        e_pad += frontier * f
        frontier = frontier * f
        n_pad += frontier
    return n_pad, e_pad


class NeighborSampler:
    def __init__(
        self,
        indptr: np.ndarray,
        indices: np.ndarray,
        labels: np.ndarray,
        fanout: Sequence[int],
        seed: int = 0,
    ):
        self.indptr = indptr
        self.indices = indices
        self.labels = labels
        self.fanout = tuple(fanout)
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        n_pad, e_pad = block_shape(len(seeds), self.fanout)
        local = {int(s): i for i, s in enumerate(seeds)}
        order: List[int] = [int(s) for s in seeds]
        src_l: List[int] = []
        dst_l: List[int] = []
        frontier = list(seeds)
        for f in self.fanout:
            nxt: List[int] = []
            for u in frontier:
                s, e = int(self.indptr[u]), int(self.indptr[u + 1])
                deg = e - s
                if deg == 0:
                    continue
                take = min(f, deg)
                picks = self.rng.choice(deg, size=take, replace=False) + s
                for p in picks:
                    v = int(self.indices[p])
                    if v not in local:
                        local[v] = len(order)
                        order.append(v)
                        nxt.append(v)
                    # message flows neighbor -> node
                    src_l.append(local[v])
                    dst_l.append(local[u])
            frontier = nxt

        n, m = len(order), len(src_l)
        feats_idx = np.full(n_pad, -1, np.int64)
        feats_idx[:n] = order
        src = np.zeros(e_pad, np.int32)
        dst = np.zeros(e_pad, np.int32)
        src[:m] = src_l
        dst[:m] = dst_l
        # padding edges become self-loops on a dummy last node so they do not
        # perturb real aggregations
        if m < e_pad and n < n_pad:
            src[m:] = n_pad - 1
            dst[m:] = n_pad - 1
        labels = np.full(n_pad, -1, np.int64)
        labels[: len(seeds)] = self.labels[np.asarray(seeds, np.int64)]
        return SampledBlock(
            feats_idx=feats_idx, src=src, dst=dst, labels=labels, n_nodes=n, n_edges=m
        )


def balance_buckets(sizes: Sequence[int], n_shards: int) -> np.ndarray:
    """Assign variable-size graphs to shards, minimizing makespan (LPT)."""
    return balance_assignment(np.asarray(sizes, np.float64), n_shards)
