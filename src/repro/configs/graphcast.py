"""graphcast — 16L d_hidden=512 mesh_refinement=6 aggregator=sum n_vars=227.
[arXiv:2212.12794; unverified]

Encoder-processor-decoder runs on a synthetic mesh overlay for the generic
GNN shapes (grid = target graph, mesh = N/4 subsampled nodes, fanout-4
bipartite edges — DESIGN.md §4); the icosahedral weather configuration
(refinement 6 ⇒ 40,962 mesh nodes, 0.25° grid) is exercised by
``examples/weather_graphcast.py``.
"""

from repro.configs.gnn_common import GnnModelDef, GnnShape, make_gnn_arch
from repro.models.gnn import graphcast

CFG = graphcast.GraphCastConfig(
    n_layers=16, d_hidden=512, mesh_refinement=6, aggregator="sum", n_vars=227
)
SMOKE = graphcast.GraphCastConfig(n_layers=2, d_hidden=32, n_vars=8)


def fwd_flops(cfg: graphcast.GraphCastConfig, shape: GnnShape) -> float:
    ng, d = shape.n_nodes, cfg.d_hidden
    nm = max(8, ng // 4)
    e1 = ng * 4  # g2m
    e2 = nm * 8  # mesh
    e3 = ng * 4  # m2g
    f = 2.0 * ng * shape.d_feat * d + 2.0 * (nm + e1 + e2 + e3) * cfg.d_edge_in * d
    def interact(e, n):
        return 2.0 * e * (3 * d * d + d * d) + 2.0 * n * (2 * d * d + d * d)
    f += interact(e1, nm)
    f += cfg.n_layers * interact(e2, nm)
    f += interact(e3, ng)
    f += 2.0 * ng * (d * d + d * shape.d_out)
    return f


ARCH = make_gnn_arch(
    GnnModelDef(
        name="graphcast",
        cfg=CFG,
        param_specs=graphcast.param_specs,
        forward=lambda params, cfg, batch: graphcast.forward(params, cfg, batch),
        fwd_flops=fwd_flops,
        with_mesh=True,
        smoke_cfg=SMOKE,
        notes="Deep mesh processor (16 scanned layers); heaviest GNN cell.",
    )
)
