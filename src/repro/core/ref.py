"""Reference oracles for subgraph enumeration.

Two independent implementations used to validate the vectorized engine:

* :func:`brute_force_count` — exhaustive check of every injective mapping
  (tiny graphs only).  Fully independent of the RI machinery.
* :func:`ref_enumerate` — a sequential recursive RI/RI-DS search that shares
  the :class:`~repro.core.plan.SearchPlan` preprocessing but walks the tree
  with plain Python sets.  Its ``states`` counter defines the search-space
  metric reported in the paper's figures: a state is counted each time a
  consistent extension ``M ∪ {μ_d → v}`` is formed.

The engine must agree with ``ref_enumerate`` on *both* match count and states
explored (the search space is deterministic given the rule set), and with
``brute_force_count`` on matches.

For dynamic graphs (DESIGN.md §8), :func:`ref_delta` is the incremental
oracle: it replays an edit set one arc at a time on the growing graph —
Das et al.'s stream view — re-enumerating fully at each step, and must
agree with ``Enumerator.run_delta`` on the exact sets of invalidated and
new node-indexed mappings.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

import numpy as np

from repro.core.graph import Graph, PackedGraph, bitmap_to_indices
from repro.core.plan import SearchPlan, build_plan


def _edge_set(g: Graph):
    return {
        (int(u), int(v)): int(l)
        for u, v, l in zip(g.src.tolist(), g.dst.tolist(), g.edge_labels.tolist())
    }


def brute_force_count(pattern: Graph, target: Graph) -> int:
    """Count isomorphic (non-induced) subgraphs by exhaustive enumeration of
    injective mappings.  Only usable for very small inputs."""
    pe = _edge_set(pattern)
    te = _edge_set(target)
    count = 0
    for perm in itertools.permutations(range(target.n), pattern.n):
        if any(pattern.labels[p] != target.labels[perm[p]] for p in range(pattern.n)):
            continue
        ok = True
        for (u, v), l in pe.items():
            tl = te.get((perm[u], perm[v]))
            if tl is None or tl != l:
                ok = False
                break
        count += ok
    return count


@dataclasses.dataclass
class RefResult:
    matches: int
    states: int
    mappings: Optional[List[Tuple[int, ...]]] = None  # order-position -> target


def ref_enumerate(
    pattern: Graph,
    target: Graph,
    variant: str = "ri-ds-si-fc",
    packed: Optional[PackedGraph] = None,
    plan: Optional[SearchPlan] = None,
    record_mappings: bool = False,
    max_states: Optional[int] = None,
) -> RefResult:
    """Sequential reference RI/RI-DS enumeration over a SearchPlan.

    Semantics match the vectorized engine exactly: per position, candidates
    are ``domain ∧ ¬used ∧ (adjacency rows of mapped parents)``; every
    candidate accepted increments ``states``; full-depth candidates are
    matches.
    """
    if plan is None:  # a given plan already carries everything (and a
        # CSR-only plan exists precisely to avoid this dense packing)
        packed = packed or PackedGraph.from_graph(target)
        plan = build_plan(pattern, packed, variant=variant)
    if not plan.satisfiable or pattern.n == 0:
        return RefResult(matches=0, states=0, mappings=[] if record_mappings else None)

    n_p = plan.n_p
    dom = [set(bitmap_to_indices(plan.dom_bits[i]).tolist()) for i in range(n_p)]
    adj_sets = {}

    def adj(lab: int, d: int, t: int) -> set:
        key = (lab, d, t)
        if key not in adj_sets:
            if plan.csr is not None and plan.adj_bits.shape[2] == 0:
                # CSR-only plan (build_csr_plan): read the adjacency plane's
                # sorted segment instead of the never-materialized bitmaps
                ptr = plan.csr.indptr[lab * 2 + d]
                adj_sets[key] = set(plan.csr.indices[ptr[t]:ptr[t + 1]].tolist())
            else:
                adj_sets[key] = set(
                    bitmap_to_indices(plan.adj_bits[lab, d, t]).tolist()
                )
        return adj_sets[key]

    mapping = [-1] * n_p
    used = set()
    out = RefResult(matches=0, states=0, mappings=[] if record_mappings else None)

    def candidates(pos: int) -> List[int]:
        cand = dom[pos] - used
        for j in range(int(plan.n_parents[pos])):
            pp = int(plan.parent_pos[pos, j])
            pd = int(plan.parent_dir[pos, j])
            pl = int(plan.parent_elab[pos, j])
            cand = cand & adj(pl, pd, mapping[pp])
            if not cand:
                break
        return sorted(cand)

    def rec(pos: int) -> None:
        if max_states is not None and out.states >= max_states:
            return
        for v in candidates(pos):
            out.states += 1
            if pos == n_p - 1:
                out.matches += 1
                if record_mappings:
                    out.mappings.append(tuple(mapping[:pos] + [v]))
            else:
                mapping[pos] = v
                used.add(v)
                rec(pos + 1)
                used.discard(v)
                mapping[pos] = -1
            if max_states is not None and out.states >= max_states:
                return

    rec(0)
    return out


# ---------------------------------------------------------------------------
# out-of-core partitioned oracle (DESIGN.md §9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RefPartitionedResult:
    """Sequential mirror of the out-of-core scheduling loop: match/state
    counts must equal :func:`ref_enumerate` (partitioning changes
    scheduling, never the search tree), and the spill accounting gives an
    independent model the engine's stats are checked against."""

    matches: int
    states: int
    mappings: Optional[List[Tuple[int, ...]]] = None
    n_parts: int = 1
    visits: int = 0  # partition swap-ins (first residency included)
    spilled: int = 0  # children parked for a non-resident partition
    dead_spills: int = 0  # spilled entries whose candidates died at intake


def ref_enumerate_partitioned(
    pattern: Graph,
    target: Graph,
    n_parts: int,
    variant: str = "ri-ds-si-fc",
    packed: Optional[PackedGraph] = None,
    plan: Optional[SearchPlan] = None,
    record_mappings: bool = False,
) -> RefPartitionedResult:
    """Sequential numpy oracle for partitioned enumeration (DESIGN.md §9).

    Mirrors the engine's outer scheduling loop exactly: target rows are
    partitioned with the same degree-balanced partitioner
    (`repro.core.extend.plan_partitions`); only the resident partition's
    adjacency rows may be read; a child whose candidate set survives its
    resident parents but still owes intersections to non-resident parents
    is parked in the pool of its first pending parent's partition; the
    resident partition is enumerated to quiescence, then the deepest pool's
    partition is swapped in and its entries finish constraining at intake
    (dead / re-spill / resume).  Because only fully constrained entries are
    ever extended, ``matches`` and ``states`` are identical to the
    monolithic :func:`ref_enumerate` — the invariant the conformance suite
    gates — while ``visits`` / ``spilled`` / ``dead_spills`` model the
    scheduling itself.
    """
    from repro.core.extend import plan_partitions

    if plan is None:
        packed = packed or PackedGraph.from_graph(target)
        plan = build_plan(pattern, packed, variant=variant)
    out = RefPartitionedResult(
        matches=0, states=0, mappings=[] if record_mappings else None,
        n_parts=max(1, n_parts),
    )
    if not plan.satisfiable or pattern.n == 0:
        return out
    pp = plan_partitions(plan, max(1, n_parts))
    node_start = pp.node_start
    n_p = plan.n_p
    dom = [set(bitmap_to_indices(plan.dom_bits[i]).tolist()) for i in range(n_p)]
    adj_sets = {}

    def adj(lab: int, d: int, t: int) -> set:
        key = (lab, d, t)
        if key not in adj_sets:
            if plan.csr is not None and plan.adj_bits.shape[2] == 0:
                ptr = plan.csr.indptr[lab * 2 + d]
                adj_sets[key] = set(plan.csr.indices[ptr[t]:ptr[t + 1]].tolist())
            else:
                adj_sets[key] = set(
                    bitmap_to_indices(plan.adj_bits[lab, d, t]).tolist()
                )
        return adj_sets[key]

    def part_of(t: int) -> int:
        return int(np.searchsorted(node_start, t, side="right") - 1)

    # per-partition pools of parked entries (pos, mapping, cand, pending
    # parent slots) — the host-side image of the engine's spill rings
    pools: List[List[tuple]] = [[] for _ in range(pp.n_parts)]
    lo = hi = 0  # resident row range

    def expand(pos: int, mapping: List[int], cand: set) -> None:
        """DFS a fully constrained entry within the resident partition."""
        for v in sorted(cand):
            out.states += 1
            if pos == n_p - 1:
                out.matches += 1
                if record_mappings:
                    out.mappings.append(tuple(mapping + [v]))
                continue
            m2 = mapping + [v]
            used = set(m2)
            cpos = pos + 1
            cand2 = dom[cpos] - used
            pend: List[int] = []
            for j in range(int(plan.n_parents[cpos])):
                if not cand2:
                    break
                t = m2[int(plan.parent_pos[cpos, j])]
                if lo <= t < hi:
                    cand2 = cand2 & adj(
                        int(plan.parent_elab[cpos, j]),
                        int(plan.parent_dir[cpos, j]), t,
                    )
                else:
                    pend.append(j)
            if not cand2:
                continue
            if pend:
                out.spilled += 1
                tgt = part_of(m2[int(plan.parent_pos[cpos, pend[0]])])
                pools[tgt].append((cpos, m2, cand2, tuple(pend)))
            else:
                expand(cpos, m2, cand2)

    # Roots prefill the pools per owning partition (DESIGN.md §10), exactly
    # mirroring engine.partition_root_entries — depth-0 children extend while
    # their parent rows are resident instead of spilling from partition 0.
    for pid in range(pp.n_parts):
        plo, phi = int(node_start[pid]), int(node_start[pid + 1])
        rcand = {t for t in dom[0] if plo <= t < phi}
        if rcand:
            pools[pid].append((0, [], rcand, ()))

    cur = next((pid for pid in range(pp.n_parts) if pools[pid]), None)
    while cur is not None:
        lo, hi = int(node_start[cur]), int(node_start[cur + 1])
        out.visits += 1
        while pools[cur]:
            pos, m2, cand2, pend = pools[cur].pop()
            npend: List[int] = []
            for j in pend:
                if not cand2:
                    break
                t = m2[int(plan.parent_pos[pos, j])]
                if lo <= t < hi:
                    cand2 = cand2 & adj(
                        int(plan.parent_elab[pos, j]),
                        int(plan.parent_dir[pos, j]), t,
                    )
                else:
                    npend.append(j)
            if not cand2:
                out.dead_spills += 1
                continue
            if npend:
                tgt = part_of(m2[int(plan.parent_pos[pos, npend[0]])])
                pools[tgt].append((pos, m2, cand2, tuple(npend)))
                continue
            expand(pos, m2, cand2)
        nxt, depth_best = None, 0
        for pid in range(pp.n_parts):
            if len(pools[pid]) > depth_best:
                nxt, depth_best = pid, len(pools[pid])
        if nxt is None:
            break
        cur = nxt
    if record_mappings:
        out.mappings.sort()
    return out


# ---------------------------------------------------------------------------
# incremental oracle (DESIGN.md §8)
# ---------------------------------------------------------------------------

def ref_node_mappings(
    pattern: Graph, target: Graph, variant: str = "ri-ds-si-fc"
) -> List[Tuple[int, ...]]:
    """Sorted node-indexed mappings (``m[pattern_node] = target_node``) of a
    full sequential enumeration — the ordering-independent form delta
    results are compared in."""
    packed = PackedGraph.from_graph(target)
    plan = build_plan(pattern, packed, variant=variant)
    res = ref_enumerate(
        pattern, target, variant=variant, packed=packed, plan=plan,
        record_mappings=True,
    )
    order = [int(x) for x in plan.order[: plan.n_p]]
    out = []
    for row in res.mappings:
        nm = [0] * len(order)
        for i, t in enumerate(row):
            nm[order[i]] = int(t)
        out.append(tuple(nm))
    return sorted(out)


@dataclasses.dataclass
class RefDeltaResult:
    """Incremental-oracle result: sorted node-indexed mapping sets."""

    added: List[Tuple[int, ...]]
    removed: List[Tuple[int, ...]]
    n_old: int

    @property
    def matches(self) -> int:
        return self.n_old - len(self.removed) + len(self.added)


def ref_delta(
    pattern: Graph,
    old_target: Graph,
    added=(),
    removed=(),
    variant: str = "ri-ds-si-fc",
) -> RefDeltaResult:
    """Incremental enumeration oracle, independent of the anchored engine
    path: removals invalidate old matches by arc-membership test; then the
    effective insertions are replayed **one arc at a time** on the growing
    graph, fully re-enumerating at each step and crediting each match to
    the step whose arc it uses (a match needing arc ``i`` cannot exist
    before step ``i``, so this partitions the new matches exactly).
    Mirrors ``SubgraphIndex.update``'s set semantics: insert∩remove of one
    arc cancels, duplicate inserts and removals of absent arcs drop out.
    """
    from repro.core.delta import apply_delta, normalize_edges, pattern_edge_triples

    adds = normalize_edges(added)
    rems = normalize_edges(removed)
    cancel = set(adds) & set(rems)
    old_arcs = set(
        zip(
            old_target.src.tolist(),
            old_target.dst.tolist(),
            old_target.edge_labels.tolist(),
        )
    )
    eff_add = tuple(t for t in adds if t not in cancel and t not in old_arcs)
    eff_rem = tuple(t for t in rems if t not in cancel and t in old_arcs)

    old_maps = ref_node_mappings(pattern, old_target, variant)
    pe = pattern_edge_triples(pattern)
    rset = set(eff_rem)
    removed_maps = [
        m for m in old_maps if any((m[u], m[v], l) in rset for (u, v, l) in pe)
    ]

    g = apply_delta(old_target, removed=eff_rem)
    added_maps: List[Tuple[int, ...]] = []
    for arc in eff_add:
        g = apply_delta(g, added=[arc])
        added_maps.extend(
            m
            for m in ref_node_mappings(pattern, g, variant)
            if any((m[u], m[v], l) == arc for (u, v, l) in pe)
        )
    return RefDeltaResult(
        added=sorted(added_maps), removed=removed_maps, n_old=len(old_maps)
    )
