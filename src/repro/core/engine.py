"""Frontier-vectorized parallel RI/RI-DS search engine — the driver layer.

The TPU-native form of the paper's work-stealing DFS (DESIGN.md §2),
split into a layered pipeline (DESIGN.md §6): `repro.core.frontier` owns
the ring-buffer stack state and ops, `repro.core.extend` the expansion
step behind the ``StepBackend`` seam (``step_backend="jnp"`` loose-ops
reference / ``"pallas"`` fused `repro.kernels.extend_step` kernel), and
this module only the ``lax.while_loop`` drivers, the steal rounds
(`repro.core.scheduler` decides, this module moves entries), and the
``shard_map`` glue.  **Both** execution paths call the one shared step:

* **single device** (``run(plan, cfg)``): all ``V`` workers in one array
  program; the steal round is plain gathers/scatters over ``V``.
* **mesh-sharded** (``run(plan, cfg, mesh=...)``): the ``V`` axis shards
  over the mesh ``data`` axis via ``shard_map`` (DESIGN.md §2.4); steal
  rounds all-gather occupancy + donor rows, every device computes the
  *same* `repro.core.scheduler.plan_steals`, termination is a cross-device
  ``lax.psum``.  With ``D == 1`` the collectives are identities and
  results are bit-identical to the single-device path.

Counters are per-worker int32 (DESIGN.md §2.5); cross-query aggregation
happens on host in int64.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.core import extend, frontier, scheduler
from repro.core.plan import SearchPlan

# Re-exports: the state/plan layers moved out in the §6 split but remain
# importable from the engine (configs/sge.py, session, tests, dryrun).
from repro.core.extend import (  # noqa: F401
    CSR_PLAN_LOGICAL, CsrPlanArrays, PLAN_LOGICAL, PartPlanArrays, PlanArrays,
    abstract_csr_plan_arrays, abstract_plan_arrays, is_csr_only,
    make_csr_plan_arrays, make_part_plan_arrays, make_plan_arrays,
    part_plan_partition_specs, part_resident_nbytes, plan_arrays_for,
    plan_partition_specs, plan_partition_specs_for, plan_partitions,
    resolve_step_backend, resolve_step_backend_for_plan,
)
from repro.core.frontier import (  # noqa: F401
    STATE_LOGICAL, EngineState, SpillState, abstract_engine_state, init_state,
    spill_partition_specs, state_partition_specs,
)
from repro.core.graph import bitmap_from_indices


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine parameters.

    Attributes:
      n_workers: number of (virtual) workers ``V``.  On a mesh, ``V`` is
        sharded over the ``data`` axis; on one device all ``V`` run vectorized
        (used by the CPU benchmarks to reproduce the paper's worker sweeps).
      expand_width: entries expanded per worker per step (SIMD lane count).
      steal_chunk: entries a donor offers per steal round — the paper's task
        group size (Fig. 4: 4 is best).
      keep_min: donors never drop below this size.
      recv_cap: max entries a receiver accepts per round.
      rebalance_interval: steps between steal rounds.
      work_stealing: disable to reproduce the paper's Fig. 3 ablation.
      stack_cap: ring-buffer capacity per worker; 0 = auto
        (``expand_width * (p_pad + 2) + steal_chunk + 8``).
      max_steps: safety bound on outer loop iterations (0 = 2**30).
      collect_matches: if > 0, materialize up to this many mappings per worker
        into a ring buffer (the paper's tools print matches; counting is the
        benchmarked mode).
      step_backend: which ``StepBackend`` expands lanes (DESIGN.md §6.2):
        ``"jnp"`` (loose-ops reference), ``"pallas"`` (the fused
        `repro.kernels.extend_step` kernel — interpret mode off-TPU),
        ``"csr"`` (sparse CSR adjacency walk for huge targets, DESIGN.md
        §6.4), or ``"auto"`` (``csr`` past ``extend.CSR_AUTO_NT`` target
        nodes, else ``jnp``).
      use_pallas: with ``step_backend="jnp"``, route only the
        candidate-bitmap AND through `repro.kernels.candidate_mask` (the
        pre-seam kerneling point; the fused backend subsumes it); with
        ``"csr"``, route the CSR walk through `repro.kernels.csr_extend`.
      store_used: keep per-entry used-bitmaps on the stack (True) or
        recompute them from the mapping at expansion time (False; refuted
        as a default by §Perf iteration 7 — see EXPERIMENTS.md §Perf).
      n_partitions: with ``step_backend="partitioned"``, how many
        contiguous row partitions the target streams through (0 → 1).  The
        session derives it from ``memory_budget_bytes``
        (`repro.core.session.Enumerator`).
      spill_cap: per-worker spill-ring capacity under the partitioned
        backend; 0 = auto (see :meth:`resolved_spill_cap`).
      root_seeding: how worker stacks are first populated (DESIGN.md §10):
        ``"vertex"`` — the classic depth-0 root split over the first order
        position's domain; ``"edge"`` — enumerate the plan's seed edge
        class (``plan.seed_edge``, selected by
        `repro.core.ordering.select_seed_edge`) directly into depth-1
        entries, shrinking the root frontier by orders of magnitude on
        hub-heavy targets; ``"auto"`` — ``"edge"`` iff the plan carries a
        seed edge.  The match set is provably identical — seeding changes
        traversal order, never results (the conformance suite gates this).
      csr_walk: CSR driver-segment schedule (DESIGN.md §10): ``"bucketed"``
        (default) clamps each lane's walk to its row's pow2 degree-bucket
        cap (`repro.core.graph.deg_bucket_caps`); ``"flat"`` keeps the
        PR-5 global-``deg_cap`` walk (the benchmark baseline).  Ignored by
        the dense backends.
    """

    n_workers: int = 1
    expand_width: int = 8
    steal_chunk: int = 4
    keep_min: int = 2
    recv_cap: int = 4
    rebalance_interval: int = 8
    work_stealing: bool = True
    stack_cap: int = 0
    max_steps: int = 0
    collect_matches: int = 0
    step_backend: str = "jnp"
    use_pallas: bool = False
    store_used: bool = True
    n_partitions: int = 0
    spill_cap: int = 0
    root_seeding: str = "vertex"
    csr_walk: str = "bucketed"

    def __post_init__(self):
        # "partitioned" is deliberately NOT in STEP_BACKENDS: it is not a
        # drop-in StepBackend (it needs the outer scheduling loop of
        # run_partitioned), so the generic backend-matrix tests don't
        # parametrize over it — it has its own conformance cases.
        valid = extend.STEP_BACKENDS + ("auto", "partitioned")
        if self.step_backend not in valid:
            raise ValueError(
                f"step_backend={self.step_backend!r}; expected one of {valid}"
            )
        if self.root_seeding not in ("vertex", "edge", "auto"):
            raise ValueError(
                f"root_seeding={self.root_seeding!r}; expected "
                "'vertex', 'edge', or 'auto'"
            )
        if self.csr_walk not in ("bucketed", "flat"):
            raise ValueError(
                f"csr_walk={self.csr_walk!r}; expected 'bucketed' or 'flat'"
            )

    def resolved_stack_cap(self, p_pad: int) -> int:
        if self.stack_cap:
            return self.stack_cap
        return self.expand_width * (p_pad + 2) + self.steal_chunk + 8

    def resolved_spill_cap(self, p_pad: int) -> int:
        """Spill-ring capacity: at least 2× the per-round push bound (the
        drain watermark margin, :func:`part_spill_margin`) so the inner
        loop always yields to the host before the ring can overflow."""
        if self.spill_cap:
            return self.spill_cap
        return max(4 * self.resolved_stack_cap(p_pad),
                   2 * self.rebalance_interval * self.expand_width)


class EngineResult(NamedTuple):
    matches: int
    states: int
    steps: int
    steals: int
    steal_rounds: int
    mean_steal_depth: float
    mean_expand_depth: float
    per_worker_states: np.ndarray
    per_worker_matches: np.ndarray
    overflow: bool
    match_buf: Optional[np.ndarray]
    per_worker_steals: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# steal round (cross-worker, pure array ops over the V axis)
# ---------------------------------------------------------------------------

def _steal_round(cfg: EngineConfig, state: EngineState) -> EngineState:
    policy = scheduler.StealPolicy(
        steal_chunk=cfg.steal_chunk, keep_min=cfg.keep_min, recv_cap=cfg.recv_cap
    )
    v_workers, s_cap = state.st_depth.shape
    c = cfg.steal_chunk

    donate, accepted, dest_rank, dest_pos = scheduler.plan_steals(state.size, policy)
    wor = scheduler.receiver_workers(state.size)  # [V] worker per rank

    any_transfer = jnp.sum(accepted) > 0

    # gather donated rows from stack bottoms: donor d slot j = logical pos j
    slot_j = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (v_workers, c))
    src_slot = (state.base[:, None] + slot_j) % s_cap  # [V, C]
    didx = jnp.arange(v_workers, dtype=jnp.int32)[:, None]
    don_depth = state.st_depth[didx, src_slot]  # [V, C]
    don_map = state.st_map[didx, src_slot]
    don_used = state.st_used[didx, src_slot]
    don_cand = state.st_cand[didx, src_slot]

    taken = slot_j < accepted[:, None]  # [V, C]
    dest_w = jnp.where(taken, wor[jnp.clip(dest_rank, 0, v_workers - 1)], -1)
    # receivers are empty (size==0) so intake slot = (base + pos) % S
    recv_base = jnp.where(dest_w >= 0, state.base[jnp.maximum(dest_w, 0)], 0)
    dst_slot = (recv_base + dest_pos) % s_cap
    dw = jnp.where(dest_w >= 0, dest_w, v_workers)  # drop invalid

    st_depth = state.st_depth.at[dw, dst_slot].set(don_depth, mode="drop")
    st_map = state.st_map.at[dw, dst_slot].set(don_map, mode="drop")
    st_used = state.st_used.at[dw, dst_slot].set(don_used, mode="drop")
    st_cand = state.st_cand.at[dw, dst_slot].set(don_cand, mode="drop")

    # intake counts / steal metrics per receiver
    flat_w = dw.reshape(-1)
    ones = jnp.where(dest_w.reshape(-1) >= 0, 1, 0)
    recv_cnt = jnp.zeros((v_workers,), jnp.int32).at[flat_w].add(ones, mode="drop")
    depth_add = jnp.zeros((v_workers,), jnp.int32).at[flat_w].add(
        jnp.where(dest_w.reshape(-1) >= 0, don_depth.reshape(-1), 0), mode="drop"
    )

    # donors advance base (accepted slots were their bottom prefix)
    new_base = (state.base + accepted) % s_cap
    new_size = state.size - accepted + recv_cnt

    return state._replace(
        st_depth=st_depth,
        st_map=st_map,
        st_used=st_used,
        st_cand=st_cand,
        base=new_base,
        size=new_size,
        steals=state.steals + recv_cnt,
        steal_depth=state.steal_depth + depth_add,
        steal_rounds=state.steal_rounds + any_transfer.astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def make_expand_fn(cfg: EngineConfig, plan: extend.AnyPlanArrays):
    """Build the purely worker-local part of one engine round:
    ``rebalance_interval`` shared expansion steps
    (`repro.core.extend.make_step_fn`), over whatever worker axis the
    caller holds (all ``V`` workers single-device, or the local ``V / D``
    shard under ``shard_map``).

    Under the CSR backend (:class:`~repro.core.extend.CsrPlanArrays`) each
    round ends with a ring compaction (`repro.core.frontier.compact`): the
    sparse walk's segment gathers want every worker's stack as one
    contiguous bottom-anchored block — the layout hook ``compact``'s
    docstring has anticipated since the §6 split.  Compaction only rotates
    physical slots, so results stay bit-identical (the conformance suite
    asserts this against the dense backends)."""
    step = extend.make_step_fn(cfg, plan)
    is_csr = isinstance(plan, extend.CsrPlanArrays)

    def expand(state: EngineState) -> EngineState:
        state = lax.fori_loop(
            0, cfg.rebalance_interval, lambda _, st: step(st), state
        )
        if is_csr:
            sd, sm, su, sc, base, size = frontier.compact(
                state.st_depth, state.st_map, state.st_used, state.st_cand,
                state.base, state.size,
            )
            state = state._replace(
                st_depth=sd, st_map=sm, st_used=su, st_cand=sc,
                base=base, size=size,
            )
        return state

    return expand


def make_round_fn(cfg: EngineConfig, plan: extend.AnyPlanArrays):
    """Build the body of the outer loop: ``rebalance_interval`` expansion
    steps followed by one steal round.  Exposed separately so the dry-run /
    roofline can lower exactly one round (stable cost accounting)."""
    expand = make_expand_fn(cfg, plan)

    def body(state: EngineState) -> EngineState:
        state = expand(state)
        if cfg.work_stealing and cfg.n_workers > 1:
            state = _steal_round(cfg, state)
        return state._replace(steps=state.steps + cfg.rebalance_interval)

    return body


def _engine_loop(
    cfg: EngineConfig, plan: extend.AnyPlanArrays, state: EngineState
) -> EngineState:
    max_steps = cfg.max_steps or (1 << 30)
    body = make_round_fn(cfg, plan)

    # ~overflow: a full ring freezes its worker (the pop guard yields k=0
    # while size > 0), so an overflowed run can never drain — abort it
    # promptly; the result is undercounted either way and the session
    # retries with a doubled stack_cap (`repro.core.session.Enumerator.run`).
    def cond(state: EngineState) -> jnp.ndarray:
        return (jnp.sum(state.size) > 0) & (state.steps < max_steps) & ~state.overflow

    return lax.while_loop(cond, body, state)


# ---------------------------------------------------------------------------
# mesh-sharded execution: shard_map over the worker axis (DESIGN.md §2.4)
# ---------------------------------------------------------------------------

def mesh_worker_axis(mesh: Mesh) -> str:
    """The mesh axis the worker dimension shards over: ``data`` by
    convention, else the mesh's first axis."""
    return "data" if "data" in mesh.axis_names else mesh.axis_names[0]


def mesh_signature(mesh: Optional[Mesh]) -> Optional[tuple]:
    """Hashable identity of a mesh for compile-cache keys: axis names,
    axis sizes, and the flat device ids."""
    if mesh is None:
        return None
    return (
        tuple(str(a) for a in mesh.axis_names),
        tuple(int(s) for s in mesh.shape.values()),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def _steal_round_sharded(cfg: EngineConfig, state: EngineState, axis: str) -> EngineState:
    """One steal round under ``shard_map``: ``state`` holds this device's
    ``V / D`` worker stacks.

    The collective form of :func:`_steal_round` (DESIGN.md §2.4):
    ``all_gather`` occupancy → every device runs the same deterministic
    :func:`repro.core.scheduler.plan_steals` (no coordinator) →
    ``all_gather`` each donor's bottom ``steal_chunk`` rows (the steal
    traffic, ``V·C·(1 + P + W_used + W)`` words/round) → each device
    scatters only entries addressed to its local receivers; donors advance
    base by the globally agreed accepted count.  Entry-for-entry identical
    to the unsharded round computed in one address space.
    """
    policy = scheduler.StealPolicy(
        steal_chunk=cfg.steal_chunk, keep_min=cfg.keep_min, recv_cap=cfg.recv_cap
    )
    v_loc, s_cap = state.st_depth.shape
    c = cfg.steal_chunk
    d = lax.axis_index(axis)

    sizes = lax.all_gather(state.size, axis, tiled=True)  # [V]
    v_tot = sizes.shape[0]
    donate, accepted, dest_rank, dest_pos = scheduler.plan_steals(sizes, policy)
    wor = scheduler.receiver_workers(sizes)  # [V] global worker per rank
    any_transfer = jnp.sum(accepted) > 0

    # gather local donors' bottom rows, then all-gather them to every device
    slot_j = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (v_loc, c))
    src_slot = (state.base[:, None] + slot_j) % s_cap  # [V_loc, C]
    lidx = jnp.arange(v_loc, dtype=jnp.int32)[:, None]
    don_depth = lax.all_gather(state.st_depth[lidx, src_slot], axis, tiled=True)
    don_map = lax.all_gather(state.st_map[lidx, src_slot], axis, tiled=True)
    don_used = lax.all_gather(state.st_used[lidx, src_slot], axis, tiled=True)
    don_cand = lax.all_gather(state.st_cand[lidx, src_slot], axis, tiled=True)

    # destination workers (global ids), restricted to this device's shard
    slot_g = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (v_tot, c))
    taken = slot_g < accepted[:, None]  # [V, C]
    dest_w = jnp.where(taken, wor[jnp.clip(dest_rank, 0, v_tot - 1)], -1)
    local_dest = dest_w - d * v_loc
    on_dev = (dest_w >= 0) & (local_dest >= 0) & (local_dest < v_loc)
    safe_dest = jnp.clip(local_dest, 0, v_loc - 1)
    # receivers are empty (size==0) so intake slot = (base + pos) % S
    recv_base = jnp.where(on_dev, state.base[safe_dest], 0)
    dst_slot = (recv_base + dest_pos) % s_cap
    dw = jnp.where(on_dev, safe_dest, v_loc)  # drop off-device slots

    st_depth = state.st_depth.at[dw, dst_slot].set(don_depth, mode="drop")
    st_map = state.st_map.at[dw, dst_slot].set(don_map, mode="drop")
    st_used = state.st_used.at[dw, dst_slot].set(don_used, mode="drop")
    st_cand = state.st_cand.at[dw, dst_slot].set(don_cand, mode="drop")

    # intake counts / steal metrics for local receivers only
    flat_w = dw.reshape(-1)
    on_flat = on_dev.reshape(-1)
    recv_cnt = jnp.zeros((v_loc,), jnp.int32).at[flat_w].add(
        jnp.where(on_flat, 1, 0), mode="drop"
    )
    depth_add = jnp.zeros((v_loc,), jnp.int32).at[flat_w].add(
        jnp.where(on_flat, don_depth.reshape(-1), 0), mode="drop"
    )

    # local donors advance base by their slice of the global accepted vector
    accepted_loc = lax.dynamic_slice_in_dim(accepted, d * v_loc, v_loc)
    new_base = (state.base + accepted_loc) % s_cap
    new_size = state.size - accepted_loc + recv_cnt

    return state._replace(
        st_depth=st_depth,
        st_map=st_map,
        st_used=st_used,
        st_cand=st_cand,
        base=new_base,
        size=new_size,
        steals=state.steals + recv_cnt,
        steal_depth=state.steal_depth + depth_add,
        steal_rounds=state.steal_rounds + any_transfer.astype(jnp.int32),
    )


def _sharded_device_loop(
    cfg: EngineConfig, axis: str, plan: extend.AnyPlanArrays, state: EngineState
) -> EngineState:
    """Per-device program run under ``shard_map``: local expansion rounds
    (the same shared step as the single-device path), collective steal
    rounds, and psum-based termination detection.

    The loop carries the psum'd global entry count so the `while` condition
    is collective-free; every device sees the same count and therefore runs
    the same number of rounds (SPMD lockstep).
    """
    max_steps = cfg.max_steps or (1 << 30)
    expand = make_expand_fn(cfg, plan)

    def global_size(st: EngineState) -> jnp.ndarray:
        return lax.psum(jnp.sum(st.size), axis)

    def global_overflow(st: EngineState) -> jnp.ndarray:
        return lax.psum(st.overflow.astype(jnp.int32), axis) > 0

    def body(carry):
        st, _, _ = carry
        st = expand(st)
        if cfg.work_stealing and cfg.n_workers > 1:
            st = _steal_round_sharded(cfg, st, axis)
        st = st._replace(steps=st.steps + cfg.rebalance_interval)
        return st, global_size(st), global_overflow(st)

    # ~overflow: abort promptly on any device's overflow (see _engine_loop);
    # the psum'd flag keeps every device exiting the same iteration.
    def cond(carry):
        st, gsize, govf = carry
        return (gsize > 0) & (st.steps < max_steps) & ~govf

    state, _, _ = lax.while_loop(
        cond, body, (state, global_size(state), global_overflow(state))
    )
    # overflow is device-local until here; replicate so the P() out-spec holds
    overflow = lax.psum(state.overflow.astype(jnp.int32), axis) > 0
    return state._replace(overflow=overflow)


def make_sharded_engine_fn(
    cfg: EngineConfig, mesh: Mesh, axis: Optional[str] = None, n_t: int = 0,
    csr_only: bool = False,
):
    """Jitted ``(PlanArrays | CsrPlanArrays, EngineState) -> EngineState``
    with the worker axis sharded over ``axis`` of ``mesh`` via ``shard_map``.

    ``cfg.n_workers`` must be a multiple of the axis size (the session API
    snaps it up; `repro.core.session.Enumerator`).  ``n_t`` / ``csr_only``
    feed the ``"auto"`` backend resolution (the plan in-specs pytree must
    match the array layout `plan_arrays_for` will build).
    """
    axis = axis or mesh_worker_axis(mesh)
    n_dev = int(mesh.shape[axis])
    if cfg.n_workers % n_dev:
        raise ValueError(
            f"n_workers={cfg.n_workers} not divisible by mesh axis "
            f"{axis!r} size {n_dev}; round up to a multiple"
        )
    specs = state_partition_specs(axis)
    fn = shard_map(
        functools.partial(_sharded_device_loop, cfg, axis),
        mesh=mesh,
        in_specs=(plan_partition_specs_for(cfg, n_t, csr_only), specs),
        out_specs=specs,
        check_rep=False,
    )
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _sharded_fn_cached(
    cfg: EngineConfig, mesh: Mesh, axis: Optional[str], n_t: int, csr_only: bool
):
    # Mesh hashes by device set + axis names, so repeated direct eng.run()
    # calls over a collection reuse one jitted engine per (cfg, mesh) —
    # the module-level analogue of _run_jit; the session layer keeps its
    # own richer cache (shape buckets, counters).
    return make_sharded_engine_fn(cfg, mesh, axis, n_t=n_t, csr_only=csr_only)


def run_sharded(plan: SearchPlan, cfg: EngineConfig, mesh: Mesh) -> EngineResult:
    """Enumerate with worker stacks sharded over ``mesh`` (see :func:`run`)."""
    fn = _sharded_fn_cached(cfg, mesh, None, plan.n_t, extend.is_csr_only(plan))
    arrays = plan_arrays_for(cfg, plan)
    state = init_state(plan, cfg)
    final = jax.block_until_ready(fn(arrays, state))
    return result_from_state(final, cfg)


@functools.partial(jax.jit, static_argnums=(0,))
def _run_jit(
    cfg: EngineConfig, plan: extend.AnyPlanArrays, state: EngineState
) -> EngineState:
    return _engine_loop(cfg, plan, state)


def run(plan: SearchPlan, cfg: EngineConfig, mesh: Optional[Mesh] = None) -> EngineResult:
    """Enumerate all isomorphic subgraphs described by ``plan``.

    With ``mesh=None`` (the default) all ``V`` workers run in one device
    program — today's single-device behavior, unchanged.  With a mesh the
    worker axis shards over its ``data`` axis (:func:`run_sharded`).
    The plan arrays match the resolved step backend (dense bitmaps, or
    CSR planes for ``step_backend="csr"`` / large-``n_t`` ``"auto"``).
    ``step_backend="partitioned"`` routes to the out-of-core scheduling
    loop (:func:`run_partitioned`), which streams target partitions
    through device memory.
    """
    if cfg.step_backend == "partitioned":
        return run_partitioned(plan, cfg, mesh=mesh)
    if mesh is not None:
        return run_sharded(plan, cfg, mesh)
    arrays = plan_arrays_for(cfg, plan)
    state = init_state(plan, cfg)
    final = jax.block_until_ready(_run_jit(cfg, arrays, state))
    return result_from_state(final, cfg)


def result_from_state(final: EngineState, cfg: EngineConfig) -> EngineResult:
    """Reduce a drained (unbatched) :class:`EngineState` to an
    :class:`EngineResult` — shared by the one-shot :func:`run` and the
    session executor (`repro.core.session`), whose batch path reduces one
    vmapped lane at a time."""
    steals = int(jnp.sum(final.steals))
    sdepth = int(jnp.sum(final.steal_depth))
    states = int(jnp.sum(final.states))
    edepth = int(jnp.sum(final.exp_depth))
    return EngineResult(
        matches=int(jnp.sum(final.matches)),
        states=states,
        steps=int(final.steps),
        steals=steals,
        steal_rounds=int(final.steal_rounds),
        mean_steal_depth=(sdepth / steals) if steals else 0.0,
        mean_expand_depth=(edepth / states) if states else 0.0,
        per_worker_states=np.asarray(final.states),
        per_worker_matches=np.asarray(final.matches),
        overflow=bool(final.overflow),
        match_buf=np.asarray(final.match_buf) if cfg.collect_matches else None,
        per_worker_steals=np.asarray(final.steals),
    )


# ---------------------------------------------------------------------------
# out-of-core partitioned execution (DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# The target's adjacency planes are row-partitioned (PartitionedPlanes); at
# any moment exactly ONE partition's planes are device-resident.  Children
# whose parent rows are all resident are fully constrained and go to the
# live stacks; children owing intersections to non-resident rows are
# *partially* constrained and parked in per-worker spill rings with a
# pending-parent bitmask.  The host drains rings into per-partition pools,
# enumerates the resident partition to quiescence, swaps in the partition
# with the deepest pool (round-robin under a mesh), finishes constraining
# its pooled entries at intake (dead / live seed / re-spill toward the next
# pending parent), and repeats until every pool is empty.  Only fully
# constrained entries are ever extracted, so the match set is bit-identical
# to the monolithic run — partitioning changes scheduling, never results.

def part_spill_margin(cfg: EngineConfig) -> int:
    """Max spill pushes per worker per round — the drain watermark: the
    inner loop yields to the host while at least this much ring headroom
    remains, so a round in flight can never overflow the ring."""
    return cfg.rebalance_interval * cfg.expand_width


def make_part_round_fn(cfg: EngineConfig, plan: extend.PartPlanArrays):
    """One partitioned engine round over ``(EngineState, SpillState)``:
    ``rebalance_interval`` partitioned steps, a ring compaction (the CSR
    layout hook), and a live-stack steal round (spill rings are worker-local
    and never stolen from — they hold parked, not runnable, work)."""
    step = extend.make_partitioned_step_fn(cfg, plan)

    def body(carry):
        st, spill = carry
        st, spill = lax.fori_loop(
            0, cfg.rebalance_interval, lambda _, c: step(*c), (st, spill)
        )
        sd, sm, su, sc, base, size = frontier.compact(
            st.st_depth, st.st_map, st.st_used, st.st_cand, st.base, st.size,
        )
        st = st._replace(
            st_depth=sd, st_map=sm, st_used=su, st_cand=sc, base=base, size=size,
        )
        if cfg.work_stealing and cfg.n_workers > 1:
            st = _steal_round(cfg, st)
        return st._replace(steps=st.steps + cfg.rebalance_interval), spill

    return body


def _part_engine_loop(
    cfg: EngineConfig, plan: extend.PartPlanArrays,
    st: EngineState, spill: SpillState,
):
    """Single-device partitioned inner loop: run rounds until the live
    stacks drain, a stack overflows, or a spill ring crosses its drain
    watermark (yield to the host, which drains the rings and re-enters
    with the same live state)."""
    max_steps = cfg.max_steps or (1 << 30)
    body = make_part_round_fn(cfg, plan)
    margin = part_spill_margin(cfg)

    def cond(carry):
        s, sp = carry
        return (
            (jnp.sum(s.size) > 0) & (s.steps < max_steps)
            & ~s.overflow & ~sp.sp_overflow
            & ~frontier.spill_watermark(sp, margin)
        )

    return lax.while_loop(cond, body, (st, spill))


def _part_sharded_device_loop(
    cfg: EngineConfig, axis: str, plan: extend.PartPlanArrays,
    st: EngineState, spill: SpillState,
):
    """Mesh form of :func:`_part_engine_loop`: the resident partition is
    replicated on every device, worker stacks and spill rings shard over
    ``axis``.  Termination (drain / overflow / watermark) is psum'd so all
    devices exit the same iteration and the host drains globally."""
    max_steps = cfg.max_steps or (1 << 30)
    body0 = make_part_round_fn(cfg, plan)
    margin = part_spill_margin(cfg)

    def gsize(s):
        return lax.psum(jnp.sum(s.size), axis)

    def gstop(s, sp):
        local = (
            s.overflow.astype(jnp.int32)
            + sp.sp_overflow.astype(jnp.int32)
            + frontier.spill_watermark(sp, margin).astype(jnp.int32)
        )
        return lax.psum(local, axis) > 0

    def body(carry):
        s, sp, _, _ = carry
        s, sp = body0((s, sp))
        return s, sp, gsize(s), gstop(s, sp)

    def cond(carry):
        s, sp, gs, stop = carry
        return (gs > 0) & (s.steps < max_steps) & ~stop

    st, spill, _, _ = lax.while_loop(
        cond, body, (st, spill, gsize(st), gstop(st, spill))
    )
    # overflow flags are device-local until here; replicate for P() out-specs
    ovf = lax.psum(st.overflow.astype(jnp.int32), axis) > 0
    spovf = lax.psum(spill.sp_overflow.astype(jnp.int32), axis) > 0
    return st._replace(overflow=ovf), spill._replace(sp_overflow=spovf)


def make_partitioned_engine_fn(
    cfg: EngineConfig, mesh: Optional[Mesh] = None, axis: Optional[str] = None
):
    """Jitted ``(PartPlanArrays, EngineState, SpillState) → (EngineState,
    SpillState)`` — the per-leg inner engine :func:`run_partitioned` drives.
    One compile serves every partition of a target: all partitions pad to
    common shapes and the resident row range rides in traced scalars."""
    if mesh is None:
        return jax.jit(functools.partial(_part_engine_loop, cfg))
    axis = axis or mesh_worker_axis(mesh)
    n_dev = int(mesh.shape[axis])
    if cfg.n_workers % n_dev:
        raise ValueError(
            f"n_workers={cfg.n_workers} not divisible by mesh axis "
            f"{axis!r} size {n_dev}; round up to a multiple"
        )
    st_specs = state_partition_specs(axis)
    sp_specs = spill_partition_specs(axis)
    fn = shard_map(
        functools.partial(_part_sharded_device_loop, cfg, axis),
        mesh=mesh,
        in_specs=(extend.part_plan_partition_specs(), st_specs, sp_specs),
        out_specs=(st_specs, sp_specs),
        check_rep=False,
    )
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _part_fn_cached(cfg: EngineConfig, mesh: Optional[Mesh]):
    return make_partitioned_engine_fn(cfg, mesh)


def _intake_entry(plan: SearchPlan, pp, pid: int, depth: int,
                  map_row: np.ndarray, cand: np.ndarray, pending: int):
    """Apply the now-resident pending parents of one pooled entry: AND the
    partition's adjacency rows into ``cand`` and clear their pending bits.
    Returns the updated ``(cand, pending)``."""
    lo, hi = int(pp.node_start[pid]), int(pp.node_start[pid + 1])
    part = pp.parts[pid]
    j = 0
    rem = pending
    while rem:
        if rem & 1:
            ppos = int(plan.parent_pos[depth, j])
            t = int(map_row[ppos])
            if lo <= t < hi:
                plane = int(plan.parent_elab[depth, j]) * 2 + int(
                    plan.parent_dir[depth, j]
                )
                s = int(part.indptr[plane, t - lo])
                e = int(part.indptr[plane, t - lo + 1])
                row = bitmap_from_indices(
                    part.indices[s:e].astype(np.int64), plan.n_t, plan.w
                )
                cand = cand & row
                pending &= ~(1 << j)
        rem >>= 1
        j += 1
    return cand, pending


def _intake_chunk(plan: SearchPlan, pp, pid: int, pools, chunk_n: int):
    """Pop up to ``chunk_n`` entries from partition ``pid``'s pool and
    finish/advance their constraints: dead entries are dropped, still-
    pending entries are re-routed to the partition of their (new) first
    pending parent, fully constrained entries become live seeds.  Returns
    ``(seed_depth, seed_map, seed_cand, n_dead)`` — possibly zero seeds.
    """
    pool = pools[pid]
    sd, sm, sc = [], [], []
    n_dead = 0
    while pool and len(sd) < chunk_n:
        depth, map_row, cand, pending = pool.pop()
        cand, pending = _intake_entry(plan, pp, pid, depth, map_row, cand, pending)
        if not cand.any():
            n_dead += 1
            continue
        if pending:
            j0 = (pending & -pending).bit_length() - 1
            t = int(map_row[int(plan.parent_pos[depth, j0])])
            tgt = int(np.searchsorted(pp.node_start, t, side="right") - 1)
            pools[tgt].append((depth, map_row, cand, pending))
            continue
        sd.append(depth)
        sm.append(map_row)
        sc.append(cand)
    return (
        np.asarray(sd, dtype=np.int32),
        np.asarray(sm, dtype=np.int32).reshape(len(sm), plan.p_pad),
        np.asarray(sc, dtype=np.uint32).reshape(len(sc), plan.w),
        n_dead,
    )


def _drain_spill(spill: SpillState):
    """Pull every worker's spill-ring entries to host tuples
    ``(depth, map, cand, pending, part)`` (the rings' write cursor resets
    device-side; slots past ``sp_size`` are stale and never read)."""
    d_, m_, c_, pe_, pa_, sz_ = jax.device_get((
        spill.sp_depth, spill.sp_map, spill.sp_cand,
        spill.sp_pending, spill.sp_part, spill.sp_size,
    ))
    out = []
    for v in range(sz_.shape[0]):
        for i in range(int(sz_[v])):
            out.append((
                int(d_[v, i]), m_[v, i].copy(), c_[v, i].copy(),
                int(pe_[v, i]), int(pa_[v, i]),
            ))
    return out


_PART_MAX_ATTEMPTS = 4


def partition_root_entries(plan: SearchPlan, cfg: EngineConfig, pp):
    """Root pool entries for the partitioned driver, one batch per owning
    partition (DESIGN.md §10).

    Under vertex seeding each partition gets **one** entry ``(depth=0,
    map=[-1...], cand=dom[0] ∩ its row range, pending=0)`` — roots are
    enumerated while their own rows are resident instead of all seeding on
    the first-visited partition (which spilled nearly every depth-1 child
    whose parent row lived elsewhere).  Under edge seeding the plan's seed
    arcs (`repro.core.frontier.root_seed_entries`) become depth-1 entries
    routed to the partition owning ``map[0]``; they carry no pending
    parents (position 1's constraints all reference position 0 and are
    applied host-side at seed build).  Returns ``[(part, (depth, map_row,
    cand, pending)), ...]`` in deterministic partition/row order.
    """
    mode = cfg.root_seeding
    if mode == "auto":
        mode = "edge" if plan.seed_edge is not None else "vertex"
    entries = []
    if mode == "edge":
        if plan.seed_edge is None:
            raise ValueError(
                "root_seeding='edge' requires a plan built with seed_edge= "
                "(plan.seed_edge is unset; see repro.core.plan.build_plan)"
            )
        sd, sm, sc = frontier.root_seed_entries(plan)
        for i in range(sd.shape[0]):
            part = int(
                np.searchsorted(pp.node_start, int(sm[i, 0]), side="right") - 1
            )
            entries.append((part, (int(sd[i]), sm[i].copy(), sc[i].copy(), 0)))
        return entries
    if not plan.satisfiable:
        return entries
    m0 = np.full(plan.p_pad, -1, dtype=np.int32)
    for pid in range(pp.n_parts):
        lo, hi = int(pp.node_start[pid]), int(pp.node_start[pid + 1])
        if hi <= lo:
            continue
        cand = plan.dom_bits[0] & bitmap_from_indices(
            np.arange(lo, hi), plan.n_t, plan.w
        )
        if cand.any():
            entries.append((pid, (0, m0.copy(), cand, 0)))
    return entries


def run_partitioned(
    plan: SearchPlan,
    cfg: EngineConfig,
    mesh: Optional[Mesh] = None,
    engine_factory=None,
    stats: Optional[dict] = None,
) -> EngineResult:
    """Enumerate ``plan`` against a row-partitioned target streamed through
    device memory — the outer scheduling loop of the out-of-core path
    (DESIGN.md §9).

    ``cfg.n_partitions`` partitions (0 → 1; with 1 no extension can ever
    leave the resident range, degenerating to the CSR backend's behavior)
    are visited: the resident one is enumerated to quiescence in *legs*
    (seed → inner-loop to drain, with host ring-drains at the spill
    watermark), then the partition with the deepest spill pool is swapped
    in (round-robin under a mesh) and re-seeded from its pooled entries.
    Stack or spill-ring overflow retries the leg with the affected capacity
    doubled (the PR-4 watermark semantics, leg-scoped).

    ``engine_factory(cfg) → fn`` overrides the inner-engine builder (the
    session routes it through its compile cache); ``stats`` — if given — is
    filled with partition/scheduling counters (resident bytes, visits,
    legs, spills, deaths).
    """
    if cfg.step_backend != "partitioned":
        cfg = dataclasses.replace(cfg, step_backend="partitioned")
    n_parts = max(1, cfg.n_partitions)
    pp = extend.plan_partitions(plan, n_parts)
    p_pad, w, v = plan.p_pad, plan.w, cfg.n_workers
    mcap = max(1, cfg.collect_matches)
    if engine_factory is None:
        engine_factory = lambda c: _part_fn_cached(c, mesh)  # noqa: E731

    pools = [[] for _ in range(n_parts)]
    leg_cfg = cfg
    totals = dict(matches=0, states=0, steps=0, steals=0, steal_rounds=0,
                  steal_depth=0, exp_depth=0)
    pw_states = np.zeros(v, dtype=np.int64)
    pw_matches = np.zeros(v, dtype=np.int64)
    pw_steals = np.zeros(v, dtype=np.int64)
    match_rows = []
    n_visits = n_legs = n_rounds = n_spilled = n_dead = 0
    max_pool = 0

    def run_leg(arrays, seed):
        """One leg: seed → inner loop to quiescence (draining rings at the
        watermark); retries with doubled caps on overflow.  Returns the
        final state and this leg's staged spill entries."""
        nonlocal leg_cfg, n_rounds
        for _ in range(_PART_MAX_ATTEMPTS):
            fn = engine_factory(leg_cfg)
            st = frontier.init_delta_state(plan, leg_cfg, *seed)
            spill = frontier.init_spill_state(
                v, leg_cfg.resolved_spill_cap(p_pad), p_pad, w
            )
            staged = []
            retry = False
            while True:
                st, spill = jax.block_until_ready(fn(arrays, st, spill))
                n_rounds += 1
                if bool(st.overflow):
                    leg_cfg = dataclasses.replace(
                        leg_cfg, stack_cap=2 * leg_cfg.resolved_stack_cap(p_pad)
                    )
                    retry = True
                    break
                if bool(spill.sp_overflow):
                    leg_cfg = dataclasses.replace(
                        leg_cfg, spill_cap=2 * leg_cfg.resolved_spill_cap(p_pad)
                    )
                    retry = True
                    break
                staged.extend(_drain_spill(spill))
                spill = spill._replace(
                    sp_size=jnp.zeros_like(spill.sp_size),
                    sp_overflow=jnp.zeros_like(spill.sp_overflow),
                )
                max_steps = leg_cfg.max_steps or (1 << 30)
                if int(jnp.sum(st.size)) == 0 or int(st.steps) >= max_steps:
                    return st, staged
            if not retry:  # pragma: no cover — loop exits via return/break
                break
        raise RuntimeError(
            f"partitioned leg kept overflowing after {_PART_MAX_ATTEMPTS} "
            f"capacity doublings (stack_cap={leg_cfg.stack_cap}, "
            f"spill_cap={leg_cfg.spill_cap})"
        )

    def absorb(st, staged):
        """Fold a completed leg into the run totals and commit its spills."""
        nonlocal n_spilled, max_pool, pw_states, pw_matches, pw_steals
        totals["matches"] += int(jnp.sum(st.matches))
        totals["states"] += int(jnp.sum(st.states))
        totals["steps"] += int(st.steps)
        totals["steals"] += int(jnp.sum(st.steals))
        totals["steal_rounds"] += int(st.steal_rounds)
        totals["steal_depth"] += int(jnp.sum(st.steal_depth))
        totals["exp_depth"] += int(jnp.sum(st.exp_depth))
        pw_states += np.asarray(st.states, dtype=np.int64)
        pw_matches += np.asarray(st.matches, dtype=np.int64)
        pw_steals += np.asarray(st.steals, dtype=np.int64)
        if cfg.collect_matches:
            m = np.asarray(st.matches)
            buf = np.asarray(st.match_buf)
            for v_ in range(v):
                k = min(int(m[v_]), mcap)
                if k:
                    match_rows.append(buf[v_, :k])
        for depth, map_row, cand, pending, part in staged:
            pools[part].append((depth, map_row, cand, pending))
        n_spilled += len(staged)
        max_pool = max(max_pool, max((len(p) for p in pools), default=0))

    # Roots enter through the pools, each batch owned by the partition whose
    # rows it maps (DESIGN.md §10) — the first leg of every partition extends
    # against resident parent rows instead of spilling depth-1 children.
    for part, entry in partition_root_entries(plan, cfg, pp):
        pools[part].append(entry)

    current = next((pid for pid in range(n_parts) if pools[pid]), None)
    while current is not None:
        arrays = extend.make_part_plan_arrays(plan, pp, current)
        n_visits += 1
        while True:
            chunk_n = v * max(leg_cfg.resolved_stack_cap(p_pad) // 2, 1)
            sd, sm, sc, dead = _intake_chunk(plan, pp, current, pools, chunk_n)
            n_dead += dead
            if sd.shape[0] == 0:
                if pools[current]:
                    continue  # chunk was all dead/re-routed; keep draining
                break  # partition quiescent
            st, staged = run_leg(arrays, (sd, sm, sc))
            absorb(st, staged)
            n_legs += 1
        nxt = None
        if mesh is not None:  # round-robin partition rotation under a mesh
            for off in range(1, n_parts + 1):
                cand_p = (current + off) % n_parts
                if pools[cand_p]:
                    nxt = cand_p
                    break
        else:  # deepest spill pool first
            depth_best = 0
            for pid in range(n_parts):
                if len(pools[pid]) > depth_best:
                    nxt, depth_best = pid, len(pools[pid])
        if nxt is None:
            break
        current = nxt

    if stats is not None:
        stats.update(
            n_parts=n_parts,
            visits=n_visits,
            legs=n_legs,
            rounds=n_rounds,
            spilled=n_spilled,
            dead_spills=n_dead,
            max_pool=max_pool,
            cut_edges=pp.cut_edges,
            resident_plane_bytes=extend.part_resident_nbytes(pp),
            per_part_nbytes=[p.nbytes for p in pp.parts],
            final_stack_cap=leg_cfg.resolved_stack_cap(p_pad),
            final_spill_cap=leg_cfg.resolved_spill_cap(p_pad),
        )

    match_buf = None
    if cfg.collect_matches:
        rows = (
            np.concatenate(match_rows, axis=0)
            if match_rows else np.zeros((0, p_pad), np.int32)
        )
        match_buf = np.full((1, max(1, rows.shape[0]), p_pad), -1, np.int32)
        if rows.shape[0]:
            match_buf[0, : rows.shape[0]] = rows

    steals = totals["steals"]
    states = totals["states"]
    return EngineResult(
        matches=totals["matches"],
        states=states,
        steps=totals["steps"],
        steals=steals,
        steal_rounds=totals["steal_rounds"],
        mean_steal_depth=(totals["steal_depth"] / steals) if steals else 0.0,
        mean_expand_depth=(totals["exp_depth"] / states) if states else 0.0,
        per_worker_states=pw_states,
        per_worker_matches=pw_matches,
        overflow=False,
        match_buf=match_buf,
        per_worker_steals=pw_steals,
    )
