"""§Roofline — three-term roofline from the dry-run artifacts.

Terms (per cell, per mesh; v5e constants):
  t_compute = flops_per_device / 197e12        (bf16 peak per chip)
  t_memory  = bytes_per_device / 819e9         (HBM bandwidth per chip)
  t_coll    = collective_bytes_per_device / 50e9  (ICI per-link bandwidth)

Per-device values are the loop-corrected HLO-walk totals
(benchmarks/hlo_walk.py) — XLA's cost_analysis visits scan bodies once and
is reported alongside for reference.  Fleet totals = per-device × chips, so
``t_compute == HLO_FLOPs_total / (chips × peak)`` exactly as specified.

Also derived:
  MODEL_FLOPS ratio = model_flops_total / (flops_per_device × chips)
      (useful fraction of compiled compute; catches remat/dispatch waste)
  roofline fraction = t_model / max(t_compute, t_memory, t_coll)
      where t_model = model_flops_total / (chips × 197e12) — the score
      reported in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link
VPU_INT_OPS = 3.9e12  # ~int32 word-ops/s on the v5e VPU (8x128 lanes, ~1GHz,
# 4 ALU slots) — used only for the zero-matmul SGE cells

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def load_cells(mesh: str = "single") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "dryrun", mesh, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def terms(rec: Dict) -> Optional[Dict]:
    if rec.get("skipped"):
        return None
    chips = rec["n_devices"]
    walk = rec["hlo_walk"]
    f_dev = walk["flops"]
    b_min = walk.get("bytes_min", walk.get("bytes_traffic", walk["bytes"]))
    b_up = walk.get("bytes_traffic", walk["bytes"])
    c_dev = walk["collective_total"]
    # cells with zero dot-flops (the SGE engine is pure bitwise/int work)
    # take their compute term from the analytic word-op count at VPU int
    # throughput (~3.9e12 int-ops/s on v5e; documented approximation)
    int_ops = f_dev == 0
    t_c = (rec["model_flops"] / chips / VPU_INT_OPS) if int_ops else f_dev / PEAK_FLOPS
    # memory term is bracketed: [fusion-optimal lower bound, CPU-backend
    # boundary upper bound]; dominance / fractions use the lower bound (the
    # realistic TPU estimate — TPU fuses elementwise chains the CPU HLO
    # leaves at boundaries), the upper bound is reported alongside.
    t_m = b_min / HBM_BW
    t_m_up = b_up / HBM_BW
    t_l = c_dev / ICI_BW
    t_model = rec["model_flops"] / (chips * (VPU_INT_OPS if int_ops else PEAK_FLOPS))
    bound = max(t_c, t_m, t_l, 1e-30)
    dom = {t_c: "compute", t_m: "memory", t_l: "collective"}[max(t_c, t_m, t_l)]
    return {
        "cell": rec["cell"],
        "kind": rec["kind"],
        "chips": chips,
        "t_compute": t_c,
        "t_memory": t_m,
        "t_memory_upper": t_m_up,
        "t_collective": t_l,
        "dominant": dom,
        "model_flops": rec["model_flops"],
        "hlo_flops_total": f_dev * chips,
        "useful_ratio": (1.0 if int_ops
                         else rec["model_flops"] / max(f_dev * chips, 1e-30)),
        "roofline_fraction": t_model / bound,
        "step_time_bound_s": bound,
        "bytes_per_device": b_min,
        "bytes_upper_per_device": b_up,
        "bytes_xla_per_device": walk["bytes"],
        "collective_per_device": c_dev,
        "dynamic_loops": walk.get("n_dynamic_loops", 0),
        "compile_s": rec.get("compile_s"),
    }


MOVE_HINTS = {
    "compute": "raise arithmetic intensity / cut redundant recompute (remat "
    "policy, fuse epilogues) or add chips",
    "memory": "cut HBM traffic: larger fused blocks, bf16 intermediates, "
    "avoid re-materialized activations, better layouts",
    "collective": "re-shard to shrink cross-device traffic: move the sharded "
    "axis, overlap collectives with compute, compress payloads",
}


def table(mesh: str = "single") -> str:
    rows = []
    for rec in load_cells(mesh):
        t = terms(rec)
        if t is None:
            rows.append(
                f"| {rec['cell']} | — | — | — | — | SKIP | — | — | {rec['skip_reason'][:60]}… |"
            )
            continue
        rows.append(
            "| {cell} | {t_compute:.2e} | {t_memory:.2e} | {t_collective:.2e} "
            "| **{dominant}** | {model_flops:.2e} | {useful_ratio:.3f} "
            "| {roofline_fraction:.3f} | {hint} |".format(
                **t, hint=MOVE_HINTS[t["dominant"]][:70]
            )
        )
    hdr = (
        "| cell | t_compute (s) | t_memory (s) | t_coll (s) | dominant "
        "| MODEL_FLOPS | useful ratio | roofline frac | to move the dominant term |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    return hdr + "\n" + "\n".join(rows)


def emit_csv(mesh: str = "single") -> List[str]:
    lines = []
    for rec in load_cells(mesh):
        t = terms(rec)
        if t is None:
            continue
        lines.append(
            f"roofline/{mesh}/{t['cell']},{t['step_time_bound_s']*1e6:.2f},"
            f"dom={t['dominant']};frac={t['roofline_fraction']:.3f};"
            f"useful={t['useful_ratio']:.3f}"
        )
    return lines


def main() -> None:
    for mesh in ("single", "multi"):
        cells = load_cells(mesh)
        if not cells:
            continue
        md = table(mesh)
        path = os.path.join(ARTIFACTS, f"roofline_{mesh}.md")
        with open(path, "w") as f:
            f.write(f"# Roofline — {mesh} mesh\n\n{md}\n")
        print(f"[roofline] wrote {path} ({len(cells)} cells)")
        print("\n".join(emit_csv(mesh)))


if __name__ == "__main__":
    main()
