"""Tests for the beyond-paper extensions: flash kernel, windowed attention,
config overrides, multi-query driver, token pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref


@pytest.mark.parametrize("bh,s,d,bq,bk,dt,tol", [
    (2, 64, 32, 16, 16, jnp.float32, 1e-4),
    (4, 128, 64, 32, 64, jnp.float32, 1e-4),
    (2, 64, 32, 16, 16, jnp.bfloat16, 2e-1),
    (1, 32, 16, 32, 32, jnp.float32, 1e-4),
])
def test_flash_attention_kernel(rng, bh, s, d, bq, bk, dt, tol):
    q = jnp.asarray(rng.normal(size=(bh, s, d)), dt)
    k = jnp.asarray(rng.normal(size=(bh, s, d)), dt)
    v = jnp.asarray(rng.normal(size=(bh, s, d)), dt)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_windowed_attention_equals_full_when_window_covers(rng):
    from repro.models.attention import blockwise_attention, windowed_attention

    B, S, H, KH, dh = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, dh)), jnp.float32)
    full = blockwise_attention(q, k, v, kv_block=16)
    win = windowed_attention(q, k, v, window=S, q_chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(win),
                               rtol=2e-4, atol=2e-4)


def test_windowed_attention_masks_history(rng):
    from repro.models.attention import windowed_attention

    B, S, H, dh, w = 1, 32, 2, 8, 4
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    out = windowed_attention(q, k, v, window=w, q_chunk=8)
    # tampering with kv beyond the window must not change position t
    k2 = k.at[:, :10].set(0.0)
    v2 = v.at[:, :10].set(0.0)
    out2 = windowed_attention(q, k2, v2, window=w, q_chunk=8)
    t = 20  # window [16..20] untouched
    np.testing.assert_allclose(np.asarray(out[0, t]), np.asarray(out2[0, t]),
                               rtol=1e-5, atol=1e-6)


def test_config_overrides():
    from repro.configs import overrides
    from repro.configs.kimi_k2_1t_a32b import CFG

    c = overrides.apply(CFG, ["n_layers=3", "moe.top_k=2", "attn_window=512"])
    assert c.n_layers == 3 and c.moe.top_k == 2 and c.attn_window == 512
    assert CFG.n_layers == 61  # frozen original untouched
    with pytest.raises(overrides.OverrideError):
        overrides.apply(CFG, ["nonexistent=1"])
    with pytest.raises(overrides.OverrideError):
        overrides.apply(CFG, ["badformat"])


def test_multi_query_matches_single(rng):
    from repro.core import EngineConfig, enumerate_subgraphs
    from repro.core.multi import enumerate_many
    from repro.data import graphgen

    tgt = graphgen.random_graph(50, 300, n_labels=3, seed=2)
    pats = [graphgen.extract_pattern(tgt, e, seed=20 + i)
            for i, e in enumerate((4, 6, 5, 7))]
    pats = [p for p in pats if p.m > 0]
    cfg = EngineConfig(n_workers=4, expand_width=2)
    results = enumerate_many(pats, tgt, variant="ri-ds", cfg=cfg, pack_size=2)
    assert len(results) == len(pats)
    for p, r in zip(pats, results):
        single = enumerate_subgraphs(p, tgt, variant="ri-ds", config=cfg)
        assert (r.matches, r.states) == (single.matches, single.states)


def test_token_loader_roundtrip(tmp_path, rng):
    from repro.data import tokens as tok

    stream = rng.integers(0, 1000, 10_000).astype(np.int32)
    n = tok.write_shards(stream, str(tmp_path), shard_tokens=3000)
    assert n == 4
    loader = tok.TokenLoader(str(tmp_path), batch=4, seq=64, seed=1)
    it = loader.batches()
    b1, cur1 = next(it)
    assert b1["tokens"].shape == (4, 64)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # resume determinism: restarting from cursor reproduces the stream
    b2, cur2 = next(it)
    loader2 = tok.TokenLoader(str(tmp_path), batch=4, seq=64, seed=1)
    b2b, _ = next(loader2.batches(cur1))
    np.testing.assert_array_equal(b2["tokens"], b2b["tokens"])


def test_swa_lm_forward_finite():
    """LM with attn_window runs the sub-quadratic path and stays finite."""
    from repro.models import transformer as tf

    cfg = tf.LMConfig(name="swa-t", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab_size=97,
                      activation="swiglu", max_seq_len=64, loss_chunk=16,
                      kv_block=8, attn_window=8)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 97)
    loss, _ = jax.jit(lambda p: tf.loss_fn(p, cfg, {"tokens": toks, "labels": toks}))(params)
    assert bool(jnp.isfinite(loss))
