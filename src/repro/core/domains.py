"""RI-DS domain assignment: initial compatibility domains, arc-consistency
filtering, and the paper's singleton forward checking (FC).

Domains are packed ``[n_p, w]`` uint32 bitmaps over target nodes — the same
representation RI-DS uses ("domains are implemented as bitmasks", paper
§4.2.2), which makes every filtering step a dense bitwise sweep.

Pipeline (paper §4.1 / §4.2.2):

  1. ``initial_domains``    — label equality + degree dominance.
  2. ``arc_consistency``    — drop ``t`` from ``D(p)`` if some pattern edge
     ``(p, q)`` has no counterpart ``(t, t')`` with ``t' ∈ D(q)`` and a
     compatible edge label.  Iterated to a fixpoint (each removal can expose
     more inconsistency).
  3. ``forward_check_singletons`` — every pattern node with ``|D(p)| == 1``
     *will* consume its target node; remove that node from all other domains,
     repeating on newly created singletons.  Detects unsatisfiability when a
     domain empties or two singletons collide.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.graph import Graph, PackedGraph, bitmap_from_indices, n_words, popcount


@dataclasses.dataclass
class DomainResult:
    """Packed domains plus satisfiability flag."""

    bits: np.ndarray  # [n_p, w] uint32
    satisfiable: bool

    def sizes(self) -> np.ndarray:
        return popcount(self.bits)


def initial_domains(pattern: Graph, target: PackedGraph) -> np.ndarray:
    """``D0(p) = { t : lab(t) == lab(p), deg_out(t) >= deg_out(p),
    deg_in(t) >= deg_in(p) }`` as ``[n_p, w]`` bitmaps."""
    p_out = pattern.out_degrees()
    p_in = pattern.in_degrees()
    w = target.w
    bits = np.zeros((pattern.n, w), dtype=np.uint32)
    for p in range(pattern.n):
        ok = (
            (target.labels == pattern.labels[p])
            & (target.deg_out >= p_out[p])
            & (target.deg_in >= p_in[p])
        )
        idx = np.nonzero(ok)[0]
        if idx.size:
            bits[p] = bitmap_from_indices(idx, target.n, w)
    return bits


def _pattern_arcs(pattern: Graph) -> np.ndarray:
    """All directed constraint arcs ``(p, q, dir, elab)``.

    For pattern edge ``(p -> q)`` with label ``l`` we emit two arcs:
      * ``(p, q, dir=0, l)``: every ``t ∈ D(p)`` needs an out-edge with label
        ``l`` to some ``t' ∈ D(q)``;
      * ``(q, p, dir=1, l)``: every ``t ∈ D(q)`` needs an in-edge from some
        ``t' ∈ D(p)``.
    """
    arcs = []
    for u, v, l in zip(pattern.src.tolist(), pattern.dst.tolist(), pattern.edge_labels.tolist()):
        if u == v:
            continue
        arcs.append((u, v, 0, l))
        arcs.append((v, u, 1, l))
    return np.asarray(arcs, dtype=np.int32).reshape(-1, 4)


def arc_consistency(
    pattern: Graph,
    target: PackedGraph,
    bits: np.ndarray,
    max_iters: Optional[int] = None,
) -> DomainResult:
    """Filter domains to (iterated) arc consistency.

    For arc ``(p, q, dir, l)``: keep ``t`` in ``D(p)`` only if
    ``adj_bits[l, dir, t] & D(q)`` is non-empty — a row-wise AND + any-bit
    test over the target adjacency bitmaps, vectorized over all ``t``.
    """
    bits = bits.copy()
    arcs = _pattern_arcs(pattern)
    if arcs.size == 0:
        return DomainResult(bits, bool(np.all(popcount(bits) > 0)))
    it = 0
    while True:
        it += 1
        changed = False
        for p, q, d, l in arcs.tolist():
            rows = target.adj_bits[l, d]  # [n_t, w]
            ok = np.any(rows & bits[q][None, :], axis=-1)  # [n_t] any neighbor in D(q)
            mask = bitmap_from_indices(np.nonzero(ok)[0], target.n, target.w) if ok.any() else np.zeros(target.w, np.uint32)
            nb = bits[p] & mask
            if not np.array_equal(nb, bits[p]):
                bits[p] = nb
                changed = True
                if not nb.any():
                    return DomainResult(bits, False)
        if not changed or (max_iters is not None and it >= max_iters):
            break
    return DomainResult(bits, bool(np.all(popcount(bits) > 0)))


def forward_check_singletons(bits: np.ndarray) -> DomainResult:
    """The paper's FC (§4.2.2): propagate injectivity from singleton domains.

    Pattern nodes with ``|D(p)| == 1`` are guaranteed to be assigned their
    single target node; remove that node from every *other* domain, and
    iterate on newly created singletons.
    """
    bits = bits.copy()
    n_p = bits.shape[0]
    sizes = popcount(bits)
    if np.any(sizes == 0):
        return DomainResult(bits, False)
    processed = np.zeros(n_p, dtype=bool)
    while True:
        new = np.nonzero((sizes == 1) & ~processed)[0]
        if new.size == 0:
            break
        # Union bitmap of all newly discovered singleton targets.  Collision
        # (two singletons sharing a target) surfaces as an emptied domain.
        union = np.zeros(bits.shape[1], dtype=np.uint32)
        for p in new.tolist():
            if (union & bits[p]).any():
                return DomainResult(bits, False)  # two singletons collide
            union |= bits[p]
            processed[p] = True
        keep = ~processed
        bits[keep] &= ~union[None, :]
        sizes = popcount(bits)
        if np.any(sizes == 0):
            return DomainResult(bits, False)
    return DomainResult(bits, True)


def compute_domains(
    pattern: Graph,
    target: PackedGraph,
    use_ac: bool = True,
    use_fc: bool = False,
    ac_iters: Optional[int] = None,
) -> DomainResult:
    """Full RI-DS domain pipeline.

    ``use_ac=False`` yields RI's implicit domains (label + degree only);
    ``use_fc=True`` adds the paper's singleton forward checking.
    """
    bits = initial_domains(pattern, target)
    res = DomainResult(bits, bool(np.all(popcount(bits) > 0)))
    if not res.satisfiable:
        return res
    if use_ac:
        res = arc_consistency(pattern, target, res.bits, max_iters=ac_iters)
        if not res.satisfiable:
            return res
    if use_fc:
        res = forward_check_singletons(res.bits)
    return res
