"""Incremental-maintenance benchmark: run_delta vs full recompute.

  PYTHONPATH=src python benchmarks/bench_incremental.py [--smoke]

Drives a stream of batched edge edits — each step touches ~1% of the arcs
(half removals, half insertions) of a power-law target (the regime of
Das et al.'s dynamic workloads: hubs, long sparse tail) — and maintains a
pattern's match set two ways:

  * **delta**: ``SubgraphIndex.update`` (incremental bitmap/CSR-plane
    patching) + ``Enumerator.run_delta`` (membership invalidation +
    edge-anchored seeded enumeration, DESIGN.md §8);
  * **recompute**: the same ``update`` followed by a fresh full
    ``Enumerator.run`` against the new version.

Gates (PR acceptance):

  (a) **Correctness**: the maintained match set is checked against the
      fresh enumeration at every step on counts, and on full sorted
      node-indexed mapping sets at spot-check steps plus the final
      version (the same differential identity as
      ``tests/test_incremental_conformance.py``).
  (b) **Speedup**: summed over the stream, delta maintenance beats full
      recompute by >= 5x wall-clock.  Both sides run warm: the shared
      XLA trace pool means neither pays a re-trace per version, so the
      comparison is enumeration work vs enumeration work.  The gate is
      asserted in compiled mode; a ``--use-pallas`` run under interpret
      mode is exempt and reports honestly.
"""

from __future__ import annotations

import argparse
import time
from typing import List, Tuple

import numpy as np

try:
    from benchmarks import common
except ImportError:  # executed from an arbitrary cwd
    import repro.bench  # noqa: F401  (puts the repo root on sys.path)
    from benchmarks import common

from repro.core import EngineConfig, Enumerator, SubgraphIndex
from repro.core.delta import as_mapping_array, as_node_mappings
from repro.data import graphgen
from repro.kernels import ops as kops

SPEEDUP_FLOOR = 5.0
EDIT_FRACTION = 0.01  # arcs edited per step (half removed, half inserted)


def build_stream(tgt, pat, n_steps: int, seed: int):
    """Reproducible stream of batched edits over ``tgt``, each touching
    ~EDIT_FRACTION of the arc set.  Removals are sampled from present
    arcs; insertions are sampled *pattern-relevant* (endpoint node labels
    and edge label drawn from the pattern's edge triples) so the delta
    side has to run real anchored enumeration, not just membership
    invalidation."""
    rng = np.random.default_rng(seed)
    pe = sorted(set(zip(
        pat.labels[pat.src].tolist(), pat.labels[pat.dst].tolist(),
        pat.edge_labels.tolist())))
    by_label = {l: np.nonzero(tgt.labels == l)[0]
                for l in {x for (a, b, _) in pe for x in (a, b)}}
    # the corpus is undirected (symmetric arc pairs); edits stay in that
    # regime by always touching both arcs of an edge
    present = set(zip(tgt.src.tolist(), tgt.dst.tolist(),
                      tgt.edge_labels.tolist()))
    k = max(4, int(len(present) * EDIT_FRACTION))
    steps: List[Tuple[list, list]] = []
    for _ in range(n_steps):
        pres_list = sorted((u, v, l) for (u, v, l) in present if u < v)
        rem_idx = rng.choice(len(pres_list), size=k // 4, replace=False)
        rems = []
        for i in rem_idx:
            u, v, l = pres_list[i]
            rems += [(u, v, l), (v, u, l)]
        adds = []
        while len(adds) < k - len(rems):
            la, lb, el = pe[int(rng.integers(len(pe)))]
            u = int(rng.choice(by_label[la]))
            v = int(rng.choice(by_label[lb]))
            t, tr = (u, v, int(el)), (v, u, int(el))
            if u != v and t not in present and t not in adds:
                adds += [t, tr]
        steps.append((adds, rems))
        present -= set(rems)
        present |= set(adds)
    return steps


def pick_pattern(enum, tgt, seed: int, min_matches: int, max_matches: int):
    """First extracted pattern whose standing match set is substantial
    (``min_matches`` floor, capped at ``max_matches`` so the maintained
    mapping set stays materializable).  Incremental maintenance targets
    standing queries whose full enumeration is expensive — a pattern the
    target barely matches would gate on fixed per-step overhead instead
    of enumeration work.  The tried seeds and the chosen pattern are
    deterministic in ``seed``."""
    best = None
    for s in range(seed + 1, seed + 17):
        pat = graphgen.extract_pattern(tgt, 4, seed=s)
        q = enum.prepare(pat)
        ms = enum.run(q)
        if ms.matches > max_matches:
            continue
        if best is None or ms.matches > best[2].matches:
            best = (pat, q, ms)
        if ms.matches >= min_matches:
            return pat, q, ms
    if best is None:
        raise RuntimeError(
            f"no extracted pattern had <= {max_matches} matches; "
            "lower --n-t or --avg-deg"
        )
    pat, q, ms = best
    print(f"  note: no tried pattern reached {min_matches} matches; "
          f"using the densest found ({ms.matches})")
    return pat, q, ms


def run(n_t: int, avg_deg: float, n_steps: int, seed: int,
        use_pallas: bool, check_every: int) -> dict:
    cfg = EngineConfig(n_workers=4, expand_width=2, step_backend="auto",
                       use_pallas=use_pallas)
    interpret = kops.resolve_interpret(None)
    gate = not (use_pallas and interpret)  # interpret-mode pallas is exempt

    tgt = graphgen.power_law_graph(
        n_t, avg_deg=avg_deg, alpha=2.0, n_labels=4, seed=seed,
    )
    idx0 = SubgraphIndex.build(tgt)
    idx0.plane_set()  # materialize once so updates patch instead of rebuild

    # -- warm both paths on version 0 (shared trace pool: no per-version
    # re-trace afterwards; what remains is enumeration work) -------------
    enum = Enumerator(idx0, config=cfg)
    pat, q0, ms0 = pick_pattern(enum, tgt, seed,
                                min_matches=5 * n_t, max_matches=120_000)
    steps = build_stream(tgt, pat, n_steps, seed)
    cur = as_mapping_array(ms0)  # maintained set stays an [M, n_p] array
    warm_add, warm_rem = steps[0]
    widx, wdelta = idx0.update(add_edges=warm_add, remove_edges=warm_rem)
    wq = enum.prepare(pat, index=widx)
    enum.run_delta(wq, cur, wdelta)  # traces the seeded-engine shapes
    enum.run(wq)

    # -- delta maintenance -------------------------------------------------
    idx = idx0
    t_update = t_delta = 0.0
    n_seeds = n_states_delta = 0
    counts_per_step: List[int] = []
    snapshots = {}  # step -> maintained mapping set (for the spot checks)
    for i, (adds, rems) in enumerate(steps):
        t0 = time.perf_counter()
        idx, delta = idx.update(add_edges=adds, remove_edges=rems)
        t_update += time.perf_counter() - t0
        q = enum.prepare(pat, index=idx)
        t0 = time.perf_counter()
        dm = enum.run_delta(q, cur, delta)
        t_delta += time.perf_counter() - t0
        cur = dm.apply_array(cur)
        n_seeds += dm.n_seeds
        n_states_delta += dm.states
        counts_per_step.append(len(cur))
        if i % check_every == 0 or i == len(steps) - 1:
            snapshots[i] = cur

    # -- full recompute baseline (same updates, fresh full run each step),
    # doubling as gate (a): counts verified at every step, full sorted
    # mapping sets at the spot-check steps and the final version ----------
    idx_b = idx0
    t_recompute = 0.0
    n_states_full = 0
    for i, (adds, rems) in enumerate(steps):
        idx_b, _ = idx_b.update(add_edges=adds, remove_edges=rems)
        q = enum.prepare(pat, index=idx_b)
        t0 = time.perf_counter()
        full = enum.run(q)
        t_recompute += time.perf_counter() - t0
        n_states_full += full.states
        assert full.matches == counts_per_step[i], (
            f"step {i}: maintained count {counts_per_step[i]} != fresh "
            f"recompute {full.matches}"
        )
        if i in snapshots:
            fresh = sorted(as_node_mappings(full))
            assert snapshots[i].tolist() == [list(t) for t in fresh], (
                f"step {i}: maintained mapping set diverged from fresh "
                "enumeration"
            )

    # -- (b) the speedup gate ----------------------------------------------
    t_incremental = t_update + t_delta
    speedup = t_recompute / t_incremental if t_incremental else float("inf")
    if gate:
        assert speedup >= SPEEDUP_FLOOR, (
            f"delta maintenance must beat full recompute {SPEEDUP_FLOOR}x "
            f"on a {EDIT_FRACTION:.0%}-edit stream in compiled mode; "
            f"measured {speedup:.2f}x ({t_incremental*1e3:.1f} ms vs "
            f"{t_recompute*1e3:.1f} ms over {len(steps)} steps)"
        )

    per_step = t_incremental / len(steps)
    print(common.csv_row(
        "incr_delta", per_step * 1e6,
        f"steps={len(steps)} k={len(steps[0][0]) + len(steps[0][1])} "
        f"seeds={n_seeds} states={n_states_delta}"))
    print(common.csv_row(
        "incr_recompute", t_recompute / len(steps) * 1e6,
        f"steps={len(steps)} states={n_states_full}"))
    print(f"  delta vs full recompute: {speedup:.2f}x "
          f"({'gated >= %.1fx' % SPEEDUP_FLOOR if gate else 'interpret mode: exempt'})")
    print(f"  update={t_update*1e3:.1f}ms run_delta={t_delta*1e3:.1f}ms "
          f"recompute={t_recompute*1e3:.1f}ms "
          f"states {n_states_delta} vs {n_states_full} "
          f"matches={len(cur)}")

    return dict(
        n_t=n_t, avg_deg=avg_deg, n_steps=len(steps),
        edits_per_step=len(steps[0][0]) + len(steps[0][1]),
        t_update_s=t_update, t_run_delta_s=t_delta,
        t_incremental_s=t_incremental, t_recompute_s=t_recompute,
        speedup=speedup, gated=gate,
        seeds=n_seeds, states_delta=n_states_delta, states_full=n_states_full,
        matches_final=len(cur), use_pallas=use_pallas, interpret=interpret,
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small stream for CI (same gates)")
    ap.add_argument("--n-t", type=int, default=None, help="target nodes")
    ap.add_argument("--steps", type=int, default=None, help="stream length")
    ap.add_argument("--avg-deg", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--use-pallas", action="store_true")
    args = ap.parse_args()

    n_t = args.n_t or (4000 if args.smoke else 4500)
    n_steps = args.steps or (5 if args.smoke else 20)
    payload = run(n_t, args.avg_deg, n_steps, args.seed,
                  use_pallas=args.use_pallas,
                  check_every=max(1, n_steps // 3))
    common.save_json("bench_incremental", payload)


if __name__ == "__main__":
    main()
