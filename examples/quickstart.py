"""Quickstart: enumerate all isomorphic subgraphs with the parallel engine.

  PYTHONPATH=src python examples/quickstart.py

Builds a small labeled target graph, extracts a pattern, and runs all four
algorithm variants (RI, RI-DS, RI-DS-SI, RI-DS-SI-FC) with 8 workers,
printing matches / search-space size / steal statistics — the paper's core
loop in ~20 lines of user code.
"""

import sys

sys.path.insert(0, "src")

from repro.core import enumerate_subgraphs
from repro.data import graphgen

# A PPI-flavored synthetic target: 400 nodes, dense, 32 labels.
target = graphgen.random_graph(400, 3200, n_labels=32, label_dist="normal", seed=1)
# A 16-edge pattern extracted from the target (=> at least one match exists).
pattern = graphgen.extract_pattern(target, 16, seed=2)
print(f"target: {target.n} nodes / {target.m} arcs; "
      f"pattern: {pattern.n} nodes / {pattern.m} arcs\n")

for variant in ("ri", "ri-ds", "ri-ds-si", "ri-ds-si-fc"):
    res = enumerate_subgraphs(
        pattern, target, variant=variant,
        n_workers=8, expand_width=4, steal_chunk=4,
    )
    print(f"{variant:12s} matches={res.matches:<6d} states={res.states:<8d} "
          f"steps={res.steps:<6d} steals={res.steals:<4d} "
          f"preprocess={res.preprocess_s*1e3:6.1f}ms match={res.match_s:6.2f}s")

print("\nSearch-space (states) should shrink monotonically RI -> RI-DS-SI-FC;"
      "\nmatch counts must be identical across variants.")
