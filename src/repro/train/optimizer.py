"""In-house AdamW with ZeRO-style sharded states and LR schedules.

No optax in this environment, so the optimizer is implemented directly:

* ``adamw_init / adamw_update`` — decoupled weight decay, fp32 moments.
* Moments inherit the parameter's logical sharding **plus** FSDP
  (``('pod','data')``) on the first shardable dim — ZeRO-1 semantics fall out
  of GSPMD: the reduce-scatter/all-gather pair around the update is inserted
  automatically when the gradient sharding (batch-reduced, replicated) meets
  the state sharding.
* ``cosine_schedule / linear_warmup`` — standard LR schedules.
* Global-norm clipping in fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: Any  # pytree like params, fp32
    nu: Any  # pytree like params, fp32


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to ``min_lr_ratio * lr``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.copy, zeros))


def abstract_state(abstract_params) -> AdamWState:
    z = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params
    )
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=z,
        nu=jax.tree.map(lambda x: x, z),
    )


def state_logical(param_logical) -> AdamWState:
    """Moments share the parameter logical axes (FSDP included)."""
    return AdamWState(step=(), mu=param_logical, nu=jax.tree.map(lambda x: x, param_logical))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def update(
    cfg: AdamWConfig, grads, state: AdamWState, params
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        p32 = p.astype(jnp.float32)
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32
        return (p32 - lr * step_).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([x[0] for x in new])
    new_m = treedef.unflatten([x[1] for x in new])
    new_v = treedef.unflatten([x[2] for x in new])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), {
        "grad_norm": gnorm,
        "lr": lr,
    }
