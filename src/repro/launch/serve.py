"""Batched serving driver: prefill + decode loop with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-12b --smoke \
      --max-new 16

Implements a minimal continuous-batching server core: requests are padded
into a fixed batch, prefilled once, then decoded step-by-step; finished
sequences are masked.  The production mesh path shards the batch over
``('pod','data')`` and the KV cache sequence dim over ``'model'``
(flash-decoding via GSPMD, see models/attention.py).
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import transformer as tf


def generate(
    params, cfg: tf.LMConfig, prompts: jnp.ndarray, max_new: int = 16,
    temperature: float = 0.0, seed: int = 0,
):
    """Greedy/temperature decode of a padded prompt batch."""
    b, s = prompts.shape
    max_len = s + max_new
    logits, cache = jax.jit(
        lambda p, t: tf.prefill(p, cfg, t, max_len=max_len)
    )(params, prompts)
    decode = jax.jit(lambda p, c, t, l: tf.decode_step(p, cfg, c, t, l))
    key = jax.random.PRNGKey(seed)
    out = [prompts]
    tok = None
    for i in range(max_new):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok[:, None].astype(jnp.int32)
        out.append(tok)
        logits, cache = decode(params, cache, tok, jnp.int32(s + i))
    return jnp.concatenate(out, axis=1)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    mod = importlib.import_module(f"repro.configs.{args.arch.replace('-', '_')}")
    cfg = mod.SMOKE if args.smoke else mod.CFG
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    ).astype(jnp.int32)
    t0 = time.perf_counter()
    out = generate(params, cfg, prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    assert out.shape == (args.batch, args.prompt_len + args.max_new)
    print(f"[serve] {args.arch}: generated {args.max_new} tokens × {args.batch} "
          f"seqs in {dt:.2f}s; sample: {np.asarray(out[0])[:12].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
