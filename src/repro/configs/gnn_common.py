"""Shared cell builders for the four assigned GNN architectures.

Shapes (assigned; every GNN arch runs all four):
  * ``full_graph_sm``  2,708 nodes / 10,556 edges / d_feat 1,433 (cora-like)
  * ``minibatch_lg``   232,965-node / 114.6M-edge graph (reddit-like), sampled
                       blocks of 1,024 seeds with fanout (15, 10); the device
                       step consumes the padded block + gathers rows from the
                       full feature table (the 114.6M edges live host-side in
                       the real `repro.models.gnn.sampler.NeighborSampler`)
  * ``ogb_products``   2,449,029 nodes / 61,859,140 edges / d_feat 100,
                       full-batch training
  * ``molecule``       128 graphs × 30 nodes / 64 edges, per-graph regression

All cells are full train steps (grad + AdamW).  Node counts are padded to a
multiple of 32 and edge counts to 512 so the logical shardings
(nodes→``batch``, edges→``edge``) always divide the mesh.

MODEL_FLOPS = 3 × analytic forward matmul flops (fwd + bwd ≈ 3× fwd).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.registry import Arch, Cell, CellBuild, round_up
from repro.data import graphgen
from repro.models.common import abstract_from_specs, init_from_specs, logical_from_specs
from repro.models.gnn import sampler as sampler_mod
from repro.models.gnn.common import segment_sum
from repro.train import optimizer as opt_mod
from repro.train.trainer import make_train_step

OPT = opt_mod.AdamWConfig(lr=1e-3, total_steps=100000)

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class GnnShape:
    n_nodes: int
    n_edges: int
    d_feat: int
    d_out: int
    task: str  # node_cls | block_cls | graph_reg
    n_graphs: int = 1
    table_nodes: int = 0  # block task: full feature-table rows
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()

    def padded(self) -> "GnnShape":
        # §Perf iter 4: nodes padded to 512 (not 32) so every *derived* edge
        # set (GraphCast overlay: e_g2m = 4n, e_mesh = 2n, mesh nodes = n/4)
        # stays divisible by the full 512-way mesh — otherwise the [E, d]
        # edge tensors replicate on every model shard (observed 100×+ memory
        # inflation on graphcast/ogb_products).  ≤ 13% pad on the smallest
        # graph, ≤ 0.02% at ogb scale.
        return dataclasses.replace(
            self,
            n_nodes=round_up(self.n_nodes, 512),
            n_edges=round_up(self.n_edges, 512),
            table_nodes=round_up(self.table_nodes, 16) if self.table_nodes else 0,
        )


def _block_dims(batch_nodes: int, fanout) -> Tuple[int, int]:
    return sampler_mod.block_shape(batch_nodes, fanout)


def gnn_shapes() -> Dict[str, GnnShape]:
    n_blk, e_blk = _block_dims(1024, (15, 10))
    return {
        "full_graph_sm": GnnShape(2708, 10556, 1433, 7, "node_cls").padded(),
        "minibatch_lg": GnnShape(
            n_blk, e_blk, 602, 41, "block_cls",
            table_nodes=232965, batch_nodes=1024, fanout=(15, 10),
        ).padded(),
        "ogb_products": GnnShape(2449029, 61859140, 100, 47, "node_cls").padded(),
        "molecule": GnnShape(30 * 128, 64 * 128, 16, 1, "graph_reg", n_graphs=128).padded(),
    }


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def batch_abstract(shape: GnnShape, with_positions: bool, with_mesh: bool):
    n, e = shape.n_nodes, shape.n_edges
    sds: Dict[str, Any] = {
        "src": jax.ShapeDtypeStruct((e,), I32),
        "dst": jax.ShapeDtypeStruct((e,), I32),
    }
    logical: Dict[str, Any] = {"src": ("edge",), "dst": ("edge",)}
    if shape.task == "block_cls":
        sds["table"] = jax.ShapeDtypeStruct((shape.table_nodes, shape.d_feat), F32)
        sds["feats_idx"] = jax.ShapeDtypeStruct((n,), I32)
        logical["table"] = ("tensor", None)
        logical["feats_idx"] = ("batch",)
    else:
        sds["feats"] = jax.ShapeDtypeStruct((n, shape.d_feat), F32)
        logical["feats"] = ("batch", None)
    if shape.task == "graph_reg":
        sds["graph_ids"] = jax.ShapeDtypeStruct((n,), I32)
        sds["graph_targets"] = jax.ShapeDtypeStruct((shape.n_graphs, shape.d_out), F32)
        logical["graph_ids"] = ("batch",)
        logical["graph_targets"] = ("batch", None)
    else:
        sds["labels"] = jax.ShapeDtypeStruct((n,), I32)
        logical["labels"] = ("batch",)
    if with_positions:
        sds["positions"] = jax.ShapeDtypeStruct((n, 3), F32)
        logical["positions"] = ("batch", None)
    if with_mesh:
        for key, (shp, dt) in graphgen.mesh_overlay_shapes(n).items():
            sds[key] = jax.ShapeDtypeStruct(shp, jnp.dtype(dt))
            logical[key] = graphgen.MESH_OVERLAY_LOGICAL[key]
    return sds, logical


def batch_concrete(shape: GnnShape, with_positions: bool, with_mesh: bool, seed=0):
    """Synthetic numpy batch matching ``batch_abstract`` (smoke tests)."""
    base = graphgen.gnn_batch(
        shape.n_nodes, shape.n_edges, shape.d_feat,
        n_classes=shape.d_out if shape.task != "graph_reg" else 0,
        with_positions=with_positions,
        n_graphs=shape.n_graphs if shape.task == "graph_reg" else 1,
        seed=seed,
    )
    if shape.task == "block_cls":
        rng = np.random.default_rng(seed + 1)
        base["table"] = rng.normal(size=(shape.table_nodes, shape.d_feat)).astype(np.float32)
        base["feats_idx"] = rng.integers(0, shape.table_nodes, shape.n_nodes).astype(np.int32)
        base.pop("feats")
    if with_mesh:
        base.update(graphgen.mesh_overlay(shape.n_nodes, seed=seed))
    return {k: jnp.asarray(v) for k, v in base.items()}


# ---------------------------------------------------------------------------
# loss glue (task adapters around each model's forward)
# ---------------------------------------------------------------------------

def task_loss(forward: Callable, shape: GnnShape):
    """Wrap a model ``forward(params, batch)->[N, d_out]`` for the cell task."""

    def loss(params, batch):
        batch = dict(batch)
        if shape.task == "block_cls":
            idx = jnp.maximum(batch["feats_idx"], 0)
            feats = jnp.take(batch["table"], idx, axis=0)
            feats = feats * (batch["feats_idx"] >= 0).astype(feats.dtype)[:, None]
            batch["feats"] = feats
        out = forward(params, batch)
        if shape.task == "graph_reg":
            g = segment_sum(out, batch["graph_ids"], shape.n_graphs)
            l = jnp.mean(jnp.square(g - batch["graph_targets"]))
            return l, {"loss": l}
        logz = jax.nn.logsumexp(out.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            out.astype(jnp.float32), jnp.maximum(batch["labels"], 0)[:, None], axis=1
        )[:, 0]
        mask = (batch["labels"] >= 0).astype(jnp.float32)
        l = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return l, {"loss": l}

    return loss


# ---------------------------------------------------------------------------
# arch assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GnnModelDef:
    """How one GNN architecture plugs into the shared cells."""

    name: str
    cfg: Any
    param_specs: Callable  # (cfg, d_in, d_out) -> SpecTree
    forward: Callable  # (params, cfg, batch) -> [N, d_out]
    fwd_flops: Callable  # (cfg, shape: GnnShape) -> float
    with_positions: bool = False
    with_mesh: bool = False
    smoke_cfg: Any = None
    notes: str = ""


def build_cell(md: GnnModelDef, shape: GnnShape) -> CellBuild:
    specs = md.param_specs(md.cfg, shape.d_feat, shape.d_out)
    p_abs = abstract_from_specs(specs)
    p_log = logical_from_specs(specs)
    o_abs = opt_mod.abstract_state(p_abs)
    o_log = opt_mod.state_logical(p_log)
    b_abs, b_log = batch_abstract(shape, md.with_positions, md.with_mesh)
    fwd = functools.partial(md.forward, cfg=md.cfg)
    loss = task_loss(lambda p, b: fwd(p, batch=b), shape)
    step = make_train_step(loss, OPT)
    return CellBuild(
        fn=step,
        args=(p_abs, o_abs, b_abs),
        logical=(p_log, o_log, b_log),
        model_flops=3.0 * md.fwd_flops(md.cfg, shape),
        donate=(0, 1),
    )


def gnn_smoke(md: GnnModelDef) -> Dict[str, float]:
    cfg = md.smoke_cfg or md.cfg
    shape = GnnShape(64, 256, 8, 4, "node_cls").padded()
    specs = md.param_specs(cfg, shape.d_feat, shape.d_out)
    params = init_from_specs(jax.random.PRNGKey(0), specs)
    batch = batch_concrete(shape, md.with_positions, md.with_mesh, seed=0)
    fwd = functools.partial(md.forward, cfg=cfg)
    loss = task_loss(lambda p, b: fwd(p, batch=b), shape)
    step = make_train_step(loss, OPT)
    opt = opt_mod.init(params)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    lv = float(metrics["loss_total"])
    assert np.isfinite(lv), f"{md.name}: non-finite loss {lv}"
    out = jax.jit(lambda p, b: fwd(p, batch=b))(params, batch)
    assert out.shape == (shape.n_nodes, shape.d_out)
    assert bool(jnp.all(jnp.isfinite(out)))
    return {"loss": lv}


def make_gnn_arch(md: GnnModelDef) -> Arch:
    cells = {}
    for sname, shape in gnn_shapes().items():
        cells[sname] = Cell(
            md.name, sname, "train",
            functools.partial(build_cell, md, shape),
        )
    return registry.register(
        Arch(
            name=md.name,
            family="gnn",
            cfg=md.cfg,
            cells=cells,
            smoke=functools.partial(gnn_smoke, md),
            notes=md.notes,
        )
    )
