"""The expansion step — candidate bitmaps, lowest-untried-bit extraction,
child emission, match counting — behind the ``StepBackend`` seam
(DESIGN.md §6.2).

One expansion step, for every popped lane: extract the lowest untried
candidate bit ``v``, extend the mapping, build the child's candidate
bitmap ``dom[pos+1] ∧ ¬used' ∧ ⋀ adj_rows(mapped parents)`` (the paper's
check-consistency-before-spawning rule, §3.1), and flag matches at full
depth.  The work is *lane-flat*: the step function flattens all
``V·expand_width`` lanes of its worker shard into one batch, so a backend
sees a single dense batch regardless of worker count or mesh shard — and a
Pallas backend gets one big grid instead of ``V`` vmapped kernel calls.

Backends (selected by ``EngineConfig.step_backend``):

* ``"jnp"`` — :class:`JnpStepBackend`, the loose-ops reference: pure jnp
  phases with full HBM round-trips between them; with
  ``EngineConfig.use_pallas`` the candidate-bitmap AND routes through the
  `repro.kernels.candidate_mask` kernel (the pre-seam behavior, kept as
  the mask-only kerneling point of comparison).
* ``"pallas"`` — :class:`PallasStepBackend`, the fused
  `repro.kernels.extend_step` kernel: adjacency-row gathers
  (scalar-prefetched), the ``dom ∧ ¬used ∧ parents`` AND-tree, per-lane
  lowest-bit extraction and match flagging in **one** kernel invocation
  (DESIGN.md §6.3) — subsuming ``candidate_mask`` on the engine path.
* ``"csr"`` — :class:`CsrStepBackend`, the sparse layout for targets far
  beyond paper scale (DESIGN.md §6.4): instead of ANDing dense
  ``[n_t, w]`` adjacency bitmap rows, it gathers each mapped parent's CSR
  neighbor segment (:class:`CsrPlanArrays`) and **sorted-intersects** the
  lists — ``O(parents · deg)`` work against the sparse structure, with the
  dense ``O(n_planes · n_t · w)`` bitmaps never resident.  With
  ``cfg.use_pallas`` the walk routes through the
  `repro.kernels.csr_extend` kernel (scalar-prefetched ``indptr`` row
  bounds, ``pl.ds`` neighbor loads).
* ``"auto"`` — not a backend: resolves per plan to ``"csr"`` when
  ``n_t > CSR_AUTO_NT`` and ``"jnp"`` otherwise
  (:func:`resolve_step_backend`).

All backends are bit-identical on every :class:`StepLanes` field the
engine consumes (the conformance matrix in
``tests/test_backend_conformance.py`` gates this for every current and
future entry of ``STEP_BACKENDS``); the driver (`repro.core.engine`)
never knows which one ran.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Protocol, Tuple, TYPE_CHECKING, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec

from repro.core import frontier
from repro.core.frontier import EngineState, SpillState
from repro.core.graph import (
    WORD_BITS,
    CsrPlanes,
    PartitionedPlanes,
    bitmap_from_indices,
    csr_planes_from_bitmaps,
    deg_bucket_caps,
    partition_csr_planes,
)
from repro.core.plan import SearchPlan

if TYPE_CHECKING:  # engine imports extend; annotations only
    from repro.core.engine import EngineConfig

STEP_BACKENDS = ("jnp", "pallas", "csr")

# "auto" resolution threshold: beyond this many target nodes the dense
# [n_elab, 2, n_t, w] bitmaps cost O(n_t²/32) words (sge_pdbsv1's 33,067
# nodes ⇒ ~273 MB) and the sparse layout takes over.
CSR_AUTO_NT = 32768

# int32 sentinel for padded CSR segment slots: larger than any node id, so
# sentinel-masked segments stay sorted for the binary-search membership test.
CSR_SENTINEL = np.int32(2**31 - 1)


def resolve_step_backend(cfg: "EngineConfig", n_t: int) -> str:
    """Resolve ``cfg.step_backend`` for a plan with ``n_t`` target nodes:
    ``"auto"`` picks ``"csr"`` past :data:`CSR_AUTO_NT` (an explicit backend
    always wins).  Deterministic per (cfg, n_t), so session compile-cache
    keys — which carry both — stay unambiguous."""
    if cfg.step_backend != "auto":
        return cfg.step_backend
    return "csr" if n_t > CSR_AUTO_NT else "jnp"


class PlanArrays(NamedTuple):
    """Device-resident static plan arrays (see SearchPlan)."""

    order_valid: jnp.ndarray  # [p_pad] bool (True for real positions)
    parent_pos: jnp.ndarray  # [p_pad, mp] int32
    parent_dir: jnp.ndarray  # [p_pad, mp]
    parent_elab: jnp.ndarray  # [p_pad, mp]
    dom_bits: jnp.ndarray  # [p_pad, w] uint32
    adj_bits: jnp.ndarray  # [n_elab, 2, n_t, w] uint32
    n_p: jnp.ndarray  # scalar int32 (actual pattern size)


def make_plan_arrays(plan: SearchPlan, adj_bits=None) -> PlanArrays:
    """``adj_bits`` optionally supplies an already device-resident
    adjacency buffer (the dominant transfer) so same-version plans — a
    query plan and its delta anchor plans — share one host→device copy."""
    return PlanArrays(
        order_valid=jnp.asarray(plan.order >= 0),
        parent_pos=jnp.asarray(plan.parent_pos, jnp.int32),
        parent_dir=jnp.asarray(plan.parent_dir, jnp.int32),
        parent_elab=jnp.asarray(plan.parent_elab, jnp.int32),
        dom_bits=jnp.asarray(plan.dom_bits, jnp.uint32),
        adj_bits=(jnp.asarray(plan.adj_bits, jnp.uint32)
                  if adj_bits is None else adj_bits),
        n_p=jnp.asarray(plan.n_p, jnp.int32),
    )


def abstract_plan_arrays(
    n_t: int, w: int, p_pad: int, max_parents: int, n_elab: int = 1
) -> PlanArrays:
    sds = jax.ShapeDtypeStruct
    return PlanArrays(
        order_valid=sds((p_pad,), jnp.bool_),
        parent_pos=sds((p_pad, max_parents), jnp.int32),
        parent_dir=sds((p_pad, max_parents), jnp.int32),
        parent_elab=sds((p_pad, max_parents), jnp.int32),
        dom_bits=sds((p_pad, w), jnp.uint32),
        adj_bits=sds((n_elab, 2, n_t, w), jnp.uint32),
        n_p=sds((), jnp.int32),
    )


PLAN_LOGICAL = PlanArrays(
    order_valid=(None,),
    parent_pos=(None, None),
    parent_dir=(None, None),
    parent_elab=(None, None),
    dom_bits=(None, "tensor"),
    adj_bits=(None, None, None, "tensor"),
    n_p=(),
)


def plan_partition_specs() -> PlanArrays:
    """PartitionSpecs for :class:`PlanArrays`: fully replicated (every
    device needs the whole domain/adjacency bitmaps to expand its workers)."""
    P = PartitionSpec
    return PlanArrays(
        order_valid=P(None),
        parent_pos=P(None, None),
        parent_dir=P(None, None),
        parent_elab=P(None, None),
        dom_bits=P(None, None),
        adj_bits=P(None, None, None, None),
        n_p=P(),
    )


# ---------------------------------------------------------------------------
# CSR plan arrays (the sparse twin of PlanArrays, DESIGN.md §6.4)
# ---------------------------------------------------------------------------

class CsrPlanArrays(NamedTuple):
    """Device-resident static plan arrays in CSR adjacency layout.

    The shared fields mirror :class:`PlanArrays`; the dense ``adj_bits``
    are replaced by flattened per-``(elab, dir)`` CSR planes
    (`repro.core.graph.CsrPlanes`).  ``seg_iota`` exists to carry the
    static segment-gather width ``deg_cap`` in its *shape* (plan arrays are
    traced under jit, so structural constants must be shape-derived);
    ``indices`` is over-padded by ``deg_cap`` sentinel entries so a
    ``deg_cap``-wide dynamic slice starting at any real offset never
    clamps.
    """

    order_valid: jnp.ndarray  # [p_pad] bool (True for real positions)
    parent_pos: jnp.ndarray  # [p_pad, mp] int32
    parent_dir: jnp.ndarray  # [p_pad, mp]
    parent_elab: jnp.ndarray  # [p_pad, mp]
    dom_bits: jnp.ndarray  # [p_pad, w] uint32
    indptr: jnp.ndarray  # [n_planes, n_t + 1] int32, global offsets
    indices: jnp.ndarray  # [nnz_pad + deg_cap] int32, sentinel-padded tail
    seg_iota: jnp.ndarray  # [deg_cap] int32 (0..deg_cap-1)
    n_p: jnp.ndarray  # scalar int32 (actual pattern size)


def _pad_deg_cap(deg_cap: int) -> int:
    """Segment-gather width: max row degree snapped up to a multiple of 8
    (min 8), so near-identical targets share a compile shape."""
    return max(8, ((deg_cap + 7) // 8) * 8)


def _pad_nnz(nnz: int) -> int:
    """nnz shape bucket (multiples of 1024) — keeps re-prepared same-target
    queries on one compiled engine."""
    return max(1024, ((nnz + 1023) // 1024) * 1024)


def _plan_csr(plan: SearchPlan) -> CsrPlanes:
    """The plan's CSR planes, resolved once and cached on the plan:
    explicit ``plan.csr`` (CSR-only plans) wins, then ``plan.csr_factory``
    (session-built plans share the index's incrementally patched plane set,
    DESIGN.md §8), then a fresh dense→sparse conversion."""
    cp = plan.csr
    if cp is None:
        if plan.csr_factory is not None:
            cp = plan.csr_factory()
        else:
            cp = csr_planes_from_bitmaps(np.asarray(plan.adj_bits))
        plan.csr = cp  # cache: conversion is O(n_t · w) host work
    return cp


def make_csr_plan_arrays(plan: SearchPlan) -> CsrPlanArrays:
    """Build :class:`CsrPlanArrays` from a :class:`SearchPlan`.

    CSR-only plans (``plan.csr`` set by `repro.core.plan.build_csr_plan`)
    use their planes directly; dense-built plans derive (and cache) the
    planes from ``adj_bits`` — bit-for-bit the same adjacency relation
    (`repro.core.graph.csr_planes_from_bitmaps`), which is what lets the
    conformance suite run every backend on one plan.
    """
    cp = _plan_csr(plan)
    deg_cap = _pad_deg_cap(cp.deg_cap)
    nnz_pad = _pad_nnz(cp.nnz)
    indices = np.full(nnz_pad + deg_cap, CSR_SENTINEL, dtype=np.int32)
    indices[: cp.nnz] = cp.indices
    return CsrPlanArrays(
        order_valid=jnp.asarray(plan.order >= 0),
        parent_pos=jnp.asarray(plan.parent_pos, jnp.int32),
        parent_dir=jnp.asarray(plan.parent_dir, jnp.int32),
        parent_elab=jnp.asarray(plan.parent_elab, jnp.int32),
        dom_bits=jnp.asarray(plan.dom_bits, jnp.uint32),
        indptr=jnp.asarray(cp.indptr, jnp.int32),
        indices=jnp.asarray(indices),
        seg_iota=jnp.arange(deg_cap, dtype=jnp.int32),
        n_p=jnp.asarray(plan.n_p, jnp.int32),
    )


def abstract_csr_plan_arrays(
    n_t: int, w: int, p_pad: int, max_parents: int, n_elab: int = 1,
    nnz: int = 0, deg_cap: int = 8,
) -> CsrPlanArrays:
    sds = jax.ShapeDtypeStruct
    deg_cap = _pad_deg_cap(deg_cap)
    return CsrPlanArrays(
        order_valid=sds((p_pad,), jnp.bool_),
        parent_pos=sds((p_pad, max_parents), jnp.int32),
        parent_dir=sds((p_pad, max_parents), jnp.int32),
        parent_elab=sds((p_pad, max_parents), jnp.int32),
        dom_bits=sds((p_pad, w), jnp.uint32),
        indptr=sds((n_elab * 2, n_t + 1), jnp.int32),
        indices=sds((_pad_nnz(nnz) + deg_cap,), jnp.int32),
        seg_iota=sds((deg_cap,), jnp.int32),
        n_p=sds((), jnp.int32),
    )


CSR_PLAN_LOGICAL = CsrPlanArrays(
    order_valid=(None,),
    parent_pos=(None, None),
    parent_dir=(None, None),
    parent_elab=(None, None),
    dom_bits=(None, "tensor"),
    indptr=(None, None),
    indices=(None,),
    seg_iota=(None,),
    n_p=(),
)


def csr_plan_partition_specs() -> CsrPlanArrays:
    """PartitionSpecs for :class:`CsrPlanArrays`: fully replicated, like the
    dense plan (any worker may map any target node, so every device needs
    the whole — small — CSR structure)."""
    P = PartitionSpec
    return CsrPlanArrays(
        order_valid=P(None),
        parent_pos=P(None, None),
        parent_dir=P(None, None),
        parent_elab=P(None, None),
        dom_bits=P(None, None),
        indptr=P(None, None),
        indices=P(None),
        seg_iota=P(None),
        n_p=P(),
    )


# ---------------------------------------------------------------------------
# partitioned plan arrays (out-of-core targets, DESIGN.md §9)
# ---------------------------------------------------------------------------

class PartPlanArrays(NamedTuple):
    """Device-resident plan arrays for **one resident partition** of a
    row-partitioned target (`repro.core.graph.PartitionedPlanes`).

    Mirrors :class:`CsrPlanArrays` with the plane rows restricted to the
    resident partition: ``indptr`` is over partition-**local** rows (global
    row ``t`` ↦ ``t - part_lo``); ``indices`` keep **global** column ids.
    Every partition of a target is padded to the *same* shapes
    (``max_local`` rows, ``max_nnz`` entries), so one compiled engine serves
    all partitions and swapping partitions is a pure data transfer —
    ``part_lo`` / ``part_hi`` bound the resident global-row range and
    ``part_starts`` routes spill entries to the partition owning their
    first pending parent.
    """

    order_valid: jnp.ndarray  # [p_pad] bool
    parent_pos: jnp.ndarray  # [p_pad, mp] int32
    parent_dir: jnp.ndarray  # [p_pad, mp]
    parent_elab: jnp.ndarray  # [p_pad, mp]
    dom_bits: jnp.ndarray  # [p_pad, w] uint32
    indptr: jnp.ndarray  # [n_planes, max_loc_pad + 1] int32, local rows
    indices: jnp.ndarray  # [nnz_pad + deg_cap] int32, global columns
    seg_iota: jnp.ndarray  # [deg_cap] int32
    part_starts: jnp.ndarray  # [n_parts + 1] int32 global row boundaries
    part_lo: jnp.ndarray  # [] int32 resident range start (global row)
    part_hi: jnp.ndarray  # [] int32 resident range end (exclusive)
    n_p: jnp.ndarray  # [] int32


def _pad_rows(n: int) -> int:
    """Local-row shape bucket (multiples of 64, min 64) so all partitions of
    a target — and re-partitioned same-scale targets — share one compile."""
    return max(64, ((n + 63) // 64) * 64)


def plan_partitions(plan: SearchPlan, n_parts: int) -> PartitionedPlanes:
    """The plan's target partitioning at ``n_parts``, computed once and
    cached on the plan (partitioning is O(nnz) host work per count)."""
    cache = getattr(plan, "_partitions", None)
    if cache is None:
        cache = {}
        plan._partitions = cache
    pp = cache.get(n_parts)
    if pp is None:
        pp = partition_csr_planes(_plan_csr(plan), n_parts=n_parts)
        cache[n_parts] = pp
    return pp


def plan_partitions_budget(plan: SearchPlan, max_bytes: int) -> PartitionedPlanes:
    """Partitioning at the smallest count whose **padded** resident plane
    arrays (:func:`part_resident_nbytes` — what actually occupies the
    device) fit ``max_bytes``; cached on the plan under both the budget and
    the resulting count, so the engine's ``plan_partitions(plan,
    pp.n_parts)`` returns the same object."""
    cache = getattr(plan, "_partitions", None)
    if cache is None:
        cache = {}
        plan._partitions = cache
    key = ("budget", int(max_bytes))
    pp = cache.get(key)
    if pp is None:
        cp = _plan_csr(plan)
        pp = partition_csr_planes(cp, max_bytes=max_bytes)
        while part_resident_nbytes(pp) > max_bytes and pp.n_parts < cp.n_t:
            pp = partition_csr_planes(cp, n_parts=pp.n_parts + 1)
        if part_resident_nbytes(pp) > max_bytes:
            raise ValueError(
                f"memory_budget_bytes={max_bytes} cannot hold even a "
                f"single-row partition's padded planes "
                f"({part_resident_nbytes(pp)} bytes at n_parts={pp.n_parts})"
            )
        cache[key] = pp
        cache.setdefault(pp.n_parts, pp)
    return pp


def partitioned_shape_bucket(plan: SearchPlan, n_parts: int) -> Tuple[int, ...]:
    """``(n_parts, max_loc_pad, nnz_pad, *bucket_caps)`` — the partition
    identity the session folds into compile-cache and coalesce keys: two
    queries share a compiled partitioned engine iff these (plus the usual
    bucket) agree.  As in :func:`csr_shape_bucket`, the trailing entries are
    the pow2 degree-bucket ladder rather than one global ``deg_cap``."""
    pp = plan_partitions(plan, n_parts)
    return (
        pp.n_parts,
        _pad_rows(pp.max_local),
        _pad_nnz(pp.max_nnz),
    ) + deg_bucket_caps(_pad_deg_cap(pp.deg_cap))


def part_resident_nbytes(pp: PartitionedPlanes) -> int:
    """Device bytes of one resident partition's padded plane arrays
    (``indptr`` + ``indices`` + ``part_starts``) — what the memory budget
    bounds.  Slightly above ``PartitionedPlanes.max_resident_nbytes``
    because of the shared-compile shape padding."""
    max_loc_pad = _pad_rows(pp.max_local)
    nnz_pad = _pad_nnz(pp.max_nnz)
    deg_cap = _pad_deg_cap(pp.deg_cap)
    return 4 * (pp.n_planes * (max_loc_pad + 1) + nnz_pad + deg_cap + pp.n_parts + 1)


def make_part_plan_arrays(
    plan: SearchPlan, pp: PartitionedPlanes, pid: int
) -> PartPlanArrays:
    """Device arrays for partition ``pid`` — all partitions pad to common
    shapes (see :class:`PartPlanArrays`).  Padded local rows repeat the
    plane's end offset (zero-length rows); padded ``indices`` entries are
    :data:`CSR_SENTINEL`."""
    part = pp.parts[pid]
    max_loc_pad = _pad_rows(pp.max_local)
    nnz_pad = _pad_nnz(pp.max_nnz)
    deg_cap = _pad_deg_cap(pp.deg_cap)
    n_loc = part.n_t
    indptr = np.zeros((pp.n_planes, max_loc_pad + 1), dtype=np.int32)
    indptr[:, : n_loc + 1] = part.indptr
    indptr[:, n_loc + 1 :] = part.indptr[:, -1:]
    indices = np.full(nnz_pad + deg_cap, CSR_SENTINEL, dtype=np.int32)
    indices[: part.nnz] = part.indices
    return PartPlanArrays(
        order_valid=jnp.asarray(plan.order >= 0),
        parent_pos=jnp.asarray(plan.parent_pos, jnp.int32),
        parent_dir=jnp.asarray(plan.parent_dir, jnp.int32),
        parent_elab=jnp.asarray(plan.parent_elab, jnp.int32),
        dom_bits=jnp.asarray(plan.dom_bits, jnp.uint32),
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(indices),
        seg_iota=jnp.arange(deg_cap, dtype=jnp.int32),
        part_starts=jnp.asarray(pp.node_start, jnp.int32),
        part_lo=jnp.asarray(int(pp.node_start[pid]), jnp.int32),
        part_hi=jnp.asarray(int(pp.node_start[pid + 1]), jnp.int32),
        n_p=jnp.asarray(plan.n_p, jnp.int32),
    )


def part_plan_partition_specs() -> PartPlanArrays:
    """PartitionSpecs for :class:`PartPlanArrays`: fully replicated — under
    a mesh the *same* resident partition is swapped onto every device and
    workers shard over the ``data`` axis (partitions stream through time,
    not across devices)."""
    P = PartitionSpec
    return PartPlanArrays(
        order_valid=P(None),
        parent_pos=P(None, None),
        parent_dir=P(None, None),
        parent_elab=P(None, None),
        dom_bits=P(None, None),
        indptr=P(None, None),
        indices=P(None),
        seg_iota=P(None),
        part_starts=P(None),
        part_lo=P(),
        part_hi=P(),
        n_p=P(),
    )


AnyPlanArrays = Union[PlanArrays, CsrPlanArrays, PartPlanArrays]


def is_csr_only(plan: SearchPlan) -> bool:
    """True for plans built by ``build_csr_plan``: the dense adjacency was
    never materialized, so only the csr layout can run them."""
    return plan.csr is not None and plan.adj_bits.shape[2] == 0


def resolve_step_backend_for_plan(cfg: "EngineConfig", plan: SearchPlan) -> str:
    """:func:`resolve_step_backend` with the plan in hand: a CSR-only plan
    has no dense layout to fall back to, so ``"auto"`` always resolves to
    ``"csr"`` for it — whatever its ``n_t``."""
    if is_csr_only(plan) and cfg.step_backend == "auto":
        return "csr"
    return resolve_step_backend(cfg, plan.n_t)


def validate_backend_for_plan(cfg: "EngineConfig", plan: SearchPlan) -> None:
    """Fail fast when an **explicitly dense** step backend is asked to run
    a CSR-only plan.  :func:`plan_arrays_for` raises for the combination
    anyway, but only after the session has already traced (and counted) an
    engine for the doomed configuration — sessions call this at
    prepare/run entry instead, before any compile is spent."""
    if cfg.step_backend in ("jnp", "pallas") and is_csr_only(plan):
        raise ValueError(
            f"step_backend={cfg.step_backend!r} is a dense backend, but the "
            "plan is CSR-only (layout: csr — built by build_csr_plan, so "
            "dense adj_bits were never materialized); valid backends for "
            "this plan are 'csr', 'auto', or 'partitioned'"
        )


def plan_arrays_for(cfg: "EngineConfig", plan: SearchPlan,
                    adj_bits=None) -> AnyPlanArrays:
    """The one plan-array construction point for both drivers and the
    session: dense :class:`PlanArrays` or sparse :class:`CsrPlanArrays`
    per the resolved step backend.  ``adj_bits`` passes a pre-transferred
    device adjacency through to :func:`make_plan_arrays` (ignored by the
    CSR layout, which never ships the dense bitmaps)."""
    resolved = resolve_step_backend_for_plan(cfg, plan)
    if resolved == "partitioned":
        raise ValueError(
            "step_backend='partitioned' builds per-partition arrays inside "
            "repro.core.engine.run_partitioned (one PartPlanArrays per swap), "
            "not a single monolithic plan-array pytree"
        )
    if resolved == "csr":
        return make_csr_plan_arrays(plan)
    if is_csr_only(plan):
        raise ValueError(
            "plan is CSR-only (built by build_csr_plan: dense adj_bits were "
            "never materialized) — run it with step_backend='csr' or 'auto'"
        )
    return make_plan_arrays(plan, adj_bits=adj_bits)


def csr_shape_bucket(plan: SearchPlan) -> Tuple[int, ...]:
    """``(nnz, *bucket_caps)`` padded shape bucket of a plan's CSR arrays —
    the extra pack-grouping key the session needs under the csr backend: two
    same-``(n_t, w)`` targets of different density have differently shaped
    :class:`CsrPlanArrays` and cannot share a vmapped pack lane.  The former
    scalar ``deg_cap`` entry is now the full pow2 degree-bucket ladder
    (`repro.core.graph.deg_bucket_caps`, DESIGN.md §10): the bucketed walk's
    trip count is derived from the ladder, so targets agreeing on it share a
    compiled engine even when their raw max degrees differ."""
    cp = _plan_csr(plan)
    return (_pad_nnz(cp.nnz),) + deg_bucket_caps(_pad_deg_cap(cp.deg_cap))


def plan_partition_specs_for(cfg: "EngineConfig", n_t: int, csr_only: bool = False):
    """Replicated in-specs matching :func:`plan_arrays_for`'s pytree
    (``csr_only`` mirrors :func:`resolve_step_backend_for_plan`'s rule for
    plans that have no dense layout)."""
    if csr_only and cfg.step_backend == "auto":
        return csr_plan_partition_specs()
    if resolve_step_backend(cfg, n_t) == "csr":
        return csr_plan_partition_specs()
    return plan_partition_specs()


# ---------------------------------------------------------------------------
# bit helpers
# ---------------------------------------------------------------------------

def pop_lowest_bit(cand: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Extract the lowest set bit of a ``[W]`` uint32 bitmap.

    Returns ``(valid, v, cand_without_v)``; ``v`` is the global bit index.
    """
    nz = cand != 0
    valid = jnp.any(nz)
    widx = jnp.argmax(nz)  # first non-zero word (0 if none)
    word = cand[widx]
    # trailing zeros = popcount(~w & (w - 1)); word==0 guarded by `valid`.
    tz = lax.population_count(~word & (word - jnp.uint32(1)))
    v = widx.astype(jnp.int32) * WORD_BITS + tz.astype(jnp.int32)
    cand2 = cand.at[widx].set(word & (word - jnp.uint32(1)))
    return valid, v, cand2


def bit_row(v: jnp.ndarray, w: int) -> jnp.ndarray:
    """One-hot ``[w]`` uint32 bitmap with bit ``v`` set."""
    word = v // WORD_BITS
    bit = jnp.uint32(1) << (v % WORD_BITS).astype(jnp.uint32)
    return jnp.zeros((w,), jnp.uint32).at[word].set(bit)


def compute_cand_jnp(
    plan: PlanArrays, pos: jnp.ndarray, map_: jnp.ndarray, used: jnp.ndarray
) -> jnp.ndarray:
    """Candidate bitmap for order position ``pos`` given mapping/used.

    ``dom[pos] ∧ ¬used ∧ ⋀_parents adj_bits[elab, dir, mapped_parent]`` —
    the engine's hot loop; `repro.kernels.extend_step` is the fused Pallas
    form and `repro.kernels.candidate_mask` the mask-only one.
    """
    mp = plan.parent_pos.shape[1]
    safe_pos = jnp.clip(pos, 0, plan.dom_bits.shape[0] - 1)
    cand = plan.dom_bits[safe_pos] & ~used

    def body(j, c):
        pp = plan.parent_pos[safe_pos, j]
        pd = plan.parent_dir[safe_pos, j]
        pl = plan.parent_elab[safe_pos, j]
        t = jnp.where(pp >= 0, map_[jnp.maximum(pp, 0)], 0)
        row = plan.adj_bits[pl, pd, jnp.clip(t, 0, plan.adj_bits.shape[2] - 1)]
        return jnp.where(pp >= 0, c & row, c)

    return lax.fori_loop(0, mp, body, cand)


def host_cand_bitmap(plan: SearchPlan, pos: int, mapping: np.ndarray) -> np.ndarray:
    """Host (numpy) twin of :func:`compute_cand_jnp` for one entry.

    ``mapping`` is a ``[p_pad]`` int array whose positions ``< pos`` hold the
    partial embedding (-1 elsewhere); returns the ``[w]`` uint32 candidate
    bitmap ``dom[pos] ∧ ¬used ∧ ⋀_parents adj_row`` with exactly the
    engine's semantics.  The delta seeding path (DESIGN.md §8) uses this to
    pre-validate engine seeds — the engine trusts stored candidate bitmaps
    and never re-checks them.  Works for dense and CSR-only plans.
    """
    pos = int(pos)
    prefix = np.asarray(mapping[:pos], dtype=np.int64)
    used = bitmap_from_indices(prefix[prefix >= 0], plan.n_t, plan.w)
    cand = plan.dom_bits[pos] & ~used
    dense = plan.adj_bits.shape[2] > 0
    cp = None if dense else _plan_csr(plan)
    for j in range(plan.max_parents):
        pp = int(plan.parent_pos[pos, j])
        if pp < 0:
            continue
        t = int(mapping[pp])
        pd = int(plan.parent_dir[pos, j])
        pl = int(plan.parent_elab[pos, j])
        if dense:
            row = plan.adj_bits[pl, pd, t]
        else:
            plane = pl * 2 + pd
            s, e = int(cp.indptr[plane, t]), int(cp.indptr[plane, t + 1])
            row = bitmap_from_indices(cp.indices[s:e], plan.n_t, plan.w)
        cand = cand & row
    return cand


# ---------------------------------------------------------------------------
# the StepBackend seam
# ---------------------------------------------------------------------------

class StepLanes(NamedTuple):
    """Everything one expansion produces per flattened lane ``[B = V·E]``.

    ``v`` is informational (-1 or unspecified on invalid lanes; every
    consumer gates on ``valid``); the stack payloads are ``cand2`` (the
    parent's residual candidates), ``(map2, used2, child_cand)`` (the
    child entry), and the ``is_match`` / ``has_child`` flags the driver
    accumulates.
    """

    valid: jnp.ndarray  # [B] bool — lane had an untried candidate
    v: jnp.ndarray  # [B] int32 — extracted target node
    is_match: jnp.ndarray  # [B] bool — extension completed the pattern
    has_child: jnp.ndarray  # [B] bool — child has a non-empty candidate set
    cand2: jnp.ndarray  # [B, W] uint32 — parent candidates minus v
    map2: jnp.ndarray  # [B, P] int32 — mapping extended with v
    used2: jnp.ndarray  # [B, W] uint32 — used-bitmap with v set
    child_cand: jnp.ndarray  # [B, W] uint32 — zeroed unless a child is wanted


class StepBackend(Protocol):
    """One expansion over a flat batch of popped lanes (DESIGN.md §6.2).

    Implementations must be bit-identical on every field of
    :class:`StepLanes` that the engine consumes (all but ``v`` on invalid
    lanes); ``tests/test_extend_step.py`` property-tests this.
    """

    name: str

    def expand_lanes(
        self,
        depth: jnp.ndarray,  # [B] int32 (0 on off lanes)
        map_: jnp.ndarray,  # [B, P] int32
        used: jnp.ndarray,  # [B, W] uint32
        cand: jnp.ndarray,  # [B, W] uint32 (0 on off lanes)
    ) -> StepLanes:
        ...


class JnpStepBackend:
    """Reference backend: the loose-ops jnp step (optionally routing the
    candidate-bitmap AND through the ``candidate_mask`` kernel when
    ``cfg.use_pallas`` — the pre-seam kerneling point)."""

    name = "jnp"

    def __init__(self, cfg: "EngineConfig", plan: PlanArrays):
        self.plan = plan
        self.p_pad, self.w = plan.dom_bits.shape
        if cfg.use_pallas:
            from repro.kernels import ops as kops

            rows = kops.flatten_adj_rows(plan.adj_bits)
            n_rows = rows.shape[0] - 1
            n_t = plan.adj_bits.shape[2]
            p_max = self.p_pad - 1

            def compute_cand(pos, map2, used2):
                safe_pos = jnp.clip(pos, 0, p_max)
                row_idx = jax.vmap(
                    lambda p, m: kops.flat_row_index(
                        plan.parent_pos[p], plan.parent_dir[p], plan.parent_elab[p],
                        m, n_t, n_rows,
                    )
                )(safe_pos, map2)
                return kops.candidate_mask(rows, plan.dom_bits, safe_pos, row_idx, used2)
        else:
            compute_one = functools.partial(compute_cand_jnp, plan)

            def compute_cand(pos, map2, used2):
                return jax.vmap(compute_one)(pos, map2, used2)

        self._compute_cand = compute_cand

    def expand_lanes(self, depth, map_, used, cand) -> StepLanes:
        plan = self.plan
        b = depth.shape[0]
        valid, v, cand2 = jax.vmap(pop_lowest_bit)(cand)
        map2 = jnp.where(
            valid[:, None],
            map_.at[jnp.arange(b), jnp.clip(depth, 0, self.p_pad - 1)].set(v),
            map_,
        )
        used2 = jnp.where(
            valid[:, None], used | jax.vmap(bit_row, (0, None))(v, self.w), used
        )
        is_match = valid & (depth + 1 >= plan.n_p)
        want_child = valid & ~is_match
        child_cand = self._compute_cand(jnp.where(want_child, depth + 1, 0), map2, used2)
        child_cand = jnp.where(want_child[:, None], child_cand, jnp.uint32(0))
        has_child = want_child & jnp.any(child_cand != 0, axis=-1)
        return StepLanes(valid, v, is_match, has_child, cand2, map2, used2, child_cand)


class PallasStepBackend:
    """The fused step: one `repro.kernels.extend_step` invocation per
    expansion (DESIGN.md §6.3).

    jnp's only jobs here are scalar bookkeeping the scalar-prefetch
    machinery requires up front — the extracted ``v`` feeds the flattened
    adjacency-row table the kernel's DMA pipeline chases — and the cheap
    ``map2`` / ``used2`` payload updates.  All ``w``-wide work (extraction,
    the AND-tree, child zeroing, match/child flagging) happens inside the
    kernel without intermediate HBM round-trips.
    """

    name = "pallas"

    def __init__(self, cfg: "EngineConfig", plan: PlanArrays):
        from repro.kernels import ops as kops

        self._kops = kops
        self.plan = plan
        self.p_pad, self.w = plan.dom_bits.shape
        self.rows = kops.flatten_adj_rows(plan.adj_bits)
        self.n_rows = self.rows.shape[0] - 1
        self.n_t = plan.adj_bits.shape[2]

    def expand_lanes(self, depth, map_, used, cand) -> StepLanes:
        plan, kops = self.plan, self._kops
        b = depth.shape[0]
        valid_j, v_j, _ = jax.vmap(pop_lowest_bit)(cand)
        map2 = jnp.where(
            valid_j[:, None],
            map_.at[jnp.arange(b), jnp.clip(depth, 0, self.p_pad - 1)].set(v_j),
            map_,
        )
        used2 = jnp.where(
            valid_j[:, None], used | jax.vmap(bit_row, (0, None))(v_j, self.w), used
        )
        child_pos = jnp.clip(depth + 1, 0, self.p_pad - 1)
        row_idx = jax.vmap(
            lambda p, m: kops.flat_row_index(
                plan.parent_pos[p], plan.parent_dir[p], plan.parent_elab[p],
                m, self.n_t, self.n_rows,
            )
        )(child_pos, map2)
        cand2, child_cand, meta = kops.extend_step(
            self.rows, plan.dom_bits, child_pos, row_idx, depth, plan.n_p,
            used, cand,
        )
        valid = meta[:, 0] != 0
        return StepLanes(
            valid=valid,
            v=meta[:, 1],
            is_match=meta[:, 2] != 0,
            has_child=meta[:, 3] != 0,
            cand2=cand2,
            map2=map2,
            used2=used2,
            child_cand=child_cand,
        )


class CsrStepBackend:
    """The sparse backend (DESIGN.md §6.4): child candidates come from a
    CSR walk instead of the dense-row AND-tree.

    Per lane, the driver parent's neighbor segment (its ``indptr`` run,
    gathered ``deg_cap`` wide) proposes candidates; each survives iff its
    bit is set in ``dom[pos+1] ∧ ¬used'`` and a **binary search finds it in
    every other mapped parent's sorted segment** — the sorted-intersection
    of the paper's adjacency lists.  Survivors scatter back into the
    ``[w]`` candidate bitmap the stack stores, so every downstream
    structure (and therefore every result bit) is identical to the dense
    backends.  Parentless positions (disconnected patterns / roots) fall
    back to the plain ``dom ∧ ¬used`` bitmap.

    With ``cfg.use_pallas`` the whole walk (extraction included) runs as
    the `repro.kernels.csr_extend` kernel — scalar-prefetched segment
    bounds, ``pl.ds`` neighbor loads — mirroring how ``use_pallas`` routes
    the dense jnp backend through ``candidate_mask``.
    """

    name = "csr"

    def __init__(self, cfg: "EngineConfig", plan: CsrPlanArrays):
        self.plan = plan
        self.p_pad, self.w = plan.dom_bits.shape
        self.n_planes = plan.indptr.shape[0]
        self.n_t = plan.indptr.shape[1] - 1
        self.deg_cap = plan.seg_iota.shape[0]
        self.use_kernel = cfg.use_pallas
        bucketed = cfg.csr_walk == "bucketed"
        if self.use_kernel:
            from repro.kernels import ops as kops

            if bucketed:
                self._step = functools.partial(
                    kops.csr_extend_bucketed, deg_cap=self.deg_cap
                )
            else:
                self._step = functools.partial(kops.csr_extend, deg_cap=self.deg_cap)
        else:
            from repro.kernels import ref as kref

            step_ref = (
                kref.csr_extend_bucketed_ref if bucketed else kref.csr_extend_ref
            )
            self._step = jax.jit(functools.partial(step_ref, deg_cap=self.deg_cap))

    def _segments(self, pos: jnp.ndarray, map2: jnp.ndarray):
        """Per-lane CSR segment bounds for the child position's parents:
        ``(start, length)`` int32 ``[B, mp]``, length ``-1`` on unused
        parent slots."""
        plan = self.plan
        safe_pos = jnp.clip(pos, 0, self.p_pad - 1)
        pp = plan.parent_pos[safe_pos]  # [B, mp]
        pd = plan.parent_dir[safe_pos]
        pe = plan.parent_elab[safe_pos]
        t = jnp.take_along_axis(map2, jnp.maximum(pp, 0), axis=1)
        t = jnp.clip(jnp.where(pp >= 0, t, 0), 0, self.n_t - 1)
        plane = jnp.clip(pe * 2 + pd, 0, self.n_planes - 1)
        start = plan.indptr[plane, t]
        length = plan.indptr[plane, t + 1] - start
        return start, jnp.where(pp >= 0, length, -1)

    def expand_lanes(self, depth, map_, used, cand) -> StepLanes:
        plan = self.plan
        b = depth.shape[0]
        # scalar bookkeeping before the walk, as in PallasStepBackend: the
        # extracted v feeds map2, whose mapped targets select the CSR
        # segments (a child's parent constraint may reference the
        # just-extended position).
        valid_j, v_j, _ = jax.vmap(pop_lowest_bit)(cand)
        map2 = jnp.where(
            valid_j[:, None],
            map_.at[jnp.arange(b), jnp.clip(depth, 0, self.p_pad - 1)].set(v_j),
            map_,
        )
        used2 = jnp.where(
            valid_j[:, None], used | jax.vmap(bit_row, (0, None))(v_j, self.w), used
        )
        child_pos = jnp.clip(depth + 1, 0, self.p_pad - 1)
        start, length = self._segments(child_pos, map2)
        cand2, child_cand, meta = self._step(
            plan.indices, plan.dom_bits, start, length, child_pos,
            depth, plan.n_p, used, cand,
        )
        return StepLanes(
            valid=meta[:, 0] != 0,
            v=meta[:, 1],
            is_match=meta[:, 2] != 0,
            has_child=meta[:, 3] != 0,
            cand2=cand2,
            map2=map2,
            used2=used2,
            child_cand=child_cand,
        )


class PartStepLanes(NamedTuple):
    """:class:`StepLanes` plus the spill routing a partitioned expansion
    produces (DESIGN.md §9).  ``lanes.has_child`` is narrowed to *live*
    children (fully constrained: every real parent resident and applied);
    ``spill`` flags children with surviving partial candidates that still
    owe intersections to non-resident parents."""

    lanes: StepLanes
    spill: jnp.ndarray  # [B] bool — child parked for a non-resident partition
    pending: jnp.ndarray  # [B] int32 bitmask of unapplied parent slots
    spill_part: jnp.ndarray  # [B] int32 partition of first pending parent (-1)


class PartitionedCsrStepBackend(CsrStepBackend):
    """Partition-aware CSR walk (DESIGN.md §9): candidates are intersected
    with the rows of parents **resident** in the swapped-in partition; the
    remaining parents are recorded in a per-child ``pending`` bitmask and
    the child is flagged for the spill frontier instead of the live stack.

    The walk itself is :class:`CsrStepBackend`'s, with non-resident parent
    slots neutralized exactly like unused slots (segment length ``-1``):
    the driver is the first *resident* parent and membership is tested only
    against resident segments, so the partial candidate set is
    ``dom ∧ ¬used ∧ ⋂ resident parents`` — an over-approximation that the
    outer scheduling loop finishes constraining at intake, when the pending
    parents' partitions become resident.  Because only fully-constrained
    entries ever reach a live stack, every extraction — and therefore every
    match — is exactly a monolithic extraction: the match set is
    bit-identical to the unpartitioned run (the conformance suite gates
    counts *and* sorted mappings per partition count).
    """

    name = "partitioned"

    def __init__(self, cfg: "EngineConfig", plan: PartPlanArrays):
        super().__init__(cfg, plan)
        self.n_parts = plan.part_starts.shape[0] - 1

    def _segments(self, pos: jnp.ndarray, map2: jnp.ndarray):
        """Resident-masked segment bounds plus spill routing: ``(start,
        length, pending, spill_part)`` — length ``-1`` on unused *and*
        non-resident parent slots."""
        plan = self.plan
        mp = plan.parent_pos.shape[1]
        safe_pos = jnp.clip(pos, 0, self.p_pad - 1)
        pp = plan.parent_pos[safe_pos]  # [B, mp]
        pd = plan.parent_dir[safe_pos]
        pe = plan.parent_elab[safe_pos]
        real = pp >= 0
        t = jnp.take_along_axis(map2, jnp.maximum(pp, 0), axis=1)
        t = jnp.where(real, t, 0)
        resident = real & (t >= plan.part_lo) & (t < plan.part_hi)
        t_loc = jnp.clip(t - plan.part_lo, 0, self.n_t - 1)
        plane = jnp.clip(pe * 2 + pd, 0, self.n_planes - 1)
        start = plan.indptr[plane, t_loc]
        length = jnp.where(resident, plan.indptr[plane, t_loc + 1] - start, -1)

        pend_mask = real & ~resident
        pending = jnp.sum(
            pend_mask.astype(jnp.int32) << jnp.arange(mp, dtype=jnp.int32)[None, :],
            axis=1, dtype=jnp.int32,
        )
        first_j = jnp.argmax(pend_mask, axis=1)
        t_first = jnp.take_along_axis(t, first_j[:, None], axis=1)[:, 0]
        spill_part = jnp.searchsorted(plan.part_starts, t_first, side="right") - 1
        spill_part = jnp.where(pending != 0, spill_part.astype(jnp.int32), -1)
        return start, length, pending, spill_part

    def expand_lanes_part(self, depth, map_, used, cand) -> PartStepLanes:
        plan = self.plan
        b = depth.shape[0]
        valid_j, v_j, _ = jax.vmap(pop_lowest_bit)(cand)
        map2 = jnp.where(
            valid_j[:, None],
            map_.at[jnp.arange(b), jnp.clip(depth, 0, self.p_pad - 1)].set(v_j),
            map_,
        )
        used2 = jnp.where(
            valid_j[:, None], used | jax.vmap(bit_row, (0, None))(v_j, self.w), used
        )
        child_pos = jnp.clip(depth + 1, 0, self.p_pad - 1)
        start, length, pending, spill_part = self._segments(child_pos, map2)
        cand2, child_cand, meta = self._step(
            plan.indices, plan.dom_bits, start, length, child_pos,
            depth, plan.n_p, used, cand,
        )
        survived = meta[:, 3] != 0  # want_child ∧ partial candidates non-empty
        live = survived & (pending == 0)
        spill = survived & (pending != 0)
        lanes = StepLanes(
            valid=meta[:, 0] != 0,
            v=meta[:, 1],
            is_match=meta[:, 2] != 0,
            has_child=live,
            cand2=cand2,
            map2=map2,
            used2=used2,
            child_cand=child_cand,
        )
        return PartStepLanes(lanes=lanes, spill=spill, pending=pending,
                             spill_part=spill_part)

    def expand_lanes(self, depth, map_, used, cand) -> StepLanes:
        return self.expand_lanes_part(depth, map_, used, cand).lanes


def make_step_backend(cfg: "EngineConfig", plan: AnyPlanArrays) -> StepBackend:
    """Backend for ``cfg`` over ``plan`` — the array layout must match the
    resolved backend (``plan_arrays_for`` guarantees it; ``"auto"``
    resolves by layout here since the abstract path has no ``n_t``)."""
    if isinstance(plan, PartPlanArrays):
        if cfg.step_backend != "partitioned":
            raise ValueError(
                f"step_backend={cfg.step_backend!r} cannot run PartPlanArrays"
            )
        return PartitionedCsrStepBackend(cfg, plan)
    if cfg.step_backend == "partitioned":
        raise ValueError(
            "step_backend='partitioned' needs PartPlanArrays "
            "(build them with make_part_plan_arrays; run via "
            "repro.core.engine.run_partitioned)"
        )
    if isinstance(plan, CsrPlanArrays):
        if cfg.step_backend not in ("csr", "auto"):
            raise ValueError(
                f"step_backend={cfg.step_backend!r} cannot run CsrPlanArrays"
            )
        return CsrStepBackend(cfg, plan)
    if cfg.step_backend == "csr":
        raise ValueError(
            "step_backend='csr' needs CsrPlanArrays "
            "(build them with make_csr_plan_arrays / plan_arrays_for)"
        )
    if cfg.step_backend in ("jnp", "auto"):
        return JnpStepBackend(cfg, plan)
    if cfg.step_backend == "pallas":
        return PallasStepBackend(cfg, plan)
    raise ValueError(
        f"unknown step_backend {cfg.step_backend!r}; expected one of {STEP_BACKENDS}"
    )


# ---------------------------------------------------------------------------
# the shared expansion step (frontier pop -> backend -> counters -> push)
# ---------------------------------------------------------------------------

def make_step_fn(cfg: "EngineConfig", plan: PlanArrays):
    """Build one full expansion step ``EngineState -> EngineState`` over
    whatever worker axis the caller holds (all ``V`` workers single-device,
    or the local ``V / D`` shard under ``shard_map``) — the one step both
    engine paths share (DESIGN.md §6)."""
    backend = make_step_backend(cfg, plan)
    e = cfg.expand_width

    def step(st: EngineState) -> EngineState:
        v_loc, s_cap = st.st_depth.shape
        pop = frontier.pop_top_k(
            st.st_depth, st.st_map, st.st_used, st.st_cand,
            st.base, st.size, e, store_used=cfg.store_used,
        )

        b = v_loc * e
        lanes = backend.expand_lanes(
            pop.depth.reshape(b),
            pop.map.reshape(b, -1),
            pop.used.reshape(b, -1),
            pop.cand.reshape(b, -1),
        )
        sh2 = lambda x: x.reshape(v_loc, e)  # noqa: E731
        sh3 = lambda x: x.reshape((v_loc, e) + x.shape[1:])  # noqa: E731
        valid = sh2(lanes.valid) & pop.lane_on
        is_match = sh2(lanes.is_match) & pop.lane_on
        has_child = sh2(lanes.has_child) & pop.lane_on
        cand2 = sh3(lanes.cand2)
        map2 = sh3(lanes.map2)
        used2 = sh3(lanes.used2)
        child_cand = sh3(lanes.child_cand)

        states = st.states + jnp.sum(valid, axis=1, dtype=jnp.int32)
        exp_depth = st.exp_depth + jnp.sum(
            jnp.where(valid, pop.depth, 0), axis=1, dtype=jnp.int32
        )
        matches = st.matches + jnp.sum(is_match, axis=1, dtype=jnp.int32)

        mbuf = st.match_buf
        if cfg.collect_matches > 0:
            mcap = mbuf.shape[1]
            # per-lane match ordinal within this step, on top of the
            # pre-step per-worker match count
            m_prefix = jnp.cumsum(is_match.astype(jnp.int32), axis=1) - is_match
            m_slot = (st.matches[:, None] + m_prefix) % mcap
            m_slot = jnp.where(is_match, m_slot, mcap)  # drop non-matches
            vidx = jnp.arange(v_loc, dtype=jnp.int32)[:, None]
            mbuf = mbuf.at[vidx, m_slot].set(map2, mode="drop")

        parent_keep = pop.lane_on & jnp.any(cand2 != 0, axis=-1)
        st_depth, st_map, st_used, st_cand, new_size = frontier.push_entries(
            st.st_depth, st.st_map, st.st_used, st.st_cand, st.base, st.size,
            pop.k, parent_keep, has_child,
            pop.depth, pop.map, pop.used, cand2,
            pop.depth + 1, map2, used2, child_cand,
            store_used=cfg.store_used,
        )
        overflow = st.overflow | frontier.overflowed(new_size, s_cap)
        return st._replace(
            st_depth=st_depth, st_map=st_map, st_used=st_used, st_cand=st_cand,
            size=new_size, matches=matches, states=states,
            exp_depth=exp_depth, match_buf=mbuf, overflow=overflow,
        )

    return step


def make_partitioned_step_fn(cfg: "EngineConfig", plan: PartPlanArrays):
    """The partitioned expansion step ``(EngineState, SpillState) →
    (EngineState, SpillState)``: :func:`make_step_fn`'s pop → expand →
    counters → push pipeline, with children that owe intersections to
    non-resident partitions routed to the worker's spill ring instead of
    the live stack (DESIGN.md §9)."""
    backend = PartitionedCsrStepBackend(cfg, plan)
    e = cfg.expand_width

    def step(st: EngineState, spill: SpillState):
        v_loc, s_cap = st.st_depth.shape
        pop = frontier.pop_top_k(
            st.st_depth, st.st_map, st.st_used, st.st_cand,
            st.base, st.size, e, store_used=cfg.store_used,
        )

        b = v_loc * e
        part = backend.expand_lanes_part(
            pop.depth.reshape(b),
            pop.map.reshape(b, -1),
            pop.used.reshape(b, -1),
            pop.cand.reshape(b, -1),
        )
        lanes = part.lanes
        sh2 = lambda x: x.reshape(v_loc, e)  # noqa: E731
        sh3 = lambda x: x.reshape((v_loc, e) + x.shape[1:])  # noqa: E731
        valid = sh2(lanes.valid) & pop.lane_on
        is_match = sh2(lanes.is_match) & pop.lane_on
        has_child = sh2(lanes.has_child) & pop.lane_on
        do_spill = sh2(part.spill) & pop.lane_on
        cand2 = sh3(lanes.cand2)
        map2 = sh3(lanes.map2)
        used2 = sh3(lanes.used2)
        child_cand = sh3(lanes.child_cand)

        states = st.states + jnp.sum(valid, axis=1, dtype=jnp.int32)
        exp_depth = st.exp_depth + jnp.sum(
            jnp.where(valid, pop.depth, 0), axis=1, dtype=jnp.int32
        )
        matches = st.matches + jnp.sum(is_match, axis=1, dtype=jnp.int32)

        mbuf = st.match_buf
        if cfg.collect_matches > 0:
            mcap = mbuf.shape[1]
            m_prefix = jnp.cumsum(is_match.astype(jnp.int32), axis=1) - is_match
            m_slot = (st.matches[:, None] + m_prefix) % mcap
            m_slot = jnp.where(is_match, m_slot, mcap)
            vidx = jnp.arange(v_loc, dtype=jnp.int32)[:, None]
            mbuf = mbuf.at[vidx, m_slot].set(map2, mode="drop")

        spill = frontier.push_spill(
            spill, do_spill,
            pop.depth + 1, map2, child_cand,
            sh2(part.pending), sh2(part.spill_part),
        )

        parent_keep = pop.lane_on & jnp.any(cand2 != 0, axis=-1)
        st_depth, st_map, st_used, st_cand, new_size = frontier.push_entries(
            st.st_depth, st.st_map, st.st_used, st.st_cand, st.base, st.size,
            pop.k, parent_keep, has_child,
            pop.depth, pop.map, pop.used, cand2,
            pop.depth + 1, map2, used2, child_cand,
            store_used=cfg.store_used,
        )
        overflow = st.overflow | frontier.overflowed(new_size, s_cap)
        st = st._replace(
            st_depth=st_depth, st_map=st_map, st_used=st_used, st_cand=st_cand,
            size=new_size, matches=matches, states=states,
            exp_depth=exp_depth, match_buf=mbuf, overflow=overflow,
        )
        return st, spill

    return step
