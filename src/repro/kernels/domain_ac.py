"""Pallas TPU kernel for RI-DS arc-consistency filtering.

One AC sweep for a single constraint arc ``(p, q, dir, label)`` tests, for
every target node ``t``, whether ``adj_rows[t] ∧ D(q)`` has any set bit —
a ``[n_t, w]`` bitmap AND against a broadcast ``[w]`` mask followed by a
per-row any-reduce.  This is the SDDMM-shaped part of domain preprocessing
(DESIGN.md §2): dense rows stream from HBM once, the mask stays resident in
VMEM.

TPU mapping: grid over row tiles of ``tr`` rows; block ``(tr, w)`` of
adjacency rows, mask block ``(1, w)`` pinned (same index every step), output
``(tr, 1)`` int32 flags.  ``w`` padded to 128-word lanes, ``tr`` a multiple
of 8 sublanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.candidate_mask import pad_words

ROW_TILE = 256


def _kernel(rows_ref, mask_ref, out_ref):
    hit = (rows_ref[...] & mask_ref[...]) != 0  # [tr, w] bool
    out_ref[...] = jnp.any(hit, axis=-1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile"))
def adjacency_any(
    rows: jnp.ndarray,  # [n_t, w] uint32
    mask: jnp.ndarray,  # [w] uint32
    interpret: bool = True,
    row_tile: int = ROW_TILE,
) -> jnp.ndarray:
    """Per-row any-bit test of ``rows ∧ mask`` -> ``[n_t]`` int32 {0,1}."""
    n_t, w = rows.shape
    wp = pad_words(w)
    tr = row_tile
    n_pad = ((n_t + tr - 1) // tr) * tr
    rows_p = jnp.pad(rows, ((0, n_pad - n_t), (0, wp - w)))
    mask_p = jnp.pad(mask, (0, wp - w))[None, :]

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // tr,),
        in_specs=[
            pl.BlockSpec((tr, wp), lambda i: (i, 0)),
            pl.BlockSpec((1, wp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
        interpret=interpret,
    )(rows_p, mask_p)
    return out[:n_t, 0]
