"""Domain-preprocessing benchmark: host loop vs jitted device fixpoint vs
Pallas-interpret, plus a prune-quality table (AC → FC vs AC ⇄ FC).

  PYTHONPATH=src python -m benchmarks.bench_domains [--patterns N] [--smoke]
  PYTHONPATH=src python -m benchmarks.bench_domains --sparse [--smoke] \
      [--json BENCH_10.json]

``--sparse`` switches to the CSR-native domain engine at pdbsv1 scale
(DESIGN.md §11): ``ri-ds-si-acfc`` domains for a 33k-node power-law target
computed entirely from ``CsrPlanes`` segments — dense ``[n_elab, 2, n_t, w]``
adjacency bitmaps never exist on the sparse side.  Asserts bit-identity
against the dense oracle, a ≫ memory gap between the CSR domain arrays and
the dense analytic bitmap bytes, and that the acfc domains cut enumeration
states vs variant ``ri`` on CSR-only plans.

Three ways to compute RI-DS domains for a ≥ 32-pattern same-bucket batch
(DESIGN.md §5):

  * ``host``   — the numpy oracle, one Python arc-loop per query (the old
    `core/domains.py` path and still the correctness reference);
  * ``jitted`` — the device fixpoint, **one vmapped jitted call** for the
    whole padded batch (the `Enumerator.prepare_batch` backend);
  * ``pallas`` — the same engine with the row-AND-any reduction routed
    through the Pallas kernels in **interpret mode** (semantics validation;
    slower than jnp on CPU — see API.md's use_pallas caveat), measured on a
    small slice.

Asserts (the CI smoke gate):

  * device bits == numpy-oracle bits for every pattern and both variants;
  * the batched jitted call beats the per-query host loop in wall-clock;
  * AC ⇄ FC (ri-ds-si-acfc) domains are never larger than AC → FC.

Emits CSV rows (name, us_per_query, derived) and a JSON artifact.
"""

from __future__ import annotations

import argparse
import time

try:
    from benchmarks import common
except ImportError:  # executed from an arbitrary cwd
    import repro.bench  # noqa: F401  (puts the repo root on sys.path)
    from benchmarks import common

import numpy as np

from repro.core import SubgraphIndex
from repro.core import domains as dom_mod
from repro.core.graph import popcount
from repro.data import graphgen


def _corpus(n_patterns: int, smoke: bool, seed: int):
    n, m = (90, 360) if smoke else (200, 900)
    tgt = graphgen.random_graph(n, m, n_labels=4, seed=seed)
    pats = [graphgen.extract_pattern(tgt, 5 + (i % 4), seed=seed + 1 + i)
            for i in range(n_patterns)]
    return tgt, pats


def run(n_patterns: int = 32, smoke: bool = False, seed: int = 7) -> dict:
    assert n_patterns >= 32, "the acceptance criterion is a >=32-pattern batch"
    tgt, pats = _corpus(n_patterns, smoke, seed)
    index = SubgraphIndex.build(tgt)
    packed = index.packed

    # one shared shape bucket (pads = corpus maxima) => one compilation
    dims = [dom_mod.domain_bucket(p) for p in pats]
    p_pad = max(d[0] for d in dims)
    a_pad = max(d[1] for d in dims)
    l_pad = max(d[2] for d in dims)

    flags = dict(use_ac=True, use_fc=True, interleave=False)

    def batch(use_pallas=False, patterns=pats, interleave=False):
        return dom_mod.compute_domains_batch(
            patterns, packed, use_ac=True, use_fc=True, interleave=interleave,
            use_pallas=use_pallas, p_pad=p_pad, arc_pad=a_pad, loop_pad=l_pad,
            batch_pad=len(patterns),
        )

    def best_of(fn, reps=3):
        """Best wall-clock of ``reps`` runs (de-noises the CI smoke gate)."""
        best, out = float("inf"), None
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best, out

    # --- host loop (the old per-query path; correctness reference) --------
    t_host, host = best_of(
        lambda: [dom_mod.compute_domains(p, packed, **flags) for p in pats]
    )

    # --- jitted batched device fixpoint ----------------------------------
    batch()  # warm-up: one compilation per bucket is the amortized regime
    t_jit, dev = best_of(batch)

    for h, d in zip(host, dev):
        assert h.satisfiable == d.satisfiable
        np.testing.assert_array_equal(h.bits, d.bits)
    assert t_jit < t_host, (
        f"batched device preprocessing ({t_jit:.3f}s) must beat the "
        f"per-query host loop ({t_host:.3f}s) on a {n_patterns}-pattern batch"
    )

    # --- Pallas interpret mode (semantics check; small slice) -------------
    n_pal = 2 if smoke else 4
    pal_pats = pats[:n_pal]
    batch(use_pallas=True, patterns=pal_pats)  # warm-up
    t_pal, pal = best_of(lambda: batch(use_pallas=True, patterns=pal_pats),
                         reps=1 if smoke else 2)
    for h, d in zip(host[:n_pal], pal):
        np.testing.assert_array_equal(h.bits, d.bits)

    # --- prune quality: AC -> FC vs AC <-> FC -----------------------------
    batch(interleave=True)  # warm-up (separate static-flag compilation)
    t_joint, joint = best_of(lambda: batch(interleave=True))
    bits_seq = sum(int(popcount(r.bits).sum()) for r in dev)
    bits_joint = sum(int(popcount(r.bits).sum()) for r in joint)
    tightened = sum(
        1 for a, b in zip(dev, joint)
        if int(popcount(b.bits).sum()) < int(popcount(a.bits).sum())
        or (a.satisfiable and not b.satisfiable)
    )
    assert bits_joint <= bits_seq, "AC ⇄ FC may never enlarge domains"

    n = len(pats)
    print("variant,total_domain_bits,unsat_queries,queries_tightened")
    print(f"ri-ds-si-fc,{bits_seq},{sum(not r.satisfiable for r in dev)},-")
    print(f"ri-ds-si-acfc,{bits_joint},{sum(not r.satisfiable for r in joint)},{tightened}")
    print()
    print(common.csv_row("domains_host_loop", t_host / n * 1e6, "numpy oracle"))
    print(common.csv_row("domains_jitted_batch", t_jit / n * 1e6,
                         f"speedup={t_host / t_jit:.1f}x bucket=({p_pad},{a_pad},{l_pad})"))
    print(common.csv_row("domains_jitted_acfc", t_joint / n * 1e6, "joint fixpoint"))
    print(common.csv_row("domains_pallas_interpret", t_pal / n_pal * 1e6,
                         f"n={n_pal} (interpret mode: validation, not speed)"))
    payload = dict(
        n_patterns=n,
        bucket=dict(p_pad=p_pad, arc_pad=a_pad, loop_pad=l_pad),
        host_s=t_host,
        jitted_batch_s=t_jit,
        jitted_acfc_s=t_joint,
        pallas_interpret_s=t_pal,
        pallas_patterns=n_pal,
        speedup=t_host / t_jit,
        domain_bits_fc=bits_seq,
        domain_bits_acfc=bits_joint,
        queries_tightened=tightened,
    )
    common.save_json("domains", payload)
    return payload


SPARSE_NT = 33_067  # sge_pdbsv1 (Table 1) — the paper's largest target
# CSR domain arrays must be >= this much smaller than the dense analytic
# bitmap bytes; the gap grows with n_t (dense is O(n_t²/32), CSR is
# O(nnz + n_planes·n_t)), so the smoke target gates a smaller factor
SPARSE_MEM_FACTOR = 50
SPARSE_MEM_FACTOR_SMOKE = 4
SPARSE_VARIANTS = ("ri", "ri-ds-si-fc", "ri-ds-si-acfc")


def run_sparse(smoke: bool = False, seed: int = 7) -> dict:
    """The CSR-native domain engine at pdbsv1 scale (DESIGN.md §11).

    Asserts (the ``--sparse --smoke`` CI gate, same at full scale):

    * sparse domains == the dense oracle's bits for every pattern and
      every variant in ``SPARSE_VARIANTS``;
    * the domain-side working set (``CsrTargetDomainArrays``) is at least
      ``SPARSE_MEM_FACTOR``× smaller than the dense analytic adjacency
      bytes, and the CSR-only plans carry no dense bitmap planes;
    * ``ri-ds-si-acfc`` never explores more enumeration states than ``ri``
      on the same CSR-only plans, and strictly fewer in aggregate.
    """
    from repro.core import EngineConfig
    from repro.core import engine as eng
    from repro.core.graph import PackedGraph, n_words
    from repro.core.plan import build_csr_plan, variant_flags

    n_t = 2_000 if smoke else SPARSE_NT
    n_pats = 3 if smoke else 6
    tgt = graphgen.power_law_graph(n_t, avg_deg=4.0, alpha=0.5, n_labels=32,
                                   seed=seed)
    w = n_words(tgt.n)
    deg = tgt.out_degrees() + tgt.in_degrees()
    busy = np.argsort(deg)
    pats = []
    i = 0
    while len(pats) < n_pats and i < 64:
        p = graphgen.extract_pattern(
            tgt, 5 + len(pats) % 3, seed=seed + i,
            start=int(busy[-(40 + 17 * i)]),
        )
        i += 1
        if p.m:
            pats.append(p)
    assert len(pats) == n_pats, "sparse pattern extraction degenerated"

    # one CsrPlanes / CsrTargetDomainArrays pair shared by every query —
    # the entire target-side working set of the sparse domain engine
    planes = tgt.csr_planes(tgt.n_edge_labels)
    arrs = dom_mod.csr_target_domain_arrays(tgt, w, planes=planes)

    # --- memory: measured sparse bytes vs the dense analytic bitmap ------
    sparse_bytes = sum(int(np.asarray(a).nbytes) for a in arrs)
    dense_bytes = tgt.n_edge_labels * 2 * n_t * w * 4  # [n_elab, 2, n_t, w]
    mem_ratio = dense_bytes / max(sparse_bytes, 1)
    factor = SPARSE_MEM_FACTOR_SMOKE if smoke else SPARSE_MEM_FACTOR
    assert mem_ratio >= factor, (
        f"CSR domain arrays ({sparse_bytes} B) must be >= {factor}x "
        f"smaller than the dense adjacency working set ({dense_bytes} B); "
        f"measured {mem_ratio:.0f}x"
    )

    # --- bit-identity vs the dense oracle, every variant ------------------
    t0 = time.perf_counter()
    packed = PackedGraph.from_graph(tgt)  # the oracle's dense side only
    t_pack = time.perf_counter() - t0
    table = {}  # variant -> (total bits, unsat queries, sparse seconds)
    for variant in SPARSE_VARIANTS:
        f = variant_flags(variant)
        kw = dict(use_ac=f["use_ac"], use_fc=f["use_fc"],
                  interleave=f["interleave"])
        t0 = time.perf_counter()
        sparse = [dom_mod.compute_domains_sparse(p, tgt, w, tgt_arrays=arrs,
                                                 **kw) for p in pats]
        t_sparse = time.perf_counter() - t0
        for p, s in zip(pats, sparse):
            d = dom_mod.compute_domains(p, packed, **kw)
            assert d.satisfiable == s.satisfiable, variant
            np.testing.assert_array_equal(d.bits, s.bits)
        table[variant] = (
            sum(int(popcount(r.bits).sum()) for r in sparse),
            sum(not r.satisfiable for r in sparse),
            t_sparse,
        )
    assert table["ri-ds-si-acfc"][0] <= table["ri"][0]

    # --- states reduction: CSR-only plans, ri vs ri-ds-si-acfc ------------
    cfg = EngineConfig(n_workers=8, expand_width=4, step_backend="csr")
    states = {}
    for variant in ("ri", "ri-ds-si-acfc"):
        total = 0
        for p in pats:
            plan = build_csr_plan(p, tgt, variant=variant, planes=planes)
            assert plan.adj_bits.shape[2] == 0  # nothing dense, ever
            if plan.satisfiable:
                total += int(eng.run(plan, cfg).states)
        states[variant] = total
    assert states["ri-ds-si-acfc"] <= states["ri"], states
    assert states["ri-ds-si-acfc"] < states["ri"], (
        "acfc domains must cut enumeration states vs ri at pdbsv1 scale"
    )

    print("variant,total_domain_bits,unsat_queries,sparse_domains_s")
    for variant, (bits, unsat, secs) in table.items():
        print(f"{variant},{bits},{unsat},{secs:.3f}")
    print()
    print(common.csv_row(
        "sparse_domain_bytes", sparse_bytes,
        f"dense analytic {dense_bytes} B -> {mem_ratio:.0f}x smaller"))
    print(common.csv_row(
        "sparse_states_ri", states["ri"], "csr backend, CSR-only plans"))
    print(common.csv_row(
        "sparse_states_acfc", states["ri-ds-si-acfc"],
        f"reduction {states['ri'] / max(states['ri-ds-si-acfc'], 1):.2f}x"))
    payload = dict(
        n_t=n_t,
        target_edges=int(tgt.m),
        n_patterns=len(pats),
        nnz=int(planes.nnz),
        deg_cap=int(planes.deg_cap),
        sparse_domain_bytes=sparse_bytes,
        dense_analytic_bytes=dense_bytes,
        mem_ratio=mem_ratio,
        pack_oracle_s=t_pack,
        prune_table={
            v: dict(domain_bits=b, unsat=u, sparse_s=s)
            for v, (b, u, s) in table.items()
        },
        states_ri=states["ri"],
        states_acfc=states["ri-ds-si-acfc"],
        states_reduction=states["ri"] / max(states["ri-ds-si-acfc"], 1),
    )
    common.save_json("domains_sparse", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--patterns", type=int, default=32)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="small target for CI (same assertions)")
    ap.add_argument("--sparse", action="store_true",
                    help="CSR-native domain engine at pdbsv1 scale "
                    "(DESIGN.md §11) instead of the dense batch benchmark")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the payload to PATH (e.g. the "
                    "committed BENCH_10.json)")
    args = ap.parse_args()
    if args.sparse:
        out = run_sparse(smoke=args.smoke, seed=args.seed)
        common.write_json_path(args.json, out)
        print(f"\nn_t={out['n_t']} ({out['target_edges']} edges, "
              f"nnz={out['nnz']}): CSR domain arrays "
              f"{out['sparse_domain_bytes']} B vs dense analytic "
              f"{out['dense_analytic_bytes']} B ({out['mem_ratio']:.0f}x); "
              f"states {out['states_ri']} (ri) -> {out['states_acfc']} "
              f"(acfc, {out['states_reduction']:.2f}x fewer)")
        return
    out = run(n_patterns=args.patterns, smoke=args.smoke, seed=args.seed)
    common.write_json_path(args.json, out)
    print(f"\n{out['n_patterns']} patterns, one bucket {out['bucket']}: "
          f"host loop {out['host_s']:.3f}s -> batched device "
          f"{out['jitted_batch_s']:.3f}s ({out['speedup']:.1f}x); "
          f"AC⇄FC tightened {out['queries_tightened']} queries "
          f"({out['domain_bits_fc']} -> {out['domain_bits_acfc']} domain bits)")


if __name__ == "__main__":
    main()
