"""Soundness of the preprocessing prunings (domains, AC, FC, orderings).

The invariant behind every pruning in the paper: no pruning may remove a
target node from a domain if that node participates in a true match at that
pattern position.  Verified against brute-force enumeration of all matches.
"""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import domains as dom_mod
from repro.core import ordering as ord_mod
from repro.core.graph import Graph, PackedGraph, bitmap_to_indices, popcount
from repro.core.ref import ref_enumerate
from tests.conftest import extract_connected_pattern, random_graph


def all_matches(pattern, target):
    """All match mappings (pattern node -> target node), via the oracle."""
    res = ref_enumerate(pattern, target, variant="ri", record_mappings=True)
    from repro.core.plan import build_plan

    plan = build_plan(pattern, PackedGraph.from_graph(target), variant="ri")
    # mappings are in order-position space; convert to pattern-node space
    out = []
    for m in res.mappings:
        node_map = {}
        for pos, t in enumerate(m):
            node_map[int(plan.order[pos])] = t
        out.append(node_map)
    return out


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_domain_pipeline_soundness(seed):
    rng = np.random.default_rng(seed)
    tgt = random_graph(rng, 12, 26, n_labels=2)
    pat = extract_connected_pattern(rng, tgt, 3)
    if pat.m == 0:
        return
    packed = PackedGraph.from_graph(tgt)
    matches = all_matches(pat, tgt)
    for use_ac, use_fc in [(False, False), (True, False), (True, True)]:
        res = dom_mod.compute_domains(pat, packed, use_ac=use_ac, use_fc=use_fc)
        if matches:
            assert res.satisfiable
            for m in matches:
                for p, t in m.items():
                    dom = set(bitmap_to_indices(res.bits[p]).tolist())
                    assert t in dom, (
                        f"pruning removed true-match node {t} from D({p}) "
                        f"(ac={use_ac}, fc={use_fc})"
                    )


def test_ac_reduces_domains():
    # path pattern in a star target: leaves can't host the middle node
    tgt = Graph.from_edges(5, [(0, 1), (0, 2), (0, 3), (0, 4)], undirected=True)
    pat = Graph.from_edges(3, [(0, 1), (1, 2)], undirected=True)
    packed = PackedGraph.from_graph(tgt)
    d0 = dom_mod.initial_domains(pat, packed)
    dac = dom_mod.arc_consistency(pat, packed, d0)
    assert dac.satisfiable
    assert popcount(dac.bits).sum() <= popcount(d0).sum()
    # middle pattern node (degree 2) can only map to the hub
    mid = int(np.argmax(pat.degrees()))
    assert bitmap_to_indices(dac.bits[mid]).tolist() == [0]


def test_fc_removes_singleton_targets():
    bits = np.zeros((3, 1), dtype=np.uint32)
    bits[0, 0] = 0b001  # singleton {0}
    bits[1, 0] = 0b011  # {0,1}
    bits[2, 0] = 0b111  # {0,1,2}
    res = dom_mod.forward_check_singletons(bits)
    assert res.satisfiable
    assert res.bits[0, 0] == 0b001
    assert res.bits[1, 0] == 0b010  # 0 removed -> singleton {1}
    assert res.bits[2, 0] == 0b100  # 0 and 1 removed


def test_fc_detects_collision():
    bits = np.zeros((2, 1), dtype=np.uint32)
    bits[0, 0] = 0b01
    bits[1, 0] = 0b01  # same singleton target
    res = dom_mod.forward_check_singletons(bits)
    assert not res.satisfiable


def test_ordering_properties(rng):
    tgt = random_graph(rng, 20, 50, n_labels=2)
    pat = extract_connected_pattern(rng, tgt, 5)
    if pat.m == 0:
        pytest.skip("empty pattern")
    ordering = ord_mod.greatest_constraint_first(pat)
    # permutation of all pattern nodes
    assert sorted(ordering.order.tolist()) == list(range(pat.n))
    # every non-root position of a connected pattern has >= 1 parent
    for i in range(1, ordering.n):
        assert len(ordering.parents[i]) >= 1
    # parents reference earlier positions only
    for i, plist in enumerate(ordering.parents):
        for (j, d, l) in plist:
            assert 0 <= j < i
    # parent constraints cover every pattern edge exactly once
    n_constraints = sum(len(p) for p in ordering.parents)
    n_nonloop = sum(1 for u, v in zip(pat.src, pat.dst) if u != v)
    assert n_constraints == n_nonloop


def test_si_tiebreak_prefers_small_domain():
    # two symmetric candidates; domain sizes break the tie
    pat = Graph.from_edges(3, [(0, 1), (0, 2)], undirected=True)
    sizes = np.array([5, 7, 2])
    ordering = ord_mod.greatest_constraint_first(pat, domain_sizes=sizes)
    # node 0 has max degree; between 1 and 2 (tied w_m, w_n, deg), node 2
    # (smaller domain) must come first
    assert ordering.order.tolist() == [0, 2, 1]
    ordering_plain = ord_mod.greatest_constraint_first(pat)
    assert ordering_plain.order.tolist() == [0, 1, 2]  # id tie-break


def test_singleton_first_placement():
    pat = Graph.from_edges(3, [(0, 1), (1, 2)], undirected=True)
    sizes = np.array([4, 4, 1])
    ordering = ord_mod.greatest_constraint_first(
        pat, domain_sizes=sizes, singleton_first=True
    )
    assert ordering.order[0] == 2
