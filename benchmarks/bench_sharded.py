"""C8 — mesh-sharded scaling sweep (paper Fig. 3 analogue, DESIGN.md §2.4).

Runs the ppis32-like synthetic collection through the engine's ``shard_map``
path on a 1 / 2 / 4-device ``data`` mesh (CPU virtual devices: the module
forces ``--xla_force_host_platform_device_count=4`` before jax initializes,
so one process sweeps all three mesh sizes over subsets of the same device
pool) and asserts the **matches invariant**: every mesh size must report
exactly the same match and state counts as the single-device engine —
sharding redistributes work, never results.

Reported per sweep point (BSP methodology, benchmarks/common.py):

  * total matches / states (must be constant across device counts);
  * engine steps (the BSP makespan — constant here too, since the sharded
    steal round is entry-for-entry identical to the single-device one;
    device count changes *where* stacks live, not the global schedule);
  * steal traffic per device: entries stolen **into** each device's
    workers — under the all-gather protocol every stolen entry is part of
    the cross-device traffic a real multi-chip run pays for.

Run:

    PYTHONPATH=src python benchmarks/bench_sharded.py [--scale 0.3]
"""

from __future__ import annotations

import os


def _force_virtual_devices(n: int = 4) -> None:
    # Device count is locked at first jax initialization; this must run
    # before anything below imports jax.
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()


_force_virtual_devices()

import argparse  # noqa: E402
import time  # noqa: E402
from typing import Dict, List  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

import repro.bench  # noqa: F401,E402  (repo root on sys.path)
from benchmarks import common  # noqa: E402
from repro.core import EngineConfig, Enumerator, SubgraphIndex  # noqa: E402
from repro.data import graphgen  # noqa: E402

DEVICE_SWEEP = (1, 2, 4)
N_WORKERS = 8
EXPAND = 4


def run(scale: float = 0.3, seed: int = 7, collection: str = "ppis32-like") -> Dict:
    instances = graphgen.make_collection(
        collection, pattern_edges=(8, 16, 24), patterns_per_target=2,
        scale=scale, seed=seed,
    )
    indices: dict = {}
    for inst in instances:
        indices.setdefault(id(inst.target), SubgraphIndex.build(inst.target))

    avail = len(jax.devices())
    sweep = [d for d in DEVICE_SWEEP if d <= avail]
    assert len(sweep) >= 2, (
        f"need >= 2 devices for the sweep, have {avail}; run this module as "
        "a fresh process (it sets XLA_FLAGS itself) or set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4"
    )

    out: Dict[str, Dict] = {}
    baseline: Dict[str, tuple] = {}
    for n_dev in sweep:
        cfg = EngineConfig(n_workers=N_WORKERS, expand_width=EXPAND)
        session = Enumerator(config=cfg, mesh=None if n_dev == 1 else n_dev)
        v = session.config.n_workers
        v_per_dev = v // n_dev
        matches = states = steps = steals = 0
        pw_steals = np.zeros(v, dtype=np.int64)
        t0 = time.perf_counter()
        for inst in instances:
            q = session.prepare(inst.pattern, name=inst.name,
                                index=indices[id(inst.target)])
            if not q.satisfiable:
                continue
            ms = session.run(q)
            if n_dev == sweep[0]:
                baseline[inst.name] = (ms.matches, ms.states)
            else:
                assert baseline[inst.name] == (ms.matches, ms.states), (
                    f"{inst.name}: devices={n_dev} changed results "
                    f"{(ms.matches, ms.states)} != {baseline[inst.name]}"
                )
            matches += ms.matches
            states += ms.states
            steps += ms.steps
            steals += ms.steals
            pw_steals += ms.per_worker_steals.astype(np.int64)
        wall = time.perf_counter() - t0
        per_dev = pw_steals.reshape(n_dev, v_per_dev).sum(axis=1)
        out[f"d{n_dev}"] = dict(
            devices=n_dev, workers=v, matches=matches, states=states,
            steps=steps, steals=steals, wall_s=wall,
            steals_into_device=per_dev.tolist(),
            compiles=session.cache_info()["compiles"],
        )

    ref = out[f"d{sweep[0]}"]
    for n_dev in sweep[1:]:
        row = out[f"d{n_dev}"]
        assert (row["matches"], row["states"]) == (ref["matches"], ref["states"])
    out["_invariant"] = dict(
        matches=ref["matches"], states=ref["states"],
        device_counts=sweep, holds=True,
    )
    common.save_json("sharded", out)
    return out


def emit_csv(out: Dict) -> List[str]:
    lines = []
    for key, row in sorted(out.items()):
        if key.startswith("_"):
            continue
        per_dev = ";".join(f"d{i}={s}" for i, s in enumerate(row["steals_into_device"]))
        lines.append(common.csv_row(
            f"sharded/{key}", row["wall_s"] * 1e6 / max(row["states"], 1),
            f"matches={row['matches']};states={row['states']};"
            f"steps={row['steps']};steals={row['steals']};{per_dev}",
        ))
    inv = out["_invariant"]
    lines.append(common.csv_row(
        "sharded/invariant", 0.0,
        f"holds={inv['holds']};matches={inv['matches']};"
        f"devices={'/'.join(str(d) for d in inv['device_counts'])}",
    ))
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--collection", default="ppis32-like")
    args = ap.parse_args()
    print("\n".join(emit_csv(run(args.scale, args.seed, args.collection))))
