"""Serving benchmark: continuous coalescing vs per-query submission.

  PYTHONPATH=src python benchmarks/bench_serving.py [--smoke]

Drives >= 32 concurrent heterogeneous queries (three shape buckets: two
dense targets of different size + one CSR-only sparse target) through one
:class:`repro.serve.EnumerationService` and checks the PR-6 acceptance
gates:

  (a) **Throughput**: the coalesced service sustains >= 2x the throughput
      of *sequential per-query submission* — the pre-service serving
      model where each request is handled in isolation (a fresh session
      per query, so every query pays its own engine compilation; that is
      precisely the cost the PR-1 compile cache + this PR's coalescer
      amortize across clients).  For calibration the **warm** sequential
      number (one shared session, per-query ``run`` loop, cache hot) is
      also reported un-gated: on a 1-core CPU host packed lanes share the
      core, so against a warm session wall-clock parity — not speedup —
      is the expectation (EXPERIMENTS.md §Methodology); the service's win
      there is amortized dispatch, not lane parallelism.  The gate is
      asserted in compiled mode; a ``--use-pallas`` run under interpret
      mode is exempt and reports honestly.
  (b) **Compile count == bucket count**: the service's whole corpus costs
      exactly one vmapped engine compilation per coalesce bucket, not one
      per query.
  (c) **Bit-identity**: every client's streamed result — counts AND the
      concatenation of its mapping chunks — equals a standalone
      ``Enumerator.run`` of the same query.
  (d) **Metrics**: p50/p99 latency, batch occupancy, QPS, and compile-
      cache hit rate all come from the `repro.serve.metrics` layer.
"""

from __future__ import annotations

import argparse
import threading
import time
from typing import List, Optional, Tuple

try:
    from benchmarks import common
except ImportError:  # executed from an arbitrary cwd
    import repro.bench  # noqa: F401  (puts the repo root on sys.path)
    from benchmarks import common

from repro.core import EngineConfig, Enumerator, Query, SubgraphIndex
from repro.core.plan import build_csr_plan
from repro.data import graphgen
from repro.kernels import ops as kops
from repro.serve import EnumerationService, ServiceConfig

COLLECT = 32  # per-worker match budget: every query streams mapping chunks
THROUGHPUT_FLOOR = 2.0


def build_corpus(n_queries: int, seed: int) -> Tuple[SubgraphIndex, List[Query]]:
    """>= 3 coalesce buckets of heterogeneous queries: dense target A,
    smaller dense target B (different n_t => different bucket), and a
    CSR-only sparse target C."""
    tgt_a = graphgen.random_graph(120, 360, n_labels=4, seed=seed)
    tgt_b = graphgen.random_graph(60, 180, n_labels=3, seed=seed + 1)
    tgt_c = graphgen.random_graph(240, 520, n_labels=4, seed=seed + 2)
    index_a = SubgraphIndex.build(tgt_a)
    index_b = SubgraphIndex.build(tgt_b)
    prep = Enumerator(index_a)  # prepare() only
    queries: List[Query] = []
    for i in range(n_queries):
        k = i % 3
        if k == 0:
            pat = graphgen.extract_pattern(tgt_a, 3 + (i % 4), seed=seed + 10 + i)
            queries.append(prep.prepare(pat, name=f"a{i}", index=index_a))
        elif k == 1:
            pat = graphgen.extract_pattern(tgt_b, 3 + (i % 3), seed=seed + 10 + i)
            queries.append(prep.prepare(pat, name=f"b{i}", index=index_b))
        else:
            pat = graphgen.extract_pattern(tgt_c, 3 + (i % 2), seed=seed + 10 + i)
            queries.append(Query(pattern=pat, plan=build_csr_plan(pat, tgt_c),
                                 variant="ri", name=f"c{i}", prepare_s=0.0))
    return index_a, queries


def sequential_per_query(queries: List[Query], cfg: EngineConfig) -> Tuple[float, list]:
    """The pre-service model: each query served in isolation — a fresh
    session, so plan-shaped engine compilation is paid per query."""
    t0 = time.perf_counter()
    results = []
    for q in queries:
        fresh = Enumerator(config=cfg)
        results.append(fresh.run(q, collect_matches=COLLECT))
    return time.perf_counter() - t0, results


def sequential_warm(queries: List[Query], cfg: EngineConfig) -> Tuple[float, list]:
    """Calibration: one shared warm session, per-query run loop."""
    session = Enumerator(config=cfg)
    for q in queries[:3]:
        session.run(q, collect_matches=COLLECT)  # warm each bucket's engine
    t0 = time.perf_counter()
    results = [session.run(q, collect_matches=COLLECT) for q in queries]
    return time.perf_counter() - t0, results


def coalesced_service(
    index: SubgraphIndex, queries: List[Query], cfg: EngineConfig,
    lanes: int, window_s: float,
) -> Tuple[float, list, list, dict, int]:
    """All queries submitted concurrently (one client thread each) through
    the coalescing service; returns wall time, MatchSets, streamed
    mappings, the metrics snapshot, and the compile count."""
    svc = EnumerationService(
        index, config=cfg,
        service=ServiceConfig(max_lanes=lanes, batch_window_s=window_s),
    )
    out: List[Optional[tuple]] = [None] * len(queries)
    errors: List[BaseException] = []

    def client(i: int, q: Query) -> None:
        try:
            h = svc.submit(q, tenant=f"t{i % 8}", collect=COLLECT, timeout=60.0)
            out[i] = (h.result(timeout=600.0), h.mappings())
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=client, args=(i, q), daemon=True)
               for i, q in enumerate(queries)]
    t0 = time.perf_counter()
    with svc:
        for t in threads:
            t.start()
        for t in threads:
            t.join(600.0)
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    assert all(r is not None for r in out), "service dropped a client"
    stats = svc.stats()
    compiles = svc.enumerator.cache_stats()["compiles"]
    return (wall, [r[0] for r in out], [r[1] for r in out], stats, compiles)


def run(n_queries: int, baseline_n: int, lanes: int, window_ms: float,
        seed: int, use_pallas: bool) -> dict:
    cfg = EngineConfig(n_workers=4, expand_width=2, step_backend="auto",
                       use_pallas=use_pallas)
    interpret = kops.resolve_interpret(None)
    gate = not (use_pallas and interpret)  # interpret-mode pallas is exempt

    index, queries = build_corpus(n_queries, seed)
    n_buckets = len({Enumerator(config=cfg).coalesce_key(q) for q in queries})

    # --- coalesced service (all clients concurrent) -----------------------
    t_coal, served, streamed, stats, compiles = coalesced_service(
        index, queries, cfg, lanes=lanes, window_s=window_ms / 1e3,
    )
    thr_coal = len(queries) / t_coal

    # --- (b) compile count == bucket count --------------------------------
    assert compiles == n_buckets, (
        f"service compiled {compiles} engines for {len(queries)} queries in "
        f"{n_buckets} buckets — expected one per bucket"
    )

    # --- (c) bit-identity vs standalone runs ------------------------------
    ref = Enumerator(config=cfg)
    for q, ms, maps in zip(queries, served, streamed):
        r = ref.run(q, collect_matches=COLLECT)
        assert (ms.matches, ms.states, ms.steps) == (r.matches, r.states, r.steps), (
            f"{q.name}: served counts diverge from standalone run"
        )
        assert maps == r.mappings(), (
            f"{q.name}: streamed mapping chunks do not concatenate to the "
            f"standalone run's mappings"
        )

    # --- sequential baselines --------------------------------------------
    base_qs = queries[:baseline_n]
    t_seq, _ = sequential_per_query(base_qs, cfg)
    thr_seq = len(base_qs) / t_seq
    t_warm, _ = sequential_warm(queries, cfg)
    thr_warm = len(queries) / t_warm

    # --- (a) throughput gate ---------------------------------------------
    speedup = thr_coal / thr_seq
    if gate:
        assert speedup >= THROUGHPUT_FLOOR, (
            f"coalesced service must beat sequential per-query submission "
            f"{THROUGHPUT_FLOOR}x in compiled mode; measured {speedup:.2f}x "
            f"({thr_coal:.2f} vs {thr_seq:.2f} q/s)"
        )

    # --- (d) metrics come from the metrics layer --------------------------
    for key in ("latency_p50_s", "latency_p99_s", "batch_occupancy",
                "cache_hit_rate", "qps"):
        assert key in stats, f"metrics snapshot missing {key}"
    assert stats["latency_p99_s"] >= stats["latency_p50_s"] > 0
    assert 0 < stats["batch_occupancy"] <= 1
    assert stats["completed"] == len(queries)

    print(common.csv_row("serve_seq_perquery", t_seq / len(base_qs) * 1e6,
                         f"n={len(base_qs)} thr={thr_seq:.2f}q/s (compile per query)"))
    print(common.csv_row("serve_seq_warm", t_warm / len(queries) * 1e6,
                         f"n={len(queries)} thr={thr_warm:.2f}q/s (shared warm session)"))
    print(common.csv_row("serve_coalesced", t_coal / len(queries) * 1e6,
                         f"n={len(queries)} thr={thr_coal:.2f}q/s "
                         f"compiles={compiles} buckets={n_buckets}"))
    print(f"  coalesced vs per-query submission: {speedup:.2f}x "
          f"({'gated >= %.1fx' % THROUGHPUT_FLOOR if gate else 'interpret mode: exempt'})")
    print(f"  coalesced vs warm sequential:      {thr_coal / thr_warm:.2f}x "
          f"(reported, un-gated: 1-core host, see docstring)")
    print(f"  p50={stats['latency_p50_s']:.3f}s p99={stats['latency_p99_s']:.3f}s "
          f"occupancy={stats['batch_occupancy']:.2f} "
          f"cache_hit_rate={stats['cache_hit_rate']:.2f} qps={stats['qps']:.1f}")

    payload = dict(
        n_queries=len(queries), n_buckets=n_buckets, compiles=compiles,
        lanes=lanes, window_ms=window_ms,
        t_coalesced_s=t_coal, t_seq_perquery_s=t_seq, t_seq_warm_s=t_warm,
        baseline_n=len(base_qs),
        thr_coalesced=thr_coal, thr_seq_perquery=thr_seq, thr_seq_warm=thr_warm,
        speedup_vs_perquery=speedup, speedup_vs_warm=thr_coal / thr_warm,
        speedup_asserted=gate,
        latency_p50_s=stats["latency_p50_s"], latency_p99_s=stats["latency_p99_s"],
        batch_occupancy=stats["batch_occupancy"],
        cache_hit_rate=stats["cache_hit_rate"], qps=stats["qps"],
        matches=[ms.matches for ms in served],
    )
    common.save_json("serving", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: same >= 32 concurrent queries, smaller "
                         "per-query-compile baseline sample")
    ap.add_argument("--patterns", type=int, default=None)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--window-ms", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--use-pallas", action="store_true")
    args = ap.parse_args()
    n = args.patterns or (32 if args.smoke else 36)
    assert n >= 32, "the acceptance gate requires >= 32 concurrent queries"
    baseline_n = 6 if args.smoke else n
    out = run(n, baseline_n, args.lanes, args.window_ms, args.seed,
              args.use_pallas)
    print(f"\n{out['n_queries']} concurrent queries, {out['n_buckets']} buckets, "
          f"{out['compiles']} compiles: coalesced {out['thr_coalesced']:.2f} q/s = "
          f"{out['speedup_vs_perquery']:.2f}x per-query submission "
          f"({out['thr_seq_perquery']:.2f} q/s), "
          f"{out['speedup_vs_warm']:.2f}x warm sequential "
          f"({out['thr_seq_warm']:.2f} q/s)")


if __name__ == "__main__":
    main()
