"""§Dry-run summary: per-cell memory feasibility table from the compiled
``memory_analysis()`` records.

  PYTHONPATH=src python -m benchmarks.dryrun_report

Writes artifacts/dryrun_summary_<mesh>.md: argument/temp/output bytes per
device, the 16 GB v5e HBM feasibility verdict, and compile times — the
"proves it fits" artifact the brief requires, reported honestly (kimi/grok
training exceed 256-chip residency; the dry run validates their sharding).
"""

from __future__ import annotations

import os

from benchmarks import roofline

HBM_BYTES = 16 * 1024**3  # v5e per chip

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def gb(x) -> str:
    return f"{x / 1024**3:.2f}"


def table(mesh: str) -> str:
    rows = []
    for rec in roofline.load_cells(mesh):
        if rec.get("skipped"):
            rows.append(f"| {rec['cell']} | — | — | — | SKIP | — |")
            continue
        mem = rec.get("memory_analysis", {})
        arg = mem.get("argument_size_in_bytes", 0)
        tmp = mem.get("temp_size_in_bytes", 0)
        out = mem.get("output_size_in_bytes", 0)
        alias = mem.get("alias_size_in_bytes", 0)
        peak = arg + tmp + out - alias
        verdict = "fits" if peak <= HBM_BYTES else f"needs ≥{-(-peak // HBM_BYTES) * rec['n_devices']} chips"
        rows.append(
            f"| {rec['cell']} | {gb(arg)} | {gb(tmp)} | {gb(out)} "
            f"| {verdict} | {rec.get('compile_s', 0):.1f}s |"
        )
    hdr = ("| cell | args (GB/dev) | temp (GB/dev) | out (GB/dev) "
           "| 16 GB HBM verdict | compile |\n|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def main() -> None:
    for mesh in ("single", "multi"):
        cells = roofline.load_cells(mesh)
        if not cells:
            continue
        path = os.path.join(ART, f"dryrun_summary_{mesh}.md")
        with open(path, "w") as f:
            f.write(f"# Dry-run memory summary — {mesh} mesh\n\n"
                    f"{table(mesh)}\n\n"
                    "peak ≈ args + temp + out − aliased (donated buffers "
                    "alias outputs).  CAVEATS: temp sizes come from the "
                    "CPU-backend buffer assignment, which lacks TPU-grade "
                    "liveness reuse — the chip-count verdicts are UPPER "
                    "bounds (e.g. dense-LM train cells fit far fewer chips "
                    "with TPU buffer reuse + microbatching).  The "
                    "param+optimizer arithmetic is exact though: kimi-k2 "
                    "training genuinely needs ≥2048 chips (14 B/param "
                    "ZeRO-sharded), grok ≥512.  The compile itself is the "
                    "deliverable: the sharding is coherent at 256/512 "
                    "chips.\n")
        print(f"[dryrun_report] wrote {path}")


if __name__ == "__main__":
    main()
