"""GraphSAGE (mean aggregator): ``h' = act(W_self h + W_nbr mean_{u∈N(v)} h_u)``.

Works both full-batch and on sampled blocks from
`repro.models.gnn.sampler` (the reddit ``minibatch_lg`` path).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constraint
from repro.models.common import ParamSpec, dot
from repro.models.gnn.common import AGGREGATORS, gather_src, masked_softmax_ce


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    n_layers: int = 2
    d_hidden: int = 128
    aggregator: str = "mean"
    sample_sizes: tuple = (25, 10)
    normalize: bool = True


def param_specs(cfg: SAGEConfig, d_in: int, d_out: int) -> Dict[str, ParamSpec]:
    dims = [d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [d_out]
    specs: Dict[str, ParamSpec] = {}
    for i in range(cfg.n_layers):
        specs[f"w_self{i}"] = ParamSpec(
            (dims[i], dims[i + 1]), (None, "tensor" if i == 0 else None), jnp.float32
        )
        specs[f"w_nbr{i}"] = ParamSpec(
            (dims[i], dims[i + 1]), (None, "tensor" if i == 0 else None), jnp.float32
        )
        specs[f"b{i}"] = ParamSpec((dims[i + 1],), (None,), jnp.float32, init="zeros")
    return specs


def forward(params, cfg: SAGEConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    h = batch["feats"]
    src, dst = batch["src"], batch["dst"]
    n = h.shape[0]
    agg_fn = AGGREGATORS[cfg.aggregator]
    for i in range(cfg.n_layers):
        msg = gather_src(h, src)
        agg = agg_fn(msg, dst, n)
        h = dot(h, params[f"w_self{i}"]) + dot(agg, params[f"w_nbr{i}"]) + params[f"b{i}"]
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
            if cfg.normalize:
                h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
        h = constraint(h, (None, None))
    return h


def loss_fn(params, cfg: SAGEConfig, batch):
    logits = forward(params, cfg, batch)
    loss, count = masked_softmax_ce(logits, batch["labels"])
    return loss, {"loss": loss, "nodes": count}
