"""Error-feedback int8 gradient compression for bandwidth-bound DP links.

For inter-pod data parallelism the all-reduce crosses the slowest links; 4×
compression there buys real wall-clock at 1000-node scale.  Scheme (1-bit
Adam family, simplified to int8):

  1. ``e += g``                 (accumulate incoming grad into the residual)
  2. ``q = round(e / s) · s``   (per-tensor symmetric int8 quantization)
  3. ``e -= q``                 (keep the quantization error for next step)
  4. all-reduce ``q`` (int8 payload), decode.

The compression is lossless *in expectation* thanks to error feedback; tests
verify convergence on a quadratic.  Wired into ``make_train_step`` via
``compress_grads`` (applied before the optimizer, after batch-mean).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jnp.ndarray, err: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (int8 payload, scale, new error residual)."""
    e = err + g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(e)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(e / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, e - deq


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, err_state):
    """Tree-wise error-feedback int8 round trip (the all-reduce in between is
    inserted by GSPMD when gradients are batch-sharded)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, e2 = compress(g, e)
        out_g.append(decompress(q, s).astype(g.dtype))
        out_e.append(e2)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)
