"""Re-run the HLO walker over archived .hlo.gz artifacts without recompiling.

  PYTHONPATH=src python -m benchmarks.reanalyze [dir ...]

Updates the ``hlo_walk`` section of each JSON in place — used whenever the
accounting methodology improves (the compile results themselves are
immutable).
"""

from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from benchmarks import hlo_walk

DEFAULT_DIRS = [
    os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun"),
    os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun_baseline"),
]


def reanalyze_dir(d: str) -> int:
    n = 0
    for path in sorted(glob.glob(os.path.join(d, "**", "*.json"), recursive=True)):
        gz = path.replace(".json", ".hlo.gz")
        if not os.path.exists(gz):
            continue
        with open(path) as f:
            rec = json.load(f)
        with gzip.open(gz, "rt") as f:
            text = f.read()
        rec["hlo_walk"] = hlo_walk.analyze(text)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        n += 1
    return n


def main() -> None:
    dirs = sys.argv[1:] or DEFAULT_DIRS
    for d in dirs:
        if os.path.isdir(d):
            n = reanalyze_dir(d)
            print(f"[reanalyze] {d}: {n} cells updated")


if __name__ == "__main__":
    main()
