"""Subgraph-enumeration driver — the paper's tool, end to end.

  PYTHONPATH=src python -m repro.launch.sge_run --collection ppis32-like \
      --variant ri-ds-si-fc --workers 16 --scale 0.3

Generates (or loads) a collection, prepares one
:class:`~repro.core.session.SubgraphIndex` per target, and runs every
pattern through a single :class:`~repro.core.session.Enumerator` session —
so all instances share a handful of shape-bucketed engine compilations.
Three execution modes map to the session's three methods:

  * ``--mode single``   one engine invocation per query (default);
  * ``--mode packed``   LPT-balanced vmapped packs (``run_batch``; on the
    production mesh the pack axis maps to ``pod``);
  * ``--mode stream``   results printed as packs drain (``stream``; the
    serving path).

Reports per-instance matches / states / steps plus collection aggregates —
the shape of the paper's experiment tables — and the session's compile
cache counters.
"""

from __future__ import annotations

import argparse
import sys
import time

from repro.core import EngineConfig, Enumerator, SubgraphIndex
from repro.data import graphgen


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--collection", default="ppis32-like",
                    choices=sorted(graphgen.COLLECTIONS))
    ap.add_argument("--variant", default="ri-ds-si-fc")
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--expand", type=int, default=4)
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--mode", choices=("single", "packed", "stream"),
                    default="single")
    ap.add_argument("--packed", action="store_true",
                    help="deprecated alias for --mode packed")
    ap.add_argument("--pack-size", type=int, default=4)
    args = ap.parse_args()
    mode = "packed" if args.packed else args.mode

    instances = graphgen.make_collection(
        args.collection, pattern_edges=(8, 16, 24), patterns_per_target=2,
        scale=args.scale, seed=args.seed,
    )
    cfg = EngineConfig(n_workers=args.workers, expand_width=args.expand)
    session = Enumerator(config=cfg, variant=args.variant)

    indices: dict = {}
    t0 = time.perf_counter()
    queries = []
    for inst in instances:
        key = id(inst.target)
        if key not in indices:
            indices[key] = SubgraphIndex.build(inst.target)
        queries.append(session.prepare(inst.pattern, name=inst.name,
                                       index=indices[key]))

    matches = states = 0
    if mode == "single":
        for q in queries:
            ms = session.run(q)
            print(f"{ms.name:40s} matches={ms.matches:<8d} states={ms.states:<9d} "
                  f"steps={ms.steps:<7d} steals={ms.steals:<5d} {ms.match_s:6.2f}s")
            matches += ms.matches
            states += ms.states
    elif mode == "packed":
        for ms in session.run_batch(queries, pack_size=args.pack_size):
            print(f"{ms.name:40s} matches={ms.matches:<8d} states={ms.states:<9d} "
                  f"steps={ms.steps}")
            matches += ms.matches
            states += ms.states
    else:  # stream: print in completion order, as the serving loop would
        for ms in session.stream(queries, pack_size=args.pack_size):
            print(f"{ms.name:40s} matches={ms.matches:<8d} states={ms.states:<9d} "
                  f"steps={ms.steps}")
            matches += ms.matches
            states += ms.states

    total = time.perf_counter() - t0
    info = session.cache_info()
    print(f"\n[{args.collection}/{mode}] {len(queries)} queries, "
          f"{matches} matches, {states} states, {total:.1f}s "
          f"({states/max(total,1e-9):.0f} states/s); "
          f"engine compiles={info['compiles']} cache_hits={info['cache_hits']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
