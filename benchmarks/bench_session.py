"""Session-level benchmark: prove re-compilation is gone.

  PYTHONPATH=src python -m benchmarks.bench_session [--patterns N]

Runs ``N >= 32`` same-bucket patterns against one target three ways —
per-call ``enumerate_subgraphs`` (the old one-shot API), session
``run`` and session ``run_batch`` — and checks:

  * the session triggers **<= 2 engine compilations** total (one single
    engine + one vmapped batch engine) for the whole corpus, counted by the
    `Enumerator`'s own cache counters;
  * every session count matches ``enumerate_subgraphs`` exactly.

Emits CSV rows (name, us_per_query, derived) and a JSON artifact.
"""

from __future__ import annotations

import argparse
import time

try:
    from benchmarks import common
except ImportError:  # executed from an arbitrary cwd
    import repro.bench  # noqa: F401  (puts the repo root on sys.path)
    from benchmarks import common

from repro.core import EngineConfig, Enumerator, SubgraphIndex, enumerate_subgraphs
from repro.data import graphgen


def run(n_patterns: int = 32, seed: int = 7) -> dict:
    tgt = graphgen.random_graph(120, 600, n_labels=6, seed=seed)
    pats = [graphgen.extract_pattern(tgt, 4 + (i % 5), seed=seed + 1 + i)
            for i in range(n_patterns)]
    cfg = EngineConfig(n_workers=8, expand_width=4)

    # --- old one-shot API, fresh pack+plan per call (baseline) -------------
    t0 = time.perf_counter()
    base = [enumerate_subgraphs(p, tgt, config=cfg) for p in pats]
    t_oneshot = time.perf_counter() - t0

    # --- session: prepare once, run each --------------------------------
    session = Enumerator(SubgraphIndex.build(tgt), config=cfg)
    t0 = time.perf_counter()
    queries = [session.prepare(p, name=f"q{i}") for i, p in enumerate(pats)]
    singles = [session.run(q) for q in queries]
    t_single = time.perf_counter() - t0
    compiles_after_single = session.cache_info()["compiles"]

    # --- session: vmapped batch path -------------------------------------
    t0 = time.perf_counter()
    batch = session.run_batch(queries, pack_size=8)
    t_batch = time.perf_counter() - t0
    info = session.cache_info()

    for b, s, m in zip(base, singles, batch):
        assert (b.matches, b.states) == (s.matches, s.states), "run() mismatch"
        assert (b.matches, b.states) == (m.matches, m.states), "run_batch() mismatch"
    assert info["compiles"] <= 2, (
        f"expected <= 2 engine compilations for {n_patterns} same-bucket "
        f"patterns, got {info['compiles']}"
    )

    n = len(pats)
    print(common.csv_row("session_oneshot", t_oneshot / n * 1e6,
                         f"matches={sum(r.matches for r in base)}"))
    print(common.csv_row("session_run", t_single / n * 1e6,
                         f"compiles={compiles_after_single}"))
    print(common.csv_row("session_run_batch", t_batch / n * 1e6,
                         f"compiles={info['compiles']} hits={info['cache_hits']}"))
    payload = dict(
        n_patterns=n,
        oneshot_s=t_oneshot,
        session_run_s=t_single,
        session_batch_s=t_batch,
        compiles=info["compiles"],
        cache_hits=info["cache_hits"],
        matches=[r.matches for r in singles],
        states=[r.states for r in singles],
    )
    common.save_json("session", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--patterns", type=int, default=32)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    out = run(n_patterns=args.patterns, seed=args.seed)
    print(f"\n{out['n_patterns']} same-bucket patterns: "
          f"{out['compiles']} engine compilations, "
          f"{out['cache_hits']} cache hits; "
          f"one-shot {out['oneshot_s']:.2f}s -> session run "
          f"{out['session_run_s']:.2f}s -> batch {out['session_batch_s']:.2f}s")


if __name__ == "__main__":
    main()
