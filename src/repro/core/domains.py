"""RI-DS domain assignment: initial compatibility domains, arc-consistency
filtering, and the paper's singleton forward checking (FC).

Domains are packed ``[n_p, w]`` uint32 bitmaps over target nodes — the same
representation RI-DS uses ("domains are implemented as bitmasks", paper
§4.2.2), which makes every filtering step a dense bitwise sweep.

Two implementations of the same pipeline live here (DESIGN.md §5):

* the **numpy oracle** — ``initial_domains`` / ``arc_consistency`` /
  ``forward_check_singletons`` / ``fixpoint_domains``, a host-side loop over
  constraint arcs.  Slow but transparent; every device result is validated
  against it bit-for-bit.
* the **device engine** — a jitted ``lax.while_loop`` fixpoint
  (:func:`device_fixpoint`) that sweeps *all* constraint arcs at once against
  ``adj_bits[n_elab, 2, n_t, w]``, optionally routing the row-AND-any
  reduction and popcounts through the Pallas kernels
  (`repro.kernels.domain_ac.adjacency_any` / `arc_any_sweep`,
  `repro.kernels.popcount_reduce.popcount_rows`), and vmappable across a
  padded pattern batch (:func:`compute_domains_batch` — the
  ``Enumerator.prepare_batch`` backend, DESIGN.md §5).

The same fixpoint engine also runs **CSR-native** (DESIGN.md §11): hand it a
:class:`CsrTargetDomainArrays` instead of a :class:`TargetDomainArrays` and
every AC sweep walks `repro.core.graph.CsrPlanes` segments ("some neighbor
of ``v`` in ``D(child)``" via per-row segment bit tests) instead of dense
adjacency bitmaps — the ``[n_elab, 2, n_t, w]`` planes are never
materialized, which is what lets every ``ri-ds*`` variant run on CSR-only
plans (`repro.core.plan.build_csr_plan`) at the >33k-node scale the sparse
step backend unlocked.  Kernel: `repro.kernels.domain_ac.csr_arc_sweep`
(scalar-prefetch — single-query only); jnp/vmap path:
`repro.kernels.ref.csr_arc_sweep_ref`.

Pipeline (paper §4.1 / §4.2.2):

  1. ``initial_domains``    — label equality + degree dominance + **self-loop
     dominance**: a pattern node carrying a self-loop with edge label ``l``
     can only map to target nodes carrying a same-label self-loop.  Pattern
     self-loops are inexpressible as parent constraints (the ordering skips
     ``u == v`` edges), so this unary constraint is their single enforcement
     point; the engine/ref candidate checks inherit it because candidates are
     always intersected with the domain bitmap.
  2. ``arc_consistency``    — drop ``t`` from ``D(p)`` if some pattern edge
     ``(p, q)`` has no counterpart ``(t, t')`` with ``t' ∈ D(q)`` and a
     compatible edge label.  Iterated to a fixpoint (each removal can expose
     more inconsistency).
  3. ``forward_check_singletons`` — every pattern node with ``|D(p)| == 1``
     *will* consume its target node; remove that node from all other domains,
     repeating on newly created singletons.  Detects unsatisfiability when a
     domain empties or two singletons collide.
  4. ``fixpoint_domains`` (variant ``ri-ds-si-acfc``) — interleave 2 and 3
     until a *joint* fixpoint: FC removals re-trigger AC, reaching prunings
     the sequential AC→FC pipeline leaves on the table (paper §4.2.2's
     "improved pruning" taken to closure).  The joint fixpoint is unique
     (both rules are monotone prunings), so iteration order never changes
     the result — only how fast it is reached.

Contracts:

* ``DomainResult.satisfiable is False`` ⇒ ``bits`` is **all-zero**.  Early
  unsat exits used to leak partially-filtered bitmaps; callers must never be
  able to enumerate from a half-pruned plan.
* A pattern edge label with no adjacency plane in the target
  (``elab >= target.n_edge_labels``) makes the query unsatisfiable in every
  variant — it used to raise ``IndexError`` (arcs) or silently clamp to a
  wrong label plane (engine gathers).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import (
    Graph,
    PackedGraph,
    WORD_BITS,
    bitmap_from_indices,
    n_words,
    popcount,
)


@dataclasses.dataclass
class DomainResult:
    """Packed domains plus satisfiability flag.

    Invariant: ``satisfiable is False`` implies ``bits`` is all-zero, so an
    unsatisfiable result can never seed a search.
    """

    bits: np.ndarray  # [n_p, w] uint32
    satisfiable: bool

    def sizes(self) -> np.ndarray:
        return popcount(self.bits)


def _unsat(bits: np.ndarray) -> DomainResult:
    """The canonical unsatisfiable result: zeroed bits (see class invariant)."""
    return DomainResult(np.zeros_like(bits), False)


# ---------------------------------------------------------------------------
# pattern constraint extraction
# ---------------------------------------------------------------------------

def _self_loops(pattern: Graph) -> List[Tuple[int, int]]:
    """All pattern self-loop constraints ``(u, elab)``.

    Self-loops cannot be parent constraints (both endpoints are the same
    ordering position), so they are enforced as unary domain constraints in
    :func:`initial_domains` / the device engine's initial phase."""
    return [
        (int(u), int(l))
        for u, v, l in zip(pattern.src.tolist(), pattern.dst.tolist(),
                           pattern.edge_labels.tolist())
        if u == v
    ]


def _pattern_arcs(pattern: Graph) -> np.ndarray:
    """All directed constraint arcs ``(p, q, dir, elab)``.

    For pattern edge ``(p -> q)`` with label ``l`` we emit two arcs:
      * ``(p, q, dir=0, l)``: every ``t ∈ D(p)`` needs an out-edge with label
        ``l`` to some ``t' ∈ D(q)``;
      * ``(q, p, dir=1, l)``: every ``t ∈ D(q)`` needs an in-edge from some
        ``t' ∈ D(p)``.

    Self-loops (``u == v``) are excluded: their binary form ("some D(u) node
    is an out-neighbor") is strictly weaker than the true unary constraint
    ("t itself carries the loop"), which :func:`initial_domains` enforces.
    """
    arcs = []
    for u, v, l in zip(pattern.src.tolist(), pattern.dst.tolist(),
                       pattern.edge_labels.tolist()):
        if u == v:
            continue
        arcs.append((u, v, 0, l))
        arcs.append((v, u, 1, l))
    return np.asarray(arcs, dtype=np.int32).reshape(-1, 4)


def target_self_loop_bits(target: PackedGraph) -> np.ndarray:
    """``[n_elab, w]`` bitmaps: bit ``t`` set iff the target has a self-loop
    ``(t, t)`` with edge label ``l`` — the diagonal of each adjacency plane."""
    n, w = target.n, target.w
    out = np.zeros((target.n_edge_labels, w), dtype=np.uint32)
    if n == 0:
        return out
    t = np.arange(n)
    word = t // WORD_BITS
    shift = (t % WORD_BITS).astype(np.uint32)
    for l in range(target.n_edge_labels):
        diag = (target.adj_bits[l, 0, t, word] >> shift) & np.uint32(1)
        idx = np.nonzero(diag)[0]
        if idx.size:
            out[l] = bitmap_from_indices(idx, n, w)
    return out


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------

def initial_domains(pattern: Graph, target: PackedGraph) -> np.ndarray:
    """``D0(p) = { t : lab(t) == lab(p), deg_out(t) >= deg_out(p),
    deg_in(t) >= deg_in(p), self-loops of p ⊆ self-loops of t }``
    as ``[n_p, w]`` bitmaps.

    The self-loop clause is the bugfix for patterns with loop edges: a loop
    with a label the target lacks empties the domain outright."""
    p_out = pattern.out_degrees()
    p_in = pattern.in_degrees()
    w = target.w
    bits = np.zeros((pattern.n, w), dtype=np.uint32)
    for p in range(pattern.n):
        ok = (
            (target.labels == pattern.labels[p])
            & (target.deg_out >= p_out[p])
            & (target.deg_in >= p_in[p])
        )
        idx = np.nonzero(ok)[0]
        if idx.size:
            bits[p] = bitmap_from_indices(idx, target.n, w)
    loops = _self_loops(pattern)
    if loops:
        loop_bits = target_self_loop_bits(target)
        for p, l in loops:
            if l >= target.n_edge_labels:
                bits[p] = 0  # label overflow: no target loop can match
            else:
                bits[p] &= loop_bits[l]
    return bits


def arc_consistency(
    pattern: Graph,
    target: PackedGraph,
    bits: np.ndarray,
    max_iters: Optional[int] = None,
) -> DomainResult:
    """Filter domains to (iterated) arc consistency.

    For arc ``(p, q, dir, l)``: keep ``t`` in ``D(p)`` only if
    ``adj_bits[l, dir, t] & D(q)`` is non-empty — a row-wise AND + any-bit
    test over the target adjacency bitmaps, vectorized over all ``t``.
    A label ``l`` with no adjacency plane (``l >= n_elab``) is treated as an
    all-empty plane, so the arc's domain empties (label-overflow bugfix —
    this used to raise ``IndexError``).
    """
    bits = bits.copy()
    arcs = _pattern_arcs(pattern)
    if arcs.size == 0:
        if np.all(popcount(bits) > 0):
            return DomainResult(bits, True)
        return _unsat(bits)
    n_elab = target.adj_bits.shape[0]
    it = 0
    while True:
        it += 1
        changed = False
        for p, q, d, l in arcs.tolist():
            if l >= n_elab:
                rows_any = np.zeros(target.n, dtype=bool)
            else:
                rows = target.adj_bits[l, d]  # [n_t, w]
                rows_any = np.any(rows & bits[q][None, :], axis=-1)  # [n_t]
            mask = (
                bitmap_from_indices(np.nonzero(rows_any)[0], target.n, target.w)
                if rows_any.any()
                else np.zeros(target.w, np.uint32)
            )
            nb = bits[p] & mask
            if not np.array_equal(nb, bits[p]):
                bits[p] = nb
                changed = True
                if not nb.any():
                    return _unsat(bits)
        if not changed or (max_iters is not None and it >= max_iters):
            break
    if np.all(popcount(bits) > 0):
        return DomainResult(bits, True)
    return _unsat(bits)


def forward_check_singletons(bits: np.ndarray) -> DomainResult:
    """The paper's FC (§4.2.2): propagate injectivity from singleton domains.

    Pattern nodes with ``|D(p)| == 1`` are guaranteed to be assigned their
    single target node; remove that node from every *other* domain, and
    iterate on newly created singletons.
    """
    bits = bits.copy()
    n_p = bits.shape[0]
    sizes = popcount(bits)
    if np.any(sizes == 0):
        return _unsat(bits)
    processed = np.zeros(n_p, dtype=bool)
    while True:
        new = np.nonzero((sizes == 1) & ~processed)[0]
        if new.size == 0:
            break
        # Union bitmap of all newly discovered singleton targets.  Collision
        # (two singletons sharing a target) surfaces as an emptied domain.
        union = np.zeros(bits.shape[1], dtype=np.uint32)
        for p in new.tolist():
            if (union & bits[p]).any():
                return _unsat(bits)  # two singletons collide
            union |= bits[p]
            processed[p] = True
        keep = ~processed
        bits[keep] &= ~union[None, :]
        sizes = popcount(bits)
        if np.any(sizes == 0):
            return _unsat(bits)
    return DomainResult(bits, True)


def fixpoint_domains(
    pattern: Graph,
    target: PackedGraph,
    bits: np.ndarray,
    max_iters: Optional[int] = None,
) -> DomainResult:
    """AC ⇄ FC joint fixpoint (numpy oracle for the device engine).

    Alternates arc consistency and singleton forward checking until neither
    removes a candidate: FC removals re-trigger AC.  Both rules are monotone
    prunings, so the joint fixpoint is unique and iteration order does not
    affect the result (DESIGN.md §5).
    """
    res = DomainResult(bits.copy(), True)
    while True:
        res = arc_consistency(pattern, target, res.bits, max_iters=max_iters)
        if not res.satisfiable:
            return res
        nxt = forward_check_singletons(res.bits)
        if not nxt.satisfiable or np.array_equal(nxt.bits, res.bits):
            return nxt
        res = nxt


def initial_domains_sparse(pattern: Graph, target: Graph, w: int) -> np.ndarray:
    """:func:`initial_domains` computed from a host :class:`Graph` directly
    — no :class:`PackedGraph` (hence no ``O(n_t² / 32)`` dense adjacency
    bitmaps) is ever materialized.  Bit-identical to the packed form for the
    same target; the entry point for CSR-only plans
    (`repro.core.plan.build_csr_plan`, DESIGN.md §6.4)."""
    t_out = target.out_degrees()
    t_in = target.in_degrees()
    p_out = pattern.out_degrees()
    p_in = pattern.in_degrees()
    bits = np.zeros((pattern.n, w), dtype=np.uint32)
    for p in range(pattern.n):
        ok = (
            (target.labels == pattern.labels[p])
            & (t_out >= p_out[p])
            & (t_in >= p_in[p])
        )
        idx = np.nonzero(ok)[0]
        if idx.size:
            bits[p] = bitmap_from_indices(idx, target.n, w)
    loops = _self_loops(pattern)
    if loops:
        n_elab = target.n_edge_labels
        loop_mask = target.src == target.dst
        loop_bits = np.zeros((n_elab, w), dtype=np.uint32)
        for l in range(n_elab):
            idx = target.src[loop_mask & (target.edge_labels == l)]
            if idx.size:
                loop_bits[l] = bitmap_from_indices(idx, target.n, w)
        for p, l in loops:
            if l >= n_elab:
                bits[p] = 0  # label overflow: no target loop can match
            else:
                bits[p] &= loop_bits[l]
    return bits


def compute_domains_sparse(
    pattern: Graph,
    target: Graph,
    w: int,
    use_ac: bool = False,
    use_fc: bool = False,
    interleave: bool = False,
    use_pallas: bool = False,
    ac_iters: Optional[int] = None,
    tgt_arrays: Optional["CsrTargetDomainArrays"] = None,
) -> DomainResult:
    """Domain pipeline over a host :class:`Graph` — dense adjacency bitmaps
    are never materialized, for any variant (DESIGN.md §11).

    With the default flags (variant ``ri``) this is
    :func:`initial_domains_sparse` plus the same label-overflow /
    empty-domain unsat rules as :func:`compute_domains`, computed entirely on
    host.  Any of ``use_ac`` / ``use_fc`` routes through the CSR-native
    device fixpoint (:func:`compute_domains_csr`) instead — the same jitted
    AC ⇄ FC engine as the dense path, sweeping `CsrPlanes` segments.
    Bit-identical to :func:`compute_domains` on the packed form of the same
    target with the same flags (property-tested)."""
    if use_ac or use_fc:
        return compute_domains_csr(
            pattern, target, w, use_ac=use_ac, use_fc=use_fc,
            interleave=interleave, use_pallas=use_pallas, ac_iters=ac_iters,
            tgt_arrays=tgt_arrays,
        )
    bits = initial_domains_sparse(pattern, target, w)
    if pattern.m and int(pattern.edge_labels.max()) >= target.n_edge_labels:
        return _unsat(bits)
    if not np.all(popcount(bits) > 0):
        return _unsat(bits)
    return DomainResult(bits, True)


def compute_domains(
    pattern: Graph,
    target: PackedGraph,
    use_ac: bool = True,
    use_fc: bool = False,
    ac_iters: Optional[int] = None,
    interleave: bool = False,
) -> DomainResult:
    """Full RI-DS domain pipeline (numpy oracle).

    ``use_ac=False`` yields RI's implicit domains (label + degree + self-loop
    compat only); ``use_fc=True`` adds the paper's singleton forward checking;
    ``interleave=True`` (with both) runs AC and FC to their joint fixpoint
    (variant ``ri-ds-si-acfc``) instead of the sequential AC → FC pass.

    A pattern edge label outside the target's label range makes the query
    unsatisfiable in **every** variant (label-overflow bugfix): without this,
    variant ``ri`` plans would hand the engine out-of-range adjacency plane
    indices that jnp gathers silently clamp to the wrong label.
    """
    bits = initial_domains(pattern, target)
    if pattern.m and int(pattern.edge_labels.max()) >= target.n_edge_labels:
        return _unsat(bits)
    if not np.all(popcount(bits) > 0):
        return _unsat(bits)
    if use_ac and use_fc and interleave:
        return fixpoint_domains(pattern, target, bits, max_iters=ac_iters)
    res = DomainResult(bits, True)
    if use_ac:
        res = arc_consistency(pattern, target, res.bits, max_iters=ac_iters)
        if not res.satisfiable:
            return res
    if use_fc:
        res = forward_check_singletons(res.bits)
    return res


# ---------------------------------------------------------------------------
# device-resident fixpoint engine (DESIGN.md §5)
# ---------------------------------------------------------------------------

class TargetDomainArrays(NamedTuple):
    """Device-resident target-side inputs to the fixpoint engine.

    Built once per target (:func:`target_domain_arrays`) and shared by every
    pattern in a batch; the session layer caches it per index."""

    adj_flat: "jnp.ndarray"  # [n_elab * 2, n_t, w] uint32 (label-major planes)
    labels: "jnp.ndarray"  # [n_t] int32
    deg_out: "jnp.ndarray"  # [n_t] int32
    deg_in: "jnp.ndarray"  # [n_t] int32
    loop_bits: "jnp.ndarray"  # [n_elab, w] uint32 self-loop diagonals


class CsrTargetDomainArrays(NamedTuple):
    """CSR-layout target-side inputs to the **same** fixpoint engine
    (DESIGN.md §11) — the sparse twin of :class:`TargetDomainArrays`.

    ``seg_start[p, t] / seg_len[p, t]`` bound target node ``t``'s neighbor
    segment of plane ``p = elab * 2 + dir`` inside the flat ``indices``
    array (`repro.core.graph.CsrPlanes`, global offsets); ``indices`` is
    sentinel-tailed and over-padded by ``deg_cap`` so kernel segment slices
    never clamp.  ``seg_iota`` is a ``[deg_cap]`` iota whose *shape* carries
    the static ``deg_cap`` through jit.  Peak footprint is
    ``O(nnz + n_planes · n_t)`` words vs the dense form's
    ``n_elab · 2 · n_t · w`` — the whole point of the CSR path."""

    seg_start: "jnp.ndarray"  # [n_planes, n_t] int32 global segment offsets
    seg_len: "jnp.ndarray"  # [n_planes, n_t] int32 row lengths
    indices: "jnp.ndarray"  # [n_idx] int32 flat CSR columns (sentinel tail)
    seg_iota: "jnp.ndarray"  # [deg_cap] int32 (shape = static deg_cap)
    labels: "jnp.ndarray"  # [n_t] int32
    deg_out: "jnp.ndarray"  # [n_t] int32
    deg_in: "jnp.ndarray"  # [n_t] int32
    loop_bits: "jnp.ndarray"  # [n_elab, w] uint32 self-loop diagonals


class PatternDomainArrays(NamedTuple):
    """Per-pattern padded inputs to the fixpoint engine (host numpy).

    Shapes ``[p_pad] / [a_pad] / [l_pad]`` define the compile bucket; invalid
    slots are neutral (``valid == False``)."""

    labels: np.ndarray  # [p_pad] int32 (-1 pad: matches no target label)
    deg_out: np.ndarray  # [p_pad] int32
    deg_in: np.ndarray  # [p_pad] int32
    valid: np.ndarray  # [p_pad] bool
    arc_p: np.ndarray  # [a_pad] int32
    arc_q: np.ndarray  # [a_pad] int32
    arc_dir: np.ndarray  # [a_pad] int32
    arc_lab: np.ndarray  # [a_pad] int32
    arc_valid: np.ndarray  # [a_pad] bool
    loop_p: np.ndarray  # [l_pad] int32
    loop_lab: np.ndarray  # [l_pad] int32
    loop_valid: np.ndarray  # [l_pad] bool


def target_domain_arrays(target: PackedGraph) -> TargetDomainArrays:
    """Ship a packed target to the device for domain preprocessing."""
    import jax.numpy as jnp

    ne = target.n_edge_labels
    return TargetDomainArrays(
        adj_flat=jnp.asarray(
            target.adj_bits.reshape(ne * 2, target.n, target.w), jnp.uint32
        ),
        labels=jnp.asarray(target.labels, jnp.int32),
        deg_out=jnp.asarray(target.deg_out, jnp.int32),
        deg_in=jnp.asarray(target.deg_in, jnp.int32),
        loop_bits=jnp.asarray(target_self_loop_bits(target), jnp.uint32),
    )


def csr_target_domain_arrays(
    target: Graph,
    w: int,
    planes=None,  # Optional[repro.core.graph.CsrPlanes]
) -> CsrTargetDomainArrays:
    """Ship a host :class:`Graph`'s CSR planes to the device for sparse
    domain preprocessing — the :func:`target_domain_arrays` twin that never
    materializes dense adjacency bitmaps (DESIGN.md §11).

    Padding (``deg_cap`` up to a multiple of 8, ``nnz`` up to 1024-multiples,
    plus a ``deg_cap`` sentinel tail) matches
    `repro.core.extend.make_csr_plan_arrays` so domain preprocessing and the
    CSR step backend share shape buckets."""
    import jax.numpy as jnp

    from repro.core.extend import CSR_SENTINEL, _pad_deg_cap, _pad_nnz

    if planes is None:
        planes = target.csr_planes(target.n_edge_labels)
    indptr = np.asarray(planes.indptr)
    seg_start = np.ascontiguousarray(indptr[:, :-1]).astype(np.int32)
    seg_len = np.diff(indptr, axis=1).astype(np.int32)
    deg_cap = _pad_deg_cap(int(planes.deg_cap))
    nnz = int(planes.nnz)
    n_idx = _pad_nnz(nnz) + deg_cap
    indices = np.full(n_idx, CSR_SENTINEL, np.int32)
    indices[:nnz] = np.asarray(planes.indices)

    n_elab = planes.n_edge_labels
    loop_mask = target.src == target.dst
    loop_bits = np.zeros((n_elab, w), dtype=np.uint32)
    for l in range(n_elab):
        idx = target.src[loop_mask & (target.edge_labels == l)]
        if idx.size:
            loop_bits[l] = bitmap_from_indices(idx, target.n, w)

    return CsrTargetDomainArrays(
        seg_start=jnp.asarray(seg_start),
        seg_len=jnp.asarray(seg_len),
        indices=jnp.asarray(indices),
        seg_iota=jnp.arange(deg_cap, dtype=jnp.int32),
        labels=jnp.asarray(target.labels, jnp.int32),
        deg_out=jnp.asarray(target.out_degrees(), jnp.int32),
        deg_in=jnp.asarray(target.in_degrees(), jnp.int32),
        loop_bits=jnp.asarray(loop_bits),
    )


def pattern_domain_arrays(
    pattern: Graph,
    p_pad: Optional[int] = None,
    arc_pad: Optional[int] = None,
    loop_pad: Optional[int] = None,
) -> PatternDomainArrays:
    """Pad a pattern's unary + binary constraints into a compile bucket."""
    arcs = _pattern_arcs(pattern)
    loops = _self_loops(pattern)
    n_p, n_a, n_l = pattern.n, arcs.shape[0], len(loops)
    p_pad = max(p_pad or n_p, n_p, 1)
    a_pad = max(arc_pad or n_a, n_a, 1)
    l_pad = max(loop_pad or n_l, n_l, 1)

    labels = np.full(p_pad, -1, dtype=np.int32)
    labels[:n_p] = pattern.labels
    deg_out = np.zeros(p_pad, dtype=np.int32)
    deg_out[:n_p] = pattern.out_degrees()
    deg_in = np.zeros(p_pad, dtype=np.int32)
    deg_in[:n_p] = pattern.in_degrees()
    valid = np.zeros(p_pad, dtype=bool)
    valid[:n_p] = True

    arc = np.zeros((a_pad, 4), dtype=np.int32)
    arc[:n_a] = arcs
    arc_valid = np.zeros(a_pad, dtype=bool)
    arc_valid[:n_a] = True

    loop_p = np.zeros(l_pad, dtype=np.int32)
    loop_lab = np.zeros(l_pad, dtype=np.int32)
    loop_valid = np.zeros(l_pad, dtype=bool)
    for j, (p, l) in enumerate(loops):
        loop_p[j], loop_lab[j], loop_valid[j] = p, l, True

    return PatternDomainArrays(
        labels=labels, deg_out=deg_out, deg_in=deg_in, valid=valid,
        arc_p=arc[:, 0], arc_q=arc[:, 1], arc_dir=arc[:, 2], arc_lab=arc[:, 3],
        arc_valid=arc_valid,
        loop_p=loop_p, loop_lab=loop_lab, loop_valid=loop_valid,
    )


def domain_bucket(pattern: Graph) -> Tuple[int, int, int]:
    """Un-padded bucket dimensions ``(n_p, n_arcs, n_loops)`` of a pattern
    (the session snaps each up to its shape bucket)."""
    n_loops = int(np.sum(pattern.src == pattern.dst))
    return pattern.n, 2 * (pattern.m - n_loops), n_loops


# Pallas routing modes for the device engine (DESIGN.md §5):
#   "off"     — pure-jnp reductions (kernels/ref.py oracles);
#   "sweep"   — one scalar-prefetched `arc_any_sweep` kernel call per AC
#               sweep (single-query path);
#   "per-arc" — `adjacency_any` / `popcount_rows` per arc, which (unlike the
#               scalar-prefetch sweep kernel) compose with vmap for the
#               batched path.
PALLAS_MODES = ("off", "sweep", "per-arc")


def _device_fixpoint(
    use_ac: bool,
    use_fc: bool,
    interleave: bool,
    pallas_mode: str,
    max_iters: Optional[int],
    tgt: TargetDomainArrays,
    pat: PatternDomainArrays,
):
    """Jitted AC ⇄ FC fixpoint over one (padded) pattern.

    Returns ``(bits [p_pad, w] uint32, satisfiable bool)``; bits are zeroed
    when unsatisfiable (the :class:`DomainResult` invariant, on device).
    All control flow is static except the ``lax.while_loop`` fixpoint
    iteration; the function vmaps over a pattern batch (``pat`` axis 0).

    ``tgt`` selects the layout: a :class:`TargetDomainArrays` sweeps dense
    adjacency planes, a :class:`CsrTargetDomainArrays` walks CSR segments
    (DESIGN.md §11) — only the arc-support mask differs; the initial
    domains, loop/overflow unsat rules, FC step, and fixpoint loops are the
    same traced code.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.kernels import ref as kref

    use_pallas = pallas_mode != "off"
    if use_pallas:
        from repro.kernels import ops as kops

    is_csr = isinstance(tgt, CsrTargetDomainArrays)
    if is_csr:
        n_elab, w = tgt.loop_bits.shape
        n_t = tgt.labels.shape[0]
        deg_cap = tgt.seg_iota.shape[0]
    else:
        n_planes, n_t, w = tgt.adj_flat.shape
        n_elab = n_planes // 2
    p_pad = pat.labels.shape[0]
    a_pad = pat.arc_p.shape[0]
    l_pad = pat.loop_p.shape[0]
    ones_row = jnp.full((w,), jnp.uint32(0xFFFFFFFF))
    zeros_row = jnp.zeros((w,), jnp.uint32)

    def pop_rows(bits):  # [n, w] -> [n]
        if use_pallas:
            return kops.popcount_rows(bits)
        return kref.popcount_rows_ref(bits)

    # ---- initial domains: label + degree + self-loop dominance ------------
    flags = (
        (tgt.labels[None, :] == pat.labels[:, None])
        & (tgt.deg_out[None, :] >= pat.deg_out[:, None])
        & (tgt.deg_in[None, :] >= pat.deg_in[:, None])
        & pat.valid[:, None]
    )  # [p_pad, n_t]
    bits = jax.vmap(kref.pack_bits_ref, (0, None))(flags.astype(jnp.int32), w)

    def apply_loop(j, b):
        lab = pat.loop_lab[j]
        m = tgt.loop_bits[jnp.clip(lab, 0, n_elab - 1)]
        m = jnp.where(lab < n_elab, m, zeros_row)  # overflow: no loop matches
        m = jnp.where(pat.loop_valid[j], m, ones_row)  # pad slot: no-op
        p = pat.loop_p[j]
        return b.at[p].set(b[p] & m)

    bits = lax.fori_loop(0, l_pad, apply_loop, bits)

    # label overflow on any constraint (arc or loop) ⇒ unsatisfiable in every
    # variant, matching `compute_domains` (the engine would otherwise gather
    # a clamped — wrong — adjacency plane).
    overflow = jnp.any(pat.arc_valid & (pat.arc_lab >= n_elab)) | jnp.any(
        pat.loop_valid & (pat.loop_lab >= n_elab)
    )
    empty0 = jnp.any(pat.valid & (pop_rows(bits) == 0))
    unsat = overflow | empty0

    # ---- one AC sweep: all arcs at once (Jacobi) ---------------------------
    arc_row = jnp.clip(pat.arc_lab, 0, n_elab - 1) * 2 + pat.arc_dir  # [a_pad]
    arc_dead = pat.arc_valid & (pat.arc_lab >= n_elab)

    def arc_masks_jnp(bits):
        def one(a):
            rows = tgt.adj_flat[arc_row[a]]  # [n_t, w]
            if pallas_mode == "per-arc":
                ok = kops.adjacency_any(rows, bits[pat.arc_q[a]])
            else:
                ok = kref.adjacency_any_ref(rows, bits[pat.arc_q[a]])
            return kref.pack_bits_ref(ok, w)

        return lax.map(one, jnp.arange(a_pad))  # [a_pad, w]

    def arc_masks_pallas(bits):
        ok = kops.arc_any_sweep(tgt.adj_flat, arc_row, bits[pat.arc_q])
        return jax.vmap(kref.pack_bits_ref, (0, None))(ok, w)

    def arc_masks_csr_jnp(bits):
        # the oracle doubles as the (vmappable) jnp compute path; "per-arc"
        # has no CSR kernel, so it lands here too.
        ok = kref.csr_arc_sweep_ref(
            tgt.seg_start, tgt.seg_len, tgt.indices, arc_row,
            bits[pat.arc_q], deg_cap=deg_cap,
        )
        return jax.vmap(kref.pack_bits_ref, (0, None))(ok, w)

    def arc_masks_csr_pallas(bits):
        ok = kops.csr_arc_sweep(
            tgt.seg_start, tgt.seg_len, tgt.indices, arc_row,
            bits[pat.arc_q], deg_cap=deg_cap,
        )
        return jax.vmap(kref.pack_bits_ref, (0, None))(ok, w)

    if is_csr:
        arc_masks = (
            arc_masks_csr_pallas if pallas_mode == "sweep" else arc_masks_csr_jnp
        )
    else:
        arc_masks = arc_masks_pallas if pallas_mode == "sweep" else arc_masks_jnp

    def ac_sweep(bits):
        masks = arc_masks(bits)
        # neutralize pad slots, kill overflow arcs, then AND per pattern node
        masks = jnp.where(pat.arc_valid[:, None], masks, ones_row[None, :])
        masks = jnp.where(arc_dead[:, None], zeros_row[None, :], masks)

        def comb(a, allowed):
            p = pat.arc_p[a]
            return allowed.at[p].set(allowed[p] & masks[a])

        allowed = lax.fori_loop(
            0, a_pad, comb, jnp.broadcast_to(ones_row, (p_pad, w)).astype(jnp.uint32)
        )
        return bits & allowed, jnp.asarray(False)

    # ---- one FC step: all singletons at once -------------------------------
    def fc_step(bits):
        sizes = pop_rows(bits)
        single = (sizes == 1) & pat.valid
        sel = jnp.where(single[:, None], bits, jnp.uint32(0))
        union = lax.reduce(sel, jnp.uint32(0), lax.bitwise_or, (0,))  # [w]
        # collision: two singletons share a target ⇔ OR loses a bit
        collide = jnp.sum(jnp.where(single, sizes, 0)) > jnp.sum(
            lax.population_count(union)
        )
        new = jnp.where(single[:, None], bits, bits & ~union[None, :])
        return new, collide

    # ---- fixpoint loops ----------------------------------------------------
    mi = max_iters if max_iters is not None else p_pad * w * WORD_BITS + 2

    def run_loop(step, bits, unsat):
        def cond(c):
            b, u, changed, it = c
            return changed & ~u & (it < mi)

        def body(c):
            b, u, _, it = c
            nb, step_unsat = step(b)
            u2 = u | step_unsat | jnp.any(pat.valid & (pop_rows(nb) == 0))
            return nb, u2, jnp.any(nb != b), it + 1

        bits, unsat, _, _ = lax.while_loop(
            cond, body, (bits, unsat, jnp.asarray(True), jnp.asarray(0))
        )
        return bits, unsat

    if use_ac and use_fc and interleave:
        def both(b):
            b1, u1 = ac_sweep(b)
            b2, u2 = fc_step(b1)
            return b2, u1 | u2

        bits, unsat = run_loop(both, bits, unsat)
    else:
        if use_ac:
            bits, unsat = run_loop(ac_sweep, bits, unsat)
        if use_fc:
            bits, unsat = run_loop(fc_step, bits, unsat)

    bits = jnp.where(unsat, jnp.uint32(0), bits)
    return bits, ~unsat


@functools.lru_cache(maxsize=None)
def device_fixpoint(
    use_ac: bool = True,
    use_fc: bool = False,
    interleave: bool = False,
    pallas_mode: str = "off",
    max_iters: Optional[int] = None,
    batched: bool = False,
):
    """The jitted device fixpoint ``(TargetDomainArrays, PatternDomainArrays)
    -> (bits, satisfiable)`` for one static flag combination.

    ``batched=True`` vmaps over a leading pattern-batch axis (target arrays
    broadcast).  Cached per flag tuple; XLA adds per-shape caching below.
    """
    import jax

    if pallas_mode not in PALLAS_MODES:
        raise ValueError(f"pallas_mode {pallas_mode!r} not in {PALLAS_MODES}")
    if batched and pallas_mode == "sweep":
        # the scalar-prefetch sweep kernel has no vmap batching rule; the
        # per-arc kernels do (DESIGN.md §5).
        raise ValueError("pallas_mode='sweep' does not compose with batching; "
                         "use 'per-arc'")
    fn = functools.partial(
        _device_fixpoint, use_ac, use_fc, interleave, pallas_mode, max_iters
    )
    if batched:
        fn = jax.vmap(fn, in_axes=(None, 0))
    return jax.jit(fn)


def _to_device(pat: PatternDomainArrays):
    import jax.numpy as jnp

    return PatternDomainArrays(*(jnp.asarray(x) for x in pat))


def compute_domains_device(
    pattern: Graph,
    target: PackedGraph,
    use_ac: bool = True,
    use_fc: bool = False,
    interleave: bool = False,
    use_pallas: bool = False,
    ac_iters: Optional[int] = None,
    tgt_arrays: Optional[TargetDomainArrays] = None,
) -> DomainResult:
    """Single-query device preprocessing; bit-identical to
    :func:`compute_domains` with the same flags (property-tested) **when run
    to convergence** (``ac_iters=None``, the default).  A finite ``ac_iters``
    bounds *Jacobi whole-sweeps* here but *Gauss-Seidel passes* (each arc
    applied against already-updated domains) in the numpy oracle, so
    truncated runs may differ — both remain sound over-approximations of
    the fixpoint."""
    import jax
    import numpy as _np

    tgt = tgt_arrays if tgt_arrays is not None else target_domain_arrays(target)
    pat = _to_device(pattern_domain_arrays(pattern))
    fn = device_fixpoint(
        use_ac=use_ac, use_fc=use_fc, interleave=interleave,
        pallas_mode="sweep" if use_pallas else "off",
        max_iters=ac_iters, batched=False,
    )
    bits, sat = jax.block_until_ready(fn(tgt, pat))
    return DomainResult(_np.asarray(bits)[: pattern.n].copy(), bool(sat))


def compute_domains_csr(
    pattern: Graph,
    target: Graph,
    w: int,
    use_ac: bool = True,
    use_fc: bool = False,
    interleave: bool = False,
    use_pallas: bool = False,
    ac_iters: Optional[int] = None,
    tgt_arrays: Optional[CsrTargetDomainArrays] = None,
) -> DomainResult:
    """Single-query CSR-native device preprocessing (DESIGN.md §11):
    :func:`compute_domains_device` without a :class:`PackedGraph` — the AC
    sweeps walk `CsrPlanes` segments, so dense adjacency bitmaps are never
    materialized.  Bit-identical to :func:`compute_domains` on the packed
    form of the same target with the same flags when run to convergence
    (``ac_iters=None``; finite ``ac_iters`` bounds Jacobi whole-sweeps, as
    in the dense engine).  ``use_pallas`` routes each sweep through the
    scalar-prefetch `csr_arc_sweep` kernel."""
    import jax
    import numpy as _np

    tgt = (
        tgt_arrays if tgt_arrays is not None
        else csr_target_domain_arrays(target, w)
    )
    pat = _to_device(pattern_domain_arrays(pattern))
    fn = device_fixpoint(
        use_ac=use_ac, use_fc=use_fc, interleave=interleave,
        pallas_mode="sweep" if use_pallas else "off",
        max_iters=ac_iters, batched=False,
    )
    bits, sat = jax.block_until_ready(fn(tgt, pat))
    return DomainResult(_np.asarray(bits)[: pattern.n].copy(), bool(sat))


def compute_domains_batch(
    patterns: Sequence[Graph],
    target: PackedGraph,
    use_ac: bool = True,
    use_fc: bool = False,
    interleave: bool = False,
    use_pallas: bool = False,
    p_pad: Optional[int] = None,
    arc_pad: Optional[int] = None,
    loop_pad: Optional[int] = None,
    batch_pad: Optional[int] = None,
    tgt_arrays: Optional[TargetDomainArrays] = None,
    fn: Optional[callable] = None,
) -> List[DomainResult]:
    """Batched device preprocessing: one vmapped fixpoint call for a padded
    pattern batch (the ``Enumerator.prepare_batch`` backend, DESIGN.md §5).

    All patterns share one compile bucket ``(p_pad, arc_pad, loop_pad,
    batch_pad)``; unspecified pads snap to the batch maxima.  ``batch_pad``
    lanes beyond ``len(patterns)`` replicate lane 0 and are discarded.
    ``fn`` overrides the jitted batched fixpoint (the session passes its
    cached one); it must have been built with matching flags.
    """
    import jax
    import jax.numpy as jnp
    import numpy as _np

    patterns = list(patterns)
    if not patterns:
        return []
    dims = [domain_bucket(p) for p in patterns]
    p_pad = p_pad or max(d[0] for d in dims)
    arc_pad = arc_pad or max(d[1] for d in dims)
    loop_pad = loop_pad or max(d[2] for d in dims)
    arrs = [
        pattern_domain_arrays(p, p_pad=p_pad, arc_pad=arc_pad, loop_pad=loop_pad)
        for p in patterns
    ]
    b_pad = max(batch_pad or len(arrs), len(arrs))
    arrs = arrs + [arrs[0]] * (b_pad - len(arrs))
    stacked = PatternDomainArrays(
        *(jnp.asarray(_np.stack(cols)) for cols in zip(*arrs))
    )
    tgt = tgt_arrays if tgt_arrays is not None else target_domain_arrays(target)
    if fn is None:
        fn = device_fixpoint(
            use_ac=use_ac, use_fc=use_fc, interleave=interleave,
            pallas_mode="per-arc" if use_pallas else "off",
            max_iters=None, batched=True,
        )
    bits, sat = jax.block_until_ready(fn(tgt, stacked))
    bits = _np.asarray(bits)
    sat = _np.asarray(sat)
    return [
        DomainResult(bits[i, : p.n].copy(), bool(sat[i]))
        for i, p in enumerate(patterns)
    ]
