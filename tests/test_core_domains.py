"""Soundness of the preprocessing prunings (domains, AC, FC, orderings).

The invariant behind every pruning in the paper: no pruning may remove a
target node from a domain if that node participates in a true match at that
pattern position.  Verified against brute-force enumeration of all matches.
"""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import domains as dom_mod
from repro.core import ordering as ord_mod
from repro.core.graph import Graph, PackedGraph, bitmap_to_indices, popcount
from repro.core.ref import ref_enumerate
from tests.conftest import bump_edge_label, extract_connected_pattern, random_graph

# (use_ac, use_fc, interleave) triples covering all pipeline modes incl. the
# AC ⇄ FC joint fixpoint (variant ri-ds-si-acfc)
PIPELINES = [(False, False, False), (True, False, False), (True, True, False),
             (True, True, True)]


def all_matches(pattern, target):
    """All match mappings (pattern node -> target node), via the oracle."""
    res = ref_enumerate(pattern, target, variant="ri", record_mappings=True)
    from repro.core.plan import build_plan

    plan = build_plan(pattern, PackedGraph.from_graph(target), variant="ri")
    # mappings are in order-position space; convert to pattern-node space
    out = []
    for m in res.mappings:
        node_map = {}
        for pos, t in enumerate(m):
            node_map[int(plan.order[pos])] = t
        out.append(node_map)
    return out


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), selfloops=st.integers(0, 3))
def test_domain_pipeline_soundness(seed, selfloops):
    rng = np.random.default_rng(seed)
    tgt = random_graph(rng, 12, 26, n_labels=2, selfloops=selfloops)
    pat = extract_connected_pattern(rng, tgt, 3)
    if pat.m == 0:
        return
    packed = PackedGraph.from_graph(tgt)
    matches = all_matches(pat, tgt)
    for use_ac, use_fc, interleave in PIPELINES:
        res = dom_mod.compute_domains(
            pat, packed, use_ac=use_ac, use_fc=use_fc, interleave=interleave
        )
        if matches:
            assert res.satisfiable
            for m in matches:
                for p, t in m.items():
                    dom = set(bitmap_to_indices(res.bits[p]).tolist())
                    assert t in dom, (
                        f"pruning removed true-match node {t} from D({p}) "
                        f"(ac={use_ac}, fc={use_fc}, acfc={interleave})"
                    )


def test_ac_reduces_domains():
    # path pattern in a star target: leaves can't host the middle node
    tgt = Graph.from_edges(5, [(0, 1), (0, 2), (0, 3), (0, 4)], undirected=True)
    pat = Graph.from_edges(3, [(0, 1), (1, 2)], undirected=True)
    packed = PackedGraph.from_graph(tgt)
    d0 = dom_mod.initial_domains(pat, packed)
    dac = dom_mod.arc_consistency(pat, packed, d0)
    assert dac.satisfiable
    assert popcount(dac.bits).sum() <= popcount(d0).sum()
    # middle pattern node (degree 2) can only map to the hub
    mid = int(np.argmax(pat.degrees()))
    assert bitmap_to_indices(dac.bits[mid]).tolist() == [0]


def test_fc_removes_singleton_targets():
    bits = np.zeros((3, 1), dtype=np.uint32)
    bits[0, 0] = 0b001  # singleton {0}
    bits[1, 0] = 0b011  # {0,1}
    bits[2, 0] = 0b111  # {0,1,2}
    res = dom_mod.forward_check_singletons(bits)
    assert res.satisfiable
    assert res.bits[0, 0] == 0b001
    assert res.bits[1, 0] == 0b010  # 0 removed -> singleton {1}
    assert res.bits[2, 0] == 0b100  # 0 and 1 removed


def test_fc_detects_collision():
    bits = np.zeros((2, 1), dtype=np.uint32)
    bits[0, 0] = 0b01
    bits[1, 0] = 0b01  # same singleton target
    res = dom_mod.forward_check_singletons(bits)
    assert not res.satisfiable


def test_ordering_properties(rng):
    tgt = random_graph(rng, 20, 50, n_labels=2)
    pat = extract_connected_pattern(rng, tgt, 5)
    if pat.m == 0:
        pytest.skip("empty pattern")
    ordering = ord_mod.greatest_constraint_first(pat)
    # permutation of all pattern nodes
    assert sorted(ordering.order.tolist()) == list(range(pat.n))
    # every non-root position of a connected pattern has >= 1 parent
    for i in range(1, ordering.n):
        assert len(ordering.parents[i]) >= 1
    # parents reference earlier positions only
    for i, plist in enumerate(ordering.parents):
        for (j, d, l) in plist:
            assert 0 <= j < i
    # parent constraints cover every pattern edge exactly once
    n_constraints = sum(len(p) for p in ordering.parents)
    n_nonloop = sum(1 for u, v in zip(pat.src, pat.dst) if u != v)
    assert n_constraints == n_nonloop


def test_si_tiebreak_prefers_small_domain():
    # two symmetric candidates; domain sizes break the tie
    pat = Graph.from_edges(3, [(0, 1), (0, 2)], undirected=True)
    sizes = np.array([5, 7, 2])
    ordering = ord_mod.greatest_constraint_first(pat, domain_sizes=sizes)
    # node 0 has max degree; between 1 and 2 (tied w_m, w_n, deg), node 2
    # (smaller domain) must come first
    assert ordering.order.tolist() == [0, 2, 1]
    ordering_plain = ord_mod.greatest_constraint_first(pat)
    assert ordering_plain.order.tolist() == [0, 1, 2]  # id tie-break


def test_singleton_first_placement():
    pat = Graph.from_edges(3, [(0, 1), (1, 2)], undirected=True)
    sizes = np.array([4, 4, 1])
    ordering = ord_mod.greatest_constraint_first(
        pat, domain_sizes=sizes, singleton_first=True
    )
    assert ordering.order[0] == 2


# ---------------------------------------------------------------------------
# device engine == numpy oracle (DESIGN.md §5)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    selfloops=st.integers(0, 3),
    n_elabs=st.integers(1, 2),
    overflow=st.booleans(),
)
def test_device_fixpoint_matches_numpy(seed, selfloops, n_elabs, overflow):
    """The jitted fixpoint engine must be bit-identical to the numpy oracle
    for every pipeline mode, including self-loops and overflow labels."""
    rng = np.random.default_rng(seed)
    tgt = random_graph(rng, 12, 24, n_labels=2, n_elabs=n_elabs,
                       selfloops=selfloops)
    pat = extract_connected_pattern(rng, tgt, 3)
    if pat.m == 0:
        return
    if overflow:
        pat = bump_edge_label(pat, int(rng.integers(pat.m)), n_elabs + 3)
    packed = PackedGraph.from_graph(tgt)
    for use_ac, use_fc, interleave in PIPELINES:
        a = dom_mod.compute_domains(
            pat, packed, use_ac=use_ac, use_fc=use_fc, interleave=interleave
        )
        b = dom_mod.compute_domains_device(
            pat, packed, use_ac=use_ac, use_fc=use_fc, interleave=interleave
        )
        assert a.satisfiable == b.satisfiable, (use_ac, use_fc, interleave)
        np.testing.assert_array_equal(a.bits, b.bits)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    selfloops=st.integers(0, 3),
    n_elabs=st.integers(1, 2),
    overflow=st.booleans(),
)
def test_sparse_domains_match_dense(seed, selfloops, n_elabs, overflow):
    """The CSR-native pipeline (``compute_domains_sparse``: host initial
    domains + the CSR-segment device fixpoint, dense bitmaps never built)
    equals the dense numpy oracle bit for bit in every pipeline mode
    (DESIGN.md §11)."""
    from repro.core.graph import n_words

    rng = np.random.default_rng(seed)
    tgt = random_graph(rng, 12, 24, n_labels=2, n_elabs=n_elabs,
                       selfloops=selfloops)
    pat = extract_connected_pattern(rng, tgt, 3)
    if pat.m == 0:
        return
    if overflow:
        pat = bump_edge_label(pat, int(rng.integers(pat.m)), n_elabs + 3)
    packed = PackedGraph.from_graph(tgt)
    w = n_words(tgt.n)
    for use_ac, use_fc, interleave in PIPELINES:
        a = dom_mod.compute_domains(
            pat, packed, use_ac=use_ac, use_fc=use_fc, interleave=interleave
        )
        b = dom_mod.compute_domains_sparse(
            pat, tgt, w, use_ac=use_ac, use_fc=use_fc, interleave=interleave
        )
        assert a.satisfiable == b.satisfiable, (use_ac, use_fc, interleave)
        np.testing.assert_array_equal(a.bits, b.bits)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_device_batch_matches_numpy(seed):
    """One vmapped call over a padded pattern batch == per-query oracle."""
    rng = np.random.default_rng(seed)
    tgt = random_graph(rng, 14, 30, n_labels=2, selfloops=2)
    pats = []
    while len(pats) < 5:
        p = extract_connected_pattern(rng, tgt, int(rng.integers(2, 5)))
        if p.m:
            pats.append(p)
    packed = PackedGraph.from_graph(tgt)
    outs = dom_mod.compute_domains_batch(
        pats, packed, use_ac=True, use_fc=True, interleave=True, batch_pad=8
    )
    for p, o in zip(pats, outs):
        a = dom_mod.compute_domains(p, packed, use_ac=True, use_fc=True,
                                    interleave=True)
        assert a.satisfiable == o.satisfiable
        np.testing.assert_array_equal(a.bits, o.bits)


# ---------------------------------------------------------------------------
# AC ⇄ FC joint fixpoint: never coarser than AC → FC
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), selfloops=st.integers(0, 2))
def test_acfc_domains_subset_of_ac_fc(seed, selfloops):
    """Joint-fixpoint domains are a subset of the sequential AC → FC pass
    (never larger — the paper's 'reachable prunings left on the table')."""
    rng = np.random.default_rng(seed)
    tgt = random_graph(rng, 12, 26, n_labels=2, selfloops=selfloops)
    pat = extract_connected_pattern(rng, tgt, 4)
    if pat.m == 0:
        return
    packed = PackedGraph.from_graph(tgt)
    seq = dom_mod.compute_domains(pat, packed, use_ac=True, use_fc=True)
    joint = dom_mod.compute_domains(pat, packed, use_ac=True, use_fc=True,
                                    interleave=True)
    if not seq.satisfiable:
        assert not joint.satisfiable
        return
    if joint.satisfiable:
        assert not np.any(joint.bits & ~seq.bits)  # subset, bitwise
        assert popcount(joint.bits).sum() <= popcount(seq.bits).sum()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), selfloops=st.integers(0, 2))
def test_acfc_states_never_increase(seed, selfloops):
    """Search states explored under ri-ds-si-acfc never exceed ri-ds-si-fc
    (for the same node ordering), and matches are always identical.

    When the tighter acfc domains flip the SI ordering tie-break the search
    trees are not comparable position-by-position, so the state bound is
    asserted only when both variants pick the same ordering (the common
    case); match-count equality is unconditional."""
    from repro.core.plan import build_plan

    rng = np.random.default_rng(seed)
    tgt = random_graph(rng, 12, 26, n_labels=2, selfloops=selfloops)
    pat = extract_connected_pattern(rng, tgt, 4)
    if pat.m == 0:
        return
    fc = ref_enumerate(pat, tgt, variant="ri-ds-si-fc")
    acfc = ref_enumerate(pat, tgt, variant="ri-ds-si-acfc")
    assert acfc.matches == fc.matches
    packed = PackedGraph.from_graph(tgt)
    p_fc = build_plan(pat, packed, variant="ri-ds-si-fc")
    p_acfc = build_plan(pat, packed, variant="ri-ds-si-acfc")
    if p_fc.order.tolist() == p_acfc.order.tolist():
        assert acfc.states <= fc.states
