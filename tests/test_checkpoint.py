"""Checkpoint store: atomic save/restore, GC, async, elastic reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.train import optimizer as opt_mod


def _params():
    return {"w": jnp.arange(6.0).reshape(2, 3), "b": {"x": jnp.ones(4)}}


def test_save_restore_roundtrip(tmp_path):
    base = str(tmp_path / "ck")
    params = _params()
    opt = opt_mod.init(params)
    store.save(base, 7, params, opt)
    step, p2, o2 = store.restore(base, 7, like_params=params, like_opt=opt)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))
    np.testing.assert_array_equal(np.asarray(o2.mu["b"]["x"]),
                                  np.asarray(opt.mu["b"]["x"]))


def test_restore_latest_and_gc(tmp_path):
    base = str(tmp_path / "ck")
    params = _params()
    for s in (1, 2, 3, 4):
        store.save(base, s, params, keep=2)
    assert store.list_steps(base) == [3, 4]
    step, p2, _ = store.restore_latest(base, like_params=params)
    assert step == 4


def test_async_save(tmp_path):
    base = str(tmp_path / "ck")
    params = _params()
    store.save(base, 1, params, async_write=True)
    store.wait_for_writes()
    assert store.list_steps(base) == [1]


def test_aborted_write_ignored(tmp_path):
    base = str(tmp_path / "ck")
    params = _params()
    store.save(base, 1, params)
    # simulate crash: step dir without manifest
    broken = os.path.join(base, "step_00000009")
    os.makedirs(broken)
    with open(os.path.join(broken, "arrays.npz"), "wb") as f:
        f.write(b"junk")
    assert store.list_steps(base) == [1]
    assert store.restore_latest(base, like_params=params)[0] == 1


def test_elastic_reshard_roundtrip(tmp_path):
    """Restore onto a (trivially) different mesh via logical axes."""
    from repro.checkpoint.reshard import place
    from repro.launch.mesh import make_local_mesh

    base = str(tmp_path / "ck")
    params = _params()
    store.save(base, 3, params)
    _, host, _ = store.restore(base, 3, like_params=params)
    mesh = make_local_mesh(("data", "model"))
    logical = {"w": ("batch", None), "b": {"x": (None,)}}
    placed = place(host, logical, mesh)
    np.testing.assert_array_equal(np.asarray(placed["w"]), np.asarray(params["w"]))


def test_trainer_restart_from_checkpoint(tmp_path):
    """Kill-and-restart: the loop resumes from the saved step."""
    from repro.train.trainer import LoopConfig, TrainLoop, make_train_step

    cfg = opt_mod.AdamWConfig(lr=0.3, warmup_steps=0, total_steps=20,
                              weight_decay=0.0)

    def loss_fn(params, batch):
        loss = jnp.sum((params["w"] - batch) ** 2)
        return loss, {"loss": loss}

    step_fn = jax.jit(make_train_step(loss_fn, cfg))
    params = {"w": jnp.zeros(3)}
    opt = opt_mod.init(params)
    data = [jnp.asarray([1.0, 2.0, 3.0])] * 40
    ckdir = str(tmp_path / "ck")

    loop1 = TrainLoop(step_fn, LoopConfig(total_steps=10, checkpoint_every=5,
                                          log_every=100), ckpt_dir=ckdir,
                      log=lambda *_: None)
    loop1.run(params, opt, iter(data))
    steps_after_1 = store.list_steps(ckdir)
    assert steps_after_1[-1] == 10

    # "restart": fresh params, loop resumes from step 10's weights
    loop2 = TrainLoop(step_fn, LoopConfig(total_steps=20, checkpoint_every=5,
                                          log_every=100), ckpt_dir=ckdir,
                      log=lambda *_: None)
    msgs = []
    loop2.log = msgs.append
    p2, _, hist = loop2.run(params, opt, iter(data))
    assert any("restored checkpoint at step 10" in m for m in msgs)
    # loss must keep decreasing from the restored point
    assert hist[-1] < hist[0]
    assert hist[-1] < 2.0
