"""Logical-axis sharding: mesh-agnostic PartitionSpecs.

Models annotate tensors with *logical* axis names; this module maps them to
whatever physical mesh is in use.  The production meshes (launch/mesh.py) are
``(data=16, model=16)`` single-pod and ``(pod=2, data=16, model=16)``
multi-pod; smoke tests run on a trivial 1-device mesh where everything maps
to ``None`` (replicated).

Logical axes:
  * ``batch``  — data-parallel batch dim → ``('pod', 'data')``.
  * ``fsdp``   — ZeRO-3/FSDP parameter dim (all-gathered on use)
                 → ``('pod', 'data')``.
  * ``tensor`` — tensor-parallel dim (heads / d_ff / vocab / experts / bitmap
                 words) → ``'model'``.
  * ``expert`` — expert-parallel dim → ``'model'``.
  * ``seq``    — sequence dim (KV-cache length in decode) → ``'model'``.
  * ``edge``   — GNN edge dim → ``('pod', 'data', 'model')`` (flattened).
  * ``worker`` — SGE worker dim → ``('pod', 'data')``.
  * ``query``  — independent SGE query dim → ``'pod'``.

Divisibility: an axis mapping is applied only if the dim size is divisible by
the mapped mesh-axis product; otherwise the dim is replicated.  That keeps
every (arch × shape × mesh) cell compilable without per-arch exceptions —
GSPMD would otherwise reject uneven shardings at lowering time.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalAxis = Optional[str]

_DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "tensor": ("model",),
    "expert": ("model",),
    "seq": ("model",),
    "edge": ("pod", "data", "model"),
    "worker": ("pod", "data"),
    "query": ("pod",),
    None: (),
}


def _mesh_axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) if axes else 1


def logical_to_pspec(
    logical: Sequence[LogicalAxis],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[dict] = None,
) -> P:
    """Map per-dim logical axis names to a PartitionSpec for ``mesh``.

    Drops mappings whose mesh axes are absent or whose dim size is not
    divisible by the mesh-axis product (replicates instead).
    """
    rules = rules or _DEFAULT_RULES
    assert len(logical) == len(shape), (logical, shape)
    spec = []
    used: set = set()
    for name, dim in zip(logical, shape):
        axes = tuple(a for a in rules.get(name, ()) if a in mesh.shape and a not in used)
        size = _mesh_axis_size(mesh, axes)
        if axes and size > 1 and dim % size == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            spec.append(None)
    return P(*spec)


def named_sharding(
    logical: Sequence[LogicalAxis],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[dict] = None,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(logical, shape, mesh, rules))


def tree_shardings(logical_tree, abstract_tree, mesh: Mesh, rules: Optional[dict] = None):
    """Zip a pytree of logical-axis tuples with matching ShapeDtypeStructs
    into NamedShardings."""
    return jax.tree.map(
        lambda log, ab: named_sharding(log, ab.shape, mesh, rules),
        logical_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def constraint(x, logical: Sequence[LogicalAxis], mesh: Optional[Mesh] = None):
    """``with_sharding_constraint`` by logical axes (no-op outside a mesh)."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(logical, x.shape, mesh)
    )


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        return None if mesh.empty else mesh
    except Exception:
        return None
