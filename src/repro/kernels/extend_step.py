"""Fused Pallas TPU kernel for the engine's whole expansion step
(DESIGN.md §6.3).

For a batch of ``b`` popped search lanes, one ``pallas_call`` performs
everything ``w``-wide the step needs:

1. **lowest-untried-bit extraction** — find the lowest set bit ``v`` of
   the lane's candidate bitmap, clear it (the parent's residual
   ``cand2``), and form its one-hot mask;
2. **child candidate initialization** — ``dom[pos+1] ∧ ¬used ∧ ¬bit(v)``
   (``used ∨ bit(v)`` is the child's used-set, so its complement is one
   fused AND);
3. **parent-constraint AND-tree** — one grid step per parent slot ANDs the
   flattened adjacency row chosen by the scalar-prefetched ``row_idx``
   table (unused slots point at a neutral all-ones row);
4. **match / child flagging** — at the finalize step, compare depth
   against the pattern size, zero the child bitmap unless a child is
   wanted, and emit per-lane ``(valid, v, is_match, has_child)`` flags the
   driver accumulates into its per-worker counters.

The loose-ops jnp step (`repro.core.extend.JnpStepBackend`) round-trips
each of these phases through HBM; here the lane's bitmaps stay in VMEM
across all ``mp + 2`` grid steps.

TPU mapping
-----------
* Grid ``(b, mp + 2)`` — lane-major: step 0 extracts + initializes, steps
  ``1..mp`` AND one prefetch-indexed adjacency row each, step ``mp + 1``
  finalizes.  Same-lane output blocks keep the same index for every ``j``,
  so the running bitmaps accumulate in VMEM without HBM round-trips
  (the `repro.kernels.candidate_mask` trick, extended to the whole step).
* The adjacency operand's ``index_map`` reads the scalar-prefetched
  ``row_idx`` table — the DMA engine chases the paper's adjacency-list
  pointers while the VPU processes the previous row.  ``row_idx`` is
  computed by the backend *before* launch (scalar prefetch requires it);
  it encodes the freshly mapped ``v`` for parent constraints that
  reference the just-extended position.
* Blocks are ``(1, wp)`` with ``wp = pad_words(w)`` (128-lane multiples);
  per grid step the kernel touches ≤ 5 such rows (cand/used/dom/row +
  out) — ≤ ~23 KB at the largest paper target — far below VMEM, leaving
  the pipeline free to double-buffer row DMAs.

Oracle: `repro.kernels.ref.extend_step_ref` (bit-exact, swept in
``tests/test_extend_step.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.candidate_mask import pad_words

WORD_BITS = 32
META_WIDTH = 4  # (valid, v, is_match, has_child) per lane


def _lowest_bit(c: jnp.ndarray):
    """Lowest set bit of a ``[1, wp]`` uint32 block.

    Returns ``(valid, v, vmask)``: a scalar flag, the global bit index
    (garbage when ``!valid`` — callers gate on ``valid``), and the one-hot
    ``[1, wp]`` mask of the bit (all-zero when ``!valid``).
    """
    nz = c != jnp.uint32(0)
    valid = jnp.any(nz)
    iota = lax.broadcasted_iota(jnp.int32, c.shape, 1)
    widx = jnp.min(jnp.where(nz, iota, c.shape[1]))  # first non-zero word
    sel = iota == widx
    word = jnp.sum(jnp.where(sel, c, jnp.uint32(0)), dtype=jnp.uint32)
    tz = lax.population_count(~word & (word - jnp.uint32(1)))
    v = widx * WORD_BITS + tz.astype(jnp.int32)
    lowbit = word & (~word + jnp.uint32(1))
    vmask = jnp.where(sel, lowbit, jnp.uint32(0))
    return valid, v, vmask


def _kernel(
    cpos_ref, ridx_ref, depth_ref, np_ref,  # scalar prefetch
    cand_ref, used_ref, dom_ref, row_ref,  # operands
    cand2_ref, child_ref, meta_ref,  # outputs
    *, mp: int,
):
    l = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _extract_and_init():
        c = cand_ref[...]
        _valid, _v, vmask = _lowest_bit(c)
        cand2_ref[...] = c ^ vmask
        # child used-set is used ∨ bit(v); its complement fuses into the init
        child_ref[...] = dom_ref[...] & ~used_ref[...] & ~vmask

    @pl.when((j >= 1) & (j <= mp))
    def _and_parent_row():
        child_ref[...] = child_ref[...] & row_ref[...]

    @pl.when(j == mp + 1)
    def _finalize():
        valid, v, _vmask = _lowest_bit(cand_ref[...])
        depth = depth_ref[l]
        n_p = np_ref[0]
        is_match = valid & (depth + 1 >= n_p)
        want_child = valid & jnp.logical_not(is_match)
        child = jnp.where(want_child, child_ref[...], jnp.uint32(0))
        child_ref[...] = child
        has_child = want_child & jnp.any(child != jnp.uint32(0))
        meta_ref[...] = jnp.stack(
            [
                valid.astype(jnp.int32),
                jnp.where(valid, v, -1),
                is_match.astype(jnp.int32),
                has_child.astype(jnp.int32),
            ]
        ).reshape(1, META_WIDTH)


@functools.partial(jax.jit, static_argnames=("interpret",))
def extend_step(
    rows: jnp.ndarray,  # [n_rows + 1, w] uint32, last row all-ones neutral
    dom_bits: jnp.ndarray,  # [p_pad, w] uint32
    child_pos: jnp.ndarray,  # [b] int32 order position of the child
    row_idx: jnp.ndarray,  # [b, mp] int32 (unused slots -> n_rows)
    depth: jnp.ndarray,  # [b] int32 depth of the popped entry
    n_p: jnp.ndarray,  # scalar int32 actual pattern size
    used: jnp.ndarray,  # [b, w] uint32
    cand: jnp.ndarray,  # [b, w] uint32
    interpret: bool = True,
):
    """One fused expansion over ``b`` lanes.

    Returns ``(cand2 [b, w], child_cand [b, w], meta [b, 4] int32)`` with
    ``meta`` columns ``(valid, v, is_match, has_child)``; ``v`` is -1 on
    invalid lanes.  ``interpret=True`` executes the kernel body in Python
    on CPU (the validation mode for this container); on TPU the wrapper in
    `repro.kernels.ops` auto-selects compiled mode.
    """
    b, w = cand.shape
    mp = row_idx.shape[1]
    n_rows = rows.shape[0] - 1
    if mp == 0:  # degenerate plans: keep one neutral parent slot
        row_idx = jnp.full((b, 1), n_rows, jnp.int32)
        mp = 1
    wp = pad_words(w)
    if wp != w:
        padw = ((0, 0), (0, wp - w))
        rows = jnp.pad(rows, padw)
        dom_bits = jnp.pad(dom_bits, padw)
        used = jnp.pad(used, padw)
        cand = jnp.pad(cand, padw)

    grid = (b, mp + 2)

    def lane_map(l, j, cpos_s, ridx_s, depth_s, np_s):
        return (l, 0)

    def dom_map(l, j, cpos_s, ridx_s, depth_s, np_s):
        return (cpos_s[l], 0)

    def row_map(l, j, cpos_s, ridx_s, depth_s, np_s):
        # j == 0 init and j == mp + 1 finalize get the neutral row
        jj = jnp.clip(j - 1, 0, mp - 1)
        take = (j >= 1) & (j <= mp)
        return (jnp.where(take, ridx_s[l, jj], n_rows), 0)

    cand2, child, meta = pl.pallas_call(
        functools.partial(_kernel, mp=mp),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, wp), lane_map),  # cand
                pl.BlockSpec((1, wp), lane_map),  # used
                pl.BlockSpec((1, wp), dom_map),  # dom_bits
                pl.BlockSpec((1, wp), row_map),  # adjacency rows
            ],
            out_specs=[
                pl.BlockSpec((1, wp), lane_map),  # cand2
                pl.BlockSpec((1, wp), lane_map),  # child_cand
                pl.BlockSpec((1, META_WIDTH), lane_map),  # meta
            ],
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, wp), jnp.uint32),
            jax.ShapeDtypeStruct((b, wp), jnp.uint32),
            jax.ShapeDtypeStruct((b, META_WIDTH), jnp.int32),
        ),
        interpret=interpret,
    )(
        child_pos.astype(jnp.int32),
        row_idx.astype(jnp.int32),
        depth.astype(jnp.int32),
        jnp.asarray(n_p, jnp.int32).reshape((1,)),
        cand,
        used,
        dom_bits,
        rows,
    )
    return cand2[:, :w], child[:, :w], meta
