"""Mixture-of-Experts layer: top-k routing with group-local sort-based
dispatch.

TPU-native dispatch (no ragged ops), §Perf iteration 6: tokens are split
into ``dispatch_groups`` contiguous groups aligned with the data shards;
each group sorts ITS OWN (token, expert-choice) pairs and scatters into its
slice of the ``[G, E, C_g, D]`` capacity buffer.  Because scatter indices
never cross a group, GSPMD partitions the scatter trivially along ``G``
(= the ``batch`` axis) and the only cross-device movement left is the
``G×E`` transpose feeding the expert einsum — a true all-to-all.  (The
previous single-group formulation made GSPMD materialize replicated
scatter buffers and all-reduce 240 GB *per layer* on kimi-k2 — see
EXPERIMENTS.md §Perf.)

Per-group capacity also matches large-scale practice (local capacity =
global/G), and the group structure is the MoE echo of the paper's scheduler:
groups are coalesced task batches, capacity plays ``recv_cap`` (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constraint
from repro.models.common import ACTIVATIONS, dot


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    capacity_round: int = 64  # round per-group capacity for shardability
    dispatch_groups: int = 32  # data-shard-aligned dispatch groups (pod×data)
    router_dtype: str = "float32"


def n_groups(cfg: MoEConfig, n_tokens: int) -> int:
    g = cfg.dispatch_groups
    while g > 1 and n_tokens % g:
        g //= 2
    return max(g, 1)


def capacity(cfg: MoEConfig, n_tokens: int) -> int:
    """Per-group expert capacity for ``n_tokens`` *per group*."""
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    r = cfg.capacity_round
    return max(r, ((c + r - 1) // r) * r)


def _dispatch_one_group(n_experts, c, top_e, top_w):
    """Sort-based dispatch within one token group.

    top_e/top_w: [Tg, K].  Returns
    (e_sorted, pos_sorted, tok_sorted, w_sorted, keep) over the Tg·K pairs.
    """
    tg, k = top_e.shape
    e_flat = top_e.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)
    w_flat = top_w.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    w_sorted = w_flat[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(tg * k, dtype=jnp.int32) - starts[e_sorted]
    keep = pos_sorted < c
    return e_sorted, pos_sorted, tok_sorted, w_sorted, keep


def moe_ffn(
    x: jnp.ndarray,  # [T, D] flattened tokens
    router_w: jnp.ndarray,  # [D, E]
    w_gate: jnp.ndarray,  # [E, D, F]
    w_up: jnp.ndarray,  # [E, D, F]
    w_down: jnp.ndarray,  # [E, F, D]
    cfg: MoEConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output [T, D], aux load-balance loss)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = n_groups(cfg, t)
    tg = t // g
    c = capacity(cfg, tg)

    # ---- routing ----------------------------------------------------------
    logits = jnp.einsum(
        "td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # [T, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    aux = aux_load_balance_loss(logits, top_e, e)

    # ---- group-local sort dispatch -----------------------------------------
    xg = constraint(x.reshape(g, tg, d), ("batch", None, None))
    eg = top_e.reshape(g, tg, k)
    wg = top_w.reshape(g, tg, k).astype(x.dtype)
    e_s, p_s, tok_s, w_s, keep = jax.vmap(
        lambda te, tw: _dispatch_one_group(e, c, te, tw)
    )(eg, wg)

    # gather the dispatched rows first and pin their sharding (G over batch)
    # — un-constrained, GSPMD replicated this [G, Tg·K, D] tensor per model
    # shard and resolved the scatter with ~2 TB of all-reduce (§Perf iter 9b)
    rows_in = jax.vmap(lambda xr, toks: xr[toks])(xg, tok_s)
    rows_in = constraint(rows_in, ("batch", None, None))

    def scatter_group(rows, es, ps, kp):
        buf = jnp.zeros((e, c, d), x.dtype)
        return buf.at[
            jnp.where(kp, es, e), jnp.where(kp, ps, 0)
        ].set(rows, mode="drop")

    buf = jax.vmap(scatter_group)(rows_in, e_s, p_s, keep)  # [G, E, C, D]
    # scatter stays fully group-local, THEN one explicit reshard moves the
    # buffer from group-major to expert-major sharding — the textbook MoE
    # all-to-all.  Without the intermediate constraint GSPMD fuses the
    # reshard into the scatter and resolves it by replicating the buffer
    # (u32 [TgK, D]-wide all-reduces observed on kimi).
    buf = constraint(buf, ("batch", None, None, None))
    buf = constraint(buf, ("batch", "expert", None, None))

    # ---- expert FFN (the G×E transpose here is the MoE all-to-all) ---------
    # §Perf iter 9: gather the FSDP dim of expert weights at the use site
    # (otherwise GSPMD all-reduces [G,E,C,F] partial sums over data)
    w_gate = constraint(w_gate.astype(buf.dtype), ("expert", None, "tensor"))
    w_up = constraint(w_up.astype(buf.dtype), ("expert", None, "tensor"))
    w_down = constraint(w_down.astype(buf.dtype), ("expert", "tensor", None))
    gate = jnp.einsum("gecd,edf->gecf", buf, w_gate)
    up = jnp.einsum("gecd,edf->gecf", buf, w_up)
    hidden = jax.nn.silu(gate) * up
    hidden = constraint(hidden, ("batch", "expert", None, "tensor"))
    out_buf = jnp.einsum("gecf,efd->gecd", hidden, w_down)
    out_buf = constraint(out_buf, ("batch", "expert", None, None))

    # ---- combine (group-local gather + weighted scatter-add) ---------------
    # reshard expert-major -> group-major first (the return all-to-all), so
    # the row gather below is local per group
    out_buf = constraint(out_buf, ("batch", None, None, None))
    rows_out = jax.vmap(
        lambda ob, es, ps, kp: ob[jnp.where(kp, es, 0), jnp.where(kp, ps, 0)]
    )(out_buf, e_s, p_s, keep)
    rows_out = constraint(rows_out, ("batch", None, None))
    rows_out = rows_out * jnp.where(keep, w_s, 0).astype(rows_out.dtype)[..., None]

    def combine_group(rows, toks):
        return jnp.zeros((tg, d), x.dtype).at[toks].add(rows)

    out = jax.vmap(combine_group)(rows_out, tok_s)
    out = constraint(out, ("batch", None, None)).reshape(t, d)
    return out, aux


def aux_load_balance_loss(router_logits: jnp.ndarray, top_e: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (mean fraction × mean prob)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(top_e[:, 0], n_experts, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    return n_experts * jnp.sum(me * ce)
