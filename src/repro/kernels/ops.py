"""Jit'd public wrappers around the Pallas kernels.

Every wrapper resolves its execution mode through one helper,
:func:`resolve_interpret`: on a TPU backend the kernels lower compiled,
anywhere else they run in interpret mode (the kernel body executes as
Python/jnp — validation, not speed).  The ``SGE_PALLAS_INTERPRET``
environment variable overrides the autodetect in both directions
(``1``/``true`` forces interpret, ``0``/``false`` forces compiled), and an
explicit ``interpret=`` argument beats both.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import candidate_mask as _cm
from repro.kernels import csr_extend as _ce
from repro.kernels import domain_ac as _ac
from repro.kernels import extend_step as _es
from repro.kernels import popcount_reduce as _pc
from repro.kernels import ref as kref

# Kept for callers that want the process default at import time; prefer
# resolve_interpret(), which also honors the env override per call.
INTERPRET = jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """The one interpret-mode decision point for every kernel wrapper.

    Precedence: explicit ``interpret=`` argument > ``SGE_PALLAS_INTERPRET``
    env var > backend autodetect (TPU → compiled, else interpret).
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("SGE_PALLAS_INTERPRET", "").strip()
    if env:  # set-but-empty falls through to the autodetect
        return env.lower() not in ("0", "false", "no", "off")
    return jax.default_backend() != "tpu"


def candidate_mask(rows, dom_bits, pos, row_idx, used, interpret=None):
    """See `repro.kernels.candidate_mask.candidate_mask`."""
    return _cm.candidate_mask(
        rows, dom_bits, pos, row_idx, used, interpret=resolve_interpret(interpret)
    )


def extend_step(rows, dom_bits, child_pos, row_idx, depth, n_p, used, cand,
                interpret=None):
    """See `repro.kernels.extend_step.extend_step` (the fused engine step)."""
    return _es.extend_step(
        rows, dom_bits, child_pos, row_idx, depth, n_p, used, cand,
        interpret=resolve_interpret(interpret),
    )


def csr_extend(indices, dom_bits, seg_start, seg_len, child_pos, depth, n_p,
               used, cand, deg_cap=8, interpret=None):
    """See `repro.kernels.csr_extend.csr_extend` (the sparse engine step)."""
    return _ce.csr_extend(
        indices, dom_bits, seg_start, seg_len, child_pos, depth, n_p,
        used, cand, deg_cap=deg_cap, interpret=resolve_interpret(interpret),
    )


def csr_extend_bucketed(indices, dom_bits, seg_start, seg_len, child_pos, depth,
                        n_p, used, cand, deg_cap=8, chunk=8, interpret=None):
    """See `repro.kernels.csr_extend.csr_extend_bucketed` (the degree-bucketed
    sparse engine step, DESIGN.md §10)."""
    return _ce.csr_extend_bucketed(
        indices, dom_bits, seg_start, seg_len, child_pos, depth, n_p,
        used, cand, deg_cap=deg_cap, chunk=chunk,
        interpret=resolve_interpret(interpret),
    )


def adjacency_any(rows, mask, interpret=None):
    """See `repro.kernels.domain_ac.adjacency_any`."""
    return _ac.adjacency_any(rows, mask, interpret=resolve_interpret(interpret))


def arc_any_sweep(adj_flat, arc_row, masks, interpret=None):
    """See `repro.kernels.domain_ac.arc_any_sweep`."""
    return _ac.arc_any_sweep(
        adj_flat, arc_row, masks, interpret=resolve_interpret(interpret)
    )


def csr_arc_sweep(seg_start, seg_len, indices, arc_row, masks, deg_cap=8,
                  interpret=None):
    """See `repro.kernels.domain_ac.csr_arc_sweep` (the sparse AC sweep)."""
    return _ac.csr_arc_sweep(
        seg_start, seg_len, indices, arc_row, masks, deg_cap=deg_cap,
        interpret=resolve_interpret(interpret),
    )


def popcount_rows(bits, interpret=None):
    """See `repro.kernels.popcount_reduce.popcount_rows`."""
    return _pc.popcount_rows(bits, interpret=resolve_interpret(interpret))


flatten_adj_rows = _cm.flatten_adj_rows
flat_row_index = _cm.flat_row_index
pack_bits = kref.pack_bits_ref
