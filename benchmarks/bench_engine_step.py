"""Engine-step backend benchmark: loose-ops jnp step vs fused Pallas
extend-step kernel (DESIGN.md §6).

  PYTHONPATH=src python benchmarks/bench_engine_step.py [--smoke]

Runs a ppis32-like collection through a ≥ 32-worker session twice — once
per ``EngineConfig.step_backend`` — and checks the two claims the backend
seam makes:

* **bit-identity** (always asserted): matches, states, steps, and steals
  agree query-for-query between the ``jnp`` and ``pallas`` backends.  Off
  TPU the fused kernel runs in *interpret mode* (Python kernel body —
  ~10-100× slower than jnp; see API.md), so the identity sweep runs on the
  smallest-states slice of the corpus there, the full corpus on TPU.
* **speedup** (asserted in compiled mode only): the fused step must beat
  the loose-ops step by ≥ 1.5× wall-clock.  Interpret mode is exempt by
  construction — it validates semantics, not speed — so on CPU the ratio
  is only reported.

Emits CSV rows (name, us_per_state, derived) and a JSON artifact.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

try:
    from benchmarks import common
except ImportError:  # executed from an arbitrary cwd
    import repro.bench  # noqa: F401  (puts the repo root on sys.path)
    from benchmarks import common

from repro.core import EngineConfig, Enumerator, SubgraphIndex
from repro.data import graphgen
from repro.kernels import ops as kops

SPEEDUP_FLOOR = 1.5  # compiled-mode acceptance (interpret exempt)
# interpret mode: only identity-check queries up to this many (jnp-counted)
# search states, so the Python kernel body finishes in CI time
INTERPRET_STATE_BUDGET = 60_000


def _corpus(smoke: bool, scale: float, seed: int):
    if smoke:
        return graphgen.make_collection(
            "ppis32-like", pattern_edges=(8,), patterns_per_target=1,
            scale=min(scale, 0.12), seed=seed,
        )
    return graphgen.make_collection(
        "ppis32-like", pattern_edges=(8, 16, 24), patterns_per_target=2,
        scale=scale, seed=seed,
    )


def _sweep(cfg: EngineConfig, instances, indices, names=None):
    """Run (a subset of) the collection; returns (per-query dict, wall_s).

    The compile pass is excluded from the timing: each query runs once to
    warm the session's shape-bucket cache, then once timed — the amortized
    regime the session API exists for.
    """
    session = Enumerator(config=cfg)
    queries = [
        session.prepare(inst.pattern, name=inst.name, index=indices[id(inst.target)])
        for inst in instances
        if names is None or inst.name in names
    ]
    for q in queries:  # warm-up: compile + first execution
        session.run(q)
    t0 = time.perf_counter()
    out = {}
    for q in queries:
        ms = session.run(q)
        out[q.name] = dict(matches=ms.matches, states=ms.states,
                           steps=ms.steps, steals=ms.steals)
    return out, time.perf_counter() - t0


def run(smoke: bool = False, scale: float = 0.3, workers: int = 32,
        seed: int = 7) -> dict:
    assert workers >= 32, "the acceptance criterion is a >=32-worker run"
    instances = _corpus(smoke, scale, seed)
    indices: dict = {}
    for inst in instances:
        indices.setdefault(id(inst.target), SubgraphIndex.build(inst.target))

    base = EngineConfig(n_workers=workers, expand_width=4)
    interpret = kops.resolve_interpret(None)

    jnp_res, t_jnp = _sweep(base, instances, indices)
    total_states = sum(r["states"] for r in jnp_res.values())

    # pick the fused sweep's query set: everything in compiled mode, the
    # smallest-states prefix under the budget in interpret mode
    if interpret:
        by_states = sorted(jnp_res.items(), key=lambda kv: kv[1]["states"])
        picked, budget = [], INTERPRET_STATE_BUDGET
        for name, r in by_states:
            if r["states"] <= budget or not picked:
                picked.append(name)
                budget -= r["states"]
        names = set(picked)
    else:
        names = None

    fused_cfg = dataclasses.replace(base, step_backend="pallas")
    pal_res, t_pal = _sweep(fused_cfg, instances, indices, names=names)

    # --- bit-identity: the seam's core contract ---------------------------
    for name, r in pal_res.items():
        assert r == jnp_res[name], (
            f"{name}: fused step diverged from loose-ops step — "
            f"pallas={r} jnp={jnp_res[name]}"
        )
    checked_states = sum(jnp_res[n]["states"] for n in pal_res)

    # --- speed: compiled mode must win, interpret mode just reports -------
    # compare on the same query set the fused sweep ran
    t_jnp_same = t_jnp
    if names is not None and len(names) < len(jnp_res):
        _, t_jnp_same = _sweep(base, instances, indices, names=names)
    speedup = t_jnp_same / max(t_pal, 1e-9)
    if not interpret:
        assert speedup >= SPEEDUP_FLOOR, (
            f"fused extend_step must be >= {SPEEDUP_FLOOR}x the loose-ops "
            f"step in compiled mode; measured {speedup:.2f}x "
            f"({t_jnp_same:.3f}s vs {t_pal:.3f}s)"
        )

    mode = "interpret" if interpret else "compiled"
    print(common.csv_row(
        "engine_step/jnp", t_jnp * 1e6 / max(total_states, 1),
        f"queries={len(jnp_res)};states={total_states};wall={t_jnp:.3f}s",
    ))
    print(common.csv_row(
        f"engine_step/pallas_{mode}", t_pal * 1e6 / max(checked_states, 1),
        f"queries={len(pal_res)};states={checked_states};wall={t_pal:.3f}s;"
        f"speedup={speedup:.2f}x;identical=True",
    ))
    payload = dict(
        mode=mode,
        workers=workers,
        queries=len(jnp_res),
        fused_queries=len(pal_res),
        total_states=total_states,
        checked_states=checked_states,
        jnp_wall_s=t_jnp,
        jnp_wall_same_set_s=t_jnp_same,
        pallas_wall_s=t_pal,
        speedup_same_set=speedup,
        speedup_asserted=not interpret,
        bit_identical=True,
    )
    common.save_json("engine_step", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--workers", type=int, default=32)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus for CI (same assertions)")
    args = ap.parse_args()
    out = run(smoke=args.smoke, scale=args.scale, workers=args.workers,
              seed=args.seed)
    verdict = (
        f"{out['speedup_same_set']:.2f}x (asserted >= {SPEEDUP_FLOOR}x)"
        if out["speedup_asserted"]
        else f"{out['speedup_same_set']:.2f}x (interpret mode: exempt)"
    )
    print(
        f"\n[{out['mode']}] {out['queries']} queries, {out['workers']} workers: "
        f"loose-ops {out['jnp_wall_s']:.2f}s; fused step on "
        f"{out['fused_queries']} queries ({out['checked_states']} states) "
        f"bit-identical; fused/loose = {verdict}"
    )


if __name__ == "__main__":
    main()
