"""Ring-buffer frontier ops (DESIGN.md §6.1): edge cases the engine's hot
loop silently relies on — empty pops, full rings, cap-1 stacks, wraparound
— plus the pop/push round-trip invariant."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frontier


def _ring(rng, v=2, s_cap=8, p=4, w=2, base=None, size=None):
    """Random stack arrays with controllable base/size."""
    st_depth = jnp.asarray(rng.integers(0, 5, (v, s_cap)), jnp.int32)
    st_map = jnp.asarray(rng.integers(-1, 10, (v, s_cap, p)), jnp.int32)
    st_used = jnp.asarray(rng.integers(0, 2**32, (v, s_cap, w), dtype=np.uint32))
    st_cand = jnp.asarray(
        rng.integers(1, 2**32, (v, s_cap, w), dtype=np.uint32)
    )  # nonzero so popped lanes are valid
    base = jnp.asarray(base if base is not None else np.zeros(v), jnp.int32)
    size = jnp.asarray(size if size is not None else np.full(v, s_cap // 2), jnp.int32)
    return st_depth, st_map, st_used, st_cand, base, size


def test_empty_pop_is_inert(rng):
    """size == 0: no lanes light up and payloads come back zeroed, so the
    expansion backend sees only invalid lanes."""
    arrs = _ring(rng, size=np.zeros(2))
    pop = frontier.pop_top_k(*arrs, expand_width=4)
    assert not bool(pop.lane_on.any())
    assert int(pop.k.sum()) == 0
    np.testing.assert_array_equal(np.asarray(pop.depth), 0)
    np.testing.assert_array_equal(np.asarray(pop.cand), 0)


def test_full_ring_freezes_and_flags(rng):
    """size == s_cap: the capacity guard yields k = 0 (a frozen worker —
    popping k lanes may push up to k net entries) and overflow reports."""
    s_cap = 8
    arrs = _ring(rng, s_cap=s_cap, size=np.full(2, s_cap))
    pop = frontier.pop_top_k(*arrs, expand_width=4)
    assert int(pop.k.sum()) == 0 and not bool(pop.lane_on.any())
    assert bool(frontier.overflowed(arrs[5], s_cap))
    assert not bool(frontier.overflowed(jnp.asarray([s_cap - 1, 0]), s_cap))


def test_cap_one_stack_can_never_expand(rng):
    """stack_cap == 1 with one entry: zero free space ⇒ k = 0 forever.
    The engine treats this as overflow (size > s_cap - 1 ... not here:
    size == 1 == s_cap), which the overflowed() watermark catches — the
    driver aborts instead of spinning (engine._engine_loop)."""
    arrs = _ring(rng, s_cap=1, size=np.ones(2))
    pop = frontier.pop_top_k(*arrs, expand_width=4)
    assert int(pop.k.sum()) == 0
    assert bool(frontier.overflowed(arrs[5], 1))


def test_pop_push_roundtrip_preserves_stack(rng):
    """Popping k entries and re-pushing them all as surviving parents (no
    children) must reproduce the stack exactly — contents, size, and DFS
    order — including across the ring-wraparound boundary."""
    v, s_cap, e = 3, 6, 4
    base = np.array([0, 4, 5])  # worker 2's entries wrap around the ring
    size = np.array([2, 4, 3])
    arrs = _ring(rng, v=v, s_cap=s_cap, base=base, size=size)
    st_depth, st_map, st_used, st_cand = arrs[:4]
    pop = frontier.pop_top_k(*arrs, expand_width=e)
    np.testing.assert_array_equal(np.asarray(pop.k), np.minimum(size, np.minimum(e, s_cap - size)))

    parent_keep = pop.lane_on
    has_child = jnp.zeros_like(parent_keep)
    zeros3 = jnp.zeros_like(pop.used)
    out = frontier.push_entries(
        st_depth, st_map, st_used, st_cand, arrs[4], arrs[5],
        pop.k, parent_keep, has_child,
        pop.depth, pop.map, pop.used, pop.cand,
        pop.depth + 1, pop.map, zeros3, zeros3,
    )
    nd, nm, nu, nc, new_size = out
    np.testing.assert_array_equal(np.asarray(new_size), size)
    # every logical position must hold the same entry as before
    for wk in range(v):
        for j in range(size[wk]):
            slot = (base[wk] + j) % s_cap
            np.testing.assert_array_equal(np.asarray(nd)[wk, slot],
                                          np.asarray(st_depth)[wk, slot])
            np.testing.assert_array_equal(np.asarray(nc)[wk, slot],
                                          np.asarray(st_cand)[wk, slot])
            np.testing.assert_array_equal(np.asarray(nm)[wk, slot],
                                          np.asarray(st_map)[wk, slot])
            np.testing.assert_array_equal(np.asarray(nu)[wk, slot],
                                          np.asarray(st_used)[wk, slot])


def test_push_drops_nothing_until_capacity(rng):
    """Parents + children from a k-entry pop fit by construction
    (k ≤ free space and net growth ≤ k): new_size never exceeds s_cap."""
    v, s_cap, e = 2, 5, 4
    size = np.array([4, 1])
    arrs = _ring(rng, v=v, s_cap=s_cap, size=size)
    pop = frontier.pop_top_k(*arrs, expand_width=e)
    ones = pop.lane_on
    out = frontier.push_entries(
        *arrs[:6], pop.k, ones, ones,
        pop.depth, pop.map, pop.used, pop.cand,
        pop.depth + 1, pop.map, pop.used, pop.cand,
    )
    new_size = np.asarray(out[4])
    assert (new_size <= s_cap).all()
    np.testing.assert_array_equal(new_size, size + np.asarray(pop.k))


def test_compact_rebases_without_reordering(rng):
    """compact() rotates each ring so base becomes 0; the logical entry
    sequence is untouched."""
    v, s_cap = 2, 6
    base = np.array([3, 5])
    size = np.array([4, 6])
    arrs = _ring(rng, v=v, s_cap=s_cap, base=base, size=size)
    nd, nm, nu, nc, nb, ns = frontier.compact(*arrs)
    np.testing.assert_array_equal(np.asarray(nb), 0)
    np.testing.assert_array_equal(np.asarray(ns), size)
    for wk in range(v):
        for j in range(size[wk]):
            old = (base[wk] + j) % s_cap
            np.testing.assert_array_equal(np.asarray(nd)[wk, j],
                                          np.asarray(arrs[0])[wk, old])
            np.testing.assert_array_equal(np.asarray(nm)[wk, j],
                                          np.asarray(arrs[1])[wk, old])
            np.testing.assert_array_equal(np.asarray(nu)[wk, j],
                                          np.asarray(arrs[2])[wk, old])
            np.testing.assert_array_equal(np.asarray(nc)[wk, j],
                                          np.asarray(arrs[3])[wk, old])


def test_store_used_false_reconstructs_used(rng):
    """With store_used=False the pop materializes used-bitmaps from the
    mapping prefix; spot-check against a hand-built mapping."""
    v, s_cap, p, w = 1, 4, 4, 2
    st_depth = jnp.asarray(np.full((v, s_cap), 2), jnp.int32)
    st_map = jnp.full((v, s_cap, p), -1, jnp.int32)
    st_map = st_map.at[0, :, 0].set(3).at[0, :, 1].set(33)  # bits 3 and 33
    st_used = jnp.zeros((v, s_cap, 1), jnp.uint32)  # collapsed when unused
    st_cand = jnp.ones((v, s_cap, w), jnp.uint32)
    base = jnp.zeros((v,), jnp.int32)
    size = jnp.ones((v,), jnp.int32)
    pop = frontier.pop_top_k(st_depth, st_map, st_used, st_cand, base, size,
                             expand_width=2, store_used=False)
    got = np.asarray(pop.used)[0, 0]
    np.testing.assert_array_equal(got, np.array([1 << 3, 1 << 1], np.uint32))


# ---------------------------------------------------------------------------
# compact's contiguous-segment guarantee (the property the csr step backend
# relies on: engine.make_expand_fn compacts every round under CsrPlanArrays)
# ---------------------------------------------------------------------------

def test_compact_contiguous_segment_guarantee(rng):
    """After compact every worker's live entries occupy physical slots
    [0, size) — logical position j IS slot j, with no wraparound — and a
    pop from the compacted state selects exactly the same entries."""
    v, s_cap = 3, 7
    base = np.array([6, 3, 0])  # worker 0 and 1 wrap, worker 2 doesn't
    size = np.array([5, 6, 4])
    arrs = _ring(rng, v=v, s_cap=s_cap, base=base, size=size)
    pop_before = frontier.pop_top_k(*arrs, expand_width=3)
    nd, nm, nu, nc, nb, ns = frontier.compact(*arrs)
    np.testing.assert_array_equal(np.asarray(nb), 0)
    for wk in range(v):
        for j in range(size[wk]):
            old = (base[wk] + j) % s_cap
            # slot j holds logical entry j: the contiguity invariant
            np.testing.assert_array_equal(np.asarray(nd)[wk, j],
                                          np.asarray(arrs[0])[wk, old])
            np.testing.assert_array_equal(np.asarray(nc)[wk, j],
                                          np.asarray(arrs[3])[wk, old])
    pop_after = frontier.pop_top_k(nd, nm, nu, nc, nb, ns, expand_width=3)
    np.testing.assert_array_equal(np.asarray(pop_before.k), np.asarray(pop_after.k))
    np.testing.assert_array_equal(np.asarray(pop_before.lane_on),
                                  np.asarray(pop_after.lane_on))
    np.testing.assert_array_equal(np.asarray(pop_before.depth),
                                  np.asarray(pop_after.depth))
    np.testing.assert_array_equal(np.asarray(pop_before.cand),
                                  np.asarray(pop_after.cand))
    on = np.asarray(pop_before.lane_on)
    # map/used payloads are only defined on lit lanes (off lanes read slot 0)
    np.testing.assert_array_equal(np.asarray(pop_before.map)[on],
                                  np.asarray(pop_after.map)[on])
    np.testing.assert_array_equal(np.asarray(pop_before.used)[on],
                                  np.asarray(pop_after.used)[on])


def test_compact_idempotent_and_full_ring(rng):
    """Compacting twice equals compacting once, including for completely
    full rings (size == s_cap, every slot live)."""
    v, s_cap = 2, 5
    arrs = _ring(rng, v=v, s_cap=s_cap, base=np.array([4, 2]),
                 size=np.array([s_cap, s_cap]))
    once = frontier.compact(*arrs)
    twice = frontier.compact(*once)
    for a, b in zip(once, twice):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compact_after_wraparound_push(rng):
    """Post-wraparound compaction: drive a ring across the physical
    boundary with a real pop/push cycle (children + surviving parents),
    then compact and check the contiguity invariant survives."""
    v, s_cap, e = 1, 6, 2
    base = np.array([4])  # 3 live entries at slots 4, 5, 0 — wrapped
    size = np.array([3])
    arrs = _ring(rng, v=v, s_cap=s_cap, base=base, size=size)
    pop = frontier.pop_top_k(*arrs, expand_width=e)
    assert int(pop.k[0]) == 2
    out = frontier.push_entries(
        *arrs[:6], pop.k, pop.lane_on, pop.lane_on,
        pop.depth, pop.map, pop.used, pop.cand,
        pop.depth + 1, pop.map, pop.used, pop.cand,
    )
    nd, nm, nu, nc, new_size = out
    assert int(new_size[0]) == 5  # 1 untouched + 2 parents + 2 children
    cd, cm, cu, cc, cb, cs = frontier.compact(nd, nm, nu, nc, arrs[4], new_size)
    np.testing.assert_array_equal(np.asarray(cb), 0)
    np.testing.assert_array_equal(np.asarray(cs), np.asarray(new_size))
    for j in range(int(new_size[0])):
        old = (base[0] + j) % s_cap
        np.testing.assert_array_equal(np.asarray(cd)[0, j], np.asarray(nd)[0, old])
        np.testing.assert_array_equal(np.asarray(cc)[0, j], np.asarray(nc)[0, old])
        np.testing.assert_array_equal(np.asarray(cm)[0, j], np.asarray(nm)[0, old])
        np.testing.assert_array_equal(np.asarray(cu)[0, j], np.asarray(nu)[0, old])
