"""Out-of-core partitioned enumeration (DESIGN.md §9): partitioner
invariants, budget derivation, spill-ring watermark drains, the partitioned
numpy oracle (scheduling-stat exact vs the engine), and compile-cache
warmup accounting.

Cross-backend result conformance (n_parts x case matrix, mesh) lives in
``tests/test_backend_conformance.py``; this file covers the machinery
underneath it.
"""

import numpy as np
import pytest

from repro.core import EngineConfig, Enumerator, SubgraphIndex
from repro.core import engine as eng
from repro.core import extend, ref
from repro.core.plan import build_csr_plan
from tests.conftest import (
    extract_connected_pattern,
    power_law_target,
    random_graph,
)


def _sparse_case(rng, n=300):
    tgt = power_law_target(rng, n, avg_deg=3.0, n_labels=6)
    pat = extract_connected_pattern(rng, tgt, 4)
    return tgt, pat


# ---------------------------------------------------------------------------
# partitioner invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_parts", (1, 2, 3, 5, 8))
def test_partition_preserves_every_row(rng, n_parts):
    """Concatenating the partitions' local rows reproduces the whole CSR
    exactly: same row slice (global column ids) for every plane and global
    row, nnz conserved, node ranges contiguous and covering."""
    tgt, _ = _sparse_case(rng, n=200)
    whole = tgt.csr_planes()
    pp = tgt.partition(n_parts=n_parts)
    ns = pp.node_start
    assert ns[0] == 0 and ns[-1] == whole.n_t == pp.n_t
    assert np.all(np.diff(ns) >= 0)
    assert sum(p.nnz for p in pp.parts) == whole.nnz
    for pid, part in enumerate(pp.parts):
        lo, hi = int(ns[pid]), int(ns[pid + 1])
        assert part.n_t == hi - lo
        for pl in range(whole.n_planes):
            for v in range(lo, hi):
                want = whole.indices[whole.indptr[pl, v]:whole.indptr[pl, v + 1]]
                got = part.indices[
                    part.indptr[pl, v - lo]:part.indptr[pl, v - lo + 1]]
                np.testing.assert_array_equal(want, got)


def test_partition_cut_accounting(rng):
    """n_parts=1 has no cut; multi-part cut counts exactly the arcs whose
    endpoint lives in another partition (never replicated)."""
    tgt, _ = _sparse_case(rng, n=150)
    whole = tgt.csr_planes()
    assert tgt.partition(n_parts=1).cut_edges == 0
    pp = tgt.partition(n_parts=3)
    want = 0
    for pid, part in enumerate(pp.parts):
        lo, hi = int(pp.node_start[pid]), int(pp.node_start[pid + 1])
        want_pid = 0
        for pl in range(0, part.n_planes, 2):  # out-planes: p = elab*2 + 0
            s, e = int(part.indptr[pl, 0]), int(part.indptr[pl, part.n_t])
            cols = part.indices[s:e]
            want_pid += int(((cols < lo) | (cols >= hi)).sum())
        assert int(pp.cut_per_part[pid]) == want_pid
        want += want_pid
    assert pp.cut_edges == want
    assert pp.part_of(np.arange(whole.n_t)).min() == 0
    assert pp.part_of(np.arange(whole.n_t)).max() == pp.n_parts - 1


def test_partition_budget_mode(rng):
    """max_bytes= picks the smallest count whose largest partition fits;
    argument validation rejects none/both selectors."""
    tgt, _ = _sparse_case(rng, n=200)
    whole = tgt.csr_planes()
    budget = whole.nbytes // 3
    pp = tgt.partition(max_bytes=budget)
    assert pp.max_resident_nbytes <= budget
    assert pp.n_parts > 1
    # minimality: one fewer partition would not fit
    if pp.n_parts > 1:
        smaller = tgt.partition(n_parts=pp.n_parts - 1)
        assert smaller.max_resident_nbytes > budget
    with pytest.raises(ValueError, match="exactly one"):
        tgt.partition(n_parts=2, max_bytes=budget)
    with pytest.raises(ValueError, match="exactly one"):
        tgt.partition()
    with pytest.raises(ValueError):
        tgt.partition(n_parts=0)


def test_plan_partitions_budget_bounds_padded_bytes(rng):
    """plan_partitions_budget bounds the *padded* resident footprint (what
    the device holds under the shared compile) and caches so the engine's
    by-count lookup returns the identical object."""
    tgt, pat = _sparse_case(rng, n=300)
    plan = build_csr_plan(pat, tgt)
    whole = extend.part_resident_nbytes(extend.plan_partitions(plan, 1))
    budget = whole // 2
    pp = extend.plan_partitions_budget(plan, budget)
    assert extend.part_resident_nbytes(pp) <= budget
    assert extend.plan_partitions(plan, pp.n_parts) is pp
    assert extend.plan_partitions_budget(plan, budget) is pp  # cached
    with pytest.raises(ValueError, match="cannot (fit|hold)"):
        extend.plan_partitions_budget(plan, 64)


# ---------------------------------------------------------------------------
# partitioned oracle: results AND scheduling stats equal the engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_parts", (1, 2, 3))
def test_partitioned_oracle_matches_monolithic(rng, n_parts):
    """The sequential partitioned oracle enumerates exactly what the
    monolithic oracle does on the same plan — partitioning is invisible in
    matches, states, and sorted mappings."""
    tgt, pat = _sparse_case(rng)
    plan = build_csr_plan(pat, tgt)
    mono = ref.ref_enumerate(pat, tgt, plan=plan, record_mappings=True)
    part = ref.ref_enumerate_partitioned(
        pat, tgt, n_parts, plan=plan, record_mappings=True)
    assert (part.matches, part.states) == (mono.matches, mono.states)
    assert part.mappings == sorted(mono.mappings)


@pytest.mark.parametrize("n_parts", (1, 2, 3, 5))
def test_engine_scheduling_agrees_with_oracle(rng, n_parts):
    """The engine's partition-scheduling loop reproduces the oracle's
    *scheduling* behavior exactly — partition visits, spilled extensions,
    and dead spills — not just the enumeration outputs.  This pins the
    deepest-pool swap policy and the pending-parent intake semantics."""
    tgt, pat = _sparse_case(rng)
    plan = build_csr_plan(pat, tgt)
    oracle = ref.ref_enumerate_partitioned(pat, tgt, n_parts, plan=plan)
    stats = {}
    cfg = EngineConfig(n_workers=4, expand_width=2,
                       step_backend="partitioned", n_partitions=n_parts)
    got = eng.run_partitioned(plan, cfg, stats=stats)
    assert (got.matches, got.states) == (oracle.matches, oracle.states)
    assert stats["n_parts"] == oracle.n_parts
    assert stats["visits"] == oracle.visits
    assert stats["spilled"] == oracle.spilled
    assert stats["dead_spills"] == oracle.dead_spills


# ---------------------------------------------------------------------------
# leg-0 root seeding: every partition owns its own root batch (DESIGN.md §10)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_parts", (2, 3, 5))
def test_partition_root_entries_balance(rng, n_parts):
    """Roots prefill one pool entry per partition, each restricted to the
    owner's row range and jointly covering dom[0] exactly — no partition
    seeds another partition's roots (the pre-§10 behavior put *all* roots
    on the first-visited partition, spilling nearly every depth-1 child)."""
    from repro.core.graph import bitmap_to_indices

    tgt, pat = _sparse_case(rng)
    plan = build_csr_plan(pat, tgt)
    cfg = EngineConfig(n_workers=4, expand_width=2,
                       step_backend="partitioned", n_partitions=n_parts)
    pp = extend.plan_partitions(plan, n_parts)
    entries = eng.partition_root_entries(plan, cfg, pp)
    dom0 = set(bitmap_to_indices(plan.dom_bits[0]).tolist())
    seen = set()
    parts_with_roots = set()
    for part, (depth, map_row, cand, pending) in entries:
        lo, hi = int(pp.node_start[part]), int(pp.node_start[part + 1])
        roots = set(bitmap_to_indices(cand).tolist())
        assert depth == 0 and pending == 0
        assert (map_row == -1).all()
        assert roots and all(lo <= t < hi for t in roots)  # owner's rows only
        assert not (roots & seen)  # partitions never share a root
        seen |= roots
        parts_with_roots.add(part)
    assert seen == dom0  # jointly exhaustive
    # balance: dom0 spans the row space, so >1 partition must hold roots
    assert len(parts_with_roots) > 1


@pytest.mark.parametrize("n_parts", (2, 4))
def test_partition_edge_seeds_route_to_owner(rng, n_parts):
    """Edge seeding under the partitioned driver: every depth-1 seed lands
    in the pool of the partition owning its mapped source row."""
    tgt, pat = _sparse_case(rng)
    plan = build_csr_plan(pat, tgt, seed_edge="auto")
    cfg = EngineConfig(n_workers=4, expand_width=2, root_seeding="edge",
                       step_backend="partitioned", n_partitions=n_parts)
    pp = extend.plan_partitions(plan, n_parts)
    entries = eng.partition_root_entries(plan, cfg, pp)
    assert entries
    for part, (depth, map_row, cand, pending) in entries:
        lo, hi = int(pp.node_start[part]), int(pp.node_start[part + 1])
        assert depth == 1 and pending == 0
        assert lo <= int(map_row[0]) < hi


# ---------------------------------------------------------------------------
# spill-ring watermark: tiny rings force mid-partition host drains
# ---------------------------------------------------------------------------

def test_tiny_spill_ring_watermark_drains(rng):
    """A spill ring barely above the watermark margin forces the inner loop
    to yield for host drains many times per partition visit (legs >>
    visits) — results must not change."""
    tgt, pat = _sparse_case(rng)
    plan = build_csr_plan(pat, tgt)
    base = eng.run(plan, EngineConfig(n_workers=4, expand_width=2,
                                      step_backend="csr"))
    cfg = EngineConfig(n_workers=4, expand_width=2,
                       step_backend="partitioned", n_partitions=4)
    margin = eng.part_spill_margin(cfg)
    tiny = EngineConfig(n_workers=4, expand_width=2,
                        step_backend="partitioned", n_partitions=4,
                        spill_cap=margin + 2)
    stats, stats_tiny = {}, {}
    got = eng.run_partitioned(plan, cfg, stats=stats)
    got_tiny = eng.run_partitioned(plan, tiny, stats=stats_tiny)
    assert (got.matches, got.states) == (base.matches, base.states)
    assert (got_tiny.matches, got_tiny.states) == (base.matches, base.states)
    assert stats_tiny["spilled"] == stats["spilled"]
    if stats["spilled"]:
        # a ring barely above the margin cannot buffer a whole leg's
        # spills: the inner loop must yield for extra host drains
        assert stats_tiny["rounds"] > stats_tiny["legs"]
        assert stats_tiny["rounds"] > stats["rounds"]


def test_partitioned_tiny_stack_retries(rng):
    """Worker-stack overflow inside a leg is retried leg-locally at doubled
    capacity until it fits — the result never undercounts."""
    tgt, pat = _sparse_case(rng, n=150)
    plan = build_csr_plan(pat, tgt)
    base = eng.run(plan, EngineConfig(n_workers=2, expand_width=2,
                                      step_backend="csr"))
    stats = {}
    cfg = EngineConfig(n_workers=2, expand_width=2, stack_cap=8,
                       step_backend="partitioned", n_partitions=3)
    got = eng.run_partitioned(plan, cfg, stats=stats)
    assert not got.overflow
    assert (got.matches, got.states) == (base.matches, base.states)


# ---------------------------------------------------------------------------
# session integration: budget plumbing + warm() compile accounting
# ---------------------------------------------------------------------------

def test_session_memory_budget_derives_partitions(rng):
    """Enumerator(memory_budget_bytes=...) forces the partitioned backend,
    derives the count from the padded resident bytes, and matches the
    monolithic run."""
    tgt, pat = _sparse_case(rng)
    idx = SubgraphIndex.build(tgt)
    mono = Enumerator(idx, n_workers=2, expand_width=2, step_backend="csr")
    want = mono.run(mono.prepare(pat))

    q0 = mono.prepare(pat)
    whole = extend.part_resident_nbytes(extend.plan_partitions(q0.plan, 1))
    s = Enumerator(idx, n_workers=2, expand_width=2,
                   memory_budget_bytes=whole // 2)
    assert s.config.step_backend == "partitioned"
    got = s.run(s.prepare(pat))
    assert (got.matches, got.states) == (want.matches, want.states)
    with pytest.raises(ValueError):
        Enumerator(idx, memory_budget_bytes=0)


@pytest.mark.parametrize("backend_kw", (
    dict(step_backend="csr"),
    dict(step_backend="partitioned", n_partitions=2),
))
def test_warm_spends_compiles_upfront(rng, backend_kw):
    """Enumerator.warm() pays the XLA compile at warmup time; subsequent
    same-key submits are pure cache hits (zero fresh compiles) — for the
    monolithic and the partitioned engines alike."""
    tgt, pat = _sparse_case(rng, n=120)
    idx = SubgraphIndex.build(tgt)
    s = Enumerator(idx, n_workers=2, expand_width=2, **backend_kw)
    q = s.prepare(pat)
    out = s.warm([q])
    assert out["warmed"] == 1
    assert out["compiles"] >= 1
    compiles = s.cache_info()["compiles"]
    ms = s.run(q)
    assert ms.states > 0
    assert s.cache_info()["compiles"] == compiles  # cache hit, no compile
    assert s.warm([q]) == {"warmed": 1, "compiles": 0}  # already warm


def test_warm_pack_lanes_covers_dispatch_width(rng):
    """warm(lanes=N) traces the vmapped pack engine run_pack uses, so a
    warmed service's first dispatch compiles nothing."""
    tgt, _ = _sparse_case(rng, n=120)
    pats = [extract_connected_pattern(rng, tgt, 4) for _ in range(3)]
    idx = SubgraphIndex.build(tgt)
    s = Enumerator(idx, n_workers=2, expand_width=2)
    qs = [s.prepare(p) for p in pats]
    assert s.warm(qs, lanes=4)["compiles"] >= 1
    compiles = s.cache_info()["compiles"]
    s.run_pack(qs, pack_size=4)
    assert s.cache_info()["compiles"] == compiles


def test_warm_skips_unsatisfiable(rng):
    """Unsatisfiable queries never reach the engine, so warm() spends
    nothing on them."""
    from repro.core.graph import Graph

    tgt = random_graph(rng, 20, 40, n_labels=2)
    bad = Graph.from_edges(2, [(0, 1)], labels=[7, 0], undirected=True)
    s = Enumerator(SubgraphIndex.build(tgt), n_workers=2, expand_width=2)
    q = s.prepare(bad)  # domain-filter compile happens here, not in warm
    assert not q.plan.satisfiable
    assert s.warm([q]) == {"warmed": 0, "compiles": 0}
