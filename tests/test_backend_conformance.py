"""Cross-backend conformance suite — the single gate every entry of
``STEP_BACKENDS`` must pass (DESIGN.md §6.2/§6.4).

Supersedes the pairwise jnp ≡ pallas checks (``tests/test_extend_step.py``
keeps the kernel-vs-oracle sweeps): everything here parametrizes over
**all** step backends, so a future backend is conformance-tested the
moment it is appended to ``repro.core.extend.STEP_BACKENDS``.

Layers of evidence, strongest first:

* **state-level**: after any number of shared expansion steps, every
  backend's :class:`EngineState` pytree — stacks, ring bookkeeping,
  counters, match buffers — is bit-identical to the ``jnp`` reference
  (fixed-seed matrix always; a hypothesis property test when available);
* **end-to-end**: whole engine runs agree counter-for-counter and
  mapping-for-mapping across a case matrix that includes self-loops,
  multiple edge labels, ``store_used=False``, kernel routing
  (``use_pallas``), and a power-law large-sparse target;
* **mesh**: sharding over ≥ 2 devices changes nothing for any backend
  (runs in CI's 4-virtual-device job);
* **session**: ``Enumerator(step_backend=...)`` threads every backend
  through the compile cache, and ``"auto"`` resolves by target size with
  explicit override.
"""

import jax
import numpy as np
import pytest

from repro.core import EngineConfig, Enumerator, SubgraphIndex
from repro.core import engine as eng
from repro.core import extend
from repro.core.graph import PackedGraph
from repro.core.plan import VARIANTS, build_csr_plan, build_plan
from tests.conftest import (
    extract_connected_pattern,
    power_law_target,
    random_graph,
)

BACKENDS = extend.STEP_BACKENDS
ALT_BACKENDS = tuple(b for b in BACKENDS if b != "jnp")


# ---------------------------------------------------------------------------
# case matrix: (target, pattern) generators exercising distinct plan shapes
# ---------------------------------------------------------------------------

def _dense(rng):
    tgt = random_graph(rng, 40, 120, n_labels=3)
    return tgt, extract_connected_pattern(rng, tgt, 5)


def _selfloops(rng):
    tgt = random_graph(rng, 36, 100, n_labels=2, selfloops=4)
    return tgt, extract_connected_pattern(rng, tgt, 5)


def _edge_labels(rng):
    tgt = random_graph(rng, 32, 90, n_labels=2, n_elabs=3)
    return tgt, extract_connected_pattern(rng, tgt, 4)


def _sparse_power_law(rng):
    # n_t >> lanes with hub rows and many degenerate (isolated) indptr runs
    tgt = power_law_target(rng, 400, avg_deg=3.0, n_labels=6, selfloops=2)
    return tgt, extract_connected_pattern(rng, tgt, 4)


def _hub_power_law(rng):
    # the DESIGN.md §10 regime: flatter exponent → a hub row spanning most
    # of the target (deg ≈ n_t) next to a near-isolated tail, so the global
    # deg_cap is ~40× the p95 degree and bucketing/edge seeding matter
    tgt = power_law_target(rng, 420, avg_deg=3.5, alpha=1.7, n_labels=8)
    return tgt, extract_connected_pattern(rng, tgt, 4)


CASES = {
    "dense": _dense,
    "selfloops": _selfloops,
    "edge_labels": _edge_labels,
    "sparse_power_law": _sparse_power_law,
    "hub_power_law": _hub_power_law,
}

HUB_CASES = ("sparse_power_law", "hub_power_law")


def _plan(rng, case, variant="ri-ds-si-fc"):
    tgt, pat = CASES[case](rng)
    return build_plan(pat, PackedGraph.from_graph(tgt)), tgt, pat


def _cfg(backend, **kw):
    kw.setdefault("n_workers", 4)
    kw.setdefault("expand_width", 2)
    return EngineConfig(step_backend=backend, **kw)


def _assert_results_identical(a, b):
    assert (a.matches, a.states, a.steps, a.steals, a.steal_rounds) == (
        b.matches, b.states, b.steps, b.steals, b.steal_rounds,
    )
    np.testing.assert_array_equal(a.per_worker_states, b.per_worker_states)
    np.testing.assert_array_equal(a.per_worker_matches, b.per_worker_matches)
    np.testing.assert_array_equal(a.per_worker_steals, b.per_worker_steals)


# ---------------------------------------------------------------------------
# end-to-end conformance over the full case matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
@pytest.mark.parametrize("backend", ALT_BACKENDS)
def test_end_to_end_conformance(rng, backend, case):
    """Whole runs agree with the jnp reference counter-for-counter,
    mappings included, on every plan-shape case."""
    plan, _, _ = _plan(rng, case)
    ref = eng.run(plan, _cfg("jnp", collect_matches=64))
    got = eng.run(plan, _cfg(backend, collect_matches=64))
    _assert_results_identical(ref, got)
    np.testing.assert_array_equal(ref.match_buf, got.match_buf)


@pytest.mark.parametrize("backend", [b for b in BACKENDS if b != "pallas"])
def test_kernel_routing_conformance(rng, backend):
    """use_pallas routes part of each backend's step through a kernel
    (candidate_mask under jnp, csr_extend under csr) — still identical."""
    plan, _, _ = _plan(rng, "selfloops")
    ref = eng.run(plan, _cfg("jnp"))
    got = eng.run(plan, _cfg(backend, use_pallas=True))
    _assert_results_identical(ref, got)


@pytest.mark.parametrize("backend", ALT_BACKENDS)
def test_store_used_false_conformance(rng, backend):
    plan, _, _ = _plan(rng, "dense")
    _assert_results_identical(
        eng.run(plan, _cfg("jnp", store_used=False)),
        eng.run(plan, _cfg(backend, store_used=False)),
    )


@pytest.mark.parametrize("variant", VARIANTS)
def test_variant_conformance_csr(rng, variant):
    """Preprocessing variants change the plan, never the backend contract."""
    tgt, pat = _dense(rng)
    plan = build_plan(pat, PackedGraph.from_graph(tgt), variant=variant)
    _assert_results_identical(
        eng.run(plan, _cfg("jnp")), eng.run(plan, _cfg("csr"))
    )


def test_csr_only_plan_all_sparse_paths(rng):
    """A CSR-only plan (build_csr_plan: dense bitmaps never materialized)
    matches the dense-built ri plan through the csr backend, and refuses
    dense backends with a clear error."""
    tgt, pat = _sparse_power_law(rng)
    dense_plan = build_plan(pat, PackedGraph.from_graph(tgt), variant="ri")
    sparse_plan = build_csr_plan(pat, tgt, variant="ri")
    assert sparse_plan.adj_bits.shape[2] == 0  # nothing dense was built
    ref = eng.run(dense_plan, _cfg("jnp"))
    got = eng.run(sparse_plan, _cfg("csr"))
    _assert_results_identical(ref, got)
    # "auto" must run a CSR-only plan whatever its n_t (here << CSR_AUTO_NT):
    # there is no dense layout to fall back to
    got_auto = eng.run(sparse_plan, _cfg("auto"))
    _assert_results_identical(ref, got_auto)
    with pytest.raises(ValueError, match="CSR-only"):
        eng.run(sparse_plan, _cfg("jnp"))


# ---------------------------------------------------------------------------
# state-level conformance: bit-identical EngineState after shared steps
# ---------------------------------------------------------------------------

def _run_steps(cfg, plan, arrays, n_steps):
    step = jax.jit(extend.make_step_fn(cfg, arrays))
    state = eng.init_state(plan, cfg)
    for _ in range(n_steps):
        state = step(state)
    return state


@pytest.mark.parametrize("store_used,collect", [(True, 8), (False, 0)])
@pytest.mark.parametrize("backend", ALT_BACKENDS)
def test_state_level_conformance(rng, backend, store_used, collect):
    """Every backend's EngineState pytree equals the jnp reference after
    each of several shared expansion steps — the strongest single check
    (stacks, ring bookkeeping, counters, match buffers)."""
    plan, _, _ = _plan(rng, "selfloops")
    kw = dict(n_workers=3, expand_width=2, store_used=store_used,
              collect_matches=collect)
    cfg_ref, cfg_alt = _cfg("jnp", **kw), _cfg(backend, **kw)
    sj = _run_steps(cfg_ref, plan, eng.plan_arrays_for(cfg_ref, plan), 5)
    sb = _run_steps(cfg_alt, plan, eng.plan_arrays_for(cfg_alt, plan), 5)
    for name, a, b in zip(eng.EngineState._fields, sj, sb):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"EngineState field {name} diverged for {backend}",
        )


# ---------------------------------------------------------------------------
# auto resolution + session threading
# ---------------------------------------------------------------------------

def test_auto_resolution_rule():
    cfg = EngineConfig(step_backend="auto")
    assert extend.resolve_step_backend(cfg, extend.CSR_AUTO_NT) == "jnp"
    assert extend.resolve_step_backend(cfg, extend.CSR_AUTO_NT + 1) == "csr"
    # explicit backend always wins
    for b in BACKENDS:
        assert extend.resolve_step_backend(
            EngineConfig(step_backend=b), extend.CSR_AUTO_NT + 1
        ) == b


def test_auto_selects_csr_arrays_past_threshold(rng, monkeypatch):
    """With the threshold lowered under the test target's size, "auto"
    builds CsrPlanArrays and still reproduces the dense jnp result."""
    plan, _, _ = _plan(rng, "dense")
    monkeypatch.setattr(extend, "CSR_AUTO_NT", plan.n_t - 1)
    cfg = _cfg("auto")
    assert isinstance(eng.plan_arrays_for(cfg, plan), extend.CsrPlanArrays)
    _assert_results_identical(eng.run(plan, _cfg("jnp")), eng.run(plan, cfg))
    # explicit override ignores the threshold
    cfg_j = _cfg("jnp")
    assert isinstance(eng.plan_arrays_for(cfg_j, plan), extend.PlanArrays)


@pytest.mark.parametrize("backend", BACKENDS + ("auto",))
def test_session_threads_every_backend(rng, backend):
    """step_backend= flows through Enumerator for every backend; each cfg
    gets its own compile-cache entries and identical results."""
    tgt, pat = _dense(rng)
    idx = SubgraphIndex.build(tgt)
    ref = Enumerator(idx, n_workers=2, expand_width=2)
    alt = Enumerator(idx, n_workers=2, expand_width=2, step_backend=backend)
    ra = ref.run(ref.prepare(pat))
    rb = alt.run(alt.prepare(pat))
    assert (ra.matches, ra.states, ra.steps) == (rb.matches, rb.states, rb.steps)


def test_session_batch_stream_conformance(rng):
    """run_batch / stream (the vmapped pack path) agree across backends."""
    tgt, _ = _dense(rng)
    idx = SubgraphIndex.build(tgt)
    pats = [extract_connected_pattern(rng, tgt, k) for k in (3, 4, 5, 4)]
    want = None
    for backend in BACKENDS:
        s = Enumerator(idx, n_workers=2, expand_width=2, step_backend=backend)
        got = [(ms.matches, ms.states, ms.steps)
               for ms in s.run_batch([s.prepare(p) for p in pats])]
        if want is None:
            want = got
        else:
            assert got == want, f"pack path diverged for {backend}"


def test_pack_path_mixed_density_targets(rng):
    """Same-bucket queries against different-density targets have
    differently shaped CsrPlanArrays (deg_cap, nnz) — the pack grouper
    must split them instead of stacking mismatched shapes."""
    t1 = random_graph(rng, 40, 120, n_labels=3)
    t2 = random_graph(rng, 40, 60, n_labels=3)
    want = None
    for backend in ("jnp", "csr"):
        s = Enumerator(config=_cfg(backend, n_workers=2))
        qs = [s.prepare(extract_connected_pattern(np.random.default_rng(5), t, 4),
                        index=SubgraphIndex.build(t))
              for t in (t1, t2)]
        got = [ms.matches for ms in s.run_batch(qs, pack_size=4)]
        if want is None:
            want = got
        else:
            assert got == want


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        EngineConfig(step_backend="bogus")
    with pytest.raises(ValueError, match="CsrPlanArrays"):
        plan_arrays = extend.abstract_plan_arrays(8, 1, 4, 2)
        extend.make_step_backend(EngineConfig(step_backend="csr"), plan_arrays)


# ---------------------------------------------------------------------------
# property test: any backend ≡ jnp over random plans/configs (hypothesis)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment without hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        backend=st.sampled_from(ALT_BACKENDS),
        expand_width=st.integers(1, 4),
        n_workers=st.integers(1, 4),
        store_used=st.booleans(),
        collect=st.booleans(),
        n_steps=st.integers(1, 6),
    )
    def test_property_backends_bit_identical_states(
        seed, backend, expand_width, n_workers, store_used, collect, n_steps
    ):
        """Any STEP_BACKENDS entry must produce bit-identical EngineState
        pytrees to jnp after any number of shared expansion steps, over
        random graphs (self-loops included), patterns, and configs."""
        rng = np.random.default_rng(seed)
        tgt = random_graph(rng, 16, 40, n_labels=2,
                           selfloops=int(rng.integers(0, 3)))
        pat = extract_connected_pattern(rng, tgt, int(rng.integers(3, 6)))
        if pat.m == 0:
            return
        plan = build_plan(pat, PackedGraph.from_graph(tgt))
        kw = dict(
            n_workers=n_workers,
            expand_width=expand_width,
            store_used=store_used,
            collect_matches=8 if collect else 0,
        )
        cfg_ref, cfg_alt = _cfg("jnp", **kw), _cfg(backend, **kw)
        sj = _run_steps(cfg_ref, plan, eng.plan_arrays_for(cfg_ref, plan), n_steps)
        sb = _run_steps(cfg_alt, plan, eng.plan_arrays_for(cfg_alt, plan), n_steps)
        for name, a, b in zip(eng.EngineState._fields, sj, sb):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"EngineState field {name} diverged for {backend}",
            )


# ---------------------------------------------------------------------------
# mesh path (runs in CI's 4-virtual-device job)
# ---------------------------------------------------------------------------

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=N)",
)


@multi_device
@pytest.mark.parametrize("backend", BACKENDS)
def test_mesh_path_conformance(rng, backend):
    """Sharding the worker axis over 2 devices changes nothing for any
    backend: the mesh driver calls the same shared step (and, for csr,
    the same per-round compaction)."""
    tgt, pat = _dense(rng)
    plan = build_plan(pat, PackedGraph.from_graph(tgt))
    mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    cfg = _cfg(backend)
    _assert_results_identical(eng.run(plan, cfg), eng.run(plan, cfg, mesh=mesh))


# ---------------------------------------------------------------------------
# partitioned (out-of-core) backend conformance (DESIGN.md §9)
# ---------------------------------------------------------------------------

PART_COUNTS = (1, 2, 4)


def _part_cfg(n_parts, **kw):
    kw.setdefault("n_workers", 4)
    kw.setdefault("expand_width", 2)
    return EngineConfig(step_backend="partitioned", n_partitions=n_parts, **kw)


def _sorted_mappings(match_buf, n_p):
    """All recorded mappings (rows with every pattern position set),
    lexicographically sorted — scheduling-order independent."""
    rows = np.asarray(match_buf).reshape(-1, np.asarray(match_buf).shape[-1])
    rows = rows[:, :n_p]
    rows = rows[(rows >= 0).all(axis=1)]
    return sorted(map(tuple, rows.tolist()))


@pytest.mark.parametrize("case", sorted(CASES))
@pytest.mark.parametrize("n_parts", PART_COUNTS)
def test_partitioned_conformance(rng, case, n_parts):
    """Streaming the target through n_parts partitions is invisible in the
    results: match/state counts and the *sorted* mapping sets equal the
    monolithic CSR run on every plan-shape case (scheduling order — steps,
    steals — legitimately differs, so only enumeration outputs compare)."""
    plan, _, pat = _plan(rng, case)
    ref = eng.run(plan, _cfg("csr", collect_matches=512))
    got = eng.run_partitioned(plan, _part_cfg(n_parts, collect_matches=512))
    assert (got.matches, got.states) == (ref.matches, ref.states)
    assert not got.overflow
    ref_maps = _sorted_mappings(ref.match_buf, pat.n)
    assert len(ref_maps) == ref.matches  # ring large enough: nothing dropped
    assert _sorted_mappings(got.match_buf, pat.n) == ref_maps


def test_partitioned_single_partition_degenerates(rng):
    """n_parts=1 keeps every row resident: no spill traffic, one partition
    visit, and outputs equal to the monolithic CSR backend."""
    plan, _, pat = _plan(rng, "sparse_power_law")
    stats = {}
    ref = eng.run(plan, _cfg("csr", collect_matches=512))
    got = eng.run_partitioned(plan, _part_cfg(1, collect_matches=512),
                              stats=stats)
    assert (got.matches, got.states) == (ref.matches, ref.states)
    assert stats["n_parts"] == 1
    assert stats["visits"] == 1
    assert stats["spilled"] == 0
    assert _sorted_mappings(got.match_buf, pat.n) == _sorted_mappings(
        ref.match_buf, pat.n)


@multi_device
@pytest.mark.parametrize("n_parts", (2, 4))
def test_partitioned_mesh_conformance(rng, n_parts):
    """Sharding the partitioned driver's worker/spill stacks over 2 devices
    (resident planes replicated) leaves counts and mappings identical to
    the monolithic CSR run (runs in CI's 4-virtual-device job)."""
    plan, _, pat = _plan(rng, "sparse_power_law")
    mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    ref = eng.run(plan, _cfg("csr", collect_matches=512))
    got = eng.run_partitioned(plan, _part_cfg(n_parts, collect_matches=512),
                              mesh=mesh)
    assert (got.matches, got.states) == (ref.matches, ref.states)
    assert _sorted_mappings(got.match_buf, pat.n) == _sorted_mappings(
        ref.match_buf, pat.n)


# ---------------------------------------------------------------------------
# edge-centric seeding + degree-bucketed CSR walk (DESIGN.md §10)
# ---------------------------------------------------------------------------

def _seed_plans(rng, case):
    """Vertex- and edge-seeded plans over the same (target, pattern)."""
    tgt, pat = CASES[case](rng)
    pk = PackedGraph.from_graph(tgt)
    return build_plan(pat, pk), build_plan(pat, pk, seed_edge="auto"), pat


def _node_mappings(res, plan, n_p):
    """Sorted pattern-NODE-indexed match sets.  Edge seeding anchors the
    seed edge at positions 0/1 so the two plans order positions
    differently; re-indexing column ``i`` (position) to ``plan.order[i]``
    (pattern node) makes the match sets directly comparable."""
    buf = np.asarray(res.match_buf)
    rows = buf.reshape(-1, buf.shape[-1])[:, :n_p]
    rows = rows[(rows >= 0).all(axis=1)]
    order = np.asarray(plan.order[:n_p])
    out = np.empty_like(rows)
    out[:, order] = rows
    return sorted(map(tuple, out.tolist()))


@pytest.mark.parametrize("case", HUB_CASES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_edge_seeding_conformance(rng, backend, case):
    """Edge-seeded runs agree counter-for-counter across every backend
    (vs the jnp edge reference), and their match sets equal the
    vertex-seeded run's exactly — seeding only reshapes the search tree,
    never its leaves."""
    vplan, eplan, pat = _seed_plans(rng, case)
    ref_v = eng.run(vplan, _cfg("jnp", collect_matches=512))
    ref_e = eng.run(
        eplan, _cfg("jnp", root_seeding="edge", collect_matches=512))
    got = eng.run(
        eplan, _cfg(backend, root_seeding="edge", collect_matches=512))
    _assert_results_identical(ref_e, got)
    assert got.matches == ref_v.matches
    v_maps = _node_mappings(ref_v, vplan, pat.n)
    assert len(v_maps) == ref_v.matches  # ring large enough: nothing dropped
    assert _node_mappings(got, eplan, pat.n) == v_maps


@pytest.mark.parametrize("case", HUB_CASES)
def test_auto_seeding_resolution(rng, case):
    """root_seeding='auto' is edge iff the plan carries a seed edge —
    bit-identical to the explicit mode either way."""
    vplan, eplan, _ = _seed_plans(rng, case)
    _assert_results_identical(
        eng.run(eplan, _cfg("csr", root_seeding="auto")),
        eng.run(eplan, _cfg("csr", root_seeding="edge")),
    )
    _assert_results_identical(
        eng.run(vplan, _cfg("csr", root_seeding="auto")),
        eng.run(vplan, _cfg("csr", root_seeding="vertex")),
    )


def test_edge_seeding_requires_seed_edge(rng):
    vplan, _, _ = _seed_plans(rng, "sparse_power_law")
    with pytest.raises(ValueError, match="seed_edge"):
        eng.run(vplan, _cfg("csr", root_seeding="edge"))


def test_edge_seeding_capacity_fallback(rng):
    """When the seed class outnumbers the stacks (forced here: one worker,
    a 9-arc explicit seed class, stack_cap=9 → per-worker 9 > s_cap-1) the
    edge path falls back to a depth-0 split restricted to the qualifying
    sources — same matches, no overflow."""
    tgt, pat = CASES["hub_power_law"](rng)
    pk = PackedGraph.from_graph(tgt)
    eplan = build_plan(pat, pk, seed_edge=(3, 2, 0))
    cfg = _cfg("csr", n_workers=1, root_seeding="edge", stack_cap=9)
    st = eng.init_state(eplan, cfg)
    live = np.asarray(st.st_depth)[np.asarray(st.size) > 0]
    assert (live == 0).all()  # fell back to depth-0 roots, not depth-1 seeds
    got = eng.run(eplan, cfg)
    ref = eng.run(build_plan(pat, pk), _cfg("csr", n_workers=1))
    assert not got.overflow
    assert got.matches == ref.matches


@pytest.mark.parametrize("case", HUB_CASES)
@pytest.mark.parametrize("n_parts", (2, 4))
def test_partitioned_edge_seeding_conformance(rng, case, n_parts):
    """Edge seeds route to the partition owning their source row; counts
    and node-indexed match sets equal the monolithic vertex-seeded run."""
    vplan, eplan, pat = _seed_plans(rng, case)
    ref = eng.run(vplan, _cfg("csr", collect_matches=512))
    got = eng.run_partitioned(
        eplan,
        _part_cfg(n_parts, root_seeding="edge", collect_matches=512),
    )
    assert got.matches == ref.matches
    assert _node_mappings(got, eplan, pat.n) == _node_mappings(
        ref, vplan, pat.n)


@pytest.mark.parametrize("case", HUB_CASES)
@pytest.mark.parametrize("use_pallas", (False, True))
def test_bucketed_walk_matches_flat(rng, case, use_pallas):
    """csr_walk='bucketed' (per-bucket trip counts) is invisible in the
    results vs the PR-5 global-deg_cap 'flat' walk — full counter identity
    on hub-heavy targets, through both the jitted reference and the
    csr_extend kernels."""
    plan, _, _ = _plan(rng, case)
    _assert_results_identical(
        eng.run(plan, _cfg("csr", csr_walk="flat", use_pallas=use_pallas,
                           collect_matches=64)),
        eng.run(plan, _cfg("csr", csr_walk="bucketed", use_pallas=use_pallas,
                           collect_matches=64)),
    )


@multi_device
@pytest.mark.parametrize("backend", ("csr", "jnp"))
def test_mesh_edge_seeding_conformance(rng, backend):
    """Edge-seeded hub-heavy runs shard over 2 devices unchanged (runs in
    CI's 4-virtual-device job)."""
    _, eplan, _ = _seed_plans(rng, "hub_power_law")
    mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    cfg = _cfg(backend, root_seeding="edge", collect_matches=64)
    _assert_results_identical(
        eng.run(eplan, cfg), eng.run(eplan, cfg, mesh=mesh))


@multi_device
def test_mesh_bucketed_walk_conformance(rng):
    """Bucketed-vs-flat walk identity holds under the 2-device mesh on the
    hub-heavy case (runs in CI's 4-virtual-device job)."""
    plan, _, _ = _plan(rng, "hub_power_law")
    mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    _assert_results_identical(
        eng.run(plan, _cfg("csr", csr_walk="flat"), mesh=mesh),
        eng.run(plan, _cfg("csr", csr_walk="bucketed"), mesh=mesh),
    )


# ---------------------------------------------------------------------------
# CSR-only plans: every variant x every sparse-capable backend (DESIGN.md §11)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("backend", ("csr", "partitioned"))
def test_csr_only_variant_matrix(rng, backend, variant):
    """``build_csr_plan`` under every variant: the CSR-native domain
    pipeline (initial domains, AC, FC, the AC ⇄ FC joint fixpoint) yields
    domains bit-identical to the dense-built plan's, and both sparse-capable
    backends reproduce the dense ``jnp`` run's counts and sorted mappings
    — with dense adjacency bitmaps never materialized."""
    tgt, pat = _sparse_power_law(rng)
    dense_plan = build_plan(pat, PackedGraph.from_graph(tgt), variant=variant)
    sparse_plan = build_csr_plan(pat, tgt, variant=variant)
    assert sparse_plan.adj_bits.shape[2] == 0  # nothing dense was built
    np.testing.assert_array_equal(sparse_plan.dom_bits, dense_plan.dom_bits)
    assert sparse_plan.order.tolist() == dense_plan.order.tolist()
    ref = eng.run(dense_plan, _cfg("jnp", collect_matches=512))
    if backend == "partitioned":
        got = eng.run_partitioned(
            sparse_plan, _part_cfg(2, collect_matches=512))
    else:
        got = eng.run(sparse_plan, _cfg("csr", collect_matches=512))
    assert (got.matches, got.states) == (ref.matches, ref.states)
    ref_maps = _sorted_mappings(ref.match_buf, pat.n)
    assert len(ref_maps) == ref.matches  # ring large enough: nothing dropped
    assert _sorted_mappings(got.match_buf, pat.n) == ref_maps


# ---------------------------------------------------------------------------
# sparse (CSR-only) sessions: routing, conformance, fail-fast validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
def test_sparse_session_conformance(rng, variant):
    """``Enumerator`` over ``SubgraphIndex.build(sparse=True)``: plans come
    out CSR-only, and counts / states / sorted mappings equal the dense
    session's under every variant."""
    tgt, pat = _sparse_power_law(rng)
    dense = Enumerator(SubgraphIndex.build(tgt), variant=variant,
                       config=_cfg("jnp"))
    sparse = Enumerator(SubgraphIndex.build(tgt, sparse=True), variant=variant,
                        config=_cfg("csr"))
    ref = dense.run(dense.prepare(pat))
    qs = sparse.prepare(pat)
    assert qs.plan.adj_bits.shape[2] == 0  # the session built a CSR-only plan
    got = sparse.run(qs)
    assert (got.matches, got.states) == (ref.matches, ref.states)
    assert sorted(got.mappings()) == sorted(ref.mappings())


def test_sparse_session_compile_cache(rng):
    """Same-bucket queries against a sparse index share one compiled
    engine, exactly like the dense session path."""
    tgt, _ = _sparse_power_law(rng)
    sparse = Enumerator(SubgraphIndex.build(tgt, sparse=True),
                        config=_cfg("csr"))
    r = np.random.default_rng(5)
    p1 = extract_connected_pattern(r, tgt, 4)
    p2 = extract_connected_pattern(r, tgt, 4)
    sparse.run(sparse.prepare(p1))
    sparse.run(sparse.prepare(p2))
    info = sparse.cache_info()
    assert info["compiles"] == 1 and info["cache_hits"] >= 1, info


@pytest.mark.parametrize("backend", ("jnp", "pallas"))
def test_sparse_index_dense_backend_fails_fast(rng, backend):
    """An explicitly dense step backend can never run a CSR-only plan: the
    session must say so at prepare() time — naming the plan layout and the
    valid backends — with zero compiles spent."""
    tgt, pat = _sparse_power_law(rng)
    enum = Enumerator(SubgraphIndex.build(tgt, sparse=True),
                      config=_cfg(backend))
    with pytest.raises(ValueError, match="CSR-only") as ei:
        enum.prepare(pat)
    msg = str(ei.value)
    assert backend in msg          # names the offending backend
    assert "'csr'" in msg and "'partitioned'" in msg  # and the valid ones
    assert enum.cache_info()["compiles"] == 0


def test_csr_only_query_dense_run_fails_fast(rng):
    """The engine-cache entry point re-validates: running a CSR-only query
    through a dense-configured session raises before compiling."""
    tgt, pat = _sparse_power_law(rng)
    idx = SubgraphIndex.build(tgt, sparse=True)
    ok = Enumerator(idx, config=_cfg("csr"))
    q = ok.prepare(pat)
    dense = Enumerator(idx, config=_cfg("jnp"))
    with pytest.raises(ValueError, match="CSR-only"):
        dense.run(q)
    assert dense.cache_info()["compiles"] == 0
