"""§Dry-run summary: per-cell memory feasibility table from the compiled
``memory_analysis()`` records, plus the out-of-core partition plan.

  PYTHONPATH=src python -m benchmarks.dryrun_report
  PYTHONPATH=src python -m benchmarks.dryrun_report --partition-nodes 33000 \
      --mem-budget 300000

Writes artifacts/dryrun_summary_<mesh>.md: argument/temp/output bytes per
device, the 16 GB v5e HBM feasibility verdict, and compile times — the
"proves it fits" artifact the brief requires, reported honestly (kimi/grok
training exceed 256-chip residency; the dry run validates their sharding).

Also writes artifacts/partition_plan.md: the partitioned backend's target
partition plan (DESIGN.md §9) at a few partition counts — per-partition
node/nnz/resident-byte rows plus cut-edge counts, and the partition count a
``--mem-budget`` would derive — so out-of-core memory budgets can be sized
without running the engine.
"""

from __future__ import annotations

import argparse
import os

from benchmarks import roofline

HBM_BYTES = 16 * 1024**3  # v5e per chip

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def gb(x) -> str:
    return f"{x / 1024**3:.2f}"


def table(mesh: str) -> str:
    rows = []
    for rec in roofline.load_cells(mesh):
        if rec.get("skipped"):
            rows.append(f"| {rec['cell']} | — | — | — | SKIP | — |")
            continue
        mem = rec.get("memory_analysis", {})
        arg = mem.get("argument_size_in_bytes", 0)
        tmp = mem.get("temp_size_in_bytes", 0)
        out = mem.get("output_size_in_bytes", 0)
        alias = mem.get("alias_size_in_bytes", 0)
        peak = arg + tmp + out - alias
        verdict = "fits" if peak <= HBM_BYTES else f"needs ≥{-(-peak // HBM_BYTES) * rec['n_devices']} chips"
        rows.append(
            f"| {rec['cell']} | {gb(arg)} | {gb(tmp)} | {gb(out)} "
            f"| {verdict} | {rec.get('compile_s', 0):.1f}s |"
        )
    hdr = ("| cell | args (GB/dev) | temp (GB/dev) | out (GB/dev) "
           "| 16 GB HBM verdict | compile |\n|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def partition_plan_report(
    n_nodes: int,
    avg_deg: float = 6.0,
    pattern_edges: int = 8,
    n_parts_list=(1, 2, 4, 8),
    mem_budget: int = 0,
    seed: int = 7,
) -> str:
    """Markdown partition-plan section for a power-law target at ``n_nodes``
    (DESIGN.md §9): per-partition nodes / raw nnz / resident plane bytes and
    out-going cut arcs at each count in ``n_parts_list``, plus the padded
    per-compile resident footprint the memory budget actually bounds.  With
    ``mem_budget > 0`` also reports the count ``plan_partitions_budget``
    derives — the number ``Enumerator(memory_budget_bytes=…)`` would run."""
    from repro.core import extend, plan as plan_mod
    from repro.data import graphgen

    target = graphgen.power_law_graph(n_nodes, avg_deg=avg_deg, seed=seed)
    pattern = graphgen.extract_pattern(target, pattern_edges, seed=seed)
    plan = plan_mod.build_csr_plan(pattern, target)
    whole = extend.part_resident_nbytes(extend.plan_partitions(plan, 1))

    out = [
        f"# Partition plan — power-law target, {target.n} nodes, "
        f"{target.m} edges, pattern {pattern.n} nodes\n",
        f"whole-target resident CSR planes (padded): {whole} bytes\n",
    ]
    for n_parts in n_parts_list:
        pp = extend.plan_partitions(plan, n_parts)
        padded = extend.part_resident_nbytes(pp)
        out.append(
            f"\n## n_parts={pp.n_parts} — resident budget {padded} bytes/partition "
            f"(padded, {whole / max(padded, 1):.1f}x under whole target), "
            f"{pp.cut_edges} cut arcs total\n"
        )
        out.append("| part | nodes | nnz | resident bytes (raw) | cut arcs out |")
        out.append("|---|---|---|---|---|")
        for pid in range(pp.n_parts):
            lo, hi = int(pp.node_start[pid]), int(pp.node_start[pid + 1])
            out.append(
                f"| {pid} | {hi - lo} | {pp.parts[pid].nnz} "
                f"| {pp.resident_nbytes(pid)} | {int(pp.cut_per_part[pid])} |"
            )
    if mem_budget > 0:
        pp = extend.plan_partitions_budget(plan, mem_budget)
        out.append(
            f"\n## --mem-budget {mem_budget} derives n_parts={pp.n_parts} "
            f"({extend.part_resident_nbytes(pp)} padded resident bytes/partition)\n"
        )
    out.append(
        "\nresident bytes (raw) = one partition's plane arrays as stored; "
        "the padded per-partition figure is what the engine holds on device "
        "(all partitions share one compiled shape, so each pads to the "
        "largest).  cut arcs leave the partition and are *not* replicated — "
        "extensions that need them ride the spill frontier (DESIGN.md §9).\n"
    )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--partition-nodes", type=int, default=4096,
                    help="power-law target size for the partition-plan "
                    "section (0 disables the section)")
    ap.add_argument("--mem-budget", type=int, default=0, metavar="BYTES",
                    help="also report the partition count this device-memory "
                    "budget would derive")
    args = ap.parse_args()

    for mesh in ("single", "multi"):
        cells = roofline.load_cells(mesh)
        if not cells:
            continue
        path = os.path.join(ART, f"dryrun_summary_{mesh}.md")
        with open(path, "w") as f:
            f.write(f"# Dry-run memory summary — {mesh} mesh\n\n"
                    f"{table(mesh)}\n\n"
                    "peak ≈ args + temp + out − aliased (donated buffers "
                    "alias outputs).  CAVEATS: temp sizes come from the "
                    "CPU-backend buffer assignment, which lacks TPU-grade "
                    "liveness reuse — the chip-count verdicts are UPPER "
                    "bounds (e.g. dense-LM train cells fit far fewer chips "
                    "with TPU buffer reuse + microbatching).  The "
                    "param+optimizer arithmetic is exact though: kimi-k2 "
                    "training genuinely needs ≥2048 chips (14 B/param "
                    "ZeRO-sharded), grok ≥512.  The compile itself is the "
                    "deliverable: the sharding is coherent at 256/512 "
                    "chips.\n")
        print(f"[dryrun_report] wrote {path}")

    if args.partition_nodes > 0:
        os.makedirs(ART, exist_ok=True)
        path = os.path.join(ART, "partition_plan.md")
        with open(path, "w") as f:
            f.write(partition_plan_report(
                args.partition_nodes, mem_budget=args.mem_budget))
        print(f"[dryrun_report] wrote {path}")


if __name__ == "__main__":
    main()
