"""Subgraph-enumeration driver — the paper's tool, end to end.

  PYTHONPATH=src python -m repro.launch.sge_run --collection ppis32-like \
      --variant ri-ds-si-fc --workers 16 --scale 0.3

Generates (or loads) a collection, runs every (target, pattern) instance
through the parallel engine, and reports per-instance matches / states /
steps plus collection aggregates — the shape of the paper's experiment
tables.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import EngineConfig
from repro.data import graphgen

sys.path.insert(0, ".")


def main() -> int:
    from benchmarks import common  # reuse the corpus runner

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--collection", default="ppis32-like",
                    choices=sorted(graphgen.COLLECTIONS))
    ap.add_argument("--variant", default="ri-ds-si-fc")
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--expand", type=int, default=4)
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--packed", action="store_true",
                    help="run LPT-balanced multi-query packs (core/multi.py; "
                    "the pod-axis execution mode) instead of one query at a time")
    ap.add_argument("--pack-size", type=int, default=4)
    args = ap.parse_args()

    instances = graphgen.make_collection(
        args.collection, pattern_edges=(8, 16, 24), patterns_per_target=2,
        scale=args.scale, seed=args.seed,
    )
    cfg = EngineConfig(n_workers=args.workers, expand_width=args.expand)

    if args.packed:
        from collections import defaultdict

        from repro.core.multi import enumerate_many

        by_target = defaultdict(list)
        for inst in instances:
            by_target[id(inst.target)].append(inst)
        t0 = time.perf_counter()
        matches = states = 0
        for group in by_target.values():
            results = enumerate_many(
                [i.pattern for i in group], group[0].target,
                variant=args.variant, cfg=cfg, pack_size=args.pack_size,
                names=[i.name for i in group],
            )
            for r in results:
                print(f"{r.name:40s} matches={r.matches:<8d} states={r.states:<9d} "
                      f"steps={r.steps}")
                matches += r.matches
                states += r.states
        total = time.perf_counter() - t0
        print(f"\n[{args.collection}/packed] {len(instances)} queries, "
              f"{matches} matches, {states} states, {total:.1f}s "
              f"({states/max(total,1e-9):.0f} states/s)")
        return 0
    cache: dict = {}
    t0 = time.perf_counter()
    rows = []
    for inst in instances:
        r = common.run_instance(inst, variant=args.variant, cfg=cfg,
                                packed_cache=cache)
        rows.append(r)
        print(f"{inst.name:40s} matches={r.matches:<8d} states={r.states:<9d} "
              f"steps={r.steps:<7d} steals={r.steals:<5d} {r.wall_s:6.2f}s")
    total = time.perf_counter() - t0
    states = sum(r.states for r in rows)
    print(f"\n[{args.collection}] {len(rows)} instances, "
          f"{sum(r.matches for r in rows)} matches, {states} states, "
          f"{total:.1f}s total ({states/max(total,1e-9):.0f} states/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
