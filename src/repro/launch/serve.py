"""Always-on enumeration service driver (DESIGN.md §7).

  PYTHONPATH=src python -m repro.launch.serve --smoke

Stands up one :class:`repro.serve.EnumerationService` and drives it with
``--clients`` synthetic client threads, each submitting ``--queries``
heterogeneous patterns (sizes 3–6, several tenants) and consuming its
:class:`ResultStream` handles.  With ``--csr`` (default) a share of the
queries are CSR-only plans against a second, sparser target, so the
coalescer demonstrably keeps mixed dense/CSR load in separate buckets of
one service.  On completion the driver cross-checks every streamed result
against a standalone ``Enumerator.run`` of the same query and prints the
service metrics snapshot (QPS, p50/p99 latency, batch occupancy, compile
count, cache hit rate).

This replaces the transformer prefill/decode KV-cache demo that lived
here before PR 6 — that was an LM-serving sketch unrelated to subgraph
enumeration; the continuous-batching idea it gestured at is now real and
enumeration-shaped.
"""

from __future__ import annotations

import argparse
import sys
import threading
import time
from typing import List, Optional

from repro.core import EngineConfig, Enumerator, Query, SubgraphIndex
from repro.core.plan import build_csr_plan
from repro.data import graphgen
from repro.serve import EnumerationService, ServiceConfig, format_snapshot


def build_corpus(args) -> tuple:
    """One dense target + (optionally) one sparse CSR-only target, and the
    per-client query lists (round-robin heterogeneous patterns)."""
    dense_tgt = graphgen.random_graph(
        args.target_n, args.target_m, n_labels=4, seed=args.seed
    )
    index = SubgraphIndex.build(dense_tgt)
    csr_tgt = None
    if args.csr:
        csr_tgt = graphgen.random_graph(
            2 * args.target_n, 3 * args.target_n, n_labels=4, seed=args.seed + 1
        )
    queries: List[List[Query]] = []
    enum = Enumerator(index, config=EngineConfig())  # prepare() only — no engine use
    for c in range(args.clients):
        qs: List[Query] = []
        for k in range(args.queries):
            i = c * args.queries + k
            if csr_tgt is not None and i % 4 == 3:
                pat = graphgen.extract_pattern(csr_tgt, 3 + (i % 2), seed=args.seed + 50 + i)
                plan = build_csr_plan(pat, csr_tgt, variant="ri")
                qs.append(Query(pattern=pat, plan=plan, variant="ri",
                                name=f"c{c}q{k}-csr", prepare_s=0.0))
            else:
                pat = graphgen.extract_pattern(dense_tgt, 3 + (i % 4), seed=args.seed + 50 + i)
                qs.append(enum.prepare(pat, name=f"c{c}q{k}"))
        queries.append(qs)
    return index, queries


def drive(svc: EnumerationService, queries: List[List[Query]],
          collect: int, timeout: float) -> List[tuple]:
    """Run one client thread per query list; returns (query, MatchSet,
    streamed-mappings) triples in submission order."""
    out: List[Optional[tuple]] = [None] * sum(len(qs) for qs in queries)
    errors: List[BaseException] = []

    def client(c: int, qs: List[Query]) -> None:
        try:
            handles = [
                svc.submit(q, tenant=f"tenant-{c % 4}", collect=collect, timeout=timeout)
                for q in qs
            ]
            for k, (q, h) in enumerate(zip(qs, handles)):
                ms = h.result(timeout=timeout)
                idx = c * len(qs) + k
                out[idx] = (q, ms, h.mappings() if collect else None)
        except BaseException as e:  # surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c, qs), daemon=True)
               for c, qs in enumerate(queries)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout)
    if errors:
        raise errors[0]
    assert all(r is not None for r in out), "a client dropped a result"
    return out  # type: ignore[return-value]


def verify(results: List[tuple], svc: EnumerationService, n_check: int) -> None:
    """Cross-check a sample of served results against standalone runs."""
    ref = Enumerator(config=svc.enumerator.config)
    step = max(1, len(results) // max(n_check, 1))
    for q, ms, maps in results[::step][:n_check]:
        r = ref.run(q) if maps is None else ref.run(q, collect_matches=len(maps) or 1)
        assert (ms.matches, ms.states) == (r.matches, r.states), (
            f"{q.name}: served ({ms.matches}, {ms.states}) != standalone "
            f"({r.matches}, {r.states})"
        )
        if maps is not None:
            assert maps == r.mappings(), f"{q.name}: streamed mappings diverge"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus + tight timeouts (CI)")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--queries", type=int, default=None,
                    help="queries per client")
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--window-ms", type=float, default=2.0)
    ap.add_argument("--collect", type=int, default=32,
                    help="per-worker match budget streamed back (0 = counts only)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--target-n", type=int, default=None)
    ap.add_argument("--target-m", type=int, default=None)
    ap.add_argument("--csr", action=argparse.BooleanOptionalAction, default=True,
                    help="mix CSR-only queries against a second target")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--timeout", type=float, default=300.0)
    args = ap.parse_args(argv)
    args.clients = args.clients or (4 if args.smoke else 16)
    args.queries = args.queries or (2 if args.smoke else 4)
    args.target_n = args.target_n or (48 if args.smoke else 120)
    args.target_m = args.target_m or (3 * args.target_n)

    index, queries = build_corpus(args)
    n_total = sum(len(qs) for qs in queries)
    svc = EnumerationService(
        index,
        config=EngineConfig(n_workers=args.workers, expand_width=2,
                            step_backend="auto"),
        service=ServiceConfig(max_lanes=args.lanes,
                              batch_window_s=args.window_ms / 1e3),
    )
    print(f"[serve] {args.clients} clients x {args.queries} queries "
          f"({n_total} total, csr={'on' if args.csr else 'off'}), "
          f"lanes={args.lanes}, window={args.window_ms}ms")
    t0 = time.perf_counter()
    with svc:
        results = drive(svc, queries, collect=args.collect, timeout=args.timeout)
    wall = time.perf_counter() - t0
    verify(results, svc, n_check=4 if args.smoke else 8)
    stats = svc.stats()
    print(format_snapshot(stats))
    print(f"[serve] {n_total} queries in {wall:.2f}s "
          f"({n_total / wall:.1f} q/s end-to-end), "
          f"{stats['cache_compiles']:.0f} engine compilations, verified OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
