"""Serving metrics: counters, latency percentiles, QPS, batch occupancy.

Pure-host instrumentation for the always-on service (DESIGN.md §7).  All
observation methods are thread-safe (client threads observe rejections,
the dispatcher thread observes dispatches/completions) and cheap: counters
and fixed-size reservoirs, no allocation proportional to traffic.

:meth:`ServiceMetrics.snapshot` is the one read surface — a flat dict the
service CLI prints, ``bench_serving.py`` gates on, and tests assert
against.  Latency percentiles are nearest-rank over a sliding window of
the most recent observations; QPS is completions over the window's time
span, so an idle server decays toward 0 instead of averaging over its
whole uptime.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Dict, Optional


class LatencyWindow:
    """Sliding window of the most recent ``cap`` latency observations with
    nearest-rank percentiles.  Not thread-safe on its own — callers hold
    the :class:`ServiceMetrics` lock."""

    def __init__(self, cap: int = 4096):
        self._buf: collections.deque = collections.deque(maxlen=cap)

    def record(self, value_s: float) -> None:
        self._buf.append(float(value_s))

    def __len__(self) -> int:
        return len(self._buf)

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile ``p`` ∈ [0, 100] (0.0 when empty)."""
        if not self._buf:
            return 0.0
        ordered = sorted(self._buf)
        rank = max(0, min(len(ordered) - 1, int(round(p / 100.0 * len(ordered))) - 1))
        return ordered[rank]

    def mean(self) -> float:
        return sum(self._buf) / len(self._buf) if self._buf else 0.0

    def max(self) -> float:
        return max(self._buf) if self._buf else 0.0


# Counter names the service increments; snapshot() emits every one (zeros
# included) so downstream dashboards see a stable schema.
COUNTERS = (
    "submitted",            # admitted + rejected + unsat short-circuits
    "admitted",             # entered the admission queue
    "completed",            # terminal ok results delivered
    "failed",               # terminal error results delivered
    "rejected_quota",       # per-tenant outstanding cap hit (immediate)
    "rejected_backpressure",  # global queue full past the submit timeout
    "unsat",                # unsatisfiable queries answered without the engine
    "retries",              # overflow retries spent across completed queries
    "index_updates",        # update_index() calls (no-op edits included)
    "cache_invalidated",    # compile-cache entries evicted on index swap
    "dispatches",           # engine pack invocations
    "chunks",               # ResultChunks streamed
    "warmup_compiles",      # compiles spent by start()'s warmup_profile
)


class ServiceMetrics:
    """Thread-safe counters + windows for one :class:`EnumerationService`."""

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 window: int = 4096):
        self._clock = clock
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {name: 0 for name in COUNTERS}
        self._latency = LatencyWindow(window)       # submit -> terminal
        self._queue_wait = LatencyWindow(window)    # submit -> dispatch
        self._completion_times: collections.deque = collections.deque(maxlen=window)
        self._lanes_occupied = 0
        self._lanes_total = 0
        self._started_at = clock()

    # -- observation (any thread) -----------------------------------------

    def inc(self, name: str, by: int = 1) -> None:
        with self._lock:
            self._counters[name] += by

    def observe_dispatch(self, occupied: int, lanes: int) -> None:
        """One engine pack went out with ``occupied`` of ``lanes`` lanes
        carrying real queries (the rest are inert shape padding)."""
        with self._lock:
            self._counters["dispatches"] += 1
            self._lanes_occupied += occupied
            self._lanes_total += lanes

    def observe_queue_wait(self, wait_s: float) -> None:
        with self._lock:
            self._queue_wait.record(wait_s)

    def observe_completion(self, latency_s: float, retries: int = 0,
                           ok: bool = True) -> None:
        with self._lock:
            self._counters["completed" if ok else "failed"] += 1
            self._counters["retries"] += retries
            self._latency.record(latency_s)
            self._completion_times.append(self._clock())

    # -- read surface ------------------------------------------------------

    def qps(self) -> float:
        """Completions per second over the sliding completion window."""
        with self._lock:
            times = self._completion_times
            if len(times) < 2:
                return 0.0
            span = times[-1] - times[0]
            return (len(times) - 1) / span if span > 0 else 0.0

    def snapshot(self, cache: Optional[Dict[str, int]] = None,
                 queue_depth: int = 0, coalescing: int = 0,
                 in_flight: int = 0) -> Dict[str, float]:
        """Flat stats dict.  ``cache`` is ``Enumerator.cache_stats()``;
        ``queue_depth`` / ``coalescing`` / ``in_flight`` are sampled by the
        service at call time (they are gauges, not counters)."""
        with self._lock:
            out: Dict[str, float] = dict(self._counters)
            out["uptime_s"] = self._clock() - self._started_at
            out["queue_depth"] = queue_depth
            out["coalescing"] = coalescing
            out["in_flight"] = in_flight
            out["latency_p50_s"] = self._latency.percentile(50)
            out["latency_p99_s"] = self._latency.percentile(99)
            out["latency_mean_s"] = self._latency.mean()
            out["latency_max_s"] = self._latency.max()
            out["queue_wait_p50_s"] = self._queue_wait.percentile(50)
            out["queue_wait_p99_s"] = self._queue_wait.percentile(99)
            out["batch_occupancy"] = (
                self._lanes_occupied / self._lanes_total if self._lanes_total else 0.0
            )
        out["qps"] = self.qps()
        if cache is not None:
            out["cache_compiles"] = cache["compiles"]
            out["cache_hits"] = cache["cache_hits"]
            out["cache_evictions"] = cache["evictions"]
            out["cache_entries"] = cache["entries"]
            lookups = cache["compiles"] + cache["cache_hits"]
            out["cache_hit_rate"] = cache["cache_hits"] / lookups if lookups else 0.0
        return out


def format_snapshot(stats: Dict[str, float]) -> str:
    """Human-readable multi-line rendering of :meth:`ServiceMetrics.snapshot`
    (the ``repro.launch.serve`` periodic stats line)."""
    lines = [
        "queries   submitted={submitted:.0f} completed={completed:.0f} "
        "failed={failed:.0f} unsat={unsat:.0f} retries={retries:.0f}",
        "admission rejected_quota={rejected_quota:.0f} "
        "rejected_backpressure={rejected_backpressure:.0f} "
        "queue_depth={queue_depth:.0f} coalescing={coalescing:.0f} "
        "in_flight={in_flight:.0f}",
        "batches   dispatches={dispatches:.0f} occupancy={batch_occupancy:.2f} "
        "chunks={chunks:.0f}",
        "latency   p50={latency_p50_s:.4f}s p99={latency_p99_s:.4f}s "
        "max={latency_max_s:.4f}s qps={qps:.1f}",
    ]
    if "cache_compiles" in stats:
        lines.append(
            "cache     compiles={cache_compiles:.0f} hits={cache_hits:.0f} "
            "evictions={cache_evictions:.0f} hit_rate={cache_hit_rate:.2f}"
        )
    return "\n".join(line.format(**stats) for line in lines)
