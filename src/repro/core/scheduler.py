"""Work-stealing rebalance policy — the SPMD analogue of the paper's
receiver-initiated private-deque stealing (DESIGN.md §2).

Every worker computes the *same* global plan from the all-gathered stack
occupancy vector (the paper's ``work_available`` array):

  * donors: workers with more than ``keep_min`` entries donate up to
    ``steal_chunk`` entries from the **bottom** of their stacks (near-root ⇒
    large subtrees, the paper's steal-from-the-back heuristic).
  * receivers: workers with empty stacks (receiver-initiated).
  * matching: donated slots are compacted to a global sequence and dealt
    round-robin to receivers — slot ``s`` goes to receiver-rank ``s mod n_recv``
    at intake position ``s div n_recv``; intake is capped so a donor's
    accepted slots are always a *prefix* of its donation (donors simply keep
    the rest).

Everything is branch-free jnp so it lowers inside ``lax.while_loop``, and —
because the plan is a pure function of the occupancy vector — it is the
*shared decision procedure* of both engine paths (DESIGN.md §2.3–§2.4):
single-device, ``plan_steals`` consumes the local ``[V]`` sizes directly;
mesh-sharded, each device calls it on the ``lax.all_gather``-ed global
sizes inside ``shard_map`` and acts only on its own shard of the answer,
so no coordinator and no extra agreement round are needed.  Counters stay
int32 per worker per device (bounds in DESIGN.md §2.5).  The same policy
is reused host-side (numpy) by the GNN irregular-batch balancer
(`repro.models.gnn.sampler.balance_buckets`).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class StealPolicy:
    steal_chunk: int = 4  # entries donated per donor per round (the paper's
    # task-group size; group size 4 was the paper's best — Fig. 4)
    keep_min: int = 2  # donors never drop below this many entries
    recv_cap: int = 4  # max entries a receiver accepts per round


def plan_steals(
    sizes: jnp.ndarray, policy: StealPolicy
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compute the global steal plan from the stack-occupancy vector.

    Args:
      sizes: ``[V]`` int32 per-worker stack sizes.
      policy: steal policy constants (static).

    Returns:
      donate:     ``[V]`` int32 — entries each donor offers (bottom of stack).
      accepted:   ``[V]`` int32 — entries actually taken from each donor
                  (always a prefix of its offer).
      dest_rank:  ``[V, steal_chunk]`` int32 — receiver *rank* for each donated
                  slot, ``-1`` if the slot is not accepted.
      dest_pos:   ``[V, steal_chunk]`` int32 — intake position at the receiver.
    """
    v = sizes.shape[0]
    c = policy.steal_chunk
    donate = jnp.where(
        sizes > policy.keep_min,
        jnp.minimum(c, sizes - policy.keep_min),
        0,
    ).astype(jnp.int32)
    hungry = sizes == 0
    n_recv = jnp.sum(hungry).astype(jnp.int32)

    # Global valid-slot index (donor-major, so per-donor slots stay contiguous
    # and acceptance-by-threshold keeps a donor's accepted slots a prefix).
    slot_j = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (v, c))
    valid = slot_j < donate[:, None]
    start = jnp.cumsum(donate) - donate  # exclusive prefix sum [V]
    gidx = start[:, None] + slot_j  # [V, C] global index among valid slots
    budget = n_recv * policy.recv_cap
    accepted_slot = valid & (gidx < budget)

    safe_recv = jnp.maximum(n_recv, 1)
    dest_rank = jnp.where(accepted_slot, gidx % safe_recv, -1).astype(jnp.int32)
    dest_pos = jnp.where(accepted_slot, gidx // safe_recv, 0).astype(jnp.int32)
    accepted = jnp.sum(accepted_slot, axis=1).astype(jnp.int32)
    return donate, accepted, dest_rank, dest_pos


def receiver_workers(sizes: jnp.ndarray) -> jnp.ndarray:
    """``[V]`` worker index per receiver rank (padded with ``-1``)."""
    v = sizes.shape[0]
    hungry = sizes == 0
    rrank = jnp.cumsum(hungry.astype(jnp.int32)) - 1
    wor = jnp.full((v,), -1, dtype=jnp.int32)
    wor = wor.at[jnp.where(hungry, rrank, v)].set(
        jnp.arange(v, dtype=jnp.int32), mode="drop"
    )
    return wor


# ---------------------------------------------------------------------------
# Host-side (numpy) variant: static balanced assignment of weighted buckets.
# Used by the GNN sampler to spread skewed subgraph batches over shards — the
# paper's load-balancing insight applied to irregular minibatches.
# ---------------------------------------------------------------------------

def balance_assignment(weights: np.ndarray, n_shards: int) -> np.ndarray:
    """Greedy longest-processing-time assignment of weighted items to shards.

    Returns ``[len(weights)]`` shard ids.  LPT is a 4/3-approximation of
    makespan — adequate for batch balancing; the *dynamic* balancer (the
    engine's steal rounds) covers residual skew at runtime.
    """
    weights = np.asarray(weights, dtype=np.float64)
    order = np.argsort(-weights, kind="stable")
    load = np.zeros(n_shards, dtype=np.float64)
    out = np.zeros(len(weights), dtype=np.int32)
    for i in order:
        s = int(np.argmin(load))
        out[i] = s
        load[s] += weights[i]
    return out


def imbalance(weights: np.ndarray, assignment: np.ndarray, n_shards: int) -> float:
    """max/mean shard load — 1.0 is perfect balance."""
    load = np.bincount(assignment, weights=weights, minlength=n_shards)
    mean = load.mean()
    return float(load.max() / mean) if mean > 0 else 1.0
