"""Synthetic data generation.

* SGE collections mimicking the paper's three data sets (Table 1), scaled by
  a ``scale`` factor so CPU benchmarks finish in seconds:
    - ``ppis32-like``     dense PPI-style graphs, 32 normally-distributed labels
    - ``graemlin32-like`` dense microbial-network-style, 32 uniform labels
    - ``pdbsv1-like``     large sparse RNA/DNA/protein-style, unique-ish labels
  Patterns are extracted connected subgraphs (guaranteeing ≥ 1 match), sized
  by edge count as in the paper (4 … 256 edges).

* Model-input synthesis for the architecture smoke tests (GNN batches, LM
  token batches, DIN batches) — all numpy, deterministic by seed.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph


# ---------------------------------------------------------------------------
# SGE collections
# ---------------------------------------------------------------------------

def random_graph(
    n: int,
    m: int,
    n_labels: int,
    label_dist: str = "uniform",
    n_edge_labels: int = 1,
    undirected: bool = True,
    seed: int = 0,
) -> Graph:
    rng = np.random.default_rng(seed)
    edges = set()
    tries = 0
    while len(edges) < m and tries < 50 * m:
        u, v = rng.integers(0, n, 2)
        tries += 1
        if u == v:
            continue
        key = (int(u), int(v))
        if key in edges or (undirected and (int(v), int(u)) in edges):
            continue
        edges.add(key)
    edges = sorted(edges)
    if label_dist == "normal":
        raw = rng.normal(n_labels / 2.0, n_labels / 6.0, n)
        labels = np.clip(np.round(raw), 0, n_labels - 1).astype(np.int32)
    else:
        labels = rng.integers(0, n_labels, n).astype(np.int32)
    elabels = rng.integers(0, n_edge_labels, len(edges)).astype(np.int32)
    return Graph.from_edges(n, edges, labels=labels, edge_labels=elabels, undirected=undirected)


def power_law_graph(
    n: int,
    avg_deg: float = 4.0,
    alpha: float = 2.0,
    n_labels: int = 8,
    n_edge_labels: int = 1,
    undirected: bool = True,
    seed: int = 0,
) -> Graph:
    """Random graph with power-law degree skew — the ``n_t ≫ lanes``
    sparse regime the CSR step backend targets (DESIGN.md §6.4).

    Endpoints are sampled with probability ∝ ``rank^-alpha`` (ranks
    permuted over node ids), so a few hubs carry long neighbor rows while
    the tail is near-isolated; ``avg_deg`` fixes the expected mean degree.
    Duplicate pairs and self-loops are dropped, labels are uniform.
    """
    rng = np.random.default_rng(seed)
    m_target = max(1, int(n * avg_deg / 2))
    w = np.arange(1, n + 1, dtype=np.float64) ** -alpha
    w = rng.permutation(w)
    # 30% uniform floor: pure rank^-alpha mass concentrates on a handful of
    # hubs, whose pairings saturate under dedup and starve the edge budget;
    # the floor keeps tail pairs flowing while hubs stay hubs.
    p = 0.7 * w / w.sum() + 0.3 / n
    seen = set()
    edges: List[Tuple[int, int]] = []
    tries = 0
    # heavy-tailed weights resample hub-hub duplicates often; keep drawing
    # until the edge budget is met (the yield per round shrinks as hub pairs
    # saturate, so the bound is generous)
    while len(edges) < m_target and tries < 64:
        tries += 1
        k = 2 * (m_target - len(edges)) + 16
        us = rng.choice(n, size=k, p=p)
        vs = rng.choice(n, size=k, p=p)
        for u, v in zip(us.tolist(), vs.tolist()):
            if u == v:
                continue
            key = (min(u, v), max(u, v)) if undirected else (u, v)
            if key in seen:
                continue
            seen.add(key)
            edges.append((u, v))
            if len(edges) >= m_target:
                break
    return Graph.from_edges(
        n,
        edges,
        labels=rng.integers(0, n_labels, n).astype(np.int32),
        edge_labels=rng.integers(0, n_edge_labels, len(edges)).astype(np.int32),
        undirected=undirected,
    )


def extract_pattern(g: Graph, n_edges: int, seed: int = 0,
                    start: Optional[int] = None) -> Graph:
    """Random connected subgraph with ~n_edges edges (paper pattern style);
    guarantees at least one isomorphic occurrence in ``g``."""
    rng = np.random.default_rng(seed)
    start = int(rng.integers(g.n)) if start is None else int(start)
    nodes = [start]
    node_set = {start}
    kept: List[Tuple[int, int, int]] = []

    def count_directed() -> int:
        return len(kept)

    while count_directed() < n_edges:
        grown = False
        rng.shuffle(nodes)
        for u in list(nodes):
            nbrs = g.neighbors(u)
            rng.shuffle(nbrs)
            for v in nbrs:
                v = int(v)
                if v in node_set:
                    continue
                node_set.add(v)
                nodes.append(v)
                grown = True
                break
            if grown:
                break
        if not grown:
            break
        # collect all induced edges among chosen nodes
        kept = [
            (int(u), int(v), int(l))
            for u, v, l in zip(g.src, g.dst, g.edge_labels)
            if int(u) in node_set and int(v) in node_set
        ]
        if len(kept) >= n_edges:
            break
    kept = [
        (int(u), int(v), int(l))
        for u, v, l in zip(g.src, g.dst, g.edge_labels)
        if int(u) in node_set and int(v) in node_set
    ]
    idx = {u: i for i, u in enumerate(sorted(node_set))}
    edges = [(idx[u], idx[v]) for u, v, _ in kept]
    elabels = [l for _, _, l in kept]
    labels = g.labels[sorted(node_set)]
    return Graph.from_edges(len(idx), edges, labels=labels, edge_labels=elabels)


@dataclasses.dataclass
class Instance:
    target: Graph
    pattern: Graph
    name: str


# name: (n_targets, n, m, nodes_per_label, label_dist) at scale=1.0.
# The nodes/label ratio controls search-space hardness at reduced scale
# (calibrated so the scale=0.5 corpus lands at 10^5–10^6 states per
# collection with clear long/short instance spread — see EXPERIMENTS.md
# §Methodology).  PPIS32-like keeps the paper's skewed (normal) label
# distribution; rare tail labels are what give forward checking its
# singleton domains.
COLLECTIONS = {
    "ppis32-like": (4, 800, 10000, 33, "normal"),
    "graemlin32-like": (4, 500, 7000, 31, "uniform"),
    "pdbsv1-like": (4, 2400, 7200, 240, "uniform"),
}


def make_collection(
    name: str,
    pattern_edges: Sequence[int] = (4, 8, 16, 32),
    patterns_per_target: int = 3,
    scale: float = 1.0,
    seed: int = 0,
) -> List[Instance]:
    """Scaled-down analogue of one of the paper's collections."""
    n_targets, n, m, npl, dist = COLLECTIONS[name]
    out: List[Instance] = []
    for t in range(n_targets):
        tn = max(32, int(n * scale))
        tm = max(tn, int(m * scale))
        n_labels = max(2, round(tn / npl))
        g = random_graph(tn, tm, n_labels, dist, seed=seed * 1000 + t)
        # rare-label node (smallest label class): half the patterns start
        # there, giving the FC singleton conditions the paper's skewed-label
        # collections exhibit
        label_counts = np.bincount(g.labels, minlength=n_labels)
        label_counts = np.where(label_counts == 0, 1 << 30, label_counts)
        rare_nodes = np.nonzero(g.labels == int(np.argmin(label_counts)))[0]
        k = 0
        for pe in pattern_edges:
            for r in range(patterns_per_target):
                start = int(rare_nodes[r % len(rare_nodes)]) if (
                    r % 2 == 1 and len(rare_nodes)
                ) else None
                p = extract_pattern(g, pe, seed=seed * 10000 + t * 100 + k,
                                    start=start)
                if p.m > 0:
                    out.append(Instance(target=g, pattern=p, name=f"{name}/t{t}/e{pe}/r{r}"))
                k += 1
    return out


# ---------------------------------------------------------------------------
# model-input synthesis
# ---------------------------------------------------------------------------

def gnn_batch(
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    n_classes: int = 0,
    with_positions: bool = False,
    n_graphs: int = 1,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {
        "feats": rng.normal(size=(n_nodes, d_feat)).astype(np.float32),
        "src": rng.integers(0, n_nodes, n_edges).astype(np.int32),
        "dst": rng.integers(0, n_nodes, n_edges).astype(np.int32),
    }
    if n_classes > 0:
        out["labels"] = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    if with_positions:
        out["positions"] = rng.normal(size=(n_nodes, 3)).astype(np.float32) * 3.0
    if n_graphs > 1:
        per = n_nodes // n_graphs
        out["graph_ids"] = np.minimum(np.arange(n_nodes) // per, n_graphs - 1).astype(np.int32)
        out["graph_targets"] = rng.normal(size=(n_graphs, 1)).astype(np.float32)
        out.pop("labels", None)
    return out


def mesh_overlay_shapes(
    n_nodes: int, d_edge: int = 4, fanout: int = 4
) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    """Shape/dtype spec of the GraphCast mesh hierarchy (no allocation)."""
    nm = max(8, n_nodes // 4)
    eg2m = n_nodes * fanout
    em = nm * 8
    em2g = n_nodes * fanout
    return {
        "mesh_feats": ((nm, d_edge), "float32"),
        "g2m_src": ((eg2m,), "int32"),
        "g2m_dst": ((eg2m,), "int32"),
        "g2m_efeats": ((eg2m, d_edge), "float32"),
        "mesh_src": ((em,), "int32"),
        "mesh_dst": ((em,), "int32"),
        "mesh_efeats": ((em, d_edge), "float32"),
        "m2g_src": ((em2g,), "int32"),
        "m2g_dst": ((em2g,), "int32"),
        "m2g_efeats": ((em2g, d_edge), "float32"),
    }


MESH_OVERLAY_LOGICAL = {
    "mesh_feats": ("batch", None),
    "g2m_src": ("edge",),
    "g2m_dst": ("edge",),
    "g2m_efeats": ("edge", None),
    "mesh_src": ("edge",),
    "mesh_dst": ("edge",),
    "mesh_efeats": ("edge", None),
    "m2g_src": ("edge",),
    "m2g_dst": ("edge",),
    "m2g_efeats": ("edge", None),
}


def mesh_overlay(
    n_nodes: int, d_edge: int = 4, fanout: int = 4, seed: int = 0
) -> Dict[str, np.ndarray]:
    """Synthetic mesh hierarchy for GraphCast-style cells (DESIGN.md §4)."""
    rng = np.random.default_rng(seed)
    nm = max(8, n_nodes // 4)
    eg2m = n_nodes * fanout
    em = nm * 8
    em2g = n_nodes * fanout
    return {
        "mesh_feats": rng.normal(size=(nm, d_edge)).astype(np.float32),
        "g2m_src": rng.integers(0, n_nodes, eg2m).astype(np.int32),
        "g2m_dst": rng.integers(0, nm, eg2m).astype(np.int32),
        "g2m_efeats": rng.normal(size=(eg2m, d_edge)).astype(np.float32),
        "mesh_src": rng.integers(0, nm, em).astype(np.int32),
        "mesh_dst": rng.integers(0, nm, em).astype(np.int32),
        "mesh_efeats": rng.normal(size=(em, d_edge)).astype(np.float32),
        "m2g_src": rng.integers(0, nm, em2g).astype(np.int32),
        "m2g_dst": rng.integers(0, n_nodes, em2g).astype(np.int32),
        "m2g_efeats": rng.normal(size=(em2g, d_edge)).astype(np.float32),
    }


def lm_batch(batch: int, seq: int, vocab: int, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, (batch, seq)).astype(np.int32)
    labels = np.concatenate([toks[:, 1:], np.full((batch, 1), -1, np.int32)], axis=1)
    return {"tokens": toks, "labels": labels}


def din_batch(
    batch: int, seq_len: int, n_items: int, n_cats: int, d_dense: int, seed: int = 0
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        "hist_items": rng.integers(0, n_items, (batch, seq_len)).astype(np.int32),
        "hist_cats": rng.integers(0, n_cats, (batch, seq_len)).astype(np.int32),
        "hist_len": rng.integers(1, seq_len + 1, batch).astype(np.int32),
        "target_item": rng.integers(0, n_items, batch).astype(np.int32),
        "target_cat": rng.integers(0, n_cats, batch).astype(np.int32),
        "dense": rng.normal(size=(batch, d_dense)).astype(np.float32),
        "click": rng.integers(0, 2, batch).astype(np.int32),
    }


def icosa_mesh_shape(refinement: int) -> Tuple[int, int]:
    """(n_mesh_nodes, n_mesh_edges_directed) of an icosahedral refinement."""
    n = 10 * 4**refinement + 2
    e = 2 * (30 * 4**refinement)
    return n, e
