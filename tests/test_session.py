"""Prepared-query session API: compile-cache behaviour, run/run_batch/stream
agreement with the sequential oracle, and wrapper-vs-session equivalence."""

import pickle

import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    Enumerator,
    SubgraphIndex,
    enumerate_subgraphs,
    prepare_query,
    snap_p_pad,
)
from repro.core.graph import Graph
from repro.core.multi import enumerate_many
from repro.core.ref import ref_enumerate
from tests.conftest import extract_connected_pattern, random_graph

CFG = EngineConfig(n_workers=4, expand_width=2)


def _corpus(rng, n_pats=5):
    tgt = random_graph(rng, 40, 120, n_labels=3)
    pats = []
    while len(pats) < n_pats:
        p = extract_connected_pattern(rng, tgt, int(rng.integers(2, 5)))
        if p.m > 0:
            pats.append(p)
    return tgt, pats


def test_snap_p_pad_buckets():
    assert snap_p_pad(1) == 16
    assert snap_p_pad(16) == 16
    assert snap_p_pad(17) == 32
    assert snap_p_pad(33) == 64
    assert snap_p_pad(128) == 128
    assert snap_p_pad(129) == 256  # escape hatch beyond the last bucket


def test_compile_cache_hits_same_bucket(rng):
    """N same-bucket patterns through one session -> exactly one compile."""
    tgt, pats = _corpus(rng, n_pats=6)
    session = Enumerator(SubgraphIndex.build(tgt), config=CFG)
    for i, p in enumerate(pats):
        session.run(session.prepare(p, name=f"q{i}"))
    info = session.cache_info()
    assert info["compiles"] == 1, info
    assert info["cache_hits"] == len(pats) - 1, info


def test_run_matches_oracle(rng):
    tgt, pats = _corpus(rng)
    session = Enumerator(SubgraphIndex.build(tgt), config=CFG)
    for p in pats:
        ms = session.run(session.prepare(p))
        ref = ref_enumerate(p, tgt, variant="ri-ds-si-fc")
        assert (ms.matches, ms.states) == (ref.matches, ref.states)
        assert ms.matches >= 1  # extracted patterns always occur


def test_run_batch_and_stream_agree_with_run(rng):
    tgt, pats = _corpus(rng, n_pats=7)
    session = Enumerator(SubgraphIndex.build(tgt), config=CFG)
    queries = [session.prepare(p, name=f"q{i}") for i, p in enumerate(pats)]
    singles = [session.run(q) for q in queries]

    batch = session.run_batch(queries, pack_size=3)
    assert len(batch) == len(queries)
    assert [ms.query_index for ms in batch] == list(range(len(queries)))
    assert [ms.name for ms in batch] == [q.name for q in queries]
    for s, b in zip(singles, batch):
        assert (s.matches, s.states) == (b.matches, b.states)

    streamed = {ms.query_index: ms for ms in session.stream(queries, pack_size=3)}
    assert sorted(streamed) == list(range(len(queries)))
    for i, s in enumerate(singles):
        assert (streamed[i].matches, streamed[i].states) == (s.matches, s.states)


def test_run_batch_keeps_unsatisfiable_aligned(rng):
    """The old enumerate_many dropped queries; the session must return one
    result per query, in order, including unsatisfiable ones."""
    tgt, pats = _corpus(rng, n_pats=3)
    # a pattern whose label does not exist in the target: unsatisfiable
    bad = Graph.from_edges(2, [(0, 1)], labels=[99, 0], undirected=True)
    mixed = [pats[0], bad, pats[1], bad, pats[2]]
    session = Enumerator(SubgraphIndex.build(tgt), config=CFG)
    results = session.run_batch([session.prepare(p, name=f"m{i}")
                                 for i, p in enumerate(mixed)], pack_size=2)
    assert len(results) == len(mixed)
    assert [r.name for r in results] == [f"m{i}" for i in range(len(mixed))]
    assert results[1].matches == results[3].matches == 0
    assert results[0].matches >= 1

    # ... and the compat wrapper inherits the fix with its old signature.
    qrs = enumerate_many(mixed, tgt, cfg=CFG, pack_size=2,
                         names=[f"m{i}" for i in range(len(mixed))])
    assert [r.name for r in qrs] == [f"m{i}" for i in range(len(mixed))]
    assert [r.matches for r in qrs] == [r.matches for r in results]


@pytest.mark.parametrize("variant", ["ri", "ri-ds", "ri-ds-si", "ri-ds-si-fc"])
def test_wrapper_equals_session_all_variants(rng, variant):
    tgt, pats = _corpus(rng, n_pats=2)
    session = Enumerator(SubgraphIndex.build(tgt), config=CFG, variant=variant)
    for p in pats:
        ms = session.run(session.prepare(p))
        res = enumerate_subgraphs(p, tgt, variant=variant, config=CFG)
        assert (res.matches, res.states) == (ms.matches, ms.states)


def test_matchset_lazy_mappings(rng):
    tgt, pats = _corpus(rng, n_pats=1)
    session = Enumerator(SubgraphIndex.build(tgt), config=CFG)
    ms = session.run(session.prepare(pats[0]))
    assert ms._match_buf is None  # counting mode: nothing materialized yet
    maps = ms.mappings()
    assert len(maps) == ms.matches
    for m in maps:
        assert len(set(m)) == len(m)  # injective
    assert ms.mappings() is maps  # cached


def test_prepare_batch_matches_per_query_prepare(rng):
    """Batched device domain preprocessing (one vmapped fixpoint call per
    shape bucket) must produce plans identical to per-query numpy prepare,
    and key its jitted fixpoints into the session compile cache."""
    tgt, pats = _corpus(rng, n_pats=8)
    index = SubgraphIndex.build(tgt)
    dev = Enumerator(index, config=CFG)  # domain_backend='device' default
    host = Enumerator(index, config=CFG, domain_backend="numpy")

    qs_dev = dev.prepare_batch(pats, names=[f"q{i}" for i in range(len(pats))])
    qs_host = [host.prepare(p) for p in pats]
    assert [q.name for q in qs_dev] == [f"q{i}" for i in range(len(pats))]
    for a, b in zip(qs_dev, qs_host):
        np.testing.assert_array_equal(a.plan.dom_bits, b.plan.dom_bits)
        assert a.plan.satisfiable == b.plan.satisfiable
        assert a.plan.order.tolist() == b.plan.order.tolist()
    # domain fixpoints live in the same compile cache ('domains' entries)
    info = dev.cache_info()
    assert info["compiles"] >= 1
    # a second same-bucket batch is all cache hits, no new compiles
    before = dev.cache_info()["compiles"]
    dev.prepare_batch(pats)
    assert dev.cache_info()["compiles"] == before

    # raw Graphs through run_batch route through prepare_batch and agree
    res_dev = dev.run_batch(pats, pack_size=3)
    res_host = host.run_batch(qs_host, pack_size=3)
    assert [(m.matches, m.states) for m in res_dev] == [
        (m.matches, m.states) for m in res_host
    ]


def test_prepare_batch_selfloops_and_unsat(rng):
    """Self-loop patterns and unsatisfiable (overflow-label) patterns keep
    their order and results through the batched path."""
    tgt = random_graph(rng, 20, 50, n_labels=2, selfloops=3)
    index = SubgraphIndex.build(tgt)
    session = Enumerator(index, config=CFG)
    good = extract_connected_pattern(rng, tgt, 3)
    if good.m == 0:
        pytest.skip("empty pattern")
    from tests.conftest import bump_edge_label

    bad = bump_edge_label(good, 0, 9)  # label overflow: unsatisfiable
    results = session.run_batch([good, bad, good], pack_size=2)
    assert results[0].matches == results[2].matches >= 1
    assert results[1].matches == 0


def test_index_picklable_and_reusable(rng):
    tgt, pats = _corpus(rng, n_pats=1)
    index = SubgraphIndex.build(tgt)
    index2 = pickle.loads(pickle.dumps(index))
    np.testing.assert_array_equal(index.packed.adj_bits, index2.packed.adj_bits)
    a = Enumerator(index, config=CFG)
    b = Enumerator(index2, config=CFG)
    pa, pb = a.prepare(pats[0]), b.prepare(pats[0])
    assert (a.run(pa).matches, a.run(pa).states) == (b.run(pb).matches, b.run(pb).states)


def test_cache_lru_eviction_bounded(rng):
    """A bounded session must cap its engine cache: LRU entries evict,
    the evictions counter records them, and evicted engines recompile
    correctly on reuse (counts unchanged)."""
    tgt_a = random_graph(rng, 40, 120, n_labels=2)
    tgt_b = random_graph(rng, 30, 80, n_labels=2)  # different n_t: own bucket
    pa = extract_connected_pattern(rng, tgt_a, 3)
    pb = extract_connected_pattern(rng, tgt_b, 3)
    s = Enumerator(config=CFG, max_cache_entries=1)
    qa = prepare_query(pa, tgt_a)
    qb = prepare_query(pb, tgt_b)
    first = s.run(qa)
    assert s.cache_stats() == {"compiles": 1, "cache_hits": 0, "evictions": 0,
                               "entries": 1, "max_entries": 1}
    s.run(qb)  # second bucket evicts the first engine
    assert s.cache_stats()["evictions"] == 1
    assert s.cache_stats()["entries"] == 1
    again = s.run(qa)  # evicted: recompiles, same result
    stats = s.cache_stats()
    assert stats["compiles"] == 3 and stats["cache_hits"] == 0
    assert stats["evictions"] == 2 and stats["entries"] == 1
    assert (again.matches, again.states) == (first.matches, first.states)


def test_cache_lru_hit_refreshes_recency(rng):
    """A cache hit must move the entry to most-recent: with capacity 2,
    touching A before inserting C evicts B, not A."""
    tgts = [random_graph(rng, 30 + 10 * i, 80 + 20 * i, n_labels=2)
            for i in range(3)]
    qs = [prepare_query(extract_connected_pattern(rng, t, 3), t) for t in tgts]
    s = Enumerator(config=CFG, max_cache_entries=2)
    s.run(qs[0])           # cache: [A]
    s.run(qs[1])           # cache: [A, B]
    s.run(qs[0])           # hit refreshes A -> cache: [B, A]
    s.run(qs[2])           # evicts B      -> cache: [A, C]
    compiles_before = s.cache_stats()["compiles"]
    s.run(qs[0])           # must still be a hit
    stats = s.cache_stats()
    assert stats["compiles"] == compiles_before == 3
    assert stats["cache_hits"] == 2 and stats["evictions"] == 1


def test_cache_unbounded_by_default(rng):
    s = Enumerator(config=CFG)
    assert s.max_cache_entries == 0
    assert s.cache_stats()["max_entries"] == 0
    with pytest.raises(ValueError, match="max_cache_entries"):
        Enumerator(config=CFG, max_cache_entries=-1)


def test_run_pack_hook_matches_run(rng):
    """The serving layer's batch-submission hook: one padded pack, results
    in input order, identical to per-query run(); mixed coalesce keys are
    refused."""
    tgt, pats = _corpus(rng, n_pats=5)
    index = SubgraphIndex.build(tgt)
    s = Enumerator(index, config=CFG)
    qs = [s.prepare(p, name=f"q{i}") for i, p in enumerate(pats)]
    singles = [s.run(q) for q in qs]
    packed = s.run_pack(qs, pack_size=4)
    assert [ms.query_index for ms in packed] == list(range(len(qs)))
    for one, ms in zip(singles, packed):
        assert (one.matches, one.states) == (ms.matches, ms.states)

    other = random_graph(rng, 25, 60, n_labels=3)
    qo = prepare_query(extract_connected_pattern(rng, other, 3), other)
    with pytest.raises(ValueError, match="coalesce_key"):
        s.run_pack([qs[0], qo])

    # unsatisfiable lanes come back empty, order preserved, engine untouched
    bad = Graph.from_edges(2, [(0, 1)], labels=[99, 0], undirected=True)
    mixed = s.run_pack([qs[0], s.prepare(bad), qs[1]], pack_size=4)
    assert [ms.query_index for ms in mixed] == [0, 1, 2]
    assert mixed[1].matches == 0
    assert (mixed[0].matches, mixed[2].matches) == (singles[0].matches, singles[1].matches)


def test_overflow_retries_once_with_doubled_cap(rng):
    """A stack_cap too small for the query must not silently undercount:
    run() aborts the overflowed run, warns, retries once with a doubled
    cap, and reports identical counts to a roomy run (retries=1)."""
    tgt = random_graph(rng, 40, 120, n_labels=2)
    pat = extract_connected_pattern(rng, tgt, 6)
    index = SubgraphIndex.build(tgt)
    roomy = Enumerator(index, n_workers=2, expand_width=2)
    ref = roomy.run(roomy.prepare(pat))
    assert ref.retries == 0

    tight = Enumerator(index, n_workers=2, expand_width=2, stack_cap=8)
    with pytest.warns(RuntimeWarning, match="overflowed"):
        ms = tight.run(tight.prepare(pat))
    assert ms.retries == 1
    assert (ms.matches, ms.states) == (ref.matches, ref.states)


def test_overflow_retry_in_batch_path(rng):
    """An overflowed pack lane goes straight to the doubled-cap single
    retry; its MatchSet reports retries=1 and correct counts."""
    tgt = random_graph(rng, 40, 120, n_labels=2)
    pat = extract_connected_pattern(rng, tgt, 6)
    small = extract_connected_pattern(rng, tgt, 3)
    index = SubgraphIndex.build(tgt)
    roomy = Enumerator(index, n_workers=2, expand_width=2)
    ref = {q.name: roomy.run(q).matches
           for q in [roomy.prepare(pat, name="big"), roomy.prepare(small, name="small")]}

    tight = Enumerator(index, n_workers=2, expand_width=2, stack_cap=8)
    qs = [tight.prepare(pat, name="big"), tight.prepare(small, name="small")]
    with pytest.warns(RuntimeWarning, match="overflowed"):
        out = tight.run_batch(qs)
    by_name = {ms.name: ms for ms in out}
    assert by_name["big"].retries == 1
    assert {n: ms.matches for n, ms in by_name.items()} == ref


def test_overflow_raises_when_doubled_cap_still_too_small(rng):
    """If the doubled cap overflows too, the session refuses to guess
    further and demands an explicit budget."""
    tgt = random_graph(rng, 40, 120, n_labels=2)
    pat = extract_connected_pattern(rng, tgt, 6)
    s = Enumerator(SubgraphIndex.build(tgt), n_workers=2, expand_width=2,
                   stack_cap=3)
    with pytest.warns(RuntimeWarning, match="overflowed"):
        with pytest.raises(RuntimeError, match="stack overflow persists"):
            s.run(s.prepare(pat))
