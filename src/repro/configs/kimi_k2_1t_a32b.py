"""kimi-k2-1t-a32b — 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert)
vocab=163840, MoE 384 experts top-8 (+1 shared).  [arXiv:2501.kimi2; unverified]"""

from repro.configs.lm_common import make_lm_arch
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    loss_chunk=65536,  # §Perf iter 2: fewer lm_head re-reads (was 2048)
    vocab_size=163840,
    activation="swiglu",
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048, n_shared_experts=1),
    max_seq_len=32768,
)

SMOKE = LMConfig(
    name="kimi-k2-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    activation="swiglu",
    moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=64, n_shared_experts=1,
                  capacity_round=8),
    max_seq_len=64,
    loss_chunk=16,
    kv_block=8,
)

ARCH = make_lm_arch(CFG, SMOKE, notes="Trillion-param MoE; training memory "
                    "needs >=2048 chips (reported honestly in §Dry-run); "
                    "dry-run validates sharding at 256/512.")
