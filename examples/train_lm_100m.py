"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

  PYTHONPATH=src python examples/train_lm_100m.py [--steps 200] [--tiny]

Exercises the full production stack on CPU: scanned+remat transformer,
chunked loss, AdamW with warmup+cosine, gradient accumulation, atomic
checkpointing with restart, and the pull-based prefetcher.  ``--tiny``
shrinks the model for CI-speed runs (the default 100M config needs ~2 GB and
tens of minutes on this container).
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import train_lm
from repro.models.transformer import LMConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_100m")
    args = ap.parse_args()

    if args.tiny:
        cfg = LMConfig(name="lm-tiny", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, d_ff=128, vocab_size=512,
                       activation="swiglu", max_seq_len=64, loss_chunk=32,
                       kv_block=16)
        batch, seq, accum = 4, 48, 1
    else:
        # ~100M params: 12L × d512 × ff2048, 32k vocab
        cfg = LMConfig(name="lm-100m", n_layers=12, d_model=512, n_heads=8,
                       n_kv_heads=4, d_ff=2048, vocab_size=32768,
                       activation="swiglu", max_seq_len=512, loss_chunk=512,
                       kv_block=128)
        batch, seq, accum = 8, 256, 2
    print(f"[example] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {batch}x{seq} accum {accum}")
    _, _, history = train_lm(
        cfg, steps=args.steps, batch=batch, seq=seq,
        ckpt_dir=args.ckpt_dir, accum=accum,
    )
    print(f"[example] loss {history[0]:.3f} -> {history[-1]:.3f} "
          f"({'improved' if history[-1] < history[0] else 'NOT improved'})")
    return 0 if history[-1] < history[0] else 1


if __name__ == "__main__":
    sys.exit(main())
