"""Shared benchmark machinery.

CPU-measurement methodology (documented in EXPERIMENTS.md §Methodology):

* **Search-space size** (paper Figs. 7/8/12): states-explored counters are
  deterministic and hardware-independent — they reproduce the paper's
  qualitative claims exactly.
* **Parallel speedup** (paper Tables 2/3, Figs. 3/5/6): this container has
  one CPU core, so wall-clock cannot show multi-worker speedup.  We report
  the **BSP step-count speedup**: the engine advances all ``V`` workers in
  lock-step, so the number of engine steps to drain the search space is the
  parallel makespan under equal-step cost; ``speedup(V) = steps(V=1) /
  steps(V)`` with the same per-worker expansion width.  Work stealing, task
  coalescing, and worker-count effects all act through this quantity.
  Wall-clock per state (states/sec) is additionally reported where the
  comparison is same-configuration (C6).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import EngineConfig, SubgraphIndex
from repro.core.session import shared_enumerator

from repro.data import graphgen

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


@dataclasses.dataclass
class InstanceRun:
    name: str
    matches: int
    states: int
    steps: int
    steals: int
    wall_s: float
    per_worker_states: np.ndarray


def run_instance(
    inst: graphgen.Instance,
    variant: str = "ri-ds-si-fc",
    cfg: Optional[EngineConfig] = None,
    packed_cache: Optional[dict] = None,
) -> InstanceRun:
    cfg = cfg or EngineConfig(n_workers=16, expand_width=4)
    key = id(inst.target)
    packed_cache = packed_cache if packed_cache is not None else {}
    if key not in packed_cache:
        packed_cache[key] = SubgraphIndex.build(inst.target)
    index = packed_cache[key]
    session = shared_enumerator(cfg)
    query = session.prepare(inst.pattern, variant=variant, name=inst.name, index=index)
    if not query.satisfiable:
        return InstanceRun(inst.name, 0, 0, 0, 0, 0.0, np.zeros(cfg.n_workers))
    t0 = time.perf_counter()
    ms = session.run(query)
    wall = time.perf_counter() - t0
    return InstanceRun(
        name=inst.name,
        matches=ms.matches,
        states=ms.states,
        steps=ms.steps,
        steals=ms.steals,
        wall_s=wall,
        per_worker_states=ms.per_worker_states,
    )


def bench_instances(scale: float = 0.5, seed: int = 7) -> Dict[str, List[graphgen.Instance]]:
    """The benchmark corpus: one scaled-down analogue per paper collection.

    Pattern sizes follow the paper: 4–256 edges on the dense collections,
    larger (sparser) patterns on PDBSv1 where RI's hard instances live."""
    return {
        "ppis32-like": graphgen.make_collection(
            "ppis32-like", pattern_edges=(8, 16, 24), patterns_per_target=2,
            scale=scale, seed=seed),
        "graemlin32-like": graphgen.make_collection(
            "graemlin32-like", pattern_edges=(8, 16, 24), patterns_per_target=2,
            scale=scale, seed=seed + 1),
        "pdbsv1-like": graphgen.make_collection(
            "pdbsv1-like", pattern_edges=(16, 32, 48), patterns_per_target=2,
            scale=scale, seed=seed + 2),
    }


def save_json(name: str, payload) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=_np_default)
    return path


def write_json_path(path: Optional[str], payload) -> Optional[str]:
    """Shared ``--json PATH`` writer: persist a benchmark payload (numpy
    values included) to an explicit path — e.g. a committed ``BENCH_*.json``
    at the repo root — next to the artifacts/ copy ``save_json`` keeps.
    No-op on ``None`` so callers can pass the flag through unconditionally.
    """
    if path is None:
        return None
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=_np_default)
        f.write("\n")
    return path


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
