"""Public API for the subgraph-enumeration core.

    from repro.core import enumerate_subgraphs
    res = enumerate_subgraphs(pattern, target, variant="ri-ds-si-fc",
                              n_workers=16)
    print(res.matches, res.states)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Union

from repro.core import engine as engine_mod
from repro.core.engine import EngineConfig, EngineResult
from repro.core.graph import Graph, PackedGraph
from repro.core.plan import SearchPlan, build_plan


@dataclasses.dataclass
class EnumerationResult:
    matches: int
    states: int
    steps: int
    steals: int
    steal_rounds: int
    mean_steal_depth: float
    preprocess_s: float
    match_s: float
    engine: EngineResult
    plan: SearchPlan

    @property
    def total_s(self) -> float:
        return self.preprocess_s + self.match_s


def enumerate_subgraphs(
    pattern: Graph,
    target: Union[Graph, PackedGraph],
    variant: str = "ri-ds-si-fc",
    config: Optional[EngineConfig] = None,
    **config_kwargs,
) -> EnumerationResult:
    """Enumerate all non-induced subgraphs of ``target`` isomorphic to
    ``pattern``.

    Args:
      pattern: the (small) pattern graph.
      target: the target graph; a pre-packed :class:`PackedGraph` is reused
        across queries against the same target (the common case in the
        paper's collections: thousands of patterns per target).
      variant: ``ri`` | ``ri-ds`` | ``ri-ds-si`` | ``ri-ds-si-fc``.
      config: engine configuration; keyword overrides accepted.
    """
    cfg = config or EngineConfig(**config_kwargs)
    if config is not None and config_kwargs:
        cfg = dataclasses.replace(config, **config_kwargs)

    t0 = time.perf_counter()
    packed = target if isinstance(target, PackedGraph) else PackedGraph.from_graph(target)
    plan = build_plan(pattern, packed, variant=variant)
    t1 = time.perf_counter()

    if not plan.satisfiable:
        empty = EngineResult(
            matches=0, states=0, steps=0, steals=0, steal_rounds=0,
            mean_steal_depth=0.0, mean_expand_depth=0.0,
            per_worker_states=None,
            per_worker_matches=None, overflow=False, match_buf=None,
        )
        return EnumerationResult(
            matches=0, states=0, steps=0, steals=0, steal_rounds=0,
            mean_steal_depth=0.0, preprocess_s=t1 - t0, match_s=0.0,
            engine=empty, plan=plan,
        )

    res = engine_mod.run(plan, cfg)
    t2 = time.perf_counter()
    if res.overflow:
        raise RuntimeError(
            "engine stack overflow — increase EngineConfig.stack_cap "
            f"(current auto={cfg.resolved_stack_cap(plan.p_pad)})"
        )
    return EnumerationResult(
        matches=res.matches,
        states=res.states,
        steps=res.steps,
        steals=res.steals,
        steal_rounds=res.steal_rounds,
        mean_steal_depth=res.mean_steal_depth,
        preprocess_s=t1 - t0,
        match_s=t2 - t1,
        engine=res,
        plan=plan,
    )
