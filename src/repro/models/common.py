"""Shared model substrate: param trees with logical sharding axes, norms,
initializers, MLPs.

Parameters live in nested dicts of jnp arrays.  Every model module exposes:

  * ``Config`` dataclass (static hyperparameters)
  * ``init_params(rng, cfg)``     — real arrays (smoke tests / examples)
  * ``abstract_params(cfg)``      — ShapeDtypeStructs (dry-run lowering)
  * ``param_logical(cfg)``        — matching pytree of per-dim logical axis
                                    tuples (see distributed/shardings.py)

``ParamSpec`` triples keep the three views in sync from one declaration.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One parameter declaration: shape + logical sharding axes + init scale."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones
    scale: Optional[float] = None  # None -> 1/sqrt(fan_in)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, rng: jax.Array) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else max(1, self.shape[-1])
        scale = self.scale if self.scale is not None else 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(rng, self.shape, jnp.float32) * scale).astype(self.dtype)


SpecTree = Dict[str, Any]  # nested dicts of ParamSpec


def abstract_from_specs(specs: SpecTree):
    return jax.tree.map(lambda s: s.abstract(), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def logical_from_specs(specs: SpecTree):
    return jax.tree.map(lambda s: s.logical, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def init_from_specs(rng: jax.Array, specs: SpecTree):
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    rngs = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [s.materialize(r) for s, r in zip(leaves, rngs)])


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dtype) * gamma


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dtype) * gamma + beta


def squared_relu(x):
    """Primer's squared ReLU — nemotron-4's activation."""
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS: Dict[str, Callable] = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "squared_relu": squared_relu,
}


def dot(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Matmul in the activation dtype.

    §Perf iter 5: emitting the dot at fp32 made GSPMD place the
    tensor-parallel all-reduce on fp32 partials (2× collective and
    activation bytes per projection).  The MXU accumulates fp32 internally
    for bf16 operands regardless, so the HLO-level output dtype stays bf16;
    only cross-shard partial sums lose the extra mantissa — the standard
    TP trade (Megatron does the same).
    """
    return jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
    )
