"""Train-step builder and the fault-tolerant training loop.

``make_train_step`` turns any ``loss_fn(params, batch) -> (loss, metrics)``
into a full step: value-and-grad → global-norm clip → AdamW (ZeRO-sharded
states) → metrics.  Optional gradient accumulation runs microbatches through
``lax.scan`` (keeps the HLO small and lets XLA overlap the grad all-reduce of
microbatch *i* with the compute of *i+1*).

``TrainLoop`` (used by launch/train.py and examples) adds production
concerns: periodic atomic checkpoints, restart-from-latest, NaN/inf guards
with step skipping, throughput accounting, and a pull-based prefetched data
iterator (straggler mitigation at the input layer).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_mod
from repro.train.optimizer import AdamWConfig, AdamWState


def make_train_step(
    loss_fn: Callable,
    opt_cfg: AdamWConfig,
    accum_steps: int = 1,
):
    """Build ``step(params, opt_state, batch) -> (params, opt_state, metrics)``.

    With ``accum_steps > 1``, ``batch`` must have a leading microbatch axis of
    that size.
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(params, opt_state: AdamWState, batch):
        if accum_steps == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def micro(carry, mb):
                acc = carry
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            acc, (losses, metricses) = jax.lax.scan(micro, zeros, batch)
            grads = jax.tree.map(lambda g: g / accum_steps, acc)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)
        new_params, new_opt, om = opt_mod.update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss_total"] = loss
        return new_params, new_opt, metrics

    return step


# ---------------------------------------------------------------------------
# data prefetcher (pull-based, bounded queue => backpressure)
# ---------------------------------------------------------------------------

class Prefetcher:
    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()

        def worker():
            try:
                for x in it:
                    self._q.put(x)
            finally:
                self._q.put(self._done)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        x = self._q.get()
        if x is self._done:
            raise StopIteration
        return x


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    keep_checkpoints: int = 3
    skip_nonfinite: bool = True
    max_consecutive_bad: int = 10


class TrainLoop:
    """Checkpointed training loop.  ``ckpt_dir=None`` disables persistence."""

    def __init__(
        self,
        step_fn: Callable,
        loop_cfg: LoopConfig,
        ckpt_dir: Optional[str] = None,
        log: Callable[[str], None] = print,
    ):
        self.step_fn = step_fn
        self.cfg = loop_cfg
        self.ckpt_dir = ckpt_dir
        self.log = log

    def run(self, params, opt_state, data: Iterator, start_step: int = 0):
        from repro.checkpoint import store as ckpt_store

        if self.ckpt_dir:
            restored = ckpt_store.restore_latest(
                self.ckpt_dir, like_params=params, like_opt=opt_state
            )
            if restored is not None:
                start_step, params, opt_state = restored
                self.log(f"[trainer] restored checkpoint at step {start_step}")

        data = Prefetcher(iter(data))
        bad = 0
        t0 = time.perf_counter()
        history = []
        for step_i, batch in zip(range(start_step, self.cfg.total_steps), data):
            new_params, new_opt, metrics = self.step_fn(params, opt_state, batch)
            loss = float(metrics.get("loss_total", metrics.get("loss", jnp.nan)))
            if self.cfg.skip_nonfinite and not jnp.isfinite(loss):
                bad += 1
                self.log(f"[trainer] step {step_i}: non-finite loss, skipping update ({bad})")
                if bad > self.cfg.max_consecutive_bad:
                    raise RuntimeError("too many consecutive non-finite steps")
                continue
            bad = 0
            params, opt_state = new_params, new_opt
            history.append(loss)
            if step_i % self.cfg.log_every == 0:
                dt = time.perf_counter() - t0
                self.log(f"[trainer] step {step_i} loss {loss:.4f} ({dt:.1f}s)")
            if self.ckpt_dir and step_i > 0 and step_i % self.cfg.checkpoint_every == 0:
                ckpt_store.save(
                    self.ckpt_dir, step_i, params, opt_state,
                    keep=self.cfg.keep_checkpoints, async_write=True,
                )
        if self.ckpt_dir:
            ckpt_store.save(
                self.ckpt_dir, self.cfg.total_steps, params, opt_state,
                keep=self.cfg.keep_checkpoints, async_write=False,
            )
        return params, opt_state, history
