"""The StepBackend seam (DESIGN.md §6.2) and the fused Pallas extend-step
kernel (§6.3).

Three layers of evidence that the fused step is the loose-ops step:

* kernel vs pure-jnp oracle (`extend_step_ref`), shape/dtype sweeps —
  bit-exact;
* jnp vs pallas-interpret **backends** produce bit-identical
  :class:`EngineState` pytrees (stacks, counters, match buffers) over
  random plans/configs — the hypothesis property test;
* whole-engine runs (single-device and mesh-sharded — the multi-device
  test runs in CI's 4-virtual-device job) agree counter-for-counter.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, Enumerator, SubgraphIndex
from repro.core import engine as eng
from repro.core import extend
from repro.core.graph import PackedGraph
from repro.core.plan import build_plan
from repro.kernels import ops
from repro.kernels import ref as kref
from tests.conftest import extract_connected_pattern, random_graph

SHAPES_ES = [
    # (b, w, mp, n_rows, p_pad)
    (1, 1, 1, 2, 1),
    (4, 3, 2, 10, 5),
    (16, 130, 4, 64, 8),
    (8, 128, 8, 32, 64),
    (32, 257, 6, 100, 16),
    (64, 13, 0, 7, 4),  # mp == 0: degenerate parent-free plans
]


@pytest.mark.parametrize("b,w,mp,n_rows,p_pad", SHAPES_ES)
def test_extend_step_kernel_vs_oracle(rng, b, w, mp, n_rows, p_pad):
    rows = np.concatenate(
        [
            rng.integers(0, 2**32, (n_rows, w), dtype=np.uint32),
            np.full((1, w), 0xFFFFFFFF, np.uint32),
        ],
        0,
    )
    dom = rng.integers(0, 2**32, (p_pad, w), dtype=np.uint32)
    child_pos = rng.integers(0, p_pad, b).astype(np.int32)
    row_idx = rng.integers(0, n_rows + 1, (b, mp)).astype(np.int32)
    depth = rng.integers(0, p_pad, b).astype(np.int32)
    n_p = np.int32(p_pad // 2 + 1)
    used = rng.integers(0, 2**32, (b, w), dtype=np.uint32)
    # mix of empty, sparse, and dense candidate bitmaps
    cand = rng.integers(0, 2**32, (b, w), dtype=np.uint32)
    cand[:: 3] = 0
    args = [jnp.asarray(x) for x in (rows, dom, child_pos, row_idx, depth,
                                     n_p, used, cand)]
    got = ops.extend_step(*args)
    want = kref.extend_step_ref(*args)
    for g, wnt, name in zip(got, want, ("cand2", "child_cand", "meta")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(wnt), err_msg=name)


def _case(rng, n=40, m=120, pat_n=5, **graph_kw):
    tgt = random_graph(rng, n, m, n_labels=3, **graph_kw)
    pat = extract_connected_pattern(rng, tgt, pat_n)
    return tgt, pat


def _cfg_pair(**kw):
    a = EngineConfig(step_backend="jnp", **kw)
    b = EngineConfig(step_backend="pallas", **kw)
    return a, b


def _assert_results_identical(a, b):
    assert (a.matches, a.states, a.steps, a.steals, a.steal_rounds) == (
        b.matches, b.states, b.steps, b.steals, b.steal_rounds,
    )
    np.testing.assert_array_equal(a.per_worker_states, b.per_worker_states)
    np.testing.assert_array_equal(a.per_worker_matches, b.per_worker_matches)
    np.testing.assert_array_equal(a.per_worker_steals, b.per_worker_steals)


def test_engine_backends_identical_end_to_end(rng):
    """Whole runs agree counter-for-counter, mappings included."""
    tgt, pat = _case(rng)
    plan = build_plan(pat, PackedGraph.from_graph(tgt))
    cfg_j, cfg_p = _cfg_pair(n_workers=4, expand_width=2, collect_matches=64)
    a = eng.run(plan, cfg_j)
    b = eng.run(plan, cfg_p)
    _assert_results_identical(a, b)
    np.testing.assert_array_equal(a.match_buf, b.match_buf)


def test_engine_backends_identical_store_used_false(rng):
    tgt, pat = _case(rng)
    plan = build_plan(pat, PackedGraph.from_graph(tgt))
    cfg_j, cfg_p = _cfg_pair(n_workers=4, expand_width=2, store_used=False)
    _assert_results_identical(eng.run(plan, cfg_j), eng.run(plan, cfg_p))


def test_session_threads_step_backend(rng):
    """step_backend= flows through Enumerator kwargs; configs with
    different backends must not share a compile-cache entry."""
    tgt, pat = _case(rng)
    idx = SubgraphIndex.build(tgt)
    a = Enumerator(idx, n_workers=2, expand_width=2)
    b = Enumerator(idx, n_workers=2, expand_width=2, step_backend="pallas")
    assert b.config.step_backend == "pallas"
    ra = a.run(a.prepare(pat))
    rb = b.run(b.prepare(pat))
    assert (ra.matches, ra.states, ra.steps) == (rb.matches, rb.states, rb.steps)


def test_unknown_step_backend_rejected():
    with pytest.raises(ValueError):
        EngineConfig(step_backend="bogus")


def test_resolve_interpret_env_override(monkeypatch):
    monkeypatch.delenv("SGE_PALLAS_INTERPRET", raising=False)
    default = ops.resolve_interpret(None)
    assert default == (jax.default_backend() != "tpu")
    monkeypatch.setenv("SGE_PALLAS_INTERPRET", "0")
    assert ops.resolve_interpret(None) is False
    monkeypatch.setenv("SGE_PALLAS_INTERPRET", "1")
    assert ops.resolve_interpret(None) is True
    # set-but-empty (the `VAR= cmd` clearing idiom) falls back to autodetect
    monkeypatch.setenv("SGE_PALLAS_INTERPRET", "")
    assert ops.resolve_interpret(None) == default
    # explicit argument beats the env
    assert ops.resolve_interpret(False) is False
    assert ops.resolve_interpret(True) is True


# ---------------------------------------------------------------------------
# property test: backends produce bit-identical step states
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment without hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        expand_width=st.integers(1, 4),
        n_workers=st.integers(1, 4),
        store_used=st.booleans(),
        collect=st.booleans(),
        n_steps=st.integers(1, 6),
    )
    def test_step_backends_bit_identical_states(
        seed, expand_width, n_workers, store_used, collect, n_steps
    ):
        """jnp and pallas-interpret step backends must produce bit-identical
        EngineState pytrees — stacks, ring bookkeeping, counters, and match
        buffers — after any number of shared expansion steps."""
        rng = np.random.default_rng(seed)
        tgt = random_graph(rng, 16, 40, n_labels=2,
                           selfloops=int(rng.integers(0, 3)))
        pat = extract_connected_pattern(rng, tgt, int(rng.integers(3, 6)))
        if pat.m == 0:
            return
        plan = build_plan(pat, PackedGraph.from_graph(tgt))
        kw = dict(
            n_workers=n_workers,
            expand_width=expand_width,
            store_used=store_used,
            collect_matches=8 if collect else 0,
        )
        cfg_j, cfg_p = _cfg_pair(**kw)
        arrays = eng.make_plan_arrays(plan)

        def run_steps(cfg):
            step = jax.jit(extend.make_step_fn(cfg, arrays))
            state = eng.init_state(plan, cfg)
            for _ in range(n_steps):
                state = step(state)
            return state

        sj = run_steps(cfg_j)
        sp = run_steps(cfg_p)
        for name, a, b in zip(eng.EngineState._fields, sj, sp):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"StepState field {name}"
            )


# ---------------------------------------------------------------------------
# mesh path through the shared step (runs in CI's 4-virtual-device job)
# ---------------------------------------------------------------------------

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=N)",
)


@multi_device
def test_mesh_path_uses_shared_step_both_backends(rng):
    """Sharding over 2 devices with either backend changes nothing: the
    mesh driver calls the same shared step as the single-device path."""
    tgt, pat = _case(rng, n=48, m=160)
    plan = build_plan(pat, PackedGraph.from_graph(tgt))
    mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    for backend in ("jnp", "pallas"):
        cfg = EngineConfig(n_workers=4, expand_width=2, step_backend=backend)
        ref = eng.run(plan, cfg)
        sh = eng.run(plan, cfg, mesh=mesh)
        _assert_results_identical(ref, sh)
