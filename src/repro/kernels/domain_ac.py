"""Pallas TPU kernels for RI-DS arc-consistency filtering (DESIGN.md §5).

One AC test for a single constraint arc ``(p, q, dir, label)`` asks, for
every target node ``t``, whether ``adj_rows[t] ∧ D(q)`` has any set bit —
a ``[n_t, w]`` bitmap AND against a broadcast ``[w]`` mask followed by a
per-row any-reduce.  This is the SDDMM-shaped part of domain preprocessing
(DESIGN.md §2): dense rows stream from HBM once, the mask stays resident in
VMEM.

Two granularities:

* :func:`adjacency_any` — one arc.  Grid over row tiles of ``tr`` rows;
  block ``(tr, w)`` of adjacency rows, mask block ``(1, w)`` pinned (same
  index every step), output ``(tr, 1)`` int32 flags.  ``w`` padded to
  128-word lanes, ``tr`` a multiple of 8 sublanes.  Composes with ``vmap``
  (plain BlockSpecs), which is what the batched domain engine uses.
* :func:`arc_any_sweep` — **all arcs of one AC sweep in a single
  ``pallas_call``**.  Grid ``(n_arcs, row tiles)``; the adjacency operand's
  ``index_map`` reads the scalar-prefetched ``arc_row`` table to pick which
  ``(label, dir)`` plane the pipeline DMAs next — the same
  pointer-chasing-by-prefetch trick as `candidate_mask`.  Used by the
  single-query device fixpoint (`repro.core.domains.device_fixpoint`); the
  scalar-prefetch grid spec has no vmap rule, so the batched path falls
  back to per-arc kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.candidate_mask import pad_words

ROW_TILE = 256


def _kernel(rows_ref, mask_ref, out_ref):
    hit = (rows_ref[...] & mask_ref[...]) != 0  # [tr, w] bool
    out_ref[...] = jnp.any(hit, axis=-1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile"))
def adjacency_any(
    rows: jnp.ndarray,  # [n_t, w] uint32
    mask: jnp.ndarray,  # [w] uint32
    interpret: bool = True,
    row_tile: int = ROW_TILE,
) -> jnp.ndarray:
    """Per-row any-bit test of ``rows ∧ mask`` -> ``[n_t]`` int32 {0,1}."""
    n_t, w = rows.shape
    wp = pad_words(w)
    tr = row_tile
    n_pad = ((n_t + tr - 1) // tr) * tr
    rows_p = jnp.pad(rows, ((0, n_pad - n_t), (0, wp - w)))
    mask_p = jnp.pad(mask, (0, wp - w))[None, :]

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // tr,),
        in_specs=[
            pl.BlockSpec((tr, wp), lambda i: (i, 0)),
            pl.BlockSpec((1, wp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
        interpret=interpret,
    )(rows_p, mask_p)
    return out[:n_t, 0]


def _sweep_kernel(arc_row_ref, adj_ref, mask_ref, out_ref):
    hit = (adj_ref[0] & mask_ref[...]) != 0  # [tr, w] & [1, w] -> [tr, w]
    out_ref[...] = jnp.any(hit, axis=-1)[None, :].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile"))
def arc_any_sweep(
    adj_flat: jnp.ndarray,  # [n_planes, n_t, w] uint32 (label-major planes)
    arc_row: jnp.ndarray,  # [n_arcs] int32 plane index per arc
    masks: jnp.ndarray,  # [n_arcs, w] uint32 (D(q) bitmap per arc)
    interpret: bool = True,
    row_tile: int = ROW_TILE,
) -> jnp.ndarray:
    """All arcs of one AC sweep in one kernel call.

    ``out[a, t] = any(adj_flat[arc_row[a], t] ∧ masks[a])`` — ``[n_arcs,
    n_t]`` int32 {0, 1}.  The adjacency plane per grid step is chosen by the
    scalar-prefetched ``arc_row`` table, so the DMA engine chases the arc
    table while the VPU reduces the previous tile.
    """
    n_arcs, w = masks.shape
    n_t = adj_flat.shape[1]
    wp = pad_words(w)
    tr = min(row_tile, max(8, ((n_t + 7) // 8) * 8))
    n_pad = ((n_t + tr - 1) // tr) * tr
    adj_p = jnp.pad(adj_flat, ((0, 0), (0, n_pad - n_t), (0, wp - w)))
    masks_p = jnp.pad(masks, ((0, 0), (0, wp - w)))

    def adj_map(a, i, arc_row_s):
        return (arc_row_s[a], i, 0)

    def mask_map(a, i, arc_row_s):
        return (a, 0)

    def out_map(a, i, arc_row_s):
        return (a, i)

    out = pl.pallas_call(
        _sweep_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_arcs, n_pad // tr),
            in_specs=[
                pl.BlockSpec((1, tr, wp), adj_map),
                pl.BlockSpec((1, wp), mask_map),
            ],
            out_specs=pl.BlockSpec((1, tr), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((n_arcs, n_pad), jnp.int32),
        interpret=interpret,
    )(arc_row.astype(jnp.int32), adj_p, masks_p)
    return out[:, :n_t]
