"""Per-client streaming result handles (DESIGN.md §7).

A :class:`ResultStream` is what :meth:`EnumerationService.submit` hands
back: a thread-safe one-producer (the dispatcher) / one-consumer (the
client) channel carrying zero or more :class:`ResultChunk` slices of the
query's match mappings followed by exactly one terminal
:class:`ResultStatus`.

Chunks are deterministic: the dispatcher slices the engine's match buffer
in buffer order into ``chunk_size`` pieces with consecutive ``seq``
numbers, so for a given query + config the chunk sequence is identical
across runs and its concatenation is bit-identical to a one-shot
``Enumerator.run(query, collect_matches=...)`` — the property
``tests/test_serving.py`` locks down.  Counting-mode queries
(``collect=0``) stream no chunks, only the terminal status.
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
from typing import Iterator, List, Optional, Tuple

from repro.core.session import MatchSet


@dataclasses.dataclass(frozen=True)
class ResultChunk:
    """One slice of a query's match mappings, in engine-buffer order."""

    seq: int                                   # 0-based, consecutive
    mappings: Tuple[Tuple[int, ...], ...]      # order position -> target node
    final: bool                                # last chunk of this stream


@dataclasses.dataclass(frozen=True)
class ResultStatus:
    """Terminal status of a served query."""

    ok: bool
    matchset: Optional[MatchSet]   # present iff ok
    error: Optional[str]           # present iff not ok
    retries: int                   # PR-4 overflow retries spent (0 = clean)
    n_chunks: int
    latency_s: float               # submit -> terminal


class ServiceError(RuntimeError):
    """Raised by :meth:`ResultStream.result` when the query failed."""


_DONE = object()


class ResultStream:
    """Client-side handle for one submitted query."""

    def __init__(self, name: str, tenant: str):
        self.name = name
        self.tenant = tenant
        self._q: "queue_mod.Queue" = queue_mod.Queue()
        self._done = threading.Event()
        self._status: Optional[ResultStatus] = None
        self._seen: List[ResultChunk] = []   # consumed chunks (replayable)
        self._drained = False

    # -- producer side (service dispatcher only) ---------------------------

    def _push_chunk(self, chunk: ResultChunk) -> None:
        self._q.put(chunk)

    def _finish(self, status: ResultStatus) -> None:
        self._status = status
        self._done.set()
        self._q.put(_DONE)

    # -- consumer side (one consumer thread; chunks replay once seen) ------

    def __iter__(self) -> Iterator[ResultChunk]:
        """Yield chunks as they arrive; returns when the stream completes
        (the terminal status is read via :meth:`result` / :meth:`status`).
        Already-consumed chunks are replayed first, so iterating twice is
        safe."""
        yield from self._seen
        while not self._drained:
            item = self._q.get()
            if item is _DONE:
                self._drained = True
                return
            self._seen.append(item)
            yield item

    def chunks(self, timeout: Optional[float] = None) -> List[ResultChunk]:
        """Every chunk of the stream (blocks until terminal)."""
        while not self._drained:
            item = self._q.get(timeout=timeout)
            if item is _DONE:
                self._drained = True
                break
            self._seen.append(item)
        return list(self._seen)

    def status(self, timeout: Optional[float] = None) -> ResultStatus:
        """Block for the terminal status."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"query {self.name!r} not terminal after {timeout}s")
        assert self._status is not None
        return self._status

    def result(self, timeout: Optional[float] = None) -> MatchSet:
        """Block for the terminal :class:`MatchSet`; raise
        :class:`ServiceError` if the query failed."""
        st = self.status(timeout)
        if not st.ok:
            raise ServiceError(f"query {self.name!r} failed: {st.error}")
        assert st.matchset is not None
        return st.matchset

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def mappings(self, timeout: Optional[float] = None) -> List[Tuple[int, ...]]:
        """Concatenation of every streamed chunk, in order — bit-identical
        to ``Enumerator.run(query, collect_matches=...).mappings()``."""
        out: List[Tuple[int, ...]] = []
        for chunk in self.chunks(timeout=timeout):
            out.extend(chunk.mappings)
        return out
