"""Quickstart: the prepared-query session API on a small labeled graph.

  PYTHONPATH=src python examples/quickstart.py

Builds a target, indexes it **once** (`SubgraphIndex`), opens an
`Enumerator` session, and prepares one `Query` per algorithm variant
(RI, RI-DS, RI-DS-SI, RI-DS-SI-FC, and the AC ⇄ FC joint-fixpoint
RI-DS-SI-ACFC).  All queries share the session's shape-bucketed engine
cache, so the engine compiles once and every later run is a cache hit —
the session prints its own counters to prove it.  Finally the same
queries go through `run_batch` (the vmapped multi-query path) and must
produce identical counts.
"""

from repro.core import EngineConfig, Enumerator, SubgraphIndex
from repro.data import graphgen

# A PPI-flavored synthetic target: 400 nodes, dense, 32 labels.
target = graphgen.random_graph(400, 3200, n_labels=32, label_dist="normal", seed=1)
# A 16-edge pattern extracted from the target (=> at least one match exists).
pattern = graphgen.extract_pattern(target, 16, seed=2)
print(f"target: {target.n} nodes / {target.m} arcs; "
      f"pattern: {pattern.n} nodes / {pattern.m} arcs\n")

index = SubgraphIndex.build(target)            # pack the target once
session = Enumerator(index, config=EngineConfig(
    n_workers=8, expand_width=4, steal_chunk=4))

queries = [session.prepare(pattern, variant=v, name=v)
           for v in ("ri", "ri-ds", "ri-ds-si", "ri-ds-si-fc", "ri-ds-si-acfc")]

single = {}
for q in queries:
    ms = session.run(q)
    single[q.name] = (ms.matches, ms.states)
    print(f"{ms.name:12s} matches={ms.matches:<6d} states={ms.states:<8d} "
          f"steps={ms.steps:<6d} steals={ms.steals:<4d} "
          f"prepare={ms.preprocess_s*1e3:6.1f}ms match={ms.match_s:6.2f}s")

info = session.cache_info()
print(f"\nengine compiles={info['compiles']} cache_hits={info['cache_hits']} "
      f"(5 variants, one shape bucket)")

# The batch path shares the same cache and must agree exactly.
for ms in session.run_batch(queries, pack_size=4):
    assert (ms.matches, ms.states) == single[ms.name], ms.name
print("run_batch agrees with run for all variants "
      f"(compiles now {session.cache_info()['compiles']})")

print("\nSearch-space (states) should shrink monotonically RI -> RI-DS-SI-FC;"
      "\nmatch counts must be identical across variants.")
