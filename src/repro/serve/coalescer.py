"""Continuous same-bucket coalescing: the admission → execution scheduler
(DESIGN.md §7).

Pending queries accumulate in buckets keyed by
``Enumerator.coalesce_key`` — the ``(p_pad, max_parents, n_t, w, n_elab
[, deg_cap, nnz])`` pack-compatibility key, extended by the request's
``collect_matches`` budget (a different budget means a different engine
cfg and therefore a different compilation).  A bucket **dispatches** as a
packed lane group the moment either condition holds:

* **lane budget fills**: the bucket reaches ``max_lanes`` entries — a
  full pack, go now; waiting longer only adds latency;
* **batch window closes**: the bucket's *oldest* entry has waited
  ``window_s`` — dispatch partial, padding the missing lanes with inert
  state (shape stability is free; idle lanes freeze immediately).

This is deliberately a plain data structure with an injectable clock and
no thread of its own: the service's single dispatcher thread drives it,
which keeps dispatch order deterministic (FIFO within a bucket, buckets
by fill/ripeness order) and keeps all JAX dispatch on one thread.
"""

from __future__ import annotations

import collections
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

Batch = Tuple[Any, List[Any]]  # (key, items)


class Coalescer:
    """Same-key batch accumulator with a lane budget and a time window."""

    def __init__(
        self,
        max_lanes: int = 8,
        window_s: float = 0.002,
        clock: Callable[[], float] = time.monotonic,
    ):
        if max_lanes < 1:
            raise ValueError(f"max_lanes must be >= 1, got {max_lanes}")
        if window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {window_s}")
        self.max_lanes = max_lanes
        self.window_s = window_s
        self._clock = clock
        # insertion-ordered: the first bucket to receive an entry is the
        # first to ripen, so iteration order == dispatch order
        self._buckets: "collections.OrderedDict[Any, List[Any]]" = collections.OrderedDict()
        self._oldest: Dict[Any, float] = {}

    def add(self, key: Any, item: Any) -> Optional[Batch]:
        """Add ``item`` under ``key``; if that fills the lane budget, the
        full batch is popped and returned for immediate dispatch."""
        bucket = self._buckets.get(key)
        if bucket is None:
            self._buckets[key] = bucket = []
            self._oldest[key] = self._clock()
        bucket.append(item)
        if len(bucket) >= self.max_lanes:
            return self._pop(key)
        return None

    def ripe(self) -> List[Batch]:
        """Pop every bucket whose oldest entry has waited ``window_s``."""
        now = self._clock()
        due = [k for k, t in self._oldest.items() if now - t >= self.window_s]
        return [self._pop(k) for k in due]

    def flush(self) -> List[Batch]:
        """Pop everything (shutdown drain / forced dispatch)."""
        return [self._pop(k) for k in list(self._buckets)]

    def next_deadline(self) -> Optional[float]:
        """Clock time when the earliest bucket ripens (None when empty) —
        the dispatcher sleeps at most until then."""
        if not self._oldest:
            return None
        return min(self._oldest.values()) + self.window_s

    def pending(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    def _pop(self, key: Any) -> Batch:
        items = self._buckets.pop(key)
        del self._oldest[key]
        return (key, items)
