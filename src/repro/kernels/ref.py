"""Pure-jnp oracles for the Pallas kernels.

Each function mirrors its kernel's contract exactly; the kernel tests sweep
shapes/dtypes and assert bit-exact equality (these are integer bitwise ops —
no tolerance needed).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def candidate_mask_ref(
    rows: jnp.ndarray,  # [n_rows + 1, w] uint32 (last row all-ones neutral)
    dom_bits: jnp.ndarray,  # [p_pad, w] uint32
    pos: jnp.ndarray,  # [b] int32 order position per lane
    row_idx: jnp.ndarray,  # [b, mp] int32 flattened adjacency row per parent
    used: jnp.ndarray,  # [b, w] uint32
) -> jnp.ndarray:
    """``dom[pos] ∧ ¬used ∧ ⋀_j rows[row_idx[:, j]]`` per lane.

    ``row_idx`` entries must already point at the neutral all-ones row for
    unused parent slots.
    """
    cand = dom_bits[pos] & ~used  # [b, w]

    def body(j, c):
        return c & rows[row_idx[:, j]]

    return lax.fori_loop(0, row_idx.shape[1], body, cand)


def extend_step_ref(
    rows: jnp.ndarray,  # [n_rows + 1, w] uint32 (last row all-ones neutral)
    dom_bits: jnp.ndarray,  # [p_pad, w] uint32
    child_pos: jnp.ndarray,  # [b] int32 order position of the child
    row_idx: jnp.ndarray,  # [b, mp] int32 (unused slots -> n_rows)
    depth: jnp.ndarray,  # [b] int32 depth of the popped entry
    n_p: jnp.ndarray,  # scalar int32 actual pattern size
    used: jnp.ndarray,  # [b, w] uint32
    cand: jnp.ndarray,  # [b, w] uint32
):
    """Oracle for the fused expansion step `repro.kernels.extend_step`.

    Per lane: extract the lowest set candidate bit ``v`` (``cand2`` is the
    residual), build ``child = dom[child_pos] ∧ ¬used ∧ ¬bit(v) ∧ ⋀_j
    rows[row_idx[:, j]]``, zero it unless a child is wanted, and emit
    ``meta = (valid, v, is_match, has_child)`` int32 columns (``v`` is -1
    on invalid lanes).  Returns ``(cand2, child_cand, meta)``.
    """
    b, w = cand.shape
    nz = cand != 0
    valid = jnp.any(nz, axis=-1)
    widx = jnp.argmax(nz, axis=-1)  # first non-zero word (0 if none)
    word = jnp.take_along_axis(cand, widx[:, None], axis=-1)[:, 0]
    tz = lax.population_count(~word & (word - jnp.uint32(1)))
    v = widx.astype(jnp.int32) * 32 + tz.astype(jnp.int32)
    lowbit = word & (~word + jnp.uint32(1))
    sel = (jnp.arange(w)[None, :] == widx[:, None]) & valid[:, None]
    vmask = jnp.where(sel, lowbit[:, None], jnp.uint32(0))
    cand2 = cand ^ vmask

    child = dom_bits[child_pos] & ~used & ~vmask

    def body(j, c):
        return c & rows[row_idx[:, j]]

    if row_idx.shape[1]:  # fori_loop traces its body even for zero trips
        child = lax.fori_loop(0, row_idx.shape[1], body, child)
    is_match = valid & (depth + 1 >= n_p)
    want_child = valid & ~is_match
    child = jnp.where(want_child[:, None], child, jnp.uint32(0))
    has_child = want_child & jnp.any(child != 0, axis=-1)
    meta = jnp.stack(
        [
            valid.astype(jnp.int32),
            jnp.where(valid, v, -1),
            is_match.astype(jnp.int32),
            has_child.astype(jnp.int32),
        ],
        axis=1,
    )
    return cand2, child, meta


def csr_extend_ref(
    indices: jnp.ndarray,  # [nnz_pad + deg_cap] int32 flat CSR columns
    dom_bits: jnp.ndarray,  # [p_pad, w] uint32
    seg_start: jnp.ndarray,  # [b, mp] int32 segment offsets into ``indices``
    seg_len: jnp.ndarray,  # [b, mp] int32 (-1 on unused parent slots)
    child_pos: jnp.ndarray,  # [b] int32 order position of the child
    depth: jnp.ndarray,  # [b] int32 depth of the popped entry
    n_p: jnp.ndarray,  # scalar int32 actual pattern size
    used: jnp.ndarray,  # [b, w] uint32
    cand: jnp.ndarray,  # [b, w] uint32
    *,
    deg_cap: int,
):
    """Oracle for the sparse expansion step `repro.kernels.csr_extend` —
    and the jnp compute path of `repro.core.extend.CsrStepBackend`
    (DESIGN.md §6.4).

    Per lane: extract the lowest set candidate bit ``v`` (``cand2`` is the
    residual) and form ``base = dom[child_pos] ∧ ¬used ∧ ¬bit(v)``; then,
    instead of ANDing dense adjacency rows, gather the **first** real
    parent's CSR neighbor segment (``deg_cap``-wide, sorted + deduped) and
    keep each proposed node iff its bit is set in ``base`` and a binary
    search finds it in every other real parent's segment (sorted
    intersection).  Survivors scatter into the child bitmap; parentless
    lanes (all ``seg_len < 0``) fall back to ``base``.  Returns
    ``(cand2, child_cand, meta)`` with ``meta`` columns
    ``(valid, v, is_match, has_child)`` exactly as `extend_step_ref`.
    """
    b, w = cand.shape
    mp = seg_len.shape[1]
    sentinel = jnp.int32(2**31 - 1)

    # --- lowest-bit extraction (identical to extend_step_ref) -------------
    nz = cand != 0
    valid = jnp.any(nz, axis=-1)
    widx = jnp.argmax(nz, axis=-1)
    word0 = jnp.take_along_axis(cand, widx[:, None], axis=-1)[:, 0]
    tz = lax.population_count(~word0 & (word0 - jnp.uint32(1)))
    v = widx.astype(jnp.int32) * 32 + tz.astype(jnp.int32)
    lowbit = word0 & (~word0 + jnp.uint32(1))
    sel = (jnp.arange(w)[None, :] == widx[:, None]) & valid[:, None]
    vmask = jnp.where(sel, lowbit[:, None], jnp.uint32(0))
    cand2 = cand ^ vmask

    base = dom_bits[child_pos] & ~used & ~vmask  # [b, w]

    # --- the CSR walk ------------------------------------------------------
    real = seg_len >= 0
    has_parent = jnp.any(real, axis=1)
    d = jnp.argmax(real, axis=1)  # driver = first real parent
    bidx = jnp.arange(b, dtype=jnp.int32)
    d_start = seg_start[bidx, d]
    d_len = jnp.where(has_parent, seg_len[bidx, d], 0)
    offs = jnp.arange(deg_cap, dtype=jnp.int32)[None, :]  # [1, K]
    u = indices[d_start[:, None] + offs]  # [b, K]
    k_on = offs < d_len[:, None]
    dup = jnp.concatenate(
        [jnp.zeros((b, 1), bool), u[:, 1:] == u[:, :-1]], axis=1
    )
    ok = k_on & ~dup
    u_c = jnp.clip(u, 0, w * 32 - 1)
    word = u_c // 32
    bit = (u_c % 32).astype(jnp.uint32)
    in_base = (jnp.take_along_axis(base, word, axis=1) >> bit) & jnp.uint32(1)
    ok = ok & (in_base != 0)

    def member(j, ok):
        seg = indices[seg_start[:, j][:, None] + offs]
        seg = jnp.where(offs < seg_len[:, j][:, None], seg, sentinel)
        p = jax.vmap(jnp.searchsorted)(seg, u)
        hit = jnp.take_along_axis(seg, jnp.clip(p, 0, deg_cap - 1), axis=1) == u
        skip = (~real[:, j]) | (j == d)
        return ok & (skip[:, None] | hit)

    ok = lax.fori_loop(0, mp, member, ok)
    bits = jnp.where(ok, jnp.uint32(1) << bit, jnp.uint32(0))
    w_scatter = jnp.where(ok, word, w)  # out-of-range ⇒ dropped
    walked = (
        jnp.zeros((b, w), jnp.uint32)
        .at[bidx[:, None], w_scatter]
        .add(bits, mode="drop")
    )
    child = jnp.where(has_parent[:, None], walked, base)

    # --- match / child flagging (identical to extend_step_ref) ------------
    is_match = valid & (depth + 1 >= n_p)
    want_child = valid & ~is_match
    child = jnp.where(want_child[:, None], child, jnp.uint32(0))
    has_child = want_child & jnp.any(child != 0, axis=-1)
    meta = jnp.stack(
        [
            valid.astype(jnp.int32),
            jnp.where(valid, v, -1),
            is_match.astype(jnp.int32),
            has_child.astype(jnp.int32),
        ],
        axis=1,
    )
    return cand2, child, meta


def csr_extend_bucketed_ref(
    indices: jnp.ndarray,  # [nnz_pad + deg_cap] int32 flat CSR columns
    dom_bits: jnp.ndarray,  # [p_pad, w] uint32
    seg_start: jnp.ndarray,  # [b, mp] int32 segment offsets into ``indices``
    seg_len: jnp.ndarray,  # [b, mp] int32 (-1 on unused parent slots)
    child_pos: jnp.ndarray,  # [b] int32 order position of the child
    depth: jnp.ndarray,  # [b] int32 depth of the popped entry
    n_p: jnp.ndarray,  # scalar int32 actual pattern size
    used: jnp.ndarray,  # [b, w] uint32
    cand: jnp.ndarray,  # [b, w] uint32
    *,
    deg_cap: int,
    chunk: int = 8,
):
    """Degree-bucketed variant of :func:`csr_extend_ref` (DESIGN.md §10).

    Same contract, same results.  Two changes to the walk make hub-heavy
    targets cheap:

    * the driver segment is consumed in ``chunk``-wide trips, and each lane
      stops at its **pow2 degree-bucket cap** (`repro.core.graph
      .deg_bucket_caps`) instead of the global hub-sized ``deg_cap`` — a
      batch of tail rows does ``O(chunk)`` work per lane, and the
      ``while_loop`` bound is the *batch* maximum, so a hub lane only slows
      its own batch;
    * membership in the other parents' segments is a branchless
      lower-bound **binary search on the flat ``indices`` array** with
      dynamic per-parent bounds — no ``deg_cap``-wide segment gathers at
      all.
    """
    b, w = cand.shape
    mp = seg_len.shape[1]
    n_idx = indices.shape[0]

    # --- lowest-bit extraction (identical to csr_extend_ref) ---------------
    nz = cand != 0
    valid = jnp.any(nz, axis=-1)
    widx = jnp.argmax(nz, axis=-1)
    word0 = jnp.take_along_axis(cand, widx[:, None], axis=-1)[:, 0]
    tz = lax.population_count(~word0 & (word0 - jnp.uint32(1)))
    v = widx.astype(jnp.int32) * 32 + tz.astype(jnp.int32)
    lowbit = word0 & (~word0 + jnp.uint32(1))
    sel = (jnp.arange(w)[None, :] == widx[:, None]) & valid[:, None]
    vmask = jnp.where(sel, lowbit[:, None], jnp.uint32(0))
    cand2 = cand ^ vmask

    base = dom_bits[child_pos] & ~used & ~vmask  # [b, w]

    # --- bucketed driver walk ----------------------------------------------
    real = seg_len >= 0
    has_parent = jnp.any(real, axis=1)
    d = jnp.argmax(real, axis=1)
    bidx = jnp.arange(b, dtype=jnp.int32)
    d_start = seg_start[bidx, d]
    d_len = jnp.where(has_parent, seg_len[bidx, d], 0)

    # per-lane pow2 bucket cap: smallest ladder cap >= d_len, clamped so
    # trips * chunk never exceeds the over-padded deg_cap gather region
    m = jnp.maximum(d_len, 1) - 1
    for shift in (1, 2, 4, 8, 16):
        m = m | (m >> shift)
    bcap = jnp.minimum(jnp.maximum(m + 1, chunk), deg_cap)
    trips = (bcap + chunk - 1) // chunk  # [b]
    n_trips = jnp.max(trips)

    offs_c = jnp.arange(chunk, dtype=jnp.int32)[None, :]  # [1, chunk]
    lo0 = seg_start  # [b, mp] global flat offsets
    hi0 = lo0 + jnp.maximum(seg_len, 0)
    search_iters = max(1, deg_cap).bit_length() + 1

    def member(j, carry):
        u, ok = carry
        lo = jnp.broadcast_to(lo0[:, j][:, None], u.shape)
        hi = jnp.broadcast_to(hi0[:, j][:, None], u.shape)

        def step(_, lh):
            lo, hi = lh
            pred = lo < hi
            mid = (lo + hi) >> 1
            val = indices[jnp.clip(mid, 0, n_idx - 1)]
            go = pred & (val < u)
            return jnp.where(go, mid + 1, lo), jnp.where(pred & ~go, mid, hi)

        lo, _ = lax.fori_loop(0, search_iters, step, (lo, hi))
        hit = (lo < hi0[:, j][:, None]) & (indices[jnp.clip(lo, 0, n_idx - 1)] == u)
        skip = (~real[:, j]) | (j == d)
        return u, ok & (skip[:, None] | hit)

    def trip(state):
        i, prev, walked = state
        u = indices[d_start[:, None] + i * chunk + offs_c]  # [b, chunk]
        k_on = (i * chunk + offs_c) < d_len[:, None]
        left = jnp.concatenate([prev[:, None], u[:, :-1]], axis=1)
        ok = k_on & (u != left)  # rows are deduped; boundary-safe defense
        rem = jnp.clip(d_len - i * chunk, 0, chunk)
        last = jnp.take_along_axis(u, jnp.maximum(rem - 1, 0)[:, None], axis=1)[:, 0]
        prev2 = jnp.where(rem > 0, last, prev)

        u_c = jnp.clip(u, 0, w * 32 - 1)
        word = u_c // 32
        bit = (u_c % 32).astype(jnp.uint32)
        in_base = (jnp.take_along_axis(base, word, axis=1) >> bit) & jnp.uint32(1)
        ok = ok & (in_base != 0)
        _, ok = lax.fori_loop(0, mp, member, (u, ok))
        bits = jnp.where(ok, jnp.uint32(1) << bit, jnp.uint32(0))
        w_scatter = jnp.where(ok, word, w)  # out-of-range ⇒ dropped
        walked = walked.at[bidx[:, None], w_scatter].add(bits, mode="drop")
        return i + 1, prev2, walked

    _, _, walked = lax.while_loop(
        lambda s: s[0] < n_trips,
        trip,
        (jnp.int32(0), jnp.full((b,), -1, jnp.int32), jnp.zeros((b, w), jnp.uint32)),
    )
    child = jnp.where(has_parent[:, None], walked, base)

    # --- match / child flagging (identical to csr_extend_ref) --------------
    is_match = valid & (depth + 1 >= n_p)
    want_child = valid & ~is_match
    child = jnp.where(want_child[:, None], child, jnp.uint32(0))
    has_child = want_child & jnp.any(child != 0, axis=-1)
    meta = jnp.stack(
        [
            valid.astype(jnp.int32),
            jnp.where(valid, v, -1),
            is_match.astype(jnp.int32),
            has_child.astype(jnp.int32),
        ],
        axis=1,
    )
    return cand2, child, meta


def adjacency_any_ref(rows: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Per-row "does ``rows[t] ∧ mask`` have any set bit" — the inner test of
    RI-DS arc consistency.  Returns ``[n_t]`` int32 in {0, 1}."""
    return jnp.any((rows & mask[None, :]) != 0, axis=-1).astype(jnp.int32)


def arc_any_sweep_ref(
    adj_flat: jnp.ndarray,  # [n_planes, n_t, w] uint32
    arc_row: jnp.ndarray,  # [n_arcs] int32
    masks: jnp.ndarray,  # [n_arcs, w] uint32
) -> jnp.ndarray:
    """All arcs of one AC sweep: ``out[a, t] = any(adj_flat[arc_row[a], t] ∧
    masks[a])`` — the oracle for `repro.kernels.domain_ac.arc_any_sweep`.
    Sequential over arcs (``lax.map``) to avoid materializing the
    ``[n_arcs, n_t, w]`` gather."""
    def one(x):
        r, m = x
        return adjacency_any_ref(adj_flat[r], m)

    return lax.map(one, (arc_row, masks))


def csr_arc_sweep_ref(
    seg_start: jnp.ndarray,  # [n_planes, n_t] int32 global offsets into indices
    seg_len: jnp.ndarray,  # [n_planes, n_t] int32 row lengths
    indices: jnp.ndarray,  # [n_idx] int32 flat CSR columns (sentinel tail)
    arc_row: jnp.ndarray,  # [n_arcs] int32 plane index per arc
    masks: jnp.ndarray,  # [n_arcs, w] uint32 (D(q) bitmap per arc)
    *,
    deg_cap: int,
) -> jnp.ndarray:
    """All arcs of one **CSR** AC sweep: ``out[a, t] = any(u in
    row(arc_row[a], t) : bit u set in masks[a])`` — the oracle for
    `repro.kernels.domain_ac.csr_arc_sweep`, and the jnp compute path of
    the sparse domain fixpoint (DESIGN.md §11).

    Matches the kernel's walk contract exactly: each row is consumed for at
    most ``deg_cap`` entries (the global padded row cap — never truncating
    on well-formed `CsrPlanes`).  Instead of ``deg_cap``-wide segment
    gathers, the oracle bit-tests the whole flat ``indices`` array once per
    arc and reduces each row by a prefix-sum difference over its
    ``[seg_start, seg_start + seg_len)`` span — ``O(nnz)`` transient per
    arc, sequential over arcs (``lax.map``), and vmappable over a pattern
    batch (the scalar-prefetch kernel is not).
    """
    n_idx = indices.shape[0]
    w = masks.shape[1]
    sl = jnp.minimum(seg_len, deg_cap)
    u_c = jnp.clip(indices, 0, w * 32 - 1)
    word = u_c // 32
    bit = (u_c % 32).astype(jnp.uint32)
    node_ok = (indices >= 0) & (indices < w * 32)  # sentinel tail drops out

    def one(x):
        r, m = x
        hits = jnp.where(node_ok, ((m[word] >> bit) & jnp.uint32(1)).astype(jnp.int32), 0)
        c = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(hits)])
        lo = jnp.clip(seg_start[r], 0, n_idx)
        hi = jnp.clip(seg_start[r] + sl[r], lo, n_idx)
        return ((c[hi] - c[lo]) > 0).astype(jnp.int32)

    return lax.map(one, (arc_row, masks))


def popcount_rows_ref(bits: jnp.ndarray) -> jnp.ndarray:
    """Per-row popcount of ``[n, w]`` uint32 bitmaps -> ``[n]`` int32."""
    return jnp.sum(lax.population_count(bits), axis=-1).astype(jnp.int32)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Dense causal attention oracle for the flash kernel.

    q/k/v: [BH, S, d]; returns [BH, S, d].
    """
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (q.shape[-1] ** 0.5)
    n_q, n_k = s.shape[-2:]
    mask = jnp.arange(n_q)[:, None] >= jnp.arange(n_k)[None, :]
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def pack_bits_ref(flags: jnp.ndarray, w: int) -> jnp.ndarray:
    """Pack ``[n]`` {0,1} int32 flags into a ``[w]`` uint32 bitmap."""
    n = flags.shape[0]
    padded = jnp.zeros((w * 32,), jnp.uint32).at[:n].set(flags.astype(jnp.uint32))
    words = padded.reshape(w, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, :]
    return jnp.sum(words * weights, axis=-1, dtype=jnp.uint32)
